"""Durable-state integrity: verify-on-restore, generation fallback, chaos.

Covers the durability contract (EXPERIMENTS.md §Durability):

* clean-path fidelity: a verified restore of an intact checkpoint is BITWISE
  identical to the pre-integrity restore path, and saves with the envelope
  disabled restore identically to saves with it on;
* detection: every storage fault kind (bit flip, truncation, torn write,
  missing file) against both checkpoint generations and exported serve
  bundles raises a TYPED error naming the failing file/array/field — no
  corrupt state ever reaches the trainer or the engine;
* generation fallback: the restore walk skips corrupt generations newest-
  first, QUARANTINES them (rename — the bytes never leave the disk), and
  returns the newest verified generation with the depth reported;
* the serve watchdog refuses a hot-swap of a corrupt bundle and keeps
  serving the old field; a clean re-export swaps in;
* satellites: ``latest_step`` skips unreadable step dirs with a warning,
  ``parse_faults`` rejects unknown kinds listing the allowed vocabulary,
  ``load_bundle`` turns truncated/garbage npz into ``CorruptBundleError``.

The unmarked tests are the always-on tier-1 subset; the full fault-kind x
target x geometry matrix runs under ``-m chaos`` (see pytest.ini).
"""
import json
import os
import warnings

import numpy as np
import pytest

import jax

from repro.checkpoint import ckpt, integrity
from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology, us_map_decomposition,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
from repro.data import make_batch
from repro.launch.serve_field import reload_bundle
from repro.runtime import (
    ChaosInjector, Fault, STORAGE_FAULT_KINDS, Supervisor, SupervisorConfig,
    compose, corrupt_generation, parse_faults,
)
from repro.serve import (
    CorruptBundleError, FieldEngine, ServeFrontend, export_bundle,
    load_bundle,
)

KINDS = list(STORAGE_FAULT_KINDS)


def _tree(seed=0, n=3, shape=(4, 8, 8)):
    rng = np.random.default_rng(seed)
    return {"params": {"W": [rng.standard_normal(shape).astype(np.float32)
                             for _ in range(n)],
                       "b": rng.standard_normal(shape[:1]).astype(np.float32)}}


def _like(tree):
    return jax.tree.map(lambda x: np.zeros_like(x), tree)


def _save_gens(root, n=2, seed=0, **kw):
    for i in range(1, n + 1):
        ckpt.save(root, i * 10, _tree(seed + i), **kw)


def _geometry(family):
    if family == "cartesian":
        return CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    return us_map_decomposition()


def _export(root, family, seed=0, step=1):
    dec = _geometry(family)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 8, 2)})
    params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed))
    export_bundle(root, params, cfg, dec, act_codes=np.asarray(codes),
                  pde=Burgers1D(), step=step)
    return dec, cfg, params


# --------------------------------------------------------------- clean path

def test_verified_restore_bitwise_matches_plain_restore(tmp_path):
    root = str(tmp_path / "ckpt")
    tree = _tree()
    ckpt.save(root, 7, tree, metadata={"k": 1})
    plain, meta_p = ckpt.restore(root, _like(tree))
    verified, meta_v, info = integrity.verified_restore(root, _like(tree))
    assert info.step == 7 and info.fallback_depth == 0
    assert info.status == "verified" and not info.quarantined
    assert meta_p == meta_v
    for a, b in zip(jax.tree.leaves(plain), jax.tree.leaves(verified)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_integrity_toggle_restores_identically(tmp_path):
    tree = _tree()
    r_on, r_off = str(tmp_path / "on"), str(tmp_path / "off")
    ckpt.save(r_on, 1, tree, integrity=True)
    ckpt.save(r_off, 1, tree, integrity=False)
    t_on, _ = ckpt.restore(r_on, _like(tree))
    t_off, _ = ckpt.restore(r_off, _like(tree))
    for a, b in zip(jax.tree.leaves(t_on), jax.tree.leaves(t_off)):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
    # the envelope is one manifest key; the npz bytes are identical
    assert integrity.verify_step_dir(
        os.path.join(r_on, "step_0000000001")) == "verified"
    assert integrity.verify_step_dir(
        os.path.join(r_off, "step_0000000001")) == "legacy"


def test_generation_chain_records_parent(tmp_path):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=3)
    parents = []
    for _step, name in integrity.generations(root):
        with open(os.path.join(root, name, "manifest.json")) as f:
            parents.append(json.load(f)["integrity"]["parent"])
    assert parents == ["step_0000000020", "step_0000000010", None]


# ---------------------------------------------------------------- detection

def test_manifest_tamper_detected(tmp_path):
    root = str(tmp_path / "ckpt")
    ckpt.save(root, 1, _tree(), metadata={"lr": 1e-3})
    man = os.path.join(root, "step_0000000001", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    m["metadata"]["lr"] = 1.0  # silent hyperparameter rot
    with open(man, "w") as f:
        json.dump(m, f)
    with pytest.raises(integrity.CorruptCheckpointError,
                       match="digest mismatch"):
        integrity.verify_step_dir(os.path.join(root, "step_0000000001"))


def test_swapped_npz_detected(tmp_path):
    """zip-internal CRCs can't catch a whole-file swap; the manifest can."""
    root, other = str(tmp_path / "a"), str(tmp_path / "b")
    ckpt.save(root, 1, _tree(seed=0))
    ckpt.save(other, 1, _tree(seed=9), integrity=False)
    os.replace(os.path.join(other, "step_0000000001", "arrays.npz"),
               os.path.join(root, "step_0000000001", "arrays.npz"))
    with pytest.raises(integrity.CorruptCheckpointError,
                       match="checksum mismatch") as ei:
        integrity.verify_step_dir(os.path.join(root, "step_0000000001"))
    assert ei.value.array is not None


@pytest.mark.parametrize("kind", ["bit_flip", "truncate"])
def test_ckpt_fault_detected_and_fallback(tmp_path, kind):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=2)
    corrupt_generation(root, kind, 0, np.random.default_rng(3))
    info = integrity.latest_verified_step(root)
    assert info.step == 10 and info.fallback_depth == 1
    assert [n for n, _r in info.quarantined] == ["step_0000000020"]
    tree, _m, info2 = integrity.verified_restore(root, _like(_tree(1)))
    assert info2.step == 10
    want = jax.tree.leaves(_tree(1))
    for a, b in zip(jax.tree.leaves(tree), want):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_quarantine_never_deletes(tmp_path):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=2)
    gen = os.path.join(root, "step_0000000020")
    sizes = {f: os.path.getsize(os.path.join(gen, f))
             for f in os.listdir(gen)}
    corrupt_generation(root, "bit_flip", 0, np.random.default_rng(3))
    integrity.latest_verified_step(root)
    qdir = os.path.join(root, integrity.QUARANTINE_PREFIX + "step_0000000020")
    assert os.path.isdir(qdir) and not os.path.exists(gen)
    assert {f: os.path.getsize(os.path.join(qdir, f))
            for f in os.listdir(qdir)} == sizes  # same files, same bytes kept
    # quarantined dirs are invisible to every step scan
    assert ckpt.latest_step(root) == 10
    ckpt.save(root, 30, _tree(2))  # GC must not touch the quarantine
    assert os.path.isdir(qdir)


def test_all_generations_corrupt_raises(tmp_path):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=2)
    for i in (0, 1):
        corrupt_generation(root, "truncate", i, np.random.default_rng(i))
    with pytest.raises(integrity.NoVerifiedCheckpointError):
        integrity.latest_verified_step(root)


def test_max_fallback_bounds_the_walk(tmp_path):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=3)
    for i in (0, 1):
        corrupt_generation(root, "bit_flip", i, np.random.default_rng(i))
    with pytest.raises(integrity.NoVerifiedCheckpointError):
        integrity.latest_verified_step(str(tmp_path / "ckpt2"))
    with pytest.raises(integrity.NoVerifiedCheckpointError):
        # depth 2 would verify, but the budget stops at 1
        integrity.latest_verified_step(root, max_fallback=1,
                                       do_quarantine=False)
    info = integrity.latest_verified_step(root, max_fallback=2)
    assert info.step == 10 and info.fallback_depth == 2


# ------------------------------------------------------------- satellites

def test_latest_step_skips_unreadable_dirs(tmp_path):
    root = str(tmp_path / "ckpt")
    _save_gens(root, n=1)
    # a partially-copied newer generation: dir exists, manifest is garbage,
    # and LATEST got bumped to it before the copy died
    rotten = os.path.join(root, "step_0000000099")
    os.makedirs(rotten)
    with open(os.path.join(rotten, "manifest.json"), "w") as f:
        f.write("{ not json")
    with open(os.path.join(root, "LATEST"), "w") as f:
        f.write("step_0000000099")
    os.makedirs(os.path.join(root, "step_garbagename"))  # unparsable name
    with pytest.warns(RuntimeWarning, match="unreadable checkpoint dir"):
        assert ckpt.latest_step(root) == 10
    # restore follows the same skip: it lands on the readable generation
    tree, _ = ckpt.restore(root, _like(_tree(1)))
    assert np.asarray(jax.tree.leaves(tree)[0]).dtype == np.float32


def test_parse_faults_rejects_unknown_kind():
    with pytest.raises(ValueError) as ei:
        parse_faults("frobnicate@1")
    msg = str(ei.value)
    assert "frobnicate" in msg
    for kind in ("crash", "engine_raise", "bit_flip", "torn_write"):
        assert kind in msg  # the error lists the full allowed vocabulary
    with pytest.raises(ValueError):
        parse_faults("crash")  # malformed: no @chunk
    fs = parse_faults("bundle.torn-write@3:1,ckpt.missing_file@2")
    assert (fs[0].kind, fs[0].target, fs[0].index) == ("torn_write",
                                                       "bundle", 1)
    assert (fs[1].kind, fs[1].target, fs[1].chunk) == ("missing_file",
                                                       "ckpt", 2)


def test_load_bundle_truncated_npz_typed_error(tmp_path):
    root = str(tmp_path / "bundle")
    _export(root, "cartesian")
    npz = os.path.join(root, "step_0000000001", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(CorruptBundleError) as ei:
        load_bundle(root)
    assert "corrupt bundle" in str(ei.value)
    # legacy pre-integrity bundle with the same rot: still typed, names file
    root2 = str(tmp_path / "legacy")
    dec = _geometry("cartesian")
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 8, 2)})
    params, _ = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(0))
    ckpt.save(root2, 1, {"params": params}, integrity=False, metadata={
        "format": "repro.serve.bundle/1",
        "model": {"u": {"in_dim": 2, "out_dim": 1, "width": 8, "depth": 2}},
        "act_codes": [0] * dec.n_sub, "width_mask_nets": [],
        "decomp": {"kind": "cartesian", "bounds": [[-1, 1], [0, 1]],
                   "nx": 2, "ny": 2},
        "pde": None, "n_iface": 16, "user": {}})
    npz2 = os.path.join(root2, "step_0000000001", "arrays.npz")
    with open(npz2, "wb") as f:
        f.write(b"PK\x03\x04 not really a zip")
    with pytest.raises(CorruptBundleError, match="arrays.npz"):
        load_bundle(root2)


def test_load_bundle_bit_flip_names_array_and_field(tmp_path):
    root = str(tmp_path / "bundle")
    _export(root, "cartesian")
    corrupt_generation(root, "bit_flip", 0, np.random.default_rng(3))
    with pytest.raises(CorruptBundleError) as ei:
        load_bundle(root)
    e = ei.value
    assert e.file is not None and "arrays.npz" in e.file
    if e.array is not None:  # localized flip: the field must resolve too
        assert e.field is not None and "params" in e.field


# --------------------------------------------------------- serve watchdog

def test_reload_refused_keeps_old_field_then_swaps(tmp_path):
    root = str(tmp_path / "bundle")
    dec, cfg, params1 = _export(root, "cartesian", seed=0, step=1)
    fe = ServeFrontend(FieldEngine(load_bundle(root)), order=1)
    pts = np.random.default_rng(0).uniform((-1, 0), (1, 1), (24, 2))
    r1 = fe.query(pts)

    corrupt_generation(root, "torn_write", 0, np.random.default_rng(5))
    rep = reload_bundle(fe, root)
    assert rep["swapped"] is False and rep["error"]
    r2 = fe.query(pts + 1e-7)  # fresh signature: not the result cache
    assert np.allclose(np.nan_to_num(r2["u"]), np.nan_to_num(r1["u"]),
                       atol=1e-5)  # the old field still answers

    params2, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(9))
    export_bundle(root, params2, cfg, dec, act_codes=np.asarray(codes),
                  pde=Burgers1D(), step=2)
    rep = reload_bundle(fe, root)
    assert rep["swapped"] is True
    r3 = fe.query(pts)  # same signature as r1: the cache MUST have dropped it
    assert not np.allclose(np.nan_to_num(r3["u"]), np.nan_to_num(r1["u"]),
                           atol=1e-5)  # new params serve now


# ------------------------------------------------------------- chaos driver

def test_chaos_injector_defers_until_target_exists(tmp_path):
    root = str(tmp_path / "ckpt")
    inj = ChaosInjector([Fault(chunk=0, kind="bit_flip", target="ckpt")],
                        roots={"ckpt": root}, seed=0)
    assert inj.take(0) == [] and not inj.storage_fired  # nothing to corrupt
    ckpt.save(root, 1, _tree())
    assert inj.take(1) == []
    assert [r["kind"] for r in inj.storage_fired] == ["bit_flip"]
    with pytest.raises(integrity.CorruptCheckpointError):
        integrity.verify_step_dir(os.path.join(root, "step_0000000001"))


def test_compose_merges_schedules():
    a = [Fault(chunk=3, kind="crash")]
    b = parse_faults("ckpt.bit_flip@1,nan_params@2:0")
    merged = compose(a, b)
    assert [f.chunk for f in merged] == [1, 2, 3]
    assert {f.kind for f in merged} == {"bit_flip", "nan_params", "crash"}


def _setup_train(n_res=48):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"))
    return dec, b, tr


def test_supervisor_survives_poisoned_latest_checkpoint(tmp_path):
    """Storage fault rots the newest generation right before a crash: the
    rollback must detect it, fall back one generation, and the replayed run
    must still finish BITWISE equal to the clean run."""
    dec, b, tr = _setup_train()
    chunk, total = 4, 16

    def run(root, inj):
        sup = Supervisor(tr, root,
                         SupervisorConfig(chunk_steps=chunk,
                                          ckpt_every_chunks=1),
                         inj, decomp=dec)
        return sup.run(tr.init(0), b, total)

    s_clean, _ = run(str(tmp_path / "clean"), None)
    root = str(tmp_path / "chaos")
    inj = ChaosInjector([Fault(chunk=2, kind="bit_flip", target="ckpt"),
                         Fault(chunk=2, kind="crash")],
                        roots={"ckpt": root}, seed=0)
    s_chaos, rep = run(root, inj)
    assert rep.corruptions == 1 and rep.fallback_depths == [1]
    assert rep.crashes == 1 and int(s_chaos.step) == total
    for a, c in zip(jax.tree.leaves(s_chaos.params),
                    jax.tree.leaves(s_clean.params)):
        assert np.asarray(a).tobytes() == np.asarray(c).tobytes()
    assert any(d.startswith(integrity.QUARANTINE_PREFIX)
               for d in os.listdir(root))


# -------------------------------------------------- full matrix (-m chaos)

@pytest.mark.chaos
@pytest.mark.parametrize("family", ["cartesian", "us_map"])
@pytest.mark.parametrize("kind", KINDS)
def test_ckpt_matrix(tmp_path, kind, family):
    dec = _geometry(family)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 8, 2)})
    params, _ = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(0))
    root = str(tmp_path / "ckpt")
    for i in (1, 2):
        p, _ = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(i))
        ckpt.save(root, i, {"params": p})
    corrupt_generation(root, kind, 0, np.random.default_rng(11))
    events = []
    info = integrity.latest_verified_step(
        root, on_event=lambda k, **f: events.append((k, f)))
    assert info.step == 1 and info.fallback_depth == 1
    kinds = [k for k, _f in events]
    assert kinds == ["corruption", "fallback"]


@pytest.mark.chaos
@pytest.mark.parametrize("family", ["cartesian", "us_map"])
@pytest.mark.parametrize("kind", KINDS)
def test_bundle_matrix(tmp_path, kind, family):
    root = str(tmp_path / "bundle")
    _export(root, family, seed=0, step=1)
    before = load_bundle(root)
    _export(root, family, seed=1, step=2)
    corrupt_generation(root, kind, 0, np.random.default_rng(11))
    with pytest.raises(CorruptBundleError):
        load_bundle(root)  # max_fallback=0: hard typed failure
    # the older generation was quarantine-hidden? no — only the corrupt one;
    # with a fallback budget the load walks back to generation 1
    b = load_bundle(root, max_fallback=1)
    for a, c in zip(jax.tree.leaves(b.params), jax.tree.leaves(before.params)):
        assert np.asarray(a).tobytes() == np.asarray(c).tobytes()
