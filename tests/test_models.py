"""Architecture-zoo tests: per-arch smoke (forward/train on CPU, shapes + no NaNs),
decode-vs-teacher-forced parity, MoE drop-free parity, WKV/SSD chunk invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeConfig, active_param_count, param_count
from repro.models import build_model, make_batch
from repro.models import layers as L

SMOKE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_train_step(name):
    """Reduced config: one forward/train step, output shapes + finite values."""
    cfg = ARCHS[name].reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, SMOKE, "train")
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
    logits = m.prefill(params, make_batch(cfg, SMOKE, "prefill"))
    S = SMOKE.seq_len - (cfg.n_patches if cfg.family == "vlm" else 0)
    total = S + (cfg.n_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_param_counts_match_published():
    expect = {
        "yi-34b": 34.4e9, "llama3.2-1b": 1.24e9, "qwen2.5-14b": 14.8e9,
        "minicpm3-4b": 4.3e9, "llava-next-mistral-7b": 7.2e9,
        "zamba2-1.2b": 1.2e9, "deepseek-moe-16b": 16.4e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9, "rwkv6-3b": 2.7e9,
        "seamless-m4t-large-v2": 2.0e9,
    }
    for name, n in expect.items():
        got = param_count(get_config(name))
        assert abs(got - n) / n < 0.12, (name, got, n)
    # MoE active counts match the model names
    assert abs(active_param_count(get_config("deepseek-moe-16b")) - 2.8e9) < 0.2e9
    assert abs(active_param_count(get_config("phi3.5-moe-42b-a6.6b")) - 6.6e9) < 0.4e9


def _decode_parity(name, S=16, B=2, extra=None):
    cfg = ARCHS[name].reduced()
    cfg = dataclasses.replace(cfg, dtype="float32", **(extra or {}))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, S // cfg.enc_ratio, cfg.d_model)), jnp.float32)
    full = m.prefill(params, batch)
    cache = m.init_cache(B, S)
    if cfg.family == "encdec":
        mem = m.encode(params, batch["frames"])
        cks, cvs = [], []
        for l in range(cfg.n_dec_layers):
            lp = jax.tree.map(lambda v: v[l], params["dec"])
            _, mk, mv = L.gqa_project(lp["cross_attn"], mem, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd, mem.dtype)
            cks.append(mk), cvs.append(mv)
        cache = dict(cache)
        cache["cross_k"], cache["cross_v"] = jnp.stack(cks), jnp.stack(cvs)
    outs = []
    for t in range(S):
        logits, cache = m.decode_step(params, cache,
                                      {"tokens": batch["tokens"][:, t:t + 1]}, t)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    return rel


@pytest.mark.parametrize("name", [n for n in sorted(ARCHS)
                                  if ARCHS[n].family != "moe"])
def test_decode_matches_teacher_forced(name):
    """KV-cache/absorbed-MLA/SSD/WKV decode reproduces the full forward."""
    assert _decode_parity(name) < 2e-3, name


@pytest.mark.parametrize("name", ["deepseek-moe-16b", "phi3.5-moe-42b-a6.6b"])
def test_moe_decode_parity_dropfree(name):
    """MoE parity holds exactly when capacity dropping is disabled (the residual
    divergence under default capacity is the documented drop semantics)."""
    assert _decode_parity(name, extra={"capacity_factor": 64.0}) < 1e-4, name


def test_ssd_chunk_size_invariance():
    """Mamba2 SSD: result independent of chunk size (chunking is exact algebra)."""
    from repro.models.ssm import _ssd_chunked
    rng = np.random.default_rng(0)
    B, T, H, P, N = 2, 32, 3, 4, 5
    x = jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (B, T, N)), jnp.float32)
    s0 = jnp.zeros((B, H, P, N))
    y1, sT1 = _ssd_chunked(x, dt, A, Bm, Cm, s0, chunk=4)
    y2, sT2 = _ssd_chunked(x, dt, A, Bm, Cm, s0, chunk=16)
    np.testing.assert_allclose(y1, y2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sT1, sT2, rtol=1e-4, atol=1e-5)


def test_ssd_chunked_matches_stepwise():
    """Chunked scan == token-by-token recurrence (training == decode math)."""
    from repro.models.ssm import _ssd_chunked, _ssd_step
    rng = np.random.default_rng(1)
    B, T, H, P, N = 1, 12, 2, 3, 4
    x = jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (B, T, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 1.5, (H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (B, T, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (B, T, N)), jnp.float32)
    y, sT = _ssd_chunked(x, dt, A, Bm, Cm, jnp.zeros((B, H, P, N)), chunk=4)
    s = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(T):
        yt, s = _ssd_step(x[:, t], dt[:, t], A, Bm[:, t], Cm[:, t], s)
        ys.append(yt)
    np.testing.assert_allclose(y, jnp.stack(ys, 1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sT, s, rtol=1e-4, atol=1e-5)


def test_wkv6_chunked_matches_stepwise():
    from repro.models.ssm import _wkv6_chunked, _wkv6_step
    rng = np.random.default_rng(2)
    B, T, H, P = 1, 12, 2, 4
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32) for _ in range(3))
    w = jnp.asarray(rng.uniform(0.2, 0.95, (B, T, H, P)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, P)), jnp.float32)
    y, sT = _wkv6_chunked(r, k, v, w, u, jnp.zeros((B, H, P, P)), chunk=4)
    s = jnp.zeros((B, H, P, P))
    ys = []
    for t in range(T):
        yt, s = _wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, s)
        ys.append(yt)
    np.testing.assert_allclose(y, jnp.stack(ys, 1), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(sT, s, rtol=1e-4, atol=1e-5)


def test_chunked_attention_matches_reference():
    from repro.kernels import ref
    rng = np.random.default_rng(3)
    B, H, Hk, S, dh = 2, 8, 2, 64, 16
    q = jnp.asarray(rng.normal(0, 1, (B, S, H, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, S, Hk, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, S, Hk, dh)), jnp.float32)
    out = L.chunked_attention(q, k, v, causal=True, block_q=16)
    r = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, r, rtol=2e-4, atol=2e-4)


def test_fused_ce_matches_plain():
    rng = np.random.default_rng(4)
    B, S, D, V = 2, 24, 8, 50
    x = jnp.asarray(rng.normal(0, 1, (B, S, D)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.2, (D, V)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, V, (B, S)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (B, S)), jnp.float32)
    fused = L.fused_head_cross_entropy(x, w, labels, mask, chunk=7)
    plain = L.cross_entropy(x @ w, labels, mask)
    np.testing.assert_allclose(fused, plain, rtol=1e-5)
    # fused CE gradients match too
    g1 = jax.grad(lambda w: L.fused_head_cross_entropy(x, w, labels, mask, chunk=7))(w)
    g2 = jax.grad(lambda w: L.cross_entropy(x @ w, labels, mask))(w)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-6)
