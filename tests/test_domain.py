"""Decomposition + topology invariants (paper Fig 3), incl. hypothesis properties."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # container may lack hypothesis; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.domain import (
    CartesianDecomposition, PolygonDecomposition, build_topology,
    us_map_decomposition,
)


@given(nx=st.integers(1, 6), ny=st.integers(1, 6), n_iface=st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_cartesian_topology_invariants(nx, ny, n_iface):
    dec = CartesianDecomposition(((-1, 1), (0, 2)), nx, ny)
    topo = build_topology(dec, n_iface)
    n_edges_expected = (nx - 1) * ny + nx * (ny - 1)
    assert int(topo.edge_mask.sum()) == 2 * n_edges_expected  # both endpoints
    # edge coloring: matching property — neighbor[neighbor[q,k],k] == q
    for q in range(topo.n_sub):
        for k in range(topo.n_slots):
            nb = topo.neighbor[q, k]
            if nb >= 0:
                assert topo.neighbor[nb, k] == q
                # shared physical points identical on both sides
                np.testing.assert_array_equal(topo.iface_points[q, k],
                                              topo.iface_points[nb, k])
                # outward normals are opposite and unit
                np.testing.assert_allclose(topo.iface_normal[q, k],
                                           -topo.iface_normal[nb, k])
                np.testing.assert_allclose(
                    np.linalg.norm(topo.iface_normal[q, k], axis=-1), 1.0, rtol=1e-6)
    # perms are permutations of pairs: each (src,dst) unique per slot
    for perm in topo.perms:
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts)


@given(nx=st.integers(1, 5), ny=st.integers(1, 5))
@settings(max_examples=20, deadline=None)
def test_cartesian_interior_sampling(nx, ny):
    dec = CartesianDecomposition(((0, 1), (0, 1)), nx, ny)
    rng = np.random.default_rng(0)
    for q in range(dec.n_sub):
        pts = dec.sample_interior(q, 50, rng)
        assert dec.subdomain_contains(q, pts).all()


def test_cartesian_rank_map_paper_eq7():
    dec = CartesianDecomposition(((0, 1), (0, 1)), 4, 3)
    for q in range(12):
        ix, iy = dec.grid_index(q)
        assert dec.rank(ix, iy) == q


def test_boundary_segments_only_on_outer_walls():
    dec = CartesianDecomposition(((0, 1), (0, 1)), 3, 3)
    assert dec.boundary_segments(4) == []       # center subdomain
    assert len(dec.boundary_segments(0)) == 2   # corner


def test_us_map_ten_regions():
    dec = us_map_decomposition()
    assert dec.n_sub == 10
    topo = build_topology(dec, 16)
    # the 5x2 lattice has 13 internal interfaces
    assert int(topo.edge_mask.sum()) == 2 * 13
    assert topo.max_degree <= topo.n_slots <= topo.max_degree + 1  # Vizing-ish greedy
    # each region's sampled interior points stay inside its polygon
    rng = np.random.default_rng(1)
    for q in range(10):
        pts = dec.sample_interior(q, 40, rng)
        assert dec.subdomain_contains(q, pts).all()
    # regions tile the bounding rectangle: areas sum to 5x2
    def poly_area(p):
        x, y = p[:, 0], p[:, 1]
        return 0.5 * abs(np.dot(x, np.roll(y, 1)) - np.dot(y, np.roll(x, 1)))
    assert abs(sum(poly_area(p) for p in dec.polygons) - 10.0) < 1e-6


def test_polygon_shared_edges_exact():
    a = np.array([[0, 0], [1, 0], [1, 1], [0, 1]], float)
    b = np.array([[1, 0], [2, 0], [2, 1], [1, 1]], float)
    dec = PolygonDecomposition([a, b])
    edges = dec.interface_edges(8)
    assert len(edges) == 1
    e = edges[0]
    assert (e.a, e.b) == (0, 1)
    np.testing.assert_allclose(e.points[:, 0], 1.0)      # on shared line x=1
    np.testing.assert_allclose(e.normal_a, [[1.0, 0.0]] * 8)  # outward from region 0
