"""End-to-end behaviour: trained XPINN/cPINN solutions approach the exact PDE
solutions; the inverse problem recovers the conductivity; serving generates."""
import jax
import numpy as np
import pytest

from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, HeatConduction2D, LossWeights,
    ReferenceTrainer, XPINN, build_topology, evaluate_l2, us_map_decomposition,
)
from repro.core.losses import CPINN
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch


@pytest.mark.slow
def test_burgers_xpinn_converges_toward_exact():
    """Space-time XPINN on Burgers: rel-L2 vs Cole-Hopf drops well below init."""
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, 20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    rng = np.random.default_rng(0)
    batch = make_batch(dec, topo, pde, 512, 64, rng)
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(method=XPINN), lrs=2e-3)
    st = tr.init(0)
    b = batch.device_arrays()
    e0 = evaluate_l2(dec, cfg, st.params, tr.act_codes, pde)
    for _ in range(900):
        st, terms = tr.step(st, b)
    e1 = evaluate_l2(dec, cfg, st.params, tr.act_codes, pde)
    assert e1 < 0.45 and e1 < 0.5 * e0, (e0, e1)


@pytest.mark.slow
def test_burgers_cpinn_spatial_converges():
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 4, 1)   # space-only DD
    topo = build_topology(dec, 20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    rng = np.random.default_rng(0)
    batch = make_batch(dec, topo, pde, 512, 64, rng)
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(method=CPINN), lrs=2e-3)
    st = tr.init(0)
    b = batch.device_arrays()
    losses = []
    for _ in range(600):
        st, terms = tr.step(st, b)
        losses.append(float(np.asarray(terms["loss"]).sum()))
    assert losses[-1] < 0.1 * losses[0]


@pytest.mark.slow
def test_inverse_heat_recovers_conductivity():
    """Paper §7.6 (reduced): 10 irregular regions, T observed, K inferred."""
    pde = HeatConduction2D()
    dec = us_map_decomposition()
    topo = build_topology(dec, 12)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 3),
                                     "k": MLPConfig(2, 1, 24, 3)})
    rng = np.random.default_rng(0)
    batch = make_batch(dec, topo, pde, 256, 48, rng, n_interior_data=128)
    # per-subdomain heterogeneity as in the paper's Table 3
    acts = ["tanh", "sin", "cos", "tanh", "sin", "cos", "tanh", "sin", "cos", "tanh"]
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(method=XPINN,
                                                   weights=LossWeights(data=40.0)),
                          act_codes=acts, lrs=4e-3)
    st = tr.init(0)
    b = batch.device_arrays()
    e0 = evaluate_l2(dec, cfg, st.params, tr.act_codes, pde)
    for _ in range(700):
        st, terms = tr.step(st, b)
    e1 = evaluate_l2(dec, cfg, st.params, tr.act_codes, pde)
    assert e1 < 0.1 * e0, (e0, e1)   # T+K jointly converge toward exact


def test_serve_generates_tokens():
    import subprocess, sys, os
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "llama3.2-1b",
         "--batch", "2", "--prompt-len", "8", "--gen", "8"],
        env=env, capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "generated 16 tokens" in res.stdout
