"""Fault-tolerant supervisor: in-graph guards, rollback/backoff, recovery.

Covers the robustness contract (EXPERIMENTS.md §Robustness):

* ``run_chunk_guarded`` bitwise-matches ``run_chunk`` on healthy runs (all
  three trainers; Distributed in a 4-device subprocess) and adds no compute
  to the hot path — the guarded chunk body still traces/packs the megabatched
  network entry exactly once per loss evaluation (trace + HLO asserted);
* injected NaNs trip the guard within ONE chunk, with per-subdomain
  attribution: ``nan_params`` flags the poisoned subdomain and its interface
  neighbors (never the diagonal), ``nan_grads`` keeps the loss finite and is
  caught by the param-norm check alone;
* a crash mid-chunk (compute done, checkpoint lost) rolls back and replays —
  the recovered run equals the uninterrupted run BITWISE on ReferenceTrainer
  and DataParallelTrainer;
* a guard trip rolls back with per-subdomain lr backoff and the retried run
  completes; budget/floor exhaustion raise instead of looping forever;
* checkpoint hygiene: orphaned ``.tmp_step_*`` dirs from a crashed save are
  swept on the next save / latest_step.

The unmarked tests here are the always-on tier-1 subset; the full fault
matrix sweep runs under ``-m ft`` (see pytest.ini).
"""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology,
)
from repro.core.losses import ResidualPath
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.trainer import DataParallelTrainer, TrainState
from repro.data import make_batch
from repro.kernels import ops
from repro.runtime import (
    FAULT_KINDS, Fault, FaultInjector, Supervisor, SupervisorConfig,
    inject_nan, parse_faults,
)


def _setup(n_res=48, width=16, depth=2):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"))
    return pde, dec, cfg, b, tr


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _poison(tr, kind, subdomain):
    st = tr.init(0)
    tree = inject_nan({"params": st.params, "opt": st.opt, "step": st.step},
                      kind, subdomain)
    return TrainState(params=tree["params"], opt=tree["opt"],
                      step=tree["step"])


# ------------------------------------------------------------- guarded chunk

def test_guarded_chunk_matches_unguarded_bitwise():
    pde, dec, cfg, b, tr = _setup()
    s_u, t_u = tr.run_chunk(tr.init(0), b, 5)
    s_g, t_g, health = tr.run_chunk_guarded(tr.init(0), b, 5)
    assert _max_diff(s_u.params, s_g.params) == 0.0
    assert _max_diff(s_u.opt, s_g.opt) == 0.0
    assert int(s_g.step) == 5
    for k in t_u:
        np.testing.assert_array_equal(np.asarray(t_u[k]), np.asarray(t_g[k]))
    assert bool(health["ok"]) and np.asarray(health["ok_sub"]).all()
    assert int(health["good_steps"]) == 5


def test_guarded_data_parallel_matches_unguarded_bitwise():
    pde, dec, cfg, b, tr_ref = _setup()
    tr = DataParallelTrainer(pde, cfg, n_workers=1, residual_path="pallas")
    bd = jax.tree.map(lambda x: x[:1], b)
    s_u, _ = tr.run_chunk(tr.init(0), bd, 4)
    s_g, _, health = tr.run_chunk_guarded(tr.init(0), bd, 4)
    assert _max_diff(s_u["params"], s_g["params"]) == 0.0
    assert _max_diff(s_u["opt"], s_g["opt"]) == 0.0
    assert bool(np.asarray(health["ok"])) and int(health["good_steps"]) == 4


def test_guard_trips_on_nan_params_with_subdomain_attribution():
    """Acceptance: NaN params trip the guard within one chunk.  Attribution:
    the poisoned subdomain AND its interface neighbors go non-finite at the
    same step (the XPINN interface term evaluates both sides), but the
    DIAGONAL subdomain (no shared edge) stays healthy — and the frozen carry
    stops the rot from spreading to it on later steps."""
    pde, dec, cfg, b, tr = _setup()
    s, terms, health = tr.run_chunk_guarded(_poison(tr, "nan_params", 0), b, 5)
    ok_sub = np.asarray(health["ok_sub"])
    assert not bool(health["ok"])
    assert not ok_sub[0]                       # the poisoned subdomain
    assert ok_sub[3]                           # diagonal: no shared interface
    assert int(health["good_steps"]) == 1      # tripped during the first step
    assert int(s.step) == 1                    # carry frozen from then on
    loss = np.asarray(terms["loss"])
    assert np.isnan(loss[0, 0]) and np.isfinite(loss[0, 3])
    assert np.isnan(loss[1:]).all()            # post-trip rows are markers


def test_guard_catches_nan_moments_despite_finite_loss():
    """nan_grads poisons the Adam first moment: the loss computed that step is
    FINITE (params were clean) — only the param-norm check sees the poisoned
    update.  A loss-only guard would ship a corrupted checkpoint."""
    pde, dec, cfg, b, tr = _setup()
    s, terms, health = tr.run_chunk_guarded(_poison(tr, "nan_grads", 0), b, 3)
    ok_sub = np.asarray(health["ok_sub"])
    assert not bool(health["ok"])
    np.testing.assert_array_equal(ok_sub, [False, True, True, True])
    assert np.isfinite(np.asarray(terms["loss"])[0]).all()
    assert int(health["good_steps"]) == 1


def test_guarded_chunk_adds_no_network_entries_or_weight_packs():
    """Acceptance: the guard adds no extra dispatches.  Trace level: the
    guarded body touches the megabatched entry twice per loss eval — one
    abstract ``eval_shape`` structure probe (compiles to nothing) plus the ONE
    live ``lax.cond`` branch — independent of chunk length.  HLO level: the
    compiled guarded chunk packs the layer weight stack exactly as often as
    the unguarded chunk (once per loss eval), so the frozen branch and health
    checks add no network compute."""
    pde, dec, cfg, b, tr = _setup(n_res=32)
    tr.res_path = ResidualPath(act="tanh", block_n=32, interpret=True)
    state = tr.init(0)
    ones = jnp.ones((4,), jnp.float32)

    def entries(steps):
        calls = []
        orig = ops.pinn_mlp_forward2
        ops.pinn_mlp_forward2 = lambda *a, **k: (calls.append(1),
                                                 orig(*a, **k))[1]
        try:
            jax.jit(tr._run_chunk_guarded, static_argnums=(2,)).lower(
                state, b, steps, ones)
        finally:
            ops.pinn_mlp_forward2 = orig
        return len(calls)

    assert entries(5) == 2 == entries(1)

    def weight_pads(txt):
        return sum(1 for ln in txt.splitlines()
                   if " pad(" in ln and "f32[4,128,128]" in ln)

    guarded = jax.jit(tr._run_chunk_guarded, static_argnums=(2,)).lower(
        state, b, 3, ones).compile().as_text()
    unguarded = jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(
        state, b, 3).compile().as_text()
    assert weight_pads(guarded) == weight_pads(unguarded) == 3


# ---------------------------------------------------------------- supervisor

def test_supervisor_crash_recovery_bitwise(tmp_path):
    """Acceptance: a crash mid-chunk (compute done, checkpoint lost) recovers
    to EXACTLY the uninterrupted run — replay happens at full lr from the last
    good checkpoint, so the trajectory is bit-identical."""
    pde, dec, cfg, b, tr = _setup()
    injector = FaultInjector([Fault(chunk=1, kind="crash")])
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=3), injector, decomp=dec)
    s_f, report = sup.run(tr.init(0), b, 9)
    assert report.crashes == 1 and report.restarts == 1
    assert report.chunks == 3 and injector.exhausted
    assert len(report.recovery_s) == 1

    s_b = tr.init(0)
    for _ in range(3):
        s_b, _ = tr.run_chunk(s_b, b, 3)
    assert int(s_f.step) == int(s_b.step) == 9
    assert _max_diff(s_f.params, s_b.params) == 0.0
    assert _max_diff(s_f.opt, s_b.opt) == 0.0


def test_supervisor_nan_trip_backoff_retry_completes(tmp_path):
    """Acceptance: injected NaN trips the guard within one chunk, rolls back,
    and the retried run (per-subdomain lr backoff on exactly the subdomains
    that went non-finite) trains to completion with finite state."""
    pde, dec, cfg, b, tr = _setup()
    injector = FaultInjector([Fault(chunk=1, kind="nan_params", subdomain=0)])
    root = str(tmp_path / "ckpt")
    sup = Supervisor(tr, root, SupervisorConfig(chunk_steps=3), injector,
                     decomp=dec)
    s_f, report = sup.run(tr.init(0), b, 9)
    assert report.guard_trips == 1 and report.crashes == 0
    assert report.restarts == 1 and int(s_f.step) == 9
    # backoff hit the tripped subdomains only; the diagonal kept full lr
    assert sup.lr_scale is not None
    assert sup.lr_scale[0] == pytest.approx(0.5)
    assert sup.lr_scale[3] == pytest.approx(1.0)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(s_f.params))
    # the backoff state survives in checkpoint metadata for the next restart
    _, manifest = ckpt.raw_leaves(root)
    meta = manifest["metadata"]["supervisor"]
    assert meta["restarts"] == 1
    assert meta["lr_scale"][0] == pytest.approx(0.5)
    assert len(meta["chunk_walltimes"]) == report.chunks


def test_supervisor_straggler_absorbed_and_walltimes_recorded(tmp_path):
    pde, dec, cfg, b, tr = _setup()
    injector = FaultInjector([Fault(chunk=1, kind="straggler", delay=0.05)])
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=2), injector, decomp=dec)
    s_f, report = sup.run(tr.init(0), b, 6)
    assert report.stragglers == 1 and report.restarts == 0
    assert int(s_f.step) == 6 and len(report.walltimes) == 3
    assert report.walltimes[1] >= 0.05          # the delayed chunk


def test_supervisor_restart_budget_exhausted_raises(tmp_path):
    pde, dec, cfg, b, tr = _setup()
    injector = FaultInjector([Fault(chunk=i, kind="crash") for i in range(6)])
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=2, max_restarts=2), injector)
    with pytest.raises(RuntimeError, match="restart budget"):
        sup.run(tr.init(0), b, 8)


def test_supervisor_backoff_floor_raises(tmp_path):
    pde, dec, cfg, b, tr = _setup()
    injector = FaultInjector([Fault(chunk=1, kind="nan_params", subdomain=0),
                              Fault(chunk=2, kind="nan_params", subdomain=0)])
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=2, lr_backoff=0.5,
                                      min_lr_scale=0.3), injector)
    with pytest.raises(RuntimeError, match="floor"):
        sup.run(tr.init(0), b, 8)


def test_supervisor_data_parallel_crash_recovery_bitwise(tmp_path):
    pde, dec, cfg, b, _ = _setup()
    tr = DataParallelTrainer(pde, cfg, n_workers=1, residual_path="pallas")
    bd = jax.tree.map(lambda x: x[:1], b)
    sup0 = Supervisor(tr, str(tmp_path / "a"), SupervisorConfig(chunk_steps=3))
    s_a, _ = sup0.run(tr.init(0), bd, 9)
    injector = FaultInjector([Fault(chunk=1, kind="crash")])
    sup1 = Supervisor(tr, str(tmp_path / "b"), SupervisorConfig(chunk_steps=3),
                      injector)
    s_b, report = sup1.run(tr.init(0), bd, 9)
    assert report.crashes == 1
    assert _max_diff(s_a["params"], s_b["params"]) == 0.0
    assert _max_diff(s_a["opt"], s_b["opt"]) == 0.0
    assert int(np.asarray(s_b["step"])) == 9


# ------------------------------------------------------------ fault schedule

def test_parse_faults_and_injector_fire_once():
    faults = parse_faults("crash@1, nan_params@2:0, straggler@3*0.5,nan_grads@4")
    assert [f.kind for f in faults] == ["crash", "nan_params", "straggler",
                                       "nan_grads"]
    assert faults[1].subdomain == 0 and faults[2].delay == 0.5
    assert parse_faults("straggler@0")[0].delay == 0.25   # default delay
    inj = FaultInjector(faults)
    assert inj.take(0) == [] and not inj.exhausted
    assert inj.take(1) == [faults[0]]
    assert inj.take(1) == []                              # fires exactly once
    for c in (2, 3, 4):
        inj.take(c)
    assert inj.exhausted and inj.fired == faults
    with pytest.raises(ValueError, match="fault kind"):
        Fault(chunk=0, kind="meteor")
    with pytest.raises(ValueError, match="NaN fault"):
        inject_nan({"params": {}, "opt": {}}, "crash")


# -------------------------------------------------------- checkpoint hygiene

def test_ckpt_sweeps_orphan_tmp_dirs(tmp_path):
    """A crash between mkdtemp and rename leaves ``.tmp_step_*`` behind; the
    next save (and latest_step) sweeps it so long-running jobs don't leak."""
    root = str(tmp_path / "ckpt")
    ckpt.save(root, 1, {"w": np.arange(3.0)})
    stale = os.path.join(root, ".tmp_step_7_deadbeef")
    os.makedirs(stale)
    with open(os.path.join(stale, "arrays.npz"), "wb") as f:
        f.write(b"half-written junk")
    assert ckpt.latest_step(root) == 1          # ignored AND swept
    assert not os.path.exists(stale)
    os.makedirs(stale)
    ckpt.save(root, 2, {"w": np.arange(3.0) + 1})
    assert not os.path.exists(stale)
    tree, _ = ckpt.restore(root, {"w": np.zeros(3)})
    np.testing.assert_array_equal(tree["w"], np.arange(3.0) + 1)
    assert ckpt.latest_step(root) == 2


# ------------------------------------------------------- full fault matrix

@pytest.mark.ft
@pytest.mark.parametrize("kind", FAULT_KINDS)
def test_fault_matrix_reference_trainer_recovers(kind, tmp_path):
    """The full matrix sweep (``-m ft``): every fault kind injected mid-run;
    the supervisor absorbs it and trains to the target step count."""
    pde, dec, cfg, b, tr = _setup()
    fault = Fault(chunk=1, kind=kind,
                  subdomain=0 if kind.startswith("nan") else None,
                  delay=0.02 if kind == "straggler" else 0.0)
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=3), FaultInjector([fault]),
                     decomp=dec)
    s_f, report = sup.run(tr.init(0), b, 9)
    assert int(s_f.step) == 9
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(s_f.params))
    expected = {"crash": (1, 0, 0), "nan_params": (0, 1, 0),
                "nan_grads": (0, 1, 0), "straggler": (0, 0, 1)}[kind]
    assert (report.crashes, report.guard_trips,
            report.stragglers) == expected


# --------------------------------------------------- distributed (subprocess)

DIST_FT_CODE = """
import numpy as np, jax, jax.numpy as jnp, tempfile
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.trainer import TrainState
from repro.data import make_batch
from repro.runtime import Fault, FaultInjector, Supervisor, SupervisorConfig, inject_nan

pde = Burgers1D()
dec = CartesianDecomposition(((-1,1),(0,1)), nx=2, ny=2)
topo = build_topology(dec, n_iface=8)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2,1,16,2)})
b = make_batch(dec, topo, pde, n_res=48, n_bnd=16,
               rng=np.random.default_rng(0)).device_arrays()
tr = DistributedDDTrainer(pde, cfg, topo, DDConfig(method=XPINN, residual_path="pallas"),
                          lrs=[1e-3, 2e-3, 3e-3, 4e-3])
bd = tr.shard_batch(b)
md = lambda a, c: max(float(np.max(np.abs(np.asarray(x)-np.asarray(y))))
                      for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(c)))

# guarded == unguarded on the healthy path (separately compiled SPMD programs:
# float-noise tolerance, same as the run_chunk-vs-step-loop contract)
s_u, t_u = tr.run_chunk(tr.shard_state(tr.init(0)), bd, 4)
s_g, t_g, health = tr.run_chunk_guarded(tr.shard_state(tr.init(0)), bd, 4)
assert md(s_u.params, s_g.params) < 1e-7
assert bool(np.asarray(health["ok"])) and int(np.asarray(health["good_steps"])) == 4
assert np.asarray(health["ok_sub"]).shape == (4,)

# the pmin consensus freezes EVERY rank when one subdomain trips
st = tr.shard_state(tr.init(0))
tree = inject_nan({"params": st.params, "opt": st.opt, "step": st.step},
                  "nan_params", 0)
st = TrainState(params=tree["params"], opt=tree["opt"], step=tree["step"])
s, terms, health = tr.run_chunk_guarded(st, bd, 4)
ok_sub = np.asarray(health["ok_sub"])
assert not bool(np.asarray(health["ok"])) and not ok_sub[0] and ok_sub[3]
assert int(np.asarray(health["good_steps"])) == 1

# supervisor crash recovery over the SPMD trainer
with tempfile.TemporaryDirectory() as d:
    sup = Supervisor(tr, d + "/a", SupervisorConfig(chunk_steps=2))
    s_a, _ = sup.run(tr.shard_state(tr.init(0)), bd, 6)
with tempfile.TemporaryDirectory() as d:
    sup = Supervisor(tr, d + "/b", SupervisorConfig(chunk_steps=2),
                     FaultInjector([Fault(chunk=1, kind="crash")]))
    s_b, report = sup.run(tr.shard_state(tr.init(0)), bd, 6)
assert report.crashes == 1 and int(np.asarray(s_b.step)) == 6
assert md(s_a.params, s_b.params) < 1e-7, md(s_a.params, s_b.params)
print("DIST-FT-OK")
"""


@pytest.mark.slow
def test_distributed_guarded_and_crash_recovery(subproc):
    out = subproc(DIST_FT_CODE, n_devices=4, timeout=900)
    assert "DIST-FT-OK" in out
