"""Reduced-mesh dry-run integration: the full lower+compile+analyze pipeline on a
(2, 4) fake-CPU mesh with reduced configs — every kind (train/prefill/decode) and
every family lowers with the production sharding rules."""
import pytest

CODE_TMPL = """
import os
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import dataclasses

from repro.configs import get_config, SHAPES
from repro.configs.base import ShapeConfig
from repro.models import batch_struct, build_model
from repro.models.sharding import rules_for, use_rules, spec as lspec
from repro.optim import adam as adam_lib
from repro.launch import dryrun as dr
from repro.utils.hlo import collective_bytes

mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))

def ns(tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda v: isinstance(v, P))

def run(arch, kind):
    cfg = get_config(arch).reduced(n_heads=4, n_kv_heads=4, vocab=512)
    shape = ShapeConfig("t", 64, 4, kind)
    model = build_model(cfg)
    rules = rules_for(decode=(kind == "decode"))
    with mesh, use_rules(rules):
        p_struct = dr.param_structs(model)
        p_specs = model.param_specs(rules)
        b_struct = batch_struct(cfg, shape, kind)
        b_specs = dr.batch_specs(b_struct, rules)
        if kind == "train":
            def step(params, opt, batch):
                loss, g = jax.value_and_grad(model.loss)(params, batch)
                p2, o2 = adam_lib.adam_update(g, opt, params, 1e-4)
                return p2, o2, loss
            fn = jax.jit(step, in_shardings=(ns(p_specs), ns(dr.opt_specs(p_specs)), ns(b_specs)),
                         out_shardings=(ns(p_specs), ns(dr.opt_specs(p_specs)), NamedSharding(mesh, P())))
            lowered = fn.lower(p_struct, dr.opt_structs(p_struct), b_struct)
        elif kind == "prefill":
            fn = jax.jit(lambda p, b: model.prefill(p, b),
                         in_shardings=(ns(p_specs), ns(b_specs)),
                         out_shardings=NamedSharding(mesh, lspec("batch", None, "vocab", rules=rules)))
            lowered = fn.lower(p_struct, b_struct)
        else:
            c_struct = model.cache_struct(shape.global_batch, shape.seq_len)
            c_specs = model.cache_specs(rules)
            fn = jax.jit(lambda p, c, b, pos: model.decode_step(p, c, b, pos),
                         in_shardings=(ns(p_specs), ns(c_specs), ns(b_specs), NamedSharding(mesh, P())),
                         out_shardings=(NamedSharding(mesh, lspec("batch", None, "vocab", rules=rules)), ns(c_specs)))
            lowered = fn.lower(p_struct, c_struct, b_struct, jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca.get("flops", 0) > 0
    cb = collective_bytes(compiled.as_text())
    print(arch, kind, "flops=%.2e coll=%.2e OK" % (ca.get("flops", 0), cb["total_bytes"]))

for arch in {archs}:
    for kind in {kinds}:
        run(arch, kind)
print("REDUCED-DRYRUN-OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("archs,kinds", [
    (["llama3.2-1b", "minicpm3-4b"], ["train", "prefill", "decode"]),
    (["deepseek-moe-16b", "rwkv6-3b"], ["train", "decode"]),
    (["zamba2-1.2b", "seamless-m4t-large-v2"], ["train", "decode"]),
])
def test_reduced_mesh_dryrun(subproc, archs, kinds):
    code = CODE_TMPL.format(archs=archs, kinds=kinds)
    out = subproc(code, n_devices=8, timeout=900)
    assert "REDUCED-DRYRUN-OK" in out
