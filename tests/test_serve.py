"""Field-serving subsystem: routing, stitching, single-dispatch engine, cache.

Covers the serve contract (EXPERIMENTS.md §Serving):

* vectorized routing agrees with ``Decomposition.subdomain_contains`` on
  random clouds (Cartesian grid AND the 10-region us_map polygons, bitwise);
* engine output matches per-subdomain reference apply to <= 1e-5, interface
  points return the two-sided average, outside points come back NaN;
* one ``evaluate`` call = ONE fused traced network entry (trace-counted for
  both the static-act and the heterogeneous-act select path, on a mixed cloud
  spanning all 10 us_map regions) and one packed weight stack in the compiled
  HLO;
* the frontend LRU returns bitwise-identical arrays on a repeat query without
  a new engine dispatch;
* export -> load roundtrips the full artifact (params, geometry, acts, PDE).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    Burgers1D, CartesianDecomposition, us_map_decomposition,
)
from repro.core import nets
from repro.core.nets import MLPConfig, SubdomainModelConfig, model_apply
from repro.core.pdes import HeatConduction2D
from repro.kernels import ops
from repro.serve import (
    FieldBundle, FieldEngine, ServeFrontend, export_bundle, load_bundle,
    membership_matrix, route,
)
from repro.serve import engine as engine_mod

TABLE3_ACTS = ["tanh", "sin", "cos", "tanh", "sin", "cos", "tanh", "sin",
               "cos", "tanh"]


def _cart_bundle(width=16, depth=3, seed=0):
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    params, codes = nets.stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed))
    return FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                       act_codes=np.asarray(codes), pde=Burgers1D())


def _usmap_bundle(two_nets=True, seed=1):
    dec = us_map_decomposition()
    nets_d = {"u": MLPConfig(2, 1, 12, 2)}
    if two_nets:
        nets_d["k"] = MLPConfig(2, 1, 12, 2)
    cfg = SubdomainModelConfig(nets=nets_d)
    params, codes = nets.stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed),
                                      TABLE3_ACTS)
    return FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                       act_codes=np.asarray(codes),
                       pde=HeatConduction2D() if two_nets else None)


# ------------------------------------------------------------------- routing

def test_cartesian_routing_matches_contains():
    dec = CartesianDecomposition(((-1, 2), (0, 1)), 3, 2)
    rng = np.random.default_rng(0)
    pts = rng.uniform([-1.5, -0.5], [2.5, 1.5], size=(2000, 2))
    pts = np.concatenate([pts, np.array([[0.0, 0.5], [-1.0, 0.0], [2.0, 1.0]])])
    M = membership_matrix(dec, pts, tol=0.0)
    for q in range(dec.n_sub):
        np.testing.assert_array_equal(M[q], dec.subdomain_contains(q, pts))


def test_polygon_routing_matches_contains():
    dec = us_map_decomposition()
    rng = np.random.default_rng(1)
    pts = rng.uniform([-0.5, -0.5], [5.5, 2.5], size=(3000, 2))
    M = membership_matrix(dec, pts, tol=0.0)
    for q in range(dec.n_sub):
        np.testing.assert_array_equal(M[q], dec.subdomain_contains(q, pts))


def test_polygon_interface_points_claimed_by_both_sides():
    dec = us_map_decomposition()
    # exact shared-edge points from the topology construction
    for e in dec.interface_edges(n_iface=6):
        M = membership_matrix(dec, e.points, tol=1e-9)
        assert M[e.a].all() and M[e.b].all()
    r = route(dec, dec.interface_edges(n_iface=6)[0].points)
    assert (r.claims >= 2).all()


def test_route_buckets_and_claims():
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    pts = np.array([[-0.5, 0.25], [0.5, 0.75], [0.0, 0.25], [9.0, 9.0]])
    r = route(dec, pts, bucket=8)
    assert r.m == 8 and r.X.shape == (4, 8, 2)
    np.testing.assert_array_equal(r.claims, [1, 1, 2, 0])
    np.testing.assert_array_equal(r.owner, [0, 3, 0, -1])
    assert r.n_unclaimed == 1
    # every claimed point has exactly one primary claim
    assert r.primary.sum() == (r.claims > 0).sum()


# -------------------------------------------------------------------- engine

def _single_claim_mask(dec, pts):
    return membership_matrix(dec, pts, tol=1e-9).sum(axis=0) == 1


@pytest.mark.parametrize("mixed_acts", [False, True])
def test_engine_matches_reference_apply(mixed_acts):
    bundle = _usmap_bundle() if mixed_acts else _cart_bundle()
    dec, cfg, params = bundle.decomp, bundle.model_cfg, bundle.params
    codes = bundle.act_codes
    rng = np.random.default_rng(2)
    pts = np.concatenate([dec.sample_interior(q, 40, rng)
                          for q in range(dec.n_sub)])
    out = FieldEngine(bundle).evaluate(pts, order=2)
    assert np.isfinite(out["u"]).all() and np.isfinite(out["residual"]).all()
    single = _single_claim_mask(dec, pts)
    for q in range(dec.n_sub):
        sel = dec.subdomain_contains(q, pts) & single
        p_q = jax.tree.map(lambda x: x[q], params)
        ref = np.asarray(model_apply(cfg, p_q, jnp.asarray(pts[sel], jnp.float32),
                                     int(codes[q])))
        assert np.abs(out["u"][sel] - ref).max() <= 1e-5


def test_engine_interface_average_and_outside_nan():
    bundle = _cart_bundle()
    dec, cfg, params = bundle.decomp, bundle.model_cfg, bundle.params
    eng = FieldEngine(bundle)
    iface = np.stack([np.zeros(7), np.linspace(0.05, 0.45, 7)], axis=1)
    out = eng.evaluate(np.concatenate([iface, [[5.0, 5.0]]]), order=1)
    # x=0, y<0.5 sits between subdomains 0 (ix=0,iy=0) and 2 (ix=1,iy=0)
    ref = lambda q: np.asarray(model_apply(
        cfg, jax.tree.map(lambda x: x[q], params),
        jnp.asarray(iface, jnp.float32), 0))
    want = 0.5 * (ref(0) + ref(2))
    np.testing.assert_allclose(out["u"][:-1], want, atol=1e-6)
    assert np.isnan(out["u"][-1]).all()


def test_engine_first_order_tier():
    """order=1 (d2 stream disabled) returns the SAME u/grad/flux, no residual."""
    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    pts = np.array([[0.2, 0.2], [-0.7, 0.9]])
    o1 = eng.evaluate(pts, order=1)
    o2 = eng.evaluate(pts, order=2)
    assert sorted(o1) == ["flux", "grad_u", "u"]
    assert sorted(o2) == ["flux", "grad_u", "residual", "u"]
    for k in o1:
        np.testing.assert_array_equal(o1[k], o2[k])


def test_engine_order2_without_pde_raises():
    bundle = _usmap_bundle(two_nets=False)
    bundle = FieldBundle(model_cfg=bundle.model_cfg, params=bundle.params,
                         decomp=bundle.decomp, act_codes=bundle.act_codes,
                         pde=None)
    eng = FieldEngine(bundle)
    with pytest.raises(ValueError, match="order=1"):
        eng.evaluate(np.array([[1.0, 1.0]]), order=2)
    out = eng.evaluate(np.array([[1.0, 1.0]]), order=1)
    assert sorted(out) == ["grad_u", "u"]


# ------------------------------------------------- single-dispatch contract

def _count_entries(fn_names, body):
    """Run ``body`` with the named ops entries wrapped by a trace counter."""
    calls = []
    origs = {n: getattr(ops, n) for n in fn_names}
    for n in fn_names:
        def wrap(*a, _orig=origs[n], _n=n, **k):
            calls.append(_n)
            return _orig(*a, **k)
        setattr(ops, n, wrap)
    try:
        body()
    finally:
        for n, f in origs.items():
            setattr(ops, n, f)
    return calls


def test_engine_single_fused_entry_uniform_act():
    """Acceptance: one evaluate = ONE traced fused entry (static-act path)."""
    engine_mod._EVAL_CACHE.clear()
    bundle = _cart_bundle(width=12, depth=2, seed=3)
    eng = FieldEngine(bundle)
    pts = np.random.default_rng(3).uniform([-1, 0], [1, 1], size=(50, 2))
    calls = _count_entries(["pinn_mlp_forward2", "pinn_mlp_forward2_select"],
                           lambda: eng.evaluate(pts, order=2))
    assert calls == ["pinn_mlp_forward2"], calls


def test_engine_single_fused_entry_usmap_mixed_cloud():
    """Acceptance: a mixed query cloud spanning ALL 10 us_map regions (with
    heterogeneous Table-3 activations) is served by exactly one traced fused
    network entry per field net — the vmapped select entry, not a per-region
    loop."""
    engine_mod._EVAL_CACHE.clear()
    bundle = _usmap_bundle(two_nets=False, seed=4)
    eng = FieldEngine(bundle)
    assert eng.uniform_act is None  # heterogeneous: select path
    rng = np.random.default_rng(4)
    pts = np.concatenate([bundle.decomp.sample_interior(q, 20, rng)
                          for q in range(10)])
    assert (membership_matrix(bundle.decomp, pts).any(axis=1)).all()
    calls = _count_entries(["pinn_mlp_forward2", "pinn_mlp_forward2_select"],
                           lambda: eng.evaluate(pts, order=1))
    assert calls == ["pinn_mlp_forward2_select"], calls
    # repeat evaluates reuse the compiled program: no retrace, still 1 dispatch each
    d0 = eng.n_dispatches
    calls = _count_entries(["pinn_mlp_forward2", "pinn_mlp_forward2_select"],
                           lambda: eng.evaluate(pts, order=1))
    assert calls == [] and eng.n_dispatches == d0 + 1


def test_engine_hlo_packs_weights_once():
    """HLO single-entry assertion (the PR-2 pad-count idiom, serving side):
    the compiled evaluate program packs each layer's weight stack exactly once
    — a per-subdomain or per-segment loop would pad it n times."""
    engine_mod._EVAL_CACHE.clear()
    bundle = _cart_bundle(width=16, depth=2, seed=5)
    eng = FieldEngine(bundle, block_n=32, interpret=True)
    routed = route(bundle.decomp, np.random.default_rng(5).uniform(
        [-1, 0], [1, 1], size=(40, 2)), bucket=32)
    fn = eng._get_fn(order=2)
    txt = fn.lower(*eng._device_args(routed)).compile().as_text()
    n_layer_mats = 3  # depth-2 MLP: 2 hidden + 1 output weight matrix
    pads = sum(1 for ln in txt.splitlines()
               if " pad(" in ln and "f32[4,128,128]" in ln)
    assert pads == n_layer_mats, f"expected {n_layer_mats} weight packs, got {pads}"


# ------------------------------------------------------------------ frontend

def test_frontend_cache_bitwise_no_new_dispatch():
    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    fe = ServeFrontend(eng, order=2, cache_size=4)
    pts = np.random.default_rng(6).uniform([-1, 0], [1, 1], size=(64, 2))
    a = fe.query(pts)
    d0 = eng.n_dispatches
    b = fe.query(pts)
    assert eng.n_dispatches == d0, "cache hit must not dispatch"
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
        assert a[k].tobytes() == b[k].tobytes()  # bitwise, not just approx
    s = fe.stats()
    assert s["cache_hits"] == 1 and s["cache_misses"] == 1


def test_frontend_microbatch_matches_standalone():
    """Aggregated requests slice back to exactly their standalone results."""
    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    fe = ServeFrontend(eng, order=1, max_batch=4096)
    rng = np.random.default_rng(7)
    clouds = [rng.uniform([-1, 0], [1, 1], size=(n, 2)) for n in (17, 33, 5)]
    tickets = [fe.submit(c) for c in clouds]
    d0 = eng.n_dispatches
    fe.flush()
    assert eng.n_dispatches == d0 + 1  # three requests, one microbatch dispatch
    for t, c in zip(tickets, clouds):
        got = fe.result(t)
        want = eng.evaluate(c, order=1)
        for k in want:
            np.testing.assert_allclose(got[k], want[k], atol=1e-6)


def test_frontend_failed_flush_requeues_tickets():
    """A failing engine evaluation must not strand queued tickets."""
    bundle = _usmap_bundle(two_nets=False)  # pde=None: order=2 raises
    fe = ServeFrontend(FieldEngine(bundle), order=2)
    t = fe.submit(np.array([[1.0, 1.0]]))
    with pytest.raises(ValueError, match="order=1"):
        fe.flush()
    fe.order = 1                     # recover and serve the queued request
    fe.flush()
    assert sorted(fe.result(t)) == ["grad_u", "u"]


def test_query_cloud_shape_validated():
    """Wrongly-shaped clouds fail loudly instead of being blindly reshaped."""
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    with pytest.raises(ValueError, match="query cloud"):
        route(dec, np.zeros((4, 3)))
    with pytest.raises(ValueError, match="query cloud"):
        membership_matrix(dec, np.zeros((2, 2, 2)))
    assert route(dec, np.array([0.5, 0.5])).pts.shape == (1, 2)  # single point ok


def test_frontend_deadline_flush_stubbed_clock():
    """max_queue_age: the oldest queued request is flushed once it ages out —
    driven by an injected monotonic clock, so no real sleeping."""
    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    now = [0.0]
    fe = ServeFrontend(eng, order=1, max_queue_age=1.0, clock=lambda: now[0])
    rng = np.random.default_rng(10)
    a = rng.uniform([-1, 0], [1, 1], size=(8, 2))
    ta = fe.submit(a)
    d0 = eng.n_dispatches
    now[0] = 0.5
    assert not fe.poll() and eng.n_dispatches == d0   # under the deadline: queued
    now[0] = 1.0
    assert fe.poll() and eng.n_dispatches == d0 + 1   # head aged out: flushed
    assert sorted(fe.result(ta)) == ["flux", "grad_u", "u"]
    assert fe.stats()["deadline_flushes"] == 1

    # submit() itself triggers the flush when the queue HEAD (not the new
    # request) is past the deadline — and both ride one dispatch
    now[0] = 2.0
    tb = fe.submit(rng.uniform([-1, 0], [1, 1], size=(4, 2)))
    now[0] = 3.5
    d1 = eng.n_dispatches
    tc = fe.submit(rng.uniform([-1, 0], [1, 1], size=(4, 2)))
    assert eng.n_dispatches == d1 + 1
    fe.result(tb), fe.result(tc)
    assert fe.stats()["deadline_flushes"] == 2

    # no deadline configured: poll never force-flushes
    fe2 = ServeFrontend(eng, order=1)
    fe2.submit(a)
    assert not fe2.poll() and len(fe2._pending) == 1


def test_frontend_result_pending_autoflush_and_double_pop():
    """result() on a still-pending ticket used to KeyError opaquely: now it
    auto-flushes; an unknown/already-popped ticket raises a typed error."""
    from repro.serve import UnknownTicketError

    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    fe = ServeFrontend(eng, order=1)
    pts = np.random.default_rng(11).uniform([-1, 0], [1, 1], size=(6, 2))
    t = fe.submit(pts)
    d0 = eng.n_dispatches
    out = fe.result(t)                 # no explicit flush: auto-flushes
    assert eng.n_dispatches == d0 + 1 and sorted(out) == ["flux", "grad_u", "u"]
    with pytest.raises(UnknownTicketError, match=f"ticket {t}"):
        fe.result(t)                   # results are handed out exactly once
    with pytest.raises(UnknownTicketError, match="ticket 999"):
        fe.result(999)


def test_frontend_cache_point_budget():
    """The cache is bounded by total cached POINTS, not just entry count —
    cache_size huge grids must not pin unbounded result arrays."""
    bundle = _cart_bundle()
    eng = FieldEngine(bundle)
    fe = ServeFrontend(eng, order=1, cache_size=64, cache_points=20)
    rng = np.random.default_rng(12)
    clouds = [rng.uniform([-1, 0], [1, 1], size=(8, 2)) for _ in range(3)]
    for c in clouds:
        fe.query(c)
    s = fe.stats()
    assert s["cache_points"] <= 20 and s["cache_entries"] == 2
    fe.query(clouds[0])                # evicted by the point budget: miss
    assert fe.stats()["cache_misses"] == 4
    fe.query(clouds[2])                # most-recent entries survived: hit
    assert fe.stats()["cache_hits"] == 1

    # an entry larger than the whole budget bypasses the cache instead of
    # evicting everything else and then missing anyway
    giant = rng.uniform([-1, 0], [1, 1], size=(30, 2))
    fe.query(giant)
    s = fe.stats()
    assert s["cache_points"] <= 20
    fe.query(giant)
    assert fe.stats()["cache_misses"] == 6     # giant is never cached


def test_frontend_lru_eviction():
    bundle = _cart_bundle()
    fe = ServeFrontend(FieldEngine(bundle), order=1, cache_size=2)
    rng = np.random.default_rng(8)
    clouds = [rng.uniform([-1, 0], [1, 1], size=(8, 2)) for _ in range(3)]
    for c in clouds:
        fe.query(c)
    fe.query(clouds[0])  # evicted by the LRU (size 2): miss again
    assert fe.stats()["cache_misses"] == 4


# ------------------------------------------------- trainer checkpoint wiring

def test_pinn_train_resume_bitwise(tmp_path):
    """repro.checkpoint wired into the PINN trainers (save_train_state /
    restore_train_state): a run interrupted mid-way through its run_chunk
    schedule and resumed from the checkpoint matches the uninterrupted
    ReferenceTrainer run BITWISE."""
    from repro.core import (
        DDConfig, ReferenceTrainer, XPINN, build_topology,
        restore_train_state, save_train_state,
    )
    from repro.checkpoint import ckpt
    from repro.data import make_batch

    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    b = make_batch(dec, topo, pde, n_res=48, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(method=XPINN,
                                                   residual_path="pallas"))

    s_full, _ = tr.run_chunk(tr.init(0), b, 4)           # uninterrupted

    s_half, _ = tr.run_chunk(tr.init(0), b, 2)           # interrupted at 2...
    root = str(tmp_path / "ckpt")
    save_train_state(root, s_half)
    del s_half
    s_res = restore_train_state(root, tr.init(0))        # ...resumed
    assert int(s_res.step) == 2 and ckpt.latest_step(root) == 2
    s_res, _ = tr.run_chunk(s_res, b, 2)

    assert int(s_res.step) == int(s_full.step) == 4
    for a, c in zip(jax.tree.leaves((s_full.params, s_full.opt)),
                    jax.tree.leaves((s_res.params, s_res.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


# ------------------------------------------------------------- export / load

def test_export_load_roundtrip(tmp_path):
    bundle = _usmap_bundle(seed=9)
    root = str(tmp_path / "bundle")
    export_bundle(root, bundle.params, bundle.model_cfg, bundle.decomp,
                  act_codes=bundle.act_codes, pde=bundle.pde, n_iface=12,
                  metadata={"rel_l2": 0.1})
    loaded = load_bundle(root)
    assert loaded.model_cfg == bundle.model_cfg
    assert loaded.pde == bundle.pde and loaded.n_iface == 12
    assert loaded.metadata == {"rel_l2": 0.1}
    np.testing.assert_array_equal(loaded.act_codes, bundle.act_codes)
    for a, b in zip(jax.tree.leaves(loaded.params),
                    jax.tree.leaves(bundle.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for pa, pb in zip(loaded.decomp.polygons, bundle.decomp.polygons):
        np.testing.assert_allclose(pa, pb)
    # the loaded bundle serves bitwise the same field as the in-memory one
    pts = np.random.default_rng(9).uniform([0.2, 0.2], [4.8, 1.8], size=(60, 2))
    a = FieldEngine(bundle).evaluate(pts, order=2)
    b = FieldEngine(loaded).evaluate(pts, order=2)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # rebuildable topology rides along
    topo = loaded.topology()
    assert topo.n_sub == 10 and topo.n_iface == 12


def test_export_cartesian_spec_roundtrip(tmp_path):
    bundle = _cart_bundle()
    root = str(tmp_path / "b")
    export_bundle(root, bundle.params, bundle.model_cfg, bundle.decomp,
                  act_codes=bundle.act_codes, pde=bundle.pde)
    loaded = load_bundle(root)
    dec = loaded.decomp
    assert isinstance(dec, CartesianDecomposition)
    assert dec.bounds == bundle.decomp.bounds
    assert (dec.nx, dec.ny) == (2, 2)
    assert isinstance(loaded.pde, Burgers1D)
