"""Distributed (shard_map + ppermute) trainer == single-device vmap reference.

This is the core correctness claim for the paper's Algorithm 1 port: the SPMD
program computes exactly what the per-rank MPI program computes.  Runs in a
subprocess with 4 fake CPU devices (the main process keeps 1 device).
"""
import pytest

CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch

pde = Burgers1D()
dec = CartesianDecomposition(((-1,1),(0,1)), nx=2, ny=2)
topo = build_topology(dec, n_iface=16)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2,1,20,3)})
rng = np.random.default_rng(0)
batch = make_batch(dec, topo, pde, n_res=128, n_bnd=32, rng=rng)
b = batch.device_arrays()

for method, couple, local_steps in [(XPINN, False, 1), (CPINN, False, 1),
                                    (XPINN, True, 1), (XPINN, False, 3)]:
    dd = DDConfig(method=method, couple_gradients=couple, local_steps=local_steps)
    ref = ReferenceTrainer(pde, cfg, topo, dd, lrs=[1e-3, 2e-3, 3e-3, 4e-3],
                           act_codes=["tanh", "sin", "cos", "tanh"])
    dist = DistributedDDTrainer(pde, cfg, topo, dd, lrs=[1e-3, 2e-3, 3e-3, 4e-3],
                                act_codes=["tanh", "sin", "cos", "tanh"])
    s_ref, s_dist = ref.init(0), dist.init(0)
    s_dist = dist.shard_state(s_dist)
    bd = dist.shard_batch(b)
    for i in range(4):
        s_ref, t_ref = ref.step(s_ref, b)
        s_dist, t_dist = dist.step(s_dist, bd)
    pr, pd = jax.tree.leaves(s_ref.params), jax.tree.leaves(s_dist.params)
    err = max(float(np.max(np.abs(np.asarray(a)-np.asarray(c)))) for a, c in zip(pr, pd))
    assert err < 1e-5, (method, couple, local_steps, err)
    tr = float(np.asarray(t_ref["loss"]).sum())
    td = float(np.asarray(t_dist["loss"]).sum())
    assert abs(tr - td) < 1e-4 * max(1.0, abs(tr)), (tr, td)
print("EQUIVALENCE-OK")
"""


@pytest.mark.slow
def test_distributed_equals_reference(subproc):
    out = subproc(CODE, n_devices=4, timeout=900)
    assert "EQUIVALENCE-OK" in out


DP_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.trainer import DataParallelTrainer
from repro.data import make_batch, make_vanilla_batch
from repro.optim import CompressionConfig

pde = Burgers1D()
dec = CartesianDecomposition(((-1,1),(0,1)), nx=4, ny=1)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2,1,20,3)})
rng = np.random.default_rng(0)
from repro.core.domain import build_topology
topo = build_topology(dec, 4)
batch = make_batch(dec, topo, pde, n_res=64, n_bnd=16, rng=rng)
b = batch.device_arrays()

for comp in [None, CompressionConfig("int8"), CompressionConfig("topk", topk_frac=0.05)]:
    tr = DataParallelTrainer(pde, cfg, n_workers=4, compression=comp, lr=5e-4)
    st = tr.init(0)
    losses = []
    for i in range(30):
        st, terms = tr.step(st, b)
        losses.append(float(terms["loss"]))
    assert losses[-1] < losses[0], (comp, losses[0], losses[-1])
print("DP-OK")
"""


@pytest.mark.slow
def test_data_parallel_baseline_with_compression(subproc):
    out = subproc(DP_CODE, n_devices=4, timeout=900)
    assert "DP-OK" in out


def test_reference_trainer_pallas_residual_path_equals_jvp():
    """E2E: the fused-kernel residual path and the per-point jvp path produce
    the same losses AND the same trained parameters (i.e. the custom VJP's
    gradients match) over several optimizer steps on Burgers."""
    import numpy as np
    import jax
    from repro.core import XPINN, CPINN, Burgers1D, CartesianDecomposition, build_topology
    from repro.core.nets import MLPConfig, SubdomainModelConfig
    from repro.core.trainer import DDConfig, ReferenceTrainer
    from repro.data import make_batch

    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), nx=2, ny=2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 20, 3)})
    batch = make_batch(dec, topo, pde, n_res=64, n_bnd=16,
                       rng=np.random.default_rng(0))
    b = batch.device_arrays()
    for method in (XPINN, CPINN):
        trainers = {
            p: ReferenceTrainer(pde, cfg, topo, DDConfig(method=method, residual_path=p))
            for p in ("jvp", "pallas")
        }
        assert trainers["pallas"].res_path is not None  # dispatch actually armed
        states = {p: t.init(0) for p, t in trainers.items()}
        terms = {}
        for _ in range(3):
            for p, t in trainers.items():
                states[p], terms[p] = t.step(states[p], b)
        for a, c in zip(jax.tree.leaves(states["jvp"].params),
                        jax.tree.leaves(states["pallas"].params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=2e-5, atol=2e-6)
        lj = float(np.asarray(terms["jvp"]["loss"]).sum())
        lp = float(np.asarray(terms["pallas"]["loss"]).sum())
        assert abs(lj - lp) < 1e-4 * max(1.0, abs(lj)), (method, lj, lp)


ERRFB_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.trainer import DataParallelTrainer
from repro.core.domain import build_topology
from repro.data import make_batch
from repro.optim import CompressionConfig

pde = Burgers1D()
dec = CartesianDecomposition(((-1,1),(0,1)), nx=4, ny=1)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2,1,20,3)})
topo = build_topology(dec, 4)
batch = make_batch(dec, topo, pde, n_res=64, n_bnd=16, rng=np.random.default_rng(0))
b = batch.device_arrays()

tr = DataParallelTrainer(pde, cfg, n_workers=4,
                         compression=CompressionConfig("topk", topk_frac=0.05), lr=5e-4)
st = tr.init(0)
# regression (trainer err_spec dead branch): the error-feedback buffer must be
# PER-WORKER, not replicated
for leaf in jax.tree.leaves(st["err"]):
    assert leaf.shape[0] == 4, leaf.shape
losses_ = []
for i in range(10):
    st, terms = tr.step(st, b)
    losses_.append(float(terms["loss"]))
err0 = np.asarray(jax.tree.leaves(st["err"])[0])
# each worker compresses ITS OWN gradient -> per-worker error slices differ
diffs = max(float(np.abs(err0[i] - err0[0]).max()) for i in range(1, 4))
assert diffs > 0.0, "error-feedback buffer is identical across workers (replicated?)"
assert losses_[-1] < losses_[0], losses_
print("ERRFB-OK")
"""


@pytest.mark.slow
def test_compression_error_feedback_is_per_worker(subproc):
    """Regression for the err_spec dead branch: err must shard over 'sub'."""
    out = subproc(ERRFB_CODE, n_devices=4, timeout=900)
    assert "ERRFB-OK" in out
