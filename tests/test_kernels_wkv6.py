"""WKV6 Pallas kernel vs the chunked/stepwise oracles (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import wkv6
from repro.models.ssm import _wkv6_chunked


@pytest.mark.parametrize("B,T,H,P,chunk", [
    (1, 64, 2, 16, 16), (2, 128, 3, 32, 64), (1, 64, 1, 128, 32),
])
def test_wkv6_kernel_vs_oracle(B, T, H, P, chunk):
    rng = np.random.default_rng(hash((B, T, H, P)) % 2**31)
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.2, 0.98, (B, T, H, P)), jnp.float32)
    u = jnp.asarray(rng.normal(0, 1, (H, P)), jnp.float32)
    y = wkv6(r, k, v, w, u, chunk=chunk)
    y_ref, _ = _wkv6_chunked(r, k, v, w, u,
                             jnp.zeros((B, H, P, P)), chunk=min(16, T))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_wkv6_strong_decay_stability():
    """w near 0 (fast forgetting): the pairwise exponent form must not overflow."""
    rng = np.random.default_rng(0)
    B, T, H, P = 1, 128, 1, 16
    r, k, v = (jnp.asarray(rng.normal(0, 1, (B, T, H, P)), jnp.float32)
               for _ in range(3))
    w = jnp.full((B, T, H, P), 0.05, jnp.float32)
    u = jnp.zeros((H, P), jnp.float32)
    y = wkv6(r, k, v, w, u, chunk=64)
    assert bool(jnp.all(jnp.isfinite(y)))
    y_ref, _ = _wkv6_chunked(r, k, v, w, u, jnp.zeros((B, H, P, P)), chunk=16)
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)
