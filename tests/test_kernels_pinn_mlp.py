"""Second-order fused PINN-MLP kernel: parity sweeps, custom VJP, dispatch.

The correctness chain is

    pallas _kernel2 (interpret)  ==  ref.pinn_mlp_ref2 (batched recurrence)
                                 ==  pdes.dir_deriv / dir_deriv2 (per-point
                                     nested jvp — the paper's §4.1 oracle)

plus: the custom VJP differentiates the fused outputs w.r.t. params, the
packed-weight prepare step is CSE'd inside one jit scope, and
``losses.residual_eval`` ACTUALLY routes through the fused bundle when given a
ResidualPath.  The exhaustive sweep is marked ``kernel`` (deselected by
default); a small unmarked subset keeps tier-1 coverage.
"""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fused, losses, nets
from repro.core.losses import ResidualPath
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.pdes import Burgers1D, dir_deriv, dir_deriv2
from repro.kernels import ops, pinn_mlp_forward2, ref


def _seed(*parts):
    """Deterministic per-config seed (Python hash() is salted per process)."""
    return zlib.adler32(repr(parts).encode())


def _mk_mlp(rng, d_in, width, depth, out, dtype):
    dims = [d_in] + [width] * depth + [out]
    Ws = [jnp.asarray(rng.normal(0, np.sqrt(2 / (a + b)), (a, b)), dtype)
          for a, b in zip(dims[:-1], dims[1:])]
    bs = [jnp.asarray(rng.normal(0, 0.1, (b,)), dtype) for b in dims[1:]]
    a = jnp.asarray(rng.uniform(0.9, 1.1, (depth,)), dtype)
    return Ws, bs, a


def _closure(Ws, bs, a, act):
    phi = {"tanh": jnp.tanh, "sin": jnp.sin, "cos": jnp.cos}[act]

    def f(y):
        h = y @ Ws[0] + bs[0]
        for l in range(len(Ws) - 1):
            h = phi(a[l] * h)
            h = h @ Ws[l + 1] + bs[l + 1]
        return h

    return f


def _oracle_bundle(Ws, bs, a, act, x):
    """Per-point nested-jvp oracle (pdes.dir_deriv / dir_deriv2)."""
    f = _closure(Ws, bs, a, act)
    d_in = x.shape[1]
    u = jax.vmap(f)(x)
    basis = [jnp.zeros((d_in,)).at[j].set(1.0) for j in range(d_in)]
    du = jnp.stack([jax.vmap(lambda xi, e=e: dir_deriv(f, xi, e))(x) for e in basis])
    d2u = jnp.stack([jax.vmap(lambda xi, e=e: dir_deriv2(f, xi, e))(x) for e in basis])
    return u, du, d2u


def _check(act, dtype, d_in, width, depth, out, n=96, block_n=32):
    rng = np.random.default_rng(_seed(act, d_in, width, depth, out))
    Ws, bs, a = _mk_mlp(rng, d_in, width, depth, out, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (n, d_in)), jnp.float32)
    u_o, du_o, d2u_o = _oracle_bundle(Ws, bs, a, act, x)
    cast = lambda t: jax.tree.map(lambda z: z.astype(dtype), t)
    u, du, d2u = pinn_mlp_forward2(x.astype(dtype), cast(Ws), cast(bs),
                                   a.astype(dtype), act=act, block_n=block_n,
                                   interpret=True)
    if dtype == jnp.float32:
        rtol_u, rtol_d = 1e-4, 1e-4
        atol_u, atol_d = 1e-5, 5e-4
    else:  # bf16: ~8 mantissa bits; second derivatives compound rounding
        rtol_u, rtol_d = 0.05, 0.2
        atol_u, atol_d = 0.05, 0.5
    np.testing.assert_allclose(np.asarray(u, np.float32), u_o, rtol=rtol_u, atol=atol_u)
    np.testing.assert_allclose(np.asarray(du, np.float32), du_o, rtol=rtol_d, atol=atol_d)
    np.testing.assert_allclose(np.asarray(d2u, np.float32), d2u_o, rtol=rtol_d, atol=atol_d)


# ---- tier-1 subset: one config per activation, incl. a width<128 padding edge
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
def test_forward2_vs_dir_deriv2_oracle(act):
    _check(act, jnp.float32, d_in=2, width=20, depth=3, out=1)


def test_forward2_width_128_exact_lanes():
    _check("tanh", jnp.float32, d_in=2, width=128, depth=2, out=1)


# ---- exhaustive sweep: acts x dtypes x shapes (run with `pytest -m kernel`)
@pytest.mark.kernel
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d_in,width,depth,out", [
    (2, 16, 3, 1),    # narrow width — heavy lane padding
    (2, 40, 8, 3),    # paper's Fig-4 center config
    (3, 64, 5, 2),    # 3 input directions
    (2, 128, 2, 1),   # exact lane width, no padding
    (1, 33, 4, 1),    # single direction, odd width
])
def test_forward2_parity_sweep(act, dtype, d_in, width, depth, out):
    _check(act, dtype, d_in, width, depth, out)


# ---- megabatch (segment-aware) wrapper -------------------------------------

def _check_segments(act, dtype, d_in, width, depth, out, sizes, interpret,
                    block_n=32):
    """One concatenated dispatch == separate per-segment calls: the kernel math
    is row-independent, so segment membership must not matter.  Pallas blocks
    (interpret=True) match BITWISE; the compiled jnp recurrence may pick a
    different XLA gemm strategy per batch size (observed ~5e-8 on degenerate
    single-row segments), so it gets float-noise tolerance."""
    rng = np.random.default_rng(_seed(act, d_in, width, sizes))
    Ws, bs, a = _mk_mlp(rng, d_in, width, depth, out, dtype)
    segs = tuple(jnp.asarray(rng.uniform(-1, 1, (n, d_in)), dtype) for n in sizes)
    fused_out = ops.pinn_mlp_forward2_segments(segs, Ws, bs, a, act=act,
                                               block_n=block_n,
                                               interpret=interpret)
    assert len(fused_out) == len(sizes)
    for x, (u, du, d2u) in zip(segs, fused_out):
        sep = pinn_mlp_forward2(x, Ws, bs, a, act=act, block_n=block_n,
                                interpret=interpret)
        assert u.shape == (x.shape[0], out)
        for got, want in zip((u, du, d2u), sep):
            if interpret:
                np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
            else:
                np.testing.assert_allclose(
                    np.asarray(got, np.float32), np.asarray(want, np.float32),
                    rtol=1e-5, atol=1e-5)


# tier-1 subset: one layout per dispatch path (compiled jnp recurrence +
# Pallas interpreter), sizes straddling a block boundary
@pytest.mark.parametrize("interpret", [None, True])
def test_forward2_segments_match_separate_calls(interpret):
    _check_segments("tanh", jnp.float32, 2, 20, 3, 1, (40, 17, 9), interpret)


# exhaustive megabatch cases ride the kernel marker so default test time does
# not regress (run with `pytest -m kernel`)
@pytest.mark.kernel
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("interpret", [None, True])
@pytest.mark.parametrize("sizes", [
    (96, 32, 32),    # block-aligned residual/iface/data layout
    (100, 7, 1),     # ragged segments, minimum-size data segment
    (1, 1, 1),       # degenerate: every segment a single point
    (256, 80, 33),   # >1 point block with ragged tail
])
def test_forward2_segments_parity_sweep(act, dtype, interpret, sizes):
    _check_segments(act, dtype, 2, 24, 3, 1, sizes, interpret)


@pytest.mark.parametrize("interpret", [None, True])
def test_forward2_d2_dirs_pruning(interpret):
    """PDE-declared second-order pruning: selected d2u rows match the full
    computation, pruned rows are exact zeros, and (u, du) are untouched."""
    rng = np.random.default_rng(31)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 2)), jnp.float32)
    u_f, du_f, d2u_f = pinn_mlp_forward2(x, Ws, bs, a, block_n=32,
                                         interpret=interpret)
    for dirs in ((0,), (1,), ()):
        u, du, d2u = pinn_mlp_forward2(x, Ws, bs, a, block_n=32,
                                       interpret=interpret, d2_dirs=dirs)
        np.testing.assert_allclose(u, u_f, rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(du, du_f, rtol=1e-6, atol=1e-7)
        for j in range(2):
            if j in dirs:
                np.testing.assert_allclose(d2u[j], d2u_f[j], rtol=1e-6,
                                           atol=1e-6)
            else:
                assert not np.any(np.asarray(d2u[j])), \
                    f"pruned direction {j} must come back as exact zeros"


def test_forward2_d2_dirs_pruned_grads_match_full():
    """A loss that only reads the selected d2u rows gets the same gradients
    from the pruned custom VJP as from the full one."""
    rng = np.random.default_rng(37)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 2)), jnp.float32)

    def loss(Ws, bs, a, dirs):
        u, du, d2u = pinn_mlp_forward2(x, Ws, bs, a, d2_dirs=dirs)
        return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u[0] ** 2)

    gp = jax.grad(loss, argnums=(0, 1, 2))(Ws, bs, a, (0,))
    gf = jax.grad(loss, argnums=(0, 1, 2))(Ws, bs, a, None)
    for lp, lf in zip(jax.tree.leaves(gp), jax.tree.leaves(gf)):
        np.testing.assert_allclose(lp, lf, rtol=1e-5, atol=1e-6)


def test_euler_residual_path_needs_no_d2(monkeypatch):
    """Euler1D declares d2_dirs=(): the fused residual path runs a pruned
    (empty) second-order stream and still matches the jvp oracle."""
    from repro.core.pdes import Euler1D

    pde = Euler1D()
    assert pde.d2_dirs == ()
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 3, 16, 2)})
    params = nets.init_model(cfg, jax.random.PRNGKey(0))
    pts = jnp.asarray(np.random.default_rng(1).uniform(0.1, 0.9, (24, 2)),
                      jnp.float32)
    r_jvp = losses.residual_eval(pde, cfg, params, nets.ACT_TANH, None, pts, None)
    r_pal = losses.residual_eval(pde, cfg, params, nets.ACT_TANH, None, pts,
                                 ResidualPath(act="tanh"))
    np.testing.assert_allclose(r_pal, r_jvp, rtol=1e-4, atol=1e-5)


def test_forward2_segments_grads_match_separate_calls():
    """The megabatch entry differentiates like the separate calls: one custom
    VJP over the concatenated batch == sum of per-segment VJPs."""
    rng = np.random.default_rng(23)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    xs = tuple(jnp.asarray(rng.uniform(-1, 1, (n, 2)), jnp.float32)
               for n in (24, 9, 5))

    def loss_seg(Ws, bs, a):
        outs = ops.pinn_mlp_forward2_segments(xs, Ws, bs, a, interpret=True,
                                              block_n=32)
        return sum(jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)
                   for u, du, d2u in outs)

    def loss_sep(Ws, bs, a):
        return sum(
            jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)
            for u, du, d2u in (pinn_mlp_forward2(x, Ws, bs, a, interpret=True,
                                                 block_n=32) for x in xs))

    gf = jax.grad(loss_seg, argnums=(0, 1, 2))(Ws, bs, a)
    go = jax.grad(loss_sep, argnums=(0, 1, 2))(Ws, bs, a)
    for lf, lo in zip(jax.tree.leaves(gf), jax.tree.leaves(go)):
        np.testing.assert_allclose(lf, lo, rtol=1e-5, atol=1e-5)


def test_forward2_block_padding_edge():
    """N not divisible by block_n: wrapper pads rows and slices correctly."""
    rng = np.random.default_rng(5)
    Ws, bs, a = _mk_mlp(rng, 2, 16, 2, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (37, 2)), jnp.float32)
    u, du, d2u = pinn_mlp_forward2(x, Ws, bs, a, block_n=32, interpret=True)
    assert u.shape == (37, 1) and du.shape == (2, 37, 1) and d2u.shape == (2, 37, 1)
    u_o, du_o, d2u_o = _oracle_bundle(Ws, bs, a, "tanh", x)
    np.testing.assert_allclose(u, u_o, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(d2u, d2u_o, rtol=1e-4, atol=5e-4)


def test_forward2_custom_vjp_grads_match_autodiff():
    """The fused op is differentiable w.r.t. (Ws, bs, a); grads match plain
    autodiff through the per-point closure."""
    rng = np.random.default_rng(11)
    Ws, bs, a = _mk_mlp(rng, 2, 24, 3, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 2)), jnp.float32)

    def loss_fused(Ws, bs, a):
        u, du, d2u = pinn_mlp_forward2(x, Ws, bs, a, interpret=True)
        return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)

    def loss_oracle(Ws, bs, a):
        u, du, d2u = _oracle_bundle(Ws, bs, a, "tanh", x)
        return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)

    gf = jax.grad(loss_fused, argnums=(0, 1, 2))(Ws, bs, a)
    go = jax.grad(loss_oracle, argnums=(0, 1, 2))(Ws, bs, a)
    for lf, lo in zip(jax.tree.leaves(gf), jax.tree.leaves(go)):
        np.testing.assert_allclose(lf, lo, rtol=1e-4, atol=1e-4)


# ---- hand-derived fused backward -------------------------------------------
#
# The backward correctness chain mirrors the forward one:
#
#     pallas _kernel2_bwd (interpret)  ==  ref._ref2_bwd (hand-derived, jnp)
#                                      ==  jax.vjp(ref.pinn_mlp_ref2) (autodiff)
#
# ref.pinn_mlp_ref2_vjp is an INDEPENDENT closed-form derivation (no autodiff
# anywhere), so agreement is two derivations meeting — not the kernel being
# compared against the machinery it replaces.


def _rand_cts(rng, shapes, dtype):
    return tuple(jnp.asarray(rng.normal(0, 1, s), dtype) for s in shapes)


def _vjp_bundle_check(act, d_in, width, depth, out, d2_dirs=None, n=40,
                      block_n=32, rtol=1e-4, atol=1e-4):
    """All three backwards agree on the same random cotangents."""
    rng = np.random.default_rng(_seed("vjp", act, d_in, width, depth, d2_dirs))
    Ws, bs, a = _mk_mlp(rng, d_in, width, depth, out, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (n, d_in)), jnp.float32)
    shapes = ((n, out), (d_in, n, out), (d_in, n, out))
    cts = _rand_cts(rng, shapes, jnp.float32)

    # (1) independent hand derivation (closed form, no jax.vjp)
    outs_hand, vjp_hand = ref.pinn_mlp_ref2_vjp(x, Ws, bs, a, act=act,
                                                d2_dirs=d2_dirs)
    g_hand = vjp_hand(cts)
    # (2) autodiff of the reference recurrence
    outs_auto, vjp_auto = jax.vjp(
        lambda xx, W, b, aa: ref.pinn_mlp_ref2(xx, W, b, aa, act=act,
                                               d2_dirs=d2_dirs),
        x, tuple(Ws), tuple(bs), a)
    g_auto = vjp_auto(cts)
    # (3) the fused Pallas reverse kernel (interpret mode)
    outs_pal, vjp_pal = jax.vjp(
        lambda xx, W, b, aa: pinn_mlp_forward2(xx, W, b, aa, act=act,
                                               block_n=block_n, interpret=True,
                                               d2_dirs=d2_dirs, bwd="fused"),
        x, tuple(Ws), tuple(bs), a)
    g_pal = vjp_pal(cts)

    for o_h, o_a, o_p in zip(outs_hand, outs_auto, outs_pal):
        np.testing.assert_allclose(o_h, o_a, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(o_p), o_a, rtol=1e-5, atol=1e-5)
    for l_h, l_a, l_p in zip(jax.tree.leaves(g_hand), jax.tree.leaves(g_auto),
                             jax.tree.leaves(g_pal)):
        # hand derivation vs autodiff: same math, different reduction order
        np.testing.assert_allclose(l_h, l_a, rtol=rtol, atol=atol)
        # acceptance bound: kernel vs hand-derived oracle <= 1e-5 relative
        # (scaled by the cotangent magnitude per leaf)
        scale = max(1.0, float(np.max(np.abs(l_h))))
        np.testing.assert_allclose(np.asarray(l_p) / scale,
                                   np.asarray(l_h) / scale,
                                   rtol=1e-5, atol=1e-5)


# tier-1 subset: every activation (narrow width — the padding edge) + one
# pruned-direction case
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
def test_bwd_parity_hand_vs_autodiff_vs_kernel(act):
    _vjp_bundle_check(act, d_in=2, width=20, depth=3, out=1)


def test_bwd_parity_pruned_dirs():
    _vjp_bundle_check("tanh", d_in=2, width=20, depth=3, out=1, d2_dirs=(0,))


# exhaustive backward sweep (run with `pytest -m kernel`): acts x widths
# (incl. <128 padding and exact-lane) x d2_dirs subsets x input dims
@pytest.mark.kernel
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
@pytest.mark.parametrize("d_in,width,depth,out", [
    (2, 16, 3, 1),    # narrow width — heavy lane padding
    (2, 40, 8, 3),    # paper's Fig-4 center config
    (3, 64, 5, 2),    # 3 input directions
    (2, 128, 2, 1),   # exact lane width, no padding
    (1, 33, 4, 1),    # single direction, odd width
])
@pytest.mark.parametrize("d2_dirs", [None, (0,), ()])
def test_bwd_parity_sweep(act, d_in, width, depth, out, d2_dirs):
    _vjp_bundle_check(act, d_in, width, depth, out, d2_dirs)


def test_bwd_selector_roundtrip():
    """bwd='fused' and bwd='ref' are the SAME gradient (up to float noise):
    the selector changes the implementation, never the math."""
    rng = np.random.default_rng(41)
    Ws, bs, a = _mk_mlp(rng, 2, 24, 3, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 2)), jnp.float32)

    def loss(Ws, bs, a, bwd):
        u, du, d2u = pinn_mlp_forward2(x, Ws, bs, a, bwd=bwd)
        return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)

    gf = jax.grad(loss, argnums=(0, 1, 2))(Ws, bs, a, "fused")
    gr = jax.grad(loss, argnums=(0, 1, 2))(Ws, bs, a, "ref")
    for lf, lr in zip(jax.tree.leaves(gf), jax.tree.leaves(gr)):
        np.testing.assert_allclose(lf, lr, rtol=1e-4, atol=1e-5)
    with pytest.raises(ValueError, match="backward path"):
        loss(Ws, bs, a, "nope")


def test_bwd_segments_megabatch_matches_separate():
    """The fused backward composes with the segment megabatch entry."""
    rng = np.random.default_rng(43)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    xs = tuple(jnp.asarray(rng.uniform(-1, 1, (n, 2)), jnp.float32)
               for n in (24, 9, 5))

    def loss_seg(Ws, bs, a):
        outs = ops.pinn_mlp_forward2_segments(xs, Ws, bs, a, interpret=True,
                                              block_n=32, bwd="fused")
        return sum(jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)
                   for u, du, d2u in outs)

    g = jax.grad(loss_seg, argnums=(0, 1, 2))(Ws, bs, a)
    # oracle: independent hand-derived VJP per segment, summed
    acc = None
    for x in xs:
        _, vjp = ref.pinn_mlp_ref2_vjp(x, Ws, bs, a)
        u, du, d2u = ref.pinn_mlp_ref2(x, Ws, bs, a)
        cts = (2.0 * u, 2.0 * du, 0.2 * d2u)
        _, cW, cb, ca = vjp(cts)
        gi = (cW, cb, ca)
        acc = gi if acc is None else jax.tree.map(jnp.add, acc, gi)
    for lf, lo in zip(jax.tree.leaves(g), jax.tree.leaves(acc)):
        np.testing.assert_allclose(lf, lo, rtol=1e-4, atol=1e-4)


def test_select_bwd_matches_static_act():
    """The traced-code serving entry differentiates like the static-act path
    for every code (hand-derived select backward)."""
    rng = np.random.default_rng(47)
    Ws, bs, a = _mk_mlp(rng, 2, 16, 2, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (24, 2)), jnp.float32)
    for code_v, act in ((0, "tanh"), (1, "sin"), (2, "cos")):
        def loss_sel(Ws, bs, a):
            u, du, d2u = ops.pinn_mlp_forward2_select(
                x, Ws, bs, a, jnp.asarray(code_v, jnp.int32))
            return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)

        def loss_ref(Ws, bs, a):
            u, du, d2u = ref.pinn_mlp_ref2(x, Ws, bs, a, act=act)
            return jnp.sum(u ** 2) + jnp.sum(du ** 2) + 0.1 * jnp.sum(d2u ** 2)

        gs = jax.grad(loss_sel, argnums=(0, 1, 2))(Ws, bs, a)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(Ws, bs, a)
        for l1, l2 in zip(jax.tree.leaves(gs), jax.tree.leaves(gr)):
            np.testing.assert_allclose(l1, l2, rtol=2e-4, atol=2e-4)


def test_pack_mlp_is_cse_d_within_one_jit_scope():
    """Satellite check: two fused calls on the SAME weights inside one jit
    compile to ONE packed weight stack (XLA CSE) — the padding 'prepare' step
    does not re-run per call site."""
    rng = np.random.default_rng(3)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    x1 = jnp.asarray(rng.uniform(-1, 1, (32, 2)), jnp.float32)
    x2 = jnp.asarray(rng.uniform(-1, 1, (64, 2)), jnp.float32)

    # interpret=True forces the padded Pallas path (the CPU production dispatch
    # is the unpadded jnp recurrence, which never packs)
    def one_call(Ws, bs, a):
        return sum(jnp.sum(t) for t in pinn_mlp_forward2(x1, Ws, bs, a,
                                                         interpret=True))

    def twice(Ws, bs, a):
        u1 = sum(jnp.sum(t) for t in pinn_mlp_forward2(x1, Ws, bs, a,
                                                       interpret=True))
        u2 = sum(jnp.sum(t) for t in pinn_mlp_forward2(x2, Ws, bs, a,
                                                       interpret=True))
        return u1 + u2

    def count_weight_pads(fn):
        txt = jax.jit(fn).lower(Ws, bs, a).compile().as_text()
        return sum(1 for ln in txt.splitlines()
                   if " pad(" in ln and "f32[128,128]" in ln)

    baseline = count_weight_pads(one_call)
    # guard against the HLO pattern silently rotting: the single-call compile
    # must actually show the packed-weight pads, else the comparison is vacuous
    assert baseline >= 1, "HLO pad pattern matched nothing — update the matcher"
    assert count_weight_pads(twice) <= baseline


def test_model_bundle_width_mask_folding():
    """Width masks fold into the weight stack: bundle == masked mlp_apply."""
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 3)})
    params = nets.init_model(cfg, jax.random.PRNGKey(0))
    mask = jnp.asarray((np.arange(24) < 16).astype(np.float32))
    x = jnp.asarray(np.random.default_rng(0).uniform(-1, 1, (50, 2)), jnp.float32)
    u, du, d2u = fused.model_bundle(cfg, params, x, "tanh", {"u": mask})
    u_ref = nets.model_apply(cfg, params, x, nets.ACT_TANH, {"u": mask})
    np.testing.assert_allclose(u, u_ref, rtol=1e-5, atol=1e-6)
    # derivative check against the masked per-point closure
    f = nets.scalar_field_fn(cfg, params, nets.ACT_TANH, {"u": mask})
    e0 = jnp.zeros((2,)).at[0].set(1.0)
    d2_o = jax.vmap(lambda xi: dir_deriv2(f, xi, e0))(x)
    np.testing.assert_allclose(d2u[0], d2_o, rtol=1e-4, atol=5e-4)


def test_losses_route_through_fused_bundle(monkeypatch):
    """Acceptance: with a ResidualPath, residual evaluation ACTUALLY goes
    through fused.model_bundle (and not the per-point jvp closures)."""
    pde = Burgers1D()
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    params = nets.init_model(cfg, jax.random.PRNGKey(0))
    pts = jnp.asarray(np.random.default_rng(1).uniform(-1, 1, (24, 2)), jnp.float32)

    calls = []
    orig = fused.model_bundle
    monkeypatch.setattr(fused, "model_bundle",
                        lambda *a, **k: (calls.append(1), orig(*a, **k))[1])

    r_jvp = losses.residual_eval(pde, cfg, params, nets.ACT_TANH, None, pts, None)
    assert not calls, "jvp path must not touch the fused bundle"
    r_pal = losses.residual_eval(pde, cfg, params, nets.ACT_TANH, None, pts,
                                 ResidualPath(act="tanh"))
    assert calls, "pallas path must route through fused.model_bundle"
    np.testing.assert_allclose(r_pal, r_jvp, rtol=1e-4, atol=1e-5)


def test_forward_packed_matches_unpacked():
    rng = np.random.default_rng(17)
    Ws, bs, a = _mk_mlp(rng, 2, 20, 3, 1, jnp.float32)
    x = jnp.asarray(rng.uniform(-1, 1, (40, 2)), jnp.float32)
    packed = ops.pack_mlp(Ws, bs, a)
    u1, du1 = ops.pinn_mlp_forward(x, Ws, bs, a, interpret=True)
    u2, du2 = ops.pinn_mlp_forward_packed(x, packed, out_dim=1, interpret=True)
    np.testing.assert_allclose(u1, u2, rtol=0, atol=0)
    np.testing.assert_allclose(du1, du2, rtol=0, atol=0)
