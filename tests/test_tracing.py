"""Causal tracing: span trees, sampling, export validity, trajectory gate.

The contracts under test, in dependency order:

* **Tracer core** — stack-based parenting, deterministic systematic sampling
  (unsampled traces still carry real trace_ids), bounded ring buffer with
  eviction accounting, retrospective ``record``;
* **off-mode is bitwise non-intrusive** — with ``tracer=None`` the serve and
  training integration points take the exact pre-tracing code path:
  ``ServeResult`` fields unchanged (``trace_id`` None), guarded-chunk terms
  BITWISE equal with the tracer attached vs absent, and the lowered chunk
  HLO byte-identical (the tracer wraps dispatch on the host; the compiled
  program must not know it exists);
* **one trace_id per ticket through failure paths** — a retried, ladder-
  degraded, finally-served request carries ONE trace whose subtree records
  every hop; shed and deadline-exceeded tickets still close their root span;
* **Chrome export** — structural validity (matched B/E pairs, monotone ts,
  finished flows) on serve and 4-subdomain supervised training exports, and
  the validator REJECTS malformed documents;
* **perf-trajectory gate** — passes on stable history, TRIPS on an injected
  2x single-metric slowdown (negative control), does not trip on common-mode
  drift (container quota wobble), and never records a tripped run.

Heavy end-to-end sweeps live behind ``-m trace`` (deselected from tier-1).
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
from types import SimpleNamespace

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs import Obs, MetricsRegistry, Tracer, make_obs
from repro.obs.trace_export import (ChromeTraceError, export_chrome_trace,
                                    halo_flow_events, to_chrome,
                                    training_timeline, validate_chrome_trace)
from repro.obs.trajectory import (PerfRegressionError, append_record,
                                  detect_regressions, gate, read_history)
from repro.runtime import InjectedFailure
from repro.serve import ResilienceConfig, ResilientFrontend

POISON_X = 777.0


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        self.t += 0.001
        return self.t


# ---------------------------------------------------------------- tracer core

def test_span_stack_parenting_and_tree():
    tr = Tracer(clock=FakeClock())
    with tr.start_trace("root", lane="serve") as root:
        with tr.span("mid") as mid:
            tr.span("leaf").end()
        assert mid.parent_id == root.span_id
    spans = tr.spans()
    assert [s.name for s in spans] == ["leaf", "mid", "root"]
    leaf = spans[0]
    assert leaf.parent_id == mid.span_id and leaf.trace_id == root.trace_id
    tree = tr.tree(root.trace_id)
    assert tree["span"].name == "root"
    assert tree["children"][0]["span"].name == "mid"
    assert tree["children"][0]["children"][0]["span"].name == "leaf"


def test_explicit_parent_beats_stack():
    tr = Tracer(clock=FakeClock())
    a = tr.start_trace("a")
    with tr.start_trace("b"):
        sp = tr.span("child-of-a", parent=a)
        assert sp.trace_id == a.trace_id and sp.parent_id == a.span_id
        sp.end()


def test_retrospective_record_inherits_trace():
    tr = Tracer(clock=FakeClock())
    root = tr.start_trace("root")
    sp = tr.record("queue_wait", 1.0, 2.5, parent=root, ticket=7)
    assert sp.trace_id == root.trace_id and sp.t1 - sp.t0 == 1.5
    assert sp.attrs["ticket"] == 7
    root.end()
    assert {s.name for s in tr.spans(root.trace_id)} == {"root", "queue_wait"}


def test_systematic_sampling_is_deterministic():
    tr = Tracer(clock=FakeClock(), sample_rate=0.25)
    decisions = [tr.start_trace("r").sampled for _ in range(8)]
    assert decisions == [False, False, False, True] * 2
    # unsampled traces still carry REAL trace ids: propagation stays intact
    unsampled = tr.start_trace("r")
    assert not unsampled.sampled and unsampled.trace_id.startswith("t")
    unsampled.end()
    assert tr.spans(unsampled.trace_id) == []
    st = tr.stats()
    assert st["traces"] == 9 and st["traces_sampled"] == 2
    assert st["spans_dropped_sampling"] >= 1


def test_ring_buffer_bounds_and_watermark():
    tr = Tracer(clock=FakeClock(), capacity=4)
    for i in range(7):
        tr.start_trace(f"s{i}").end()
    st = tr.stats()
    assert st["buffer"] == 4 and st["spans_evicted"] == 3
    assert st["watermark"] == 4 and st["spans_recorded"] == 7
    assert [s.name for s in tr.spans()] == ["s3", "s4", "s5", "s6"]


def test_exception_exits_annotate_error_and_close():
    tr = Tracer(clock=FakeClock())
    with pytest.raises(ValueError):
        with tr.start_trace("boom") as sp:
            raise ValueError("x")
    assert sp._ended and sp.attrs["error"] == "ValueError"
    assert tr._stack == []


# ------------------------------------------------------------- chrome export

def _spans_fixture():
    tr = Tracer(clock=FakeClock())
    with tr.start_trace("req", lane="serve") as root:
        with tr.span("dispatch"):
            tr.span("engine", lane="engine").end()
        root.event("hop")
    return tr.spans()


def test_to_chrome_valid_and_name_matched():
    rep = validate_chrome_trace(to_chrome(_spans_fixture()))
    assert rep["span_pairs"] == 3 and rep["instants"] == 1
    assert rep["lanes"] == 2        # serve + engine


def test_overlapping_traces_pack_into_slots():
    tr = Tracer(clock=FakeClock())
    a = tr.start_trace("a", lane="serve")
    b = tr.start_trace("b", lane="serve")   # overlaps a on the same lane
    a.end()
    b.end()
    rep = validate_chrome_trace(to_chrome(tr.spans()))
    assert rep["span_pairs"] == 2 and rep["lanes"] == 2   # serve + serve#2


def test_validator_rejects_malformed_documents():
    good = to_chrome(_spans_fixture())["traceEvents"]
    with pytest.raises(ChromeTraceError):
        validate_chrome_trace({"nope": []})
    # unmatched E: drop the B of a matched pair
    b_idx = next(i for i, e in enumerate(good) if e["ph"] == "B")
    with pytest.raises(ChromeTraceError):
        validate_chrome_trace(
            {"traceEvents": good[:b_idx] + good[b_idx + 1:]})
    # time travel: non-monotone ts in file order
    bad = [dict(e) for e in good]
    bad[-1]["ts"] = -5
    with pytest.raises(ChromeTraceError):
        validate_chrome_trace({"traceEvents": bad})


def test_halo_flows_and_training_timeline():
    tr = Tracer(clock=FakeClock())
    for k in range(2):
        tr.start_trace("train.chunk", lane="train", chunk=k).end()
    topo = SimpleNamespace(n_sub=2,
                           neighbor=np.array([[1, -1], [0, -1]]))
    lanes, flows = training_timeline(tr.spans(), topo,
                                     halo={"collective_permute_bytes": 4096})
    assert len(lanes) == 4                      # 2 chunks x 2 subdomain lanes
    assert len(flows) == 4                      # 2 directed edges x 2 chunks
    assert all(f["bytes"] == 2048 for f in flows)
    rep = validate_chrome_trace(
        to_chrome(list(tr.spans()) + lanes, flows=flows))
    assert rep["flows"] == 4 and rep["lanes"] == 3


def test_export_chrome_trace_writes_validated_file(tmp_path):
    path = str(tmp_path / "trace.json")
    rep = export_chrome_trace(path, _spans_fixture())
    assert rep["span_pairs"] == 3
    doc = json.load(open(path))
    assert validate_chrome_trace(doc)["events"] == len(doc["traceEvents"])


# ------------------------------------------------- serve failure-path traces

class StubEngine:
    """u = pts @ [1, 2]; clouds containing POISON_X fail the first
    ``fail_times`` dispatches (transient fault -> retry/degrade hops)."""

    def __init__(self, fail_times=0):
        self.bundle = SimpleNamespace(decomp=SimpleNamespace(dim=2))
        self.n_dispatches = 0
        self.poison_evals = 0
        self.fail_times = fail_times
        self.last_claims = None
        self.obs = None

    def evaluate(self, pts, order=2):
        # mirror FieldEngine: an engine span nested under the caller's
        # active (microbatch) span, so the hop shows up in the trace
        tracer = self.obs.tracer if self.obs is not None else None
        if tracer is not None:
            with tracer.span("serve.engine", lane="engine", order=order,
                             points=len(pts)):
                return self._eval(pts, order)
        return self._eval(pts, order)

    def _eval(self, pts, order):
        pts = np.asarray(pts, float)
        if POISON_X in pts[:, 0]:
            self.poison_evals += 1
            if self.poison_evals <= self.fail_times:
                raise InjectedFailure("stub engine failure")
        self.n_dispatches += 1
        self.last_claims = np.ones(len(pts), np.int64)
        return {"u": pts @ np.array([[1.0], [2.0]])}


def _traced_rf(engine, **cfg_kw):
    now = [0.0]
    obs = Obs(registry=MetricsRegistry(clock=lambda: now[0]),
              tracer=Tracer(clock=FakeClock()))
    engine.obs = obs
    fe = ResilientFrontend(engine, ResilienceConfig(**cfg_kw),
                           clock=lambda: now[0],
                           sleep=lambda s: now.__setitem__(0, now[0] + s),
                           obs=obs)
    return fe, now, obs.tracer


def _cloud(n, seed=0, poison=False):
    c = np.random.default_rng(seed).uniform(-1.0, 1.0, size=(n, 2))
    if poison:
        c[0, 0] = POISON_X
    return c


def test_failure_path_one_trace_id_records_every_hop():
    eng = StubEngine(fail_times=3)
    fe, _now, tr = _traced_rf(eng, retry_limit=4, retry_backoff=0.01,
                              order=2)
    t = fe.submit(_cloud(4, poison=True))
    fe.drain()
    res = fe.result(t)
    assert res.ok and res.status == "degraded" and res.order == 1
    assert res.trace_id is not None
    names = [s.name for s in tr.spans(res.trace_id)]
    # ONE trace records admission, the quarantine hops of each failed
    # attempt, the retries, the ladder step-down, and the final service
    for hop in ("serve.admitted", "serve.quarantine", "serve.retry",
                "serve.degrade", "serve.microbatch", "serve.engine",
                "serve.queue_wait", "serve.dispatch"):
        assert hop in names, (hop, names)
    root = [s for s in tr.spans(res.trace_id) if s.parent_id is None]
    assert len(root) == 1 and root[0].attrs["status"] == "degraded"
    # no other trace leaked a span
    assert set(tr.trace_ids()) == {res.trace_id}


def test_shed_and_deadline_tickets_close_their_roots():
    eng = StubEngine()
    fe, now, tr = _traced_rf(eng, max_queue_requests=1,
                             default_deadline=0.5)
    t1 = fe.submit(_cloud(4))
    t2 = fe.submit(_cloud(5, seed=1))          # over the bound: shed
    now[0] += 1.0                              # t1 expires in the queue
    fe.poll()
    r1, r2 = fe.result(t1), fe.result(t2)
    assert r1.status == "deadline_exceeded" and r2.status == "shed"
    for r in (r1, r2):
        assert r.trace_id is not None
        roots = [s for s in tr.spans(r.trace_id) if s.parent_id is None]
        assert len(roots) == 1 and roots[0]._ended
        assert roots[0].attrs["status"] == r.status


def test_cache_hit_trace_has_hop_event():
    eng = StubEngine()
    fe, _now, tr = _traced_rf(eng)
    c = _cloud(6)
    t1 = fe.submit(c)
    fe.flush()
    fe.result(t1)
    t2 = fe.submit(c)                           # admission-time cache hit
    r2 = fe.result(t2)
    assert r2.ok and r2.reason == "cache" and r2.trace_id is not None
    names = [s.name for s in tr.spans(r2.trace_id)]
    assert "serve.cache_hit" in names


def test_off_mode_serve_result_unchanged():
    eng = StubEngine()
    now = [0.0]
    fe = ResilientFrontend(eng, ResilienceConfig(), clock=lambda: now[0],
                           sleep=lambda s: None)
    t = fe.submit(_cloud(4))
    fe.drain()
    res = fe.result(t)
    assert res.ok and res.trace_id is None


# --------------------------------------------------- training parity + spans

@pytest.fixture(scope="module")
def trainer_setup():
    from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                            ReferenceTrainer, XPINN, build_topology)
    from repro.core.nets import MLPConfig, SubdomainModelConfig
    from repro.data import make_batch

    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    b = make_batch(dec, topo, pde, n_res=48, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"))
    return topo, b, tr


def test_traced_guarded_chunk_bitwise_and_hlo_parity(trainer_setup):
    import jax
    import jax.numpy as jnp

    _topo, b, tr = trainer_setup
    assert tr.tracer is None                    # off by default
    lr = jnp.ones_like(tr.lrs)
    s_off, t_off, h_off = tr.run_chunk_guarded(tr.init(0), b, 4)
    hlo_off = tr._chunk_guarded.lower(tr.init(0), b, 4, lr).as_text()

    tracer = Tracer(clock=FakeClock())
    tr.tracer = tracer
    try:
        s_on, t_on, h_on = tr.run_chunk_guarded(tr.init(0), b, 4)
        hlo_on = tr._chunk_guarded.lower(tr.init(0), b, 4, lr).as_text()
    finally:
        tr.tracer = None
    # the compiled program must not know the tracer exists
    assert hlo_on == hlo_off
    # bitwise: the tracer wraps the dispatch on the host, nothing else
    for a, c in zip(jax.tree.leaves(s_off.params), jax.tree.leaves(s_on.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    for k in t_off:
        np.testing.assert_array_equal(np.asarray(t_off[k]),
                                      np.asarray(t_on[k]))
    assert bool(h_off["ok"]) == bool(h_on["ok"])
    # exactly one dispatch span per chunk call, blocked until ready
    spans = tracer.spans()
    assert [s.name for s in spans] == ["train.run_chunk_guarded"]
    assert spans[0].t1 > spans[0].t0


def test_supervisor_one_trace_per_attempt_and_event_trace_ids(tmp_path,
                                                              trainer_setup):
    from repro.runtime import (Fault, FaultInjector, Supervisor,
                               SupervisorConfig)

    _topo, b, tr = trainer_setup
    obs = make_obs(str(tmp_path / "ev.jsonl"), trace=True)
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=3),
                     FaultInjector([Fault(1, "crash"),
                                    Fault(3, "nan_params", subdomain=0)]),
                     obs=obs)
    try:
        sup.run(tr.init(0), b, total_steps=12)
    finally:
        obs.close()
    roots = [s for s in obs.tracer.spans() if s.parent_id is None]
    outcomes = [s.attrs["outcome"] for s in roots]
    assert outcomes.count("crash") == 1 and outcomes.count("guard_trip") == 1
    # each attempt's trace nests its dispatch; failures add a rollback child
    for r in roots:
        kids = {s.name for s in obs.tracer.spans(r.trace_id)
                if s.parent_id == r.span_id}
        assert "train.run_chunk_guarded" in kids
        if r.attrs["outcome"] != "committed":
            assert "train.rollback" in kids
    # every emitted supervisor event carries the trace_id of a known attempt
    tids = {r.trace_id for r in roots}
    events = [json.loads(ln) for ln in open(tmp_path / "ev.jsonl")][1:]
    for e in events:
        if e["kind"] in ("chunk", "crash", "rollback", "guard_trip"):
            assert e["trace_id"] in tids, e


def test_supervisor_off_mode_emits_no_trace_ids(tmp_path, trainer_setup):
    from repro.runtime import Supervisor, SupervisorConfig

    _topo, b, tr = trainer_setup
    tr.tracer = None          # module fixture: undo any earlier test's wiring
    obs = make_obs(str(tmp_path / "ev.jsonl"))          # trace=False default
    assert obs.tracer is None
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=3), obs=obs)
    try:
        sup.run(tr.init(0), b, total_steps=6)
    finally:
        obs.close()
    assert tr.tracer is None
    events = [json.loads(ln) for ln in open(tmp_path / "ev.jsonl")][1:]
    assert events and all("trace_id" not in e for e in events)


# ----------------------------------------------------------- trajectory gate

def _hist_rows(scale=1.0):
    return [("bench/lat_ms", 10.0 * scale, "ms"),
            ("bench/throughput", 100.0 / scale, "pts/s"),
            ("bench/aux_ms", 5.0 * scale, "ms")]


def _seed_history(path, runs=4):
    for i in range(runs):
        append_record(path, "b", _hist_rows(1.0 + 0.02 * i), mode="smoke",
                      sha=f"s{i}", clock=lambda: float(i))


def test_gate_passes_on_stable_history(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed_history(path)
    rep = gate(path, "b", _hist_rows(1.03), mode="smoke", clock=lambda: 9.0)
    assert rep["recorded"] and not rep["regressions"]
    assert len(read_history(path)) == 5


def test_gate_trips_on_single_metric_2x_and_does_not_record(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed_history(path)
    rows = _hist_rows(1.0)
    rows[0] = ("bench/lat_ms", 20.0, "ms")      # injected 2x slowdown
    with pytest.raises(PerfRegressionError) as ei:
        gate(path, "b", rows, mode="smoke", clock=lambda: 9.0)
    assert "bench/lat_ms" in str(ei.value)
    assert len(read_history(path)) == 4         # the bad run was NOT recorded


def test_common_mode_drift_does_not_trip(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed_history(path)
    # everything 1.8x slower: container quota wobble, not a regression
    rep = detect_regressions(read_history(path), _hist_rows(1.8),
                             mode="smoke")
    assert rep["gated"] == 3 and not rep["regressions"]


def test_modes_never_share_baselines(tmp_path):
    path = str(tmp_path / "h.jsonl")
    _seed_history(path)                          # smoke-mode history only
    rep = detect_regressions(read_history(path), _hist_rows(5.0),
                             mode="full")
    assert rep["gated"] == 0                     # no full-mode baseline yet


def test_unknown_units_never_gate(tmp_path):
    path = str(tmp_path / "h.jsonl")
    for i in range(4):
        append_record(path, "b", [("bench/count", 10 + i, "")],
                      mode="smoke", sha=f"s{i}", clock=lambda: float(i))
    rep = detect_regressions(read_history(path), [("bench/count", 99, "")],
                             mode="smoke")
    assert rep["gated"] == 0


# ------------------------------------------------------ end-to-end (marked)

@pytest.mark.trace
def test_trace_observatory_smoke_exports_validate():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks import trace_observatory

    rows = dict((r[0], r[1]) for r in trace_observatory.smoke_rows())
    assert rows["trace/serve/span_pairs"] > 0
    assert rows["trace/train/halo_flows"] > 0


@pytest.mark.trace
def test_sampled_serving_keeps_ids_but_records_fraction():
    eng = StubEngine()
    now = [0.0]
    obs = Obs(registry=MetricsRegistry(clock=lambda: now[0]),
              tracer=Tracer(clock=FakeClock(), sample_rate=0.25))
    fe = ResilientFrontend(eng, ResilienceConfig(), clock=lambda: now[0],
                           sleep=lambda s: None, obs=obs)
    tickets = [fe.submit(_cloud(4, seed=i)) for i in range(8)]
    fe.drain()
    results = [fe.result(t) for t in tickets]
    assert all(r.trace_id is not None for r in results)     # ids always flow
    assert len(set(r.trace_id for r in results)) == 8
    st = obs.tracer.stats()
    assert st["traces"] == 8 and st["traces_sampled"] == 2
    assert len(obs.tracer.trace_ids()) == 2                 # recorded subset
