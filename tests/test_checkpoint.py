"""Checkpoint/restart: bitwise resume, atomicity, failure injection, elasticity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # container may lack hypothesis; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.checkpoint import ckpt
from repro.runtime import InjectedFailure, balanced_counts, remap_params, run_with_failures


def test_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5.0), "b": [jnp.ones((2, 2)), jnp.zeros(3, jnp.int32)]}
    ckpt.save(str(tmp_path), 7, tree, {"note": "x"})
    out, meta = ckpt.restore(str(tmp_path), tree)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(x, y)
        assert x.dtype == y.dtype
    assert meta["note"] == "x" and ckpt.latest_step(str(tmp_path)) == 7


def test_keep_last_k_gc(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in range(6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    dirs = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert len(dirs) == 2 and ckpt.latest_step(str(tmp_path)) == 5


def test_structure_mismatch_raises(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="structure mismatch"):
        ckpt.restore(str(tmp_path), {"b": jnp.zeros(2)})
    out, _ = ckpt.restore(str(tmp_path), {"b": jnp.ones(2)}, allow_restructure=True)
    np.testing.assert_array_equal(out["b"], 1.0)  # falls back to template


def test_latest_pointer_survives_gc_races(tmp_path):
    tree = {"x": jnp.zeros(2)}
    ckpt.save(str(tmp_path), 1, tree)
    ckpt.save(str(tmp_path), 2, tree)
    # simulate stale LATEST
    with open(tmp_path / "LATEST", "w") as f:
        f.write("step_0000000099")
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_failure_injection_resumes_to_identical_state(tmp_path):
    """Crash at arbitrary steps; final state equals the uninterrupted run."""
    def init():
        return {"x": jnp.zeros(()), "y": jnp.ones((3,))}

    def step(s):
        return {"x": s["x"] + 1, "y": s["y"] * 1.5 + s["x"]}

    clean = init()
    for _ in range(20):
        clean = step(clean)

    final = run_with_failures(root=str(tmp_path), init_fn=init, step_fn=step,
                              total_steps=20, ckpt_every=4, fail_at=[2, 9, 13, 19])
    np.testing.assert_allclose(final["x"], clean["x"])
    np.testing.assert_allclose(final["y"], clean["y"], rtol=1e-6)


def test_lm_train_resume_bitwise(tmp_path, subproc):
    """launch/train.py --resume: interrupted-then-resumed == straight-through."""
    code = f"""
import sys
sys.argv = ["train", "lm", "--arch", "llama3.2-1b", "--reduced", "--steps", "6",
            "--batch", "2", "--seq", "32", "--ckpt-dir", r"{tmp_path}/a",
            "--ckpt-every", "3", "--log-every", "100"]
from repro.launch.train import main
main()
import numpy as np
from repro.checkpoint import ckpt
a, _ = ckpt.raw_leaves(r"{tmp_path}/a")

# interrupted at 3, then resumed to 6
sys.argv = ["train", "lm", "--arch", "llama3.2-1b", "--reduced", "--steps", "3",
            "--batch", "2", "--seq", "32", "--ckpt-dir", r"{tmp_path}/b",
            "--ckpt-every", "3", "--log-every", "100"]
main()
sys.argv = ["train", "lm", "--arch", "llama3.2-1b", "--reduced", "--steps", "6",
            "--batch", "2", "--seq", "32", "--ckpt-dir", r"{tmp_path}/b",
            "--ckpt-every", "3", "--log-every", "100", "--resume"]
main()
b, _ = ckpt.raw_leaves(r"{tmp_path}/b")
assert set(a) == set(b)
for k in a:
    np.testing.assert_allclose(a[k], b[k], rtol=1e-6, atol=1e-7), k
print("RESUME-OK")
"""
    out = subproc(code, n_devices=1, timeout=600)
    assert "RESUME-OK" in out


# PINN-trainer checkpoint wiring (save_train_state / restore_train_state with
# bitwise resume through run_chunk) is covered in tests/test_serve.py, which
# stays collected when `hypothesis` is absent and this module is skipped.


# ---------------------------------------------------------------- elasticity

def test_remap_params_nearest_centroid():
    from repro.core.domain import CartesianDecomposition
    old = CartesianDecomposition(((0, 1), (0, 1)), 2, 1)   # halves
    new = CartesianDecomposition(((0, 1), (0, 1)), 4, 1)   # quarters
    params = {"w": jnp.asarray(np.array([[1.0], [2.0]]))}
    remapped, src = remap_params(params, old, new)
    np.testing.assert_array_equal(src, [0, 0, 1, 1])
    np.testing.assert_allclose(remapped["w"][:, 0], [1, 1, 2, 2])


@given(st.lists(st.integers(0, 10_000), min_size=1, max_size=32))
@settings(max_examples=50, deadline=None)
def test_balanced_counts_properties(counts):
    out = balanced_counts(counts)
    assert sum(out) == sum(counts)            # budget preserved
    assert max(out) - min(out) <= 1           # perfectly level
    assert len(out) == len(counts)
