"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, pinn_mlp_forward, ref


@pytest.mark.parametrize("d_in,width,depth,out", [
    (2, 20, 3, 1), (2, 40, 8, 3), (3, 64, 5, 2), (2, 128, 2, 1),
])
@pytest.mark.parametrize("act", ["tanh", "sin", "cos"])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_pinn_mlp_kernel_vs_oracle(d_in, width, depth, out, act, dtype):
    rng = np.random.default_rng(hash((d_in, width, depth, out, act)) % 2**31)
    dims = [d_in] + [width] * depth + [out]
    Ws = [jnp.asarray(rng.normal(0, np.sqrt(2 / (a + b)), (a, b)), dtype)
          for a, b in zip(dims[:-1], dims[1:])]
    bs = [jnp.asarray(rng.normal(0, 0.1, (b,)), dtype) for b in dims[1:]]
    a = jnp.asarray(rng.uniform(0.9, 1.1, (depth,)), dtype)
    x = jnp.asarray(rng.uniform(-1, 1, (100, d_in)), dtype)
    u, du = pinn_mlp_forward(x, Ws, bs, a, act=act, block_n=32)
    ur, dur = ref.pinn_mlp_ref(x, Ws, bs, a, act=act)
    np.testing.assert_allclose(u, ur, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(du, dur, rtol=1e-3, atol=1e-4)


@pytest.mark.parametrize("B,H,Hk,S,T,dh,causal", [
    (2, 4, 2, 128, 128, 64, True),
    (1, 8, 8, 256, 256, 128, True),
    (2, 4, 1, 128, 256, 64, False),   # cross-attention-style, MQA grouping
    (1, 2, 2, 64, 64, 100, True),     # non-lane-aligned head dim (pads to 128)
])
def test_flash_attention_vs_oracle(B, H, Hk, S, T, dh, causal):
    rng = np.random.default_rng(hash((B, H, S, T, dh)) % 2**31)
    q = jnp.asarray(rng.normal(0, 1, (B, H, S, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Hk, T, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Hk, T, dh)), jnp.float32)
    o = flash_attention(q, k, v, causal=causal, bq=64, bk=64)
    orf = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(o, orf, rtol=3e-4, atol=3e-4)


def test_flash_attention_bf16():
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 64)), jnp.bfloat16)
    o = flash_attention(q, k, v, causal=True, bq=64, bk=64)
    orf = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32), np.asarray(orf, np.float32),
                               rtol=0.05, atol=0.05)


def test_pinn_mlp_block_alignment_padding():
    """N not divisible by block_n: wrapper pads and slices correctly."""
    rng = np.random.default_rng(9)
    Ws = [jnp.asarray(rng.normal(0, 0.3, s), jnp.float32) for s in [(2, 16), (16, 1)]]
    bs = [jnp.zeros((16,)), jnp.zeros((1,))]
    a = jnp.ones((1,))
    x = jnp.asarray(rng.uniform(-1, 1, (37, 2)), jnp.float32)
    u, du = pinn_mlp_forward(x, Ws, bs, a, block_n=32)
    ur, dur = ref.pinn_mlp_ref(x, Ws, bs, a)
    assert u.shape == (37, 1) and du.shape == (2, 37, 1)
    np.testing.assert_allclose(u, ur, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(du, dur, rtol=1e-3, atol=1e-5)
