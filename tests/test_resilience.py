"""Resilient serving: admission, deadlines, the degraded ladder, isolation.

Covers the resilience contract (EXPERIMENTS.md §Serving-SLO):

* admission control sheds typed-and-fast on BOTH queue bounds, without ever
  dispatching the shed request;
* an expired deadline is answered ``deadline_exceeded`` and never dispatched;
* the degraded ladder steps order=2 -> order=1 -> cache-only under queue
  pressure, repeated failure, and an open breaker, with ``degraded=True`` in
  the envelope;
* the frontend's bisection quarantines a poisoned cloud while serving its
  healthy batch-mates, the resilience layer retries it (capped) and then
  answers ``failed``;
* the NaN/Inf output guard trips on corrupted CLAIMED points only —
  outside-domain NaN stays legal;
* the circuit breaker cycles closed -> open -> half_open -> closed on an
  injected clock;
* the invariant under the injected serve fault matrix: EVERY admitted ticket
  is answered exactly once and the queue drains.

Most tests drive a dependency-free stub engine (deterministic linear field)
on injected clocks, so they are milliseconds; two end-to-end fault-matrix
tests use the real FieldEngine (small one in tier-1, the sweep behind
``-m slo``).
"""
from types import SimpleNamespace

import numpy as np
import pytest

import jax

from repro.core import CartesianDecomposition
from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
from repro.core.pdes import Burgers1D
from repro.runtime import (
    Fault, FaultInjector, FaultyEngine, InjectedFailure, SERVE_FAULT_KINDS,
    parse_faults,
)
from repro.serve import (
    CircuitBreaker, EngineOutputError, FieldBundle, FieldEngine,
    ResilienceConfig, ResilientFrontend, ServeFrontend, UnknownTicketError,
)
from repro.serve import engine as engine_mod

POISON_X = 777.0   # stub engines treat clouds containing this x as poisoned


class StubEngine:
    """Deterministic engine double: u = pts @ [1, 2] (order-independent), all
    points claimed.  ``fail`` raises / ``nan`` corrupts row 0 whenever the
    dispatched cloud contains POISON_X — optionally only for the first
    ``fail_times`` such dispatches (transient vs persistent faults)."""

    def __init__(self, dim=2, fail=False, nan=False, fail_times=None,
                 fail_all=False):
        self.bundle = SimpleNamespace(
            decomp=SimpleNamespace(dim=dim))
        self.n_dispatches = 0
        self.poison_evals = 0
        self.last_claims = None
        self.fail, self.nan = fail, nan
        self.fail_times, self.fail_all = fail_times, fail_all

    def _faulting(self, pts) -> bool:
        if self.fail_all:
            return True
        if not (self.fail or self.nan) or POISON_X not in pts[:, 0]:
            return False
        self.poison_evals += 1
        return (self.fail_times is None
                or self.poison_evals <= self.fail_times)

    def evaluate(self, pts, order=2):
        pts = np.asarray(pts, float)
        faulting = self._faulting(pts)
        if faulting and (self.fail or self.fail_all):
            raise InjectedFailure("stub engine failure")
        self.n_dispatches += 1
        self.last_claims = np.ones(len(pts), np.int64)
        u = pts @ np.array([[1.0], [2.0]])
        if faulting and self.nan:
            u = u.copy()
            u[0] = np.nan
        return {"u": u}


def _cloud(n, seed=0):
    return np.random.default_rng(seed).uniform(-1.0, 1.0, size=(n, 2))


def _poison(n=3):
    c = _cloud(n, seed=99)
    c[0, 0] = POISON_X
    return c


def _rf(engine, clock=None, **cfg_kw):
    now = [0.0] if clock is None else clock
    fe = ResilientFrontend(engine, ResilienceConfig(**cfg_kw),
                           clock=lambda: now[0],
                           sleep=lambda s: now.__setitem__(0, now[0] + s))
    return fe, now


# ---------------------------------------------------------------- admission

def test_admission_sheds_on_queue_depth_without_dispatch():
    eng = StubEngine()
    # ladder thresholds > 1: this test isolates the admission bound
    fe, _ = _rf(eng, max_queue_requests=2, degrade_at=2.0, cache_only_at=3.0)
    t1, t2 = fe.submit(_cloud(4)), fe.submit(_cloud(5, seed=1))
    t3 = fe.submit(_cloud(6, seed=2))          # third would exceed the bound
    r3 = fe.result(t3)
    assert r3.status == "shed" and r3.reason == "overload" and not r3.ok
    assert eng.n_dispatches == 0               # shed BEFORE any dispatch
    fe.flush()
    assert fe.result(t1).status == "served"
    assert fe.result(t2).status == "served"
    assert fe.counters["shed_overload"] == 1


def test_admission_sheds_on_point_budget():
    eng = StubEngine()
    fe, _ = _rf(eng, max_queue_points=100)
    fe.submit(_cloud(90))
    t = fe.submit(_cloud(20, seed=1))          # 110 > 100 queued points
    assert fe.result(t).reason == "overload"
    assert eng.n_dispatches == 0


def test_admission_cache_hit_skips_the_queue():
    eng = StubEngine()
    fe, _ = _rf(eng)
    pts = _cloud(8)
    t = fe.submit(pts)
    fe.flush()
    assert fe.result(t).status == "served"
    d0 = eng.n_dispatches
    r = fe.result(fe.submit(pts))              # identical cloud: cache probe
    assert r.status == "served" and r.reason == "cache" and r.ok
    assert eng.n_dispatches == d0
    assert fe.counters["served_cache"] == 1


# ---------------------------------------------------------------- deadlines

def test_expired_deadline_answered_never_dispatched():
    eng = StubEngine()
    fe, now = _rf(eng, default_deadline=1.0)
    t = fe.submit(_cloud(4))
    now[0] = 2.0                               # past the deadline
    fe.flush()
    r = fe.result(t)
    assert r.status == "deadline_exceeded" and not r.ok
    assert eng.n_dispatches == 0
    assert fe.counters["deadline_exceeded"] == 1
    # per-request deadline overrides the default
    t2 = fe.submit(_cloud(4, seed=1), deadline=10.0)
    now[0] = 4.0
    fe.flush()
    assert fe.result(t2).status == "served"


def test_poll_flushes_on_queue_age():
    eng = StubEngine()
    fe, now = _rf(eng, max_queue_age=1.0)
    t = fe.submit(_cloud(4))
    assert not fe.poll() and eng.n_dispatches == 0
    assert fe.next_flush_due() == 1.0
    now[0] = 1.0
    assert fe.poll() and eng.n_dispatches == 1
    assert fe.result(t).status == "served"
    assert fe.next_flush_due() is None         # nothing pending


def test_poll_fires_exactly_at_next_flush_due():
    # Contract: a driver that advances its clock EXACTLY to next_flush_due()
    # must see poll() fire.  The old `clock - admitted >= age` comparison
    # could round one ulp below age when the due time was computed as
    # `admitted + age`, livelocking discrete-event drivers (the SLO
    # benchmark's virtual-time loop spun forever on exactly this).
    eng = StubEngine()
    fe, now = _rf(eng, max_queue_age=0.02)
    rng = np.random.default_rng(3)
    t = 0.0
    for i in range(200):
        t += float(rng.exponential(0.0137))
        now[0] = t
        ticket = fe.submit(_cloud(4, seed=i))  # unique → no admission cache hit
        due = fe.next_flush_due()
        if due is None:            # answered at admission (cache hit)
            continue
        now[0] = due
        assert fe.poll(), f"poll refused to fire at its own due time {due!r}"
        assert fe.result(ticket).status == "served"


# ------------------------------------------------------------------- ladder

def test_pressure_degrades_to_first_order():
    eng = StubEngine()
    fe, _ = _rf(eng, max_queue_requests=4, degrade_at=0.5, cache_only_at=0.9)
    ts = [fe.submit(_cloud(4, seed=s)) for s in range(2)]  # pressure 0.5
    fe.flush()
    for t in ts:
        r = fe.result(t)
        assert r.status == "degraded" and r.degraded and r.ok
        assert r.order == 1 and r.reason == "pressure"
    assert fe.counters["degraded"] == 2 and fe.level == 1


def test_cache_only_rung_serves_hits_sheds_misses():
    eng = StubEngine()
    fe, _ = _rf(eng, max_queue_requests=4, degrade_at=0.5, cache_only_at=0.9)
    warm = _cloud(4)
    # warm the cache at the DEGRADED tier (pressure 0.5 -> order=1), so the
    # admission-time full-order probe misses but the cache-only rung hits
    w0, w1 = fe.submit(warm), fe.submit(_cloud(4, seed=8))
    fe.flush()
    assert fe.result(w0).order == 1 and fe.result(w1).order == 1
    ts = [fe.submit(c) for c in
          (warm, _cloud(4, 1), _cloud(4, 2), _cloud(4, 3))]  # pressure 1.0
    d0 = eng.n_dispatches
    fe.flush()                                 # cache-only: NO dispatch
    assert eng.n_dispatches == d0 and fe.level == 2
    rs = [fe.result(t) for t in ts]
    assert rs[0].status == "degraded" and rs[0].reason == "cache_only"
    assert rs[0].ok and rs[0].degraded and rs[0].order == 1
    for r in rs[1:]:
        assert r.status == "shed" and r.reason == "cache_only"
    assert fe.counters["shed_cache_only"] == 3


def test_repeated_failure_degrades_the_retry():
    """A single transient failure still earns a full-order answer; from the
    second failed round on, the retry steps down to order=1."""
    eng = StubEngine(fail=True, fail_times=1)
    fe, _ = _rf(eng, retry_limit=4, breaker_threshold=10)
    t = fe.submit(_poison())
    fe.flush()
    r = fe.result(t)
    assert r.status == "served" and r.order == 2 and not r.degraded

    eng2 = StubEngine(fail=True, fail_times=3)
    fe2, _ = _rf(eng2, retry_limit=4, breaker_threshold=10)
    t = fe2.submit(_poison())
    fe2.flush()
    r = fe2.result(t)
    assert r.status == "degraded" and r.order == 1 and r.degraded and r.ok
    assert fe2.counters["retries"] >= 2


# ---------------------------------------------------------- circuit breaker

def test_circuit_breaker_cycle():
    now = [0.0]
    br = CircuitBreaker(threshold=2, cooldown=5.0, clock=lambda: now[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.allow()                          # under threshold
    br.record_failure()
    assert br.state == "open" and not br.allow() and br.opens == 1
    now[0] = 5.0
    assert br.allow() and br.state == "half_open"   # cooldown elapsed: probe
    br.record_failure()                        # probe failed: re-open
    assert br.state == "open" and br.opens == 2
    now[0] = 10.0
    assert br.allow() and br.state == "half_open"
    br.record_success()
    assert br.state == "closed" and br.failures == 0


def test_breaker_opens_fast_fails_then_recovers():
    eng = StubEngine(fail_all=True)
    fe, now = _rf(eng, retry_limit=1, breaker_threshold=1,
                  breaker_cooldown=5.0)
    t = fe.submit(_cloud(4))
    fe.flush()
    assert fe.result(t).status == "failed"
    assert fe.breaker.state == "open" and fe.counters["failed"] == 1
    assert not fe.health()["ready"]

    t2 = fe.submit(_cloud(4, seed=1))          # breaker open: no dispatch
    fe.flush()
    r2 = fe.result(t2)
    assert r2.status == "shed" and r2.reason == "breaker_open"
    assert eng.poison_evals == 0 and fe.counters["shed_breaker_open"] == 1

    eng.fail_all = False                       # engine healed
    now[0] = 100.0                             # past the cooldown: half-open
    t3 = fe.submit(_cloud(4, seed=2))
    fe.flush()
    r3 = fe.result(t3)                         # probe at the cheap tier
    assert r3.status == "degraded" and r3.order == 1 and r3.ok
    assert fe.breaker.state == "closed"        # probe success closed it
    assert fe.health()["ready"] and fe.health()["status"] == "ok"


# ------------------------------------------------------- bisect quarantine

def test_flush_bisects_and_serves_healthy_batchmates():
    """One poisoned cloud in a microbatch: healthy batch-mates are served,
    the poison is quarantined at the queue TAIL, and the failure re-raises —
    the old behavior requeued the whole batch at the head forever."""
    eng = StubEngine(fail=True)
    fe = ServeFrontend(eng, order=1)
    c1, c2 = _cloud(5), _cloud(7, seed=1)
    t1 = fe.submit(c1)
    tp = fe.submit(_poison())
    t2 = fe.submit(c2)
    with pytest.raises(InjectedFailure):
        fe.flush()
    assert fe.ready(t1) and fe.ready(t2) and not fe.ready(tp)
    assert fe.pending_tickets() == [tp]        # requeued, still answerable
    assert fe.counters["quarantined"] == 1
    np.testing.assert_allclose(fe.result(t1)["u"],
                               c1 @ np.array([[1.0], [2.0]]))
    np.testing.assert_allclose(fe.result(t2)["u"],
                               c2 @ np.array([[1.0], [2.0]]))
    eng.fail = False                           # heal: the quarantined cloud
    fe.flush()                                 # is served on the next flush
    assert np.isnan(fe.result(tp)["u"][0]).sum() == 0


def test_resilient_poison_failed_after_retry_cap():
    eng = StubEngine(fail=True)
    fe, _ = _rf(eng, retry_limit=2, breaker_threshold=100)
    th = fe.submit(_cloud(6))
    tp = fe.submit(_poison())
    fe.flush()
    assert fe.result(th).status == "served"    # healthy batch-mate unharmed
    rp = fe.result(tp)
    assert rp.status == "failed" and "InjectedFailure" in rp.reason
    assert fe.counters["retries"] >= 1
    assert fe.health()["unanswered"] == 0


# ------------------------------------------------------------- output guard

def test_nan_guard_trips_on_claimed_point():
    eng = StubEngine(nan=True)
    fe, _ = _rf(eng, retry_limit=2, breaker_threshold=100)
    th = fe.submit(_cloud(6))
    tp = fe.submit(_poison())
    fe.flush()
    assert fe.result(th).status == "served"
    rp = fe.result(tp)
    assert rp.status == "failed" and "EngineOutputError" in rp.reason
    assert fe.guard.trips >= 1
    # the poisoned result was never cached: a healthy re-ask dispatches anew
    assert fe.stats()["frontend"]["cache_entries"] == 1


def test_nan_at_unclaimed_point_is_legal():
    """Outside-domain NaN is the stitching contract, not corruption."""
    class OutsideNaN(StubEngine):
        def evaluate(self, pts, order=2):
            out = super().evaluate(pts, order)
            out["u"] = out["u"].copy()
            out["u"][0] = np.nan
            self.last_claims = np.ones(len(pts), np.int64)
            self.last_claims[0] = 0            # row 0: outside every region
            return out

    fe, _ = _rf(OutsideNaN())
    r = fe.query(_cloud(5))
    assert r.status == "served" and np.isnan(r.data["u"][0]).all()
    assert fe.guard.trips == 0


# ---------------------------------------------------------------- lifecycle

def test_drain_stops_admission_and_answers_everything():
    eng = StubEngine()
    fe, _ = _rf(eng)
    ts = [fe.submit(_cloud(4, seed=s)) for s in range(3)]
    health = fe.drain()
    assert health["status"] == "draining" and not health["ready"]
    assert health["unanswered"] == 0           # answered even if uncollected
    late = fe.submit(_cloud(4, seed=9))
    assert fe.result(late).reason == "draining"
    for t in ts:
        assert fe.result(t).status == "served"
    assert fe.stats()["answered"] == 4


def test_health_snapshot_fields():
    fe, _ = _rf(StubEngine(), max_queue_requests=4, degrade_at=0.5)
    h = fe.health()
    assert h["status"] == "ok" and h["ready"]
    assert h["breaker"]["state"] == "closed"
    assert h["queue"] == {"requests": 0, "points": 0, "pressure": 0.0}
    fe.submit(_cloud(4)), fe.submit(_cloud(4, 1))
    assert fe.health()["status"] == "degraded"  # pressure >= degrade_at
    assert fe.health()["queue"]["requests"] == 2


def test_resilient_result_pending_autoflush_and_double_pop():
    fe, _ = _rf(StubEngine())
    t = fe.submit(_cloud(4))
    assert fe.result(t).status == "served"     # pending ticket: auto-flush
    with pytest.raises(UnknownTicketError):
        fe.result(t)                           # results hand out once
    with pytest.raises(UnknownTicketError):
        fe.result(12345)


# -------------------------------------------------- fault-matrix end to end

def _tiny_bundle(seed=0):
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 3)})
    params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed))
    return FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                       act_codes=np.asarray(codes), pde=Burgers1D())


def _run_matrix(n_req: int, faults: list, seed=0) -> ResilientFrontend:
    now = [0.0]
    vsleep = lambda s: now.__setitem__(0, now[0] + s)
    engine = FaultyEngine(FieldEngine(_tiny_bundle()),
                          FaultInjector(faults), sleep=vsleep)
    fe = ResilientFrontend(
        engine, ResilienceConfig(order=2, default_deadline=5.0,
                                 max_queue_age=0.2, retry_backoff=0.01),
        clock=lambda: now[0], sleep=vsleep, seed=seed)
    rng = np.random.default_rng(seed)
    tickets = []
    for i in range(n_req):
        tickets.append(fe.submit(
            rng.uniform([-1, 0], [1, 1], size=(int(rng.choice((8, 24))), 2))))
        now[0] += 0.05
        fe.poll()
        if i % 3 == 2:
            fe.flush()
    fe.drain()
    results = [fe.result(t) for t in tickets]
    assert len(results) == n_req
    assert fe.stats()["answered"] == n_req     # every ticket answered once
    assert fe.health()["unanswered"] == 0      # ... and none left behind
    ok = [r for r in results if r.ok]
    assert ok, "fault matrix starved every request"
    for r in ok:   # data-bearing answers are finite at claimed points
        assert np.isfinite(r.data["u"]).any()
    return fe


def test_every_ticket_answered_under_fault_matrix():
    """Tier-1 subset: one of each serve fault kind against the real engine."""
    fe = _run_matrix(9, [Fault(chunk=1, kind="engine_raise"),
                         Fault(chunk=3, kind="nan_output"),
                         Fault(chunk=5, kind="slow_engine", delay=0.01)])
    # dispatch-indexed faults are transient: bisection's re-evaluation can
    # absorb them without a quarantine, but SOME layer must have seen them
    s = fe.stats()
    assert (s["guard_trips"] + s["flush_failures"]
            + s["frontend"]["quarantined"]) >= 1


@pytest.mark.slo
def test_fault_matrix_sweep():
    """The full sweep (``pytest -m slo``): dense cycling matrix including a
    compile storm, many microbatch shapes, breaker given a real workout."""
    from benchmarks.serve_slo import fault_matrix
    fe = _run_matrix(48, fault_matrix(96, period=3))
    s = fe.stats()
    assert s["guard_trips"] >= 1 or s["frontend"]["quarantined"] >= 1


# ------------------------------------------------------------ launch entry

def test_serve_field_demo_server(tmp_path, capsys):
    """launch/serve_field: demo bundle, Poisson traffic, faults, drain —
    exits 0 (every admitted ticket answered) and publishes a status file."""
    import json

    from repro.launch.serve_field import main

    status = str(tmp_path / "status.json")
    rc = main(["--demo", "cart", "--order", "1", "--rate", "50",
               "--duration", "0.8", "--max-requests", "6",
               "--queue-age", "0.01", "--heartbeat", "0.2",
               "--deadline", "2.0", "--status-file", status,
               "--faults", "engine-raise@2"])
    assert rc == 0
    report = json.loads(capsys.readouterr().out)
    assert report["requests"] >= 1
    assert sum(report["by_status"].values()) == report["requests"]
    assert report["drained"]["unanswered"] == 0
    final = json.loads(open(status).read())
    assert final["final"] and final["status"] == "draining"


# ------------------------------------------------------------ fault parsing

def test_parse_faults_serve_kinds_and_hyphens():
    fs = parse_faults("engine-raise@3,nan-output@5,slow-engine@7*0.2,"
                      "compile-storm@9")
    assert [f.kind for f in fs] == list(SERVE_FAULT_KINDS)
    assert fs[2].delay == 0.2
    with pytest.raises(ValueError, match="unknown fault kind"):
        parse_faults("engine-explode@1")


def test_faulty_engine_slow_and_storm():
    slept = []
    eng = FaultyEngine(StubEngine(),
                       FaultInjector([Fault(chunk=0, kind="slow_engine",
                                            delay=0.25),
                                      Fault(chunk=1, kind="compile_storm")]),
                       sleep=slept.append)
    eng.evaluate(_cloud(3))
    assert slept == [0.25]
    engine_mod._EVAL_CACHE["sentinel"] = object()
    eng.evaluate(_cloud(3))                    # storm drops the compiled cache
    assert "sentinel" not in engine_mod._EVAL_CACHE
    assert eng.injector.exhausted and eng.calls == 2
