"""HLO collective-byte parser unit tests (synthetic HLO lines + a real lowering)."""
import numpy as np
import pytest

from repro.utils.hlo import _sig_bytes, collective_bytes, op_histogram

HLO = """
HloModule jit_step
  %x = bf16[16,128]{1,0} parameter(0)
  %ar = bf16[16,128]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[64,128]{1,0} all-gather(%y), replica_groups=[2,4]<=[8], dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(%z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = bf16[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1},{1,0}}
  %aa = s32[16,16]{1,0} all-to-all(%v), replica_groups={{0,1}}
  %done = bf16[4,4]{1,0} all-reduce-done(%h)
"""


def test_sig_bytes():
    assert _sig_bytes("bf16[16,128]{1,0}") == 16 * 128 * 2
    assert _sig_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert _sig_bytes("f32[]") == 4


def test_collective_bytes_semantics():
    out = collective_bytes(HLO)
    bk = out["bytes_by_kind"]
    assert bk["all-reduce"] == 16 * 128 * 2          # operand = output
    assert bk["all-gather"] == 64 * 128 * 4 / 4      # operand = output / group 4
    assert bk["reduce-scatter"] == 8 * 128 * 4 * 4   # operand = output * group 4
    assert bk["collective-permute"] == 32 * 32 * 2
    assert bk["all-to-all"] == 16 * 16 * 4
    assert out["counts"]["all-reduce"] == 1          # -done line not double counted
    assert out["total_bytes"] == sum(bk.values())


def test_real_lowering_collectives(subproc):
    """psum over 4 fake devices shows up as an all-reduce with the right bytes."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.utils.hlo import collective_bytes
from repro.utils import shard_map
mesh = Mesh(np.array(jax.devices()), ("d",))
def f(x):
    return jax.lax.psum(x, "d")
sh = shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P(), check_vma=False)
txt = jax.jit(sh).lower(jax.ShapeDtypeStruct((8, 128), jnp.float32)).compile().as_text()
out = collective_bytes(txt)
assert out["counts"].get("all-reduce", 0) >= 1, out
assert out["total_bytes"] >= 2 * 128 * 4, out  # local shard operand bytes
print("HLO-OK", out["total_bytes"])
"""
    assert "HLO-OK" in subproc(code, n_devices=4)


def test_op_histogram():
    hist = dict(op_histogram(HLO))
    assert hist.get("all-reduce", 0) >= 1
