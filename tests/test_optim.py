"""Optimizer + compression unit/property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack hypothesis; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.optim import (
    AdamConfig, CompressionConfig, adam_update, clip_by_global_norm,
    compress_decompress, init_adam, warmup_cosine, wire_bytes,
)


def test_adam_matches_manual_math():
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st_ = init_adam(p)
    cfg = AdamConfig()
    p2, st2 = adam_update(g, st_, p, lr=0.01, cfg=cfg)
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.001 * np.array([0.1, 0.2, -0.3]) ** 2
    step = (m / 0.1) / (np.sqrt(v / 0.001) + cfg.eps)
    np.testing.assert_allclose(p2["w"], np.array([1.0, -2.0, 3.0]) - 0.01 * step,
                               rtol=1e-6)
    assert int(st2["count"]) == 1


def test_adam_per_subdomain_lr_broadcast():
    """lr vector applies along the stacked leading axis (paper's per-subdomain lr)."""
    p = {"w": jnp.ones((3, 4))}
    g = {"w": jnp.ones((3, 4))}
    st_ = init_adam(p)
    lrs = jnp.array([0.0, 0.01, 0.02])
    p2, _ = adam_update(g, st_, p, lr=lrs)
    np.testing.assert_allclose(p2["w"][0], 1.0)            # lr 0: unchanged
    d1 = float(1.0 - p2["w"][1, 0])
    d2 = float(1.0 - p2["w"][2, 0])
    assert abs(d2 / d1 - 2.0) < 1e-4


def test_clip_by_global_norm():
    g = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 5.0) < 1e-6
    total = np.sqrt(float(clipped["a"][0])**2 + float(clipped["b"][0])**2)
    assert abs(total - 1.0) < 1e-5


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), 1e-3, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1e-3) < 1e-9
    assert lrs[99] < lrs[50] < lrs[10]
    assert lrs[99] >= 0.1e-3 - 1e-9  # floor


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=4, max_size=64))
@settings(max_examples=40, deadline=None)
def test_error_feedback_is_lossless_in_aggregate(vals):
    """EF property: compressed + error == grad + prior error (nothing vanishes)."""
    g = {"w": jnp.asarray(np.array(vals, np.float32))}
    err = {"w": jnp.zeros_like(g["w"])}
    for scheme in ("int8", "topk"):
        comp, new_err = compress_decompress(g, err, CompressionConfig(scheme, 0.25))
        np.testing.assert_allclose(np.asarray(comp["w"]) + np.asarray(new_err["w"]),
                                   np.asarray(g["w"]), rtol=1e-5, atol=1e-4)


def test_topk_keeps_largest():
    g = {"w": jnp.asarray(np.array([0.1, -5.0, 0.2, 4.0], np.float32))}
    err = {"w": jnp.zeros(4)}
    comp, _ = compress_decompress(g, err, CompressionConfig("topk", topk_frac=0.5))
    np.testing.assert_allclose(comp["w"], [0.0, -5.0, 0.0, 4.0])


def test_wire_bytes_model():
    p = {"w": jnp.zeros((1000,))}
    assert wire_bytes(p, None) == 4000
    assert wire_bytes(p, CompressionConfig("int8")) == 1004
    assert wire_bytes(p, CompressionConfig("topk", 0.01)) == 80
