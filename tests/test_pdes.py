"""PDE residual/flux correctness: AD vs finite differences + exact solutions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pdes import Burgers1D, HeatConduction2D, NavierStokes2D


def _fd_deriv(u_fn, x, v, eps=1e-4):
    return (u_fn(x + eps * v) - u_fn(x - eps * v)) / (2 * eps)


def _fd_deriv2(u_fn, x, v, eps=1e-3):
    return (u_fn(x + eps * v) - 2 * u_fn(x) + u_fn(x - eps * v)) / eps**2


def _random_net(rng, n_out):
    W1 = jnp.asarray(rng.normal(0, 0.5, (2, 16)), jnp.float32)
    W2 = jnp.asarray(rng.normal(0, 0.5, (16, n_out)), jnp.float32)
    return lambda x: jnp.tanh(x @ W1) @ W2


def test_burgers_residual_matches_fd():
    rng = np.random.default_rng(0)
    u_fn = _random_net(rng, 1)
    pde = Burgers1D()
    ex, et = jnp.array([1.0, 0.0]), jnp.array([0.0, 1.0])
    for _ in range(5):
        x = jnp.asarray(rng.uniform(-1, 1, (2,)), jnp.float32)
        r = pde.residual(u_fn, x)
        u = u_fn(x)
        fd = (_fd_deriv(u_fn, x, et) + u * _fd_deriv(u_fn, x, ex)
              - pde.nu * _fd_deriv2(u_fn, x, ex))
        np.testing.assert_allclose(r, fd, rtol=2e-2, atol=2e-3)


def test_burgers_flux_conservation_form():
    """Space-time flux F=(u^2/2 - nu u_x, u): residual == div F pointwise."""
    rng = np.random.default_rng(1)
    u_fn = _random_net(rng, 1)
    pde = Burgers1D()
    for _ in range(5):
        x = jnp.asarray(rng.uniform(-1, 1, (2,)), jnp.float32)
        div = 0.0
        for i in range(2):
            v = jnp.zeros(2).at[i].set(1.0)
            div = div + _fd_deriv(lambda y: pde.flux(u_fn, y)[:, i], x, v)
        np.testing.assert_allclose(div, pde.residual(u_fn, x), rtol=3e-2, atol=3e-3)


def test_burgers_exact_cole_hopf_satisfies_ic_bc():
    pde = Burgers1D()
    x = np.linspace(-1, 1, 101)
    ic = pde.exact(np.stack([x, np.zeros_like(x)], 1))
    np.testing.assert_allclose(ic[:, 0], -np.sin(np.pi * x), atol=1e-6)
    walls = pde.exact(np.array([[1.0, 0.5], [-1.0, 0.5], [1.0, 0.9]]))
    np.testing.assert_allclose(walls, 0.0, atol=1e-4)
    # IC is -sin(pi x): u stays negative for x>0 and decays; u(0.5, 0.5) ~ -0.59
    mid = pde.exact(np.array([[0.5, 0.5]]))[0, 0]
    assert -0.65 < mid < -0.5
    # antisymmetry u(-x, t) = -u(x, t)
    pts = np.array([[0.3, 0.4], [-0.3, 0.4], [0.7, 0.8], [-0.7, 0.8]])
    u = pde.exact(pts)[:, 0]
    np.testing.assert_allclose(u[0], -u[1], rtol=1e-5)
    np.testing.assert_allclose(u[2], -u[3], rtol=1e-5)


def test_ns_residual_zero_at_kovasznay():
    """Kovasznay flow is an exact steady NS solution."""
    re = 40.0
    lam = re / 2 - np.sqrt(re**2 / 4 + 4 * np.pi**2)
    pde = NavierStokes2D(re=re)

    def exact(x):
        ex = jnp.exp(lam * x[0])
        u = 1 - ex * jnp.cos(2 * jnp.pi * x[1])
        v = lam / (2 * jnp.pi) * ex * jnp.sin(2 * jnp.pi * x[1])
        p = 0.5 * (1 - jnp.exp(2 * lam * x[0]))
        return jnp.stack([u, v, p])

    rng = np.random.default_rng(2)
    for _ in range(8):
        x = jnp.asarray(rng.uniform(0.1, 0.9, (2,)), jnp.float32)
        r = pde.residual(exact, x)
        np.testing.assert_allclose(r, 0.0, atol=5e-3)


def test_heat_inverse_residual_zero_at_exact():
    pde = HeatConduction2D()

    def exact(x):
        T = 20.0 * jnp.exp(-0.1 * x[1])
        K = 20.0 + jnp.exp(0.1 * x[1]) * jnp.sin(0.5 * x[0])
        return jnp.stack([T, K])

    rng = np.random.default_rng(3)
    for _ in range(8):
        x = jnp.asarray(rng.uniform(0, 5, (2,)), jnp.float32)
        np.testing.assert_allclose(pde.residual(exact, x), 0.0, atol=2e-3)
    # exact() helper agrees with the closure
    pts = rng.uniform(0, 5, (10, 2)).astype(np.float32)
    ref = pde.exact(pts)
    got = np.stack([np.asarray(exact(jnp.asarray(p))) for p in pts])
    np.testing.assert_allclose(got, ref, rtol=1e-5)


def test_heat_flux_is_K_grad_T():
    pde = HeatConduction2D()
    rng = np.random.default_rng(4)
    W = jnp.asarray(rng.normal(0, 0.4, (2, 12)), jnp.float32)
    W2 = jnp.asarray(rng.normal(0, 0.4, (12, 2)), jnp.float32)
    u_fn = lambda x: jnp.tanh(x @ W) @ W2 + jnp.array([1.0, 3.0])
    x = jnp.asarray(rng.uniform(0, 1, (2,)), jnp.float32)
    fl = pde.flux(u_fn, x)[0]
    K = u_fn(x)[1]
    gT = jax.jacfwd(lambda y: u_fn(y)[0])(x)
    np.testing.assert_allclose(fl, K * gT, rtol=1e-5)


# ------------------- batched derivative-bundle interface (fused-kernel path)

def _bundle_of(u_fn, x):
    """(u, du, d2u) of a closure via the per-point jvp oracle, batched."""
    from repro.core.pdes import dir_deriv, dir_deriv2

    dim = x.shape[1]
    u = jax.vmap(u_fn)(x)
    basis = [jnp.zeros((dim,)).at[j].set(1.0) for j in range(dim)]
    du = jnp.stack([jax.vmap(lambda xi, e=e: dir_deriv(u_fn, xi, e))(x) for e in basis])
    d2u = jnp.stack([jax.vmap(lambda xi, e=e: dir_deriv2(u_fn, xi, e))(x) for e in basis])
    return u, du, d2u


@pytest.mark.parametrize("pde,n_out,lo,hi", [
    (Burgers1D(), 1, -1.0, 1.0),
    (NavierStokes2D(), 3, 0.1, 0.9),
    (HeatConduction2D(), 2, 0.0, 2.0),
])
def test_residual_and_flux_from_derivs_match_closures(pde, n_out, lo, hi):
    """residual_from_derivs / flux_from_derivs on the jvp bundle == the
    per-point closure forms — the contract the fused kernel plugs into."""
    rng = np.random.default_rng(7)
    u_fn = _random_net(rng, n_out)
    x = jnp.asarray(rng.uniform(lo, hi, (16, 2)), jnp.float32)
    u, du, d2u = _bundle_of(u_fn, x)
    r_b = pde.residual_from_derivs(x, u, du, d2u)
    r_c = jax.vmap(lambda xi: pde.residual(u_fn, xi))(x)
    np.testing.assert_allclose(r_b, r_c, rtol=1e-5, atol=1e-6)
    f_b = pde.flux_from_derivs(x, u, du)
    f_c = jax.vmap(lambda xi: pde.flux(u_fn, xi))(x)
    np.testing.assert_allclose(f_b, f_c, rtol=1e-5, atol=1e-6)


def test_euler_residual_from_derivs_matches_closure():
    from repro.core.pdes import Euler1D

    pde = Euler1D()
    rng = np.random.default_rng(8)
    W = jnp.asarray(rng.normal(0, 0.3, (2, 12)), jnp.float32)
    W2 = jnp.asarray(rng.normal(0, 0.3, (12, 3)), jnp.float32)
    u_fn = lambda x: jnp.tanh(x @ W) @ W2 + jnp.array([1.5, 0.2, 2.0])  # rho>0
    x = jnp.asarray(rng.uniform(0.2, 0.8, (12, 2)), jnp.float32)
    u, du, d2u = _bundle_of(u_fn, x)
    r_b = pde.residual_from_derivs(x, u, du, d2u)
    r_c = jax.vmap(lambda xi: pde.residual(u_fn, xi))(x)
    np.testing.assert_allclose(r_b, r_c, rtol=1e-4, atol=1e-5)
    f_b = pde.flux_from_derivs(x, u, du)
    f_c = jax.vmap(lambda xi: pde.flux(u_fn, xi))(x)
    np.testing.assert_allclose(f_b, f_c, rtol=1e-5, atol=1e-6)
