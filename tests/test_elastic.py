"""Elastic re-decomposition: nearest-centroid remap, weighted rebalance, resume.

Covers the elastic-restart contract (EXPERIMENTS.md §Robustness):

* ``remap_params`` adopts each new subdomain's parameters from the old
  subdomain with the nearest centroid — verified against a hand-computed
  assignment on Cartesian grids AND the 10-region us_map polygons, and via
  :class:`CentroidSpec` (the metadata-only stand-in used after a restart,
  when the old geometry object is gone);
* ``balanced_counts`` preserves the global point budget exactly — leveled
  without weights, proportional-to-throughput with them (paper §7.6's
  straggler fix);
* ``elastic_resume`` restores a supervisor checkpoint taken at ``n_old``
  subdomains into a trainer built for ``n_new``: params remapped, moments
  fresh, the Adam step count and global step REALLY preserved end-to-end
  through save/restore (not just documented), and training re-converges.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology, evaluate_l2, us_map_decomposition,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.runtime import (
    CentroidSpec, Supervisor, SupervisorConfig, balanced_counts,
    decomp_signature, elastic_resume, remap_params, throughput_weights,
)


def _setup(nx, nt, n_res=48, width=16, depth=2, seed=0):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), nx, nt)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=16,
                   rng=np.random.default_rng(seed)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"))
    return pde, dec, cfg, b, tr


def _expected_src(old_dec, new_dec):
    oc = np.stack([old_dec.centroid(q) for q in range(old_dec.n_sub)])
    nc = np.stack([new_dec.centroid(q) for q in range(new_dec.n_sub)])
    return np.argmin(((nc[:, None] - oc[None]) ** 2).sum(-1), axis=1)


# ----------------------------------------------------------------- remapping

def test_remap_params_cartesian_hand_checked():
    old = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)   # 4 subdomains
    new = CartesianDecomposition(((-1, 1), (0, 1)), 3, 2)   # 6 subdomains
    params = {"w": jnp.arange(4 * 5, dtype=jnp.float32).reshape(4, 5)}
    remapped, src = remap_params(params, old, new)
    np.testing.assert_array_equal(src, _expected_src(old, new))
    np.testing.assert_array_equal(np.asarray(remapped["w"]),
                                  np.asarray(params["w"])[src])
    assert remapped["w"].shape == (6, 5)
    # every old subdomain's weights survive somewhere (2->3 columns: the old
    # column centroids are each nearest to at least one new column)
    assert set(src.tolist()) == {0, 1, 2, 3}


def test_remap_params_polygon_and_centroidspec():
    dec = us_map_decomposition()
    params = {"w": jnp.arange(dec.n_sub * 3, dtype=jnp.float32).reshape(
        dec.n_sub, 3)}
    # metadata round trip: the CentroidSpec rebuilt from a checkpoint's decomp
    # signature must drive the remap exactly like the live geometry object
    spec = CentroidSpec(decomp_signature(dec)["centroids"])
    assert spec.n_sub == dec.n_sub
    for q in range(dec.n_sub):
        np.testing.assert_allclose(spec.centroid(q), dec.centroid(q))
    # identity restart (same polygons): every subdomain adopts itself
    _, src_id = remap_params(params, spec, dec)
    np.testing.assert_array_equal(src_id, np.arange(dec.n_sub))
    # polygon -> Cartesian over the same footprint: matches the hand argmin
    lo = np.min([p.min(axis=0) for p in dec.polygons], axis=0)
    hi = np.max([p.max(axis=0) for p in dec.polygons], axis=0)
    new = CartesianDecomposition(((lo[0], hi[0]), (lo[1], hi[1])), 3, 2)
    remapped, src = remap_params(params, spec, new)
    np.testing.assert_array_equal(src, _expected_src(dec, new))
    np.testing.assert_array_equal(np.asarray(remapped["w"]),
                                  np.asarray(params["w"])[src])


# ---------------------------------------------------------------- rebalance

def test_balanced_counts_weighted_preserves_total_and_orders_by_speed():
    counts = [800, 3000, 3000, 3000, 3000]      # paper §7.6's idle-worker case
    total = sum(counts)
    level = balanced_counts(counts)
    assert sum(level) == total and max(level) - min(level) <= 1

    weights = [0.5, 1.0, 1.0, 1.0, 2.0]          # worker 0 slow, worker 4 fast
    out = balanced_counts(counts, weights)
    assert sum(out) == total                      # budget exact despite rounding
    assert out[0] < min(out[1:4]) < out[4]
    np.testing.assert_allclose(
        out, np.asarray(weights) / np.sum(weights) * total, atol=1.0)

    with pytest.raises(ValueError, match="weights"):
        balanced_counts(counts, [1.0, 2.0])
    with pytest.raises(ValueError, match="non-negative"):
        balanced_counts(counts, [-1.0, 1.0, 1.0, 1.0, 1.0])


def test_throughput_weights_feed_straggler_aware_rebalance():
    counts = [1000, 1000, 1000, 1000]
    walltimes = [1.0, 1.0, 1.0, 4.0]             # worker 3 is 4x slower
    w = throughput_weights(counts, walltimes)
    np.testing.assert_allclose(w, [1000.0, 1000.0, 1000.0, 250.0])
    out = balanced_counts(counts, w)
    assert sum(out) == 4000
    assert out[3] < out[0] and abs(out[3] - 4000 * 250 / 3250) <= 1.0
    # the supervisor-facing wrapper routes measured walltimes the same way
    pde, dec, cfg, b, tr = _setup(2, 2)
    sup = Supervisor(tr, "/tmp/unused-rebalance", decomp=dec)
    assert sup.rebalance_counts(counts, walltimes) == out
    lvl = sup.rebalance_counts([10, 20, 30, 40])
    assert lvl == [25, 25, 25, 25]


# ------------------------------------------------------------ elastic resume

def test_elastic_resume_same_n_sub_is_bitwise(tmp_path):
    pde, dec, cfg, b, tr = _setup(2, 2)
    root = str(tmp_path / "ckpt")
    sup = Supervisor(tr, root, SupervisorConfig(chunk_steps=3), decomp=dec)
    state, _ = sup.run(tr.init(0), b, 6)
    resumed, meta = elastic_resume(root, tr, dec)
    assert int(np.asarray(resumed.step)) == 6
    for a, c in zip(jax.tree.leaves((state.params, state.opt)),
                    jax.tree.leaves((resumed.params, resumed.opt))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert meta["supervisor"]["decomp"]["n_sub"] == 4


def test_elastic_resume_remaps_and_preserves_adam_count(tmp_path):
    """Adam step count preserved via metadata — REALLY true through
    save/restore: the resumed optimizer continues bias correction from the
    checkpointed count instead of restarting cold."""
    pde, dec, cfg, b, tr = _setup(2, 2)
    root = str(tmp_path / "ckpt")
    sup = Supervisor(tr, root, SupervisorConfig(chunk_steps=4), decomp=dec)
    state, _ = sup.run(tr.init(0), b, 8)
    assert int(np.asarray(state.opt["count"])) == 8

    pde2, dec2, cfg2, b2, tr2 = _setup(3, 2)       # elastic: 4 -> 6 subdomains
    resumed, meta = elastic_resume(root, tr2, dec2)
    src = _expected_src(dec, dec2)
    # params adopted nearest-centroid from the old stacked leaves
    for old_leaf, new_leaf in zip(jax.tree.leaves(state.params),
                                  jax.tree.leaves(resumed.params)):
        np.testing.assert_array_equal(np.asarray(old_leaf)[src],
                                      np.asarray(new_leaf))
    # moments reset, count + global step preserved from metadata
    for mom in ("m", "v"):
        assert all(float(np.abs(np.asarray(x)).max()) == 0.0
                   for x in jax.tree.leaves(resumed.opt[mom]))
    assert int(np.asarray(resumed.opt["count"])) == 8
    assert int(np.asarray(resumed.step)) == 8
    assert meta["supervisor"]["adam_count"] == 8


def test_elastic_resume_4_to_6_reconverges(tmp_path):
    """Acceptance: a checkpoint taken at 4 subdomains restarts at 6 and
    RE-CONVERGES — the remapped network is a warm start (better than cold
    init) and further training recovers the pre-restart error level."""
    pde, dec, cfg, b, tr = _setup(2, 2, n_res=64, width=20, depth=3)
    root = str(tmp_path / "ckpt")
    sup = Supervisor(tr, root, SupervisorConfig(chunk_steps=100), decomp=dec)
    state, _ = sup.run(tr.init(0), b, 400)
    err_old = evaluate_l2(dec, cfg, state.params, tr.act_codes, pde, n_pts=400)

    pde2, dec2, cfg2, b2, tr2 = _setup(3, 2, n_res=64, width=20, depth=3)
    resumed, _ = elastic_resume(root, tr2, dec2)
    err_cold = evaluate_l2(dec2, cfg2, tr2.init(0).params, tr2.act_codes, pde2,
                           n_pts=400)
    err_warm = evaluate_l2(dec2, cfg2, resumed.params, tr2.act_codes, pde2,
                           n_pts=400)
    assert err_warm < err_cold, (err_warm, err_cold)

    resumed, terms = tr2.run_chunk(resumed, b2, 400)
    err_new = evaluate_l2(dec2, cfg2, resumed.params, tr2.act_codes, pde2,
                          n_pts=400)
    assert np.isfinite(np.asarray(terms["loss"])).all()
    assert err_new < err_warm, (err_new, err_warm)
    assert err_new < max(1.5 * err_old, 0.5), (err_new, err_old)
