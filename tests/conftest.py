import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
sys.path.insert(0, SRC)


def run_subprocess(code: str, n_devices: int = 1, timeout: int = 600):
    """Run a python snippet in a fresh process with N fake CPU devices.

    Multi-device tests must run out-of-process: the main pytest process keeps the
    default single device (per the dry-run isolation rule).
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices > 1:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                            + env.get("XLA_FLAGS", ""))
    res = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={res.returncode})\nstdout:\n{res.stdout}\n"
            f"stderr:\n{res.stderr[-4000:]}")
    return res.stdout


@pytest.fixture
def subproc():
    return run_subprocess
