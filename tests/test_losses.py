"""Interface-loss semantics (paper eqs. 5/6): zero at consistency, message sizes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses, nets
from repro.core.domain import CartesianDecomposition, build_topology
from repro.core.halo import exchange_gather
from repro.core.losses import CPINN, XPINN, LossWeights
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.pdes import Burgers1D
from repro.data import make_batch


def _setup(method, same_net=True):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, 8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    rng = np.random.default_rng(0)
    batch = make_batch(dec, topo, pde, 32, 16, rng)
    if same_net:
        one = nets.init_model(cfg, jax.random.PRNGKey(0))
        params = jax.tree.map(lambda x: jnp.broadcast_to(x, (4,) + x.shape), one)
    else:
        params, _ = nets.stacked_init(cfg, 4, jax.random.PRNGKey(0))
    codes = jnp.zeros((4,), jnp.int32)
    return pde, topo, cfg, params, codes, batch.device_arrays()


def _terms(pde, topo, cfg, params, codes, b, method):
    payload = jax.vmap(
        lambda p, c, ip, nm: losses.payload_dot_normal(
            losses.interface_payload(pde, cfg, method, p, c, None, ip), nm, method)
    )(params, codes, b.iface_pts, b.iface_nrm)
    recv = jax.tree.map(lambda x: exchange_gather(x, topo), payload)
    _, terms = jax.vmap(
        lambda p, c, bb, ru, rg: losses.subdomain_loss(
            pde, cfg, method, LossWeights(), p, c, None, bb, ru, rg)
    )(params, codes, b, recv["u"], recv["g"])
    return terms


def test_interface_terms_vanish_for_identical_networks():
    """One global net split across subdomains: u_avg / flux / residual continuity = 0."""
    for method in (CPINN, XPINN):
        pde, topo, cfg, params, codes, b = _setup(method, same_net=True)
        terms = _terms(pde, topo, cfg, params, codes, b, method)
        np.testing.assert_allclose(np.asarray(terms["mse_avg"]), 0.0, atol=1e-10)
        np.testing.assert_allclose(np.asarray(terms["mse_iface"]), 0.0, atol=5e-9)


def test_interface_terms_positive_for_different_networks():
    for method in (CPINN, XPINN):
        pde, topo, cfg, params, codes, b = _setup(method, same_net=False)
        terms = _terms(pde, topo, cfg, params, codes, b, method)
        assert float(np.asarray(terms["mse_avg"]).sum()) > 1e-6
        assert float(np.asarray(terms["mse_iface"]).sum()) > 1e-6


def test_payload_wire_size_is_small():
    """The paper's communication argument: per-point message = n_fields + n_eq
    scalars (vs O(N_params) for data-parallel allreduce)."""
    pde, topo, cfg, params, codes, b = _setup(XPINN)
    p_one = jax.tree.map(lambda x: x[0], params)
    pay = losses.interface_payload(pde, cfg, XPINN, p_one, 0, None, b.iface_pts[0])
    pay = losses.payload_dot_normal(pay, b.iface_nrm[0], XPINN)
    K, nI = topo.n_slots, topo.n_iface
    assert pay["u"].shape == (K, nI, pde.n_fields)
    assert pay["g"].shape == (K, nI, pde.n_eq)
    per_point = pde.n_fields + pde.n_eq
    from repro.utils import tree_count
    assert per_point * 4 < 0.01 * tree_count(p_one) * 4  # << params bytes


def test_cpinn_flux_normal_antisymmetry():
    """Sender projects onto ITS outward normal; receiver negates: the loss term
    |f_q.n + recv|^2 must equal |f_q.n - f_q+.n|^2 of the paper."""
    pde, topo, cfg, params, codes, b = _setup(CPINN, same_net=True)
    payload = jax.vmap(
        lambda p, c, ip, nm: losses.payload_dot_normal(
            losses.interface_payload(pde, cfg, CPINN, p, c, None, ip), nm, CPINN)
    )(params, codes, b.iface_pts, b.iface_nrm)
    recv = jax.tree.map(lambda x: exchange_gather(x, topo), payload)
    em = np.asarray(b.edge_mask)[..., None, None]
    own_g, recv_g = np.asarray(payload["g"]), np.asarray(recv["g"])
    # identical nets -> f continuous -> own + recv == 0 on real edges
    np.testing.assert_allclose(em * (own_g + recv_g), 0.0, atol=1e-6)
