"""Single-dispatch training: scanned run_chunk drivers + megabatched entry.

Covers the step-fusion contract (EXPERIMENTS.md §Step fusion):

* ``run_chunk(state, batch, n)`` bitwise-matches ``n`` sequential ``step()``
  calls (Reference in-process, Distributed in a 4-device subprocess), incl.
  ``local_steps > 1`` and per-step stacked batches;
* ``TrainState`` donation: buffers really alias in place and repeated chunks
  never trip stale-buffer reuse;
* one loss evaluation == ONE megabatched network entry (trace-counted) and one
  packed weight stack per chunk body (HLO pad count — extends the PR-1 CSE
  test to the scanned driver);
* DataParallelTrainer derives its activation from the model config.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology, evaluate_l2,
)
from repro.core import nets
from repro.core.losses import CPINN, ResidualPath
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.trainer import DataParallelTrainer
from repro.data import make_batch, stack_batches
from repro.kernels import ops


def _setup(n_res=64, width=20, depth=3):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    batch = make_batch(dec, topo, pde, n_res=n_res, n_bnd=16,
                       rng=np.random.default_rng(0))
    return pde, dec, topo, cfg, batch.device_arrays()


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.mark.parametrize("path", ["jvp", "pallas"])
@pytest.mark.parametrize("method,local_steps", [(XPINN, 1), (CPINN, 2)])
def test_reference_chunk_matches_step_loop_bitwise(path, method, local_steps):
    pde, dec, topo, cfg, b = _setup()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=method, residual_path=path,
                                   local_steps=local_steps))
    s_loop = tr.init(0)
    for _ in range(3):
        s_loop, t_loop = tr.step(s_loop, b)
    s_chunk, t_chunk = tr.run_chunk(tr.init(0), b, 3)
    assert _max_diff(s_loop.params, s_chunk.params) == 0.0
    assert _max_diff(s_loop.opt, s_chunk.opt) == 0.0
    assert int(s_chunk.step) == 3
    # terms come back stacked (steps, n_sub); the last row is the loop's terms
    for k in t_loop:
        np.testing.assert_array_equal(np.asarray(t_chunk[k])[-1],
                                      np.asarray(t_loop[k]))


def test_reference_chunk_stacked_batches_matches_sequential_steps():
    """steps=None mode: leaves carry a leading chunk axis, one batch per step."""
    pde, dec, topo, cfg, _ = _setup()
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(residual_path="pallas"))
    batches = [make_batch(dec, topo, pde, n_res=64, n_bnd=16,
                          rng=np.random.default_rng(s)).device_arrays()
               for s in range(3)]
    s_loop = tr.init(1)
    for bb in batches:
        s_loop, _ = tr.step(s_loop, bb)
    s_chunk, terms = tr.run_chunk(tr.init(1), stack_batches(batches))
    assert _max_diff(s_loop.params, s_chunk.params) == 0.0
    assert np.asarray(terms["loss"]).shape == (3, topo.n_sub)


def test_run_chunk_donates_state_and_chains_cleanly():
    """donate_argnums on TrainState: the old buffers die (no silent copies)
    and chaining chunks off the returned state never hits stale-buffer reuse."""
    pde, dec, topo, cfg, b = _setup()
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(residual_path="pallas"))
    state = tr.init(0)
    leaves0 = jax.tree.leaves(state.params) + jax.tree.leaves(state.opt)
    state, _ = tr.run_chunk(state, b, 2)
    assert all(leaf.is_deleted() for leaf in leaves0), \
        "donated TrainState buffers were copied instead of aliased"
    # the returned state is fresh and immediately reusable — twice
    for expect in (4, 6):
        state, terms = tr.run_chunk(state, b, 2)
        assert int(state.step) == expect
        assert np.isfinite(np.asarray(terms["loss"])).all()


@pytest.mark.parametrize("local_steps", [1, 3])
def test_chunk_body_has_one_network_entry_per_loss_eval(local_steps):
    """Acceptance: the jitted chunk traces exactly ONE megabatched
    pinn_mlp_forward2 entry per loss evaluation — the exchange payload rides
    on inner step 1's forward (jax.vjp), so local_steps == entries, regardless
    of chunk length."""
    pde, dec, topo, cfg, b = _setup(n_res=32, width=16, depth=2)
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(residual_path="pallas",
                                   local_steps=local_steps))
    state = tr.init(0)
    calls = []
    orig = ops.pinn_mlp_forward2
    ops.pinn_mlp_forward2 = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    try:
        jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(state, b, 5)
    finally:
        ops.pinn_mlp_forward2 = orig
    assert len(calls) == local_steps, (
        f"chunk body traced {len(calls)} network entries for "
        f"{local_steps} loss evaluations")


def test_chunk_hlo_packs_weights_once_per_loss_eval():
    """HLO extension of the PR-1 pad-count test: the compiled scanned chunk
    pads/stacks the layer weights exactly once per loss evaluation (here
    local_steps=1 -> one (L, 128, 128) pack for the whole body), and the
    megabatch means ONE padded point tensor, not one per res/iface/data set."""
    pde, dec, topo, cfg, b = _setup(n_res=32, width=16, depth=2)
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(residual_path="pallas"))
    # force the padded Pallas dispatch (interpret mode); the CPU production
    # path is the unpadded jnp recurrence, which never packs
    tr.res_path = ResidualPath(act="tanh", block_n=32, interpret=True)
    state = tr.init(0)

    def weight_pads(txt):
        # packed weight stacks under vmap: f32[n_sub, 128, 128] pads
        return sum(1 for ln in txt.splitlines()
                   if " pad(" in ln and "f32[4,128,128]" in ln)

    txt3 = jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(
        state, b, 3).compile().as_text()
    n_layer_mats = 3  # depth-2 MLP: 2 hidden + 1 output weight matrix
    assert weight_pads(txt3) == n_layer_mats, \
        "chunk body packs the weight stack more than once per loss evaluation"
    # chunk length must not change the per-body pack count
    txt1 = jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(
        state, b, 1).compile().as_text()
    assert weight_pads(txt1) == weight_pads(txt3)


@pytest.mark.parametrize("backward_path", ["fused", "ref"])
def test_chunk_backward_routes_through_selected_reverse(backward_path):
    """HLO acceptance for the backward kernel: the compiled scanned chunk's
    backward contains the hand-derived fused reverse sweep (named-scope marker
    'pinn2-bwd-fused') and NO unrolled checkpointed-ref chain — and routes to
    the checkpointed oracle when backward_path='ref' is requested."""
    pde, dec, topo, cfg, b = _setup(n_res=32, width=16, depth=2)
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(residual_path="pallas",
                                   backward_path=backward_path))
    state = tr.init(0)
    txt = jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(
        state, b, 2).compile().as_text()
    has_fused, has_ref = "pinn2-bwd-fused" in txt, "pinn2-bwd-ref" in txt
    if backward_path == "fused":
        assert has_fused and not has_ref, (has_fused, has_ref)
    else:
        assert has_ref and not has_fused, (has_fused, has_ref)


def test_chunk_fused_and_ref_backward_agree():
    """Selector round-trip at the trainer level: a chunk trained with the
    hand-derived fused backward lands on the same loss as the checkpointed-ref
    backward (different implementations of the same gradient)."""
    pde, dec, topo, cfg, b = _setup()
    final = {}
    for bp in ("fused", "ref"):
        tr = ReferenceTrainer(pde, cfg, topo,
                              DDConfig(residual_path="pallas",
                                       backward_path=bp))
        _, terms = tr.run_chunk(tr.init(0), b, 10)
        final[bp] = np.asarray(terms["loss"])[-1]
    np.testing.assert_allclose(final["fused"], final["ref"], rtol=1e-3,
                               atol=1e-6)


def test_evaluate_l2_vectorized_matches_per_subdomain_loop():
    """The vmapped evaluation reproduces the per-subdomain Python loop."""
    pde, dec, topo, cfg, b = _setup()
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(),
                          act_codes=["tanh", "sin", "cos", "tanh"])
    state = tr.init(0)
    got = evaluate_l2(dec, cfg, state.params, tr.act_codes, pde, n_pts=200)

    rng = np.random.default_rng(0)
    errs, refs = [], []
    for q in range(dec.n_sub):
        pts = dec.sample_interior(q, 200 // dec.n_sub + 1, rng)
        ex = pde.exact(pts)
        p_q = jax.tree.map(lambda x: x[q], state.params)
        pred = nets.model_apply(cfg, p_q, jnp.asarray(pts, jnp.float32),
                                tr.act_codes[q])
        errs.append(np.asarray(pred) - ex)
        refs.append(ex)
    want = float(np.linalg.norm(np.concatenate(errs).ravel())
                 / (np.linalg.norm(np.concatenate(refs).ravel()) + 1e-30))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# --------------------------------------------------- DataParallel activation fix

def test_data_parallel_act_derived_from_model_cfg():
    """Regression: DataParallelTrainer no longer hardcodes tanh — the model
    config's activation reaches both the jvp loss and the fused ResidualPath."""
    pde, dec, topo, cfg_tanh, b = _setup()
    cfg_sin = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 20, 3, act="sin")})
    tr = DataParallelTrainer(pde, cfg_sin, n_workers=1, residual_path="pallas")
    assert tr.act == "sin" and tr.res_path.act == "sin"
    assert tr.act_code == nets.ACT_SIN
    st, terms = tr.step(tr.init(0), jax.tree.map(lambda x: x[:1], b))
    assert np.isfinite(float(terms["loss"]))
    # sin != tanh: the derived activation must actually change the loss
    tr_t = DataParallelTrainer(pde, cfg_tanh, n_workers=1, residual_path="pallas")
    _, terms_t = tr_t.step(tr_t.init(0), jax.tree.map(lambda x: x[:1], b))
    assert abs(float(terms["loss"]) - float(terms_t["loss"])) > 1e-6


def test_data_parallel_mixed_acts_rejected():
    """Raise only on genuinely unsupported configs: per-net mixed activations
    (model_apply evaluates all field nets with one activation code)."""
    pde = Burgers1D()
    mixed = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2, act="tanh"),
                                       "k": MLPConfig(2, 1, 16, 2, act="sin")})
    with pytest.raises(ValueError, match="mixed activations"):
        DataParallelTrainer(pde, mixed, n_workers=1)


# --------------------------------------------------- distributed (subprocess)

DIST_CHUNK_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch

pde = Burgers1D()
dec = CartesianDecomposition(((-1,1),(0,1)), nx=2, ny=2)
topo = build_topology(dec, n_iface=8)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2,1,16,2)})
batch = make_batch(dec, topo, pde, n_res=48, n_bnd=16, rng=np.random.default_rng(0))
b = batch.device_arrays()

for path, local_steps in [("pallas", 1), ("jvp", 2)]:
    dd = DDConfig(method=XPINN, residual_path=path, local_steps=local_steps)
    tr = DistributedDDTrainer(pde, cfg, topo, dd, lrs=[1e-3,2e-3,3e-3,4e-3])
    bd = tr.shard_batch(b)
    s_loop = tr.shard_state(tr.init(0))
    for _ in range(3):
        s_loop, t_loop = tr.step(s_loop, bd)
    s_chunk, t_chunk = tr.run_chunk(tr.shard_state(tr.init(0)), bd, 3)
    err = max(float(np.max(np.abs(np.asarray(a)-np.asarray(c))))
              for a, c in zip(jax.tree.leaves(s_loop.params),
                              jax.tree.leaves(s_chunk.params)))
    # the scanned SPMD program is compiled separately from the per-step one,
    # so XLA may fuse (and round) differently: float-noise tolerance here;
    # the single-device Reference trainer equivalence is asserted BITWISE
    assert err < 1e-7, (path, local_steps, err)
    assert int(s_chunk.step) == 3
    tl = np.asarray(t_loop["loss"]); tc = np.asarray(t_chunk["loss"])
    assert tc.shape == (3,) + tl.shape, (tc.shape, tl.shape)
    assert np.allclose(tc[-1], tl, rtol=1e-6, atol=1e-7), (tc[-1], tl)
print("DIST-CHUNK-OK")
"""


@pytest.mark.slow
def test_distributed_chunk_matches_step_loop(subproc):
    out = subproc(DIST_CHUNK_CODE, n_devices=4, timeout=900)
    assert "DIST-CHUNK-OK" in out
