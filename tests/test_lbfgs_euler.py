"""L-BFGS refinement (paper §6) + Euler conservation-law PDE tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pdes import Euler1D
from repro.optim.lbfgs import LBFGSConfig, lbfgs_refine


def test_lbfgs_quadratic_converges():
    target = jnp.arange(5.0)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    p, losses = lbfgs_refine(loss, {"w": jnp.zeros(5)}, 15)
    assert losses[-1] < 1e-8
    np.testing.assert_allclose(p["w"], target, atol=1e-4)


def test_lbfgs_rosenbrock():
    ros = lambda p: jnp.sum(100 * (p["x"][1:] - p["x"][:-1] ** 2) ** 2
                            + (1 - p["x"][:-1]) ** 2)
    p, losses = lbfgs_refine(ros, {"x": jnp.zeros(4)}, 80)
    assert losses[-1] < 1e-2 * losses[0]


def test_lbfgs_monotone_nonincreasing():
    """Armijo backtracking never accepts an ascent step."""
    rng = np.random.default_rng(0)
    A = jnp.asarray(rng.normal(0, 1, (8, 8)), jnp.float32)
    Q = A @ A.T + 0.1 * jnp.eye(8)
    loss = lambda p: 0.5 * p["x"] @ Q @ p["x"] + jnp.sum(jnp.sin(p["x"]))
    _, losses = lbfgs_refine(loss, {"x": jnp.ones(8)}, 25)
    assert all(b <= a + 1e-6 for a, b in zip(losses, losses[1:]))


@pytest.mark.slow
def test_lbfgs_refines_pinn_after_adam():
    """The standard PINN recipe: Adam then L-BFGS drops the loss further."""
    from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                            ReferenceTrainer, XPINN, build_topology)
    from repro.core.losses import LossWeights, vanilla_pinn_loss
    from repro.core.nets import ACT_TANH, MLPConfig, SubdomainModelConfig, init_model
    from repro.data import make_vanilla_batch

    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 1, 1)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 20, 3)})
    rng = np.random.default_rng(0)
    batch = make_vanilla_batch(dec, pde, 512, 64, rng)
    loss_fn = lambda p: vanilla_pinn_loss(pde, cfg, LossWeights(), p, ACT_TANH,
                                          None, batch)[0]
    params = init_model(cfg, jax.random.PRNGKey(0))
    # short Adam phase
    from repro.optim import adam as A
    opt = A.init_adam(params)
    step = jax.jit(lambda p, o: (lambda l, g: A.adam_update(g, o, p, 2e-3) + (l,))(
        *jax.value_and_grad(loss_fn)(p)))
    for _ in range(300):
        params, opt, adam_loss = step(params, opt)
    params, losses = lbfgs_refine(loss_fn, params, 60)
    # curvature-aware refinement beats continuing plateaued Adam; monotone by design
    assert losses[-1] < 0.9 * float(adam_loss), (float(adam_loss), losses[-1])
    assert all(b <= a + 1e-6 for a, b in zip(losses, losses[1:]))


def test_euler_residual_matches_fd():
    pde = Euler1D()
    rng = np.random.default_rng(0)
    W1 = jnp.asarray(rng.normal(0, 0.3, (2, 16)), jnp.float32)
    W2 = jnp.asarray(rng.normal(0, 0.3, (16, 3)), jnp.float32)
    u_fn = lambda x: jnp.tanh(x @ W1) @ W2 + jnp.array([1.0, 0.1, 2.0])
    eps = 1e-4
    ex, et = jnp.array([1.0, 0.0]), jnp.array([0.0, 1.0])
    for _ in range(5):
        x = jnp.asarray(rng.uniform(0.1, 0.9, (2,)), jnp.float32)
        r = pde.residual(u_fn, x)
        fd = ((u_fn(x + eps * et) - u_fn(x - eps * et)) / (2 * eps)
              + (pde._flux_x(u_fn(x + eps * ex)) - pde._flux_x(u_fn(x - eps * ex)))
              / (2 * eps))
        np.testing.assert_allclose(r, fd, rtol=3e-2, atol=3e-3)


def test_euler_constant_state_zero_residual():
    """Any constant state is an exact Euler solution."""
    pde = Euler1D()
    u_fn = lambda x: jnp.array([1.0, 0.3, 2.5]) + 0.0 * x[0]
    r = pde.residual(u_fn, jnp.array([0.3, 0.1]))
    np.testing.assert_allclose(r, 0.0, atol=1e-6)


def test_euler_sod_ic_and_flux_shape():
    pde = Euler1D()
    pts = np.array([[0.25, 0.0], [0.75, 0.0], [0.0, 0.1], [1.0, 0.05]])
    vals, comp, keep = pde.boundary_data(pts)
    assert keep.all() and comp.shape == (4, 3)
    np.testing.assert_allclose(vals[0], [1.0, 0.0, 2.5])          # left state
    np.testing.assert_allclose(vals[1], [0.125, 0.0, 0.25])       # right state
    u_fn = lambda x: jnp.array([1.0, 0.3, 2.5]) + 0.0 * x[0]
    assert pde.flux(u_fn, jnp.array([0.5, 0.1])).shape == (3, 2)


@pytest.mark.slow
def test_euler_cpinn_trains():
    """cPINN with flux continuity on the Sod problem: loss decreases."""
    from repro.core import (CartesianDecomposition, CPINN, DDConfig,
                            LossWeights, ReferenceTrainer, build_topology)
    from repro.core.nets import MLPConfig, SubdomainModelConfig
    from repro.data import make_batch

    pde = Euler1D()
    dec = CartesianDecomposition(((0, 1), (0, 0.2)), 4, 1)
    topo = build_topology(dec, 12)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 3, 24, 4)})
    rng = np.random.default_rng(0)
    batch = make_batch(dec, topo, pde, 256, 64, rng)
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=CPINN, weights=LossWeights(data=40.0)),
                          lrs=1e-3)
    st = tr.init(0)
    b = batch.device_arrays()
    losses = []
    for _ in range(250):
        st, terms = tr.step(st, b)
        losses.append(float(np.asarray(terms["loss"]).sum()))
    assert losses[-1] < 0.3 * losses[0], (losses[0], losses[-1])
