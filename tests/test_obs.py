"""Unified telemetry: registry, JSONL events, in-graph training rows,
retrace flatness, staged serve latency.

Covers the observability contract (EXPERIMENTS.md §Observability):

* the metrics registry: counters/gauges/log-bucket histograms with percentile
  export, the ``CounterGroup`` view that keeps legacy ``stats()`` shapes, and
  ONE injectable clock shared by everything hanging off it;
* the JSONL event stream: manifest-first, schema-versioned, strictly
  validated — malformed streams FAIL;
* in-graph telemetry rows (``DDConfig(telemetry=True)``): per-step
  per-subdomain grad/param norms, lr, interface mismatch, and guard ``step_ok``
  flags ride the scanned chunk's stacked outputs; ``telemetry=False`` keeps
  the terms dict AND the trained parameters bitwise identical to before;
* the telemetry-enabled guarded chunk stays a single donated dispatch — the
  megabatched network entry still traces exactly twice (eval_shape probe +
  the one live cond branch), the compiled HLO packs weights exactly as often
  as the plain chunk;
* retrace flatness, asserted with a flat-line compile counter
  (``CompileWatcher`` over ``jax.monitoring``): serve batch buckets,
  guarded/unguarded chunks, and ``lr_scale`` changes dispatch with ZERO new
  backend compiles once warm;
* supervisor and serve frontends publish into the shared registry (reports
  and ``stats()`` unchanged) and stamp staged latencies (queue wait /
  dispatch / e2e) onto every answered ticket.

Unmarked tests are the tier-1 subset; the timing-sensitive overhead bound and
the multi-device subprocess sweep run under ``-m obs`` (see pytest.ini).
"""
import json
import time
from types import SimpleNamespace

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology,
)
from repro.core.losses import ResidualPath
from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
from repro.core.trainer import DataParallelTrainer
from repro.data import make_batch
from repro.kernels import ops
from repro.obs import (
    CompileWatcher, Counter, EventLog, Histogram, MetricsRegistry, Obs,
    ObsSchemaError, SCHEMA_VERSION, make_obs, read_events, validate_events,
)
from repro.runtime import Fault, FaultInjector, Supervisor, SupervisorConfig
from repro.serve import (
    FieldBundle, FieldEngine, ResilienceConfig, ResilientFrontend,
)
from repro.utils.hlo import named_scope_counts


# ------------------------------------------------------------------ registry

def test_counter_gauge_and_group_keep_stats_shapes():
    reg = MetricsRegistry()
    c = reg.counter("x/hits")
    c.inc()
    c.inc(2)
    assert c.snapshot() == 3 and isinstance(c.snapshot(), int)
    reg.gauge("x/depth").set(7)
    assert reg.gauge("x/depth").snapshot() == 7.0
    # the legacy dict idiom, backed by registry counters
    g = reg.group("serve.test", ("requests", "shed"))
    g["requests"] += 1
    g["new_key"] = 5
    assert dict(g) == {"requests": 1, "shed": 0, "new_key": 5}
    assert reg.counter("serve.test/requests").snapshot() == 1
    with pytest.raises(TypeError):
        del g["shed"]
    with pytest.raises(TypeError):   # name collision across metric types
        reg.gauge("x/hits")
    snap = reg.snapshot("serve.test")
    assert snap == {"serve.test/new_key": 5, "serve.test/requests": 1,
                    "serve.test/shed": 0}


def test_histogram_percentiles_within_bucket_error():
    h = Histogram("t", lo=1e-6, hi=10.0)
    for v in np.linspace(0.001, 0.1, 1000):
        h.record(v)
    h.record(float("nan"))           # skipped, never poisons the summary
    s = h.snapshot()
    assert s["count"] == 1000
    assert s["min"] == pytest.approx(0.001) and s["max"] == pytest.approx(0.1)
    # log-bucket guarantee: quantile within one growth factor (2**0.25)
    for p, true in ((50, 0.0505), (90, 0.0901), (99, 0.099)):
        assert true / 2 ** 0.25 <= h.percentile(p) <= true * 2 ** 0.25
    assert h.percentile(0) == s["min"] and h.percentile(100) == s["max"]
    empty = Histogram("e")
    assert empty.percentile(50) is None
    assert empty.snapshot()["count"] == 0


def test_registry_timer_uses_injected_clock():
    now = [0.0]
    reg = MetricsRegistry(clock=lambda: now[0])
    with reg.timer("x/op_s"):
        now[0] += 0.25
    s = reg.histogram("x/op_s").snapshot()
    assert s["count"] == 1 and s["max"] == pytest.approx(0.25)


# -------------------------------------------------------------------- events

def test_eventlog_manifest_first_and_validates(tmp_path):
    path = str(tmp_path / "run.jsonl")
    now = [10.0]
    log = EventLog(path, clock=lambda: now[0], run_id="r1",
                   config={"n_sub": 4})
    now[0] = 11.5
    log.emit("chunk", step=3, steps=3, loss=0.5, walltime_s=0.2)
    log.emit("guard_trip", chunk=1, bad_subdomains=[0, 2], good_steps=2)
    log.close()
    manifest = validate_events(path)
    assert manifest["run_id"] == "r1"
    assert manifest["schema_version"] == SCHEMA_VERSION
    events = read_events(path)
    assert [e["kind"] for e in events] == ["manifest", "chunk", "guard_trip"]
    assert events[1]["t"] == pytest.approx(11.5)   # injected-clock timestamps


@pytest.mark.parametrize("corrupt", ["drop_t", "bad_kind", "bad_version",
                                     "missing_field", "no_manifest"])
def test_validate_rejects_malformed_streams(tmp_path, corrupt):
    path = str(tmp_path / "run.jsonl")
    log = EventLog(path, clock=time.perf_counter, run_id="r")
    log.emit("heartbeat", status="ok")
    log.close()
    lines = open(path).read().splitlines()
    if corrupt == "drop_t":
        e = json.loads(lines[1]); e.pop("t"); lines[1] = json.dumps(e)
    elif corrupt == "bad_kind":
        e = json.loads(lines[1]); e["kind"] = "nonsense"
        lines[1] = json.dumps(e)
    elif corrupt == "bad_version":
        m = json.loads(lines[0]); m["schema_version"] = 999
        lines[0] = json.dumps(m)
    elif corrupt == "missing_field":
        e = json.loads(lines[1]); e.pop("status"); lines[1] = json.dumps(e)
    elif corrupt == "no_manifest":
        lines = lines[1:]
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")
    with pytest.raises(ObsSchemaError):
        validate_events(path)


def test_obs_bundle_metrics_only_emit_is_noop():
    obs = Obs(registry=MetricsRegistry())
    obs.emit("heartbeat", status="ok")   # no sink: must not raise
    obs.close()
    assert obs.clock is obs.registry.clock


# ---------------------------------------------------- in-graph telemetry rows

def _setup(n_res=48, width=16, depth=2, telemetry=False, lrs=1e-3):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=8)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, telemetry=telemetry),
                          lrs=lrs)
    return pde, dec, cfg, b, tr


def _max_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_telemetry_rows_shapes_and_values():
    _, _, _, b, tr = _setup(telemetry=True, lrs=1e-3)
    _, terms = tr.run_chunk(tr.init(0), b, 3)
    for k in ("grad_norm", "param_norm", "lr", "iface_mismatch"):
        assert terms[k].shape == (3, 4), k
        assert np.isfinite(np.asarray(terms[k])).all(), k
    assert np.asarray(terms["lr"]) == pytest.approx(1e-3)
    assert (np.asarray(terms["grad_norm"]) > 0).all()
    # iface_mismatch is the rms of the two interface penalties
    im = np.sqrt(np.asarray(terms["mse_avg"]) + np.asarray(terms["mse_iface"]))
    assert np.asarray(terms["iface_mismatch"]) == pytest.approx(im)


def test_telemetry_off_keeps_terms_and_params_bitwise():
    _, _, _, b, tr_off = _setup(telemetry=False)
    _, _, _, _, tr_on = _setup(telemetry=True)
    s_off, t_off = tr_off.run_chunk(tr_off.init(0), b, 3)
    s_on, t_on = tr_on.run_chunk(tr_on.init(0), b, 3)
    assert set(t_off) == {"loss", "mse_data", "mse_res", "mse_avg",
                          "mse_iface"}           # off-mode key regression
    assert set(t_on) > set(t_off)
    assert _max_diff(s_off.params, s_on.params) == 0.0   # rows are pure reads
    assert _max_diff(t_off["loss"], t_on["loss"]) == 0.0


def test_guarded_telemetry_step_ok_and_lr_scale_row():
    _, _, _, b, tr = _setup(telemetry=True, lrs=1e-3)
    scale = jnp.asarray([1.0, 0.5, 0.25, 1.0], jnp.float32)
    _, terms, health = tr.run_chunk_guarded(tr.init(0), b, 3,
                                            lr_scale=scale)
    assert bool(np.asarray(health["ok_sub"]).all())
    ok = np.asarray(terms["step_ok"])
    assert ok.shape == (3, 4) and ok.all()
    # the lr row reports the EFFECTIVE per-subdomain rate (backoff included)
    assert np.asarray(terms["lr"]) == pytest.approx(
        np.broadcast_to(1e-3 * np.asarray(scale), (3, 4)))


def test_data_parallel_telemetry_rows():
    pde, dec, cfg, b, _tr = _setup()
    tr = DataParallelTrainer(pde, cfg, n_workers=1, lr=1e-3, telemetry=True)
    _, terms = tr.run_chunk(tr.init(0), b, 2)
    assert "iface_mismatch" not in terms    # data-parallel has no interfaces
    for k in ("grad_norm", "param_norm", "lr"):
        assert terms[k].shape[0] == 2 and np.isfinite(np.asarray(terms[k])).all()
    # linear-scaling rule [Goyal et al.]: effective lr = base lr * world size
    assert np.asarray(terms["lr"]) == pytest.approx(1e-3 * tr.n)


def test_telemetry_guarded_single_dispatch_donation_and_hlo():
    """The telemetry-enabled guarded chunk is STILL one donated dispatch: the
    megabatched entry traces exactly twice (abstract eval_shape probe + the
    one live cond branch), the compiled program packs the weight stack exactly
    as often as the plain guarded chunk, and donation holds."""
    _, _, _, b, tr = _setup(n_res=32, telemetry=True)
    tr.res_path = ResidualPath(act="tanh", block_n=32, interpret=True)
    _, _, _, _, tr_plain = _setup(n_res=32, telemetry=False)
    tr_plain.res_path = tr.res_path
    state = tr.init(0)
    ones = jnp.ones((4,), jnp.float32)

    calls = []
    orig = ops.pinn_mlp_forward2
    ops.pinn_mlp_forward2 = lambda *a, **k: (calls.append(1),
                                             orig(*a, **k))[1]
    try:
        jax.jit(tr._run_chunk_guarded, static_argnums=(2,)).lower(
            state, b, 5, ones)
    finally:
        ops.pinn_mlp_forward2 = orig
    assert len(calls) == 2

    def weight_pads(txt):
        return sum(1 for ln in txt.splitlines()
                   if " pad(" in ln and "f32[4,128,128]" in ln)

    telem = jax.jit(tr._run_chunk_guarded, static_argnums=(2,)).lower(
        state, b, 3, ones).compile().as_text()
    plain = jax.jit(tr_plain._run_chunk_guarded, static_argnums=(2,)).lower(
        tr_plain.init(0), b, 3, ones).compile().as_text()
    assert weight_pads(telem) == weight_pads(plain) == 3

    # donation: the telemetry chunk consumes its input state buffers
    st0 = tr.init(0)
    st1, _, _ = tr.run_chunk_guarded(st0, b, 2)
    assert any(x.is_deleted() for x in jax.tree.leaves(st0.params))
    st2, _, _ = tr.run_chunk_guarded(st1, b, 2)   # rebind keeps working
    assert int(st2.step) == 4


def test_named_scopes_survive_into_compiled_hlo():
    _, _, _, b, tr = _setup(n_res=32)
    hlo = jax.jit(tr._run_chunk_const, static_argnums=(2,)).lower(
        tr.init(0), b, 2).compile().as_text()
    scopes = named_scope_counts(hlo, prefix="dd-")
    assert scopes.get("dd-comp-forward", 0) > 0
    assert scopes.get("dd-comp-update", 0) > 0


# --------------------------------------------------------- retrace flatness

def test_compile_watcher_counts_compiles_not_cache_hits():
    f = jax.jit(lambda x: x * 2 + 1)
    with CompileWatcher() as w1:
        f(jnp.ones((7,)))             # fresh shape: at least one compile
    assert w1.backend_compiles >= 1
    with CompileWatcher() as w2:
        for _ in range(5):
            f(jnp.ones((7,)))         # cache hits: dead flat
    assert w2.backend_compiles == 0 and w2.traces == 0


def test_retrace_flat_across_guard_and_lr_scale():
    """Warm both chunk drivers once; interleaving them and sweeping lr_scale
    must never compile again (the supervisor backoff guarantee, asserted)."""
    _, _, _, b, tr = _setup(n_res=32, telemetry=True)
    st = tr.run_chunk(tr.init(0), b, 2)[0]
    stg = tr.run_chunk_guarded(tr.init(0), b, 2)[0]
    with CompileWatcher() as w:
        st = tr.run_chunk(st, b, 2)[0]
        for s in (1.0, 0.5, 0.25):
            stg = tr.run_chunk_guarded(stg, b, 2,
                                       lr_scale=jnp.full((4,), s))[0]
    assert w.backend_compiles == 0


def test_retrace_flat_across_serve_batch_buckets():
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 12, 2)})
    params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(0))
    eng = FieldEngine(FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                                  act_codes=np.asarray(codes), pde=None))
    rng = np.random.default_rng(0)
    clouds = [rng.uniform((-1, 0), (1, 1), size=(n, 2)) for n in (8, 60, 200)]
    for c in clouds:
        eng.evaluate(c, order=1)       # warm each padded bucket once
    with CompileWatcher() as w:
        for _ in range(2):
            for c in clouds:
                eng.evaluate(c, order=1)
    assert w.backend_compiles == 0


# ----------------------------------------------------- supervisor integration

def test_supervisor_injected_clock_and_registry_mirror(tmp_path):
    """The supervisor times chunks/straggler recovery on the obs clock (a
    5s injected straggler is 'absorbed' instantly under a fake sleep) and
    mirrors its report counters into the shared registry."""
    _, dec, _, b, tr = _setup()
    now = [0.0]
    obs = Obs(registry=MetricsRegistry(clock=lambda: now[0]))
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=2),
                     FaultInjector([Fault(chunk=1, kind="straggler",
                                          delay=5.0)]),
                     decomp=dec, obs=obs,
                     sleep=lambda s: now.__setitem__(0, now[0] + s))
    _, report = sup.run(tr.init(0), b, 6)
    assert report.stragglers == 1 and report.chunks == 3
    assert report.walltimes[1] >= 5.0          # fake clock saw the delay
    snap = obs.registry.snapshot("train.supervisor")
    assert snap["train.supervisor/chunks"] == report.chunks
    assert snap["train.supervisor/stragglers"] == 1
    assert snap["train.supervisor/crashes"] == 0
    assert snap["train.supervisor/chunk_walltime_s"]["count"] == 3


def test_supervisor_event_stream_validates(tmp_path):
    _, dec, _, b, tr = _setup()
    path = str(tmp_path / "run.jsonl")
    obs = make_obs(path, run_id="sup-test")
    sup = Supervisor(tr, str(tmp_path / "ckpt"),
                     SupervisorConfig(chunk_steps=2),
                     FaultInjector([Fault(chunk=0, kind="nan_params",
                                          subdomain=0)]),
                     decomp=dec, obs=obs)
    _, report = sup.run(tr.init(0), b, 4)
    obs.close()
    assert report.guard_trips == 1
    validate_events(path)
    kinds = [e["kind"] for e in read_events(path)]
    assert kinds[0] == "manifest"
    assert "guard_trip" in kinds and "rollback" in kinds
    assert kinds.count("chunk") == report.chunks
    trip = next(e for e in read_events(path) if e["kind"] == "guard_trip")
    assert 0 in trip["bad_subdomains"]


# ---------------------------------------------------------- serve integration

class _StubEngine:
    """Deterministic engine double (cf. tests/test_resilience.py)."""

    def __init__(self, dim=2):
        self.bundle = SimpleNamespace(decomp=SimpleNamespace(dim=dim))
        self.n_dispatches = 0
        self.last_claims = None

    def evaluate(self, pts, order=2):
        pts = np.asarray(pts, float)
        self.n_dispatches += 1
        self.last_claims = np.ones(len(pts), np.int64)
        return {"u": pts @ np.array([[1.0], [2.0]])}


def test_serve_staged_latency_on_result_and_stats():
    now = [0.0]
    fe = ResilientFrontend(_StubEngine(), ResilienceConfig(),
                           clock=lambda: now[0],
                           sleep=lambda s: now.__setitem__(0, now[0] + s))
    res = fe.query(np.array([[0.1, 0.2], [0.3, 0.4]]))
    assert res.ok
    assert res.queue_wait is not None and res.queue_wait >= 0.0
    assert res.dispatch is not None and res.dispatch >= 0.0
    lat = fe.stats()["latency"]
    for stage in ("e2e_s", "queue_wait_s", "dispatch_s"):
        assert lat[stage]["count"] >= 1, stage
    # cache hit: answered at admission, zero queue/dispatch time by definition
    res2 = fe.query(np.array([[0.1, 0.2], [0.3, 0.4]]))
    assert res2.ok and res2.reason == "cache"
    assert res2.queue_wait == 0.0 and res2.dispatch == 0.0
    # one registry spans both layers
    snap = fe.obs.registry.snapshot()
    assert snap["serve.resilience/admitted"] == 2
    assert snap["serve.frontend/dispatches"] >= 1


def test_engine_publishes_dispatch_metrics():
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 12, 2)})
    params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(0))
    obs = Obs(registry=MetricsRegistry())
    eng = FieldEngine(FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                                  act_codes=np.asarray(codes), pde=None),
                      obs=obs)
    pts = np.random.default_rng(0).uniform((-1, 0), (1, 1), size=(10, 2))
    eng.evaluate(pts, order=1)
    eng.evaluate(pts, order=1)
    snap = obs.registry.snapshot("serve.engine")
    assert snap["serve.engine/dispatches"] == 2
    assert snap["serve.engine/points"] == 20
    assert snap["serve.engine/dispatch_s"]["count"] == 2


# ------------------------------------------------------------ marked sweeps

@pytest.mark.obs
def test_telemetry_overhead_within_bound():
    """The in-graph rows must cost <= 2% on a quickstart-sized guarded chunk
    (paired interleaved timing; the benchmark enforces the same bound)."""
    from benchmarks.obs_telemetry import OVERHEAD_BOUND_PCT, overhead_rows
    _, detail = overhead_rows(iters=8, smoke=False)
    assert detail["overhead_pct"] <= OVERHEAD_BOUND_PCT


@pytest.mark.obs
@pytest.mark.slow
def test_distributed_telemetry_and_halo_scope(subproc):
    """4-device shard_map chunk: telemetry rows come back with per-subdomain
    columns and the compiled program attributes its collective-permutes to
    the dd-comm-halo named scope."""
    out = subproc("""
import json
import numpy as np, jax
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.obs import halo_traffic
from repro.utils.hlo import named_scope_counts

pde = Burgers1D()
dec = CartesianDecomposition(((-1, 1), (0, 1)), 4, 1)
topo = build_topology(dec, 8)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 12, 2)})
b = make_batch(dec, topo, pde, 32, 8, np.random.default_rng(0)).device_arrays()
tr = DistributedDDTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, telemetry=True), lrs=1e-3)
bd = tr.shard_batch(b)
st, terms = tr.run_chunk(tr.shard_state(tr.init(0)), bd, 2)
assert terms["grad_norm"].shape == (2, 4), terms["grad_norm"].shape
assert terms["lr"].shape == (2, 4)
assert np.isfinite(np.asarray(terms["iface_mismatch"])).all()
hlo = tr._build_chunk(2).lower(tr.shard_state(tr.init(0)), bd)\\
    .compile().as_text()
traffic = halo_traffic(hlo)
assert traffic["collective_permute_ops"] > 0
scopes = named_scope_counts(hlo, prefix="dd-")
assert scopes.get("dd-comm-halo", 0) > 0, scopes
print("OK", json.dumps(traffic["collective_permute_bytes"]))
""", n_devices=4)
    assert "OK" in out
