"""Property tests for the frontend's greedy microbatch packer (hypothesis).

The packer invariants, under arbitrary submit sequences:

* no dispatched microbatch exceeds ``max_batch`` points UNLESS it is a single
  cloud that is itself larger (a lone oversized request still gets served);
* every ticket's result equals its standalone evaluation (ticket -> slice
  correspondence survives packing, dedup, and batch boundaries);
* identical clouds inside one flush are evaluated once (dedup) and every
  duplicate ticket receives the shared result;
* dispatched points account exactly for the unique queued points — nothing
  evaluated twice, nothing dropped.

Plus the deadline-flush path under injected clock skew: a clock that jumps
backwards must neither crash ``poll`` nor trigger a spurious flush.
"""
from types import SimpleNamespace

import numpy as np
import pytest

try:   # property tests need hypothesis; the clock-skew test runs regardless
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False
    def given(**kw):   # decorators become skip markers
        return pytest.mark.skip(reason="hypothesis not installed")
    settings = given

    class _NullStrategies:    # st.* evaluates at decoration time: no-op it
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _NullStrategies()

from repro.serve import ServeFrontend

W = np.array([[1.0], [2.0]])   # the stub's exact linear field


class RecordingEngine:
    """Pure-numpy engine double: u = pts @ W, records every dispatch size."""

    def __init__(self):
        self.bundle = SimpleNamespace(decomp=SimpleNamespace(dim=2))
        self.batch_sizes: list[int] = []

    def evaluate(self, pts, order=2):
        pts = np.asarray(pts, float)
        self.batch_sizes.append(len(pts))
        return {"u": pts @ W}


def _clouds_from(sizes, dups, seed=0):
    """Deterministic clouds; ``dups[i]`` aliases cloud i to cloud i-1."""
    rng = np.random.default_rng(seed)
    out = []
    for i, n in enumerate(sizes):
        if i > 0 and dups[i]:
            out.append(out[i - 1])
        else:
            out.append(rng.uniform(-1.0, 1.0, size=(n, 2)))
    return out


@settings(max_examples=60, deadline=None)
@given(sizes=st.lists(st.integers(1, 40), min_size=1, max_size=12),
       dups=st.lists(st.booleans(), min_size=12, max_size=12),
       max_batch=st.integers(4, 120))
def test_packer_invariants(sizes, dups, max_batch):
    eng = RecordingEngine()
    fe = ServeFrontend(eng, order=1, max_batch=max_batch)
    clouds = _clouds_from(sizes, dups)
    tickets = [fe.submit(c) for c in clouds]
    fe.flush()

    # (1) batch bound: only a lone oversized cloud may exceed max_batch
    biggest = max(len(c) for c in clouds)
    for b in eng.batch_sizes:
        assert b <= max(max_batch, biggest)
        if b > max_batch:
            assert b == biggest  # an unsplittable single cloud, not a pack

    # (2+3) ticket -> slice correspondence, dedup shares bitwise results
    seen: dict[bytes, np.ndarray] = {}
    for t, c in zip(tickets, clouds):
        got = fe.result(t)["u"]
        np.testing.assert_allclose(got, c @ W, atol=1e-12)
        key = c.tobytes()
        if key in seen:
            assert got.tobytes() == seen[key].tobytes()
        seen[key] = got

    # (4) exact point accounting: unique queued points, each evaluated once
    unique_pts = sum(len(np.frombuffer(k, float)) // 2 for k in seen)
    assert sum(eng.batch_sizes) == unique_pts
    assert fe.counters["dispatched_points"] == unique_pts


@settings(max_examples=30, deadline=None)
@given(n_pre=st.integers(0, 5))
def test_dedup_single_dispatch_within_flush(n_pre):
    """N identical clouds in one flush = ONE evaluation of that cloud."""
    eng = RecordingEngine()
    fe = ServeFrontend(eng, order=1, max_batch=1000)
    rng = np.random.default_rng(3)
    pre = [rng.uniform(-1, 1, size=(5, 2)) for _ in range(n_pre)]
    dup = rng.uniform(-1, 1, size=(7, 2))
    tickets = [fe.submit(c) for c in pre] + [fe.submit(dup) for _ in range(4)]
    fe.flush()
    assert len(eng.batch_sizes) == 1           # everything packs + dedups
    assert eng.batch_sizes[0] == 5 * n_pre + 7
    for t in tickets:
        fe.result(t)
    assert fe.counters["requests"] == n_pre + 4


def test_deadline_flush_under_clock_skew():
    """A backwards clock jump (NTP step, VM migration) must not crash poll
    or flush early; once the clock moves past the head's age, it flushes."""
    eng = RecordingEngine()
    now = [100.0]
    fe = ServeFrontend(eng, order=1, max_queue_age=1.0, clock=lambda: now[0])
    t = fe.submit(np.zeros((3, 2)))
    now[0] = 50.0                              # clock jumps BACKWARDS
    assert not fe.poll() and not eng.batch_sizes
    tb = fe.submit(np.ones((2, 2)))            # head enqueue time stays 100.0
    assert not eng.batch_sizes                 # no spurious age-out flush
    now[0] = 100.5
    assert not fe.poll()                       # 0.5s old: under the deadline
    now[0] = 101.0
    assert fe.poll() and len(eng.batch_sizes) == 1
    fe.result(t), fe.result(tb)
    assert fe.stats()["deadline_flushes"] == 1
