"""Correctness of the §Perf optimization levers: every beyond-paper variant must be
numerically equivalent to the faithful path (debug-forward, never regress-silently)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def test_causal_skip_attention_parity():
    """Python-loop causal block skipping == scanned masked attention."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(0, 1, (2, 64, 8, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 64, 2, 16)), jnp.float32)
    a = L.chunked_attention(q, k, v, causal=True, block_q=16, causal_skip=True)
    b = L.chunked_attention(q, k, v, causal=True, block_q=16, causal_skip=False)
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


MOE_CODE = """
import os, dataclasses
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_batch
from repro.models.sharding import rules_for, use_rules
from repro.utils import set_mesh

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg0 = get_config("deepseek-moe-16b").reduced(n_heads=4, n_kv_heads=4, vocab=512,
                                              n_experts=8, top_k=2, capacity_factor=8.0)
shape = ShapeConfig("t", 32, 4, "train")
batch = make_batch(cfg0, shape, "train")
outs = {}
for sm in (False, True):
    cfg = dataclasses.replace(cfg0, moe_shard_map=sm, dtype="float32")
    model = build_model(cfg)
    with set_mesh(mesh), use_rules(rules_for()):
        params = model.init(jax.random.PRNGKey(0))
        loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    outs[sm] = (float(loss), grads)
l0, g0 = outs[False]; l1, g1 = outs[True]
assert abs(l0 - l1) < 5e-4 * max(1, abs(l0)), (l0, l1)
errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(g0), jax.tree.leaves(g1))]
assert max(errs) < 2e-3, max(errs)
print("MOE-SHARDMAP-PARITY-OK")
"""


@pytest.mark.slow
def test_moe_shardmap_parity(subproc):
    """shard_map expert parallelism == GSPMD grouped dispatch (loss AND grads)."""
    out = subproc(MOE_CODE, n_devices=8, timeout=900)
    assert "MOE-SHARDMAP-PARITY-OK" in out


SEQPAR_CODE = """
import numpy as np, jax, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_batch
from repro.models.sharding import rules_for, use_rules
from repro.utils import set_mesh
import dataclasses

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(n_heads=4, n_kv_heads=4,
                                                            vocab=512), dtype="float32")
shape = ShapeConfig("t", 64, 4, "train")
batch = make_batch(cfg, shape, "train")
model = build_model(cfg)
outs = {}
for seqpar in (False, True):
    rules = rules_for()
    if seqpar:
        rules["res_seq"] = "model"
    with set_mesh(mesh), use_rules(rules):
        params = model.init(jax.random.PRNGKey(0))
        loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    outs[seqpar] = (float(loss), grads)
l0, g0 = outs[False]; l1, g1 = outs[True]
assert abs(l0 - l1) < 1e-4 * max(1, abs(l0)), (l0, l1)
errs = [float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(g0), jax.tree.leaves(g1))]
assert max(errs) < 1e-3, max(errs)
print("SEQPAR-PARITY-OK")
"""


@pytest.mark.slow
def test_sequence_parallel_parity(subproc):
    """res_seq sharding changes layout only, never values."""
    out = subproc(SEQPAR_CODE, n_devices=8, timeout=900)
    assert "SEQPAR-PARITY-OK" in out
