"""Serving SLO under load and under faults: latency, goodput, shed, degrade.

The throughput benchmark (serve_throughput.py) asks "how fast is a dispatch";
this one asks the production question: **under Poisson arrivals at a given
rate, what fraction of requests get a within-deadline answer — and what does
the resilience layer do when the engine misbehaves?**

Protocol — discrete-event virtual time with REAL service times: the whole
stack (ResilientFrontend, deadlines, breaker, fault injection) runs on an
injected virtual clock; every engine dispatch advances that clock by its
measured wall-clock duration, injected ``slow_engine``/backoff sleeps advance
it directly.  Arrival timestamps are exact Poisson draws, so queueing
dynamics are faithful, while the run itself finishes as fast as the engine
can compute (no real idle waiting, and the container's CPU-quota drift can't
fake queueing delay).  Load is expressed in utilization ρ relative to the
measured per-request service time, so the same config is meaningful on any
machine; the deadline is a fixed multiple of that service time.

Each load point runs twice: **clean** and **faulted** (the serve fault matrix
from ``runtime.failures``: ``engine_raise``, ``nan_output``, ``slow_engine``,
``compile_storm``, cycling every few dispatches).  Reported per run: p50/p99
latency, goodput (within-deadline data-bearing fraction), shed rate, degraded
engagement, deadline-exceeded/failed counts — plus the hard invariant checks
(every ticket answered, queue fully drained).

Writes ``BENCH_slo.json`` at the repo root (``BENCH_slo_smoke.json`` with
--smoke).  ``slo_smoke_rows`` is the CI acceptance wired into
``benchmarks/run.py --smoke``: it FAILS if invariants break or if goodput
under the fault matrix drops below threshold.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import numpy as np

from repro.core import us_map_decomposition
from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
from repro.core.pdes import HeatConduction2D
from repro.runtime import Fault, FaultInjector, FaultyEngine
from repro.serve import (FieldBundle, FieldEngine, ResilienceConfig,
                         ResilientFrontend)

from benchmarks.common import bench_path, emit, history_append
TABLE3_ACTS = ["tanh", "sin", "cos", "tanh", "sin", "cos", "tanh", "sin",
               "cos", "tanh"]

# Shape discipline: the frontend merges queued clouds into microbatches, so
# dispatch shapes are NOT the per-cloud shapes — without care every merged
# batch hits a novel bucketed (n_sub, m, dim) and the virtual clock measures
# XLA retracing instead of serving.  A coarse routing bucket (512) + a
# max_batch cap (1024 points) pins essentially every dispatch to m=512
# (m=1024 worst case, pre-warmed), i.e. ONE compiled program per order.
BUCKET = 512
MAX_BATCH = 1024


def _bundle(seed: int = 0) -> FieldBundle:
    decomp = us_map_decomposition()
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2),
                                     "k": MLPConfig(2, 1, 16, 2)})
    params, codes = stacked_init(cfg, decomp.n_sub, jax.random.PRNGKey(seed),
                                 TABLE3_ACTS)
    return FieldBundle(model_cfg=cfg, params=params, decomp=decomp,
                       act_codes=np.asarray(codes), pde=HeatConduction2D())


class _TimedEngine:
    """Couple real dispatch cost into the virtual timeline: every evaluate
    advances the injected clock by its measured wall-clock duration."""

    def __init__(self, engine, now: list):
        self.engine, self._now = engine, now

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def evaluate(self, pts, order: int = 2) -> dict:
        t0 = time.perf_counter()
        try:
            return self.engine.evaluate(pts, order=order)
        finally:
            self._now[0] += time.perf_counter() - t0


def _clouds(decomp, n: int, seed: int) -> list:
    """Workload mix: ~30% repeated dashboard grid (cache traffic), the rest
    fresh uniform clouds of 32/128/512 points."""
    rng = np.random.default_rng(seed)
    verts = np.concatenate(decomp.polygons)
    lo, hi = verts.min(axis=0), verts.max(axis=0)
    gx, gy = np.meshgrid(np.linspace(lo[0], hi[0], 16),
                         np.linspace(lo[1], hi[1], 16))
    dashboard = np.stack([gx.ravel(), gy.ravel()], axis=1)
    out = []
    for _ in range(n):
        if rng.uniform() < 0.3:
            out.append(dashboard)
        else:
            out.append(rng.uniform(lo, hi,
                                   size=(int(rng.choice((32, 128, 512))), 2)))
    return out


def fault_matrix(n_dispatches: int, period: int = 4,
                 storm: bool = True) -> list:
    """The serve-side matrix: cycle the per-dispatch kinds every ``period``
    dispatches, plus ONE compile_storm (a storm models a server restart /
    cache loss — rare, but its recompile tail must not wedge the queue).
    ``storm=False`` drops it: the storm's goodput dip is expected recompile
    cost, so CI floors measure the other three kinds."""
    kinds = ("engine_raise", "nan_output", "slow_engine")
    out = [Fault(chunk=i, kind=kinds[(i // period) % 3],
                 delay=0.05 if kinds[(i // period) % 3] == "slow_engine"
                 else 0.0)
           for i in range(2, n_dispatches, period)]
    if storm:
        out.append(Fault(chunk=max(1, n_dispatches // 3),
                         kind="compile_storm"))
    return out


def _warm(engine, clouds) -> None:
    """Compile the (only) dispatch shapes a run can hit: m=512 for every
    single/merged cloud under MAX_BATCH, plus the m=1024 worst case (a merged
    batch concentrating > BUCKET points in one region)."""
    routed = engine._route(clouds[0])
    inside = clouds[0][np.asarray(routed.owner) >= 0][:1]
    tall = np.repeat(inside, BUCKET + 1, axis=0)   # one region, 513 claims
    for order in (2, 1):
        engine.evaluate(clouds[0], order=order)    # m = 512
        engine.evaluate(tall, order=order)         # m = 1024
    engine.n_dispatches = 0


def _service_time(bundle, clouds) -> float:
    """Median per-request dispatch seconds (compile-warm) — the load unit."""
    eng = FieldEngine(bundle, bucket=BUCKET)
    _warm(eng, clouds)
    ts = []
    for c in clouds[:20]:
        t0 = time.perf_counter()
        eng.evaluate(c, order=2)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _slo_run(bundle, clouds, rate: float, deadline: float,
             faults=None, seed: int = 0) -> dict:
    now = [0.0]
    clock = lambda: now[0]
    vsleep = lambda s: now.__setitem__(0, now[0] + max(0.0, float(s)))

    engine = FieldEngine(bundle, bucket=BUCKET)
    # pre-warm BOTH dispatch shapes (see BUCKET/MAX_BATCH note above) so
    # "clean" latency is queueing + service, not compile; compile_storm
    # re-injects the compile cost deliberately in the faulted runs.
    _warm(engine, clouds)
    if faults:
        engine = FaultyEngine(engine, FaultInjector(faults), sleep=vsleep)
    timed = _TimedEngine(engine, now)
    # queue caps sized to the workload (avg cloud ~230 pts) so the pressure
    # ladder is reachable: at rho > 1 the backlog crosses degrade_at (50%),
    # then cache_only_at, then sheds — instead of queueing unboundedly.
    cfg = ResilienceConfig(order=2, default_deadline=deadline,
                           max_queue_requests=32, max_queue_points=1 << 13,
                           max_queue_age=deadline / 8,
                           retry_backoff=deadline / 16,
                           breaker_cooldown=deadline)
    fe = ResilientFrontend(timed, cfg, clock=clock, sleep=vsleep, seed=seed,
                           max_batch=MAX_BATCH)

    rng = np.random.default_rng(seed + 7)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(clouds)))
    tickets = []
    for t_i, pts in zip(arrivals, clouds):
        t_i = float(t_i)
        # discrete-event step: fire every queue-head age-out scheduled before
        # this arrival (a real server's poll loop runs between arrivals too)
        while True:
            due = fe.next_flush_due()
            if due is None or due >= t_i:
                break
            now[0] = max(now[0], due)
            fe.poll()
        now[0] = max(now[0], t_i)
        tickets.append(fe.submit(pts))
    fe.drain()
    results = [fe.result(t) for t in tickets]

    lat = sorted(r.latency for r in results if r.ok)
    pct = lambda p: (float(lat[min(len(lat) - 1, int(p / 100 * len(lat)))])
                     if lat else float("nan"))
    n = len(results)
    by_status: dict = {}
    for r in results:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    stats = fe.stats()
    return {
        "rate_rps": round(rate, 2),
        "requests": n,
        "by_status": by_status,
        "p50_ms": round(pct(50) * 1e3, 2),
        "p99_ms": round(pct(99) * 1e3, 2),
        "goodput": round(sum(1 for r in results
                             if r.ok and r.latency <= deadline) / n, 4),
        "shed_rate": round(sum(1 for r in results
                               if r.status == "shed") / n, 4),
        "degraded_frac": round(sum(1 for r in results if r.degraded) / n, 4),
        "deadline_exceeded": by_status.get("deadline_exceeded", 0),
        "failed": by_status.get("failed", 0),
        "retries": stats["retries"],
        "guard_trips": stats["guard_trips"],
        "breaker_opens": stats["breaker_opens"],
        "quarantined": stats["frontend"]["quarantined"],
        "cache_hit_rate": round(stats["frontend"]["hit_rate"], 4),
        # invariants: no ticket lost, queue fully drained
        "all_answered": stats["answered"] == n,
        "drained": fe.health()["unanswered"] == 0,
    }


def run(smoke: bool = False, seed: int = 0):
    bundle = _bundle(seed)
    n_req = 60 if smoke else 250
    clouds = _clouds(bundle.decomp, n_req, seed)
    t_req = _service_time(bundle, clouds)
    deadline = max(0.05, 8.0 * t_req)
    # rho is PER-REQUEST utilization; microbatching amortizes dispatch cost
    # (a merged batch costs ~one dispatch), so effective capacity is ~4
    # requests per service time — the top load point sits well past it to
    # drive the queue into the degrade/shed regime.
    rhos = (0.6,) if smoke else (0.3, 1.0, 6.0)

    records, rows = [], []
    for rho in rhos:
        rate = rho / t_req
        faults = fault_matrix(2 * n_req)
        clean = _slo_run(bundle, clouds, rate, deadline, seed=seed)
        faulted = _slo_run(bundle, clouds, rate, deadline, faults=faults,
                           seed=seed)
        for rec in (clean, faulted):
            if not (rec["all_answered"] and rec["drained"]):
                raise AssertionError(f"SLO invariant broken at rho={rho}: "
                                     f"{rec}")
        records.append({"rho": rho, "deadline_ms": round(deadline * 1e3, 2),
                        "clean": clean, "faulted": faulted})
        for tag, rec in (("clean", clean), ("faulted", faulted)):
            rows.append((f"slo/rho{rho}/{tag}/p50_ms", rec["p50_ms"], "ms"))
            rows.append((f"slo/rho{rho}/{tag}/p99_ms", rec["p99_ms"], "ms"))
            rows.append((f"slo/rho{rho}/{tag}/goodput", rec["goodput"], ""))
            rows.append((f"slo/rho{rho}/{tag}/shed_rate",
                         rec["shed_rate"], ""))
            rows.append((f"slo/rho{rho}/{tag}/degraded_frac",
                         rec["degraded_frac"], ""))

    out = bench_path("slo", smoke)
    with open(out, "w") as f:
        json.dump({
            "workload": "us_map 10-region inverse-heat bundle (2 nets/region "
                        "3x16, Table-3 acts); 30% repeated dashboard grid + "
                        "fresh 32/128/512-pt clouds",
            "protocol": "discrete-event virtual clock, real measured service "
                        "times; load in utilization rho of the measured "
                        "per-request service time",
            "service_time_ms": round(t_req * 1e3, 3),
            "deadline_ms": round(deadline * 1e3, 2),
            "backend": jax.default_backend(),
            "fault_matrix": "engine_raise/nan_output/slow_engine/"
                            "compile_storm cycling every 4 dispatches",
            "records": records,
        }, f, indent=1)
    print(f"[serve_slo] wrote {out}", file=sys.stderr)
    history_append("slo", rows, smoke=smoke)
    return rows


def slo_smoke_rows(goodput_floor: float = 0.55,
                   clean_floor: float = 0.85, seed: int = 0):
    """CI acceptance: one moderate-load point, clean + full fault matrix.
    Fails if any ticket is lost, the queue wedges, or goodput under the
    injected fault matrix drops below ``goodput_floor``."""
    bundle = _bundle(seed)
    clouds = _clouds(bundle.decomp, 60, seed)
    t_req = _service_time(bundle, clouds)
    deadline = max(0.05, 8.0 * t_req)
    rate = 0.6 / t_req
    clean = _slo_run(bundle, clouds, rate, deadline, seed=seed)
    faulted = _slo_run(bundle, clouds, rate, deadline,
                       faults=fault_matrix(120, storm=False), seed=seed)
    for tag, rec in (("clean", clean), ("faulted", faulted)):
        if not (rec["all_answered"] and rec["drained"]):
            raise AssertionError(f"slo smoke: {tag} run lost tickets or "
                                 f"wedged: {rec}")
    if clean["goodput"] < clean_floor:
        raise AssertionError(
            f"slo smoke: clean goodput {clean['goodput']} < {clean_floor}")
    if faulted["goodput"] < goodput_floor:
        raise AssertionError(
            f"slo smoke: faulted goodput {faulted['goodput']} < "
            f"{goodput_floor} — resilience layer is not holding the SLO")
    rows = [
        ("slo/smoke/clean_goodput", clean["goodput"], ""),
        ("slo/smoke/faulted_goodput", faulted["goodput"], ""),
        ("slo/smoke/clean_p99_ms", clean["p99_ms"], "ms"),
        ("slo/smoke/faulted_p99_ms", faulted["p99_ms"], "ms"),
        ("slo/smoke/faulted_shed_rate", faulted["shed_rate"], ""),
        ("slo/smoke/faulted_degraded_frac", faulted["degraded_frac"], ""),
        ("slo/smoke/guard_trips", faulted["guard_trips"], ""),
    ]
    history_append("slo", rows, smoke=True)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    emit(run(smoke=args.smoke, seed=args.seed))
