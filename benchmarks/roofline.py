"""Roofline table generator: aggregates the dry-run JSONs into the EXPERIMENTS.md
tables (§Dry-run and §Roofline), plus an analytic roofline of the residual-loss
hot path (``--path {jvp,pallas,both}``) comparing the per-point jvp closures
against the fused Pallas kernel."""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.common import RESULTS, emit

DRYRUN = os.path.join(RESULTS, "dryrun")

# reference accelerator for the analytic residual roofline (TPU v4-ish)
PEAK_FLOPS = 275e12   # fp32-accumulated MXU
PEAK_HBM = 1.2e12     # bytes/s
WPAD = 128


def residual_rows(path: str = "both", n: int = 10000, depth: int = 8,
                  width: int = 40, d_in: int = 2) -> list[tuple]:
    """Analytic FLOPs / HBM bytes / arithmetic intensity of one residual-loss
    evaluation (Fig-4 center config by default) for each path.

    jvp path: the per-point forward-over-forward closures materialize each
    layer's primal + 1 first-order + 2 second-order tangent chains per input
    direction in HBM (read + write per layer).  pallas path: one HBM read of
    the point block + the weight stack, one write of (u, du, d2u); all
    intermediates stay in VMEM, at the cost of padding width to 128 lanes.
    """
    streams = 1 + 2 * d_in          # primal + (t, s) per direction
    L = depth + 1                   # affine layers
    rows = []

    def emit_one(tag, flops, byts):
        ai = flops / byts
        t_c, t_m = flops / PEAK_FLOPS, byts / PEAK_HBM
        rows.append((f"roofline/residual/{tag}/flops", round(flops / 1e9, 3), "GF"))
        rows.append((f"roofline/residual/{tag}/hbm_bytes", round(byts / 2**20, 2), "MiB"))
        rows.append((f"roofline/residual/{tag}/arith_intensity", round(ai, 1), "F/B"))
        rows.append((f"roofline/residual/{tag}/bound",
                     "compute" if t_c >= t_m else "memory", ""))
        rows.append((f"roofline/residual/{tag}/est_time",
                     round(max(t_c, t_m) * 1e6, 2), "us"))

    if path in ("jvp", "both"):
        flops = 2 * n * width * width * L * streams
        byts = 4 * n * width * L * streams * 2   # per-layer HBM round-trips
        emit_one("jvp", flops, byts)
    if path in ("pallas", "both"):
        flops = 2 * n * WPAD * WPAD * L * streams  # padded MXU tiles
        byts = 4 * (n * WPAD                       # x block read
                    + L * WPAD * WPAD              # weight stack read
                    + streams * n * WPAD)          # (u, du, d2u) write
        emit_one("pallas", flops, byts)
    return rows


def load(mesh: str = "16x16") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x):
    return f"{x * 1e3:.2f}ms" if x >= 1e-4 else f"{x * 1e6:.1f}us"


def markdown_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | MF ratio | HBM/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                        f"(sub-quadratic rule) | — | — |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | {r.get('error','')[:40]} | | |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | {r['model_flops_ratio']:.2f} | "
            f"{hbm/2**30:.1f}GiB |")
    return "\n".join(rows)


def run(path: str = "both"):
    rows = residual_rows(path)
    for r in load("16x16"):
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}/dominant", dom.replace("_s", ""), ""))
        rows.append((f"roofline/{r['arch']}/{r['shape']}/step_bound",
                     round(max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e3, 3),
                     "ms"))
        rows.append((f"roofline/{r['arch']}/{r['shape']}/model_flops_ratio",
                     round(r["model_flops_ratio"], 3), ""))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", choices=("jvp", "pallas", "both"), default="both",
                    help="which residual-path roofline rows to emit")
    args = ap.parse_args()
    emit(run(path=args.path))
    print()
    print(markdown_table())


if __name__ == "__main__":
    main()


def _splice(path: str, begin: str, end: str, content: str):
    with open(path) as f:
        txt = f.read()
    b, e = txt.index(begin) + len(begin), txt.index(end)
    with open(path, "w") as f:
        f.write(txt[:b] + "\n" + content + "\n" + txt[e:])


def write_experiments_md():
    """Splice the dry-run + roofline tables into EXPERIMENTS.md."""
    import os
    md_path = os.path.join(os.path.dirname(RESULTS), "..", "EXPERIMENTS.md")
    md_path = os.path.abspath(md_path)

    dry = ["**Single-pod (16,16) — 256 chips.**  Mesh compile status + per-device",
           "memory analysis; multi-pod (2,16,16) status below.", ""]
    dry.append("| arch | shape | status | args/dev | temp/dev | collectives/dev | compile |")
    dry.append("|---|---|---|---|---|---|---|")
    for r in load("16x16"):
        if r.get("skipped"):
            dry.append(f"| {r['arch']} | {r['shape']} | skip (sub-quadratic rule) | | | | |")
            continue
        if not r.get("ok"):
            dry.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        m = r.get("memory", {})
        dry.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{m.get('argument_size_in_bytes',0)/2**30:.2f}GiB | "
            f"{m.get('temp_size_in_bytes',0)/2**30:.2f}GiB | "
            f"{r['collectives']['total_bytes']/2**30:.1f}GiB | {r['compile_s']}s |")
    mp = load("2x16x16")
    if mp:
        n_ok = sum(1 for r in mp if r.get("ok"))
        n_skip = sum(1 for r in mp if r.get("skipped"))
        n_fail = len(mp) - n_ok - n_skip
        dry.append("")
        dry.append(f"**Multi-pod (2,16,16) — 512 chips:** {n_ok} ok / {n_skip} skip / "
                   f"{n_fail} fail of {len(mp)} cells (per-cell JSONs in "
                   f"benchmarks/results/dryrun/*2x16x16*).  The pod axis carries the "
                   f"data-parallel gradient all-reduce (batch sharded over pod x data).")
        if n_fail:
            for r in mp:
                if not (r.get("ok") or r.get("skipped")):
                    dry.append(f"  - FAIL {r['arch']} {r['shape']}: {r.get('error','')[:100]}")
    _splice(md_path, "<!-- DRYRUN:BEGIN -->", "<!-- DRYRUN:END -->", "\n".join(dry))

    roof = [markdown_table("16x16"), "",
            "Per-cell one-line improvement notes (dominant-term levers):", ""]
    for r in load("16x16"):
        if not r.get("ok"):
            continue
        kind, dom = r["kind"], r["roofline"]["dominant"]
        if kind == "train":
            note = ("sequence-parallel residual stream (converts TP all-reduce to RS/AG "
                    "and shards remat carries) + micro-batching" if dom != "compute_s"
                    else "larger per-device batch / fewer remat recomputes")
        elif kind == "prefill":
            note = "flash-attention kernel keeps scores in VMEM; bf16 param cast-once"
        else:
            note = ("cache layout: shard kv_seq over model; MLA absorbed decode already "
                    "minimizes cache reads" if dom == "memory_s" else "batch the decode")
        roof.append(f"- {r['arch']} × {r['shape']}: dominant={dom.replace('_s','')} → {note}")
    _splice(md_path, "<!-- ROOFLINE:BEGIN -->", "<!-- ROOFLINE:END -->", "\n".join(roof))
    print(f"wrote tables into {md_path}")
