"""Roofline table generator: aggregates the dry-run JSONs into the EXPERIMENTS.md
tables (§Dry-run and §Roofline)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS, emit

DRYRUN = os.path.join(RESULTS, "dryrun")


def load(mesh: str = "16x16") -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(DRYRUN, f"*__{mesh}.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _fmt_s(x):
    return f"{x * 1e3:.2f}ms" if x >= 1e-4 else f"{x * 1e6:.1f}us"


def markdown_table(mesh: str = "16x16") -> str:
    rows = [
        "| arch | shape | compute | memory | collective | dominant | MF ratio | HBM/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load(mesh):
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | skipped "
                        f"(sub-quadratic rule) | — | — |")
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | {r.get('error','')[:40]} | | |")
            continue
        rf = r["roofline"]
        mem = r.get("memory", {})
        hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)
               + mem.get("output_size_in_bytes", 0) - mem.get("alias_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"{rf['dominant'].replace('_s','')} | {r['model_flops_ratio']:.2f} | "
            f"{hbm/2**30:.1f}GiB |")
    return "\n".join(rows)


def run():
    rows = []
    for r in load("16x16"):
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        dom = rf["dominant"]
        rows.append((f"roofline/{r['arch']}/{r['shape']}/dominant", dom.replace("_s", ""), ""))
        rows.append((f"roofline/{r['arch']}/{r['shape']}/step_bound",
                     round(max(rf["compute_s"], rf["memory_s"], rf["collective_s"]) * 1e3, 3),
                     "ms"))
        rows.append((f"roofline/{r['arch']}/{r['shape']}/model_flops_ratio",
                     round(r["model_flops_ratio"], 3), ""))
    return rows


def main():
    emit(run())
    print()
    print(markdown_table())


if __name__ == "__main__":
    main()


def _splice(path: str, begin: str, end: str, content: str):
    with open(path) as f:
        txt = f.read()
    b, e = txt.index(begin) + len(begin), txt.index(end)
    with open(path, "w") as f:
        f.write(txt[:b] + "\n" + content + "\n" + txt[e:])


def write_experiments_md():
    """Splice the dry-run + roofline tables into EXPERIMENTS.md."""
    import os
    md_path = os.path.join(os.path.dirname(RESULTS), "..", "EXPERIMENTS.md")
    md_path = os.path.abspath(md_path)

    dry = ["**Single-pod (16,16) — 256 chips.**  Mesh compile status + per-device",
           "memory analysis; multi-pod (2,16,16) status below.", ""]
    dry.append("| arch | shape | status | args/dev | temp/dev | collectives/dev | compile |")
    dry.append("|---|---|---|---|---|---|---|")
    for r in load("16x16"):
        if r.get("skipped"):
            dry.append(f"| {r['arch']} | {r['shape']} | skip (sub-quadratic rule) | | | | |")
            continue
        if not r.get("ok"):
            dry.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | |")
            continue
        m = r.get("memory", {})
        dry.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{m.get('argument_size_in_bytes',0)/2**30:.2f}GiB | "
            f"{m.get('temp_size_in_bytes',0)/2**30:.2f}GiB | "
            f"{r['collectives']['total_bytes']/2**30:.1f}GiB | {r['compile_s']}s |")
    mp = load("2x16x16")
    if mp:
        n_ok = sum(1 for r in mp if r.get("ok"))
        n_skip = sum(1 for r in mp if r.get("skipped"))
        n_fail = len(mp) - n_ok - n_skip
        dry.append("")
        dry.append(f"**Multi-pod (2,16,16) — 512 chips:** {n_ok} ok / {n_skip} skip / "
                   f"{n_fail} fail of {len(mp)} cells (per-cell JSONs in "
                   f"benchmarks/results/dryrun/*2x16x16*).  The pod axis carries the "
                   f"data-parallel gradient all-reduce (batch sharded over pod x data).")
        if n_fail:
            for r in mp:
                if not (r.get("ok") or r.get("skipped")):
                    dry.append(f"  - FAIL {r['arch']} {r['shape']}: {r.get('error','')[:100]}")
    _splice(md_path, "<!-- DRYRUN:BEGIN -->", "<!-- DRYRUN:END -->", "\n".join(dry))

    roof = [markdown_table("16x16"), "",
            "Per-cell one-line improvement notes (dominant-term levers):", ""]
    for r in load("16x16"):
        if not r.get("ok"):
            continue
        kind, dom = r["kind"], r["roofline"]["dominant"]
        if kind == "train":
            note = ("sequence-parallel residual stream (converts TP all-reduce to RS/AG "
                    "and shards remat carries) + micro-batching" if dom != "compute_s"
                    else "larger per-device batch / fewer remat recomputes")
        elif kind == "prefill":
            note = "flash-attention kernel keeps scores in VMEM; bf16 param cast-once"
        else:
            note = ("cache layout: shard kv_seq over model; MLA absorbed decode already "
                    "minimizes cache reads" if dom == "memory_s" else "batch the decode")
        roof.append(f"- {r['arch']} × {r['shape']}: dominant={dom.replace('_s','')} → {note}")
    _splice(md_path, "<!-- ROOFLINE:BEGIN -->", "<!-- ROOFLINE:END -->", "\n".join(roof))
    print(f"wrote tables into {md_path}")
