"""Paper Fig 9: strong scaling — FIXED global problem size, growing workers.
Speedup = T_1/T_NP, efficiency = T_1/(NP * T_NP) (eq. 9); core-normalized variant
included for the single-core container (see fig8 note).  Each size also reports
the PR-8 comp/comm split (``comp_s`` speedup and ``comm_frac``): strong-scaling
efficiency loss decomposes into communication growth vs shrinking per-device
batches."""
from benchmarks.common import emit, history_append, run_worker, save_json
from benchmarks.scaling_common import worker_code

TOTAL_RES = 8192


def run(sizes=(1, 2, 4, 8), iters=5):
    rows, raw = [], []
    for method in ("cpinn", "xpinn"):
        t1 = None
        c1 = None
        for n in sizes:
            out = run_worker(worker_code(n, 1, method, n_res=TOTAL_RES // n,
                                         n_iface=20, iters=iters), n_devices=max(n, 1))
            t = out["total_s"]
            t1 = t if t1 is None else t1
            c1 = out["comp_s"] if c1 is None else c1
            rows.append((f"fig9/{method}/n{n}/speedup_core_normalized",
                         round(t1 / t * n, 3), "x"))
            rows.append((f"fig9/{method}/n{n}/efficiency_core_normalized",
                         round(t1 / t, 3), "ratio"))
            # comp-only speedup isolates the communication term from the ratio
            rows.append((f"fig9/{method}/n{n}/comp_speedup_core_normalized",
                         round(c1 / out["comp_s"] * n, 3), "x"))
            rows.append((f"fig9/{method}/n{n}/comm_frac",
                         round(out["comm_frac"], 4), "ratio"))
            raw.append({"method": method, "n": n, **out})
    save_json("fig9_strong.json", raw)
    history_append("fig9", rows)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
