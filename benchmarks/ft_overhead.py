"""Fault-tolerance overhead: the guarded chunk vs the raw chunk, checkpoint
cadence, and recovery latency.  Writes ``BENCH_ft.json`` at the repo root.

The robustness acceptance (EXPERIMENTS.md §Robustness) is that the in-graph
health guard is effectively free: the guarded scanned chunk stays ONE jitted
dispatch, traces/packs the megabatched network entry exactly as often as the
unguarded chunk (dispatch accounting below), and its wall-clock overhead on
the quickstart workload is <= 5%.  Timings reuse the fig4 round-robin +
paired-ratio idiom so the container's CPU-quota drift cancels out.

``recovery_smoke_rows`` is the CI-fast recovery acceptance (wired into
``benchmarks/run.py --smoke``): one injected crash and one injected NaN over a
supervised run — the crash recovery must be BITWISE equal to the clean run,
the NaN must trip the guard and the retried run must complete finite.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                        ReferenceTrainer, XPINN, build_topology)
from repro.core.losses import ResidualPath
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.kernels import ops
from repro.runtime import Fault, FaultInjector, Supervisor, SupervisorConfig

from benchmarks.common import bench_path, emit, history_append
from benchmarks.fig4_cost_profile import _interleaved, _med, _paired_ratio



def _workload(n_res=1000, width=24, depth=4, n_iface=20):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=n_iface)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=80,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"),
                          lrs=2e-3)
    return pde, dec, cfg, b, tr


def _dispatch_accounting():
    """Static proof that the guard adds no dispatches: traced megabatched
    network entries per chunk body (the guarded body shows 2 — one abstract
    ``eval_shape`` structure probe that compiles to nothing plus the single
    live ``lax.cond`` branch) and identical HLO weight-pack counts."""
    pde, dec, cfg, b, tr = _workload(n_res=64, width=16, depth=2, n_iface=8)
    tr.res_path = ResidualPath(act="tanh", block_n=32, interpret=True)
    state = tr.init(0)
    ones = jnp.ones((4,), jnp.float32)

    def entries(fn, *a):
        calls = []
        orig = ops.pinn_mlp_forward2
        ops.pinn_mlp_forward2 = lambda *x, **k: (calls.append(1),
                                                 orig(*x, **k))[1]
        try:
            lowered = jax.jit(fn, static_argnums=(2,)).lower(*a)
        finally:
            ops.pinn_mlp_forward2 = orig
        return len(calls), lowered

    def weight_pads(lowered):
        txt = lowered.compile().as_text()
        return sum(1 for ln in txt.splitlines()
                   if " pad(" in ln and "f32[4,128,128]" in ln)

    n_u, low_u = entries(tr._run_chunk_const, state, b, 3)
    n_g, low_g = entries(tr._run_chunk_guarded, state, b, 3, ones)
    packs_u, packs_g = weight_pads(low_u), weight_pads(low_g)
    if packs_g != packs_u:
        raise AssertionError(
            f"guarded chunk packs weights {packs_g}x vs {packs_u}x unguarded")
    return {
        "dispatches_per_chunk": {"unguarded": 1, "guarded": 1},
        "traced_network_entries_per_body": {
            "unguarded": n_u, "guarded_total": n_g, "guarded_live": n_u,
            "note": "guarded = eval_shape structure probe (abstract, no HLO) "
                    "+ the one live lax.cond branch",
        },
        "hlo_weight_packs_per_body": {"unguarded": packs_u, "guarded": packs_g},
    }


def run(iters: int = 10, smoke: bool = False):
    n_res, chunk = (250, 20) if smoke else (1000, 100)
    pde, dec, cfg, b, tr = _workload(n_res=n_res)
    rows = []

    # (a) guarded vs unguarded chunk wall-clock, round-robin paired
    fns = {
        "unguarded": lambda _: tr.run_chunk(tr.init(0), b, chunk),
        "guarded": lambda _: tr.run_chunk_guarded(tr.init(0), b, chunk),
    }
    t = _interleaved(fns, None, iters)
    ratio = _paired_ratio(t["guarded"], t["unguarded"])
    overhead_pct = (ratio - 1.0) * 100.0
    rows.append(("ft/guarded_chunk_ms", round(_med(t["guarded"]) / 1e3, 2), "ms"))
    rows.append(("ft/unguarded_chunk_ms",
                 round(_med(t["unguarded"]) / 1e3, 2), "ms"))
    rows.append(("ft/guard_overhead", round(overhead_pct, 2), "%"))
    if not smoke and not overhead_pct <= 5.0:
        raise AssertionError(
            f"guarded-chunk overhead {overhead_pct:.2f}% exceeds the 5% "
            f"acceptance bound")

    # (b) checkpoint cadence: supervised run (save every chunk — the worst
    # case) vs the bare guarded-chunk loop it wraps
    n_chunks = 3

    def bare(_):
        st = tr.init(0)
        for _ in range(n_chunks):
            st, terms, _h = tr.run_chunk_guarded(st, b, chunk)
        return terms["loss"]

    def supervised(_):
        with tempfile.TemporaryDirectory() as d:
            sup = Supervisor(tr, os.path.join(d, "ckpt"),
                             SupervisorConfig(chunk_steps=chunk,
                                              ckpt_every_chunks=1),
                             decomp=dec)
            st, _rep = sup.run(tr.init(0), b, n_chunks * chunk)
        return st.step

    t2 = _interleaved({"bare": bare, "supervised": supervised}, None,
                      max(2, iters // 2))
    cadence_pct = (_paired_ratio(t2["supervised"], t2["bare"]) - 1.0) * 100.0
    rows.append(("ft/ckpt_every_chunk_overhead", round(cadence_pct, 2), "%"))

    # (c) recovery latency: rollback-from-checkpoint wall time, crash and NaN
    recovery = {}
    for kind, sub in (("crash", None), ("nan_params", 0)):
        with tempfile.TemporaryDirectory() as d:
            sup = Supervisor(tr, os.path.join(d, "ckpt"),
                             SupervisorConfig(chunk_steps=chunk),
                             FaultInjector([Fault(chunk=1, kind=kind,
                                                  subdomain=sub)]),
                             decomp=dec)
            t0 = time.perf_counter()
            _st, rep = sup.run(tr.init(0), b, 3 * chunk)
            total = time.perf_counter() - t0
        assert rep.restarts == 1 and rep.chunks == 3
        recovery[kind] = {"rollback_ms": round(rep.recovery_s[0] * 1e3, 2),
                          "run_s": round(total, 2)}
        rows.append((f"ft/recovery/{kind}_rollback_ms",
                     recovery[kind]["rollback_ms"], "ms"))

    accounting = _dispatch_accounting()

    out = bench_path("ft", smoke)
    with open(out, "w") as f:
        json.dump({
            "workload": f"quickstart 2x2 Burgers XPINN, n_res={n_res}, "
                        f"chunk={chunk} steps",
            "backend": jax.default_backend(), "iters": iters,
            "guarded_chunk": {
                "unguarded_ms": round(_med(t["unguarded"]) / 1e3, 3),
                "guarded_ms": round(_med(t["guarded"]) / 1e3, 3),
                "paired_ratio": round(ratio, 4),
                "overhead_pct": round(overhead_pct, 2),
                "acceptance_bound_pct": 5.0,
            },
            "ckpt_cadence": {
                "bare_ms": round(_med(t2["bare"]) / 1e3, 3),
                "supervised_every_chunk_ms": round(_med(t2["supervised"]) / 1e3, 3),
                "overhead_pct": round(cadence_pct, 2),
            },
            "recovery": recovery,
            "dispatch_accounting": accounting,
        }, f, indent=1)
    print(f"wrote {out}")
    history_append("ft", rows, smoke=smoke)
    return rows


def recovery_smoke_rows(chunk: int = 20, n_chunks: int = 4):
    """Smoke acceptance: one injected crash + one injected NaN over a
    supervised quickstart-style run.  The crash-recovered run must equal the
    clean run BITWISE; the NaN must trip the guard, roll back with backoff,
    and complete finite.  Raises on violation."""
    pde, dec, cfg, b, tr = _workload(n_res=250)
    total = n_chunks * chunk

    def supervised(faults):
        with tempfile.TemporaryDirectory() as d:
            sup = Supervisor(tr, os.path.join(d, "ckpt"),
                             SupervisorConfig(chunk_steps=chunk),
                             FaultInjector(faults), decomp=dec)
            return sup.run(tr.init(0), b, total)

    s_clean, _ = supervised([])
    s_crash, rep_c = supervised([Fault(chunk=1, kind="crash")])
    diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(c))))
               for a, c in zip(jax.tree.leaves(s_clean.params),
                               jax.tree.leaves(s_crash.params)))
    if rep_c.crashes != 1 or diff != 0.0:
        raise AssertionError(
            f"crash recovery not bitwise: crashes={rep_c.crashes} diff={diff}")

    s_nan, rep_n = supervised([Fault(chunk=1, kind="nan_params", subdomain=0)])
    finite = all(np.isfinite(np.asarray(x)).all()
                 for x in jax.tree.leaves(s_nan.params))
    if rep_n.guard_trips != 1 or int(s_nan.step) != total or not finite:
        raise AssertionError(
            f"NaN recovery failed: trips={rep_n.guard_trips} "
            f"step={int(s_nan.step)} finite={finite}")
    rows = [
        ("ft/smoke/crash_recovery_bitwise_diff", diff, ""),
        ("ft/smoke/crash_rollback_ms",
         round(rep_c.recovery_s[0] * 1e3, 2), "ms"),
        ("ft/smoke/nan_guard_trips", rep_n.guard_trips, ""),
        ("ft/smoke/nan_rollback_ms",
         round(rep_n.recovery_s[0] * 1e3, 2), "ms"),
    ]
    history_append("ft", rows, smoke=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload + the crash/NaN recovery acceptance")
    args = ap.parse_args()
    rows = run(iters=args.iters, smoke=args.smoke)
    if args.smoke:
        rows += recovery_smoke_rows()
    emit(rows)


if __name__ == "__main__":
    main()
