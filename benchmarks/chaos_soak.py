"""Storage-chaos soak: corrupt artifacts on purpose, measure the recovery.

The durability acceptance (EXPERIMENTS.md §Durability) is that NO corrupt
state ever enters the trainer or the serving engine: every injected storage
fault (bit flip, truncation, torn write, missing file — against checkpoint
generations AND exported serve bundles) must be *detected* at restore/load
time, and recovery must come from generation fallback (training) or a refused
hot-swap followed by a clean re-export (serving).  This driver scripts the
full train → crash → restore → export → serve → reload loop once per storage
fault kind and measures:

* **detection rate** — injected vs detected faults; the acceptance is 100%,
* **fallback depth** — how many generations the verified restore walked back,
* **MTTR** — rollback→retrained latency on the train side
  (``SupervisorReport.recovery_s``, stamped by the injectable obs clock) and
  corrupt→reswapped latency on the serve side,
* **integrity write overhead** — paired ``ckpt.save`` with and without the
  checksum envelope (fig4 round-robin + paired-ratio idiom, acceptance <= 5%).

Writes ``BENCH_chaos.json`` at the repo root, appends headline rows to the
``BENCH_history.jsonl`` perf trajectory, and routes every ``corruption`` /
``fallback`` / ``bundle_swap`` event through the schema-validated
:mod:`repro.obs.events` JSONL sink.  ``chaos_smoke_rows`` is the CI-fast
subset wired into ``benchmarks/run.py --smoke``.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import sys
import tempfile
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                        ReferenceTrainer, XPINN, build_topology)
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.launch.serve_field import reload_bundle
from repro.obs import make_obs, read_events, validate_events
from repro.runtime import (ChaosInjector, Fault, STORAGE_FAULT_KINDS,
                           Supervisor, SupervisorConfig, corrupt_generation)
from repro.serve import FieldEngine, ServeFrontend, export_bundle, load_bundle

from benchmarks.common import bench_path, emit, history_append
from benchmarks.fig4_cost_profile import _interleaved, _med, _paired_ratio

OVERHEAD_BOUND_PCT = 5.0


def _workload(n_res=250, width=24, depth=4, n_iface=20):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=n_iface)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=80,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"),
                          lrs=2e-3)
    return pde, dec, cfg, b, tr


# ------------------------------------------------------------ soak scripting

def soak_once(kind: str, *, chunk: int = 20, n_chunks: int = 4, seed: int = 0,
              clock=time.perf_counter, obs=None) -> dict:
    """One scripted durability pass for one storage fault kind.

    Train under a composed chaos schedule (the newest checkpoint generation
    is corrupted right before an injected crash, so the rollback MUST detect
    it and fall back a generation), then export the survivor, serve it,
    corrupt the bundle, watch the watchdog refuse the swap while the old
    field keeps answering, repair by re-export, and confirm the hot-swap.
    """
    pde, dec, cfg, b, tr = _workload()
    out = {"kind": kind}
    with tempfile.TemporaryDirectory() as d:
        root = os.path.join(d, "ckpt")
        # chunk 2: two generations exist (steps chunk, 2*chunk).  The storage
        # fault rots the NEWEST one, then the crash forces a rollback through
        # the verified-restore path — detection + quarantine + depth-1
        # fallback + bitwise replay, all in one supervised run.
        inj = ChaosInjector(
            [Fault(chunk=2, kind=kind, target="ckpt", index=0),
             Fault(chunk=2, kind="crash")],
            roots={"ckpt": root}, seed=seed)
        sup = Supervisor(tr, root,
                         SupervisorConfig(chunk_steps=chunk,
                                          ckpt_every_chunks=1),
                         inj, decomp=dec, obs=obs)
        st, rep = sup.run(tr.init(0), b, n_chunks * chunk)
        out["ckpt_injected"] = len(inj.storage_fired)
        out["ckpt_detected"] = rep.corruptions
        out["fallback_depths"] = list(rep.fallback_depths)
        out["ckpt_mttr_s"] = list(rep.recovery_s)
        out["final_step"] = int(st.step)
        out["finite"] = bool(all(np.isfinite(np.asarray(x)).all()
                                 for x in jax.tree.leaves(st.params)))
        out["recovered"] = (out["finite"]
                            and out["final_step"] == n_chunks * chunk)

        # serve side: export the trained field, corrupt the bundle, demand a
        # refused swap (old field keeps serving), then repair and swap.
        broot = os.path.join(d, "bundle")
        export_bundle(broot, st.params, cfg, dec, pde=pde, n_iface=20,
                      step=int(st.step))
        fe = ServeFrontend(FieldEngine(load_bundle(broot)), order=1, obs=obs)
        pts = np.random.default_rng(seed).uniform((-1, 0), (1, 1), (32, 2))
        r0 = fe.query(pts)
        t0 = clock()
        corrupt_generation(broot, kind, 0, np.random.default_rng(seed + 1))
        refused = reload_bundle(fe, broot)
        out["bundle_injected"] = 1
        out["bundle_detected"] = int(not refused["swapped"])
        r1 = fe.query(pts + 1e-7)  # distinct signature: misses the LRU cache
        out["served_through_refusal"] = bool(np.allclose(
            np.nan_to_num(r1["u"]), np.nan_to_num(r0["u"]), atol=1e-5))
        export_bundle(broot, st.params, cfg, dec, pde=pde, n_iface=20,
                      step=int(st.step) + 1)
        swapped = reload_bundle(fe, broot)
        out["bundle_mttr_s"] = clock() - t0
        out["reswapped"] = bool(swapped["swapped"])
    return out


def _summarize(results: list[dict]) -> dict:
    injected = sum(r["ckpt_injected"] + r["bundle_injected"] for r in results)
    detected = sum(r["ckpt_detected"] + r["bundle_detected"] for r in results)
    depths = [dep for r in results for dep in r["fallback_depths"]]
    ckpt_mttr = [s for r in results for s in r["ckpt_mttr_s"]]
    bundle_mttr = [r["bundle_mttr_s"] for r in results]
    return {
        "injected": injected,
        "detected": detected,
        "detection_rate_pct": round(100.0 * detected / max(injected, 1), 2),
        "unrecovered": sum(not (r["recovered"] and r["reswapped"]
                                and r["served_through_refusal"])
                           for r in results),
        "fallback_depth_max": max(depths, default=0),
        "ckpt_mttr_ms_med": round(float(np.median(ckpt_mttr)) * 1e3, 2),
        "bundle_mttr_ms_med": round(float(np.median(bundle_mttr)) * 1e3, 2),
    }


def _check(summary: dict) -> None:
    if summary["detection_rate_pct"] != 100.0:
        raise AssertionError(
            f"storage-fault detection {summary['detected']}/"
            f"{summary['injected']} — a corrupt artifact went unnoticed")
    if summary["unrecovered"]:
        raise AssertionError(
            f"{summary['unrecovered']} soak run(s) did not recover "
            "(fallback, refusal-serving, or re-swap failed)")


# ------------------------------------------------------ integrity overhead

def save_overhead(iters: int = 8) -> dict:
    """Paired checkpoint-write cost with vs without the integrity envelope.

    Round-robin interleaved saves into two sibling roots so the container's
    CPU-quota drift cancels in the paired ratio (the fig4 idiom).  The tree
    is ~16 MB so array bytes dominate the save (the quickstart tree is a few
    hundred KB — at that size a save is ~4 ms of filesystem latency and the
    paired ratio measures noise, not the envelope)."""
    rng = np.random.default_rng(0)
    tree = {"params": {"W": [rng.standard_normal((4, 512, 512))
                             .astype(np.float32) for _ in range(4)]}}
    steps = itertools.count(1)
    with tempfile.TemporaryDirectory() as d:
        roots = {k: os.path.join(d, k) for k in ("plain", "integrity")}
        fns = {
            "plain": lambda _: ckpt.save(roots["plain"], next(steps), tree,
                                         keep=2, integrity=False),
            "integrity": lambda _: ckpt.save(roots["integrity"], next(steps),
                                             tree, keep=2, integrity=True),
        }
        t = _interleaved(fns, None, iters)
    ratio = _paired_ratio(t["integrity"], t["plain"])
    return {
        "plain_save_ms": round(_med(t["plain"]) / 1e3, 3),
        "integrity_save_ms": round(_med(t["integrity"]) / 1e3, 3),
        "paired_ratio": round(ratio, 4),
        "overhead_pct": round((ratio - 1.0) * 100.0, 2),
        "acceptance_bound_pct": OVERHEAD_BOUND_PCT,
    }


# ---------------------------------------------------------------- entrypoints

def _soak_rows(results: list[dict], summary: dict, prefix: str) -> list[tuple]:
    return [
        (f"{prefix}/detection_rate", summary["detection_rate_pct"], "%"),
        (f"{prefix}/injected_faults", summary["injected"], ""),
        (f"{prefix}/unrecovered", summary["unrecovered"], ""),
        (f"{prefix}/fallback_depth_max", summary["fallback_depth_max"], ""),
        (f"{prefix}/ckpt_mttr_ms", summary["ckpt_mttr_ms_med"], "ms"),
        (f"{prefix}/bundle_mttr_ms", summary["bundle_mttr_ms_med"], "ms"),
    ]


def run(iters: int = 8, smoke: bool = False):
    """Full soak: every storage fault kind, overhead pairs, event validation."""
    kinds = STORAGE_FAULT_KINDS if not smoke else STORAGE_FAULT_KINDS[:2]
    rows = []

    oh = save_overhead(iters=iters)
    rows.append(("chaos/integrity_save_overhead", oh["overhead_pct"], "%"))
    if not smoke and not oh["overhead_pct"] <= OVERHEAD_BOUND_PCT:
        raise AssertionError(
            f"integrity save overhead {oh['overhead_pct']:.2f}% exceeds the "
            f"{OVERHEAD_BOUND_PCT}% acceptance bound")

    with tempfile.TemporaryDirectory() as d:
        ev_path = os.path.join(d, "chaos_events.jsonl")
        obs = make_obs(ev_path, run_id="chaos_soak")
        results = [soak_once(k, seed=i, obs=obs)
                   for i, k in enumerate(kinds)]
        obs.close()
        validate_events(ev_path)  # schema-checked corruption/fallback stream
        ev = read_events(ev_path)
        events = {k: sum(e["kind"] == k for e in ev)
                  for k in ("corruption", "fallback", "bundle_swap")}
    summary = _summarize(results)
    _check(summary)
    rows += _soak_rows(results, summary, "chaos")

    out = bench_path("chaos", smoke)
    with open(out, "w") as f:
        json.dump({
            "workload": "quickstart 2x2 Burgers XPINN, chunked supervised "
                        "train + exported-bundle serving",
            "backend": jax.default_backend(),
            "fault_kinds": list(kinds),
            "save_overhead": oh,
            "soak": results,
            "summary": summary,
            "events": events,
        }, f, indent=1)
    print(f"wrote {out}")
    history_append("chaos", rows, smoke=smoke)
    return rows


def chaos_smoke_rows(kinds=("bit_flip", "truncate")) -> list[tuple]:
    """CI-fast durability acceptance (wired into ``run.py --smoke``).

    Two storage fault kinds through the full scripted soak; FAILS unless
    every injected fault is detected (100%) and every run recovers —
    generation fallback on the train side, refused-swap-then-repair on the
    serve side."""
    with tempfile.TemporaryDirectory() as d:
        ev_path = os.path.join(d, "chaos_events.jsonl")
        obs = make_obs(ev_path, run_id="chaos_smoke")
        results = [soak_once(k, seed=i, obs=obs)
                   for i, k in enumerate(kinds)]
        obs.close()
        validate_events(ev_path)
        n_corruption = sum(e["kind"] == "corruption"
                           for e in read_events(ev_path))
    summary = _summarize(results)
    _check(summary)
    if n_corruption < summary["detected"]:
        raise AssertionError(
            f"only {n_corruption} corruption events for "
            f"{summary['detected']} detections — obs stream incomplete")
    rows = _soak_rows(results, summary, "chaos/smoke")
    history_append("chaos", rows, smoke=True)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="two fault kinds + the CI acceptance subset")
    args = ap.parse_args()
    rows = run(iters=args.iters, smoke=args.smoke)
    if args.smoke:
        rows += chaos_smoke_rows()
    emit(rows)


if __name__ == "__main__":
    main()
