"""Causal-trace observatory: end-to-end span-tree exports, validated.

Two acceptance runs of EXPERIMENTS.md §Tracing, wired into
``benchmarks/run.py --smoke`` (and ``--only trace``):

* **serve** — a us_map 10-region bundle behind the full resilient stack
  (:class:`~repro.serve.resilience.ResilientFrontend`) with an injected
  flaky engine, so the exported trace shows the interesting hops: admission,
  microbatch packing, engine eval, quarantine, retry, ladder degrade, cache
  hits.  Every ticket's :class:`ServeResult` carries the trace_id of ONE
  root span whose subtree records the whole lifecycle;

* **train** — a 4-subdomain supervised run (crash + NaN faults) under the
  :class:`~repro.runtime.Supervisor`: one ``train.chunk`` root per attempt
  with the trainer dispatch span plus rollback/recovery children nested
  under it, fanned out to per-subdomain lanes with halo-exchange flow
  arrows (byte-weighted by the analytic ``halo_traffic`` HLO parse in full
  mode; an ``n_iface``-scaled estimate in smoke, labeled as such).

Both exports go through :func:`repro.obs.export_chrome_trace`, which
validates the Chrome trace-event structural contract (matched B/E pairs,
monotone timestamps, finished flows) BEFORE writing — a malformed trace
fails the benchmark, not the Perfetto import three weeks later.
"""
from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import numpy as np

from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                        ReferenceTrainer, XPINN, build_topology)
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.obs import make_obs
from repro.obs.trace_export import export_chrome_trace, training_timeline
from repro.runtime import Fault, FaultInjector, Supervisor, SupervisorConfig
from repro.serve import ResilienceConfig, ResilientFrontend

from benchmarks.common import BENCH_OUT, RESULTS, emit, run_worker

# analytic halo parse of the compiled 4-device fused chunk (full mode): one
# lowering, no timed rounds — the bytes weight the timeline's flow arrows
HALO_WORKER = """
import json
import numpy as np
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.obs import halo_traffic

pde = Burgers1D()
dec = CartesianDecomposition(((-1, 1), (0, 1)), 4, 1)
topo = build_topology(dec, 20)
cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 20, 5)})
b = make_batch(dec, topo, pde, 200, 20, np.random.default_rng(0)).device_arrays()
tr = DistributedDDTrainer(pde, cfg, topo, DDConfig(method=XPINN), lrs=1e-3)
hlo = tr._build_chunk(4).lower(tr.shard_state(tr.init(0)),
                               tr.shard_batch(b)).compile().as_text()
print("RESULT:" + json.dumps(halo_traffic(hlo)))
"""


def _out_path(name: str, smoke: bool) -> str:
    d = BENCH_OUT if smoke else RESULTS
    os.makedirs(d, exist_ok=True)
    return os.path.join(d, f"{name}{'_smoke' if smoke else ''}.json")


# ----------------------------------------------------------------- serve run

class _FlakyEngine:
    """Engine proxy failing every ``period``-th dispatch: drives the retry/
    degrade hops the serve trace is supposed to record."""

    def __init__(self, engine, period: int = 4):
        self.engine, self.period, self.n = engine, period, 0

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def evaluate(self, pts, order: int = 2):
        self.n += 1
        if self.n % self.period == 0:
            raise RuntimeError(f"injected engine fault #{self.n}")
        return self.engine.evaluate(pts, order=order)


def serve_trace_rows(smoke: bool = False):
    from benchmarks.serve_throughput import _bundle, _grid

    bundle = _bundle()
    obs = make_obs(None, trace=True)
    from repro.serve import FieldEngine

    engine = FieldEngine(bundle, obs=obs)
    now = [0.0]
    fe = ResilientFrontend(
        _FlakyEngine(engine), ResilienceConfig(retry_backoff=0.01, order=2),
        clock=lambda: now[0],
        sleep=lambda s: now.__setitem__(0, now[0] + s), obs=obs)
    rng = np.random.default_rng(0)
    n_req = 12 if smoke else 48
    dashboard = _grid(64, bundle.decomp)
    tickets = []
    for i in range(n_req):
        pts = (dashboard if i % 3 == 0 else
               rng.uniform([-0.5, -0.5], [0.5, 0.5], size=(32, 2)))
        tickets.append(fe.submit(pts))
        now[0] += 0.01
        fe.poll()
    fe.drain()
    results = [fe.result(t) for t in tickets]
    tids = {r.trace_id for r in results}
    assert None not in tids and len(tids) == n_req, \
        "every ticket must carry its own trace_id"
    path = _out_path("trace_serve", smoke)
    report = export_chrome_trace(path, obs.tracer.spans(),
                                 process_name="serve_observatory")
    st = obs.tracer.stats()
    assert st["traces"] == n_req and st["spans_evicted"] == 0
    print(f"[trace_observatory] wrote {path}", file=sys.stderr)
    return [
        ("trace/serve/requests", n_req, ""),
        ("trace/serve/span_pairs", report["span_pairs"], ""),
        ("trace/serve/hop_instants", report["instants"], ""),
        ("trace/serve/lanes", report["lanes"], ""),
    ]


# ----------------------------------------------------------------- train run

def train_trace_rows(smoke: bool = False):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 16, 2)})
    b = make_batch(dec, topo, pde, n_res=64 if smoke else 250, n_bnd=16,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, residual_path="pallas"))
    obs = make_obs(None, trace=True)
    import tempfile

    with tempfile.TemporaryDirectory() as d:
        sup = Supervisor(tr, os.path.join(d, "ckpt"),
                         SupervisorConfig(chunk_steps=3),
                         FaultInjector([Fault(1, "crash"),
                                        Fault(3, "nan_params", subdomain=0)]),
                         obs=obs)
        _st, rep = sup.run(tr.init(0), b, total_steps=5 * 3)
    assert rep.crashes == 1 and rep.guard_trips == 1 and rep.chunks == 5

    if smoke:
        # analytic estimate: one f32 "u" halo payload per interface point per
        # directed edge — labeled estimate, NOT the HLO parse (that needs the
        # 4-device distributed lowering; full mode does it in a subprocess)
        halo = {"collective_permute_bytes": 20 * 4, "estimated": True}
    else:
        halo = run_worker(HALO_WORKER, n_devices=4)
    spans = obs.tracer.spans()
    chunks = [s for s in spans if s.name == "train.chunk"]
    lane_spans, flows = training_timeline(chunks, topo, halo=halo)
    path = _out_path("trace_train", smoke)
    report = export_chrome_trace(path, list(spans) + lane_spans, flows=flows,
                                 process_name="train_observatory")
    assert report["flows"] > 0, "expected halo flow arrows"
    assert report["lanes"] >= topo.n_sub + 1, "expected per-subdomain lanes"
    print(f"[trace_observatory] wrote {path}", file=sys.stderr)
    return [
        ("trace/train/chunk_attempts", len(chunks), ""),
        ("trace/train/span_pairs", report["span_pairs"], ""),
        ("trace/train/halo_flows", report["flows"], ""),
        ("trace/train/lanes", report["lanes"], ""),
        ("trace/train/halo_bytes_per_device",
         round(float(halo["collective_permute_bytes"]), 1), "B"),
    ]


def smoke_rows():
    return serve_trace_rows(smoke=True) + train_trace_rows(smoke=True)


def run(smoke: bool = False):
    return serve_trace_rows(smoke=smoke) + train_trace_rows(smoke=smoke)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    emit(run(smoke=args.smoke))


if __name__ == "__main__":
    main()
