"""Paper Table 2: cPINN space-only partitions vs XPINN space-time partitions at
equal subdomain counts — per-iteration wall time on the viscous Burgers problem.
Total residual points fixed (80k in paper; reduced here), interface points 20.
Each case carries the PR-8 comp/comm attribution (``comp_s``/``comm_s``): the
space-time-vs-space-only comparison is only meaningful once the interface-
exchange term is separated from the per-subdomain compute."""
from benchmarks.common import emit, history_append, run_worker, save_json
from benchmarks.scaling_common import worker_code

TOTAL_RES = 16000


def run(iters=5):
    cases = [
        ("cpinn", 4, 1), ("cpinn", 8, 1),
        ("xpinn", 2, 2), ("xpinn", 4, 2),
    ]
    rows, raw = [], []
    for method, nx, nt in cases:
        n = nx * nt
        out = run_worker(worker_code(nx, nt, method, n_res=TOTAL_RES // n,
                                     n_iface=20, iters=iters), n_devices=n)
        rows.append((f"table2/{method}/{nx}x{nt}/time_per_iter",
                     round(out["total_s"] * 1e3, 2), "ms"))
        rows.append((f"table2/{method}/{nx}x{nt}/comp_per_iter",
                     round(out["comp_s"] * 1e3, 2), "ms"))
        rows.append((f"table2/{method}/{nx}x{nt}/comm_per_iter",
                     round(out["comm_s"] * 1e3, 2), "ms"))
        raw.append({"method": method, "nx": nx, "nt": nt, **out})
    save_json("table2_spacetime.json", raw)
    history_append("table2", rows)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
