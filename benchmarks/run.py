"""Benchmark driver: one function per paper table/figure.

``python -m benchmarks.run [--quick] [--smoke] [--only fig4,fig6,...]``

Prints ``name,value,unit`` CSV rows per benchmark; raw measurements land in
benchmarks/results/*.json.  The roofline rows read the dry-run outputs
(run ``python -m repro.launch.dryrun`` first for those).

``--smoke`` is the CI-fast mode: a single tiny fig4 configuration with the
jvp-vs-pallas residual comparison plus the analytic residual roofline —
seconds, not minutes; the full kernel sweeps stay on-demand
(``pytest -m kernel`` / the unflagged benchmark runs).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import common
from benchmarks.common import emit


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig4,fig6,fig8,fig9,table2,fig13,serve,"
                         "slo,ft,chaos,obs,trace,roofline")
    ap.add_argument("--quick", action="store_true", help="fewer sizes/iters")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-fast subset: tiny fig4 jvp-vs-pallas + "
                         "run_chunk e2e + supervisor crash/NaN recovery + "
                         "serve-SLO clean/faulted acceptance + storage-chaos "
                         "durability acceptance + validated trace exports + "
                         "perf-regression gate + roofline")
    args = ap.parse_args()

    from benchmarks import (chaos_soak, fig4_cost_profile, fig6_comp_comm,
                            fig8_weak_scaling, fig9_strong_scaling,
                            fig13_inverse, ft_overhead, obs_telemetry,
                            roofline, serve_slo, serve_throughput,
                            table2_spacetime, trace_observatory)

    if args.smoke:
        # history appends buffer until the gate below: a regressing run is
        # flagged BEFORE it can enter its own baseline
        common.defer_history()
        # the pallas fig4 pass exercises BOTH custom-VJP backwards (fused
        # hand-derived vs checkpointed-ref) and reports the fwd/bwd split
        rows = fig4_cost_profile.run(iters=3, path="pallas", smoke=True)
        # selector round-trip: fused-bwd and ref-bwd training must agree
        rows += fig4_cost_profile.bwd_parity_rows()
        rows += fig4_cost_profile.run_e2e(iters=1, smoke=True)
        rows += serve_throughput.run(iters=2, smoke=True)
        # supervisor recovery acceptance: one crash (bitwise replay) + one NaN
        # (guard trip -> backoff -> finite completion)
        rows += ft_overhead.recovery_smoke_rows()
        # serve-SLO acceptance: Poisson load, clean + injected fault matrix;
        # FAILS if any ticket is lost / the queue wedges / goodput under
        # faults drops below the floor
        rows += serve_slo.slo_smoke_rows()
        # durability acceptance: seeded storage faults against checkpoint
        # generations AND exported bundles through the full train -> crash ->
        # restore -> export -> serve -> reload script; FAILS unless every
        # fault is detected (100%) and every run recovers (generation
        # fallback / refused-swap-then-repair)
        rows += chaos_soak.chaos_smoke_rows()
        # observability acceptance: telemetry + tracer overhead reports,
        # flat-line retrace assertions, schema-validated obs JSONL
        rows += obs_telemetry.smoke_rows()
        # causal-trace acceptance: serve + supervised-training runs must
        # export structurally VALID Chrome traces (matched B/E pairs,
        # per-subdomain lanes, halo flows) with one trace_id per ticket
        rows += trace_observatory.smoke_rows()
        rows += roofline.residual_rows("both")
        emit(rows)
        # perf-trajectory gate: fresh headline rows vs trailing same-mode
        # history (drift-adjusted paired ratios); raises PerfRegressionError
        # on a trip and only records the run when it passes
        for rep in common.flush_history_gate():
            print(f"[gate] {rep['bench']}/{rep['mode']}: "
                  f"{rep['gated']}/{rep['checked']} metrics gated, "
                  f"drift x{rep['drift']}, recorded={rep['recorded']}",
                  file=sys.stderr)
        return

    quick = args.quick
    suite = {
        "fig4": lambda: fig4_cost_profile.run(iters=3 if quick else 10),
        "fig6": lambda: fig6_comp_comm.run(sizes=(4,) if quick else (4, 8, 12),
                                           iters=3 if quick else 5),
        "fig8": lambda: fig8_weak_scaling.run(sizes=(1, 4) if quick else (1, 2, 4, 8),
                                              iters=3 if quick else 5),
        "fig9": lambda: fig9_strong_scaling.run(sizes=(1, 4) if quick else (1, 2, 4, 8),
                                                iters=3 if quick else 5),
        "table2": lambda: table2_spacetime.run(iters=3 if quick else 5),
        "fig13": lambda: fig13_inverse.run(iters=3 if quick else 5),
        "serve": lambda: serve_throughput.run(iters=3 if quick else 5),
        "slo": lambda: serve_slo.run(smoke=quick),
        "ft": lambda: ft_overhead.run(iters=3 if quick else 10),
        "chaos": lambda: chaos_soak.run(iters=3 if quick else 8,
                                        smoke=quick),
        "obs": lambda: obs_telemetry.run(iters=3 if quick else 10,
                                         smoke=quick),
        "trace": lambda: trace_observatory.run(smoke=quick),
        "roofline": roofline.run,
    }
    only = args.only.split(",") if args.only else list(suite)

    all_rows, failures = [], []
    for name in only:
        try:
            rows = suite[name]()
            all_rows.extend(rows)
        except Exception as e:
            failures.append((name, repr(e)))
            traceback.print_exc()
    emit(all_rows)
    if failures:
        print(f"FAILURES: {failures}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
