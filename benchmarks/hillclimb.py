"""§Perf iteration harness: lower ONE cell with a named variant, print the three
roofline terms, memory, and the top collectives with attribution.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch yi-34b --shape train_4k \
      --variant seqpar [--micro 2]

Variants compose config/rules levers; every run appends a JSON record to
benchmarks/results/hillclimb.jsonl for the EXPERIMENTS.md §Perf log.
"""
import argparse
import dataclasses
import json
import os
import sys

VARIANTS = {
    "baseline": {},
    "seqpar": {"extra_rules": {"res_seq": "model"}},
    "micro2": {"micro_batches": 2},
    "micro4": {"micro_batches": 4},
    "seqpar+micro2": {"extra_rules": {"res_seq": "model"}, "micro_batches": 2},
    "seqpar+micro4": {"extra_rules": {"res_seq": "model"}, "micro_batches": 4},
    "bf16-params": {"bf16_params": True},
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. capacity_factor=1.0)")
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args()

    from repro.launch import dryrun as dr
    from repro.configs import get_config
    from repro.utils.hlo import top_collectives

    kw = dict(VARIANTS[args.variant])
    cfg = get_config(args.arch)
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        field_t = type(getattr(cfg, k))
        overrides[k] = field_t(v) if field_t is not bool else v.lower() == "true"
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
        kw["cfg_override"] = cfg

    _, compiled, rec = dr.lower_cell(args.arch, args.shape, args.multi_pod, **kw)
    rec["variant"] = args.variant + ("" if not overrides else f"+{overrides}")
    rf = rec["roofline"]
    m = rec.get("memory", {})
    hbm = (m.get("argument_size_in_bytes", 0) + m.get("temp_size_in_bytes", 0)
           + m.get("output_size_in_bytes", 0) - m.get("alias_size_in_bytes", 0))
    print(f"== {args.arch} {args.shape} [{rec['variant']}] ==")
    print(f"compute={rf['compute_s']*1e3:9.2f}ms  memory={rf['memory_s']*1e3:9.2f}ms  "
          f"collective={rf['collective_s']*1e3:9.2f}ms  dom={rf['dominant']}")
    print(f"mf_ratio={rec['model_flops_ratio']:.3f}  HBM/dev={hbm/2**30:.1f}GiB  "
          f"compile={rec['compile_s']}s")
    print("top collectives (per-device operand bytes):")
    for t in top_collectives(compiled.as_text(), args.top):
        print(f"  {t['kind']:18s} {t['bytes']/2**20:9.1f}MiB g={t['group']:4d} {t['op_name']}")
    with open(os.path.join(os.path.dirname(__file__), "results", "hillclimb.jsonl"), "a") as f:
        rec.pop("hlo_ops", None)
        f.write(json.dumps(rec, default=str) + "\n")


if __name__ == "__main__":
    main()
