"""Observability acceptance: telemetry overhead, retrace flatness, JSONL schema.

Three claims of EXPERIMENTS.md §Observability, measured and enforced:

* **telemetry overhead <= 2%** — the in-graph per-step metric rows
  (grad/param norms, interface mismatch, lr, guard flags) ride the scanned
  chunk's stacked outputs; the guarded chunk with ``telemetry=True`` must
  stay within 2% of the plain guarded chunk (fig4 round-robin + paired-ratio
  idiom, so CPU-quota drift cancels);
* **retrace flatness** — once warmed, serve batch-size buckets, guarded and
  unguarded chunks, and ``lr_scale`` changes must all dispatch with ZERO new
  backend compiles (``repro.obs.CompileWatcher``; a cache-hit dispatch emits
  no compile events, so the assertion is a flat line, not a heuristic);
* **JSONL schema** — a supervised training run with an attached event log
  must produce a stream that passes ``repro.obs.validate_events`` (manifest
  first, schema version match, typed required fields); a malformed stream
  must FAIL validation.  Wired into ``benchmarks/run.py --smoke``: a broken
  schema breaks CI.

Writes ``benchmarks/results/obs_telemetry.json``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (Burgers1D, CartesianDecomposition, DDConfig,
                        ReferenceTrainer, XPINN, build_topology)
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.obs import (CompileWatcher, ObsSchemaError, make_obs,
                       validate_events)
from repro.runtime import Fault, FaultInjector, Supervisor, SupervisorConfig

from benchmarks.common import emit, save_json
from benchmarks.fig4_cost_profile import _interleaved, _med, _paired_ratio

OVERHEAD_BOUND_PCT = 2.0


def _workload(n_res=1000, width=24, depth=4, telemetry=False):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
    b = make_batch(dec, topo, pde, n_res=n_res, n_bnd=80,
                   rng=np.random.default_rng(0)).device_arrays()
    tr = ReferenceTrainer(pde, cfg, topo,
                          DDConfig(method=XPINN, telemetry=telemetry),
                          lrs=2e-3)
    return pde, dec, cfg, b, tr


# ------------------------------------------------------------------ overhead

def overhead_rows(iters: int = 10, smoke: bool = False):
    """Guarded chunk with telemetry rows vs without, paired round-robin.
    Enforces the <= 2% acceptance bound (full mode; smoke reports only —
    a 20-step smoke chunk is too noisy for a hard 2% gate)."""
    n_res, chunk = (250, 20) if smoke else (1000, 100)
    _, _, _, b, tr_off = _workload(n_res=n_res, telemetry=False)
    _, _, _, _, tr_on = _workload(n_res=n_res, telemetry=True)
    fns = {
        "plain": lambda _: tr_off.run_chunk_guarded(tr_off.init(0), b, chunk),
        "telemetry": lambda _: tr_on.run_chunk_guarded(tr_on.init(0), b, chunk),
    }
    t = _interleaved(fns, None, iters)
    ratio = _paired_ratio(t["telemetry"], t["plain"])
    pct = (ratio - 1.0) * 100.0
    rows = [
        ("obs/telemetry_chunk_ms", round(_med(t["telemetry"]) / 1e3, 2), "ms"),
        ("obs/plain_chunk_ms", round(_med(t["plain"]) / 1e3, 2), "ms"),
        ("obs/telemetry_overhead", round(pct, 2), "%"),
    ]
    if not smoke and not pct <= OVERHEAD_BOUND_PCT:
        raise AssertionError(
            f"telemetry overhead {pct:.2f}% exceeds the "
            f"{OVERHEAD_BOUND_PCT}% acceptance bound")
    detail = {"plain_ms": round(_med(t["plain"]) / 1e3, 3),
              "telemetry_ms": round(_med(t["telemetry"]) / 1e3, 3),
              "paired_ratio": round(ratio, 4),
              "overhead_pct": round(pct, 2),
              "acceptance_bound_pct": OVERHEAD_BOUND_PCT}
    return rows, detail


def trace_overhead_rows(iters: int = 10, smoke: bool = False):
    """Causal-tracer overhead, paired round-robin: (a) the guarded training
    chunk with the trainer's dispatch span on vs off (same jitted program —
    only the host-side span wrapper differs) and (b) the serve hot path
    (frontend submit -> microbatch -> engine -> result) with a tracer-carrying
    Obs vs a bare frontend.  Enforces the <= 2% acceptance bound in full mode
    (smoke reports only; sub-ms smoke dispatches are too noisy to gate)."""
    from repro.obs import MetricsRegistry, Obs, Tracer
    from repro.serve.engine import FieldEngine
    from repro.serve.export import FieldBundle
    from repro.serve.frontend import ServeFrontend

    n_res, chunk = (250, 20) if smoke else (1000, 100)
    _, dec, cfg, b, tr = _workload(n_res=n_res)
    tracer = Tracer()

    def chunk_run(traced):
        tr.tracer = tracer if traced else None
        out = tr.run_chunk_guarded(tr.init(0), b, chunk)
        tr.tracer = None
        return out

    t = _interleaved({"plain": lambda _: chunk_run(False),
                      "traced": lambda _: chunk_run(True)}, None, iters)
    chunk_ratio = _paired_ratio(t["traced"], t["plain"])
    chunk_pct = (chunk_ratio - 1.0) * 100.0

    # serve path: one bundle, two frontends — bare vs tracer-carrying Obs;
    # caches disabled so every round pays the full admission->dispatch path
    state = tr.init(0)
    bundle = FieldBundle(model_cfg=cfg, params=state.params, decomp=dec,
                         act_codes=np.zeros((4,), np.int32), pde=None)
    rng = np.random.default_rng(0)
    cloud = rng.uniform((-1, 0), (1, 1), size=(500, 2))

    def mk_frontend(traced):
        obs = (Obs(registry=MetricsRegistry(), events=None, tracer=tracer)
               if traced else None)
        eng = FieldEngine(bundle, tol=0.0, obs=obs)
        return ServeFrontend(eng, order=1, cache_size=0, obs=obs)

    fes = {False: mk_frontend(False), True: mk_frontend(True)}
    for fe in fes.values():
        fe.result(fe.submit(cloud))           # warm the compile cache

    def serve_run(traced):
        fe = fes[traced]
        return fe.result(fe.submit(cloud))

    t2 = _interleaved({"plain": lambda _: serve_run(False),
                       "traced": lambda _: serve_run(True)}, None,
                      max(iters, 5))
    serve_ratio = _paired_ratio(t2["traced"], t2["plain"])
    serve_pct = (serve_ratio - 1.0) * 100.0

    rows = [
        ("obs/trace/chunk_overhead", round(chunk_pct, 2), "%"),
        ("obs/trace/serve_overhead", round(serve_pct, 2), "%"),
        ("obs/trace/spans_recorded", tracer.stats()["spans_recorded"], ""),
    ]
    if not smoke:
        for name, pct in (("chunk", chunk_pct), ("serve", serve_pct)):
            if not pct <= OVERHEAD_BOUND_PCT:
                raise AssertionError(
                    f"tracer {name} overhead {pct:.2f}% exceeds the "
                    f"{OVERHEAD_BOUND_PCT}% acceptance bound")
    detail = {"chunk_paired_ratio": round(chunk_ratio, 4),
              "chunk_overhead_pct": round(chunk_pct, 2),
              "serve_paired_ratio": round(serve_ratio, 4),
              "serve_overhead_pct": round(serve_pct, 2),
              "acceptance_bound_pct": OVERHEAD_BOUND_PCT}
    return rows, detail


# ------------------------------------------------------------------ flatness

def retrace_rows():
    """Flat-line compile assertions: serve batch buckets, guarded/unguarded
    chunks, lr_scale changes.  Every case warms first, then asserts ZERO
    backend compiles across the varied dispatches."""
    from repro.serve.engine import FieldEngine
    from repro.serve.export import FieldBundle

    _, dec, cfg, b, tr = _workload(n_res=64, width=16, depth=2)
    state = tr.init(0)
    rows = []

    # (a) serve batch buckets: clouds of different sizes map to padded bucket
    # shapes; after one warm pass per bucket, traffic must never recompile
    bundle = FieldBundle(model_cfg=cfg, params=state.params, decomp=dec,
                         act_codes=np.zeros((4,), np.int32), pde=None)
    eng = FieldEngine(bundle, tol=0.0)
    rng = np.random.default_rng(0)
    clouds = [rng.uniform((-1, 0), (1, 1), size=(n, 2))
              for n in (16, 100, 500)]
    for c in clouds:
        eng.evaluate(c, order=1)                      # warm each bucket
    with CompileWatcher() as w_serve:
        for _ in range(3):
            for c in clouds:
                eng.evaluate(c, order=1)
    rows.append(("obs/retrace/serve_buckets_compiles",
                 w_serve.backend_compiles, ""))

    # (b) guarded vs unguarded chunks: both warmed, then interleaved
    st = tr.run_chunk(tr.init(0), b, 3)[0]
    st2, _t, _h = tr.run_chunk_guarded(tr.init(0), b, 3)
    with CompileWatcher() as w_chunk:
        st = tr.run_chunk(st, b, 3)[0]
        st2 = tr.run_chunk_guarded(st2, b, 3)[0]
    rows.append(("obs/retrace/chunk_guard_compiles",
                 w_chunk.backend_compiles, ""))

    # (c) lr_scale rides the dispatch as a plain argument: changing it must
    # never recompile (the supervisor's backoff guarantee, now asserted)
    with CompileWatcher() as w_lr:
        for s in (1.0, 0.5, 0.25, 0.125):
            st2 = tr.run_chunk_guarded(st2, b, 3,
                                       lr_scale=jnp.full((4,), s))[0]
    rows.append(("obs/retrace/lr_scale_compiles", w_lr.backend_compiles, ""))

    for name, n, _u in rows:
        if n != 0:
            raise AssertionError(f"{name}: expected 0 backend compiles, "
                                 f"got {n} — retrace storm")
    return rows


# ----------------------------------------------------------------- jsonl/smoke

def jsonl_rows():
    """Supervised run with an attached JSONL event log; the stream must pass
    schema validation (and a corrupted stream must fail it)."""
    _, dec, _, b, tr = _workload(n_res=250, telemetry=True)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "obs.jsonl")
        obs = make_obs(path, run_id="obs-smoke",
                       config={"workload": "quickstart 2x2 Burgers XPINN"})
        sup = Supervisor(tr, os.path.join(d, "ckpt"),
                         SupervisorConfig(chunk_steps=20),
                         FaultInjector([Fault(chunk=1, kind="nan_params",
                                              subdomain=0)]),
                         decomp=dec, obs=obs)
        _st, rep = sup.run(tr.init(0), b, 60)
        obs.emit("metrics", snapshot=obs.registry.snapshot())
        obs.close()

        manifest = validate_events(path)      # raises ObsSchemaError on breakage
        events = [json.loads(ln) for ln in open(path)]
        kinds = {e["kind"] for e in events}
        for needed in ("manifest", "chunk", "guard_trip", "rollback",
                       "metrics"):
            if needed not in kinds:
                raise AssertionError(
                    f"obs smoke: expected a {needed!r} event in the stream, "
                    f"got kinds {sorted(kinds)}")

        # negative control: a corrupted stream must FAIL validation
        bad = os.path.join(d, "bad.jsonl")
        lines = open(path).read().splitlines()
        broken = json.loads(lines[1])
        broken.pop("t", None)                 # strip the required timestamp
        lines[1] = json.dumps(broken)
        with open(bad, "w") as f:
            f.write("\n".join(lines) + "\n")
        try:
            validate_events(bad)
        except ObsSchemaError:
            pass
        else:
            raise AssertionError("obs smoke: corrupted stream passed "
                                 "schema validation")

    return [
        ("obs/jsonl/events", len(events), ""),
        ("obs/jsonl/schema_version", manifest["schema_version"], ""),
        ("obs/jsonl/guard_trips", rep.guard_trips, ""),
        ("obs/jsonl/malformed_rejected", 1, "bool"),
    ]


def smoke_rows():
    """CI-fast acceptance for ``run.py --smoke``: overhead measurement (report
    only), flat-line retrace assertions, schema-validated JSONL."""
    rows, _detail = overhead_rows(iters=3, smoke=True)
    rows += trace_overhead_rows(iters=3, smoke=True)[0]
    rows += retrace_rows()
    rows += jsonl_rows()
    return rows


def run(iters: int = 10, smoke: bool = False):
    rows, detail = overhead_rows(iters=iters, smoke=smoke)
    t_rows, t_detail = trace_overhead_rows(iters=iters, smoke=smoke)
    rows += t_rows
    rows += retrace_rows()
    rows += jsonl_rows()
    save_json("obs_telemetry.json", {
        "backend": jax.default_backend(), "iters": iters,
        "telemetry_overhead": detail,
        "trace_overhead": t_detail,
        "retrace": "all flat (asserted zero backend compiles)",
    })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    emit(run(iters=args.iters, smoke=args.smoke))


if __name__ == "__main__":
    main()
