"""Paper Fig 13: inverse heat conduction on the 10-region irregular map —
wall time and speedup, 1 worker vs 10 workers, float32 vs float64.

Paper findings reproduced qualitatively: ~9-10x on 10 workers (here
core-normalized, see fig8 note), fp64 costs ~2-3x on CPU, and the Table-3
heterogeneous point counts idle fast workers unless ``--balance`` levels them
(the paper's own suggestion, measured below as the straggler-mitigation win).
"""
from benchmarks.common import emit, run_worker, save_json

WORKER = """
import json
import numpy as np, jax
from repro.core import *
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.utils import time_fn

pde = HeatConduction2D()
dec = us_map_decomposition()
topo = build_topology(dec, 12)
cfg = SubdomainModelConfig(nets={{"u": MLPConfig(2, 1, 40, 3), "k": MLPConfig(2, 1, 40, 3)}})
rng = np.random.default_rng(0)
# Table 3 heterogeneous residual counts (scaled /10)
counts = [300, 400, 500, 400, 300, 400, 80, 300, 500, 400]
batch = make_batch(dec, topo, pde, counts, 48, rng, n_interior_data=100,
                   balance={balance})
b = batch.device_arrays()
acts = ["tanh","sin","cos","tanh","sin","cos","tanh","sin","cos","tanh"]
if {distributed}:
    tr = DistributedDDTrainer(pde, cfg, topo, DDConfig(method=XPINN), act_codes=acts, lrs=6e-3)
    st = tr.shard_state(tr.init(0))
    bd = tr.shard_batch(b)
else:
    tr = ReferenceTrainer(pde, cfg, topo, DDConfig(method=XPINN), act_codes=acts, lrs=6e-3)
    st, bd = tr.init(0), b
t = time_fn(lambda: tr.step(st, bd), iters={iters}, warmup=2)
print("RESULT:" + json.dumps({{"step_s": t}}))
"""


def run(iters=5):
    rows, raw = [], []
    cases = [
        ("1worker_f32", dict(distributed=False, balance=False), 1, {}),
        ("10worker_f32", dict(distributed=True, balance=False), 10, {}),
        ("10worker_f32_balanced", dict(distributed=True, balance=True), 10, {}),
        ("1worker_f64", dict(distributed=False, balance=False), 1,
         {"JAX_ENABLE_X64": "1"}),
        ("10worker_f64", dict(distributed=True, balance=False), 10,
         {"JAX_ENABLE_X64": "1"}),
    ]
    res = {}
    for tag, kw, ndev, env in cases:
        out = run_worker(WORKER.format(iters=iters, **kw), n_devices=ndev,
                         extra_env=env)
        res[tag] = out["step_s"]
        rows.append((f"fig13/{tag}/step", round(out["step_s"] * 1e3, 2), "ms"))
        raw.append({"tag": tag, **out})
    rows.append(("fig13/speedup_10w_f32_core_normalized",
                 round(res["1worker_f32"] / res["10worker_f32"] * 10, 2), "x"))
    rows.append(("fig13/f64_cost_factor",
                 round(res["1worker_f64"] / res["1worker_f32"], 2), "x"))
    rows.append(("fig13/balance_win",
                 round(res["10worker_f32"] / res["10worker_f32_balanced"], 3), "x"))
    save_json("fig13_inverse.json", raw)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
