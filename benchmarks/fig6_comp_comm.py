"""Paper Figs 6/7: computation vs communication time, cPINN vs XPINN, growing
subdomain counts, communication-dominated regime (small nets, few points).

Each configuration runs the FUSED single-dispatch chunk driver
(``run_chunk``: lax.scan, ppermute halo inside the body) on a many-subdomain
host mesh; the split comes from :func:`repro.obs.comp_comm_split` — the full
chunk vs the exchange-ablated chunk (``disable_exchange=True`` keeps compute
identical) timed in interleaved paired rounds — plus the analytic per-device
collective-permute bytes of the compiled program (:func:`repro.obs.halo_traffic`,
attributed to the ``dd-comm-halo`` named scope).

Paper findings reproduced: XPINN comm >= cPINN comm (residual continuity needs
second-derivative payload evaluation at interfaces); both weak-scale.

Writes ``BENCH_scaling.json`` at the repo root (``BENCH_scaling_smoke.json``
in smoke mode): one row per (method, n_sub) with separated comp/comm columns,
comm fraction, halo bytes, and the worker's compile counts.
"""
from __future__ import annotations

import json

from benchmarks.common import (bench_path, emit, history_append, run_worker,
                               save_json)
from benchmarks.scaling_common import worker_code


def run(sizes=(4, 8, 12), iters=5, chunk=4, n_res=200, smoke=False):
    rows, raw = [], []
    for method in ("cpinn", "xpinn"):
        for n in sizes:
            out = run_worker(worker_code(n, 1, method, n_res=n_res, n_iface=20,
                                         iters=iters, chunk=chunk),
                             n_devices=n)
            raw.append({"method": method, **out})
            us = lambda v: round(v * 1e6, 1)
            rows.append((f"fig6/{method}/n{n}/comp", us(out["comp_s"]), "us"))
            rows.append((f"fig6/{method}/n{n}/comm", us(out["comm_s"]), "us"))
            rows.append((f"fig6/{method}/n{n}/comm_frac",
                         round(out["comm_frac"], 4), "ratio"))
            rows.append((f"fig6/{method}/n{n}/halo_bytes",
                         round(out["collective_permute_bytes"], 1), "B"))
    save_json("fig6_comp_comm.json", raw)
    _write_bench(raw, sizes, smoke)
    history_append("fig6", rows, smoke=smoke)
    return rows


def _write_bench(raw, sizes, smoke: bool) -> None:
    """BENCH_scaling.json: the comp/comm-per-subdomain-count trajectory
    (ROADMAP open item 1).  Columns per row: per-step comp/comm seconds, comm
    fraction, analytic halo bytes, scope-attributed collective counts."""
    bench = {
        "workload": ("Burgers1D strip decomposition, width=20 depth=5, "
                     "n_res=200/sub, n_iface=20, fused run_chunk "
                     "(single dispatch, ppermute in scan body)"),
        "protocol": ("repro.obs.comp_comm_split: interleaved paired rounds, "
                     "comm = median(total - exchange_ablated), per step; "
                     "halo bytes parsed from compiled HLO collective-permutes "
                     "under the dd-comm-halo named scope"),
        "sizes": list(sizes),
        "rows": [
            {
                "method": r["method"],
                "n_sub": r["n_sub"],
                "comp_s": round(r["comp_s"], 6),
                "comm_s": round(r["comm_s"], 6),
                "total_s": round(r["total_s"], 6),
                "comm_frac": round(r["comm_frac"], 4),
                "halo_bytes_per_device": r["collective_permute_bytes"],
                "collective_permute_ops": r["collective_permute_ops"],
                "scope_op_counts": r.get("scope_op_counts", {}),
                "compile": r.get("compile", {}),
            }
            for r in raw
        ],
    }
    out = bench_path("scaling", smoke)
    with open(out, "w") as f:
        json.dump(bench, f, indent=1)
    print(f"[fig6] wrote {out}")


def main():
    emit(run())


if __name__ == "__main__":
    main()
