"""Paper Figs 6/7: computation vs communication time, cPINN vs XPINN, growing
subdomain counts, communication-dominated regime (small nets, few points).

Comm time = (full step) - (exchange-disabled step): the ablation replaces the
ppermute halo with the local payload, keeping compute identical.
Paper findings reproduced: XPINN comm >= cPINN comm (residual continuity needs
second-derivative payload evaluation at interfaces); both weak-scale.
"""
from benchmarks.common import emit, run_worker, save_json
from benchmarks.scaling_common import worker_code


def run(sizes=(4, 8, 12), iters=5):
    rows, raw = [], []
    for method in ("cpinn", "xpinn"):
        for n in sizes:
            out = run_worker(worker_code(n, 1, method, n_res=200, n_iface=20,
                                         iters=iters), n_devices=n)
            raw.append({"method": method, **out})
            rows.append((f"fig6/{method}/n{n}/comp", round(out["comp_only_s"] * 1e6, 1), "us"))
            rows.append((f"fig6/{method}/n{n}/comm", round(out["comm_s"] * 1e6, 1), "us"))
    save_json("fig6_comp_comm.json", raw)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
