"""Shared worker snippet for the distributed cPINN/XPINN scaling benchmarks
(Figs 6-9, Table 2): runs N steps of the DistributedDDTrainer on a fake-device
mesh and reports per-step wall time, with an optional exchange-disabled ablation
(the paper's computation-vs-communication split)."""
from __future__ import annotations

WORKER = """
import json, time
import numpy as np, jax
from repro.core import *
from repro.core.losses import METHODS
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.utils import time_fn

nx, nt = {nx}, {nt}
method = METHODS["{method}"]
n_res, n_iface, width, depth = {n_res}, {n_iface}, {width}, {depth}
pde = Burgers1D()
dec = CartesianDecomposition(((-1, 1), (0, 1)), nx, nt)
topo = build_topology(dec, n_iface)
cfg = SubdomainModelConfig(nets={{"u": MLPConfig(2, 1, width, depth)}})
rng = np.random.default_rng(0)
batch = make_batch(dec, topo, pde, n_res, 20, rng)
b = batch.device_arrays()

out = {{"n_sub": dec.n_sub}}
for tag, disable in [("total", False), ("comp_only", True)]:
    tr = DistributedDDTrainer(pde, cfg, topo,
                              DDConfig(method=method, disable_exchange=disable),
                              lrs=1e-3)
    st = tr.shard_state(tr.init(0))
    bd = tr.shard_batch(b)
    step = lambda: tr.step(st, bd)
    out[tag + "_s"] = time_fn(lambda: tr.step(st, bd), iters={iters}, warmup=2)
out["comm_s"] = max(0.0, out["total_s"] - out["comp_only_s"])
print("RESULT:" + json.dumps(out))
"""


def worker_code(nx, nt, method, n_res=200, n_iface=20, width=20, depth=5, iters=5):
    return WORKER.format(nx=nx, nt=nt, method=method, n_res=n_res,
                         n_iface=n_iface, width=width, depth=depth, iters=iters)
