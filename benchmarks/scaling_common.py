"""Shared worker snippet for the distributed cPINN/XPINN scaling benchmarks
(Figs 6-9, Table 2): runs the FUSED single-dispatch chunk driver
(``DistributedDDTrainer.run_chunk`` — lax.scan with the ppermute halo exchange
inside the scan body) on a fake-device host mesh and reports:

* the comp-vs-comm walltime split (:func:`repro.obs.comp_comm_split` —
  interleaved paired rounds of the full chunk vs the exchange-ablated chunk,
  per-step seconds): fig8/fig9/table2 consume the splitter keys
  (``total_s`` / ``comp_s`` / ``comm_s`` / ``comm_frac``) directly;
* the analytic halo traffic of the compiled chunk program
  (:func:`repro.obs.halo_traffic` — collective-permute ops/bytes per device,
  with the ``dd-comm-halo`` named-scope attribution);
* the worker's compile counts (:class:`repro.obs.CompileWatcher`) so the
  benchmark can assert compiles happen once, outside the timed rounds.
"""
from __future__ import annotations

WORKER = """
import json
import numpy as np, jax
from repro.core import *
from repro.core.losses import METHODS
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.data import make_batch
from repro.obs import CompileWatcher, comp_comm_split, halo_traffic

nx, nt = {nx}, {nt}
method = METHODS["{method}"]
n_res, n_iface, width, depth = {n_res}, {n_iface}, {width}, {depth}
chunk = {chunk}
pde = Burgers1D()
dec = CartesianDecomposition(((-1, 1), (0, 1)), nx, nt)
topo = build_topology(dec, n_iface)
cfg = SubdomainModelConfig(nets={{"u": MLPConfig(2, 1, width, depth)}})
rng = np.random.default_rng(0)
batch = make_batch(dec, topo, pde, n_res, 20, rng).device_arrays()

def runner(disable):
    tr = DistributedDDTrainer(pde, cfg, topo,
                              DDConfig(method=method, disable_exchange=disable),
                              lrs=1e-3)
    bd = tr.shard_batch(batch)
    box = {{"st": tr.shard_state(tr.init(0))}}
    def run():
        st, terms = tr.run_chunk(box["st"], bd, chunk)
        jax.block_until_ready(terms["loss"])
        box["st"] = st          # donated buffers: rebind, never reuse
    return tr, bd, run

out = {{"n_sub": dec.n_sub, "chunk": chunk}}
with CompileWatcher() as w:
    tr, bd, run_total = runner(False)
    _, _, run_comp = runner(True)
    # analytic per-device halo traffic of the compiled fused-chunk program
    # (lowered with a FRESH state: donation must never eat the timed state)
    hlo = tr._build_chunk(chunk).lower(
        tr.shard_state(tr.init(0)), bd).compile().as_text()
    out.update(halo_traffic(hlo))
    split = comp_comm_split(run_total, run_comp, iters={iters}, warmup=1,
                            steps=chunk)
out["compile"] = {{"backend_compiles": w.backend_compiles, "traces": w.traces}}
out.update(split)
print("RESULT:" + json.dumps(out))
"""


def worker_code(nx, nt, method, n_res=200, n_iface=20, width=20, depth=5,
                iters=5, chunk=4):
    return WORKER.format(nx=nx, nt=nt, method=method, n_res=n_res,
                         n_iface=n_iface, width=width, depth=depth,
                         iters=iters, chunk=chunk)
