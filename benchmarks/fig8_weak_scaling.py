"""Paper Fig 8: weak scaling — fixed work per subdomain, growing subdomain count.
Reports aggregate residual-points/sec and W_e = T_1/T_NP (eq. 8), with the
comp-vs-comm attribution of every size from the PR-8 splitter (``comp_s`` /
``comm_s`` / ``comm_frac``) so a scaling knee is immediately attributable to
communication growth vs per-device compute drift.

NOTE (single-core container): devices timeshare one core, so T_NP grows ~linearly
with NP and W_e measures framework overhead, not hardware speedup; the dry-run
roofline carries the hardware story.  A core-count-normalized efficiency
(T_1 * NP / T_NP / NP == T_1/T_NP * 1) is also reported for reference.
"""
from benchmarks.common import emit, history_append, run_worker, save_json
from benchmarks.scaling_common import worker_code


def run(sizes=(1, 2, 4, 8), iters=5, n_res=2000):
    rows, raw = [], []
    for method in ("cpinn", "xpinn"):
        t1 = None
        for n in sizes:
            out = run_worker(worker_code(n, 1, method, n_res=n_res, n_iface=20,
                                         iters=iters), n_devices=max(n, 1))
            t = out["total_s"]
            t1 = t if t1 is None else t1
            pps = n_res * n / t
            rows.append((f"fig8/{method}/n{n}/points_per_s", round(pps, 1), "pts/s"))
            rows.append((f"fig8/{method}/n{n}/We_timeshared", round(t1 / t, 3), "ratio"))
            rows.append((f"fig8/{method}/n{n}/We_core_normalized",
                         round(t1 * n / t, 3), "ratio"))
            # comp/comm attribution: where the weak-scaling time goes
            rows.append((f"fig8/{method}/n{n}/comp_points_per_s",
                         round(n_res * n / out["comp_s"], 1), "pts/s"))
            rows.append((f"fig8/{method}/n{n}/comm_frac",
                         round(out["comm_frac"], 4), "ratio"))
            raw.append({"method": method, "n": n, **out})
    save_json("fig8_weak.json", raw)
    history_append("fig8", rows)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
