"""Serving throughput: points/sec through the FieldEngine + frontend.

Workload is the paper's §7.6 end product — the 10-region irregular-map
inverse-conductivity field (two nets per region, heterogeneous Table-3
activations) served as a stitched single-valued K(x,y).  Three paths per
batch size:

* ``cold``        — full-order engine evaluation (route -> ONE fused network
                    entry -> stitch), compile-warm but cache-cold;
* ``first_order`` — the cheaper value+gradient-only entry (second-order
                    tangent stream disabled, ``d2_dirs=()``);
* ``cached``      — the same grid re-requested through the frontend's LRU
                    (a repeated dashboard grid costs no dispatch).

Measurement protocol (this container's CPU quota drifts >1.5x on minute
scales): warmup/compile time is measured and reported SEPARATELY
(``warmup_s`` columns), then the steady-state paths are timed in
INTERLEAVED rounds — one cold + one first-order call per round, speedups
taken as the median of per-round ratios, so both paths see the same
machine.  (The earlier sequential-phase protocol produced a spurious
0.79x "first-order regression" at batch 8192 that was pure quota drift;
the engine compile cache is asserted stable across the steady-state loop,
ruling out retracing.)

Writes ``BENCH_serve.json`` at the repo root (``BENCH_serve_smoke.json``
with --smoke); per-config dispatch counts assert the single-dispatch claim.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import numpy as np

from repro.core import us_map_decomposition
from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
from repro.core.pdes import HeatConduction2D
from repro.serve import FieldBundle, FieldEngine, ServeFrontend

from benchmarks.common import bench_path, emit, history_append
TABLE3_ACTS = ["tanh", "sin", "cos", "tanh", "sin", "cos", "tanh", "sin",
               "cos", "tanh"]


def _bundle(seed: int = 0) -> FieldBundle:
    decomp = us_map_decomposition()
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 40, 3),
                                     "k": MLPConfig(2, 1, 40, 3)})
    params, codes = stacked_init(cfg, decomp.n_sub, jax.random.PRNGKey(seed),
                                 TABLE3_ACTS)
    return FieldBundle(model_cfg=cfg, params=params, decomp=decomp,
                       act_codes=np.asarray(codes), pde=HeatConduction2D())


def _grid(n: int, decomp, seed: int = 0) -> np.ndarray:
    verts = np.concatenate(decomp.polygons)
    lo, hi = verts.min(axis=0), verts.max(axis=0)
    side = int(np.ceil(np.sqrt(n)))
    gx, gy = np.meshgrid(np.linspace(lo[0], hi[0], side),
                         np.linspace(lo[1], hi[1], side))
    return np.stack([gx.ravel(), gy.ravel()], axis=1)[:n]


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def run(iters: int = 5, smoke: bool = False):
    from repro.serve import engine as engine_mod

    bundle = _bundle()
    engine = FieldEngine(bundle)
    rows, records = [], []
    batch_sizes = (2048,) if smoke else (512, 2048, 8192, 32768)
    for n in batch_sizes:
        grid = _grid(n, bundle.decomp)
        # ---- warmup/compile: measured separately, never mixed into steady
        warm2 = _timed(lambda: engine.evaluate(grid, order=2))
        warm1 = _timed(lambda: engine.evaluate(grid, order=1))
        fe = ServeFrontend(engine, order=2)
        fe.query(grid)                       # populate the LRU
        # ---- steady state: interleaved rounds (drift-robust)
        def n_traces():
            # shape-keyed compile count across every cached jitted engine fn —
            # len(_EVAL_CACHE) alone can't see jit retracing new shapes
            return sum(fn._cache_size() for fn in engine_mod._EVAL_CACHE.values())

        d0, c0 = engine.n_dispatches, n_traces()
        t_cold, t_fo, t_hot, ratios = [], [], [], []
        for _ in range(iters):
            tc = _timed(lambda: engine.evaluate(grid, order=2))
            tf = _timed(lambda: engine.evaluate(grid, order=1))
            th = _timed(lambda: fe.query(grid))
            t_cold.append(tc)
            t_fo.append(tf)
            t_hot.append(th)
            ratios.append(tc / tf)
        assert engine.n_dispatches - d0 == 2 * iters, "evaluate != one dispatch"
        retraces = n_traces() - c0
        assert retraces == 0, \
            f"steady-state loop recompiled {retraces}x — bucket sizing is retracing"
        t_c, t_f = float(np.median(t_cold)), float(np.median(t_fo))
        t_h = float(np.median(t_hot))
        rec = {
            "batch": n, "backend": jax.default_backend(),
            "warmup_order2_s": round(warm2, 3),
            "warmup_order1_s": round(warm1, 3),
            "cold_pts_per_s": round(n / t_c, 1),
            "first_order_pts_per_s": round(n / t_f, 1),
            "cached_pts_per_s": round(n / max(t_h, 1e-9), 1),
            # median of per-round ratios, NOT ratio of medians: each round's
            # pair shares the machine, so quota drift cancels
            "first_order_speedup": round(float(np.median(ratios)), 2),
            "cached_speedup": round(t_c / max(t_h, 1e-9), 1),
            "steady_retraces": retraces,
            "hit_rate": fe.stats()["hit_rate"],
        }
        records.append(rec)
        rows.append((f"serve/b{n}/cold", rec["cold_pts_per_s"], "pts/s"))
        rows.append((f"serve/b{n}/first_order", rec["first_order_pts_per_s"],
                     "pts/s"))
        rows.append((f"serve/b{n}/first_order_speedup",
                     rec["first_order_speedup"], "x"))
        rows.append((f"serve/b{n}/cached", rec["cached_pts_per_s"], "pts/s"))
        rows.append((f"serve/b{n}/cached_speedup", rec["cached_speedup"], "x"))
    out = bench_path("serve", smoke)
    with open(out, "w") as f:
        json.dump({"workload": "us_map 10-region inverse-heat bundle "
                               "(2 nets/region, Table-3 acts)",
                   "protocol": "warmup split out; steady state interleaved "
                               "(per-round ratios)",
                   "records": records}, f, indent=1)
    print(f"[serve_throughput] wrote {out}", file=sys.stderr)
    history_append("serve", rows, smoke=smoke)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    emit(run(iters=args.iters, smoke=args.smoke))
