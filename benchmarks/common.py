"""Shared benchmark harness: subprocess multi-device timing + CSV emission.

This container exposes ONE physical core; multi-device runs use
``--xla_force_host_platform_device_count`` so devices TIMESHARE the core.
Wall-clock therefore measures algorithmic + collective overhead, not true
parallel speedup — the paper's hardware-scaling story is carried by the dry-run
roofline (EXPERIMENTS.md §Roofline).  Each benchmark prints ``name,value,unit``
CSV rows and states which paper artifact it reproduces.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "benchmarks", "results")


def run_worker(code: str, n_devices: int = 1, timeout: int = 1200,
               extra_env: dict | None = None) -> dict:
    """Run a snippet in a fresh process; the snippet must print one JSON line
    prefixed with ``RESULT:``."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices > 1:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                            + env.get("XLA_FLAGS", ""))
    env.update(extra_env or {})
    res = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    if res.returncode != 0:
        raise RuntimeError(f"worker failed:\n{res.stderr[-3000:]}")
    for line in res.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise RuntimeError(f"no RESULT line in worker output:\n{res.stdout[-2000:]}")


def emit(rows: list[tuple], header=("name", "value", "unit")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path
