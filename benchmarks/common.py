"""Shared benchmark harness: subprocess multi-device timing + CSV emission.

This container exposes ONE physical core; multi-device runs use
``--xla_force_host_platform_device_count`` so devices TIMESHARE the core.
Wall-clock therefore measures algorithmic + collective overhead, not true
parallel speedup — the paper's hardware-scaling story is carried by the dry-run
roofline (EXPERIMENTS.md §Roofline).  Each benchmark prints ``name,value,unit``
CSV rows and states which paper artifact it reproduces.

Output layout: full-mode headline JSONs stay tracked at the repo root
(``BENCH_*.json``); smoke-mode outputs go to the gitignored ``bench_out/``
(:func:`bench_path`).  Every benchmark also appends its headline rows to the
append-only ``BENCH_history.jsonl`` perf trajectory (:func:`history_append` →
:mod:`repro.obs.trajectory`), which the ``--smoke`` regression gate reads.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")
RESULTS = os.path.join(REPO, "benchmarks", "results")
BENCH_OUT = os.path.join(REPO, "bench_out")
HISTORY = os.path.join(REPO, "BENCH_history.jsonl")


def run_worker(code: str, n_devices: int = 1, timeout: int = 1200,
               extra_env: dict | None = None) -> dict:
    """Run a snippet in a fresh process; the snippet must print one JSON line
    prefixed with ``RESULT:``."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    if n_devices > 1:
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                            + env.get("XLA_FLAGS", ""))
    env.update(extra_env or {})
    res = subprocess.run([sys.executable, "-c", code], env=env, timeout=timeout,
                         capture_output=True, text=True)
    if res.returncode != 0:
        raise RuntimeError(f"worker failed:\n{res.stderr[-3000:]}")
    for line in res.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise RuntimeError(f"no RESULT line in worker output:\n{res.stdout[-2000:]}")


def emit(rows: list[tuple], header=("name", "value", "unit")):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))


def save_json(name: str, obj) -> str:
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, name)
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return path


def bench_path(name: str, smoke: bool = False) -> str:
    """Headline-JSON output path: full runs keep the tracked repo-root
    ``BENCH_<name>.json``; smoke runs land in the gitignored ``bench_out/``
    so CI passes never dirty the tree."""
    if not smoke:
        return os.path.join(REPO, f"BENCH_{name}.json")
    os.makedirs(BENCH_OUT, exist_ok=True)
    return os.path.join(BENCH_OUT, f"BENCH_{name}_smoke.json")


# run.py --smoke arms this: history appends buffer here so the regression
# gate can compare the fresh rows against trailing history BEFORE they are
# recorded (a regressing run must not become part of its own baseline)
_DEFERRED: list | None = None


def history_append(bench: str, rows, smoke: bool = False):
    """Append this run's headline rows to the perf trajectory
    (``BENCH_history.jsonl``), keyed on git SHA + bench id + mode.  Smoke and
    full runs never share a baseline (different workload sizes)."""
    mode = "smoke" if smoke else "full"
    if _DEFERRED is not None:
        _DEFERRED.append((bench, list(rows), mode))
        return None
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.obs.trajectory import append_record

    return append_record(HISTORY, bench, rows, mode=mode)


def defer_history() -> None:
    """Buffer subsequent :func:`history_append` calls until
    :func:`flush_history_gate` (the ``--smoke`` gate protocol)."""
    global _DEFERRED
    _DEFERRED = []


def flush_history_gate() -> list[dict]:
    """Gate every deferred bench's rows against its trailing history, then
    record them.  Raises :class:`repro.obs.trajectory.PerfRegressionError`
    on the first tripped bench — WITHOUT recording it."""
    global _DEFERRED
    pending, _DEFERRED = _DEFERRED or [], None
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.obs.trajectory import gate

    return [gate(HISTORY, bench, rows, mode=mode)
            for bench, rows, mode in pending]
