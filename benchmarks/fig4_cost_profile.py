"""Paper Fig 4: PINN cost profile — data-loss vs residual-loss vs backward time as
functions of (#residual points | depth | width), 1-D Burgers, single worker.

The paper's finding: residual-loss evaluation (AD graph traversal) dominates and
grows with all three knobs.  We time the three phases with separate jitted
closures on CPU.

``--path pallas`` additionally times the fused-kernel residual path
(``losses.residual_eval`` with a ResidualPath — the production hot path: one
fused pass for u / du / d²u instead of per-point jvp closures under vmap; on
non-TPU backends this compiles the batched jnp recurrence, on TPU the Pallas
kernel) and writes ``BENCH_residual.json`` at the repo root with both timings
per configuration.

``--e2e`` times WHOLE training steps on the quickstart workload (2x2 Burgers
XPINN) instead of isolated loss phases: the per-step jit loop vs the scanned
single-dispatch ``run_chunk`` driver, on both residual paths, and writes
``BENCH_step.json`` at the repo root (steps/s + dispatch/entry counts).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# allow `python benchmarks/fig4_cost_profile.py` (script mode) as well as -m,
# with or without PYTHONPATH=src
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.losses import LossWeights, ResidualPath, vanilla_pinn_loss
from repro.core.nets import MLPConfig, SubdomainModelConfig, init_model, ACT_TANH
from repro.core.domain import CartesianDecomposition
from repro.core.pdes import Burgers1D
from repro.data import make_vanilla_batch

from benchmarks.common import REPO, bench_path, emit, history_append


def _phases(pde, cfg, params, batch, res_path: ResidualPath | None = None):
    w = LossWeights()

    @jax.jit
    def data_loss(p):
        from repro.core import nets
        u_fn = nets.scalar_field_fn(cfg, p, ACT_TANH, None)
        pred = jax.vmap(u_fn)(batch.data_pts)
        return jnp.sum((pred - batch.data_vals) ** 2)

    @jax.jit
    def res_loss(p):
        r = losses.residual_eval(pde, cfg, p, ACT_TANH, None, batch.res_pts, res_path)
        return jnp.sum(r ** 2)

    @jax.jit
    def forward(p):
        return vanilla_pinn_loss(pde, cfg, w, p, ACT_TANH, None, batch,
                                 path=res_path)[0]

    @jax.jit
    def grad(p):
        return jax.grad(lambda pp: vanilla_pinn_loss(pde, cfg, w, pp, ACT_TANH,
                                                     None, batch, path=res_path)[0])(p)

    return data_loss, res_loss, forward, grad


def _interleaved(fns: dict, arg, iters: int) -> dict:
    """Per-round us samples per candidate, measured in ROUND-ROBIN.

    The container's CPU quota drifts on minute scales; timing candidate A for
    its full budget and then candidate B confounds the comparison with the
    drift.  One pass per round over every candidate puts competing paths
    seconds (not minutes) apart, so PAIRED per-round statistics (differences,
    ratios) see the same machine.  Returns the raw per-round lists — derive
    medians / paired diffs from them, never a difference of medians.
    """
    import time as _time

    for fn in fns.values():
        jax.block_until_ready(fn(arg))  # compile + warm
        jax.block_until_ready(fn(arg))
    ts = {k: [] for k in fns}
    for _ in range(iters):
        for k, fn in fns.items():
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(arg))
            ts[k].append((_time.perf_counter() - t0) * 1e6)
    return {k: np.asarray(v) for k, v in ts.items()}


def _med(x) -> float:
    return float(np.median(x))


def _paired_ratio(num, den):
    """Median of per-round ratios over rounds where both diffs are positive
    (a quota dip can make a small same-round difference go non-positive);
    falls back to the ratio of median diffs, and to NaN when even the medians
    are non-positive — a visible sentinel, never a fabricated huge speedup."""
    num, den = np.asarray(num), np.asarray(den)
    ok = (num > 0) & (den > 0)
    if ok.any():
        return float(np.median(num[ok] / den[ok]))
    mn, md = float(np.median(num)), float(np.median(den))
    return mn / md if mn > 0 and md > 0 else float("nan")


def run(iters: int = 10, path: str = "jvp", smoke: bool = False):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 1, 1)
    rng = np.random.default_rng(0)
    rows, records = [], []
    pallas = path == "pallas"

    def one(tag, n_res, depth, width):
        cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_vanilla_batch(dec, pde, n_res, 200, rng)
        d, r, fwd, grad = _phases(pde, cfg, params, batch)
        fns = {"data": d, "res_jvp": r, "fwd_jvp": fwd, "grad_jvp": grad}
        if pallas:
            # fused hand-derived backward (production) vs checkpointed-ref
            # oracle — SAME forward, the selector changes only the reverse pass
            _, rk, fwd_p, grad_fused = _phases(pde, cfg, params, batch,
                                               ResidualPath(act="tanh"))
            _, _, _, grad_ref = _phases(pde, cfg, params, batch,
                                        ResidualPath(act="tanh", bwd="ref"))
            fns.update(res_pallas=rk, fwd_pallas=fwd_p,
                       grad_pallas_fused=grad_fused, grad_pallas_ref=grad_ref)
        t = _interleaved(fns, params, iters)
        # forward and backward wall-time as SEPARATE columns: bwd = grad - fwd
        # (the VJP application alone; fwd is the loss evaluation it shares).
        # All diffs/ratios are PAIRED within a round — same-machine samples —
        # never a difference of medians (quota drift can make that negative).
        bwd_jvp_r = t["grad_jvp"] - t["fwd_jvp"]
        rows.append((f"fig4/{tag}/data_loss", round(_med(t["data"]), 1), "us"))
        rows.append((f"fig4/{tag}/residual_loss",
                     round(_med(t["res_jvp"]), 1), "us"))
        rows.append((f"fig4/{tag}/forward", round(_med(t["fwd_jvp"]), 1), "us"))
        rows.append((f"fig4/{tag}/backward", round(_med(bwd_jvp_r), 1), "us"))
        if pallas:
            bwd_fused_r = t["grad_pallas_fused"] - t["fwd_pallas"]
            bwd_ref_r = t["grad_pallas_ref"] - t["fwd_pallas"]
            sp_ref = _paired_ratio(bwd_ref_r, bwd_fused_r)
            sp_jvp = _paired_ratio(bwd_jvp_r, bwd_fused_r)
            sp_res = _paired_ratio(t["res_jvp"], t["res_pallas"])
            rows.append((f"fig4/{tag}/residual_loss_pallas",
                         round(_med(t["res_pallas"]), 1), "us"))
            rows.append((f"fig4/{tag}/residual_speedup", round(sp_res, 2), "x"))
            rows.append((f"fig4/{tag}/forward_pallas",
                         round(_med(t["fwd_pallas"]), 1), "us"))
            rows.append((f"fig4/{tag}/backward_pallas_fused",
                         round(_med(bwd_fused_r), 1), "us"))
            rows.append((f"fig4/{tag}/backward_pallas_ref",
                         round(_med(bwd_ref_r), 1), "us"))
            rows.append((f"fig4/{tag}/backward_speedup_vs_ref",
                         round(sp_ref, 2), "x"))
            rows.append((f"fig4/{tag}/backward_speedup_vs_jvp",
                         round(sp_jvp, 2), "x"))
            records.append({
                "config": tag, "n_res": n_res, "depth": depth, "width": width,
                "backend": jax.default_backend(),
                "jvp_us": round(_med(t["res_jvp"]), 1),
                "pallas_us": round(_med(t["res_pallas"]), 1),
                "speedup": round(sp_res, 3),
                # fwd/bwd split columns (whole vanilla-PINN loss): the
                # backward-kernel win is tracked per backward path
                "fwd_jvp_us": round(_med(t["fwd_jvp"]), 1),
                "bwd_jvp_us": round(_med(bwd_jvp_r), 1),
                "fwd_pallas_us": round(_med(t["fwd_pallas"]), 1),
                "bwd_pallas_fused_us": round(_med(bwd_fused_r), 1),
                "bwd_pallas_ref_us": round(_med(bwd_ref_r), 1),
                "bwd_speedup_vs_ref": round(sp_ref, 3),
                "bwd_speedup_vs_jvp": round(sp_jvp, 3),
            })

    if smoke:
        one("nres=1000", 1000, 4, 40)
    else:
        # (a) vs #residual points (200 data pts, 8x40 net)
        for n in (1000, 4000, 10000):
            one(f"nres={n}", n, 8, 40)
        # (b) vs depth (10000 residual points, width 40)
        for depth in (4, 8, 12):
            one(f"depth={depth}", 10000, depth, 40)
        # (c) vs width (10000 residual points, 8 hidden layers)
        for width in (20, 40, 80):
            one(f"width={width}", 10000, 8, width)

    if pallas:
        # smoke runs get their own gitignored file so a CI smoke pass never
        # clobbers the full-grid measurement artifact EXPERIMENTS.md cites
        out = bench_path("residual", smoke)
        with open(out, "w") as f:
            json.dump({"unit": "us", "backend": jax.default_backend(),
                       "iters": iters, "rows": records}, f, indent=1)
        print(f"wrote {out}")
    history_append("fig4", rows, smoke=smoke)
    return rows


def bwd_parity_rows(steps: int = 10):
    """Smoke acceptance: the backward selector round-trips — a quickstart-style
    chunk trained with the hand-derived fused backward lands on the same loss
    as the checkpointed-ref backward.  Raises on divergence."""
    from repro.core import (Burgers1D as _B, CartesianDecomposition as _C,
                            DDConfig, ReferenceTrainer, XPINN, build_topology)
    from repro.data import make_batch

    pde = _B()
    dec = _C(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    b = make_batch(dec, topo, pde, n_res=250, n_bnd=80,
                   rng=np.random.default_rng(0)).device_arrays()
    final = {}
    for bp in ("fused", "ref"):
        tr = ReferenceTrainer(pde, cfg, topo,
                              DDConfig(method=XPINN, residual_path="pallas",
                                       backward_path=bp), lrs=2e-3)
        _, terms = tr.run_chunk(tr.init(0), b, steps)
        final[bp] = float(np.sum(np.asarray(terms["loss"])[-1]))
    if not np.allclose(final["fused"], final["ref"], rtol=5e-3, atol=1e-6):
        raise AssertionError(f"backward selector diverged: {final}")
    return [("fig4/bwd_parity/fused_loss", round(final["fused"], 6), ""),
            ("fig4/bwd_parity/ref_loss", round(final["ref"], 6), "")]


def run_e2e(iters: int = 3, smoke: bool = False):
    """Whole-step timing: per-step jit loop vs the scanned run_chunk driver.

    The quickstart workload (2x2 space-time Burgers XPINN).  Per residual path
    ("jvp" oracle / "pallas" fused megabatch) measures steps/s for (a) a Python
    loop of ``trainer.step`` — one jit dispatch and, pre-megabatch, 4 network
    entries per step (the PR-1 dispatch pattern) — and (b) one
    ``trainer.run_chunk`` dispatch per chunk.  Writes BENCH_step.json.
    """
    import time

    from repro.core import (Burgers1D as _B, CartesianDecomposition, DDConfig,
                            ReferenceTrainer, XPINN, build_topology)
    from repro.data import make_batch

    pde = _B()
    n_res, steps = (250, 20) if smoke else (1000, 100)
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    batch = make_batch(dec, topo, pde, n_res=n_res, n_bnd=80,
                       rng=np.random.default_rng(0))
    b = batch.device_arrays()

    rows, records = [], {}
    # "pallas" = fused hand-derived backward (production default);
    # "pallas-refbwd" = same forward, PR-1 checkpointed-ref backward — the
    # end-to-end measure of the backward-kernel win
    variants = (("jvp", "jvp", "fused"), ("pallas", "pallas", "fused"),
                ("pallas-refbwd", "pallas", "ref"))
    for path, res_path, bwd_path in variants:
        tr = ReferenceTrainer(pde, cfg, topo,
                              DDConfig(method=XPINN, residual_path=res_path,
                                       backward_path=bwd_path), lrs=2e-3)

        def loop_once():
            st = tr.init(0)
            for _ in range(steps):
                st, terms = tr.step(st, b)
            jax.block_until_ready(terms["loss"])

        def chunk_once():
            st = tr.init(0)
            st, terms = tr.run_chunk(st, b, steps)
            jax.block_until_ready(terms["loss"])

        timings = {}
        for tag, fn in (("loop", loop_once), ("chunk", chunk_once)):
            fn()  # compile
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                fn()
                ts.append(time.perf_counter() - t0)
            timings[tag] = steps / float(np.median(ts))
            rows.append((f"fig4/e2e/{path}/{tag}_steps_per_s",
                         round(timings[tag], 2), "it/s"))
        rows.append((f"fig4/e2e/{path}/chunk_speedup",
                     round(timings["chunk"] / timings["loop"], 2), "x"))
        records[path] = {"loop_it_s": round(timings["loop"], 2),
                         "chunk_it_s": round(timings["chunk"], 2),
                         "speedup": round(timings["chunk"] / timings["loop"], 3)}

    quickstart = None
    if not smoke:
        # the acceptance workload: examples/quickstart.py --steps 500 end to
        # end (training + periodic eval), parsed from its own report
        import re
        import subprocess
        res = subprocess.run(
            [sys.executable, os.path.join(REPO, "examples", "quickstart.py"),
             "--steps", "500"],
            capture_output=True, text=True, timeout=1200)
        if res.returncode != 0:
            raise RuntimeError(
                f"quickstart acceptance run failed (rc={res.returncode}):\n"
                f"{res.stderr[-2000:]}")
        m = re.findall(r"step\s+500.*\((\d+\.?\d*) it/s\)", res.stdout)
        if not m:
            raise RuntimeError(
                f"no step-500 rate in quickstart output:\n{res.stdout[-2000:]}")
        quickstart = float(m[-1])
        rows.append(("fig4/e2e/quickstart_500_steps_per_s", quickstart, "it/s"))

    bwd_e2e = round(records["pallas"]["chunk_it_s"]
                    / records["pallas-refbwd"]["chunk_it_s"], 3)
    rows.append(("fig4/e2e/bwd_fused_vs_ref_chunk_speedup", bwd_e2e, "x"))
    out = bench_path("step", smoke)
    with open(out, "w") as f:
        json.dump({
            "workload": f"quickstart 2x2 Burgers XPINN, n_res={n_res}, "
                        f"chunk={steps} steps",
            "backend": jax.default_backend(), "iters": iters,
            "paths": records,
            "bwd_fused_vs_ref_chunk_speedup": bwd_e2e,
            "quickstart_500_it_s": quickstart,
            # static dispatch accounting (see EXPERIMENTS.md §Step fusion)
            "entries_per_loss_eval": {"pre_megabatch": 3, "megabatch": 1},
            "entries_per_step": {"pre_megabatch": 4, "megabatch": 1},
            "dispatches_per_100_steps": {"loop": 100, "chunk": round(100 / steps, 2)},
        }, f, indent=1)
    print(f"wrote {out}")
    history_append("fig4_e2e", rows, smoke=smoke)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", choices=("jvp", "pallas"), default="jvp",
                    help="residual evaluation: per-point jvp closures or the "
                         "fused kernel (also times jvp for the comparison)")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", help="single tiny config")
    ap.add_argument("--e2e", action="store_true",
                    help="time whole run_chunk training steps (loop vs scan) "
                         "and write BENCH_step.json")
    args = ap.parse_args()
    if args.e2e:
        emit(run_e2e(iters=max(1, args.iters // 3), smoke=args.smoke))
        return
    emit(run(iters=args.iters, path=args.path, smoke=args.smoke))


if __name__ == "__main__":
    main()
