"""Paper Fig 4: PINN cost profile — data-loss vs residual-loss vs backward time as
functions of (#residual points | depth | width), 1-D Burgers, single worker.

The paper's finding: residual-loss evaluation (AD graph traversal) dominates and
grows with all three knobs.  We time the three phases with separate jitted
closures on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import LossWeights, vanilla_pinn_loss
from repro.core.nets import MLPConfig, SubdomainModelConfig, init_model, ACT_TANH
from repro.core.domain import CartesianDecomposition
from repro.core.pdes import Burgers1D
from repro.data import make_vanilla_batch
from repro.utils import time_fn

from benchmarks.common import emit


def _phases(pde, cfg, params, batch):
    w = LossWeights()

    @jax.jit
    def data_loss(p):
        from repro.core import losses, nets
        u_fn = nets.scalar_field_fn(cfg, p, ACT_TANH, None)
        pred = jax.vmap(u_fn)(batch.data_pts)
        return jnp.sum((pred - batch.data_vals) ** 2)

    @jax.jit
    def res_loss(p):
        from repro.core import nets
        u_fn = nets.scalar_field_fn(cfg, p, ACT_TANH, None)
        r = jax.vmap(lambda x: pde.residual(u_fn, x))(batch.res_pts)
        return jnp.sum(r ** 2)

    @jax.jit
    def backward(p):
        return jax.grad(lambda pp: vanilla_pinn_loss(pde, cfg, w, pp, ACT_TANH,
                                                     None, batch)[0])(p)

    return data_loss, res_loss, backward


def run(iters: int = 10):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 1, 1)
    rng = np.random.default_rng(0)
    rows = []

    def one(tag, n_res, depth, width):
        cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_vanilla_batch(dec, pde, n_res, 200, rng)
        d, r, b = _phases(pde, cfg, params, batch)
        rows.append((f"fig4/{tag}/data_loss", round(time_fn(d, params, iters=iters) * 1e6, 1), "us"))
        rows.append((f"fig4/{tag}/residual_loss", round(time_fn(r, params, iters=iters) * 1e6, 1), "us"))
        rows.append((f"fig4/{tag}/backward", round(time_fn(b, params, iters=iters) * 1e6, 1), "us"))

    # (a) vs #residual points (200 data pts, 8x40 net)
    for n in (1000, 4000, 10000):
        one(f"nres={n}", n, 8, 40)
    # (b) vs depth (10000 residual points, width 40)
    for depth in (4, 8, 12):
        one(f"depth={depth}", 10000, depth, 40)
    # (c) vs width (10000 residual points, 8 hidden layers)
    for width in (20, 40, 80):
        one(f"width={width}", 10000, 8, width)
    return rows


def main():
    emit(run())


if __name__ == "__main__":
    main()
