"""Paper Fig 4: PINN cost profile — data-loss vs residual-loss vs backward time as
functions of (#residual points | depth | width), 1-D Burgers, single worker.

The paper's finding: residual-loss evaluation (AD graph traversal) dominates and
grows with all three knobs.  We time the three phases with separate jitted
closures on CPU.

``--path pallas`` additionally times the fused-kernel residual path
(``losses.residual_eval`` with a ResidualPath — the production hot path: one
fused pass for u / du / d²u instead of per-point jvp closures under vmap; on
non-TPU backends this compiles the batched jnp recurrence, on TPU the Pallas
kernel) and writes ``BENCH_residual.json`` at the repo root with both timings
per configuration.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# allow `python benchmarks/fig4_cost_profile.py` (script mode) as well as -m,
# with or without PYTHONPATH=src
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.losses import LossWeights, ResidualPath, vanilla_pinn_loss
from repro.core.nets import MLPConfig, SubdomainModelConfig, init_model, ACT_TANH
from repro.core.domain import CartesianDecomposition
from repro.core.pdes import Burgers1D
from repro.data import make_vanilla_batch
from repro.utils import time_fn

from benchmarks.common import REPO, emit

BENCH_JSON = os.path.join(REPO, "BENCH_residual.json")


def _phases(pde, cfg, params, batch, res_path: ResidualPath | None = None):
    w = LossWeights()

    @jax.jit
    def data_loss(p):
        from repro.core import nets
        u_fn = nets.scalar_field_fn(cfg, p, ACT_TANH, None)
        pred = jax.vmap(u_fn)(batch.data_pts)
        return jnp.sum((pred - batch.data_vals) ** 2)

    @jax.jit
    def res_loss(p):
        r = losses.residual_eval(pde, cfg, p, ACT_TANH, None, batch.res_pts, res_path)
        return jnp.sum(r ** 2)

    @jax.jit
    def backward(p):
        return jax.grad(lambda pp: vanilla_pinn_loss(pde, cfg, w, pp, ACT_TANH,
                                                     None, batch, path=res_path)[0])(p)

    return data_loss, res_loss, backward


def run(iters: int = 10, path: str = "jvp", smoke: bool = False):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 1, 1)
    rng = np.random.default_rng(0)
    rows, records = [], []
    pallas = path == "pallas"

    def one(tag, n_res, depth, width):
        cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_vanilla_batch(dec, pde, n_res, 200, rng)
        d, r, b = _phases(pde, cfg, params, batch)
        t_data = time_fn(d, params, iters=iters) * 1e6
        t_jvp = time_fn(r, params, iters=iters) * 1e6
        t_bwd = time_fn(b, params, iters=iters) * 1e6
        rows.append((f"fig4/{tag}/data_loss", round(t_data, 1), "us"))
        rows.append((f"fig4/{tag}/residual_loss", round(t_jvp, 1), "us"))
        rows.append((f"fig4/{tag}/backward", round(t_bwd, 1), "us"))
        if pallas:
            rp = ResidualPath(act="tanh")
            _, rk, bk = _phases(pde, cfg, params, batch, res_path=rp)
            t_pal = time_fn(rk, params, iters=iters) * 1e6
            t_bwd_pal = time_fn(bk, params, iters=iters) * 1e6
            rows.append((f"fig4/{tag}/residual_loss_pallas", round(t_pal, 1), "us"))
            rows.append((f"fig4/{tag}/backward_pallas", round(t_bwd_pal, 1), "us"))
            rows.append((f"fig4/{tag}/residual_speedup", round(t_jvp / t_pal, 2), "x"))
            records.append({
                "config": tag, "n_res": n_res, "depth": depth, "width": width,
                "backend": jax.default_backend(),
                "jvp_us": round(t_jvp, 1), "pallas_us": round(t_pal, 1),
                "speedup": round(t_jvp / t_pal, 3),
                "backward_jvp_us": round(t_bwd, 1),
                "backward_pallas_us": round(t_bwd_pal, 1),
            })

    if smoke:
        one("nres=1000", 1000, 4, 40)
    else:
        # (a) vs #residual points (200 data pts, 8x40 net)
        for n in (1000, 4000, 10000):
            one(f"nres={n}", n, 8, 40)
        # (b) vs depth (10000 residual points, width 40)
        for depth in (4, 8, 12):
            one(f"depth={depth}", 10000, depth, 40)
        # (c) vs width (10000 residual points, 8 hidden layers)
        for width in (20, 40, 80):
            one(f"width={width}", 10000, 8, width)

    if pallas:
        # smoke runs get their own file so a CI smoke pass never clobbers the
        # full-grid measurement artifact that EXPERIMENTS.md cites
        out = BENCH_JSON.replace(".json", "_smoke.json") if smoke else BENCH_JSON
        with open(out, "w") as f:
            json.dump({"unit": "us", "backend": jax.default_backend(),
                       "iters": iters, "rows": records}, f, indent=1)
        print(f"wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", choices=("jvp", "pallas"), default="jvp",
                    help="residual evaluation: per-point jvp closures or the "
                         "fused kernel (also times jvp for the comparison)")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", help="single tiny config")
    args = ap.parse_args()
    emit(run(iters=args.iters, path=args.path, smoke=args.smoke))


if __name__ == "__main__":
    main()
