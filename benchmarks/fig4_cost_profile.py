"""Paper Fig 4: PINN cost profile — data-loss vs residual-loss vs backward time as
functions of (#residual points | depth | width), 1-D Burgers, single worker.

The paper's finding: residual-loss evaluation (AD graph traversal) dominates and
grows with all three knobs.  We time the three phases with separate jitted
closures on CPU.

``--path pallas`` additionally times the fused-kernel residual path
(``losses.residual_eval`` with a ResidualPath — the production hot path: one
fused pass for u / du / d²u instead of per-point jvp closures under vmap; on
non-TPU backends this compiles the batched jnp recurrence, on TPU the Pallas
kernel) and writes ``BENCH_residual.json`` at the repo root with both timings
per configuration.

``--e2e`` times WHOLE training steps on the quickstart workload (2x2 Burgers
XPINN) instead of isolated loss phases: the per-step jit loop vs the scanned
single-dispatch ``run_chunk`` driver, on both residual paths, and writes
``BENCH_step.json`` at the repo root (steps/s + dispatch/entry counts).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# allow `python benchmarks/fig4_cost_profile.py` (script mode) as well as -m,
# with or without PYTHONPATH=src
_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO)
sys.path.insert(0, os.path.join(_REPO, "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.losses import LossWeights, ResidualPath, vanilla_pinn_loss
from repro.core.nets import MLPConfig, SubdomainModelConfig, init_model, ACT_TANH
from repro.core.domain import CartesianDecomposition
from repro.core.pdes import Burgers1D
from repro.data import make_vanilla_batch
from repro.utils import time_fn

from benchmarks.common import REPO, emit

BENCH_JSON = os.path.join(REPO, "BENCH_residual.json")
BENCH_STEP_JSON = os.path.join(REPO, "BENCH_step.json")


def _phases(pde, cfg, params, batch, res_path: ResidualPath | None = None):
    w = LossWeights()

    @jax.jit
    def data_loss(p):
        from repro.core import nets
        u_fn = nets.scalar_field_fn(cfg, p, ACT_TANH, None)
        pred = jax.vmap(u_fn)(batch.data_pts)
        return jnp.sum((pred - batch.data_vals) ** 2)

    @jax.jit
    def res_loss(p):
        r = losses.residual_eval(pde, cfg, p, ACT_TANH, None, batch.res_pts, res_path)
        return jnp.sum(r ** 2)

    @jax.jit
    def backward(p):
        return jax.grad(lambda pp: vanilla_pinn_loss(pde, cfg, w, pp, ACT_TANH,
                                                     None, batch, path=res_path)[0])(p)

    return data_loss, res_loss, backward


def run(iters: int = 10, path: str = "jvp", smoke: bool = False):
    pde = Burgers1D()
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 1, 1)
    rng = np.random.default_rng(0)
    rows, records = [], []
    pallas = path == "pallas"

    def one(tag, n_res, depth, width):
        cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, width, depth)})
        params = init_model(cfg, jax.random.PRNGKey(0))
        batch = make_vanilla_batch(dec, pde, n_res, 200, rng)
        d, r, b = _phases(pde, cfg, params, batch)
        t_data = time_fn(d, params, iters=iters) * 1e6
        t_jvp = time_fn(r, params, iters=iters) * 1e6
        t_bwd = time_fn(b, params, iters=iters) * 1e6
        rows.append((f"fig4/{tag}/data_loss", round(t_data, 1), "us"))
        rows.append((f"fig4/{tag}/residual_loss", round(t_jvp, 1), "us"))
        rows.append((f"fig4/{tag}/backward", round(t_bwd, 1), "us"))
        if pallas:
            rp = ResidualPath(act="tanh")
            _, rk, bk = _phases(pde, cfg, params, batch, res_path=rp)
            t_pal = time_fn(rk, params, iters=iters) * 1e6
            t_bwd_pal = time_fn(bk, params, iters=iters) * 1e6
            rows.append((f"fig4/{tag}/residual_loss_pallas", round(t_pal, 1), "us"))
            rows.append((f"fig4/{tag}/backward_pallas", round(t_bwd_pal, 1), "us"))
            rows.append((f"fig4/{tag}/residual_speedup", round(t_jvp / t_pal, 2), "x"))
            records.append({
                "config": tag, "n_res": n_res, "depth": depth, "width": width,
                "backend": jax.default_backend(),
                "jvp_us": round(t_jvp, 1), "pallas_us": round(t_pal, 1),
                "speedup": round(t_jvp / t_pal, 3),
                "backward_jvp_us": round(t_bwd, 1),
                "backward_pallas_us": round(t_bwd_pal, 1),
            })

    if smoke:
        one("nres=1000", 1000, 4, 40)
    else:
        # (a) vs #residual points (200 data pts, 8x40 net)
        for n in (1000, 4000, 10000):
            one(f"nres={n}", n, 8, 40)
        # (b) vs depth (10000 residual points, width 40)
        for depth in (4, 8, 12):
            one(f"depth={depth}", 10000, depth, 40)
        # (c) vs width (10000 residual points, 8 hidden layers)
        for width in (20, 40, 80):
            one(f"width={width}", 10000, 8, width)

    if pallas:
        # smoke runs get their own file so a CI smoke pass never clobbers the
        # full-grid measurement artifact that EXPERIMENTS.md cites
        out = BENCH_JSON.replace(".json", "_smoke.json") if smoke else BENCH_JSON
        with open(out, "w") as f:
            json.dump({"unit": "us", "backend": jax.default_backend(),
                       "iters": iters, "rows": records}, f, indent=1)
        print(f"wrote {out}")
    return rows


def run_e2e(iters: int = 3, smoke: bool = False):
    """Whole-step timing: per-step jit loop vs the scanned run_chunk driver.

    The quickstart workload (2x2 space-time Burgers XPINN).  Per residual path
    ("jvp" oracle / "pallas" fused megabatch) measures steps/s for (a) a Python
    loop of ``trainer.step`` — one jit dispatch and, pre-megabatch, 4 network
    entries per step (the PR-1 dispatch pattern) — and (b) one
    ``trainer.run_chunk`` dispatch per chunk.  Writes BENCH_step.json.
    """
    import time

    from repro.core import (Burgers1D as _B, CartesianDecomposition, DDConfig,
                            ReferenceTrainer, XPINN, build_topology)
    from repro.data import make_batch

    pde = _B()
    n_res, steps = (250, 20) if smoke else (1000, 100)
    dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
    topo = build_topology(dec, n_iface=20)
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    batch = make_batch(dec, topo, pde, n_res=n_res, n_bnd=80,
                       rng=np.random.default_rng(0))
    b = batch.device_arrays()

    rows, records = [], {}
    for path in ("jvp", "pallas"):
        tr = ReferenceTrainer(pde, cfg, topo,
                              DDConfig(method=XPINN, residual_path=path), lrs=2e-3)

        def loop_once():
            st = tr.init(0)
            for _ in range(steps):
                st, terms = tr.step(st, b)
            jax.block_until_ready(terms["loss"])

        def chunk_once():
            st = tr.init(0)
            st, terms = tr.run_chunk(st, b, steps)
            jax.block_until_ready(terms["loss"])

        timings = {}
        for tag, fn in (("loop", loop_once), ("chunk", chunk_once)):
            fn()  # compile
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                fn()
                ts.append(time.perf_counter() - t0)
            timings[tag] = steps / float(np.median(ts))
            rows.append((f"fig4/e2e/{path}/{tag}_steps_per_s",
                         round(timings[tag], 2), "it/s"))
        rows.append((f"fig4/e2e/{path}/chunk_speedup",
                     round(timings["chunk"] / timings["loop"], 2), "x"))
        records[path] = {"loop_it_s": round(timings["loop"], 2),
                         "chunk_it_s": round(timings["chunk"], 2),
                         "speedup": round(timings["chunk"] / timings["loop"], 3)}

    quickstart = None
    if not smoke:
        # the acceptance workload: examples/quickstart.py --steps 500 end to
        # end (training + periodic eval), parsed from its own report
        import re
        import subprocess
        res = subprocess.run(
            [sys.executable, os.path.join(REPO, "examples", "quickstart.py"),
             "--steps", "500"],
            capture_output=True, text=True, timeout=1200)
        if res.returncode != 0:
            raise RuntimeError(
                f"quickstart acceptance run failed (rc={res.returncode}):\n"
                f"{res.stderr[-2000:]}")
        m = re.findall(r"step\s+500.*\((\d+\.?\d*) it/s\)", res.stdout)
        if not m:
            raise RuntimeError(
                f"no step-500 rate in quickstart output:\n{res.stdout[-2000:]}")
        quickstart = float(m[-1])
        rows.append(("fig4/e2e/quickstart_500_steps_per_s", quickstart, "it/s"))

    out = BENCH_STEP_JSON.replace(".json", "_smoke.json") if smoke else BENCH_STEP_JSON
    with open(out, "w") as f:
        json.dump({
            "workload": f"quickstart 2x2 Burgers XPINN, n_res={n_res}, "
                        f"chunk={steps} steps",
            "backend": jax.default_backend(), "iters": iters,
            "paths": records,
            "quickstart_500_it_s": quickstart,
            # static dispatch accounting (see EXPERIMENTS.md §Step fusion)
            "entries_per_loss_eval": {"pre_megabatch": 3, "megabatch": 1},
            "entries_per_step": {"pre_megabatch": 4, "megabatch": 1},
            "dispatches_per_100_steps": {"loop": 100, "chunk": round(100 / steps, 2)},
        }, f, indent=1)
    print(f"wrote {out}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", choices=("jvp", "pallas"), default="jvp",
                    help="residual evaluation: per-point jvp closures or the "
                         "fused kernel (also times jvp for the comparison)")
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true", help="single tiny config")
    ap.add_argument("--e2e", action="store_true",
                    help="time whole run_chunk training steps (loop vs scan) "
                         "and write BENCH_step.json")
    args = ap.parse_args()
    if args.e2e:
        emit(run_e2e(iters=max(1, args.iters // 3), smoke=args.smoke))
        return
    emit(run(iters=args.iters, path=args.path, smoke=args.smoke))


if __name__ == "__main__":
    main()
