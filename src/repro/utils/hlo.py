"""HLO text analysis: collective-communication byte accounting for the roofline.

``cost_analysis()`` reports FLOPs/bytes but NOT collective traffic, so we parse the
SPMD-partitioned module text.  For every ``all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute`` op we compute the PER-DEVICE OPERAND bytes, deriving
the operand size from the printed OUTPUT type signature and the op semantics:

    all-reduce / all-to-all / collective-permute : operand = output
    all-gather                                   : operand = output / group_size
    reduce-scatter                               : operand = output * group_size

(group size parsed from ``replica_groups``; ``-start`` counted once, ``-done``
skipped).  Totals are per-device, matching cost_analysis' per-device convention; the
spec's total-bytes / (chips x link_bw) equals our per-device bytes / link_bw.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _sig_bytes(sig: str) -> int:
    """Bytes of one type signature, possibly a tuple '(bf16[2,3], f32[4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(sig):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups,group_size]
        return max(1, int(m.group(2)))
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device operand bytes by collective kind (+ op counts)."""
    by_kind: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        out_bytes = _sig_bytes(sig)
        g = _group_size(line)
        if kind == "all-gather":
            # start-op tuple prints (operand, output): take largest as output
            op_bytes = out_bytes / (1 + 1.0 / g) / g if m.group(3) else out_bytes / g
        elif kind == "reduce-scatter":
            op_bytes = out_bytes * g
        elif kind == "all-reduce" and m.group(3):
            op_bytes = out_bytes / 2  # start tuple prints (operand, output)
        else:
            op_bytes = out_bytes
        by_kind[kind] += op_bytes
        counts[kind] += 1
    return {
        "bytes_by_kind": dict(by_kind),
        "counts": dict(counts),
        "total_bytes": float(sum(by_kind.values())),
    }


def named_scope_counts(hlo_text: str, prefix: str = "dd-") -> dict[str, int]:
    """Ops attributed to each ``jax.named_scope`` starting with ``prefix``.

    Scope names appear as path components of the ``op_name`` metadata
    (``jit(f)/.../dd-comm-halo/...``); counting ops per scope lets tests and
    the comp/comm splitter assert the annotation scheme holds (e.g. every
    collective-permute sits under ``dd-comm-halo``).  An op nested under two
    matching scopes counts toward each (scopes are a hierarchy, not a
    partition)."""
    counts: dict[str, int] = defaultdict(int)
    pat = re.compile(r'op_name="([^"]+)"')
    for m in pat.finditer(hlo_text):
        for part in m.group(1).split("/"):
            if part.startswith(prefix):
                counts[part] += 1
    return dict(counts)


def op_histogram(hlo_text: str, top: int = 25) -> list[tuple[str, int]]:
    """Crude opcode histogram of the entry/partitioned module (dup-spotting)."""
    ops = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\w+\[[^\]]*\]\S*)\s+([a-z0-9-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return sorted(ops.items(), key=lambda kv: -kv[1])[:top]


def top_collectives(hlo_text: str, n: int = 12) -> list[dict]:
    """Largest individual collective ops with their source metadata (attribution
    for the §Perf loop: WHICH all-reduce is eating the wire)."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        g = _group_size(line)
        b = _sig_bytes(sig)
        if kind == "all-gather":
            b = b / (1 + 1.0 / g) / g if m.group(3) else b / g
        elif kind == "reduce-scatter":
            b = b * g
        elif kind == "all-reduce" and m.group(3):
            b = b / 2
        meta = re.search(r'op_name="([^"]+)"', line)
        out.append({"kind": kind, "bytes": b, "group": g, "sig": sig[:60],
                    "op_name": (meta.group(1)[-110:] if meta else "")})
    out.sort(key=lambda d: -d["bytes"])
    return out[:n]
