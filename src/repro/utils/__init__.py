"""Small shared utilities: pytree helpers, timing, numerics."""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def shard_map(f, mesh=None, in_specs=None, out_specs=None, check_vma: bool = True):
    """Version-compat ``shard_map``: jax >= 0.5 exposes ``jax.shard_map`` with
    ``check_vma``; 0.4.x has ``jax.experimental.shard_map.shard_map`` with the
    older ``check_rep`` spelling.  ``mesh=None`` resolves the active mesh
    context (``utils.set_mesh`` / ``with mesh:``).  One call site, both APIs."""
    if hasattr(jax, "shard_map"):
        kw = {} if mesh is None else {"mesh": mesh}
        return jax.shard_map(f, in_specs=in_specs, out_specs=out_specs,
                             check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    if mesh is None:
        from jax._src.mesh import thread_resources

        mesh = thread_resources.env.physical_mesh
        if mesh.empty:
            raise ValueError("shard_map with mesh=None needs an active mesh "
                             "context (utils.set_mesh)")
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)


def set_mesh(mesh):
    """Version-compat mesh activation: ``jax.set_mesh`` (>= 0.6) or the Mesh
    context manager (0.4.x), under which ``with_sharding_constraint`` accepts
    bare PartitionSpecs.  Use as ``with utils.set_mesh(mesh): ...``."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def tree_stack(trees: list[Pytree]) -> Pytree:
    """Stack a list of identically-structured pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree: Pytree, n: int) -> list[Pytree]:
    """Inverse of tree_stack."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_bytes(tree: Pytree) -> int:
    """Total bytes of all array leaves."""
    return sum(
        x.size * x.dtype.itemsize
        for x in jax.tree.leaves(tree)
        if hasattr(x, "dtype")
    )


def tree_count(tree: Pytree) -> int:
    """Total number of scalar parameters."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree) if hasattr(x, "shape"))


def tree_allclose(a: Pytree, b: Pytree, rtol: float = 1e-5, atol: float = 1e-6) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    if len(la) != len(lb):
        return False
    return all(np.allclose(x, y, rtol=rtol, atol=atol) for x, y in zip(la, lb))


def tree_finite(tree: Pytree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(x))) for x in jax.tree.leaves(tree))


@contextmanager
def timed(out: dict, key: str) -> Iterator[None]:
    """Context manager accumulating wall time into out[key]."""
    t0 = time.perf_counter()
    yield
    out[key] = out.get(key, 0.0) + (time.perf_counter() - t0)


def block_tree(tree: Pytree) -> Pytree:
    """block_until_ready on every leaf (for timing)."""
    return jax.tree.map(lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, tree)


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-clock seconds per call of a jitted function."""
    for _ in range(warmup):
        block_tree(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        block_tree(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
