"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pinn_mlp_ref(x, Ws, bs, a, act="tanh"):
    """Reference fused forward + input-Jacobian.

    x: (N, d_in); Ws: list of (in, out); bs: list of (out,); a: (n_hidden,).
    Returns u (N, out) and du (d_in, N, out) computed with jax.jvp (exact AD).
    """
    phi = {"tanh": jnp.tanh, "sin": jnp.sin, "cos": jnp.cos}[act]

    def fwd(xi):
        h = xi @ Ws[0] + bs[0]
        for l in range(len(Ws) - 1):
            h = phi(a[l] * h)
            h = h @ Ws[l + 1] + bs[l + 1]
        return h

    u = fwd(x)
    d_in = x.shape[1]
    dus = []
    for j in range(d_in):
        v = jnp.zeros_like(x).at[:, j].set(1.0)
        dus.append(jax.jvp(fwd, (x,), (v,))[1])
    return u, jnp.stack(dus, axis=0)


def pinn_mlp_ref2(x, Ws, bs, a, act="tanh", d2_dirs=None):
    """Reference fused forward + input-Jacobian + DIAGONAL input-Hessian.

    Same math as the second-order Pallas kernel (``pinn_mlp._kernel2``) written
    as batched jnp — the explicit forward-over-forward tangent recurrence, NOT
    nested per-point jvp closures.  Triple duty:

    * correctness contract for the kernel (interpret-mode parity tests),
    * the compiled non-TPU fast path of ``ops.pinn_mlp_forward2``,
    * the recompute target of the custom VJP (checkpointed backward).

    x: (N, d_in); Ws: sequence of (in, out); bs: sequence of (out,);
    a: (n_hidden,) adaptive slopes.  Returns (u (N, out), du (d_in, N, out),
    d2u (d_in, N, out)) where d2u[j] = d²u/dx_j² (no mixed terms).

    ``d2_dirs`` (static tuple, None = all directions) prunes the second-order
    tangent stream to the directions the PDE residual actually consumes
    (``PDE.d2_dirs``) — e.g. Burgers carries one ``s`` column instead of two,
    first-order systems none.  Pruned rows of d2u come back as exact zeros, so
    the output shape (and everything downstream) is unchanged.
    """
    from repro.kernels.pinn_mlp import _act_triple

    return _ref2_impl(x, Ws, bs, a, _act_triple(act), d2_dirs)


def _select_triple(code):
    """(phi, phi', phi'') with the activation chosen by a TRACED integer code
    (same branchless where-chain as ``nets.activation``).  All three branches
    are evaluated — acceptable because activations are a small fraction of the
    recurrence's matmul cost, and it buys a single fused entry across
    subdomains with heterogeneous (paper Table 3) activations."""
    def sel(t, s, c):
        return jnp.where(code == 0, t, jnp.where(code == 1, s, c))

    def d2_tanh(z):
        th = jnp.tanh(z)
        return -2.0 * th * (1.0 - th * th)

    phi = lambda z: sel(jnp.tanh(z), jnp.sin(z), jnp.cos(z))
    dphi = lambda z: sel(1.0 - jnp.tanh(z) ** 2, jnp.cos(z), -jnp.sin(z))
    d2phi = lambda z: sel(d2_tanh(z), -jnp.sin(z), -jnp.cos(z))
    return phi, dphi, d2phi


def pinn_mlp_ref2_select(x, Ws, bs, a, code, d2_dirs=None):
    """:func:`pinn_mlp_ref2` with a per-call TRACED activation code.

    Serving entry for models whose subdomains use DIFFERENT activations: under
    ``vmap`` over the stacked subdomain axis the code is data, so one traced
    recurrence covers every subdomain — the static-act kernel path would need
    one entry per activation group.  Matches ``pinn_mlp_ref2(act=name)``
    bitwise for the activation the code selects.
    """
    return _ref2_impl(x, Ws, bs, a, _select_triple(code), d2_dirs)


def _ref2_impl(x, Ws, bs, a, triple, d2_dirs):
    phi, dphi, d2phi = triple
    d_in = x.shape[1]
    sel = tuple(range(d_in)) if d2_dirs is None else tuple(d2_dirs)
    full = sel == tuple(range(d_in))
    h = x @ Ws[0] + bs[0]
    # stack the d_in directions on a leading axis: (d_in, N, width)
    t = jnp.broadcast_to(Ws[0][:d_in, None, :], (d_in,) + h.shape)
    s = jnp.zeros((len(sel),) + h.shape, h.dtype)
    for l in range(len(Ws) - 1):
        z = a[l] * h
        d1 = dphi(z) * a[l]
        if sel:  # empty sel (first-order PDE): s stays the (0, N, w) stream
            d2 = d2phi(z) * (a[l] * a[l])
            # static slice per selected direction (sel is a compile-time tuple)
            tsel = t if full else jnp.stack([t[j] for j in sel])
            s = d2[None] * tsel * tsel + d1[None] * s
        t = d1[None] * t
        h = phi(z)
        h = h @ Ws[l + 1] + bs[l + 1]
        t = t @ Ws[l + 1]
        s = s @ Ws[l + 1]
    if full:
        return h, t, s
    zero = jnp.zeros_like(h)
    rows = {j: s[k] for k, j in enumerate(sel)}
    d2u = jnp.stack([rows.get(j, zero) for j in range(d_in)])
    return h, t, d2u


def attention_ref(q, k, v, causal=True):
    """Plain softmax attention oracle. q: (B,H,S,dh); k/v: (B,Hk,T,dh)."""
    B, H, S, dh = q.shape
    Hk, T = k.shape[1], k.shape[2]
    G = H // Hk
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32)).astype(q.dtype)
