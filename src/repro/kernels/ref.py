"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pinn_mlp_ref(x, Ws, bs, a, act="tanh"):
    """Reference fused forward + input-Jacobian.

    x: (N, d_in); Ws: list of (in, out); bs: list of (out,); a: (n_hidden,).
    Returns u (N, out) and du (d_in, N, out) computed with jax.jvp (exact AD).
    """
    phi = {"tanh": jnp.tanh, "sin": jnp.sin, "cos": jnp.cos}[act]

    def fwd(xi):
        h = xi @ Ws[0] + bs[0]
        for l in range(len(Ws) - 1):
            h = phi(a[l] * h)
            h = h @ Ws[l + 1] + bs[l + 1]
        return h

    u = fwd(x)
    d_in = x.shape[1]
    dus = []
    for j in range(d_in):
        v = jnp.zeros_like(x).at[:, j].set(1.0)
        dus.append(jax.jvp(fwd, (x,), (v,))[1])
    return u, jnp.stack(dus, axis=0)


def pinn_mlp_ref2(x, Ws, bs, a, act="tanh", d2_dirs=None):
    """Reference fused forward + input-Jacobian + DIAGONAL input-Hessian.

    Same math as the second-order Pallas kernel (``pinn_mlp._kernel2``) written
    as batched jnp — the explicit forward-over-forward tangent recurrence, NOT
    nested per-point jvp closures.  Triple duty:

    * correctness contract for the kernel (interpret-mode parity tests),
    * the compiled non-TPU fast path of ``ops.pinn_mlp_forward2``,
    * the recompute target of the custom VJP (checkpointed backward).

    x: (N, d_in); Ws: sequence of (in, out); bs: sequence of (out,);
    a: (n_hidden,) adaptive slopes.  Returns (u (N, out), du (d_in, N, out),
    d2u (d_in, N, out)) where d2u[j] = d²u/dx_j² (no mixed terms).

    ``d2_dirs`` (static tuple, None = all directions) prunes the second-order
    tangent stream to the directions the PDE residual actually consumes
    (``PDE.d2_dirs``) — e.g. Burgers carries one ``s`` column instead of two,
    first-order systems none.  Pruned rows of d2u come back as exact zeros, so
    the output shape (and everything downstream) is unchanged.
    """
    from repro.kernels.pinn_mlp import _act_triple

    return _ref2_impl(x, Ws, bs, a, _act_triple(act), d2_dirs)


def _select_triple(code):
    """(phi, phi', phi'') with the activation chosen by a TRACED integer code
    (same branchless where-chain as ``nets.activation``).  All three branches
    are evaluated — acceptable because activations are a small fraction of the
    recurrence's matmul cost, and it buys a single fused entry across
    subdomains with heterogeneous (paper Table 3) activations."""
    def sel(t, s, c):
        return jnp.where(code == 0, t, jnp.where(code == 1, s, c))

    def d2_tanh(z):
        th = jnp.tanh(z)
        return -2.0 * th * (1.0 - th * th)

    phi = lambda z: sel(jnp.tanh(z), jnp.sin(z), jnp.cos(z))
    dphi = lambda z: sel(1.0 - jnp.tanh(z) ** 2, jnp.cos(z), -jnp.sin(z))
    d2phi = lambda z: sel(d2_tanh(z), -jnp.sin(z), -jnp.cos(z))
    return phi, dphi, d2phi


def _select_quad(code):
    """:func:`_select_triple` extended with phi''' (the reverse sweep of the
    second-order tangent recurrence differentiates phi'' once more).  The
    per-activation third derivatives are the kernel's own (``_act_quad``), not
    a second copy."""
    from repro.kernels.pinn_mlp import _act_quad

    def sel(t, s, c):
        return jnp.where(code == 0, t, jnp.where(code == 1, s, c))

    d3s = [_act_quad(n)[3] for n in ("tanh", "sin", "cos")]
    d3phi = lambda z: sel(d3s[0](z), d3s[1](z), d3s[2](z))
    return _select_triple(code) + (d3phi,)


def pinn_mlp_ref2_select(x, Ws, bs, a, code, d2_dirs=None):
    """:func:`pinn_mlp_ref2` with a per-call TRACED activation code.

    Serving entry for models whose subdomains use DIFFERENT activations: under
    ``vmap`` over the stacked subdomain axis the code is data, so one traced
    recurrence covers every subdomain — the static-act kernel path would need
    one entry per activation group.  Matches ``pinn_mlp_ref2(act=name)``
    bitwise for the activation the code selects.
    """
    return _ref2_impl(x, Ws, bs, a, _select_triple(code), d2_dirs)


def _ref2_impl(x, Ws, bs, a, triple, d2_dirs, save=False):
    phi, dphi, d2phi = triple
    d_in = x.shape[1]
    sel = tuple(range(d_in)) if d2_dirs is None else tuple(d2_dirs)
    full = sel == tuple(range(d_in))
    h = x @ Ws[0] + bs[0]
    # stack the d_in directions on a leading axis: (d_in, N, width)
    t = jnp.broadcast_to(Ws[0][:d_in, None, :], (d_in,) + h.shape)
    s = jnp.zeros((len(sel),) + h.shape, h.dtype)
    hs, ts, ss = [], [], []
    for l in range(len(Ws) - 1):
        if save:  # residuals of the reverse sweep: streams ENTERING stage l
            hs.append(h)
            ts.append(t)
            ss.append(s)
        z = a[l] * h
        d1 = dphi(z) * a[l]
        if sel:  # empty sel (first-order PDE): s stays the (0, N, w) stream
            d2 = d2phi(z) * (a[l] * a[l])
            # static slice per selected direction (sel is a compile-time tuple)
            tsel = t if full else jnp.stack([t[j] for j in sel])
            s = d2[None] * tsel * tsel + d1[None] * s
        t = d1[None] * t
        h = phi(z)
        h = h @ Ws[l + 1] + bs[l + 1]
        t = t @ Ws[l + 1]
        s = s @ Ws[l + 1]
    if full:
        outs = (h, t, s)
    else:
        zero = jnp.zeros_like(h)
        rows = {j: s[k] for k, j in enumerate(sel)}
        outs = (h, t, jnp.stack([rows.get(j, zero) for j in range(d_in)]))
    if save:
        return outs, (tuple(hs), tuple(ts), tuple(ss))
    return outs


def _ref2_bwd(x, Ws, a, res, quad, d2_dirs, cts):
    """Hand-derived reverse sweep of :func:`_ref2_impl` (closed form, NOT
    autodiff).  One backward pass over the saved per-layer residuals produces
    every cotangent; no forward recompute.

    Per activation stage ``g = phi(z)``, ``z = a h`` with tangent rules
    ``t~ = phi'(z)·a·t`` and ``s~ = phi''(z)·a²·t² + phi'(z)·a·s`` the
    cotangent flow (p_k = phi^(k)(z)) is

        h̄  = ḡ·p1·a  +  Σ_j t̄~_j·t_j·p2·a²
                       +  Σ_k s̄~_k·(t_k²·p3·a³ + s_k·p2·a²)
        t̄_j = t̄~_j·p1·a  (+ s̄~_j·2·p2·a²·t_j   for selected j)
        s̄_k = s̄~_k·p1·a
        ā   = Σ ḡ·p1·h + Σ_j t̄~_j·t_j·(p2·h·a + p1)
            + Σ_k s̄~_k·(t_k²·(p3·h·a² + 2·p2·a) + s_k·(p2·h·a + p1))

    and through each affine layer ``(h, t, s) @ W`` everything multiplies by
    ``Wᵀ`` while ``W̄ = gᵀh̄ + Σ t~ᵀt̄ + Σ s~ᵀs̄``.  The input layer closes with
    ``x̄ = h̄₀ W₀ᵀ``, ``W̄₀ = xᵀh̄₀ + row_j Σ_n t̄₀``, ``b̄₀ = Σ_n h̄₀``
    (``t₀,j`` is row j of W₀ broadcast; ``s₀ = 0``).

    ``res`` is the ``save=True`` payload of :func:`_ref2_impl`; ``cts`` the
    (ū, d̄u, d̄2u) cotangents.  Returns (x̄, W̄s, b̄s, ā).
    """
    phi, dphi, d2phi, d3phi = quad
    hs, ts, ss = res
    d_in = x.shape[1]
    sel = tuple(range(d_in)) if d2_dirs is None else tuple(d2_dirs)
    full = sel == tuple(range(d_in))
    cu, cdu, cd2u = cts
    L = len(Ws) - 1
    bar_h, bar_t = cu, cdu
    # pruned d2u rows are constant zeros — their cotangents never reach inputs
    if sel:
        bar_s = cd2u if full else jnp.stack([cd2u[j] for j in sel])
    else:
        bar_s = jnp.zeros((0,) + cu.shape, cu.dtype)
    cWs, cbs = [None] * (L + 1), [None] * (L + 1)
    ca_rev = []
    for l in reversed(range(L)):
        W, al = Ws[l + 1], a[l]
        h, t, s = hs[l], ts[l], ss[l]
        z = al * h
        p1, p2, p3 = dphi(z), d2phi(z), d3phi(z)
        d1 = p1 * al
        d2v = p2 * (al * al)
        if sel:
            tsel = t if full else jnp.stack([t[j] for j in sel])
        else:
            tsel = jnp.zeros((0,) + h.shape, h.dtype)
        g = phi(z)
        t_tl = d1[None] * t                              # t~ entering affine
        s_tl = d2v[None] * tsel * tsel + d1[None] * s    # s~ entering affine
        # ---- affine layer l+1 -------------------------------------------
        cWs[l + 1] = (g.T @ bar_h
                      + jnp.einsum("jnw,jnv->wv", t_tl, bar_t)
                      + jnp.einsum("jnw,jnv->wv", s_tl, bar_s))
        cbs[l + 1] = jnp.sum(bar_h, axis=0)
        bar_g = bar_h @ W.T
        bar_tt = bar_t @ W.T
        bar_st = bar_s @ W.T
        # ---- activation stage l -----------------------------------------
        e1 = p2 * h * al + p1                    # ∂(phi'·a)/∂a
        e2 = p3 * h * (al * al) + 2.0 * p2 * al  # ∂(phi''·a²)/∂a
        ca_rev.append(jnp.sum(bar_g * p1 * h)
                      + jnp.sum(bar_tt * t * e1[None])
                      + jnp.sum(bar_st * (tsel * tsel * e2[None]
                                          + s * e1[None])))
        bar_h = (bar_g * d1
                 + jnp.sum(bar_tt * t, axis=0) * d2v
                 + jnp.sum(bar_st * (tsel * tsel), axis=0) * (p3 * al ** 3)
                 + jnp.sum(bar_st * s, axis=0) * d2v)
        new_bar_t = bar_tt * d1[None]
        if sel:
            upd = bar_st * (2.0 * d2v[None]) * tsel
            if full:
                new_bar_t = new_bar_t + upd
            else:
                for k, j in enumerate(sel):
                    new_bar_t = new_bar_t.at[j].add(upd[k])
        bar_t = new_bar_t
        bar_s = bar_st * d1[None]
    # ---- input affine layer ---------------------------------------------
    cx = bar_h @ Ws[0].T
    cWs[0] = x.T @ bar_h + jnp.sum(bar_t, axis=1)
    cbs[0] = jnp.sum(bar_h, axis=0)
    ca = (jnp.stack(ca_rev[::-1]).astype(a.dtype) if ca_rev
          else jnp.zeros((0,), a.dtype))
    return cx, tuple(cWs), tuple(cbs), ca


def pinn_mlp_ref2_vjp(x, Ws, bs, a, act="tanh", d2_dirs=None):
    """Hand-derived closed-form VJP of :func:`pinn_mlp_ref2`.

    Independent oracle for the fused Pallas backward (``pinn_mlp._kernel2_bwd``)
    AND the compiled non-TPU backward fast path of ``ops.pinn_mlp_forward2``:
    derived on paper from the forward-over-forward recurrence, never through
    ``jax.vjp`` — so kernel parity tests validate against a second derivation,
    not against the autodiff they replace.

    Returns ``((u, du, d2u), vjp_fn)`` with
    ``vjp_fn((ū, d̄u, d̄2u)) -> (x̄, W̄s, b̄s, ā)``.
    """
    from repro.kernels.pinn_mlp import _act_quad

    quad = _act_quad(act)
    Ws, bs = tuple(Ws), tuple(bs)
    outs, res = _ref2_impl(x, Ws, bs, a, quad[:3], d2_dirs, save=True)
    return outs, lambda cts: _ref2_bwd(x, Ws, a, res, quad, d2_dirs, cts)


def attention_ref(q, k, v, causal=True):
    """Plain softmax attention oracle. q: (B,H,S,dh); k/v: (B,Hk,T,dh)."""
    B, H, S, dh = q.shape
    Hk, T = k.shape[1], k.shape[2]
    G = H // Hk
    kk = jnp.repeat(k, G, axis=1)
    vv = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, vv.astype(jnp.float32)).astype(q.dtype)
