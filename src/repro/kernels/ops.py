"""Jit'd public wrappers for the Pallas kernels: padding, dispatch, interpret-mode
selection (TPU targets compiled kernels; CPU validates via interpret=True)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pinn_mlp import (
    WPAD, _act_quad, pinn_mlp_pallas, pinn_mlp_pallas2, pinn_mlp_pallas2_bwd,
    pinn_mlp_pallas2_res,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def pack_mlp(Ws, bs, a):
    """Pad + stack an MLP pytree into the kernel's MXU-aligned layout.

    Returns (w_stack (L, WPAD, WPAD), b_stack (L, WPAD), a_vec (L,)).

    This is the hoistable 'prepare' step: the pad/stack ops are pure, so when a
    jitted step evaluates several fused calls on the SAME weights (residual +
    interface payload inside one loss), XLA CSE collapses the duplicate packing
    into one instance (verified by an HLO pad-count test in
    tests/test_kernels_pinn_mlp.py).  Callers outside a common jit scope (e.g.
    a serve loop with frozen weights) should call this once and use
    :func:`pinn_mlp_forward_packed`.
    """
    L = len(Ws)
    w_stack = jnp.stack([_pad_to(_pad_to(w, WPAD, 0), WPAD, 1) for w in Ws])
    b_stack = jnp.stack([_pad_to(b, WPAD, 0) for b in bs])
    a_vec = _pad_to(a, L, 0)
    return w_stack, b_stack, a_vec


def _pad_points(x, block_n):
    N = x.shape[0]
    n_pad = ((N + block_n - 1) // block_n) * block_n
    return _pad_to(_pad_to(x, n_pad, 0), WPAD, 1)


@partial(jax.jit, static_argnames=("act", "block_n", "interpret"))
def pinn_mlp_forward(x, Ws, bs, a, act="tanh", block_n=256, interpret=None):
    """Fused PINN MLP forward + input-Jacobian.

    x: (N, d_in); Ws: list[(in,out)]; bs: list[(out,)]; a: (n_hidden,) slopes.
    Returns (u (N, out), du (d_in, N, out)).
    """
    if interpret is None:
        interpret = not _on_tpu()
    N, d_in = x.shape
    out_dim = Ws[-1].shape[1]
    w_stack, b_stack, a_vec = pack_mlp(Ws, bs, a)
    x_pad = _pad_points(x, block_n)
    u, du = pinn_mlp_pallas(x_pad, w_stack, b_stack, a_vec, d_in=d_in, act=act,
                            block_n=block_n, interpret=interpret)
    return u[:N, :out_dim], du[:, :N, :out_dim]


@partial(jax.jit, static_argnames=("out_dim", "act", "block_n", "interpret"))
def pinn_mlp_forward_packed(x, packed, out_dim, act="tanh", block_n=256,
                            interpret=None):
    """First-order fused forward on a pre-packed weight stack (see pack_mlp)."""
    if interpret is None:
        interpret = not _on_tpu()
    N, d_in = x.shape
    w_stack, b_stack, a_vec = packed
    u, du = pinn_mlp_pallas(_pad_points(x, block_n), w_stack, b_stack, a_vec,
                            d_in=d_in, act=act, block_n=block_n,
                            interpret=interpret)
    return u[:N, :out_dim], du[:, :N, :out_dim]


# --------------------------------------------------------------- second order
#
# pinn_mlp_forward2 is the production residual path: one fused pass yields
# (u, du/dx_j, d²u/dx_j²) for all d_in directions.  Dispatch:
#   * TPU backend            -> compiled Pallas kernel (pinn_mlp._kernel2)
#   * non-TPU, interpret=None -> ref.pinn_mlp_ref2 (same math, batched jnp —
#       the compiled CPU fast path; the Pallas interpreter is a correctness
#       tool, far too slow for production)
#   * interpret=True         -> Pallas interpreter (kernel validation)
# The jax.custom_vjp makes the fused outputs differentiable w.r.t. (x, Ws, bs,
# a).  Two backward paths (static ``bwd`` selector):
#   * bwd="fused" (default) — the hand-derived reverse sweep: the forward
#       variant saves per-layer pre-activations + tangent streams as kernel
#       residuals and ONE reverse pass produces all cotangents
#       (pinn_mlp._kernel2_bwd on the Pallas dispatch, ref._ref2_bwd — the
#       same closed-form derivation as batched jnp — on the non-TPU fast
#       path).  No forward recompute, no autodiff of the recurrence.
#   * bwd="ref" — the PR-1 checkpointed oracle: save only the inputs and
#       jax.vjp through ref.pinn_mlp_ref2 inside the backward (op-granular
#       checkpointing).  Kept as the correctness reference and the fallback
#       for stacks the residual-saving kernel does not cover.
# Both paths are wrapped in jax.named_scope markers ("pinn2-bwd-fused" /
# "pinn2-bwd-ref") so compiled-HLO tests can assert WHICH backward a training
# step actually contains.


def _zero_pruned_rows(d2u, d2_dirs, d_in):
    """Zero d2u rows outside d2_dirs (kernel path parity with the pruned ref)."""
    if d2_dirs is None or tuple(d2_dirs) == tuple(range(d_in)):
        return d2u
    return d2u * _prune_mask(d2_dirs, d_in, d2u.dtype)


def _forward2_impl(x, Ws, bs, a, act, block_n, interpret, d2_dirs):
    N, d_in = x.shape
    out_dim = Ws[-1].shape[1]
    if interpret is None:
        if not _on_tpu():
            return ref.pinn_mlp_ref2(x, Ws, bs, a, act=act, d2_dirs=d2_dirs)
        interpret = False
    w_stack, b_stack, a_vec = pack_mlp(Ws, bs, a)
    u, du, d2u = pinn_mlp_pallas2(_pad_points(x, block_n), w_stack, b_stack,
                                  a_vec, d_in=d_in, act=act, block_n=block_n,
                                  interpret=interpret)
    # the VMEM-resident kernel computes every direction (pruning buys nothing
    # there); zero the unused rows so every dispatch path agrees with the ref
    d2u = _zero_pruned_rows(d2u, d2_dirs, d_in)
    return u[:N, :out_dim], du[:, :N, :out_dim], d2u[:, :N, :out_dim]


BWD_PATHS = ("fused", "ref")  # valid custom-VJP backward selectors

# conservative per-block VMEM cap for the fused reverse sweep (TPU VMEM is
# ~16 MB; leave headroom for Mosaic temporaries)
_BWD_VMEM_BUDGET = 12 * 1024 * 1024


def _use_jnp_recurrence(interpret) -> bool:
    """True when dispatch lands on the batched-jnp recurrence (non-TPU fast
    path) — decided statically, so forward and backward always agree."""
    return interpret is None and not _on_tpu()


def _fused_bwd_fits(n_weights, d_in, block_n, itemsize) -> bool:
    """Static VMEM estimate for one `_kernel2_bwd` block: residual streams
    (L·(1+2d) row tiles) + x/cu/cx + cotangent tiles + weight & cotangent
    stacks.  When the stack is too deep/wide to fit, the "fused" selector
    degrades to the checkpointed-ref save/recompute (the documented fallback)
    instead of dying in the Mosaic compiler — decided from static shapes, so
    forward and backward always agree.  Hidden-layer-free stacks (depth 0:
    one affine, nothing to spill) also take the checkpointed path — the
    residual-saving kernel requires >= 1 hidden layer."""
    L = n_weights - 1
    if L < 1:
        return False
    row_tiles = (1 + 2 * d_in) * L + 3 + 2 * d_in     # (block_n, WPAD) tiles
    fixed = 2 * n_weights * WPAD * WPAD + 3 * n_weights * WPAD
    return (row_tiles * block_n * WPAD + fixed) * itemsize <= _BWD_VMEM_BUDGET


def _prune_mask(d2_dirs, d_in, dtype):
    mask = np.zeros((d_in, 1, 1), dtype)
    for j in d2_dirs:
        mask[j] = 1.0
    return mask


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _pinn_mlp_forward2(x, Ws, bs, a, act, block_n, interpret, d2_dirs, bwd):
    return _forward2_impl(x, Ws, bs, a, act, block_n, interpret, d2_dirs)


def _pinn_mlp_forward2_fwd(x, Ws, bs, a, act, block_n, interpret, d2_dirs, bwd):
    N, d_in = x.shape
    pallas = not _use_jnp_recurrence(interpret)
    if bwd == "ref" or (pallas and not _fused_bwd_fits(
            len(Ws), d_in, block_n, np.dtype(x.dtype).itemsize)):
        # checkpointed oracle: save inputs, recompute in bwd — explicitly
        # requested, or the fused reverse sweep's residual blocks won't fit
        return (_forward2_impl(x, Ws, bs, a, act, block_n, interpret, d2_dirs),
                (x, Ws, bs, a))
    out_dim = Ws[-1].shape[1]
    if not pallas:
        outs, res = ref._ref2_impl(x, Ws, bs, a, _act_quad(act)[:3], d2_dirs,
                                   save=True)
        return outs, (x, Ws, a, res)
    w_stack, b_stack, a_vec = pack_mlp(Ws, bs, a)
    u, du, d2u, h_res, t_res, s_res = pinn_mlp_pallas2_res(
        _pad_points(x, block_n), w_stack, b_stack, a_vec, d_in=d_in, act=act,
        block_n=block_n, interpret=bool(interpret))
    d2u = _zero_pruned_rows(d2u, d2_dirs, d_in)
    outs = (u[:N, :out_dim], du[:, :N, :out_dim], d2u[:, :N, :out_dim])
    # w_stack/a_vec are NOT saved: the bwd repacks them from (Ws, a) — a pure
    # pad/stack that XLA CSEs against the forward's pack (PR-1 HLO test), so
    # the residual footprint doesn't carry the padded weights twice
    return outs, (x, Ws, a, h_res, t_res, s_res)


def _pinn_mlp_forward2_bwd(act, block_n, interpret, d2_dirs, bwd, saved, cts):
    # mirror the fwd's STATIC dispatch (selector + backend + shape-derived
    # VMEM fit) so the saved-pytree structure is always interpreted correctly
    pallas = not _use_jnp_recurrence(interpret)
    if bwd == "ref" or (pallas and not _fused_bwd_fits(
            len(saved[1]), saved[0].shape[1], block_n,
            np.dtype(saved[0].dtype).itemsize)):
        x, Ws, bs, a = saved
        with jax.named_scope("pinn2-bwd-ref"):
            _, vjp = jax.vjp(lambda xx, W, b, aa: ref.pinn_mlp_ref2(
                xx, W, b, aa, act=act, d2_dirs=d2_dirs), x, Ws, bs, a)
            return vjp(cts)
    if not pallas:
        x, Ws, a, res = saved
        with jax.named_scope("pinn2-bwd-fused"):
            return ref._ref2_bwd(x, Ws, a, res, _act_quad(act), d2_dirs, cts)
    x, Ws, a, h_res, t_res, s_res = saved
    L = len(Ws)
    w_stack = jnp.stack([_pad_to(_pad_to(w, WPAD, 0), WPAD, 1) for w in Ws])
    a_vec = _pad_to(a, L, 0)
    N, d_in = x.shape
    cu, cdu, cd2u = cts
    if d2_dirs is not None and tuple(d2_dirs) != tuple(range(d_in)):
        # pruned rows of the kernel output are masked constants: their
        # cotangents must not flow (parity with the pruned jnp backward)
        cd2u = cd2u * _prune_mask(d2_dirs, d_in, cd2u.dtype)
    n_pad = ((N + block_n - 1) // block_n) * block_n
    pad2 = lambda c: _pad_to(_pad_to(c, n_pad, 0), WPAD, 1)
    pad3 = lambda c: _pad_to(_pad_to(c, n_pad, 1), WPAD, 2)
    with jax.named_scope("pinn2-bwd-fused"):
        cx, cw, cb, ca_part = pinn_mlp_pallas2_bwd(
            _pad_points(x, block_n), w_stack, a_vec, h_res, t_res, s_res,
            pad2(cu), pad3(cdu), pad3(cd2u), d_in=d_in, act=act,
            block_n=block_n, interpret=bool(interpret))
    cWs = tuple(cw[i, :w.shape[0], :w.shape[1]] for i, w in enumerate(Ws))
    cbs = tuple(cb[i, :w.shape[1]] for i, w in enumerate(Ws))
    ca = jnp.sum(ca_part, axis=1)[:a.shape[0]].astype(a.dtype)
    return cx[:N, :d_in], cWs, cbs, ca


_pinn_mlp_forward2.defvjp(_pinn_mlp_forward2_fwd, _pinn_mlp_forward2_bwd)


@partial(jax.jit, static_argnames=("act", "block_n", "interpret", "d2_dirs",
                                   "bwd"))
def pinn_mlp_forward2(x, Ws, bs, a, act="tanh", block_n=256, interpret=None,
                      d2_dirs=None, bwd="fused"):
    """Fused PINN MLP forward + input-Jacobian + diagonal input-Hessian.

    x: (N, d_in); Ws: list[(in,out)]; bs: list[(out,)]; a: (n_hidden,) slopes.
    Returns (u (N, out), du (d_in, N, out), d2u (d_in, N, out)) with
    d2u[j] = d²u/dx_j² (diagonal only — what the repo's PDE residuals need).
    Differentiable w.r.t. (x, Ws, bs, a) via a custom VJP.

    ``bwd`` (static) selects the backward implementation: ``"fused"`` is the
    hand-derived single-sweep reverse kernel over saved layer residuals (the
    production path); ``"ref"`` is the checkpointed jax.vjp through
    ``ref.pinn_mlp_ref2`` (correctness oracle / fallback).

    ``d2_dirs`` (static, None = all) prunes the second-order tangent stream to
    the listed input directions on the recurrence path — the rows a PDE's
    ``residual_from_derivs`` actually reads (``PDE.d2_dirs``); pruned rows are
    exact zeros, and both backwards prune identically.
    """
    if bwd not in BWD_PATHS:
        raise ValueError(f"unknown backward path {bwd!r}")
    return _pinn_mlp_forward2(x, tuple(Ws), tuple(bs), a, act, block_n,
                              interpret,
                              None if d2_dirs is None else tuple(d2_dirs),
                              bwd)


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def _forward2_select(x, Ws, bs, a, code, d2_dirs):
    return ref.pinn_mlp_ref2_select(x, Ws, bs, a, code, d2_dirs=d2_dirs)


def _forward2_select_fwd(x, Ws, bs, a, code, d2_dirs):
    outs, res = ref._ref2_impl(x, Ws, bs, a, ref._select_quad(code)[:3],
                               d2_dirs, save=True)
    return outs, (x, Ws, a, code, res)


def _forward2_select_bwd(d2_dirs, saved, cts):
    x, Ws, a, code, res = saved
    with jax.named_scope("pinn2-bwd-fused-select"):
        cx, cWs, cbs, ca = ref._ref2_bwd(x, Ws, a, res,
                                         ref._select_quad(code), d2_dirs, cts)
    # the integer activation code has no tangent space
    return cx, cWs, cbs, ca, np.zeros(np.shape(code), jax.dtypes.float0)


_forward2_select.defvjp(_forward2_select_fwd, _forward2_select_bwd)


@partial(jax.jit, static_argnames=("d2_dirs",))
def pinn_mlp_forward2_select(x, Ws, bs, a, code, d2_dirs=None):
    """Fused second-order bundle with a TRACED activation code (serving path).

    Same (u, du, d2u) contract as :func:`pinn_mlp_forward2`, but the activation
    is selected per call by ``code`` (0=tanh, 1=sin, 2=cos) instead of being a
    static specialization — so a ``vmap`` over stacked subdomain params with
    per-subdomain codes stays ONE traced network entry even when subdomains use
    heterogeneous (paper Table 3) activations.  Always the batched jnp
    recurrence (``ref.pinn_mlp_ref2_select``): the Pallas kernel specializes
    the activation statically, and a data-dependent activation select inside
    VMEM buys nothing on the serving path.  ``d2_dirs=()`` disables the
    second-order tangent stream entirely (value + first-order inference).

    Differentiable w.r.t. (x, Ws, bs, a): the backward is the same
    hand-derived reverse sweep as the static-act path, with the traced-code
    activation-derivative chain (``ref._select_quad``).
    """
    return _forward2_select(x, tuple(Ws), tuple(bs), a, code,
                            None if d2_dirs is None else tuple(d2_dirs))


def pinn_mlp_forward2_segments(x_segs, Ws, bs, a, act="tanh", block_n=256,
                               interpret=None, d2_dirs=None, bwd="fused"):
    """Segment-aware megabatch entry: ONE fused dispatch for several point sets.

    x_segs: sequence of (n_i, d_in) arrays sharing d_in (e.g. residual points,
    flattened interface points, data points).  The segments are concatenated
    into one megabatch, run through a single :func:`pinn_mlp_forward2` call
    (one pack_mlp + one kernel launch + one custom-VJP backward instead of
    len(x_segs) of each), and the (u, du, d2u) bundle is sliced back per
    segment.  The kernel math is row-independent (every output row depends only
    on its input row), so each returned bundle is identical to a separate
    ``pinn_mlp_forward2(x_segs[i], ...)`` call — the jvp-oracle semantics are
    preserved exactly; only the dispatch count changes.

    Returns a tuple of (u (n_i, out), du (d_in, n_i, out), d2u (d_in, n_i, out))
    bundles, one per segment.  Segment sizes must be static (they come from the
    padded batch layout).
    """
    sizes = [int(x.shape[0]) for x in x_segs]
    u, du, d2u = pinn_mlp_forward2(jnp.concatenate(list(x_segs), axis=0), Ws, bs,
                                   a, act=act, block_n=block_n,
                                   interpret=interpret, d2_dirs=d2_dirs,
                                   bwd=bwd)
    out, ofs = [], 0
    for n in sizes:
        out.append((u[ofs:ofs + n], du[:, ofs:ofs + n], d2u[:, ofs:ofs + n]))
        ofs += n
    return tuple(out)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal=True, bq=256, bk=256, interpret=None):
    """Causal GQA flash attention. q: (B,H,S,dh); k/v: (B,Hk,T,dh)."""
    if interpret is None:
        interpret = not _on_tpu()
    dh = q.shape[-1]
    dh_pad = max(128, ((dh + 127) // 128) * 128)
    qp = _pad_to(q, dh_pad, 3)
    kp = _pad_to(k, dh_pad, 3)
    vp = _pad_to(v, dh_pad, 3)
    # keep the softmax scale of the TRUE head dim
    qp = qp * float(np.sqrt(dh_pad / dh))  # keep weak type: combined scale = 1/sqrt(dh)
    bq = min(bq, q.shape[2])
    bk = min(bk, k.shape[2])
    out = flash_attention_pallas(qp, kp, vp, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[..., :dh]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, chunk=64, interpret=None):
    """WKV6 linear attention. r/k/v/w: (B, T, H, P); u: (H, P). Returns (B,T,H,P)."""
    from repro.kernels.wkv6 import wkv6_pallas

    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, P = r.shape
    P_pad = max(128, ((P + 127) // 128) * 128)
    def prep(x):
        x = _pad_to(x, P_pad, 3)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, P_pad)
    up = _pad_to(u, P_pad, 1)
    up = jnp.broadcast_to(up[None], (B, H, P_pad)).reshape(B * H, P_pad)
    wp = prep(w)
    if P_pad != P:  # padded decay channels must not blow up cumsum(log w)
        pad_mask = jnp.arange(P_pad) >= P
        wp = jnp.where(pad_mask[None, None, :], 1.0, wp)
    y = wkv6_pallas(prep(r), prep(k), prep(v), wp, up, chunk=chunk,
                    interpret=interpret)
    y = y.reshape(B, H, T, P_pad).transpose(0, 2, 1, 3)
    return y[..., :P]
