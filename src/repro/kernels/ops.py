"""Jit'd public wrappers for the Pallas kernels: padding, dispatch, interpret-mode
selection (TPU targets compiled kernels; CPU validates via interpret=True)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.pinn_mlp import WPAD, pinn_mlp_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, n, axis):
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("act", "block_n", "interpret"))
def pinn_mlp_forward(x, Ws, bs, a, act="tanh", block_n=256, interpret=None):
    """Fused PINN MLP forward + input-Jacobian.

    x: (N, d_in); Ws: list[(in,out)]; bs: list[(out,)]; a: (n_hidden,) slopes.
    Returns (u (N, out), du (d_in, N, out)).
    """
    if interpret is None:
        interpret = not _on_tpu()
    N, d_in = x.shape
    out_dim = Ws[-1].shape[1]
    L = len(Ws)
    # pad weights into a (L, WPAD, WPAD) stack
    w_stack = jnp.stack([_pad_to(_pad_to(w, WPAD, 0), WPAD, 1) for w in Ws])
    b_stack = jnp.stack([_pad_to(b, WPAD, 0) for b in bs])
    a_vec = _pad_to(a, L, 0)
    n_pad = ((N + block_n - 1) // block_n) * block_n
    x_pad = _pad_to(_pad_to(x, n_pad, 0), WPAD, 1)
    u, du = pinn_mlp_pallas(x_pad, w_stack, b_stack, a_vec, d_in=d_in, act=act,
                            block_n=block_n, interpret=interpret)
    return u[:N, :out_dim], du[:, :N, :out_dim]


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal=True, bq=256, bk=256, interpret=None):
    """Causal GQA flash attention. q: (B,H,S,dh); k/v: (B,Hk,T,dh)."""
    if interpret is None:
        interpret = not _on_tpu()
    dh = q.shape[-1]
    dh_pad = max(128, ((dh + 127) // 128) * 128)
    qp = _pad_to(q, dh_pad, 3)
    kp = _pad_to(k, dh_pad, 3)
    vp = _pad_to(v, dh_pad, 3)
    # keep the softmax scale of the TRUE head dim
    qp = qp * float(np.sqrt(dh_pad / dh))  # keep weak type: combined scale = 1/sqrt(dh)
    bq = min(bq, q.shape[2])
    bk = min(bk, k.shape[2])
    out = flash_attention_pallas(qp, kp, vp, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[..., :dh]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, w, u, chunk=64, interpret=None):
    """WKV6 linear attention. r/k/v/w: (B, T, H, P); u: (H, P). Returns (B,T,H,P)."""
    from repro.kernels.wkv6 import wkv6_pallas

    if interpret is None:
        interpret = not _on_tpu()
    B, T, H, P = r.shape
    P_pad = max(128, ((P + 127) // 128) * 128)
    def prep(x):
        x = _pad_to(x, P_pad, 3)
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, P_pad)
    up = _pad_to(u, P_pad, 1)
    up = jnp.broadcast_to(up[None], (B, H, P_pad)).reshape(B * H, P_pad)
    wp = prep(w)
    if P_pad != P:  # padded decay channels must not blow up cumsum(log w)
        pad_mask = jnp.arange(P_pad) >= P
        wp = jnp.where(pad_mask[None, None, :], 1.0, wp)
    y = wkv6_pallas(prep(r), prep(k), prep(v), wp, up, chunk=chunk,
                    interpret=interpret)
    y = y.reshape(B, H, T, P_pad).transpose(0, 2, 1, 3)
    return y[..., :P]
