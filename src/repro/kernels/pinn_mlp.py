"""Fused PINN-MLP forward + input-Jacobian (+ diagonal Hessian) Pallas TPU kernel.

Paper hot-spot (Fig 4): residual-loss evaluation dominates PINN cost.  On TPU, a
PINN MLP is tiny (width <= ~128) so the naive path is HBM-latency-bound: every
layer round-trips (N, width) activations.  This kernel keeps the ENTIRE layer
stack resident in VMEM and fuses the forward pass with a FORWARD-MODE tangent
propagation for all ``d_in`` input directions (tangent rule
``t_l = phi'(a_l z_l) * a_l * (t_{l-1} @ W_l)``), so one HBM read of the
collocation block produces both u and du/dx — the quantities cPINN/XPINN exchange
at interfaces and the building blocks of flux terms.

The second-order variant additionally carries a forward-over-forward tangent
``s`` per direction (``s_l = phi''(z)·a²·t² + phi'(z)·a·s`` through each
activation, then ``s @ W`` through each affine layer), yielding the diagonal
second derivatives d²u/dx_j² — together with (u, du) everything the Burgers /
Navier-Stokes / heat-conduction residuals and cPINN fluxes consume, in ONE
VMEM-resident pass.

Tiling: grid over collocation-point blocks (``block_n`` rows, 8-row sublane
aligned); weights are padded to (WPAD, WPAD) = (128, 128) lanes — MXU-aligned.
Adaptive activations (tanh/sin/cos x trainable slope, paper refs [26,27]) are
selected statically per call.

``ops.pinn_mlp_forward`` / ``ops.pinn_mlp_forward2`` are the jit'd wrappers
(pad, dispatch, slice; forward2 adds a ``jax.custom_vjp`` for training);
``ref.pinn_mlp_ref`` / ``ref.pinn_mlp_ref2`` are the pure-jnp oracles;
``tests/test_kernels_pinn_mlp.py`` sweeps shapes x dtypes x activations in
interpret mode against the per-point ``pdes.dir_deriv2`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

WPAD = 128  # lane-aligned padded width


def _act_pair(name: str):
    if name == "tanh":
        return jnp.tanh, lambda z: 1.0 - jnp.tanh(z) ** 2
    if name == "sin":
        return jnp.sin, jnp.cos
    if name == "cos":
        return jnp.cos, lambda z: -jnp.sin(z)
    raise ValueError(name)


def _act_triple(name: str):
    """(phi, phi', phi'') for the second-order tangent rule."""
    if name == "tanh":
        def d2(z):
            th = jnp.tanh(z)
            return -2.0 * th * (1.0 - th * th)
        return jnp.tanh, lambda z: 1.0 - jnp.tanh(z) ** 2, d2
    if name == "sin":
        return jnp.sin, jnp.cos, lambda z: -jnp.sin(z)
    if name == "cos":
        return jnp.cos, lambda z: -jnp.sin(z), lambda z: -jnp.cos(z)
    raise ValueError(name)


def _kernel(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, *, n_layers, d_in, act):
    """One block of collocation points.

    x_ref:  (block_n, WPAD)          input block (cols >= d_in are zero)
    w_ref:  (n_layers+1, WPAD, WPAD) padded weight stack
    b_ref:  (n_layers+1, WPAD)       padded biases
    a_ref:  (n_layers+1,)            adaptive slopes (last entry unused)
    u_ref:  (block_n, WPAD)          primal output (cols >= out_dim are junk)
    du_ref: (d_in, block_n, WPAD)    input-Jacobian
    """
    phi, dphi = _act_pair(act)
    x = x_ref[...]
    h = x @ w_ref[0] + b_ref[0][None, :]
    # first-layer tangents: e_j @ W0 = row j of W0
    ts = [jnp.broadcast_to(w_ref[0][j, :][None, :], h.shape) for j in range(d_in)]
    for l in range(n_layers):
        a = a_ref[l]
        z = a * h
        g = phi(z)
        dg = dphi(z) * a
        ts = [dg * t for t in ts]
        h = g
        w_next = w_ref[l + 1]
        ts = [t @ w_next for t in ts]
        h = h @ w_next + b_ref[l + 1][None, :]
    u_ref[...] = h
    for j in range(d_in):
        du_ref[j, :, :] = ts[j]


def _kernel2(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref, *, n_layers,
             d_in, act):
    """Second-order variant: one block of collocation points.

    Same layout as :func:`_kernel` plus

    d2u_ref: (d_in, block_n, WPAD)   diagonal second derivatives d²u/dx_j²

    Per direction j the kernel carries (t_j, s_j) = (first, second) forward
    tangents of the running affine output h.  Through an activation
    ``g = phi(a h)``:  ``t -> phi'(a h)·a·t``,  ``s -> phi''(a h)·a²·t² +
    phi'(a h)·a·s`` (s BEFORE t is overwritten); through an affine layer both
    just multiply by W.  s_0 = 0 because the input enters linearly.
    """
    phi, dphi, d2phi = _act_triple(act)
    x = x_ref[...]
    h = x @ w_ref[0] + b_ref[0][None, :]
    ts = [jnp.broadcast_to(w_ref[0][j, :][None, :], h.shape) for j in range(d_in)]
    ss = [jnp.zeros_like(h) for _ in range(d_in)]
    for l in range(n_layers):
        a = a_ref[l]
        z = a * h
        d1 = dphi(z) * a
        d2 = d2phi(z) * (a * a)
        ss = [d2 * t * t + d1 * s for t, s in zip(ts, ss)]
        ts = [d1 * t for t in ts]
        h = phi(z)
        w_next = w_ref[l + 1]
        ts = [t @ w_next for t in ts]
        ss = [s @ w_next for s in ss]
        h = h @ w_next + b_ref[l + 1][None, :]
    u_ref[...] = h
    for j in range(d_in):
        du_ref[j, :, :] = ts[j]
        d2u_ref[j, :, :] = ss[j]


def pinn_mlp_pallas(x_pad, w_stack, b_stack, a_vec, *, d_in, act="tanh",
                    block_n=256, interpret=False):
    """x_pad: (N, WPAD) with N % block_n == 0. Returns (u (N, WPAD), du (d_in, N, WPAD))."""
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    grid = (n // block_n,)
    kernel = functools.partial(_kernel, n_layers=n_layers, d_in=d_in, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
        ],
        interpret=interpret,
    )(x_pad, w_stack, b_stack, a_vec)


def pinn_mlp_pallas2(x_pad, w_stack, b_stack, a_vec, *, d_in, act="tanh",
                     block_n=256, interpret=False):
    """Second-order launch: returns (u (N, WPAD), du (d_in, N, WPAD),
    d2u (d_in, N, WPAD)) with d2u the DIAGONAL second derivatives."""
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    grid = (n // block_n,)
    kernel = functools.partial(_kernel2, n_layers=n_layers, d_in=d_in, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
        ],
        interpret=interpret,
    )(x_pad, w_stack, b_stack, a_vec)
