"""Fused PINN-MLP forward + input-Jacobian (+ diagonal Hessian) Pallas TPU kernel.

Paper hot-spot (Fig 4): residual-loss evaluation dominates PINN cost.  On TPU, a
PINN MLP is tiny (width <= ~128) so the naive path is HBM-latency-bound: every
layer round-trips (N, width) activations.  This kernel keeps the ENTIRE layer
stack resident in VMEM and fuses the forward pass with a FORWARD-MODE tangent
propagation for all ``d_in`` input directions (tangent rule
``t_l = phi'(a_l z_l) * a_l * (t_{l-1} @ W_l)``), so one HBM read of the
collocation block produces both u and du/dx — the quantities cPINN/XPINN exchange
at interfaces and the building blocks of flux terms.

The second-order variant additionally carries a forward-over-forward tangent
``s`` per direction (``s_l = phi''(z)·a²·t² + phi'(z)·a·s`` through each
activation, then ``s @ W`` through each affine layer), yielding the diagonal
second derivatives d²u/dx_j² — together with (u, du) everything the Burgers /
Navier-Stokes / heat-conduction residuals and cPINN fluxes consume, in ONE
VMEM-resident pass.

Tiling: grid over collocation-point blocks (``block_n`` rows, 8-row sublane
aligned); weights are padded to (WPAD, WPAD) = (128, 128) lanes — MXU-aligned.
Adaptive activations (tanh/sin/cos x trainable slope, paper refs [26,27]) are
selected statically per call.

``ops.pinn_mlp_forward`` / ``ops.pinn_mlp_forward2`` are the jit'd wrappers
(pad, dispatch, slice; forward2 adds a ``jax.custom_vjp`` for training);
``ref.pinn_mlp_ref`` / ``ref.pinn_mlp_ref2`` are the pure-jnp oracles;
``tests/test_kernels_pinn_mlp.py`` sweeps shapes x dtypes x activations in
interpret mode against the per-point ``pdes.dir_deriv2`` oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

WPAD = 128  # lane-aligned padded width


def _act_pair(name: str):
    if name == "tanh":
        return jnp.tanh, lambda z: 1.0 - jnp.tanh(z) ** 2
    if name == "sin":
        return jnp.sin, jnp.cos
    if name == "cos":
        return jnp.cos, lambda z: -jnp.sin(z)
    raise ValueError(name)


def _act_triple(name: str):
    """(phi, phi', phi'') for the second-order tangent rule."""
    if name == "tanh":
        def d2(z):
            th = jnp.tanh(z)
            return -2.0 * th * (1.0 - th * th)
        return jnp.tanh, lambda z: 1.0 - jnp.tanh(z) ** 2, d2
    if name == "sin":
        return jnp.sin, jnp.cos, lambda z: -jnp.sin(z)
    if name == "cos":
        return jnp.cos, lambda z: -jnp.sin(z), lambda z: -jnp.cos(z)
    raise ValueError(name)


def _act_quad(name: str):
    """(phi, phi', phi'', phi''') — the reverse sweep differentiates the
    second-order tangent rule once more, so it consumes one extra derivative
    order than the forward kernel."""
    if name == "tanh":
        def d3(z):
            th = jnp.tanh(z)
            return (6.0 * th * th - 2.0) * (1.0 - th * th)
        return _act_triple("tanh") + (d3,)
    if name == "sin":
        return _act_triple("sin") + (lambda z: -jnp.cos(z),)
    if name == "cos":
        return _act_triple("cos") + (jnp.sin,)
    raise ValueError(name)


def _kernel(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, *, n_layers, d_in, act):
    """One block of collocation points.

    x_ref:  (block_n, WPAD)          input block (cols >= d_in are zero)
    w_ref:  (n_layers+1, WPAD, WPAD) padded weight stack
    b_ref:  (n_layers+1, WPAD)       padded biases
    a_ref:  (n_layers+1,)            adaptive slopes (last entry unused)
    u_ref:  (block_n, WPAD)          primal output (cols >= out_dim are junk)
    du_ref: (d_in, block_n, WPAD)    input-Jacobian
    """
    phi, dphi = _act_pair(act)
    x = x_ref[...]
    h = x @ w_ref[0] + b_ref[0][None, :]
    # first-layer tangents: e_j @ W0 = row j of W0
    ts = [jnp.broadcast_to(w_ref[0][j, :][None, :], h.shape) for j in range(d_in)]
    for l in range(n_layers):
        a = a_ref[l]
        z = a * h
        g = phi(z)
        dg = dphi(z) * a
        ts = [dg * t for t in ts]
        h = g
        w_next = w_ref[l + 1]
        ts = [t @ w_next for t in ts]
        h = h @ w_next + b_ref[l + 1][None, :]
    u_ref[...] = h
    for j in range(d_in):
        du_ref[j, :, :] = ts[j]


def _kernel2_run(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref,
                 h_ref, t_ref, s_ref, *, n_layers, d_in, act):
    """Shared second-order recurrence body (ONE copy of the tangent math).

    ``h_ref/t_ref/s_ref`` are the optional residual-spill refs of the
    training-forward variant (None for the inference kernel) — residual
    saving must never fork the recurrence itself.
    """
    phi, dphi, d2phi = _act_triple(act)
    x = x_ref[...]
    h = x @ w_ref[0] + b_ref[0][None, :]
    ts = [jnp.broadcast_to(w_ref[0][j, :][None, :], h.shape) for j in range(d_in)]
    ss = [jnp.zeros_like(h) for _ in range(d_in)]
    for l in range(n_layers):
        if h_ref is not None:
            h_ref[l] = h
            for j in range(d_in):
                t_ref[l, j] = ts[j]
                s_ref[l, j] = ss[j]
        a = a_ref[l]
        z = a * h
        d1 = dphi(z) * a
        d2 = d2phi(z) * (a * a)
        ss = [d2 * t * t + d1 * s for t, s in zip(ts, ss)]
        ts = [d1 * t for t in ts]
        h = phi(z)
        w_next = w_ref[l + 1]
        ts = [t @ w_next for t in ts]
        ss = [s @ w_next for s in ss]
        h = h @ w_next + b_ref[l + 1][None, :]
    u_ref[...] = h
    for j in range(d_in):
        du_ref[j, :, :] = ts[j]
        d2u_ref[j, :, :] = ss[j]


def _kernel2(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref, *, n_layers,
             d_in, act):
    """Second-order variant: one block of collocation points.

    Same layout as :func:`_kernel` plus

    d2u_ref: (d_in, block_n, WPAD)   diagonal second derivatives d²u/dx_j²

    Per direction j the kernel carries (t_j, s_j) = (first, second) forward
    tangents of the running affine output h.  Through an activation
    ``g = phi(a h)``:  ``t -> phi'(a h)·a·t``,  ``s -> phi''(a h)·a²·t² +
    phi'(a h)·a·s`` (s BEFORE t is overwritten); through an affine layer both
    just multiply by W.  s_0 = 0 because the input enters linearly.
    """
    _kernel2_run(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref,
                 None, None, None, n_layers=n_layers, d_in=d_in, act=act)


def _kernel2_res(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref,
                 h_ref, t_ref, s_ref, *, n_layers, d_in, act):
    """:func:`_kernel2` that ALSO spills the reverse sweep's residuals.

    Training forward variant: identical (u, du, d2u) math, plus per activation
    stage l the streams ENTERING it —

    h_ref: (n_layers, block_n, WPAD)        pre-activation affine outputs h_l
    t_ref: (n_layers, d_in, block_n, WPAD)  first-order tangents t_l
    s_ref: (n_layers, d_in, block_n, WPAD)  second-order tangents s_l

    — exactly what :func:`_kernel2_bwd` re-derives the activation factors from
    (phi^(k)(a·h) are recomputed from h; no matmul is ever recomputed).
    """
    _kernel2_run(x_ref, w_ref, b_ref, a_ref, u_ref, du_ref, d2u_ref,
                 h_ref, t_ref, s_ref, n_layers=n_layers, d_in=d_in, act=act)


def _kernel2_bwd(x_ref, w_ref, a_ref, h_ref, t_ref, s_ref,
                 cu_ref, cdu_ref, cd2u_ref,
                 cx_ref, cw_ref, cb_ref, ca_ref, *, n_layers, d_in, act):
    """Hand-derived fused reverse sweep of :func:`_kernel2` (one VMEM pass).

    One block of collocation points walks the layer stack BACKWARD carrying the
    cotangent streams (h̄, t̄_j, s̄_j); per stage the saved residuals (h, t, s)
    reproduce the activation factors p_k = phi^(k)(a·h) and the cotangent rules
    are the paper-derivation transposes of the forward tangent rules (see
    ``ref._ref2_bwd`` — the jnp twin of this kernel — for the formulas).

    Weight / bias / slope cotangents accumulate ACROSS grid blocks: every grid
    step maps cw/cb/ca to the same block (TPU grid iteration is sequential),
    zero-initialized at step 0.

    cu_ref:  (block_n, WPAD)        ū cotangent block
    cdu_ref: (d_in, block_n, WPAD)  d̄u
    cd2u_ref:(d_in, block_n, WPAD)  d̄2u (pruned rows pre-zeroed by the caller)
    cx_ref:  (block_n, WPAD)        x̄ out
    cw_ref:  (n_layers+1, WPAD, WPAD) accumulated W̄ stack
    cb_ref:  (n_layers+1, WPAD)       accumulated b̄ stack
    ca_ref:  (n_layers+1, WPAD)       ā lane-partials (reduce lanes outside;
                                      row n_layers unused)
    """
    phi, dphi, d2phi, d3phi = _act_quad(act)
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cw_ref[...] = jnp.zeros(cw_ref.shape, cw_ref.dtype)
        cb_ref[...] = jnp.zeros(cb_ref.shape, cb_ref.dtype)
        ca_ref[...] = jnp.zeros(ca_ref.shape, ca_ref.dtype)

    bar_h = cu_ref[...]
    bar_t = [cdu_ref[j] for j in range(d_in)]
    bar_s = [cd2u_ref[j] for j in range(d_in)]
    for l in reversed(range(n_layers)):
        a = a_ref[l]
        h = h_ref[l]
        t = [t_ref[l, j] for j in range(d_in)]
        s = [s_ref[l, j] for j in range(d_in)]
        z = a * h
        p1, p2, p3 = dphi(z), d2phi(z), d3phi(z)
        d1 = p1 * a
        d2v = p2 * (a * a)
        g = phi(z)
        # ---- affine layer l+1: W̄, b̄ and pull cotangents through Wᵀ ------
        cw = g.T @ bar_h
        for j in range(d_in):
            t_tl = d1 * t[j]
            s_tl = d2v * t[j] * t[j] + d1 * s[j]
            cw += t_tl.T @ bar_t[j] + s_tl.T @ bar_s[j]
        cw_ref[l + 1] += cw
        cb_ref[l + 1] += jnp.sum(bar_h, axis=0)
        wt = w_ref[l + 1].T
        bar_g = bar_h @ wt
        bar_tt = [bt @ wt for bt in bar_t]
        bar_st = [bs @ wt for bs in bar_s]
        # ---- activation stage l: ā partial, then (h̄, t̄, s̄) --------------
        e1 = p2 * h * a + p1                    # ∂(phi'·a)/∂a
        e2 = p3 * h * (a * a) + 2.0 * p2 * a    # ∂(phi''·a²)/∂a
        ca = bar_g * (p1 * h)
        for j in range(d_in):
            ca += bar_tt[j] * t[j] * e1
            ca += bar_st[j] * (t[j] * t[j] * e2 + s[j] * e1)
        ca_ref[l] += jnp.sum(ca, axis=0)
        p3a3 = p3 * (a * a * a)
        new_h = bar_g * d1
        for j in range(d_in):
            new_h += bar_tt[j] * t[j] * d2v
            new_h += bar_st[j] * (t[j] * t[j] * p3a3 + s[j] * d2v)
        bar_h = new_h
        bar_t = [bar_tt[j] * d1 + bar_st[j] * (2.0 * d2v) * t[j]
                 for j in range(d_in)]
        bar_s = [bar_st[j] * d1 for j in range(d_in)]
    # ---- input affine layer: t₀,j is row j of W₀ broadcast, s₀ = 0 -------
    x = x_ref[...]
    cx_ref[...] = bar_h @ w_ref[0].T
    cw0 = x.T @ bar_h
    rows = jax.lax.broadcasted_iota(jnp.int32, (WPAD, 1), 0)
    for j in range(d_in):
        cw0 += jnp.where(rows == j, 1.0, 0.0) * jnp.sum(bar_t[j], axis=0)[None, :]
    cw_ref[0] += cw0
    cb_ref[0] += jnp.sum(bar_h, axis=0)


def pinn_mlp_pallas(x_pad, w_stack, b_stack, a_vec, *, d_in, act="tanh",
                    block_n=256, interpret=False):
    """x_pad: (N, WPAD) with N % block_n == 0. Returns (u (N, WPAD), du (d_in, N, WPAD))."""
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    grid = (n // block_n,)
    kernel = functools.partial(_kernel, n_layers=n_layers, d_in=d_in, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
        ],
        interpret=interpret,
    )(x_pad, w_stack, b_stack, a_vec)


def pinn_mlp_pallas2(x_pad, w_stack, b_stack, a_vec, *, d_in, act="tanh",
                     block_n=256, interpret=False):
    """Second-order launch: returns (u (N, WPAD), du (d_in, N, WPAD),
    d2u (d_in, N, WPAD)) with d2u the DIAGONAL second derivatives."""
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    grid = (n // block_n,)
    kernel = functools.partial(_kernel2, n_layers=n_layers, d_in=d_in, act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
            jax.ShapeDtypeStruct((d_in, n, WPAD), x_pad.dtype),
        ],
        interpret=interpret,
    )(x_pad, w_stack, b_stack, a_vec)


def pinn_mlp_pallas2_res(x_pad, w_stack, b_stack, a_vec, *, d_in, act="tanh",
                         block_n=256, interpret=False):
    """Training-forward launch: :func:`pinn_mlp_pallas2` outputs PLUS the
    reverse-sweep residual stacks (h (L, N, WPAD), t/s (L, d_in, N, WPAD))."""
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    assert n_layers >= 1, "residual-saving kernel needs >= 1 hidden layer"
    grid = (n // block_n,)
    kernel = functools.partial(_kernel2_res, n_layers=n_layers, d_in=d_in,
                               act=act)
    dt = x_pad.dtype
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((n_layers, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((n_layers, d_in, block_n, WPAD),
                         lambda i: (0, 0, i, 0)),
            pl.BlockSpec((n_layers, d_in, block_n, WPAD),
                         lambda i: (0, 0, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), dt),
            jax.ShapeDtypeStruct((d_in, n, WPAD), dt),
            jax.ShapeDtypeStruct((d_in, n, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers, n, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers, d_in, n, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers, d_in, n, WPAD), dt),
        ],
        interpret=interpret,
    )(x_pad, w_stack, b_stack, a_vec)


def pinn_mlp_pallas2_bwd(x_pad, w_stack, a_vec, h_res, t_res, s_res,
                         cu, cdu, cd2u, *, d_in, act="tanh", block_n=256,
                         interpret=False):
    """Fused reverse-sweep launch (:func:`_kernel2_bwd`).

    Grid over point blocks; x̄ streams out per block while the parameter
    cotangents (W̄ stack, b̄ stack, ā lane-partials) accumulate in one
    revisited VMEM block across the sequential grid.  Returns
    (cx (N, WPAD), cw (L+1, WPAD, WPAD), cb (L+1, WPAD),
    ca_part (L+1, WPAD) — sum the lane axis for ā).
    """
    n, wp = x_pad.shape
    assert wp == WPAD and n % block_n == 0
    n_layers = w_stack.shape[0] - 1
    assert n_layers >= 1
    grid = (n // block_n,)
    kernel = functools.partial(_kernel2_bwd, n_layers=n_layers, d_in=d_in,
                               act=act)
    dt = x_pad.dtype
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1,), lambda i: (0,)),
            pl.BlockSpec((n_layers, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((n_layers, d_in, block_n, WPAD),
                         lambda i: (0, 0, i, 0)),
            pl.BlockSpec((n_layers, d_in, block_n, WPAD),
                         lambda i: (0, 0, i, 0)),
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
            pl.BlockSpec((d_in, block_n, WPAD), lambda i: (0, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, WPAD), lambda i: (i, 0)),
            pl.BlockSpec((n_layers + 1, WPAD, WPAD), lambda i: (0, 0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
            pl.BlockSpec((n_layers + 1, WPAD), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers + 1, WPAD, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers + 1, WPAD), dt),
            jax.ShapeDtypeStruct((n_layers + 1, WPAD), dt),
        ],
        interpret=interpret,
    )(x_pad, w_stack, a_vec, h_res, t_res, s_res, cu, cdu, cd2u)
