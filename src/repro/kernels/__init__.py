"""Pallas TPU kernels (validated in interpret mode on CPU; compiled on TPU).

pinn_mlp        — fused PINN MLP forward + input-Jacobian (+ second-order
                  variant with diagonal input-Hessian and a custom VJP — the
                  production residual-loss path; the paper's Fig-4 hot spot).
flash_attention — causal GQA flash attention (32k-prefill roofline hot spot).
"""
from repro.kernels.ops import (flash_attention, pack_mlp, pinn_mlp_forward,
                               pinn_mlp_forward2, pinn_mlp_forward2_segments,
                               pinn_mlp_forward_packed)
