"""Pallas TPU kernels (validated in interpret mode on CPU; compiled on TPU).

pinn_mlp        — fused PINN MLP forward + input-Jacobian (the paper's Fig-4
                  hot spot: residual/interface evaluation).
flash_attention — causal GQA flash attention (32k-prefill roofline hot spot).
"""
from repro.kernels.ops import flash_attention, pinn_mlp_forward
