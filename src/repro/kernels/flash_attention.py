"""Causal GQA flash-attention Pallas TPU kernel (prefill hot-spot).

Online-softmax attention with BlockSpec VMEM tiling: grid = (B, H, nq, nk); the
kv-block axis is the innermost (sequential) grid dim, so the (m, l, acc) running
statistics live in VMEM scratch across kv iterations of one q block.  Causal
skipping: kv blocks strictly above the diagonal contribute nothing and are
skipped via ``pl.when`` (keeps the MXU off the masked region — at 32k prefill
that's ~2x fewer score FLOPs).  GQA maps query head h to kv head h // G inside
the BlockSpec index maps, so no K/V replication is materialized.

Forward-only by design: training uses the XLA chunked-attention path (remat needs
a differentiable graph); this kernel serves prefill/serving, which is where the
q*k' score traffic dominates the roofline (see EXPERIMENTS.md §Perf).

``ops.flash_attention`` wraps (pads head_dim to 128 lanes, picks interpret mode on
CPU); ``ref.attention_ref`` is the oracle; tests sweep shapes/dtypes/causality.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, bq, bk, nk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _body():
        q = q_ref[0, 0].astype(jnp.float32)               # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, dh)
        s = (q @ k.T) * scale                              # (bq, bk)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + p @ v
        m_scr[...] = m_new

    if causal:
        # kv blocks strictly above the diagonal contribute nothing: skip them
        pl.when(ki * bk <= qi * bq + bq - 1)(_body)
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, bq=256, bk=256,
                           interpret=False):
    """q: (B, H, S, dh); k/v: (B, Hk, T, dh); dh must be lane-aligned (pad first).

    Returns (B, H, S, dh) attention output.
    """
    B, H, S, dh = q.shape
    _, Hk, T, _ = k.shape
    G = H // Hk
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0 and T % bk == 0
    nq, nk = S // bq, T // bk
    scale = 1.0 / np.sqrt(dh)
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, dh), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
