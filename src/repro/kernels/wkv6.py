"""WKV6 (RWKV-6 "Finch") chunked linear-attention Pallas TPU kernel.

The rwkv6-3b prefill cell's hot loop is the WKV recurrence.  The XLA chunked path
(models/ssm.py) materializes (c, c, H, P) decay tensors in HBM; this kernel keeps
the running (P, P) state and all chunk-local tensors in VMEM:

grid = (B*H, T/c) with the chunk axis innermost (sequential) — state persists in
VMEM scratch across chunk steps of one (batch, head) program:

  intra-chunk:  a_ij = sum_p r_ip k_jp exp(seg_{i-1} - seg_j)   (j < i, see ssm.py)
  inter-chunk:  y_i += (r_i * exp(seg_{i-1})) @ S ;  S <- S * exp(seg_c) + K~^T V

Forward-only (serving/prefill); training keeps the differentiable XLA path.
Oracle: models.ssm._wkv6_chunked / ref via tests/test_kernels_wkv6.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, state_scr, *, chunk, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)          # (c, P)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, P) bonus row
    c = r.shape[0]

    logw = jnp.log(w + 1e-38)
    seg = jnp.cumsum(logw, axis=0)            # (c, P) inclusive cumulative log-decay
    esc = seg - logw                          # exclusive (state read before step decay)

    # ---- intra-chunk: pairwise decayed scores, strictly causal ---------------
    # NOTE: the factored (r e^esc)(k e^-seg)^T form overflows for strong decay
    # (e^-seg grows like w^-c); the pairwise exponent esc_i - seg_j is <= 0 and
    # safe.  (c, c, P) lives in VMEM: chunk 64 x 64 x 128 fp32 = 2MiB.
    diff = esc[:, None, :] - seg[None, :, :]            # (c, c, P), <= 0 for j < i
    mask = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    dec = jnp.where(mask[:, :, None], jnp.exp(diff), 0.0)
    a = jnp.einsum("ip,jp,ijp->ij", r, k, dec)
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)   # (c, 1) diagonal term
    y = a @ v + bonus * v

    # ---- inter-chunk: carried state ------------------------------------------
    S = state_scr[...]                        # (P, P)
    y = y + (r * jnp.exp(esc)) @ S
    decay_to_end = jnp.exp(seg[-1][None, :] - seg)       # (c, P)
    state_scr[...] = S * jnp.exp(seg[-1])[:, None] + (k * decay_to_end).T @ v
    y_ref[0] = y.astype(y_ref.dtype)


def wkv6_pallas(r, k, v, w, u, *, chunk=64, interpret=False):
    """r/k/v/w: (BH, T, P) merged batch*head leading dim; w in (0,1); u: (BH, P).

    Returns y: (BH, T, P).  P should be lane-aligned (pad to 128 upstream).
    """
    BH, T, P = r.shape
    c = min(chunk, T)
    assert T % c == 0
    n_chunks = T // c
    kernel = functools.partial(_kernel, chunk=c, n_chunks=n_chunks)
    spec = pl.BlockSpec((1, c, P), lambda b, i: (b, i, 0))
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[spec, spec, spec,
                  spec,
                  pl.BlockSpec((1, P), lambda b, i: (b, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((BH, T, P), r.dtype),
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
