"""Logical-axis sharding rules (MaxText-style) for the LM model zoo.

Params and activations are annotated with LOGICAL axis names; a rules table maps
them to mesh axes.  The production meshes are ``(16,16) ("data","model")`` and
``(2,16,16) ("pod","data","model")`` (see ``launch/mesh.py``).

Default mapping (single-pod):
    batch   -> data            (DP)
    embed   -> data            (FSDP-style weight storage sharding; XLA inserts
                                the all-gathers — ZeRO-3 semantics)
    vocab / heads / kv_heads / ff / expert -> model   (TP / EP)
    seq     -> None            (replicated; long-decode caches override to data)

Multi-pod adds ``batch -> (pod, data)`` so the gradient all-reduce crosses the pod
axis (the dry-run proves that collective lowers).

``constrain`` is a no-op outside a mesh context, so model code runs unmodified in
single-device tests.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()

SINGLE_POD_RULES: dict[str, object] = {
    "batch": "data",
    "embed": "data",
    "act_embed": None,
    "res_seq": None,   # sequence-parallel residual stream (hillclimb lever)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "expert": "model",
    "seq": None,
    "kv_seq": None,
    "conv": None,
    "state": None,
    "capacity": None,
    "_": None,
}

MULTI_POD_RULES = dict(SINGLE_POD_RULES, batch=("pod", "data"))

# decode: shard the KV/latent cache sequence dim over `model` (batch stays on
# `data`) — decode memory is cache-dominated and per-device footprint must fit
# 16GB v5e (measured: minicpm3 decode_32k cache = 9.3GB/dev without this).
DECODE_OVERRIDES = {"kv_seq": "model", "kv_heads": None}

# long-context decode (global_batch=1): batch cannot shard; spread the cache
# sequence dim over BOTH axes instead.
LONG_CONTEXT_OVERRIDES = {"batch": None, "kv_seq": ("data", "model"), "kv_heads": None}


def rules_for(multi_pod: bool = False, long_context: bool = False,
              decode: bool = False) -> dict:
    r = dict(MULTI_POD_RULES if multi_pod else SINGLE_POD_RULES)
    if decode:
        r.update(DECODE_OVERRIDES)
    if long_context:
        r.update(LONG_CONTEXT_OVERRIDES)
        if multi_pod:
            r["kv_seq"] = ("pod", "data", "model")
    return r


@contextmanager
def use_rules(rules: dict | None):
    prev = getattr(_state, "rules", None)
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def current_rules() -> dict | None:
    return getattr(_state, "rules", None)


def spec(*logical: str | None, rules: dict | None = None) -> P:
    """PartitionSpec from logical dim names under the active rules."""
    r = rules if rules is not None else current_rules()
    if r is None:
        return P()
    return P(*[r.get(ax, None) if ax is not None else None for ax in logical])


def constrain(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without active rules."""
    r = current_rules()
    if r is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical, rules=r))


def specs_from_logical(logical_tree, rules: dict):
    """Map a pytree of logical-dim tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda dims: spec(*dims, rules=rules),
        logical_tree,
        is_leaf=lambda v: isinstance(v, tuple),
    )
