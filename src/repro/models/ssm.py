"""State-space / linear-attention blocks: Mamba2 (SSD), Zamba2 hybrid, RWKV6.

These are the sub-quadratic families that run the ``long_500k`` cell.  Training
uses CHUNKED scans (quadratic only within a chunk, linear across chunks — the SSD
formulation), decode is an O(1) recurrent state update.  On TPU this is the natural
adaptation of the papers' CUDA scan kernels: the chunk-local einsums feed the MXU and
the cross-chunk recurrence is a ``lax.scan`` over chunk states (sequence-parallel
state passing across data shards is the XPINN time-interface analogue, see DESIGN.md
§5).

Mamba2 (SSD), per head h with scalar decay a_t = exp(dt_t * A):
    state_t = a_t * state_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t^T state_t
RWKV6 ("Finch"), per head, data-dependent per-channel decay w_t:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ;  y_t = r_t (S_t + (u-1) k_t^T v_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.causal_lm import BlockDef, register_block
from repro.models.sharding import constrain


# ================================================================== Mamba2 (SSD)

def _ssd_chunked(x, dt, A, Bm, Cm, state0, chunk):
    """Chunked SSD scan.

    x: (B, T, H, P)    per-head inputs      (P = ssm_head_dim)
    dt: (B, T, H)      positive step sizes
    A: (H,)            negative per-head decay rate
    Bm, Cm: (B, T, N)  shared input/output projections (N = ssm_state)
    state0: (B, H, P, N)
    returns y (B, T, H, P), state_T
    """
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    nc = T // chunk
    assert nc * chunk == T, f"T={T} % chunk={chunk} != 0"
    c = chunk

    xl = x.reshape(Bsz, nc, c, H, P)
    dtl = dt.reshape(Bsz, nc, c, H)
    Bl = Bm.reshape(Bsz, nc, c, N)
    Cl = Cm.reshape(Bsz, nc, c, N)

    dA = dtl * A[None, None, None, :]                 # (B,nc,c,H) negative
    seg = jnp.cumsum(dA, axis=2)                      # within-chunk cumulative log-decay

    # ---- intra-chunk (quadratic within chunk, masked decay kernel) ----------
    # L[i,j] = exp(seg_i - seg_j) for i >= j
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]          # (B,nc,c,c,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    Ldec = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    G = jnp.einsum("bnik,bnjk->bnij", Cl, Bl)                      # (B,nc,c,c)
    M = G[..., None] * Ldec                                        # (B,nc,c,c,H)
    xdt = xl * dtl[..., None]                                      # (B,nc,c,H,P)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", M, xdt)

    # ---- chunk states + inter-chunk scan ------------------------------------
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)                # (B,nc,c,H)
    S_chunk = jnp.einsum("bnch,bnchp,bnck->bnhpk", decay_to_end * dtl, xl, Bl)
    chunk_decay = jnp.exp(seg[:, :, -1, :])                        # (B,nc,H)

    def scan_fn(s, inp):
        s_c, dec = inp                                             # (B,H,P,N), (B,H)
        s_new = s * dec[:, :, None, None] + s_c
        return s_new, s                                            # emit state ENTERING chunk

    stateT, states_in = jax.lax.scan(
        scan_fn, state0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,N)

    # ---- contribution of carried-in state -----------------------------------
    decay_from_start = jnp.exp(seg)                                # (B,nc,c,H)
    y_inter = jnp.einsum("bnck,bnhpk,bnch->bnchp", Cl, states_in, decay_from_start)
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y, stateT


def _ssd_step(x, dt, A, Bm, Cm, state):
    """Single-token recurrence. x:(B,H,P) dt:(B,H) Bm/Cm:(B,N) state:(B,H,P,N)."""
    dA = jnp.exp(dt * A[None, :])                                   # (B,H)
    upd = jnp.einsum("bhp,bk->bhpk", x * dt[..., None], Bm)
    state = state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpk,bk->bhp", state, Cm)
    return y, state


def mamba2_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = L.split_tree(rng, 6)
    return {
        "norm": jnp.ones((d,)),
        "in_proj": L.normal_init(ks[0], (d, 2 * d_in + 2 * N + H)),  # x, z, B, C, dt
        "conv_w": L.normal_init(ks[1], (cfg.ssm_conv, d_in + 2 * N), std=0.2),
        "A_log": jnp.zeros((H,)),          # A = -exp(A_log) -> A = -1 at init
        "D": jnp.ones((H,)),
        "dt_bias": jnp.zeros((H,)),
        "out_norm": jnp.ones((d_in,)),
        "out_proj": L.normal_init(ks[2], (d_in, d)),
    }


def mamba2_logical(cfg: ModelConfig):
    return {
        "norm": (None, "embed"),
        "in_proj": (None, "embed", "ff"),
        "conv_w": (None, None, "ff"),
        "A_log": (None, "ff"), "D": (None, "ff"), "dt_bias": (None, "ff"),
        "out_norm": (None, "ff"),
        "out_proj": (None, "ff", "embed"),
    }


def _causal_conv(u, w, conv_state=None):
    """Depthwise causal conv, width K. u: (B,T,C), w: (K,C).

    conv_state: (B, K-1, C) trailing inputs from the previous segment (decode).
    Returns (out, new_conv_state).
    """
    K = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = conv_state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)                       # (B, T+K-1, C)
    out = sum(full[:, i : i + u.shape[1]] * w[i][None, None, :] for i in range(K))
    new_state = full[:, -(K - 1):] if K > 1 else None
    return out, new_state


def mamba2_apply(cfg: ModelConfig, lp, x, lc, ctx):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    dt_f = x.dtype
    Bsz, T, _ = x.shape

    h = L.rms_norm(x, lp["norm"], cfg.norm_eps)
    proj = h @ lp["in_proj"].astype(dt_f)
    proj = constrain(proj, "batch", "seq", "ff")
    xz, z, Bm, Cm, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1
    )
    conv_in = jnp.concatenate([xz, Bm, Cm], axis=-1)
    conv_state = None if lc is None else lc["conv"]
    conv_out, new_conv = _causal_conv(conv_in, lp["conv_w"].astype(dt_f), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xz, Bm, Cm = jnp.split(conv_out, [d_in, d_in + N], axis=-1)

    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    xh = xz.reshape(Bsz, T, H, P).astype(jnp.float32)
    Bm32, Cm32 = Bm.astype(jnp.float32), Cm.astype(jnp.float32)

    if lc is None:
        state0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
        y, _ = _ssd_chunked(xh, dt, A, Bm32, Cm32, state0, min(cfg.ssm_chunk, T))
        new_cache = None
    else:
        y1, new_state = _ssd_step(xh[:, 0], dt[:, 0], A, Bm32[:, 0], Cm32[:, 0],
                                  lc["ssm"].astype(jnp.float32))
        y = y1[:, None]
        new_cache = {"ssm": new_state.astype(lc["ssm"].dtype), "conv": new_conv.astype(lc["conv"].dtype)}
    y = y + xh * lp["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, T, d_in).astype(dt_f)
    y = L.rms_norm(y * jax.nn.silu(z), lp["out_norm"], cfg.norm_eps)
    return x + y @ lp["out_proj"].astype(dt_f), new_cache


def mamba2_cache(cfg: ModelConfig, B, T, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    return {
        "ssm": jnp.zeros((B, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((B, cfg.ssm_conv - 1, d_in + 2 * cfg.ssm_state), dtype),
    }


def mamba2_cache_logical(cfg: ModelConfig):
    return {"ssm": ("batch", "ff", None, None), "conv": ("batch", None, "ff")}


register_block("ssm", BlockDef(init=mamba2_init, logical=mamba2_logical,
                               apply=mamba2_apply, init_cache=mamba2_cache,
                               cache_logical=mamba2_cache_logical))


# ===================================================================== RWKV6

def rwkv6_init(rng, cfg: ModelConfig):
    d = cfg.d_model
    ks = L.split_tree(rng, 8)
    return {
        "tm_norm": jnp.ones((d,)),
        "tm": {
            "mu_r": jnp.full((d,), 0.5), "mu_k": jnp.full((d,), 0.5),
            "mu_v": jnp.full((d,), 0.5), "mu_w": jnp.full((d,), 0.5),
            "mu_g": jnp.full((d,), 0.5),
            "wr": L.normal_init(ks[0], (d, d)), "wk": L.normal_init(ks[1], (d, d)),
            "wv": L.normal_init(ks[2], (d, d)), "wg": L.normal_init(ks[3], (d, d)),
            "w_decay": L.normal_init(ks[4], (d, d), std=0.01),   # data-dependent decay
            "decay_bias": jnp.full((d,), -6.0),
            "u_bonus": jnp.zeros((d,)),
            "wo": L.normal_init(ks[5], (d, d)),
            "ln_w": jnp.ones((d,)),
        },
        "cm_norm": jnp.ones((d,)),
        "cm": {
            "mu_k": jnp.full((d,), 0.5),
            "wk": L.normal_init(ks[6], (d, cfg.d_ff)),
            "wv": L.normal_init(ks[7], (cfg.d_ff, d)),
        },
    }


def rwkv6_logical(cfg: ModelConfig):
    dd = (None, "embed", "heads")
    return {
        "tm_norm": (None, "embed"),
        "tm": {
            "mu_r": (None, "embed"), "mu_k": (None, "embed"), "mu_v": (None, "embed"),
            "mu_w": (None, "embed"), "mu_g": (None, "embed"),
            "wr": dd, "wk": dd, "wv": dd, "wg": dd, "w_decay": dd,
            "decay_bias": (None, "heads"), "u_bonus": (None, "heads"),
            "wo": (None, "heads", "embed"), "ln_w": (None, "embed"),
        },
        "cm_norm": (None, "embed"),
        "cm": {"mu_k": (None, "embed"), "wk": (None, "embed", "ff"), "wv": (None, "ff", "embed")},
    }


def _token_shift(x, mu, last):
    """lerp between current token and previous token. last: (B,1,d) or None."""
    prev = jnp.concatenate([jnp.zeros_like(x[:, :1]) if last is None else last.astype(x.dtype),
                            x[:, :-1]], axis=1)
    return x + (prev - x) * mu.astype(x.dtype)


def _wkv6_chunked(r, k, v, w, u, state0, chunk):
    """Chunked WKV6. r/k/v: (B,T,H,P); w: per-step decay in (0,1) (B,T,H,P);
    u: (H,P) bonus; state0: (B,H,P,P) keyed [key_dim, value_dim]."""
    B, T, H, P = r.shape
    nc = T // chunk
    c = chunk
    rl, kl, vl, wl = (a.reshape(B, nc, c, H, P) for a in (r, k, v, w))
    logw = jnp.log(wl + 1e-38)
    seg = jnp.cumsum(logw, axis=2)                                 # (B,nc,c,H,P)

    # intra-chunk: y_i reads the state BEFORE step-i decay applies, so the decay of
    # kv_j at step i is prod_{m=j+1}^{i-1} w_m = exp((seg_i - logw_i) - seg_j), j < i
    esc = seg - logw                                               # exclusive cumsum
    diff = esc[:, :, :, None] - seg[:, :, None, :]                 # (B,nc,c,c,H,P)
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
    dec = jnp.where(mask[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    a = jnp.einsum("bnihp,bnijhp,bnjhp->bnijh", rl, dec, kl)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", a, vl)
    bonus = jnp.einsum("bnchp,hp,bnchp->bnch", rl, u, kl)
    y_intra = y_intra + bonus[..., None] * vl

    # chunk summary: S_chunk = sum_j decay(j->end) k_j v_j^T
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)                # (B,nc,c,H,P)
    S_chunk = jnp.einsum("bnchp,bnchq->bnhpq", kl * decay_to_end, vl)
    chunk_decay = jnp.exp(seg[:, :, -1])                           # (B,nc,H,P)

    def scan_fn(s, inp):
        s_c, dec_c = inp
        return s * dec_c[..., None] + s_c, s

    stateT, states_in = jax.lax.scan(
        scan_fn, state0, (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2, 3))
    )
    states_in = states_in.transpose(1, 0, 2, 3, 4)                 # (B,nc,H,P,P)
    decay_from_start = jnp.exp(seg - logw)                         # decay BEFORE applying step i
    y_inter = jnp.einsum("bnchp,bnhpq->bnchq", rl * decay_from_start, states_in)
    y = (y_intra + y_inter).reshape(B, T, H, P)
    return y, stateT


def _wkv6_step(r, k, v, w, u, state):
    """r/k/v/w: (B,H,P); state: (B,H,P,P)."""
    kv = jnp.einsum("bhp,bhq->bhpq", k, v)
    y = jnp.einsum("bhp,bhpq->bhq", r, state + u[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    return y, state


def rwkv6_apply(cfg: ModelConfig, lp, x, lc, ctx):
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads else d // 64
    P = d // H
    dt_f = x.dtype
    Bsz, T, _ = x.shape
    decode = lc is not None

    # ---- time mix -----------------------------------------------------------
    tm_h = L.rms_norm(x, lp["tm_norm"], cfg.norm_eps)
    tm = lp["tm"]
    last_x = lc["tm_shift"] if decode else None
    r = _token_shift(tm_h, tm["mu_r"], last_x) @ tm["wr"].astype(dt_f)
    k = _token_shift(tm_h, tm["mu_k"], last_x) @ tm["wk"].astype(dt_f)
    v = _token_shift(tm_h, tm["mu_v"], last_x) @ tm["wv"].astype(dt_f)
    g = _token_shift(tm_h, tm["mu_g"], last_x) @ tm["wg"].astype(dt_f)
    dw = _token_shift(tm_h, tm["mu_w"], last_x) @ tm["w_decay"].astype(dt_f)
    # data-dependent decay in (0,1):  w = exp(-exp(bias + dw))
    w = jnp.exp(-jnp.exp(tm["decay_bias"].astype(jnp.float32) + dw.astype(jnp.float32)))

    shp = (Bsz, T, H, P)
    r4, k4, v4, w4 = (a.astype(jnp.float32).reshape(shp) for a in (r, k, v, w))
    u4 = tm["u_bonus"].astype(jnp.float32).reshape(H, P)

    if not decode:
        state0 = jnp.zeros((Bsz, H, P, P), jnp.float32)
        y, _ = _wkv6_chunked(r4, k4, v4, w4, u4, state0, min(cfg.ssm_chunk, T))
        new_cache = None
    else:
        y1, new_state = _wkv6_step(r4[:, 0], k4[:, 0], v4[:, 0], w4[:, 0], u4,
                                   lc["wkv"].astype(jnp.float32))
        y = y1[:, None]
    y = y.reshape(Bsz, T, d).astype(dt_f)
    y = L.rms_norm(y, tm["ln_w"], cfg.norm_eps) * jax.nn.silu(g)
    x = x + y @ tm["wo"].astype(dt_f)

    # ---- channel mix ----------------------------------------------------------
    cm_h = L.rms_norm(x, lp["cm_norm"], cfg.norm_eps)
    cm = lp["cm"]
    last_c = lc["cm_shift"] if decode else None
    kc = _token_shift(cm_h, cm["mu_k"], last_c) @ cm["wk"].astype(dt_f)
    kc = constrain(kc, "batch", "seq", "ff")
    x = x + (jnp.square(jax.nn.relu(kc)) @ cm["wv"].astype(dt_f))

    if decode:
        new_cache = {
            "wkv": new_state.astype(lc["wkv"].dtype),
            "tm_shift": tm_h[:, -1:],   # next step's token-shift inputs
            "cm_shift": cm_h[:, -1:],
        }
        return x, new_cache
    return x, None


def rwkv6_cache(cfg: ModelConfig, B, T, dtype):
    d = cfg.d_model
    H = cfg.n_heads if cfg.n_heads else d // 64
    P = d // H
    return {
        "wkv": jnp.zeros((B, H, P, P), jnp.float32),
        "tm_shift": jnp.zeros((B, 1, d), dtype),
        "cm_shift": jnp.zeros((B, 1, d), dtype),
    }


def rwkv6_cache_logical(cfg: ModelConfig):
    # 40 heads don't divide the 16-way model axis; the recurrent state is tiny
    # (no sequence dim — RWKV's long-context selling point), so batch-shard only.
    return {"wkv": ("batch", None, None, None),
            "tm_shift": ("batch", None, "act_embed"), "cm_shift": ("batch", None, "act_embed")}


register_block("rwkv", BlockDef(init=rwkv6_init, logical=rwkv6_logical,
                                apply=rwkv6_apply, init_cache=rwkv6_cache,
                                cache_logical=rwkv6_cache_logical))
