"""Dense GQA transformer block (yi-34b, llama3.2-1b, qwen2.5-14b, mistral/llava)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.causal_lm import BlockDef, register_block


def init(rng, cfg: ModelConfig):
    ks = L.split_tree(rng, 2)
    return {
        "attn_norm": jnp.ones((cfg.d_model,)),
        "attn": L.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                           bias=cfg.qkv_bias),
        "mlp_norm": jnp.ones((cfg.d_model,)),
        "mlp": L.init_swiglu(ks[1], cfg.d_model, cfg.d_ff),
    }


def logical(cfg: ModelConfig):
    add_L = lambda t: jax.tree.map(lambda dims: (None,) + dims, t,
                                   is_leaf=lambda v: isinstance(v, tuple))
    return {
        "attn_norm": (None, "embed"),
        "attn": add_L(L.gqa_logical(bias=cfg.qkv_bias)),
        "mlp_norm": (None, "embed"),
        "mlp": add_L(L.swiglu_logical()),
    }


def apply(cfg: ModelConfig, lp, x, lc, ctx):
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    attn_out, new_cache = L.attention_block(
        lp["attn"], h, cfg=cfg, positions=ctx["positions"], cache=lc,
        pos=ctx["pos"], causal=True, q_offset=ctx["q_offset"],
    )
    x = x + attn_out
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.swiglu(lp["mlp"], h)
    return x, new_cache


def init_cache(cfg: ModelConfig, B, T, dtype):
    kv = (B, T, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}


def cache_logical(cfg: ModelConfig):
    dims = ("batch", "kv_seq", "kv_heads", None)
    return {"k": dims, "v": dims}


BLOCK = BlockDef(init=init, logical=logical, apply=apply,
                 init_cache=init_cache, cache_logical=cache_logical)
register_block("dense", BLOCK)
register_block("vlm", BLOCK)
