"""Mixture-of-Experts block (deepseek-moe-16b fine-grained, phi3.5-moe).

Dispatch is capacity-based scatter/gather (GSPMD-friendly, EP-shardable):

1. router top-k over E experts; normalized top-k gates;
2. per-(token,slot) position within its expert via a cumsum over a one-hot
   (tokens past capacity C = ceil(T*k/E * cf) are DROPPED — standard);
3. scatter-add into an (E, C, d) buffer, experts sharded over ``model`` (EP) —
   XLA lowers the resharding to an all-to-all;
4. batched SwiGLU over experts;
5. gather back and gate-combine.

DeepSeek's 2 always-on shared experts run as a dense SwiGLU of width
``n_shared * d_expert`` fused alongside.  The router load-balance auxiliary loss
(mean_e f_e * p_e * E) is returned through the scan's per-layer output channel and
added to the LM loss in train mode.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.causal_lm import BlockDef, register_block
from repro.models.sharding import constrain


def init(rng, cfg: ModelConfig):
    ks = L.split_tree(rng, 6)
    E, d, de = cfg.n_experts, cfg.d_model, cfg.d_expert
    p = {
        "attn_norm": jnp.ones((d,)),
        "attn": L.init_gqa(ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.hd, bias=cfg.qkv_bias),
        "mlp_norm": jnp.ones((d,)),
        "router": L.normal_init(ks[1], (d, E), std=0.02),
        "experts": {
            "wi": L.normal_init(ks[2], (E, d, de)),
            "wg": L.normal_init(ks[3], (E, d, de)),
            "wo": L.normal_init(ks[4], (E, de, d)),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_swiglu(ks[5], d, cfg.n_shared_experts * de)
    return p


def logical(cfg: ModelConfig):
    add_L = lambda t: jax.tree.map(lambda dm: (None,) + dm, t,
                                   is_leaf=lambda v: isinstance(v, tuple))
    p = {
        "attn_norm": (None, "embed"),
        "attn": add_L(L.gqa_logical(bias=cfg.qkv_bias)),
        "mlp_norm": (None, "embed"),
        "router": (None, "embed", None),
        "experts": {
            "wi": (None, "expert", "embed", None),
            "wg": (None, "expert", "embed", None),
            "wo": (None, "expert", None, "embed"),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = add_L(L.swiglu_logical())
    return p


def _n_groups(T: int) -> int:
    """Token groups for GROUPED dispatch (GShard-style): capacity is enforced per
    group, and the group dim shards over ``data`` so the sort/scatter stays local
    to a shard.  A global scatter into an (E, C, d) buffer is NOT GSPMD-shardable:
    measured on deepseek-moe train_4k it replicated the 32GB buffer and emitted a
    ~700GB/device all-reduce."""
    g = 256
    while g > 1 and T // g < 64:
        g //= 2
    return g


def capacity(cfg: ModelConfig, group_tokens: int) -> int:
    return max(4, int(math.ceil(group_tokens * cfg.top_k / cfg.n_experts
                                * cfg.capacity_factor)))


def moe_ffn(cfg: ModelConfig, p, x):
    """Grouped sort-based dispatch. x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    G = _n_groups(T)
    t = T // G                                                     # tokens per group
    dt = x.dtype
    xg = x.reshape(G, t, d)
    xg = constrain(xg, "batch", None, None)

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)     # (G, t, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                            # (G, t, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    C = capacity(cfg, t)
    e_flat = idx.reshape(G, t * k)                                 # token-major order

    def dispatch_one(e_row, x_row):
        """One group: sort slots by expert, position = rank within expert."""
        order = jnp.argsort(e_row, stable=True)                    # (t*k,)
        e_sorted = e_row[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_row].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(t * k, dtype=jnp.int32) - starts[e_sorted]
        keep = (pos < C)
        pos_c = jnp.minimum(pos, C - 1)
        tok = order // k                                           # source token
        vals = x_row[tok] * keep[:, None].astype(dt)
        buf = jnp.zeros((E, C, d), dt).at[e_sorted, pos_c].add(vals)
        return buf, (order, e_sorted, pos_c, keep, tok)

    buf, meta = jax.vmap(dispatch_one)(e_flat, xg)                 # (G, E, C, d)
    buf = constrain(buf, "batch", "expert", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["experts"]["wg"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", buf, p["experts"]["wi"].astype(dt))
    h = constrain(h, "batch", "expert", None, None)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["experts"]["wo"].astype(dt))
    out_buf = constrain(out_buf, "batch", "expert", None, None)

    def combine_one(ob, m, g_row):
        order, e_sorted, pos_c, keep, tok = m
        back = ob[e_sorted, pos_c] * keep[:, None].astype(dt)      # sorted slot order
        slot = order % k
        w = g_row[tok, slot].astype(dt)                            # (t*k,)
        return jnp.zeros((t, d), dt).at[tok].add(back * w[:, None])

    out = jax.vmap(combine_one)(out_buf, meta, gate)               # (G, t, d)
    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], x)
    return out, aux


def moe_ffn_shardmap(cfg: ModelConfig, p, x):
    """Explicit expert-parallel MoE via shard_map (beyond-paper §Perf change).

    The GSPMD gather/scatter across the model-sharded (E, C, d) buffer lowers to
    FULL-BUFFER all-reduces (measured 360 GiB/device on deepseek train_4k).  Here
    each model rank dispatches its data-shard's tokens to ITS OWN E/16 experts
    locally and contributes a partial (tokens, d) output; the only model-axis
    collective is the psum of that partial — the same locality lesson as the
    paper's halo exchange (neighbor-scope communication instead of global).
    Capacity is per data-shard (t_loc * k / E * cf).
    """
    from jax.sharding import PartitionSpec as P
    from repro.models.sharding import current_rules

    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    dt = x.dtype
    xf = x.reshape(T, d)
    logits = (xf @ p["router"].astype(dt)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(dt)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    rules = current_rules() or {}
    batch_axes = rules.get("batch", None)

    def body(xl, il, gl, wi, wg, wo):
        r = jax.lax.axis_index("model")
        E_loc, tl = wi.shape[0], xl.shape[0]
        C = capacity(cfg, tl)
        e_flat = il.reshape(tl * k)
        order = jnp.argsort(e_flat, stable=True)
        e_sorted = e_flat[order]
        counts = jnp.zeros((E,), jnp.int32).at[e_flat].add(1)
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(tl * k, dtype=jnp.int32) - starts[e_sorted]
        local_e = e_sorted - r * E_loc
        mine = ((local_e >= 0) & (local_e < E_loc) & (pos < C))
        le = jnp.clip(local_e, 0, E_loc - 1)
        pc = jnp.minimum(pos, C - 1)
        tok = order // k
        vals = xl[tok] * mine[:, None].astype(xl.dtype)
        buf = jnp.zeros((E_loc, C, d), xl.dtype).at[le, pc].add(vals)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wi)
        ob = jnp.einsum("ecf,efd->ecd", h, wo)
        back = ob[le, pc] * mine[:, None].astype(xl.dtype)
        w = gl[tok, order % k]
        part = jnp.zeros((tl, d), xl.dtype).at[tok].add(back * w[:, None])
        return jax.lax.psum(part, "model")

    tok_spec = P(batch_axes, None)
    w_spec = P("model", None, None)
    from repro.utils import shard_map as _shard_map

    out = _shard_map(
        body,
        in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec, w_spec),
        out_specs=tok_spec,
        check_vma=False,
    )(xf, idx, gate, p["experts"]["wi"].astype(dt), p["experts"]["wg"].astype(dt),
      p["experts"]["wo"].astype(dt))
    out = out.reshape(B, S, d)
    if cfg.n_shared_experts:
        out = out + L.swiglu(p["shared"], x)
    return out, aux


def apply(cfg: ModelConfig, lp, x, lc, ctx):
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    attn_out, new_cache = L.attention_block(
        lp["attn"], h, cfg=cfg, positions=ctx["positions"], cache=lc,
        pos=ctx["pos"], causal=True, q_offset=ctx["q_offset"],
    )
    x = x + attn_out
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    impl = moe_ffn_shardmap if getattr(cfg, "moe_shard_map", False) else moe_ffn
    ff, aux = impl(cfg, lp, h)
    x = x + ff
    if new_cache is None:
        # train mode: route the per-layer aux loss out through the scan's y channel
        return x, {"aux": aux}
    return x, new_cache


def init_cache(cfg: ModelConfig, B, T, dtype):
    kv = (B, T, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}


def cache_logical(cfg: ModelConfig):
    dims = ("batch", "kv_seq", "kv_heads", None)
    return {"k": dims, "v": dims}


register_block("moe", BlockDef(init=init, logical=logical, apply=apply,
                               init_cache=init_cache, cache_logical=cache_logical))
