"""Common transformer layers for the model zoo (pure functions over param dicts).

Conventions
-----------
* Params are nested dicts of fp32 arrays (master weights); compute is bf16
  (``cfg.dtype``), cast at use.  Layer stacks are STACKED on a leading ``L`` axis
  and driven by ``lax.scan`` (small HLO -> fast 256-device GSPMD compiles) with
  ``jax.checkpoint`` remat per layer.
* Every init function has a twin ``*_logical`` returning the same tree with tuples
  of LOGICAL axis names; ``models.sharding`` maps them to PartitionSpecs.
* Attention is CHUNKED over query blocks (lax.scan + online max-free softmax per
  block) so 32k-token prefill never materializes an S x T score tensor.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.sharding import constrain

# Dry-run measurement mode: XLA's cost_analysis counts while-loop bodies ONCE, so
# scanned graphs under-report FLOPs by the trip count.  Setting unroll mode makes
# every structural scan (layer stack, attention q-blocks, fused-CE chunks) fully
# unroll so the compiled HLO carries the true op counts.  Execution semantics are
# identical; compile time grows, which is why it is opt-in (launch/dryrun.py).
_UNROLL_SCANS = False


def set_unroll_scans(v: bool):
    global _UNROLL_SCANS
    _UNROLL_SCANS = bool(v)


def _unroll(n: int) -> int:
    return n if _UNROLL_SCANS else 1

# ------------------------------------------------------------------------- init

def normal_init(rng, shape, std=0.02, dtype=jnp.float32):
    return jax.random.normal(rng, shape, dtype) * std


def split_tree(rng, n):
    return list(jax.random.split(rng, n))


# ------------------------------------------------------------------------ norms

def rms_norm(x, w, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + 0.0) * w.astype(jnp.float32)).astype(dt)


def layer_norm(x, w, b, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------------- rope

def rope_freqs(head_dim: int, theta: float = 1e4):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x, positions, theta=1e4):
    """x: (B, S, H, dh); positions: (B, S) or (S,)"""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta))            # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,dh/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[:, :, None, :].astype(x.dtype)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1, o2 = x1 * cos - x2 * sin, x2 * cos + x1 * sin
    return jnp.stack([o1, o2], axis=-1).reshape(x.shape)


# -------------------------------------------------------------------- attention

def chunked_attention(q, k, v, *, causal=True, q_offset=0, block_q=512, kv_len=None,
                      causal_skip=False):
    """GQA attention without materializing the full (S, T) score tensor.

    q: (B, S, H, dh); k/v: (B, T, Hk, dh), H % Hk == 0.
    q_offset: absolute position of q[0] (causal masking for prefill chunks).
    kv_len: optional (B,) valid cache lengths (decode); None -> all T valid.
    causal_skip: python-loop the q blocks and slice k/v to the causal extent
      (i+1)*bq per block — true triangular FLOPs (~2x fewer score/softmax ops at
      long S), at the cost of a larger per-layer HLO (no scan).  This is the XLA
      analogue of the flash-attention kernel's diagonal block skipping.
    """
    B, S, H, dh = q.shape
    T, Hk = k.shape[1], k.shape[2]
    G = H // Hk
    scale = 1.0 / np.sqrt(dh)
    qg = q.reshape(B, S, Hk, G, dh)
    bq = min(block_q, S)
    n_blocks = (S + bq - 1) // bq
    pad = n_blocks * bq - S
    if pad:
        qg = jnp.pad(qg, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    qg = qg.reshape(B, n_blocks, bq, Hk, G, dh).transpose(1, 0, 2, 3, 4, 5)
    t_idx = jnp.arange(T)

    def one_block(i, qi):  # qi: (B, bq, Hk, G, dh) -> scores (B, Hk, G, bq, T)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        if causal:
            q_pos = q_offset + i * bq + jnp.arange(bq)
            cmask = t_idx[None, :] <= q_pos[:, None]            # (bq, T)
            s = jnp.where(cmask[None, None, None], s, -1e30)
        if kv_len is not None:
            valid = t_idx[None, :] < kv_len[:, None]            # (B, T)
            s = jnp.where(valid[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        # cast per-block outputs to the compute dtype BEFORE stacking across
        # q-blocks: the fp32 stacked buffer costs ~2GB/layer at yi-34b train_4k
        return jnp.einsum("bkgqt,btkd->bqkgd", p,
                          v.astype(jnp.float32)).astype(v.dtype)

    dv = v.shape[-1]  # v head dim may differ from qk head dim (MLA)
    if n_blocks == 1:
        out = one_block(0, qg[0])[None]
    elif causal_skip and causal and q_offset == 0 and kv_len is None:
        # BUCKETED causal skip: 4 buckets of q blocks, bucket i attends only
        # k/v[: (i+1) * T/4] (static slice).  Within a bucket the blocks run under
        # lax.scan, so liveness stays one-block-deep (the fully per-block python
        # loop saved 50% FLOPs but blew per-device HBM 3->27GiB on minicpm3
        # prefill_32k; 4 buckets keep ~37.5% of the saving at scan liveness).
        n_buckets = min(4, n_blocks)
        per = n_blocks // n_buckets
        outs = []
        for bi in range(n_buckets):
            lo, hi = bi * per, (n_blocks if bi == n_buckets - 1 else (bi + 1) * per)
            end = min(T, hi * bq)
            kb, vb = k[:, :end], v[:, :end]
            tb_idx = jnp.arange(end)

            def bucket_block(i, qi, kb=kb, vb=vb, tb_idx=tb_idx):
                sb = jnp.einsum("bqkgd,btkd->bkgqt", qi.astype(jnp.float32),
                                kb.astype(jnp.float32)) * scale
                q_pos = i * bq + jnp.arange(bq)
                cm = tb_idx[None, :] <= q_pos[:, None]
                sb = jnp.where(cm[None, None, None], sb, -1e30)
                pb = jax.nn.softmax(sb, axis=-1)
                return jnp.einsum("bkgqt,btkd->bqkgd", pb,
                                  vb.astype(jnp.float32)).astype(vb.dtype)

            if hi - lo == 1:
                outs.append(bucket_block(lo, qg[lo])[None])
            else:
                _, ob = jax.lax.scan(
                    lambda c, args: (c, bucket_block(args[0], args[1])),
                    None, (jnp.arange(lo, hi), qg[lo:hi]),
                    unroll=_unroll(hi - lo))
                outs.append(ob)
        out = jnp.concatenate(outs, axis=0)
    else:
        _, out = jax.lax.scan(
            lambda c, args: (c, one_block(args[0], args[1])),
            None, (jnp.arange(n_blocks), qg), unroll=_unroll(n_blocks))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, n_blocks * bq, Hk, G, dv)
    out = out[:, :S].reshape(B, S, H, dv)
    return out.astype(q.dtype)  # block outputs already in compute dtype


def _skip_block(qi, k, v, row0, bq, scale):
    """One q block against the causally-reachable k/v prefix only."""
    Tl = k.shape[1]
    s = jnp.einsum("bqkgd,btkd->bkgqt", qi.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    cmask = jnp.arange(Tl)[None, :] <= (row0 + jnp.arange(bq))[:, None]
    s = jnp.where(cmask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32)).astype(v.dtype)


def decode_attention(q, k, v, pos):
    """Single-position attention against a full cache. q: (B,1,H,dh), pos: (B,)"""
    return chunked_attention(q, k, v, causal=False, kv_len=pos + 1, block_q=1)


def init_gqa(rng, d_model, n_heads, n_kv, head_dim, bias=False, std=0.02):
    ks = split_tree(rng, 4)
    p = {
        "wq": normal_init(ks[0], (d_model, n_heads * head_dim), std),
        "wk": normal_init(ks[1], (d_model, n_kv * head_dim), std),
        "wv": normal_init(ks[2], (d_model, n_kv * head_dim), std),
        "wo": normal_init(ks[3], (n_heads * head_dim, d_model), std),
    }
    if bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,))
        p["bk"] = jnp.zeros((n_kv * head_dim,))
        p["bv"] = jnp.zeros((n_kv * head_dim,))
    return p


def gqa_logical(bias=False):
    p = {
        "wq": ("embed", "heads"), "wk": ("embed", "heads"), "wv": ("embed", "heads"),
        "wo": ("heads", "embed"),
    }
    if bias:
        p.update({"bq": ("heads",), "bk": ("heads",), "bv": ("heads",)})
    return p


def gqa_project(p, x, n_heads, n_kv, head_dim, dtype):
    B, S, _ = x.shape
    q = x @ p["wq"].astype(dtype)
    k = x @ p["wk"].astype(dtype)
    v = x @ p["wv"].astype(dtype)
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dtype), k + p["bk"].astype(dtype), v + p["bv"].astype(dtype)
    q = constrain(q.reshape(B, S, n_heads, head_dim), "batch", "seq", "heads", None)
    # k/v head layouts are left to GSPMD propagation: with Hk < model-axis size an
    # explicit kv_heads constraint forces padded 16-way sharding and involuntary
    # full rematerialization in the backward pass (measured: +30GB temp).
    k = k.reshape(B, S, n_kv, head_dim)
    v = v.reshape(B, S, n_kv, head_dim)
    return q, k, v


def attention_block(p, x, *, cfg, positions, cache=None, pos=None, causal=True,
                    q_offset=0):
    """Self-attention with optional KV cache. Returns (out, new_cache)."""
    dtype = x.dtype
    q, k, v = gqa_project(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.hd, dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cache is None:
        out = chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                block_q=cfg.attn_block_q,
                                causal_skip=getattr(cfg, "attn_causal_skip", False))
        new_cache = None
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1) \
            if k.shape[1] == 1 else _scatter_prefill(cache["k"], k)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1) \
            if v.shape[1] == 1 else _scatter_prefill(cache["v"], v)
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}
        kv_len = jnp.full((x.shape[0],), pos + 1, jnp.int32)
        out = decode_attention(q, ck.astype(dtype), cv.astype(dtype), kv_len - 1)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"].astype(dtype), new_cache


def _scatter_prefill(cache, fresh):
    return jax.lax.dynamic_update_slice_in_dim(
        cache, fresh.astype(cache.dtype), 0, axis=1
    )


# ------------------------------------------------------------------------ MLPs

def init_swiglu(rng, d_model, d_ff, std=0.02):
    ks = split_tree(rng, 3)
    return {
        "wi": normal_init(ks[0], (d_model, d_ff), std),
        "wg": normal_init(ks[1], (d_model, d_ff), std),
        "wo": normal_init(ks[2], (d_ff, d_model), std),
    }


def swiglu_logical():
    return {"wi": ("embed", "ff"), "wg": ("embed", "ff"), "wo": ("ff", "embed")}


def swiglu(p, x):
    dt = x.dtype
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["wo"].astype(dt)


def init_gelu_mlp(rng, d_model, d_ff, std=0.02):
    ks = split_tree(rng, 2)
    return {
        "wi": normal_init(ks[0], (d_model, d_ff), std),
        "bi": jnp.zeros((d_ff,)),
        "wo": normal_init(ks[1], (d_ff, d_model), std),
        "bo": jnp.zeros((d_model,)),
    }


def gelu_mlp_logical():
    return {"wi": ("embed", "ff"), "bi": ("ff",), "wo": ("ff", "embed"), "bo": ("embed",)}


def gelu_mlp(p, x):
    dt = x.dtype
    h = jax.nn.gelu(x @ p["wi"].astype(dt) + p["bi"].astype(dt))
    h = constrain(h, "batch", "seq", "ff")
    return h @ p["wo"].astype(dt) + p["bo"].astype(dt)


# ----------------------------------------------------------------- vocab layers

def init_embedding(rng, vocab, d_model, std=0.02):
    return {"table": normal_init(rng, (vocab, d_model), std)}


def embedding_logical():
    return {"table": ("vocab", "embed")}


def embed(p, tokens, dtype):
    out = jnp.take(p["table"].astype(dtype), tokens, axis=0)
    return constrain(out, "batch", "seq", "act_embed")


def _mask_padded_vocab(logits, n_valid):
    if n_valid is None or n_valid == logits.shape[-1]:
        return logits
    bad = jnp.arange(logits.shape[-1]) >= n_valid
    return jnp.where(bad, jnp.asarray(-1e30, logits.dtype), logits)


def unembed(p, x, n_valid=None):
    logits = x @ p["table"].astype(x.dtype).T
    return _mask_padded_vocab(constrain(logits, "batch", "seq", "vocab"), n_valid)


def init_lm_head(rng, d_model, vocab, std=0.02):
    return {"w": normal_init(rng, (d_model, vocab), std)}


def lm_head_logical():
    return {"w": ("embed", "vocab")}


def lm_head(p, x, n_valid=None):
    logits = constrain(x @ p["w"].astype(x.dtype), "batch", "seq", "vocab")
    return _mask_padded_vocab(logits, n_valid)


def cross_entropy(logits, labels, mask=None):
    """Mean token NLL; logits fp32 for stability."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def fused_head_cross_entropy(x, w, labels, mask=None, chunk=512, transpose_w=False,
                             n_valid=None):
    """LM head + softmax-xent, CHUNKED over the sequence so the full fp32
    (B, S, V) logits tensor is never materialized (the single biggest training
    activation: ~4GB/device at 4k x 128k-vocab).  Each chunk's projection+CE is
    wrapped in jax.checkpoint -> the backward recomputes one chunk at a time.

    x: (B, S, D); w: (D, V) head weight (or (V, D) tied table, transpose_w=True).
    Returns mean NLL over mask.
    """
    B, S, D = x.shape
    ck = min(chunk, S)
    n_chunks = (S + ck - 1) // ck
    pad = n_chunks * ck - S
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    mask = mask.astype(jnp.float32)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xc = x.reshape(B, n_chunks, ck, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, ck).transpose(1, 0, 2)
    mc = mask.reshape(B, n_chunks, ck).transpose(1, 0, 2)

    @jax.checkpoint
    def one(xi, li, mi):
        wt = w.astype(xi.dtype)
        logits = (xi @ wt.T) if transpose_w else (xi @ wt)
        logits = constrain(logits, "batch", None, "vocab").astype(jnp.float32)
        logits = _mask_padded_vocab(logits, n_valid)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - ll) * mi)

    def body(carry, inp):
        return carry + one(*inp), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc, mc),
                            unroll=_unroll(n_chunks))
    return total / jnp.maximum(jnp.sum(mask), 1.0)


# -------------------------------------------------------------- layer-stack scan

def scan_layers(block_fn, stacked_params, x, cache=None, remat=True, policy="full"):
    """Run x through L stacked layers; threads per-layer cache through the scan.

    block_fn(layer_params, x, layer_cache) -> (x, new_layer_cache)
    policy: "full" re-materializes everything in the backward (only the per-layer
    carries survive — the right default for 16GB v5e); "dots" keeps matmul outputs
    (dots_with_no_batch_dims_saveable) trading HBM for recompute FLOPs.
    """
    fn = block_fn
    if remat:
        pol = None if policy in (None, "full") else \
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        fn = jax.checkpoint(block_fn, policy=pol)

    def step(h, inp):
        lp, lc = inp
        h, nc = fn(lp, h, lc)
        return h, nc

    n_layers = jax.tree.leaves(stacked_params)[0].shape[0]
    x, new_cache = jax.lax.scan(step, x, (stacked_params, cache),
                                unroll=_unroll(n_layers))
    return x, new_cache


def stack_init(layer_init, rng, n_layers, *args, **kw):
    """vmap a per-layer initializer into stacked (L, ...) params."""
    return jax.vmap(lambda k: layer_init(k, *args, **kw))(jax.random.split(rng, n_layers))
