"""Multi-head Latent Attention block (minicpm3-4b; DeepSeek-V2-style MLA).

Train/prefill run the EXPANDED form (latents up-projected to per-head K/V).
Decode runs the ABSORBED form: the cache stores only the compressed latents
``c_kv (B,T,kv_lora)`` + shared rope key ``k_r (B,T,rope_dim)``; query up-projections
are absorbed into the score/value einsums, so decode attention is MQA-like over an
effective head dim of ``kv_lora + rope_dim``.  This is MLA's deployment-time win —
the 32k decode cell's cache is ~10x smaller than GQA's — and the dry-run roofline
shows it (see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.causal_lm import BlockDef, register_block
from repro.models.sharding import constrain


def init(rng, cfg: ModelConfig):
    ks = L.split_tree(rng, 8)
    H, qk = cfg.n_heads, cfg.nope_dim + cfg.rope_dim
    return {
        "attn_norm": jnp.ones((cfg.d_model,)),
        "attn": {
            "wdq": L.normal_init(ks[0], (cfg.d_model, cfg.q_lora)),
            "q_norm": jnp.ones((cfg.q_lora,)),
            "wuq": L.normal_init(ks[1], (cfg.q_lora, H * qk)),
            "wdkv": L.normal_init(ks[2], (cfg.d_model, cfg.kv_lora)),
            "kv_norm": jnp.ones((cfg.kv_lora,)),
            "wkr": L.normal_init(ks[3], (cfg.d_model, cfg.rope_dim)),
            "wuk": L.normal_init(ks[4], (cfg.kv_lora, H * cfg.nope_dim)),
            "wuv": L.normal_init(ks[5], (cfg.kv_lora, H * cfg.v_head_dim)),
            "wo": L.normal_init(ks[6], (H * cfg.v_head_dim, cfg.d_model)),
        },
        "mlp_norm": jnp.ones((cfg.d_model,)),
        "mlp": L.init_swiglu(ks[7], cfg.d_model, cfg.d_ff),
    }


def logical(cfg: ModelConfig):
    add_L = lambda t: jax.tree.map(lambda d: (None,) + d, t,
                                   is_leaf=lambda v: isinstance(v, tuple))
    return {
        "attn_norm": (None, "embed"),
        "attn": add_L({
            "wdq": ("embed", None), "q_norm": (None,), "wuq": (None, "heads"),
            "wdkv": ("embed", None), "kv_norm": (None,), "wkr": ("embed", None),
            "wuk": (None, "heads"), "wuv": (None, "heads"), "wo": ("heads", "embed"),
        }),
        "mlp_norm": (None, "embed"),
        "mlp": add_L(L.swiglu_logical()),
    }


def _project_q(p, x, cfg, dtype, positions):
    B, S, _ = x.shape
    H, qk = cfg.n_heads, cfg.nope_dim + cfg.rope_dim
    cq = L.rms_norm(x @ p["wdq"].astype(dtype), p["q_norm"], cfg.norm_eps)
    q = (cq @ p["wuq"].astype(dtype)).reshape(B, S, H, qk)
    q = constrain(q, "batch", "seq", "heads", None)
    q_nope, q_rope = q[..., : cfg.nope_dim], q[..., cfg.nope_dim:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, x, cfg, dtype, positions):
    ckv = L.rms_norm(x @ p["wdkv"].astype(dtype), p["kv_norm"], cfg.norm_eps)
    kr = (x @ p["wkr"].astype(dtype))[:, :, None, :]            # (B,S,1,rope)
    kr = L.apply_rope(kr, positions, cfg.rope_theta)
    return ckv, kr[:, :, 0, :]


def _expanded_attention(p, x, cfg, dtype, positions, q_offset):
    """Train/prefill path: latents up-projected, standard causal attention."""
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _project_q(p, x, cfg, dtype, positions)
    ckv, kr = _latents(p, x, cfg, dtype, positions)
    k_nope = (ckv @ p["wuk"].astype(dtype)).reshape(B, S, H, cfg.nope_dim)
    v = (ckv @ p["wuv"].astype(dtype)).reshape(B, S, H, cfg.v_head_dim)
    k_rope = jnp.broadcast_to(kr[:, :, None, :], (B, S, H, cfg.rope_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    out = L.chunked_attention(q, k, v, causal=True, q_offset=q_offset,
                              block_q=cfg.attn_block_q,
                              causal_skip=cfg.attn_causal_skip)
    return out.reshape(B, S, H * cfg.v_head_dim) @ p["wo"].astype(dtype)


def _absorbed_decode(p, x, cfg, dtype, positions, cache, pos):
    """Decode path: attention directly against compressed latents."""
    B, S, _ = x.shape  # S == 1
    H = cfg.n_heads
    q_nope, q_rope = _project_q(p, x, cfg, dtype, positions)
    ckv_new, kr_new = _latents(p, x, cfg, dtype, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new.astype(cache["kr"].dtype), pos, axis=1)
    ckv = constrain(ckv, "batch", "kv_seq", None)
    kr = constrain(kr, "batch", "kv_seq", None)
    new_cache = {"ckv": ckv, "kr": kr}

    wuk = p["wuk"].astype(dtype).reshape(cfg.kv_lora, H, cfg.nope_dim)
    wuv = p["wuv"].astype(dtype).reshape(cfg.kv_lora, H, cfg.v_head_dim)
    q_c = jnp.einsum("bqhn,chn->bqhc", q_nope, wuk)             # absorb W_uk
    scale = 1.0 / np.sqrt(cfg.nope_dim + cfg.rope_dim)
    s = (jnp.einsum("bqhc,btc->bhqt", q_c.astype(jnp.float32), ckv.astype(jnp.float32))
         + jnp.einsum("bqhr,btr->bhqt", q_rope.astype(jnp.float32), kr.astype(jnp.float32))) * scale
    t_idx = jnp.arange(ckv.shape[1])
    s = jnp.where((t_idx <= pos)[None, None, None, :], s, -1e30)
    prob = jax.nn.softmax(s, axis=-1)
    ctx_c = jnp.einsum("bhqt,btc->bqhc", prob, ckv.astype(jnp.float32))
    out = jnp.einsum("bqhc,chv->bqhv", ctx_c, wuv.astype(jnp.float32)).astype(dtype)
    return out.reshape(B, S, H * cfg.v_head_dim) @ p["wo"].astype(dtype), new_cache


def apply(cfg: ModelConfig, lp, x, lc, ctx):
    dtype = x.dtype
    h = L.rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    if lc is None:
        attn_out = _expanded_attention(lp["attn"], h, cfg, dtype, ctx["positions"], ctx["q_offset"])
        new_cache = None
    else:
        attn_out, new_cache = _absorbed_decode(lp["attn"], h, cfg, dtype, ctx["positions"], lc, ctx["pos"])
    x = x + attn_out
    h = L.rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    x = x + L.swiglu(lp["mlp"], h)
    return x, new_cache


def init_cache(cfg: ModelConfig, B, T, dtype):
    return {
        "ckv": jnp.zeros((B, T, cfg.kv_lora), dtype),
        "kr": jnp.zeros((B, T, cfg.rope_dim), dtype),
    }


def cache_logical(cfg: ModelConfig):
    return {"ckv": ("batch", "kv_seq", None), "kr": ("batch", "kv_seq", None)}


register_block("mla", BlockDef(init=init, logical=logical, apply=apply,
                               init_cache=init_cache, cache_logical=cache_logical))
