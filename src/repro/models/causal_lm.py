"""Decoder-only LM assembly, generic over per-family block definitions.

A family registers a :class:`BlockDef` (per-layer init / logical axes / apply /
cache builders).  The assembly provides: embedding, scan-over-layers with remat,
final norm + LM head, the three lowered entry points (``train_step`` loss,
``prefill``, ``decode_step``), cache construction, and PartitionSpec trees.

The VLM family (`llava-next-mistral-7b`) reuses the dense block; its stub
frontend contributes precomputed patch embeddings that are projected and
prepended to the token embeddings (anyres tiling is upstream of the backbone and
out of scope per the assignment).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.sharding import constrain, specs_from_logical


@dataclass(frozen=True)
class BlockDef:
    init: Callable          # (rng, cfg) -> layer params
    logical: Callable       # (cfg) -> logical tree
    apply: Callable         # (cfg, lp, x, lc, ctx) -> (y, new_lc)
    init_cache: Callable | None = None   # (cfg, B, T, dtype) -> per-layer cache
    cache_logical: Callable | None = None

BLOCKS: dict[str, BlockDef] = {}


def register_block(family: str, block: BlockDef):
    BLOCKS[family] = block


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


class CausalLM:
    """Pure-function model bundle for one config."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.block = BLOCKS[cfg.family]
        # leading dense layers outside the homogeneous stack (deepseek-moe)
        self.prelude = BLOCKS["dense"] if cfg.first_dense else None
        self._n_main = cfg.n_layers - cfg.first_dense

    def _prelude_cfg(self) -> ModelConfig:
        import dataclasses
        d_ff = getattr(self.cfg, "d_ff_dense", 0) or self.cfg.d_ff
        return dataclasses.replace(self.cfg, family="dense", d_ff=d_ff)

    # ------------------------------------------------------------------ params
    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = L.split_tree(rng, 6)
        p = {
            "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
            "layers": L.stack_init(lambda k: self.block.init(k, cfg), ks[1], self._n_main),
            "final_norm": jnp.ones((cfg.d_model,)),
        }
        if self.prelude:
            pc = self._prelude_cfg()
            p["prelude"] = L.stack_init(lambda k: self.prelude.init(k, pc), ks[4], cfg.first_dense)
        if not cfg.tie_embeddings:
            p["head"] = L.init_lm_head(ks[2], cfg.d_model, cfg.padded_vocab)
        if cfg.family == "vlm":
            p["vis_proj"] = {
                "w": L.normal_init(ks[3], (cfg.patch_dim, cfg.d_model)),
                "b": jnp.zeros((cfg.d_model,)),
            }
        return p

    def logical(self) -> dict:
        cfg = self.cfg
        t = {
            "embed": L.embedding_logical(),
            "layers": self.block.logical(cfg),
            "final_norm": ("embed",),
        }
        if self.prelude:
            t["prelude"] = self.prelude.logical(self._prelude_cfg())
        if not cfg.tie_embeddings:
            t["head"] = L.lm_head_logical()
        if cfg.family == "vlm":
            t["vis_proj"] = {"w": (None, "embed"), "b": ("embed",)}
        return t

    def param_specs(self, rules):
        return specs_from_logical(self.logical(), rules)

    # ------------------------------------------------------------------- cache
    def _stacked_cache(self, block, cfg, n_layers, B, T, as_struct):
        one = jax.eval_shape(lambda: block.init_cache(cfg, B, T, _dtype(cfg)))
        if as_struct:
            return jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_layers,) + s.shape, s.dtype), one
            )
        return jax.tree.map(lambda s: jnp.zeros((n_layers,) + s.shape, s.dtype), one)

    def _cache(self, B, T, as_struct):
        cfg = self.cfg
        if self.block.init_cache is None:
            return None
        main = self._stacked_cache(self.block, cfg, self._n_main, B, T, as_struct)
        if not self.prelude:
            return main
        pre = self._stacked_cache(self.prelude, self._prelude_cfg(), cfg.first_dense, B, T, as_struct)
        return {"prelude": pre, "layers": main}

    def init_cache(self, batch_size: int, seq_len: int):
        return self._cache(batch_size, seq_len, as_struct=False)

    def cache_struct(self, batch_size: int, seq_len: int):
        """ShapeDtypeStructs for the dry-run (no allocation)."""
        return self._cache(batch_size, seq_len, as_struct=True)

    def cache_specs(self, rules):
        if self.block.cache_logical is None:
            return None
        add_L = lambda t: jax.tree.map(lambda dims: (None,) + dims, t,
                                       is_leaf=lambda v: isinstance(v, tuple))
        main = specs_from_logical(add_L(self.block.cache_logical(self.cfg)), rules)
        if not self.prelude:
            return main
        pre = specs_from_logical(add_L(self.prelude.cache_logical(self._prelude_cfg())), rules)
        return {"prelude": pre, "layers": main}

    # ----------------------------------------------------------------- forward
    def _embed_inputs(self, params, batch, dtype):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], dtype)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(dtype)
            pe = pe @ params["vis_proj"]["w"].astype(dtype) + params["vis_proj"]["b"].astype(dtype)
            pe = constrain(pe, "batch", "seq", "act_embed")
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def _hidden(self, params, batch, cache=None, pos=None):
        """Backbone up to (and including) the final norm. Returns (x, new_cache|ys)."""
        cfg = self.cfg
        dtype = _dtype(cfg)
        x = self._embed_inputs(params, batch, dtype)
        B, S = x.shape[:2]
        if pos is None:
            positions = jnp.arange(S)[None, :]
        else:
            positions = jnp.full((B, 1), pos, jnp.int32)
        ctx = dict(positions=positions, pos=pos, q_offset=0,
                   mode="decode" if pos is not None else "full")

        main_cache, pre_cache = cache, None
        if self.prelude and cache is not None:
            pre_cache, main_cache = cache["prelude"], cache["layers"]

        new_pre = None
        if self.prelude:
            pc = self._prelude_cfg()
            pre_fn = lambda lp, h, lc: self.prelude.apply(pc, lp, h, lc, ctx)
            x, new_pre = L.scan_layers(pre_fn, params["prelude"], x, pre_cache,
                                       remat=cfg.remat, policy=cfg.remat_policy)

        def block_fn(lp, h, lc):
            # residual-stream carry sharding: under the "res_seq"->model rule the
            # saved per-layer remat carries shard along sequence (Korthikanti-style
            # sequence parallelism); XLA inserts the gather/scatter pairs.
            h = constrain(h, "batch", "res_seq", "act_embed")
            h, nc = self.block.apply(cfg, lp, h, lc, ctx)
            return constrain(h, "batch", "res_seq", "act_embed"), nc

        x, new_main = L.scan_layers(block_fn, params["layers"], x, main_cache,
                                    remat=cfg.remat, policy=cfg.remat_policy)
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if self.prelude and cache is not None:
            return x, {"prelude": new_pre, "layers": new_main}
        return x, new_main

    def _head_weight(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"]["table"], True
        return params["head"]["w"], False

    def forward(self, params, batch, cache=None, pos=None):
        """batch: {"tokens": (B,S) [, "patch_embeds": (B,P,pd)]}.

        cache/pos given  -> decode mode (S==1), returns (logits, new_cache)
        cache/pos absent -> full causal forward, returns (logits, None)
        """
        x, nc = self._hidden(params, batch, cache, pos)
        nv = self.cfg.vocab if self.cfg.padded_vocab != self.cfg.vocab else None
        if self.cfg.tie_embeddings:
            logits = L.unembed(params["embed"], x, nv)
        else:
            logits = L.lm_head(params["head"], x, nv)
        return logits, nc

    # ------------------------------------------------------------ entry points
    def loss(self, params, batch):
        """Teacher-forced next-token loss via the CHUNKED fused head+CE (the full
        fp32 logits tensor is never materialized). batch: tokens+labels (B,S)."""
        cfg = self.cfg
        x, ys = self._hidden(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm" and "patch_embeds" in batch:
            # patch positions carry no next-token targets
            P = batch["patch_embeds"].shape[1]
            x = x[:, P:]
        w, tied = self._head_weight(params)
        loss = L.fused_head_cross_entropy(
            x, w, labels, batch.get("loss_mask"), transpose_w=tied,
            n_valid=cfg.vocab if cfg.padded_vocab != cfg.vocab else None)
        if isinstance(ys, dict) and "aux" in ys:  # MoE load-balance loss
            loss = loss + 0.01 * jnp.mean(ys["aux"])
        return loss

    def prefill(self, params, batch):
        logits, _ = self.forward(params, batch)
        return logits

    def decode_step(self, params, cache, batch, pos):
        """One-token step against a pre-existing cache. tokens: (B,1)."""
        logits, new_cache = self.forward(params, batch, cache=cache, pos=pos)
        return logits, new_cache
