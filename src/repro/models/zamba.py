"""Zamba2 hybrid backbone: Mamba2 stacks with ONE SHARED attention block applied
every ``attn_every`` layers (zamba2-1.2b: 38 Mamba2 blocks, shared attn every 6).

The layer stack is therefore staged: ``n_stages = n_layers // attn_every`` scanned
Mamba2 groups, a shared-parameter attention block after each, and a scanned tail of
``n_layers % attn_every`` Mamba2 blocks.  Each shared-attn APPLICATION has its own
KV cache slot (same weights, different keys/values — that is Zamba's trick for
attention quality at SSM cost).  Sub-quadratic overall -> runs ``long_500k`` with
the cache sequence dim sharded over ``data`` (DESIGN.md §5's XPINN time-interface
analogue).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dense as dense_mod
from repro.models import layers as L
from repro.models import ssm as ssm_mod
from repro.models.causal_lm import CausalLM, _dtype
from repro.models.sharding import constrain, specs_from_logical


class Zamba2Model(CausalLM):
    def __init__(self, cfg: ModelConfig):
        # bypass CausalLM.__init__ block lookup; we compose blocks manually
        self.cfg = cfg
        self.block = None
        self.prelude = None
        self.n_stages = cfg.n_layers // cfg.attn_every
        self.tail = cfg.n_layers % cfg.attn_every

    # ------------------------------------------------------------------ params
    def init(self, rng) -> dict:
        cfg = self.cfg
        ks = L.split_tree(rng, 5)
        return {
            "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
            "mamba": L.stack_init(lambda k: ssm_mod.mamba2_init(k, cfg), ks[1], cfg.n_layers),
            "shared_attn": dense_mod.init(ks[2], cfg),
            "final_norm": jnp.ones((cfg.d_model,)),
            "head": L.init_lm_head(ks[3], cfg.d_model, cfg.padded_vocab),
        }

    def logical(self) -> dict:
        cfg = self.cfg
        strip_L = lambda t: jax.tree.map(lambda d: d[1:], t,
                                         is_leaf=lambda v: isinstance(v, tuple))
        return {
            "embed": L.embedding_logical(),
            "mamba": ssm_mod.mamba2_logical(cfg),
            "shared_attn": strip_L(dense_mod.logical(cfg)),
            "final_norm": ("embed",),
            "head": L.lm_head_logical(),
        }

    def param_specs(self, rules):
        return specs_from_logical(self.logical(), rules)

    # ------------------------------------------------------------------- cache
    def _cache(self, B, T, as_struct):
        cfg = self.cfg
        dt = _dtype(cfg)
        mam = jax.eval_shape(lambda: ssm_mod.mamba2_cache(cfg, B, T, dt))
        att = jax.eval_shape(lambda: dense_mod.init_cache(cfg, B, T, dt))
        mk = (lambda s, n: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype)) if as_struct \
            else (lambda s, n: jnp.zeros((n,) + s.shape, s.dtype))
        return {
            "mamba": jax.tree.map(lambda s: mk(s, cfg.n_layers), mam),
            "attn": jax.tree.map(lambda s: mk(s, self.n_stages), att),
        }

    def init_cache(self, batch_size, seq_len):
        return self._cache(batch_size, seq_len, as_struct=False)

    def cache_struct(self, batch_size, seq_len):
        return self._cache(batch_size, seq_len, as_struct=True)

    def cache_specs(self, rules):
        add_L = lambda t: jax.tree.map(lambda d: (None,) + d, t,
                                       is_leaf=lambda v: isinstance(v, tuple))
        return {
            "mamba": specs_from_logical(add_L(ssm_mod.mamba2_cache_logical(self.cfg)), rules),
            "attn": specs_from_logical(add_L(dense_mod.cache_logical(self.cfg)), rules),
        }

    # ----------------------------------------------------------------- forward
    def loss(self, params, batch):
        x, _ = self._hidden_zamba(params, batch)
        return L.fused_head_cross_entropy(
            x, params["head"]["w"], batch["labels"], batch.get("loss_mask"),
            n_valid=self.cfg.vocab if self.cfg.padded_vocab != self.cfg.vocab else None)

    def forward(self, params, batch, cache=None, pos=None):
        x, nc = self._hidden_zamba(params, batch, cache, pos)
        nv = self.cfg.vocab if self.cfg.padded_vocab != self.cfg.vocab else None
        return L.lm_head(params["head"], x, nv), nc

    def _hidden_zamba(self, params, batch, cache=None, pos=None):
        cfg = self.cfg
        dtype = _dtype(cfg)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        B, S = x.shape[:2]
        if pos is None:
            positions = jnp.arange(S)[None, :]
        else:
            positions = jnp.full((B, 1), pos, jnp.int32)
        ctx = dict(positions=positions, pos=pos, q_offset=0,
                   mode="decode" if pos is not None else "full")

        def mamba_fn(lp, h, lc):
            return ssm_mod.mamba2_apply(cfg, lp, h, lc, ctx)

        take = lambda t, a, b: jax.tree.map(lambda v: v[a:b], t)
        new_mamba, new_attn = [], []
        e = cfg.attn_every
        for s in range(self.n_stages):
            mc = None if cache is None else take(cache["mamba"], s * e, (s + 1) * e)
            x, nm = L.scan_layers(mamba_fn, take(params["mamba"], s * e, (s + 1) * e),
                                  x, mc, remat=cfg.remat, policy=cfg.remat_policy)
            if cache is not None:
                new_mamba.append(nm)
            ac = None if cache is None else jax.tree.map(lambda v: v[s], cache["attn"])
            h = L.rms_norm(x, params["shared_attn"]["attn_norm"], cfg.norm_eps)
            attn_out, na = L.attention_block(
                params["shared_attn"]["attn"], h, cfg=cfg, positions=positions,
                cache=ac, pos=pos, causal=True,
            )
            x = x + attn_out
            h = L.rms_norm(x, params["shared_attn"]["mlp_norm"], cfg.norm_eps)
            x = x + L.swiglu(params["shared_attn"]["mlp"], h)
            if cache is not None:
                new_attn.append(na)
        if self.tail:
            a = self.n_stages * e
            mc = None if cache is None else take(cache["mamba"], a, a + self.tail)
            x, nm = L.scan_layers(mamba_fn, take(params["mamba"], a, a + self.tail),
                                  x, mc, remat=cfg.remat, policy=cfg.remat_policy)
            if cache is not None:
                new_mamba.append(nm)

        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        if cache is None:
            return x, None
        new_cache = {
            "mamba": jax.tree.map(lambda *vs: jnp.concatenate(vs, axis=0), *new_mamba),
            "attn": jax.tree.map(lambda *vs: jnp.stack(vs, axis=0), *new_attn),
        }
        return x, new_cache
