"""Encoder-decoder backbone (seamless-m4t-large-v2 text/unit stack).

The audio frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, F, d_model) — the conformer feature extractor is
upstream of the transformer backbone being benchmarked.  Encoder: bidirectional
self-attention + GELU MLP (LayerNorm).  Decoder: causal self-attention +
cross-attention over encoder memory + GELU MLP.

Shapes: for a cell with seq_len S, the decoder runs S tokens and the encoder
``S // enc_ratio`` frames.  Decode caches: per-decoder-layer self KV (B,T,Hk,dh)
plus cross K/V precomputed ONCE from encoder memory at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.causal_lm import CausalLM, _dtype
from repro.models.sharding import constrain, specs_from_logical


def _ln_init(d):
    return {"w": jnp.ones((d,)), "b": jnp.zeros((d,))}


def _ln_logical():
    return {"w": (None, "embed"), "b": (None, "embed")}


def _enc_layer_init(rng, cfg):
    ks = L.split_tree(rng, 2)
    return {
        "attn_norm": _ln_init(cfg.d_model),
        "attn": L.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_norm": _ln_init(cfg.d_model),
        "mlp": L.init_gelu_mlp(ks[1], cfg.d_model, cfg.d_ff),
    }


def _dec_layer_init(rng, cfg):
    ks = L.split_tree(rng, 3)
    return {
        "self_norm": _ln_init(cfg.d_model),
        "self_attn": L.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "cross_norm": _ln_init(cfg.d_model),
        "cross_attn": L.init_gqa(ks[1], cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd),
        "mlp_norm": _ln_init(cfg.d_model),
        "mlp": L.init_gelu_mlp(ks[2], cfg.d_model, cfg.d_ff),
    }


class EncDecModel(CausalLM):
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.block = None
        self.prelude = None

    # ------------------------------------------------------------------ params
    def init(self, rng):
        cfg = self.cfg
        ks = L.split_tree(rng, 5)
        return {
            "embed": L.init_embedding(ks[0], cfg.padded_vocab, cfg.d_model),
            "enc": L.stack_init(lambda k: _enc_layer_init(k, cfg), ks[1], cfg.n_layers),
            "dec": L.stack_init(lambda k: _dec_layer_init(k, cfg), ks[2], cfg.n_dec_layers),
            "enc_norm": _ln_init(cfg.d_model),
            "final_norm": _ln_init(cfg.d_model),
            "head": L.init_lm_head(ks[3], cfg.d_model, cfg.padded_vocab),
        }

    def logical(self):
        cfg = self.cfg
        add_L = lambda t: jax.tree.map(lambda d: (None,) + d, t,
                                       is_leaf=lambda v: isinstance(v, tuple))
        # _ln_logical already carries the stacked-L prefix; enc/final norms are
        # UNSTACKED singles.
        enc_l = {
            "attn_norm": _ln_logical(), "attn": add_L(L.gqa_logical()),
            "mlp_norm": _ln_logical(), "mlp": add_L(L.gelu_mlp_logical()),
        }
        dec_l = {
            "self_norm": _ln_logical(), "self_attn": add_L(L.gqa_logical()),
            "cross_norm": _ln_logical(), "cross_attn": add_L(L.gqa_logical()),
            "mlp_norm": _ln_logical(), "mlp": add_L(L.gelu_mlp_logical()),
        }
        single_ln = {"w": ("embed",), "b": ("embed",)}
        return {
            "embed": L.embedding_logical(), "enc": enc_l, "dec": dec_l,
            "enc_norm": single_ln, "final_norm": single_ln,
            "head": L.lm_head_logical(),
        }

    def param_specs(self, rules):
        return specs_from_logical(self.logical(), rules)

    # ------------------------------------------------------------------- cache
    def _cache(self, B, T, as_struct):
        cfg = self.cfg
        dt = _dtype(cfg)
        F = max(1, T // cfg.enc_ratio)
        Ld = cfg.n_dec_layers
        kv = lambda t: (Ld, B, t, cfg.n_kv_heads, cfg.hd)
        mk = (lambda s: jax.ShapeDtypeStruct(s, dt)) if as_struct else (lambda s: jnp.zeros(s, dt))
        return {
            "self_k": mk(kv(T)), "self_v": mk(kv(T)),
            "cross_k": mk(kv(F)), "cross_v": mk(kv(F)),
        }

    def init_cache(self, batch_size, seq_len):
        return self._cache(batch_size, seq_len, as_struct=False)

    def cache_struct(self, batch_size, seq_len):
        return self._cache(batch_size, seq_len, as_struct=True)

    def cache_specs(self, rules):
        dims = (None, "batch", "kv_seq", "kv_heads", None)
        return specs_from_logical(
            {k: dims for k in ("self_k", "self_v", "cross_k", "cross_v")}, rules)

    # ----------------------------------------------------------------- encoder
    def encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(_dtype(cfg))
        x = constrain(x, "batch", "seq", "act_embed")
        positions = jnp.arange(x.shape[1])[None, :]

        def enc_fn(lp, h, lc):
            a = L.layer_norm(h, lp["attn_norm"]["w"], lp["attn_norm"]["b"], cfg.norm_eps)
            out, _ = L.attention_block(lp["attn"], a, cfg=cfg, positions=positions,
                                       causal=False)
            h = h + out
            a = L.layer_norm(h, lp["mlp_norm"]["w"], lp["mlp_norm"]["b"], cfg.norm_eps)
            return h + L.gelu_mlp(lp["mlp"], a), None

        x, _ = L.scan_layers(enc_fn, params["enc"], x, None, remat=cfg.remat, policy=cfg.remat_policy)
        return L.layer_norm(x, params["enc_norm"]["w"], params["enc_norm"]["b"], cfg.norm_eps)

    # ----------------------------------------------------------------- decoder
    def _decode_stack(self, params, x, memory, cache, pos, positions):
        cfg = self.cfg
        dtype = x.dtype

        def dec_fn(lp, h, lc):
            a = L.layer_norm(h, lp["self_norm"]["w"], lp["self_norm"]["b"], cfg.norm_eps)
            sc = None if lc is None else {"k": lc["self_k"], "v": lc["self_v"]}
            out, nsc = L.attention_block(lp["self_attn"], a, cfg=cfg, positions=positions,
                                         cache=sc, pos=pos, causal=True)
            h = h + out
            a = L.layer_norm(h, lp["cross_norm"]["w"], lp["cross_norm"]["b"], cfg.norm_eps)
            if lc is None:
                # teacher-forced: fresh cross K/V from encoder memory
                q, _, _ = L.gqa_project(lp["cross_attn"], a, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.hd, dtype)
                _, mk_, mv_ = L.gqa_project(lp["cross_attn"], memory, cfg.n_heads,
                                            cfg.n_kv_heads, cfg.hd, dtype)
                out = L.chunked_attention(q, mk_, mv_, causal=False, block_q=cfg.attn_block_q)
                nc = None
            else:
                q, _, _ = L.gqa_project(lp["cross_attn"], a, cfg.n_heads, cfg.n_kv_heads,
                                        cfg.hd, dtype)
                out = L.chunked_attention(q, lc["cross_k"].astype(dtype),
                                          lc["cross_v"].astype(dtype),
                                          causal=False, block_q=1)
                nc = {"self_k": nsc["k"], "self_v": nsc["v"],
                      "cross_k": lc["cross_k"], "cross_v": lc["cross_v"]}
            B, S = a.shape[:2]
            out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["cross_attn"]["wo"].astype(dtype)
            h = h + out
            a = L.layer_norm(h, lp["mlp_norm"]["w"], lp["mlp_norm"]["b"], cfg.norm_eps)
            return h + L.gelu_mlp(lp["mlp"], a), nc

        return L.scan_layers(dec_fn, params["dec"], x, cache, remat=cfg.remat, policy=cfg.remat_policy)

    # ------------------------------------------------------------ entry points
    def forward(self, params, batch, cache=None, pos=None):
        cfg = self.cfg
        dtype = _dtype(cfg)
        x = L.embed(params["embed"], batch["tokens"], dtype)
        B, S = x.shape[:2]
        if pos is None:
            positions = jnp.arange(S)[None, :]
            memory = self.encode(params, batch["frames"])
            x, _ = self._decode_stack(params, x, memory, None, None, positions)
            new_cache = None
        else:
            positions = jnp.full((B, 1), pos, jnp.int32)
            sc = {"self_k": cache["self_k"], "self_v": cache["self_v"],
                  "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
            x, nc = self._decode_stack(params, x, None, sc, pos, positions)
            new_cache = nc
        x = L.layer_norm(x, params["final_norm"]["w"], params["final_norm"]["b"], cfg.norm_eps)
        nv = cfg.vocab if cfg.padded_vocab != cfg.vocab else None
        logits = L.lm_head(params["head"], x, nv)
        return logits, new_cache

    def loss(self, params, batch):
        cfg = self.cfg
        x = L.embed(params["embed"], batch["tokens"], _dtype(cfg))
        positions = jnp.arange(x.shape[1])[None, :]
        memory = self.encode(params, batch["frames"])
        x, _ = self._decode_stack(params, x, memory, None, None, positions)
        x = L.layer_norm(x, params["final_norm"]["w"], params["final_norm"]["b"], cfg.norm_eps)
        return L.fused_head_cross_entropy(
            x, params["head"]["w"], batch["labels"], batch.get("loss_mask"),
            n_valid=cfg.vocab if cfg.padded_vocab != cfg.vocab else None)
