"""Model registry + canonical batch builders for every (arch x shape) cell.

``build_model(cfg)`` returns the family's model object (CausalLM or a subclass).
``make_batch`` builds concrete arrays (smoke tests / the train driver);
``batch_struct`` builds ShapeDtypeStructs (the dry-run — no allocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# block registration side effects
from repro.models import dense as _dense  # noqa: F401
from repro.models import mla as _mla      # noqa: F401
from repro.models import moe as _moe      # noqa: F401
from repro.models import ssm as _ssm      # noqa: F401
from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.causal_lm import CausalLM
from repro.models.encdec import EncDecModel
from repro.models.zamba import Zamba2Model


def build_model(cfg: ModelConfig):
    if cfg.family == "hybrid":
        return Zamba2Model(cfg)
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    return CausalLM(cfg)


def _token_shapes(cfg: ModelConfig, shape: ShapeConfig, kind: str):
    """Returns dict name -> (shape, dtype) for the given entry point."""
    B, S = shape.global_batch, shape.seq_len
    t = jnp.int32
    f = jnp.dtype(cfg.dtype)
    if kind == "train":
        out = {"tokens": ((B, S), t), "labels": ((B, S), t)}
        if cfg.family == "vlm":
            out["tokens"] = ((B, S - cfg.n_patches), t)
            out["labels"] = ((B, S - cfg.n_patches), t)
            out["patch_embeds"] = ((B, cfg.n_patches, cfg.patch_dim), f)
        if cfg.family == "encdec":
            out["frames"] = ((B, max(1, S // cfg.enc_ratio), cfg.d_model), f)
        return out
    if kind == "prefill":
        out = {"tokens": ((B, S), t)}
        if cfg.family == "vlm":
            out["tokens"] = ((B, S - cfg.n_patches), t)
            out["patch_embeds"] = ((B, cfg.n_patches, cfg.patch_dim), f)
        if cfg.family == "encdec":
            out["frames"] = ((B, max(1, S // cfg.enc_ratio), cfg.d_model), f)
        return out
    if kind == "decode":
        return {"tokens": ((B, 1), t)}
    raise ValueError(kind)


def batch_struct(cfg: ModelConfig, shape: ShapeConfig, kind: str | None = None) -> dict:
    kind = kind or shape.kind
    return {
        k: jax.ShapeDtypeStruct(s, d)
        for k, (s, d) in _token_shapes(cfg, shape, kind).items()
    }


def make_batch(cfg: ModelConfig, shape: ShapeConfig, kind: str | None = None, seed: int = 0) -> dict:
    """Deterministic synthetic batch (the data pipeline for smoke/e2e on CPU)."""
    kind = kind or shape.kind
    rng = np.random.default_rng(seed)
    out = {}
    for k, (s, d) in _token_shapes(cfg, shape, kind).items():
        if d == jnp.int32:
            out[k] = jnp.asarray(rng.integers(0, cfg.vocab, size=s), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(0, 1, size=s), d)
    return out
