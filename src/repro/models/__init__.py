from repro.models.api import batch_struct, build_model, make_batch
from repro.models.sharding import rules_for, use_rules
