"""Adam / AdamW from scratch (paper §6 uses Adam per subdomain).

Supports the paper's per-subdomain learning rates: ``lr`` may be a scalar OR an array
broadcast against each leaf's LEADING axis (the stacked ``n_sub`` axis in the
reference trainer).  Inside ``shard_map`` each device passes its own scalar lr.

Also provides a simple warmup-cosine schedule (used by the LM training driver) and
gradient clipping by global norm.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class AdamConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0  # decoupled (AdamW) when > 0


def init_adam(params: Pytree) -> dict:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params), "count": jnp.zeros((), jnp.int32)}


def _bcast_lr(lr, leaf):
    """Broadcast scalar/per-subdomain lr against a leaf."""
    lr = jnp.asarray(lr, leaf.dtype)
    if lr.ndim == 0:
        return lr
    return lr.reshape(lr.shape + (1,) * (leaf.ndim - lr.ndim))


def adam_update(
    grads: Pytree, state: dict, params: Pytree, lr, cfg: AdamConfig = AdamConfig()
) -> tuple[Pytree, dict]:
    count = state["count"] + 1
    c = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**c
    bc2 = 1.0 - cfg.b2**c

    m = jax.tree.map(lambda mu, g: cfg.b1 * mu + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda nu, g: cfg.b2 * nu + (1 - cfg.b2) * g * g, state["v"], grads)

    def upd(p, mu, nu):
        step = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p
        return p - _bcast_lr(lr, p) * step

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}


def clip_by_global_norm(grads: Pytree, max_norm: float) -> tuple[Pytree, jax.Array]:
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def warmup_cosine(step: jax.Array, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = peak_lr * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
