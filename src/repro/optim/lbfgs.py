"""Memory-limited BFGS (paper §6: the Sandblaster distributed L-BFGS reference).

Standard PINN practice (and the paper's own lineage, Raissi et al.) is Adam for the
bulk of training then L-BFGS for refinement: the PINN loss landscape rewards a
curvature-aware final descent.  This implementation is jit-friendly:

* fixed-size history (m pairs) carried as stacked arrays — no python-side state;
* the classic two-loop recursion runs as ``lax.fori_loop``s over the history;
* backtracking Armijo line search with a bounded number of probes (``lax.while_loop``
  is avoided so the step stays a fixed-shape XLA program — probes are vectorized and
  the first acceptable step is selected).

Per-subdomain use: the paper optimizes each subdomain's loss independently, so the
distributed trainer can vmap/shard_map this update exactly like Adam (curvature
pairs live per subdomain).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class LBFGSConfig:
    history: int = 10
    max_step: float = 1.0
    armijo_c1: float = 1e-4
    n_probes: int = 14         # backtracking ladder: max_step * 0.5**j
    eps: float = 1e-10


def _flatten(tree: Pytree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [l.size for l in leaves]
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))
    def unflatten(v):
        out, ofs = [], 0
        for sh, sz in zip(shapes, sizes):
            out.append(v[ofs:ofs + sz].reshape(sh))
            ofs += sz
        return jax.tree_util.tree_unflatten(treedef, out)
    return flat, unflatten


def init_lbfgs(params: Pytree, cfg: LBFGSConfig = LBFGSConfig()) -> dict:
    flat, _ = _flatten(params)
    n = flat.shape[0]
    return {
        "s": jnp.zeros((cfg.history, n)),   # param deltas
        "y": jnp.zeros((cfg.history, n)),   # grad deltas
        "rho": jnp.zeros((cfg.history,)),
        "count": jnp.zeros((), jnp.int32),
        "prev_flat": flat,
        "prev_grad": jnp.zeros_like(flat),
    }


def _two_loop(g, s, y, rho, count, m, eps):
    """Standard L-BFGS two-loop recursion over a circular history buffer."""
    idxs = (count - 1 - jnp.arange(m)) % m          # newest -> oldest
    valid = jnp.arange(m) < jnp.minimum(count, m)

    def bwd(i, carry):
        q, alphas = carry
        j = idxs[i]
        a = jnp.where(valid[i], rho[j] * jnp.dot(s[j], q), 0.0)
        q = q - a * y[j] * valid[i]
        return q, alphas.at[i].set(a)

    q, alphas = jax.lax.fori_loop(0, m, bwd, (g, jnp.zeros((m,))))

    # initial Hessian scaling: gamma = s.y/y.y of the newest pair; before any
    # curvature pair exists, 1/|g| (unit-norm first direction so the Armijo
    # ladder's largest probe is a max_step-length move, not |g|*max_step)
    jn = (count - 1) % m
    yy = jnp.dot(y[jn], y[jn])
    g_norm = jnp.sqrt(jnp.dot(q, q))
    gamma = jnp.where(count > 0, jnp.dot(s[jn], y[jn]) / (yy + eps),
                      1.0 / (g_norm + eps))
    r = gamma * q

    def fwd(i, r):
        ii = m - 1 - i                              # oldest -> newest
        j = idxs[ii]
        b = jnp.where(valid[ii], rho[j] * jnp.dot(y[j], r), 0.0)
        return r + (alphas[ii] - b) * s[j] * valid[ii]

    return jax.lax.fori_loop(0, m, fwd, r)


def lbfgs_step(loss_fn: Callable, params: Pytree, state: dict,
               cfg: LBFGSConfig = LBFGSConfig()):
    """One L-BFGS iteration. loss_fn: params -> scalar. Returns (params, state, loss)."""
    flat, unflatten = _flatten(params)
    loss, grads = jax.value_and_grad(loss_fn)(params)
    g, _ = _flatten(grads)
    m = cfg.history

    d = -_two_loop(g, state["s"], state["y"], state["rho"], state["count"], m, cfg.eps)
    # safeguard: fall back to steepest descent on a non-descent direction
    descent = jnp.dot(d, g)
    g_norm = jnp.sqrt(jnp.dot(g, g)) + cfg.eps
    d = jnp.where(descent < 0, d, -g / g_norm)
    descent = jnp.where(descent < 0, descent, -g_norm)

    # vectorized backtracking Armijo search over a fixed ladder of step sizes
    steps = cfg.max_step * 0.5 ** jnp.arange(cfg.n_probes)
    cand = flat[None, :] + steps[:, None] * d[None, :]
    losses = jax.vmap(lambda v: loss_fn(unflatten(v)))(cand)
    ok = losses <= loss + cfg.armijo_c1 * steps * descent
    # first acceptable probe; if none, REJECT the step (monotone by construction;
    # the curvature pair degenerates to zero and is skipped below)
    first = jnp.argmax(ok)
    t = jnp.where(jnp.any(ok), steps[first], 0.0)
    new_flat = flat + t * d
    new_loss = jnp.where(jnp.any(ok), losses[first], loss)

    new_params = unflatten(new_flat)
    new_g, _ = _flatten(jax.grad(loss_fn)(new_params))
    s_vec, y_vec = new_flat - flat, new_g - g
    sy = jnp.dot(s_vec, y_vec)
    slot = state["count"] % m
    keep = sy > cfg.eps                              # curvature condition
    new_state = {
        "s": jnp.where(keep, state["s"].at[slot].set(s_vec), state["s"]),
        "y": jnp.where(keep, state["y"].at[slot].set(y_vec), state["y"]),
        "rho": jnp.where(keep, state["rho"].at[slot].set(1.0 / (sy + cfg.eps)),
                         state["rho"]),
        "count": state["count"] + keep.astype(jnp.int32),
        "prev_flat": new_flat,
        "prev_grad": new_g,
    }
    return new_params, new_state, new_loss


def lbfgs_refine(loss_fn: Callable, params: Pytree, steps: int,
                 cfg: LBFGSConfig = LBFGSConfig()):
    """Run `steps` jitted L-BFGS iterations (the PINN refinement phase)."""
    state = init_lbfgs(params, cfg)
    step = jax.jit(partial(lbfgs_step, loss_fn, cfg=cfg))
    losses = []
    for _ in range(steps):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    return params, losses
