from repro.optim.adam import AdamConfig, adam_update, clip_by_global_norm, init_adam, warmup_cosine
from repro.optim.compress import CompressionConfig, compress_decompress, wire_bytes
from repro.optim.lbfgs import LBFGSConfig, init_lbfgs, lbfgs_refine, lbfgs_step
