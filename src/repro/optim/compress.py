"""Gradient compression with error feedback (distributed-optimization substrate).

Used by the data-parallel baseline (the paper's Fig 1a comparison point): the
allreduce buffer there is O(N_params) per step — exactly the cost the paper's
domain-decomposition avoids — so compression is the standard mitigation at scale.

Two schemes, both with error-feedback accumulators (Karimireddy et al. style:
``compressed = C(g + e); e' = (g + e) - compressed``):

* ``int8`` — per-leaf symmetric quantization (scale = max|x| / 127).
* ``topk`` — keep the top-k fraction by magnitude (dense masked representation;
  on a real interconnect this is sent sparse — the wire-bytes model used in the
  benchmarks accounts for index+value pairs).

Both are pure functions usable inside shard_map/jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Literal

import jax
import jax.numpy as jnp

Pytree = Any


@dataclass(frozen=True)
class CompressionConfig:
    scheme: Literal["int8", "topk"] = "int8"
    topk_frac: float = 0.01  # fraction of entries kept by topk


def _quant_int8(x: jax.Array) -> jax.Array:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    return q * scale  # dequantized representative (what the receiver reconstructs)


def _topk_mask(x: jax.Array, frac: float) -> jax.Array:
    flat = jnp.abs(x).ravel()
    k = max(1, int(round(frac * flat.size)))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return jnp.where(jnp.abs(x) >= thresh, x, 0.0)


def compress_decompress(
    grads: Pytree, err: Pytree, cfg: CompressionConfig
) -> tuple[Pytree, Pytree]:
    """Error-feedback compression: returns (decompressed grads, new error accum)."""

    def one(g, e):
        t = g + e
        if cfg.scheme == "int8":
            c = _quant_int8(t)
        else:
            c = _topk_mask(t, cfg.topk_frac)
        return c, t - c

    pairs = jax.tree.map(one, grads, err)
    comp = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda p: isinstance(p, tuple))
    new_err = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda p: isinstance(p, tuple))
    return comp, new_err


def wire_bytes(params: Pytree, cfg: CompressionConfig | None) -> int:
    """Modeled allreduce payload bytes per step (for the comparison benchmarks)."""
    n = sum(x.size for x in jax.tree.leaves(params))
    if cfg is None:
        return 4 * n
    if cfg.scheme == "int8":
        return n + 4 * len(jax.tree.leaves(params))  # 1B/entry + per-leaf scale
    k = max(1, int(round(cfg.topk_frac * n)))
    return 8 * k  # 4B index + 4B value per kept entry
