from repro.checkpoint import ckpt
from repro.checkpoint.ckpt import latest_step, raw_leaves, restore, save
