from repro.checkpoint import ckpt, integrity
from repro.checkpoint.ckpt import latest_step, raw_leaves, restore, save
from repro.checkpoint.integrity import (CorruptCheckpointError, IntegrityError,
                                        NoVerifiedCheckpointError, RestoreInfo,
                                        latest_verified_step, quarantine,
                                        verified_raw_leaves, verified_restore,
                                        verify_step_dir)
