"""Fault-tolerant checkpointing (no orbax dependency — self-contained npz + manifest).

Layout of a checkpoint directory::

    <root>/step_<n>/
        manifest.json     # step, pytree structure, shapes/dtypes, user metadata
        arrays.npz        # flat leaves keyed "leaf_00000", ...
    <root>/LATEST         # atomic pointer file (write-tmp + rename)

Guarantees:
* atomic publication — a crash mid-save never corrupts LATEST (tested by the
  failure-injection harness in ``repro.runtime.failures``);
* bitwise restore — training resumed from a checkpoint continues exactly
  (``tests/test_checkpoint.py`` asserts step-for-step equality);
* keep-last-k garbage collection;
* structure-checked restore with a clear error on mismatch (unless
  ``allow_restructure=True`` for elastic restarts, see ``repro.runtime.elastic``);
* durable-state integrity — every save stamps per-array CRC32s + a manifest
  digest + the parent-generation chain edge into the manifest
  (:mod:`repro.checkpoint.integrity`); corrupt generations are detected at
  restore and fallen back across via ``integrity.verified_restore``.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import warnings
from typing import Any

import jax
import numpy as np

Pytree = Any


def _flatten_with_paths(tree: Pytree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = ["/".join(str(k) for k in p) for p, _ in leaves_with_paths]
    leaves = [v for _, v in leaves_with_paths]
    return paths, leaves


def save(root: str, step: int, tree: Pytree, metadata: dict | None = None,
         keep: int = 3, integrity: bool = True) -> str:
    """Atomically write a checkpoint for ``step``; returns the checkpoint dir.

    ``integrity=True`` (the default) stamps per-array checksums, a manifest
    digest, and the parent-generation name into the manifest so restore-time
    verification and generation fallback work
    (:mod:`repro.checkpoint.integrity`; measured write overhead is bounded at
    5% by ``benchmarks/chaos_soak.py``)."""
    os.makedirs(root, exist_ok=True)
    # a crash mid-save leaves its .tmp_step_* workdir behind; sweep orphans
    # BEFORE creating our own (single-writer contract: one saver per root)
    _sweep_orphan_tmps(root)
    paths, leaves = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    tmp = tempfile.mkdtemp(dir=root, prefix=f".tmp_step_{step}_")
    try:
        arrays = {f"leaf_{i:05d}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": int(step),
            "paths": paths,
            "treedef": str(treedef),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "metadata": metadata or {},
        }
        if integrity:
            from repro.checkpoint import integrity as integ

            gens = _step_dirs(root)
            manifest["integrity"] = integ.build_integrity(
                manifest, os.path.join(tmp, "arrays.npz"),
                parent=gens[-1][1] if gens else None)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        final = os.path.join(root, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(root, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(root, "LATEST"))
    _gc(root, keep)
    return final


def _gc(root: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def _sweep_orphan_tmps(root: str) -> None:
    """Remove half-written ``.tmp_step_*`` dirs a crashed save left behind.

    They are invisible to restore (everything scans for ``step_`` prefixes),
    but they leak disk forever on a long-running job — swept on the next
    ``save`` / ``latest_step``.  Assumes the single-writer contract: the only
    live tmp dir belongs to a save() currently on this call stack, and save()
    sweeps before creating it."""
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return
    for d in names:
        if d.startswith(".tmp_step_"):
            shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def _readable_step_dir(root: str, name: str) -> int | None:
    """Step number iff ``name`` is a well-formed, READABLE step dir: parsable
    name, manifest.json present and parsable JSON.  None otherwise (the
    caller warns + continues — a partially-written or rotting dir must not
    crash the restore scan; checksum-level verification is
    :mod:`repro.checkpoint.integrity`'s job)."""
    try:
        n = int(name.split("_", 1)[1])
    except (IndexError, ValueError):
        return None
    try:
        with open(os.path.join(root, name, "manifest.json")) as f:
            json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError):
        return None
    return n


def _step_dirs(root: str) -> list[tuple[int, str]]:
    """Readable ``(step, dirname)`` pairs under ``root``, oldest first.
    Unreadable/partially-written step dirs are warned about and SKIPPED
    instead of crashing the scan."""
    try:
        names = os.listdir(root)
    except FileNotFoundError:
        return []
    out = []
    for d in sorted(names):
        if not d.startswith("step_"):
            continue
        n = _readable_step_dir(root, d)
        if n is None:
            warnings.warn(f"skipping unreadable checkpoint dir {root}/{d}",
                          RuntimeWarning, stacklevel=2)
            continue
        out.append((n, d))
    return sorted(out)


def latest_step(root: str) -> int | None:
    ptr = os.path.join(root, "LATEST")
    if os.path.isdir(root):
        _sweep_orphan_tmps(root)
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    n = _readable_step_dir(root, name) if name.startswith("step_") else None
    if n is None:
        # LATEST pointing at a GC'd/half/unreadable dir: fall back to the
        # newest readable one (warn + continue, never crash the scan)
        cands = _step_dirs(root)
        if not cands:
            return None
        n = cands[-1][0]
    return n


def restore(root: str, like: Pytree, step: int | None = None,
            allow_restructure: bool = False) -> tuple[Pytree, dict]:
    """Restore into the structure of ``like``; returns (tree, metadata)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = [data[f"leaf_{i:05d}"] for i in range(len(manifest["paths"]))]

    want_paths, want_leaves = _flatten_with_paths(like)
    if manifest["paths"] != want_paths:
        if not allow_restructure:
            raise ValueError(
                "checkpoint structure mismatch:\n"
                f"  stored {manifest['paths'][:5]}...\n  wanted {want_paths[:5]}..."
            )
        by_path = dict(zip(manifest["paths"], leaves))
        leaves = [by_path.get(p, w) for p, w in zip(want_paths, want_leaves)]
    treedef = jax.tree_util.tree_structure(like)
    out = jax.tree_util.tree_unflatten(treedef, leaves)
    return out, manifest["metadata"]


def raw_leaves(root: str, step: int | None = None) -> tuple[dict[str, np.ndarray], dict]:
    """Path-keyed leaves without a template (used by elastic re-decomposition)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "arrays.npz"))
    leaves = {p: data[f"leaf_{i:05d}"] for i, p in enumerate(manifest["paths"])}
    return leaves, manifest
