"""Durable-state integrity: checksums, verification, generation fallback.

Long runs see storage faults — torn writes, truncated files, bit rot — as
routine events, and both recovery paths in this repo (the supervisor's
bitwise-replay rollback and the serving stack's bundle load) previously
assumed the artifact they read back was valid.  This module closes that gap
on the existing npz+manifest checkpoint format (:mod:`repro.checkpoint.ckpt`)
WITHOUT changing it on disk beyond one extra manifest key:

* **per-array checksums** — ``ckpt.save`` stamps an ``integrity`` block into
  ``manifest.json``: a CRC32 per stored leaf plus a SHA-256 digest of the
  rest of the manifest, so bit rot in either file is detected at restore,
  with the failing array NAMED in the error.  The CRCs are HARVESTED from
  the zip central directory of the just-written ``arrays.npz`` (``zipfile``
  computes them during the write anyway), so stamping costs microseconds
  regardless of tree size — recomputing them would double the write cost of
  large checkpoints through this container's ~0.5 GB/s zlib;
* **verify-on-restore** — :func:`verify_step_dir` re-reads the npz and
  recomputes every checksum; any mismatch / unreadable member / missing file
  raises :class:`CorruptCheckpointError`.  Pre-integrity checkpoints (no
  ``integrity`` block) verify as ``"legacy"`` — accepted, since there is
  nothing to check against;
* **generation fallback** — checkpoints already form an append-only chain of
  ``step_*`` generations (keep-last-k, each manifest records its ``parent``
  generation).  :func:`latest_verified_step` walks the chain newest-first,
  **quarantines** corrupt generations (rename to ``.quarantine_*`` — never
  delete, the bytes stay for forensics) and returns the newest generation
  that verifies.  :func:`verified_restore` / :func:`verified_raw_leaves` are
  the drop-in wrappers the supervisor rollback, elastic resume, and bundle
  load route through, so a poisoned latest checkpoint costs one generation of
  progress instead of the run.

The clean path is bitwise-unchanged: verification only READS; the restore
itself is still :func:`repro.checkpoint.ckpt.restore` (asserted bitwise in
``tests/test_integrity.py``).  Measured write overhead is bounded at 5% by
``benchmarks/chaos_soak.py``.
"""
from __future__ import annotations

import hashlib
import json
import os
import zipfile
import zlib
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

# "crc32-npz": CRCs are the npz zip members' own (over the serialized .npy
# member bytes, harvested from the central directory).  "crc32" is the
# legacy data-bytes scheme — still verifiable, no longer written.
ALGO = "crc32-npz"


class IntegrityError(RuntimeError):
    """Base for durable-state integrity failures."""


class CorruptCheckpointError(IntegrityError):
    """A checkpoint/bundle generation failed verification.

    ``path`` is the step directory, ``reason`` the human-readable cause, and
    ``array`` (when the corruption localizes) the failing npz member name."""

    def __init__(self, path: str, reason: str, array: str | None = None):
        self.path, self.reason, self.array = str(path), reason, array
        at = f" (array {array!r})" if array else ""
        super().__init__(f"corrupt checkpoint {path}{at}: {reason}")


class NoVerifiedCheckpointError(IntegrityError):
    """Every candidate generation failed verification (or none exist).

    ``failures`` keeps the per-generation :class:`CorruptCheckpointError`
    list, newest first, so callers can surface WHICH array/file rotted
    instead of just "nothing verified"."""

    def __init__(self, msg: str, failures=()):
        super().__init__(msg)
        self.failures = list(failures)


@dataclass
class RestoreInfo:
    """What the generation walk found: the step restored, how many corrupt
    generations were skipped to reach it, and what got quarantined."""

    step: int
    fallback_depth: int = 0                 # 0 = newest generation verified
    status: str = "verified"                # "verified" | "legacy"
    quarantined: list = field(default_factory=list)  # [(dirname, reason)]


# -------------------------------------------------------------- construction

def _array_bytes(x) -> bytes:
    return np.ascontiguousarray(np.asarray(x)).tobytes()


def array_checksum(x) -> str:
    """CRC32 (hex) over an array's raw data bytes — the legacy ``"crc32"``
    integrity unit (verification-only; the write path harvests zip CRCs)."""
    return f"{zlib.crc32(_array_bytes(x)) & 0xFFFFFFFF:08x}"


def npz_member_crcs(npz_path: str) -> dict[str, str]:
    """Member-name -> CRC32 (hex) from the npz's zip central directory.

    ``zipfile`` computed these while ``np.savez`` wrote the file, so this is
    a directory read — microseconds, independent of array bytes.  Keys drop
    the ``.npy`` suffix to match the manifest's leaf naming."""
    with zipfile.ZipFile(npz_path) as z:
        return {(i.filename[:-4] if i.filename.endswith(".npy")
                 else i.filename): f"{i.CRC & 0xFFFFFFFF:08x}"
                for i in z.infolist()}


def manifest_digest(manifest: dict) -> str:
    """SHA-256 over the canonical JSON of the manifest MINUS its own
    integrity block.  ``json.dumps`` serializes tuples/lists identically, so
    the digest survives the write->parse round trip."""
    clean = {k: v for k, v in manifest.items() if k != "integrity"}
    return hashlib.sha256(
        json.dumps(clean, sort_keys=True).encode()).hexdigest()


def npz_structure_crc(npz_path: str) -> str:
    """CRC32 over every NON-member-data byte of the npz zip container.

    Member DATA is covered by the per-member zip CRCs; this covers the rest
    — local headers, gaps, the central directory, the end record — i.e. the
    bytes ``zipfile`` never validates on read (local mod-times, duplicated
    CRC/name fields, ...).  Together the two leave no byte of the file
    unchecked.  The structure is a few KB regardless of array bytes, so
    both stamping and verifying it are O(headers), not O(data)."""
    import struct

    with zipfile.ZipFile(npz_path) as z:
        infos = sorted(z.infolist(), key=lambda i: i.header_offset)
    crc, pos = 0, 0
    with open(npz_path, "rb") as f:
        for i in infos:
            f.seek(i.header_offset)
            hdr = f.read(30)  # local header: name/extra lens at 26/28
            if len(hdr) < 30 or hdr[:4] != b"PK\x03\x04":
                raise zipfile.BadZipFile(
                    f"bad local header for {i.filename!r}")
            n, m = struct.unpack("<HH", hdr[26:30])
            data_start = i.header_offset + 30 + n + m
            f.seek(pos)
            crc = zlib.crc32(f.read(data_start - pos), crc)
            pos = data_start + i.compress_size
        f.seek(pos)
        crc = zlib.crc32(f.read(), crc)  # central directory + end record
    return f"{crc & 0xFFFFFFFF:08x}"


def build_integrity(manifest: dict, npz_path: str,
                    parent: str | None = None) -> dict:
    """The ``integrity`` block ``ckpt.save`` stamps into the manifest:
    per-array CRC32s (harvested from the just-written npz), the container
    structure CRC, the manifest digest, and the parent generation name (the
    append-only chain edge)."""
    return {
        "algo": ALGO,
        "arrays": npz_member_crcs(npz_path),
        "structure_crc32": npz_structure_crc(npz_path),
        "manifest_sha256": manifest_digest(manifest),
        "parent": parent,
    }


# -------------------------------------------------------------- verification

def verify_step_dir(d: str) -> str:
    """Verify one generation directory end to end.

    Returns ``"verified"`` (integrity block present, everything checks) or
    ``"legacy"`` (pre-integrity checkpoint: structurally readable, nothing to
    check against).  Raises :class:`CorruptCheckpointError` naming the
    failing file/array otherwise.  Read-only — never mutates the directory.
    """
    man_path = os.path.join(d, "manifest.json")
    npz_path = os.path.join(d, "arrays.npz")
    if not os.path.exists(man_path):
        raise CorruptCheckpointError(d, "manifest.json missing")
    try:
        with open(man_path) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CorruptCheckpointError(
            d, f"manifest.json unreadable: {e}") from e
    if not isinstance(manifest, dict) or "paths" not in manifest:
        raise CorruptCheckpointError(d, "manifest.json malformed (no paths)")
    integ = manifest.get("integrity")
    if not os.path.exists(npz_path):
        raise CorruptCheckpointError(d, "arrays.npz missing")
    if integ is None:
        # legacy artifact: confirm the npz at least opens, then accept
        try:
            with np.load(npz_path) as data:
                list(data.files)
        except Exception as e:
            raise CorruptCheckpointError(
                d, f"arrays.npz unreadable: {e}") from e
        return "legacy"
    want_digest = integ.get("manifest_sha256")
    if want_digest != manifest_digest(manifest):
        raise CorruptCheckpointError(
            d, "manifest digest mismatch (manifest.json corrupted)")
    legacy_algo = integ.get("algo") == "crc32"  # data-bytes CRCs, recompute
    try:
        stored = {} if legacy_algo else npz_member_crcs(npz_path)
        data = np.load(npz_path)
    except Exception as e:
        raise CorruptCheckpointError(d, f"arrays.npz unreadable: {e}") from e
    try:
        if not legacy_algo:
            # pass 1 — directory CRCs vs the manifest record: a rotten
            # directory entry or a swapped-in foreign npz fails HERE, with
            # the offending array named (cheap: no data read yet)
            for name, want in integ["arrays"].items():
                if name not in stored:
                    raise CorruptCheckpointError(
                        d, "array missing from arrays.npz", array=name)
                if stored[name] != want:
                    raise CorruptCheckpointError(
                        d, f"checksum mismatch ({stored[name]} != {want})",
                        array=name)
            # pass 2 — the container bytes zipfile never validates on read
            # (local headers, gaps, the directory itself)
            want_struct = integ.get("structure_crc32")
            if (want_struct is not None
                    and npz_structure_crc(npz_path) != want_struct):
                raise CorruptCheckpointError(
                    d, "zip structure checksum mismatch (npz headers/"
                       "directory corrupted)")
        # pass 3 — read every recorded member: zipfile verifies its internal
        # CRC over the actual data bytes (bit rot / truncation / torn tail),
        # and pass 1 pinned WHICH bytes those CRCs must describe
        for name, want in integ["arrays"].items():
            if name not in data.files:
                raise CorruptCheckpointError(
                    d, "array missing from arrays.npz", array=name)
            try:
                arr = data[name]
            except Exception as e:  # truncated/torn member: zlib/zipfile err
                raise CorruptCheckpointError(
                    d, f"array unreadable: {e}", array=name) from e
            if legacy_algo and array_checksum(arr) != want:
                raise CorruptCheckpointError(
                    d, f"checksum mismatch ({array_checksum(arr)} != {want})",
                    array=name)
    finally:
        data.close()
    return "verified"


# ----------------------------------------------------- quarantine + fallback

QUARANTINE_PREFIX = ".quarantine_"


def quarantine(d: str, reason: str = "corrupt") -> str:
    """Move a corrupt generation aside — RENAME, never delete.  The hidden
    ``.quarantine_*`` name is invisible to every ``step_*`` scan (restore,
    GC, LATEST fallback) but keeps the bytes on disk for forensics."""
    root, name = os.path.split(os.path.normpath(d))
    target = os.path.join(root, QUARANTINE_PREFIX + name)
    n = 0
    while os.path.exists(target):
        n += 1
        target = os.path.join(root, f"{QUARANTINE_PREFIX}{name}.{n}")
    os.rename(d, target)
    return target


def generations(root: str) -> list[tuple[int, str]]:
    """Readable ``(step, dirname)`` generations, NEWEST FIRST (the fallback
    walk order).  Delegates the unreadable-dir skip to ``ckpt._step_dirs``."""
    from repro.checkpoint import ckpt

    return list(reversed(ckpt._step_dirs(root)))


def latest_verified_step(root: str, max_fallback: int | None = None,
                         do_quarantine: bool = True,
                         on_event: Callable | None = None) -> RestoreInfo:
    """Walk the generation chain newest-first; return the first generation
    that verifies, quarantining every corrupt one passed on the way.

    ``max_fallback`` bounds how many corrupt generations may be skipped
    (None = all available); ``on_event(kind, **fields)`` receives a
    ``corruption`` callback per quarantined generation and one ``fallback``
    callback when the verified generation is not the newest (the supervisor
    wires this to :meth:`repro.obs.Obs.emit`).  Raises
    :class:`NoVerifiedCheckpointError` when nothing survives.
    """
    gens = generations(root)
    if not gens:
        raise NoVerifiedCheckpointError(f"no checkpoint generations under {root}")
    info = RestoreInfo(step=-1)
    failures = []
    for depth, (step, name) in enumerate(gens):
        if max_fallback is not None and depth > max_fallback:
            break
        d = os.path.join(root, name)
        try:
            status = verify_step_dir(d)
        except CorruptCheckpointError as e:
            where = quarantine(d, e.reason) if do_quarantine else d
            info.quarantined.append((name, str(e)))
            failures.append(e)
            if on_event is not None:
                on_event("corruption", target="ckpt", reason=str(e),
                         path=os.path.basename(where))
            continue
        info.step, info.fallback_depth, info.status = step, depth, status
        if depth and on_event is not None:
            on_event("fallback", target="ckpt", depth=depth)
        return info
    raise NoVerifiedCheckpointError(
        f"no verified checkpoint under {root} within "
        f"{len(info.quarantined)} generation(s): "
        + "; ".join(r for _n, r in info.quarantined), failures=failures)


# ------------------------------------------------------------ restore wrappers

def verified_restore(root: str, like, step: int | None = None,
                     allow_restructure: bool = False,
                     max_fallback: int | None = None,
                     on_event: Callable | None = None):
    """Verify-then-restore: the durable replacement for ``ckpt.restore``.

    With ``step`` given, that exact generation must verify (no fallback —
    an explicit step is a contract).  Without it, the generation walk picks
    the newest verified one.  Returns ``(tree, metadata, RestoreInfo)``;
    the restore itself is the unmodified ``ckpt.restore``, so a clean
    artifact restores bitwise-identically to the pre-integrity path."""
    from repro.checkpoint import ckpt

    if step is not None:
        d = os.path.join(root, f"step_{step:010d}")
        status = verify_step_dir(d)
        info = RestoreInfo(step=step, status=status)
    else:
        info = latest_verified_step(root, max_fallback=max_fallback,
                                    on_event=on_event)
    tree, meta = ckpt.restore(root, like, step=info.step,
                              allow_restructure=allow_restructure)
    return tree, meta, info


def verified_raw_leaves(root: str, step: int | None = None,
                        max_fallback: int | None = None,
                        on_event: Callable | None = None):
    """Verified counterpart of ``ckpt.raw_leaves`` (elastic resume's entry).
    Returns ``(leaves, manifest, RestoreInfo)``."""
    from repro.checkpoint import ckpt

    if step is not None:
        status = verify_step_dir(os.path.join(root, f"step_{step:010d}"))
        info = RestoreInfo(step=step, status=status)
    else:
        info = latest_verified_step(root, max_fallback=max_fallback,
                                    on_event=on_event)
    leaves, manifest = ckpt.raw_leaves(root, step=info.step)
    return leaves, manifest, info
