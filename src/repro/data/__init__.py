from repro.data.points import (StackedBatch, make_batch, make_vanilla_batch,
                               stack_batches)
