"""Collocation / boundary / interface point pipeline (paper §5.1 pre-processing).

Builds the stacked, padded :class:`~repro.core.losses.SubBatch` arrays consumed by
the trainers.  Per-subdomain residual counts may differ (paper Table 3); arrays are
padded to the max and masked.  ``balance=True`` equalizes points per worker — the
straggler mitigation the paper itself suggests for its §7.6 load-imbalance problem.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domain import Decomposition, Topology
from repro.core.losses import SubBatch
from repro.core.pdes import PDE


@dataclass
class StackedBatch:
    """All SubBatch fields with a leading n_sub axis (numpy, host-side)."""

    res_pts: np.ndarray
    res_mask: np.ndarray
    data_pts: np.ndarray
    data_vals: np.ndarray
    data_comp: np.ndarray
    data_mask: np.ndarray
    iface_pts: np.ndarray
    iface_nrm: np.ndarray
    edge_mask: np.ndarray

    @property
    def n_sub(self) -> int:
        return self.res_pts.shape[0]

    def device_arrays(self) -> SubBatch:
        return SubBatch(**{k: jnp.asarray(v) for k, v in self.__dict__.items()})

    def subdomain(self, q: int) -> SubBatch:
        return SubBatch(**{k: jnp.asarray(v[q]) for k, v in self.__dict__.items()})


def _pad_stack(arrays: list[np.ndarray], n_max: int) -> tuple[np.ndarray, np.ndarray]:
    """Pad a list of (n_q, ...) arrays to (n_sub, n_max, ...) + mask."""
    shape = (len(arrays), n_max) + arrays[0].shape[1:]
    out = np.zeros(shape, np.float32)
    mask = np.zeros((len(arrays), n_max), np.float32)
    for q, a in enumerate(arrays):
        out[q, : len(a)] = a
        mask[q, : len(a)] = 1.0
    return out, mask


def make_batch(
    decomp: Decomposition,
    topo: Topology,
    pde: PDE,
    n_res: int | Sequence[int],
    n_bnd: int,
    rng: np.random.Generator,
    n_interior_data: int = 0,
    balance: bool = False,
) -> StackedBatch:
    """Sample all training points (paper §5.1: once, in pre-processing).

    n_res: residual points per subdomain (int) or per-subdomain counts (Table 3).
    n_bnd: boundary points per subdomain owning a piece of the global boundary.
    n_interior_data: interior observation points per subdomain (inverse problems).
    balance: override heterogeneous counts with their mean (straggler mitigation).
    """
    n = decomp.n_sub
    res_counts = [int(n_res)] * n if np.isscalar(n_res) else [int(c) for c in n_res]
    if balance:
        res_counts = [int(np.mean(res_counts))] * n

    res_list, data_pts_l, data_val_l, data_comp_l = [], [], [], []
    for q in range(n):
        res_list.append(decomp.sample_interior(q, res_counts[q], rng).astype(np.float32))
        # boundary data (Dirichlet/IC per PDE)
        bpts = decomp.sample_boundary(q, n_bnd, rng)
        if len(bpts):
            vals, comp, keep = pde.boundary_data(bpts)
            sel = keep > 0
            bpts, vals, comp = bpts[sel], vals[sel], comp[sel]
        else:
            vals = np.zeros((0, pde.n_fields), np.float32)
            comp = np.zeros((0, pde.n_fields), np.float32)
        # interior observations (inverse problems)
        if n_interior_data > 0 and hasattr(pde, "interior_data"):
            ipts = decomp.sample_interior(q, n_interior_data, rng)
            ivals, icomp = pde.interior_data(ipts)
            bpts = np.concatenate([bpts, ipts]) if len(bpts) else ipts
            vals = np.concatenate([vals, ivals])
            comp = np.concatenate([comp, icomp])
        data_pts_l.append(np.asarray(bpts, np.float32).reshape(-1, decomp.dim))
        data_val_l.append(np.asarray(vals, np.float32))
        data_comp_l.append(np.asarray(comp, np.float32))

    res_pts, res_mask = _pad_stack(res_list, max(res_counts))
    n_data_max = max(1, max(len(a) for a in data_pts_l))
    data_pts, data_mask = _pad_stack(data_pts_l, n_data_max)
    data_vals, _ = _pad_stack(data_val_l, n_data_max)
    data_comp, _ = _pad_stack(data_comp_l, n_data_max)

    return StackedBatch(
        res_pts=res_pts, res_mask=res_mask,
        data_pts=data_pts, data_vals=data_vals, data_comp=data_comp, data_mask=data_mask,
        iface_pts=topo.iface_points.astype(np.float32),
        iface_nrm=topo.iface_normal.astype(np.float32),
        edge_mask=topo.edge_mask.astype(np.float32),
    )


def stack_batches(batches: Sequence[SubBatch]) -> SubBatch:
    """Stack per-step SubBatches along a NEW leading chunk axis.

    The result feeds ``trainer.run_chunk(state, stacked)`` (steps=None): the
    scanned epoch driver consumes one batch per outer step — e.g. freshly
    resampled collocation points — while still compiling to a single dispatch.
    All batches must share the padded layout (same point counts).
    """
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *batches)


def make_vanilla_batch(
    decomp: Decomposition, pde: PDE, n_res: int, n_bnd: int, rng: np.random.Generator
) -> SubBatch:
    """Single-domain PINN batch (eq. 3 baseline): all points pooled, no interfaces."""
    sb = make_batch(decomp, _dummy_topo(decomp), pde, n_res, n_bnd, rng)
    flat = lambda a: a.reshape((-1,) + a.shape[2:])
    return SubBatch(
        res_pts=jnp.asarray(flat(sb.res_pts)), res_mask=jnp.asarray(flat(sb.res_mask)),
        data_pts=jnp.asarray(flat(sb.data_pts)), data_vals=jnp.asarray(flat(sb.data_vals)),
        data_comp=jnp.asarray(flat(sb.data_comp)), data_mask=jnp.asarray(flat(sb.data_mask)),
        iface_pts=jnp.zeros((1, 1, decomp.dim)), iface_nrm=jnp.zeros((1, 1, decomp.dim)),
        edge_mask=jnp.zeros((1,)),
    )


def _dummy_topo(decomp: Decomposition) -> "Topology":
    from repro.core.domain import Topology

    n = decomp.n_sub
    return Topology(
        n_sub=n, n_slots=1, n_iface=1, dim=decomp.dim,
        neighbor=np.full((n, 1), -1, np.int32), edge_mask=np.zeros((n, 1), np.float32),
        iface_points=np.zeros((n, 1, 1, decomp.dim)),
        iface_normal=np.ones((n, 1, 1, decomp.dim)),
        perms=[[]],
    )
