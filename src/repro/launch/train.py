"""Training launcher — PINN (the paper's workload) and LM (the arch zoo).

PINN (end-to-end driver for the paper's experiments):
  python -m repro.launch.train pinn --pde burgers1d --method xpinn \
      --nx 4 --nt 2 --steps 2000 --ckpt-dir /tmp/run --resume

LM (synthetic-token pipeline; reduced configs run on CPU):
  python -m repro.launch.train lm --arch llama3.2-1b --reduced \
      --steps 50 --batch 4 --seq 256 --ckpt-dir /tmp/lm --resume

Both paths checkpoint every ``--ckpt-every`` steps and resume bitwise with
``--resume`` (fault-tolerance contract; see runtime/failures.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core import (
    Burgers1D, CartesianDecomposition, DDConfig, DistributedDDTrainer,
    HeatConduction2D, LossWeights, NavierStokes2D, ReferenceTrainer,
    build_topology, evaluate_l2, us_map_decomposition,
)
from repro.core.losses import METHODS
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.pdes import REGISTRY as PDE_REGISTRY
from repro.data import make_batch
from repro.models import build_model, make_batch as make_lm_batch
from repro.optim import adam as adam_lib


# ------------------------------------------------------------------------ PINN

def run_pinn(args) -> dict:
    pde = PDE_REGISTRY[args.pde]()
    if args.pde == "heat2d_inverse":
        decomp = us_map_decomposition()
        nets = {
            "u": MLPConfig(2, 1, args.width, args.depth),
            "k": MLPConfig(2, 1, args.width, args.depth),
        }
        n_interior = args.n_data
    else:
        if args.pde == "burgers1d":
            bounds = ((-1.0, 1.0), (0.0, 1.0))
        elif args.pde == "euler1d":
            bounds = ((0.0, 1.0), (0.0, 0.2))   # Sod shock tube, t in [0, 0.2]
        else:
            bounds = ((0.0, 1.0), (0.0, 1.0))
        decomp = CartesianDecomposition(bounds, args.nx, args.nt)
        nets = {"u": MLPConfig(2, pde.n_fields, args.width, args.depth)}
        n_interior = 0
    topo = build_topology(decomp, args.n_iface)
    model_cfg = SubdomainModelConfig(nets=nets)
    rng = np.random.default_rng(args.seed)
    batch = make_batch(decomp, topo, pde, args.n_res, args.n_bnd, rng,
                       n_interior_data=n_interior, balance=args.balance)

    dd = DDConfig(method=METHODS[args.method], weights=LossWeights(),
                  couple_gradients=args.couple, local_steps=args.local_steps)
    cls = DistributedDDTrainer if (args.distributed and
                                   len(jax.devices()) >= topo.n_sub) else ReferenceTrainer
    trainer = cls(pde, model_cfg, topo, dd, lrs=args.lr)
    state = trainer.init(args.seed)
    b = batch.device_arrays()
    if cls is DistributedDDTrainer:
        state, b = trainer.shard_state(state), trainer.shard_batch(b)

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, meta = ckpt.restore(args.ckpt_dir, {"params": state.params, "opt": state.opt})
        state.params, state.opt = tree["params"], tree["opt"]
        start = meta["step"]
        print(f"[train] resumed from step {start}")

    t0, terms = time.time(), None
    for s in range(start, args.steps):
        state, terms = trainer.step(state, b)
        if (s + 1) % args.log_every == 0:
            loss = float(np.asarray(terms["loss"]).sum())
            print(f"[train] step {s+1}/{args.steps} loss={loss:.5f} "
                  f"({(s + 1 - start) / (time.time() - t0):.1f} it/s)")
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s + 1,
                      {"params": state.params, "opt": state.opt},
                      {"step": s + 1, "pde": args.pde, "method": args.method})
    out = {"loss": float(np.asarray(terms["loss"]).sum()) if terms else None}
    if pde.exact(np.zeros((1, 2))) is not None:
        err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes, pde)
        out["rel_l2"] = err
        print(f"[train] rel L2 error vs exact: {err:.4f}")
    return out


# -------------------------------------------------------------------------- LM

def run_lm(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.preset == "100m":
        cfg = dataclasses.replace(
            cfg.reduced(), n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
            head_dim=64, d_ff=2048, vocab=32000, remat=False)
    model = build_model(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")

    params = model.init(jax.random.PRNGKey(args.seed))
    opt = adam_lib.init_adam(params)

    @jax.jit
    def train_step(params, opt, batch, step):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, gn = adam_lib.clip_by_global_norm(grads, 1.0)
        lr = adam_lib.warmup_cosine(step, args.lr, warmup=20, total=args.steps)
        params, opt = adam_lib.adam_update(grads, opt, params, lr)
        return params, opt, loss, gn

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        tree, meta = ckpt.restore(args.ckpt_dir, {"params": params, "opt": opt})
        params, opt = tree["params"], tree["opt"]
        start = meta["step"]
        print(f"[train] resumed from step {start}")

    t0, losses = time.time(), []
    for s in range(start, args.steps):
        batch = make_lm_batch(cfg, shape, "train", seed=args.seed * 100003 + s)
        params, opt, loss, gn = train_step(params, opt, batch, jnp.asarray(s))
        losses.append(float(loss))
        if (s + 1) % args.log_every == 0:
            print(f"[train] step {s+1}/{args.steps} loss={float(loss):.4f} "
                  f"gnorm={float(gn):.3f} ({(s+1-start)/(time.time()-t0):.2f} it/s)")
        if args.ckpt_dir and (s + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, s + 1, {"params": params, "opt": opt},
                      {"step": s + 1, "arch": args.arch})
    return {"final_loss": losses[-1] if losses else None, "losses": losses}


def main():
    ap = argparse.ArgumentParser()
    sub = ap.add_subparsers(dest="mode", required=True)

    pp = sub.add_parser("pinn")
    pp.add_argument("--pde", default="burgers1d", choices=sorted(PDE_REGISTRY))
    pp.add_argument("--method", default="xpinn", choices=["cpinn", "xpinn"])
    pp.add_argument("--nx", type=int, default=4)
    pp.add_argument("--nt", type=int, default=1)
    pp.add_argument("--width", type=int, default=20)
    pp.add_argument("--depth", type=int, default=5)
    pp.add_argument("--n-res", type=int, default=1000)
    pp.add_argument("--n-bnd", type=int, default=80)
    pp.add_argument("--n-iface", type=int, default=20)
    pp.add_argument("--n-data", type=int, default=200)
    pp.add_argument("--steps", type=int, default=500)
    pp.add_argument("--lr", type=float, default=8e-4)
    pp.add_argument("--seed", type=int, default=0)
    pp.add_argument("--couple", action="store_true")
    pp.add_argument("--balance", action="store_true")
    pp.add_argument("--local-steps", type=int, default=1)
    pp.add_argument("--distributed", action="store_true")
    pp.add_argument("--ckpt-dir", default=None)
    pp.add_argument("--ckpt-every", type=int, default=100)
    pp.add_argument("--log-every", type=int, default=50)
    pp.add_argument("--resume", action="store_true")

    lp = sub.add_parser("lm")
    lp.add_argument("--arch", default="llama3.2-1b")
    lp.add_argument("--reduced", action="store_true")
    lp.add_argument("--preset", default=None, choices=[None, "100m"])
    lp.add_argument("--steps", type=int, default=50)
    lp.add_argument("--batch", type=int, default=4)
    lp.add_argument("--seq", type=int, default=256)
    lp.add_argument("--lr", type=float, default=3e-4)
    lp.add_argument("--seed", type=int, default=0)
    lp.add_argument("--ckpt-dir", default=None)
    lp.add_argument("--ckpt-every", type=int, default=25)
    lp.add_argument("--log-every", type=int, default=10)
    lp.add_argument("--resume", action="store_true")

    args = ap.parse_args()
    if args.mode == "pinn":
        run_pinn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
