"""Resilient PINN field-serving process (the paper's §7.6 field as a service).

  python -m repro.launch.serve_field --bundle exported_dir --rate 50 \
      --duration 10 --deadline 0.5

Drives a :class:`~repro.serve.resilience.ResilientFrontend` over an exported
field bundle (or a built-in demo bundle) under Poisson-arrival traffic, with
the full production lifecycle:

* **health/readiness heartbeat** — one JSON line per ``--heartbeat`` seconds
  on stderr (breaker state, queue pressure, ladder level, staged latency
  percentiles: queue wait / dispatch / end-to-end); ``--status-file``
  additionally publishes the same snapshot atomically for external probes
  (a readiness check is ``json.load(status)["ready"]``) — the status schema
  is documented in README.md §Serving telemetry;
* **metrics + JSONL events** — ``--obs-jsonl`` streams schema-validated
  events (manifest, heartbeats, final serve_report + metrics snapshot) to a
  file via :mod:`repro.obs`; the registry spans the resilience layer and the
  inner frontend, so one snapshot carries ``serve.resilience/*`` and
  ``serve.frontend/*`` together;
* **graceful draining** — SIGINT/SIGTERM (or the end of ``--duration``) stops
  admission (late submits are answered ``shed: draining``), flushes every
  queued request, then prints a final JSON report;
* **watchdog bundle reload** — SIGHUP re-reads ``--bundle`` from disk,
  VERIFIES the newest generation's integrity envelope
  (:mod:`repro.checkpoint.integrity`) and hot-swaps it into the live engine
  (result cache invalidated); a corrupt candidate is REFUSED — the old
  bundle keeps serving, a ``corruption`` event + heartbeat field record the
  refusal — so a torn re-export can never take down (or poison) a healthy
  server;
* **fault injection** — ``--faults engine-raise@3,slow-engine@7*0.2,...``
  wraps the engine in the serve-side fault matrix
  (:class:`repro.runtime.failures.FaultyEngine`) so the resilience ladder can
  be exercised end to end in a real process.

Exit code 0 iff every admitted ticket was answered (the resilience
invariant).  NOTE: this serves PINN *fields*; the LLM decoding scaffold lives
in :mod:`repro.launch.serve`.
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

import numpy as np


def _demo_bundle(kind: str = "usmap", seed: int = 0):
    """In-process demo bundles so the server runs without a prior export."""
    import jax
    from repro.core import CartesianDecomposition, us_map_decomposition
    from repro.core.nets import MLPConfig, SubdomainModelConfig, stacked_init
    from repro.core.pdes import Burgers1D, HeatConduction2D
    from repro.serve import FieldBundle

    if kind == "cart":
        dec = CartesianDecomposition(((-1, 1), (0, 1)), 2, 2)
        cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 12, 2)})
        params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed))
        return FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                           act_codes=np.asarray(codes), pde=Burgers1D())
    dec = us_map_decomposition()
    acts = ["tanh", "sin", "cos", "tanh", "sin", "cos", "tanh", "sin",
            "cos", "tanh"]
    cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 3),
                                     "k": MLPConfig(2, 1, 24, 3)})
    params, codes = stacked_init(cfg, dec.n_sub, jax.random.PRNGKey(seed),
                                 acts)
    return FieldBundle(model_cfg=cfg, params=params, decomp=dec,
                       act_codes=np.asarray(codes), pde=HeatConduction2D())


def _cloud_sampler(decomp, seed: int):
    """Workload mix: mostly fresh random clouds, ~30% repeated dashboard
    grids (cache-hit traffic), sizes spanning two orders of magnitude."""
    rng = np.random.default_rng(seed)
    if getattr(decomp, "polygons", None) is not None:
        verts = np.concatenate(decomp.polygons)
        lo, hi = verts.min(axis=0), verts.max(axis=0)
    else:
        lo = np.array([b[0] for b in decomp.bounds], float)
        hi = np.array([b[1] for b in decomp.bounds], float)
    side = 16
    gx, gy = np.meshgrid(np.linspace(lo[0], hi[0], side),
                         np.linspace(lo[1], hi[1], side))
    dashboards = [np.stack([gx.ravel(), gy.ravel()], axis=1)]

    def sample():
        if rng.uniform() < 0.3:
            return dashboards[0]
        n = int(rng.choice((32, 128, 512)))
        return rng.uniform(lo, hi, size=(n, 2))

    return sample


def _write_status(path: str, payload: dict) -> None:
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, path)   # atomic: probes never read a torn file


def _latency_summary(frontend) -> dict:
    """Compact staged-latency block for heartbeats/status: p50/p99/count per
    stage (full histogram snapshots stay in ``stats()['latency']``)."""
    out = {}
    for stage, h in frontend.stats()["latency"].items():
        out[stage] = {"p50": h["p50"], "p99": h["p99"], "count": h["count"]}
    return out


def reload_bundle(frontend, bundle_dir: str, max_fallback: int = 0) -> dict:
    """Verify-then-hot-swap the serving bundle (the watchdog reload).

    Loads the newest generation under ``bundle_dir`` with verification ON;
    on success the live engine's bundle is swapped in place and the result
    cache invalidated (stale arrays must not answer for the new field).  On
    ANY verification/decode failure the swap is REFUSED: the frontend keeps
    serving the old bundle untouched, and the returned report (plus a
    ``corruption`` obs event when a sink is attached) records why.  Returns
    ``{"swapped": bool, "path", "step"|"error"}``.
    """
    from repro.serve.export import CorruptBundleError, load_bundle

    obs = getattr(frontend, "obs", None)
    try:
        bundle = load_bundle(bundle_dir, max_fallback=max_fallback)
    except (CorruptBundleError, FileNotFoundError, ValueError) as e:
        if obs is not None:
            obs.emit("corruption", target="bundle", reason=str(e))
            obs.emit("bundle_swap", swapped=False, path=str(bundle_dir))
        return {"swapped": False, "path": str(bundle_dir), "error": str(e)}
    step = int(bundle.metadata.get("step", -1)) if isinstance(
        bundle.metadata, dict) and "step" in bundle.metadata else None
    frontend.engine.swap_bundle(bundle)
    # the inner ServeFrontend owns the result cache (ResilientFrontend wraps
    # one as ._fe); a bare ServeFrontend is its own cache owner
    getattr(frontend, "_fe", frontend).invalidate_cache()
    if obs is not None:
        obs.emit("bundle_swap", swapped=True, path=str(bundle_dir))
    return {"swapped": True, "path": str(bundle_dir),
            **({"step": step} if step is not None else {})}


def run_server(frontend, sample_cloud, *, rate: float, duration: float,
               deadline: float | None = None, heartbeat: float = 1.0,
               status_file: str | None = None, seed: int = 0,
               max_requests: int | None = None, trace_path: str | None = None,
               bundle_dir: str | None = None,
               clock=time.monotonic, sleep=time.sleep) -> dict:
    """The serving loop: Poisson admission -> poll/flush -> heartbeat ->
    drain.  Returns the final report dict (also printed as JSON).

    Heartbeats and the status file carry the frontend health snapshot plus a
    ``latency`` block (p50/p99/count per stage: queue wait, dispatch, e2e)
    and — when the frontend's obs carries a tracer — a ``trace`` block
    (sampling counts, span buffer watermark).  When the frontend carries an
    event sink (``ResilientFrontend(obs=...)`` with a JSONL path), each
    heartbeat and the final report are also emitted as schema-validated
    events.  ``trace_path`` exports the span buffer as Chrome-trace JSON at
    shutdown (open it at https://ui.perfetto.dev)."""
    rng = np.random.default_rng(seed + 1)
    stop = {"sig": None, "reload": False}
    tracer = getattr(getattr(frontend, "obs", None), "tracer", None)
    reloads = {"swapped": 0, "refused": 0, "last": None}

    def _on_signal(signum, _frame):
        stop["sig"] = signum

    def _on_hup(_signum, _frame):
        stop["reload"] = True   # handled on the loop, not in the handler

    old = {s: signal.signal(s, _on_signal)
           for s in (signal.SIGINT, signal.SIGTERM)}
    if bundle_dir is not None and hasattr(signal, "SIGHUP"):
        old[signal.SIGHUP] = signal.signal(signal.SIGHUP, _on_hup)
    tickets: list[int] = []
    t0 = clock()
    next_arrival, next_beat = t0, t0
    try:
        while stop["sig"] is None and clock() - t0 < duration and \
                (max_requests is None or len(tickets) < max_requests):
            if stop["reload"]:
                stop["reload"] = False
                rep = reload_bundle(frontend, bundle_dir)
                reloads["swapped" if rep["swapped"] else "refused"] += 1
                reloads["last"] = rep
                print(json.dumps({"reload": rep}), file=sys.stderr, flush=True)
            now = clock()
            if now >= next_arrival:
                tickets.append(frontend.submit(sample_cloud(),
                                               deadline=deadline))
                next_arrival += rng.exponential(1.0 / rate)
            else:
                frontend.poll()
                sleep(min(max(next_arrival - now, 0.0), 0.005))
            if now >= next_beat:
                h = {**frontend.health(),
                     "latency": _latency_summary(frontend)}
                if bundle_dir is not None:
                    h["reloads"] = dict(reloads)
                if tracer is not None:
                    h["trace"] = tracer.stats()
                print(json.dumps({"t": round(now - t0, 3), **h}),
                      file=sys.stderr, flush=True)
                if status_file:
                    _write_status(status_file, h)
                obs = getattr(frontend, "obs", None)
                if obs is not None:
                    obs.emit("heartbeat", status=h["status"])
                next_beat += heartbeat
    finally:
        for s, h in old.items():
            signal.signal(s, h)

    # graceful shutdown: stop admitting, answer everything queued, report
    health = frontend.drain()
    results = [frontend.result(t) for t in tickets]
    lat = sorted(r.latency for r in results if r.ok and r.latency is not None)
    pct = lambda p: (round(lat[min(len(lat) - 1,
                                   int(p / 100 * len(lat)))], 4)
                     if lat else None)
    by_status: dict = {}
    for r in results:
        by_status[r.status] = by_status.get(r.status, 0) + 1
    report = {
        "requests": len(tickets),
        "by_status": by_status,
        "p50_s": pct(50), "p99_s": pct(99),
        "latency": _latency_summary(frontend),
        "goodput": (sum(1 for r in results if r.ok) / len(tickets)
                    if tickets else 1.0),
        "degraded_frac": (sum(1 for r in results if r.degraded) / len(tickets)
                          if tickets else 0.0),
        "drained": health,
        "stats": {k: v for k, v in frontend.stats().items()
                  if k != "frontend"},
        "signal": stop["sig"],
    }
    if bundle_dir is not None:
        report["reloads"] = dict(reloads)
    if tracer is not None:
        report["trace"] = tracer.stats()
        if trace_path:
            from repro.obs import export_chrome_trace
            report["trace"]["export"] = export_chrome_trace(
                trace_path, tracer.spans(),
                process_name="serve_field")
            report["trace"]["path"] = trace_path
    if status_file:
        _write_status(status_file, {**health, "final": True,
                                    "latency": report["latency"],
                                    **({"trace": tracer.stats()}
                                       if tracer is not None else {})})
    obs = getattr(frontend, "obs", None)
    if obs is not None:
        obs.emit("serve_report", requests=len(tickets),
                 goodput=report["goodput"])
        if obs.events is not None:
            obs.emit("metrics", snapshot=obs.registry.snapshot())
    print(json.dumps(report, indent=1))
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="serve a PINN field bundle with resilience "
                    "(admission control, deadlines, degraded modes)")
    ap.add_argument("--bundle", default=None,
                    help="exported bundle dir (repro.serve.export); "
                         "omit for --demo")
    ap.add_argument("--demo", default="usmap", choices=("usmap", "cart"),
                    help="built-in demo bundle when --bundle is omitted")
    ap.add_argument("--rate", type=float, default=20.0, help="requests/s")
    ap.add_argument("--duration", type=float, default=5.0, help="seconds")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-request deadline seconds")
    ap.add_argument("--order", type=int, default=2, choices=(1, 2))
    ap.add_argument("--max-requests", type=int, default=None)
    ap.add_argument("--queue-requests", type=int, default=256)
    ap.add_argument("--queue-points", type=int, default=1 << 20)
    ap.add_argument("--queue-age", type=float, default=0.02,
                    help="flush once the queue head is this old (s)")
    ap.add_argument("--faults", default=None,
                    help="serve fault matrix, e.g. "
                         "'engine-raise@3,nan-output@5,slow-engine@7*0.2,"
                         "compile-storm@9'")
    ap.add_argument("--heartbeat", type=float, default=1.0)
    ap.add_argument("--status-file", default=None,
                    help="atomically published health JSON for probes")
    ap.add_argument("--obs-jsonl", default=None,
                    help="stream schema-validated obs events (manifest, "
                         "heartbeats, serve_report, metrics) to this JSONL")
    ap.add_argument("--trace", default=None,
                    help="export the span buffer as Chrome-trace JSON here "
                         "at shutdown (open in Perfetto / chrome://tracing)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="fraction of traces recorded (ids propagate on all)")
    ap.add_argument("--no-trace", action="store_true",
                    help="disable span tracing entirely")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.obs import make_obs
    from repro.serve import FieldEngine, ResilienceConfig, ResilientFrontend
    from repro.serve.export import load_bundle

    bundle = (load_bundle(args.bundle) if args.bundle
              else _demo_bundle(args.demo, args.seed))
    cfg = ResilienceConfig(order=args.order if bundle.pde is not None else 1,
                           max_queue_requests=args.queue_requests,
                           max_queue_points=args.queue_points,
                           max_queue_age=args.queue_age,
                           default_deadline=args.deadline)
    obs = make_obs(args.obs_jsonl or None, clock=time.monotonic,
                   run_id=f"serve-{args.seed}",
                   config={"rate": args.rate, "duration": args.duration,
                           "order": cfg.order, "faults": args.faults},
                   trace=not args.no_trace, trace_sample=args.trace_sample)
    # the engine shares the obs so its serve.engine/* metrics land in the
    # same registry and its span nests under the frontend's microbatch span
    engine = FieldEngine(bundle, obs=obs)
    if args.faults:
        from repro.runtime import FaultInjector, FaultyEngine, parse_faults
        engine = FaultyEngine(engine, FaultInjector(parse_faults(args.faults)))
    fe = ResilientFrontend(engine, cfg, seed=args.seed, obs=obs)
    sampler = _cloud_sampler(bundle.decomp, args.seed)
    fe.query(sampler())   # compile warmup outside the measured traffic
    try:
        report = run_server(fe, sampler, rate=args.rate,
                            duration=args.duration, deadline=args.deadline,
                            heartbeat=args.heartbeat,
                            status_file=args.status_file, seed=args.seed,
                            max_requests=args.max_requests,
                            trace_path=args.trace,
                            bundle_dir=args.bundle)
    finally:
        obs.close()
    return 0 if report["drained"]["unanswered"] == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
