import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the production
mesh WITHOUT allocating real tensors (ShapeDtypeStruct inputs only).

For each cell this records, into benchmarks/results/dryrun/:
  * compiled.memory_analysis()  — proves the per-device footprint fits,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective operand bytes parsed from the partitioned HLO,
  * lower/compile wall times and an opcode histogram.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config, active_param_count, param_count
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16, make_production_mesh
from repro.models import batch_struct, build_model
from repro.models import layers as layers_mod
from repro.models.sharding import rules_for, spec as lspec, use_rules
from repro.optim import adam as adam_lib
from repro import utils
from repro.utils import hlo as hlo_utils

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# measure true FLOPs/collectives via unrolled reduced-depth compiles (see lower_cell)
_UNROLL_MEASURE = True

_BATCH_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "loss_mask": ("batch", "seq"),
    "patch_embeds": ("batch", None, None),
    "frames": ("batch", None, None),
}


def batch_specs(batch: dict, rules) -> dict:
    return {k: lspec(*_BATCH_LOGICAL[k], rules=rules) for k in batch}


def param_structs(model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_structs(p_struct):
    return {
        "m": p_struct, "v": p_struct,
        "count": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_specs(p_specs):
    return {"m": p_specs, "v": p_specs, "count": P()}


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda v: isinstance(v, P),
    )


def _measure_layers(cfg: ModelConfig) -> tuple[int, int, float]:
    """(a, b, eval_at): reduced layer counts for the unrolled FLOP fit and the
    layer count to evaluate the affine fit at.  Exact for homogeneous stacks;
    zamba's 2-layer tail makes the fit overcount by ~1/3 shared-attn application
    (documented in EXPERIMENTS.md)."""
    if cfg.family == "hybrid":
        e = cfg.attn_every
        return e, 2 * e, cfg.n_layers
    return 2, 4, cfg.n_layers


def _with_layers(cfg: ModelConfig, n: int) -> ModelConfig:
    import dataclasses
    kw = {"n_layers": n}
    if cfg.family == "encdec":
        kw["n_dec_layers"] = n
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch: str, shape_name: str, multi_pod: bool = False,
               lr: float = 1e-4, extra_rules: dict | None = None,
               cfg_override: ModelConfig | None = None, micro_batches: int = 1,
               bf16_params: bool = False):
    """Returns (lowered, compiled, record_dict).

    Three compiles per cell:
      1. ROLLED full config — the deployable artifact: must compile; provides
         memory_analysis (loop liveness is realistic) and the HLO schedule.
      2./3. UNROLLED reduced-layer configs (a, b) — XLA cost_analysis counts
         while bodies once, so true FLOPs/collective-bytes come from unrolled
         graphs; an affine fit in n_layers extrapolates to the full depth.
    """
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    if not cfg.supports(shape):
        return None, None, {"arch": arch, "shape": shape_name, "skipped": True,
                            "reason": "quadratic attention at 524288 (see DESIGN.md)"}

    layers_mod.set_unroll_scans(False)
    lowered, compiled, rec = _lower_one(cfg, arch, shape, multi_pod, lr, extra_rules,
                                        micro_batches, bf16_params)

    if _UNROLL_MEASURE:
        a, b, L = _measure_layers(cfg)
        layers_mod.set_unroll_scans(True)
        try:
            fa = _lower_one(_with_layers(cfg, a), arch, shape, multi_pod, lr,
                            extra_rules, micro_batches, bf16_params)[2]
            fb = _lower_one(_with_layers(cfg, b), arch, shape, multi_pod, lr,
                            extra_rules, micro_batches, bf16_params)[2]
            for key in ("flops_per_device", "bytes_per_device"):
                slope = (fb[key] - fa[key]) / (b - a)
                rec[key + "_rolled_raw"] = rec[key]
                rec[key] = fa[key] + slope * (L - a)
            ca, cb = fa["collectives"], fb["collectives"]
            fit = {}
            for kind in set(ca["bytes_by_kind"]) | set(cb["bytes_by_kind"]):
                ya, yb = ca["bytes_by_kind"].get(kind, 0.0), cb["bytes_by_kind"].get(kind, 0.0)
                fit[kind] = max(0.0, ya + (yb - ya) / (b - a) * (L - a))
            rec["collectives_rolled_raw"] = rec["collectives"]
            rec["collectives"] = {"bytes_by_kind": fit,
                                  "total_bytes": float(sum(fit.values())),
                                  "counts": cb["counts"]}
            rec["flop_fit"] = {"a": a, "b": b, "eval_at": L,
                               "flops_a": fa["flops_per_device"],
                               "flops_b": fb["flops_per_device"]}
        finally:
            layers_mod.set_unroll_scans(False)
        _finalize_roofline(rec, arch, shape)
    return lowered, compiled, rec


def _lower_one(cfg: ModelConfig, arch: str, shape: ShapeConfig, multi_pod: bool,
               lr: float, extra_rules: dict | None, micro_batches: int = 1,
               bf16_params: bool = False):
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(multi_pod=multi_pod,
                      long_context=(shape.name == "long_500k"),
                      decode=(shape.kind == "decode"))
    if extra_rules:
        rules.update(extra_rules)

    rec = {"arch": arch, "shape": shape.name, "kind": shape.kind,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "n_devices": int(np.prod(mesh.devices.shape))}

    with utils.set_mesh(mesh), use_rules(rules):
        p_struct = param_structs(model)
        if bf16_params and shape.kind != "train":
            # serving checkpoints stored bf16: no per-use converts, half the reads
            p_struct = jax.tree.map(
                lambda s_: jax.ShapeDtypeStruct(s_.shape, jnp.bfloat16)
                if s_.dtype == jnp.float32 else s_, p_struct)
        p_specs = model.param_specs(rules)
        b_struct = batch_struct(cfg, shape)
        b_specs = batch_specs(b_struct, rules)

        t0 = time.time()
        if shape.kind == "train":
            o_struct = opt_structs(p_struct)

            def train_step(params, opt, batch):
                if micro_batches > 1:
                    # gradient accumulation: per-microbatch fwd+bwd, fp32 grad
                    # accumulator sharded like the params (memory lever)
                    def split(x):
                        m = micro_batches
                        return x.reshape((m, x.shape[0] // m) + x.shape[1:])
                    mb = jax.tree.map(split, batch)

                    def acc_fn(carry, mbatch):
                        g_acc, l_acc = carry
                        l, g = jax.value_and_grad(model.loss)(params, mbatch)
                        g_acc = jax.tree.map(jnp.add, g_acc, g)
                        return (g_acc, l_acc + l), None

                    g0 = jax.tree.map(jnp.zeros_like, params)
                    # unroll under measurement mode (cost_analysis counts scan
                    # bodies once; see layers_mod.set_unroll_scans)
                    (grads, loss), _ = jax.lax.scan(
                        acc_fn, (g0, 0.0), mb,
                        unroll=layers_mod._unroll(micro_batches))
                    grads = jax.tree.map(lambda g: g / micro_batches, grads)
                    loss = loss / micro_batches
                else:
                    loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_p, new_o = adam_lib.adam_update(grads, opt, params, lr)
                return new_p, new_o, loss

            fn = jax.jit(
                train_step,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, opt_specs(p_specs)),
                              _ns(mesh, b_specs)),
                out_shardings=(_ns(mesh, p_specs), _ns(mesh, opt_specs(p_specs)),
                               NamedSharding(mesh, P())),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(p_struct, o_struct, b_struct)
        elif shape.kind == "prefill":
            def prefill_step(params, batch):
                return model.prefill(params, batch)

            fn = jax.jit(
                prefill_step,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, b_specs)),
                out_shardings=NamedSharding(mesh, lspec("batch", None, "vocab", rules=rules)),
            )
            lowered = fn.lower(p_struct, b_struct)
        else:  # decode
            c_struct = model.cache_struct(shape.global_batch, shape.seq_len)
            c_specs = model.cache_specs(rules)

            def serve_step(params, cache, batch, pos):
                logits, new_cache = model.decode_step(params, cache, batch, pos)
                return logits, new_cache

            fn = jax.jit(
                serve_step,
                in_shardings=(_ns(mesh, p_specs), _ns(mesh, c_specs),
                              _ns(mesh, b_specs), NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, lspec("batch", None, "vocab", rules=rules)),
                               _ns(mesh, c_specs)),
                donate_argnums=(1,),
            )
            lowered = fn.lower(p_struct, c_struct, b_struct,
                               jax.ShapeDtypeStruct((), jnp.int32))
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

    # ---- analyses ------------------------------------------------------------
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        rec["flops_per_device"] = float(ca.get("flops", 0.0))
        rec["bytes_per_device"] = float(ca.get("bytes accessed", 0.0))
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = repr(e)
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(ma, k)
        }
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = repr(e)

    txt = compiled.as_text()
    rec["collectives"] = hlo_utils.collective_bytes(txt)
    rec["hlo_ops"] = hlo_utils.op_histogram(txt, top=15)
    _finalize_roofline(rec, arch, shape)
    return lowered, compiled, rec


def _finalize_roofline(rec: dict, arch: str, shape: ShapeConfig) -> None:
    n_dev = rec["n_devices"]
    flops = rec.get("flops_per_device", 0.0)
    membytes = rec.get("bytes_per_device", 0.0)
    coll = rec["collectives"]["total_bytes"]
    rec["roofline"] = {
        "compute_s": flops / PEAK_FLOPS_BF16,
        "memory_s": membytes / HBM_BW,
        "collective_s": coll / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    # useful-FLOP ratio: MODEL_FLOPS / (per-device HLO flops * n_devices)
    cfg_n = active_param_count(get_config(arch))
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = (6 if shape.kind == "train" else 2) * cfg_n * tokens
    rec["model_flops"] = float(mf)
    rec["model_flops_ratio"] = float(mf / max(flops * n_dev, 1.0))
    rec["param_count"] = param_count(get_config(arch))
    rec["active_param_count"] = cfg_n
    rec["ok"] = True


def run_cell(arch, shape_name, multi_pod, out_dir, skip_existing=False):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{'2x16x16' if multi_pod else '16x16'}"
    path = os.path.join(out_dir, tag + ".json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            old = json.load(f)
        if old.get("ok") or old.get("skipped"):
            print(f"[dryrun] {tag}: cached")
            return old
    try:
        _, compiled, rec = lower_cell(arch, shape_name, multi_pod)
        if compiled is not None:
            print(f"[dryrun] {tag}: OK lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"dom={rec['roofline']['dominant']}")
            ma = rec.get("memory", {})
            print(f"  memory_analysis: {ma}")
            print(f"  cost_analysis: flops/dev={rec.get('flops_per_device', 0):.3e} "
                  f"bytes/dev={rec.get('bytes_per_device', 0):.3e} "
                  f"coll/dev={rec['collectives']['total_bytes']:.3e}")
        else:
            print(f"[dryrun] {tag}: SKIP ({rec['reason']})")
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "2x16x16" if multi_pod else "16x16",
               "ok": False, "error": repr(e), "traceback": traceback.format_exc()}
        print(f"[dryrun] {tag}: FAIL {e!r}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--no-unroll", action="store_true",
                    help="skip the unrolled reduced-depth FLOP-measurement passes")
    args = ap.parse_args()
    global _UNROLL_MEASURE
    _UNROLL_MEASURE = not args.no_unroll

    archs = list(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    n_fail = 0
    for mp in meshes:
        for a in archs:
            for s in shapes:
                rec = run_cell(a, s, mp, args.out, args.skip_existing)
                if not (rec.get("ok") or rec.get("skipped")):
                    n_fail += 1
    print(f"[dryrun] done, failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
