"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so importing
this module never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE any jax import;
ordinary tests/benches see the real single device.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_pinn_mesh(n_sub: int) -> Mesh:
    """1-D mesh, one device per subdomain (Algorithm 1's communicator)."""
    devs = jax.devices()
    if len(devs) < n_sub:
        raise RuntimeError(f"PINN mesh needs {n_sub} devices, have {len(devs)}")
    return Mesh(np.array(devs[:n_sub]), ("sub",))


# TPU v5e single-chip peaks used by the roofline analysis (see EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip
HBM_BW = 819e9                # B/s per chip
ICI_BW = 50e9                 # B/s per link
