"""Batched serving driver: prefill a batch of prompts, then decode tokens.

  python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen 32

Uses the same ``decode_step`` that the decode_32k/long_500k dry-run cells lower,
so the serving path exercised here is the one proven on the production mesh.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.models import layers as L


def generate(model, params, prompts: jnp.ndarray, max_len: int, gen: int):
    """Greedy decode. prompts: (B, P) int32. Returns (B, P+gen)."""
    cfg = model.cfg
    B, P = prompts.shape
    cache = model.init_cache(B, max_len)
    if cfg.family == "encdec":
        rng = np.random.default_rng(0)
        frames = jnp.asarray(rng.normal(0, 1, (B, max(1, max_len // cfg.enc_ratio),
                                                cfg.d_model)), jnp.dtype(cfg.dtype))
        mem = model.encode(params, frames)
        cks, cvs = [], []
        for l in range(cfg.n_dec_layers):
            lp = jax.tree.map(lambda v: v[l], params["dec"])
            _, mk, mv = L.gqa_project(lp["cross_attn"], mem, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.hd, mem.dtype)
            cks.append(mk), cvs.append(mv)
        cache = dict(cache)
        cache["cross_k"], cache["cross_v"] = jnp.stack(cks), jnp.stack(cvs)

    decode = jax.jit(model.decode_step)
    toks = [prompts[:, i] for i in range(P)]
    logits = None
    for t in range(P + gen - 1):
        cur = toks[t][:, None]
        logits, cache = decode(params, cache, {"tokens": cur}, t)
        if t >= P - 1:
            toks.append(jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32))
    return jnp.stack(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                          jnp.int32)
    max_len = args.prompt_len + args.gen
    t0 = time.time()
    out = generate(model, params, prompts, max_len, args.gen)
    dt = time.time() - t0
    n_new = args.batch * args.gen
    print(f"[serve] generated {n_new} tokens in {dt:.2f}s "
          f"({n_new / dt:.1f} tok/s incl. compile)")
    print(f"[serve] sample: {np.asarray(out[0, -args.gen:])}")
    assert out.shape == (args.batch, max_len)


if __name__ == "__main__":
    main()
