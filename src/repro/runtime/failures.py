"""Failure injection + restart harness (fault-tolerance validation).

Real multi-pod jobs die: preemptions, ICI flaps, kernel panics.  The recovery
contract of this framework is *checkpoint/restart with bitwise continuation*.
This module provides a deterministic harness that proves the contract on CPU:

``run_with_failures`` drives a training loop, killing it (by raising
:class:`InjectedFailure` out of the step loop) at scheduled steps, then restarting
from the latest checkpoint — exactly what a cluster supervisor does.  The test
suite asserts the final state equals an uninterrupted run's state.

For the LM path the same contract is exercised through ``launch/train.py
--resume`` (see tests/test_checkpoint.py).
"""
from __future__ import annotations

from typing import Callable, Iterable

from repro.checkpoint import ckpt


class InjectedFailure(RuntimeError):
    pass


def run_with_failures(
    *,
    root: str,
    init_fn: Callable[[], object],
    step_fn: Callable[[object], object],
    total_steps: int,
    ckpt_every: int,
    fail_at: Iterable[int] = (),
    max_restarts: int = 16,
) -> object:
    """Run ``total_steps`` of ``step_fn`` with checkpoints every ``ckpt_every`` and
    injected crashes at the given global step numbers.  Returns the final state."""
    fail_at = sorted(set(fail_at))
    restarts = 0
    while True:
        # (re)start: restore or init
        template = init_fn()
        start = ckpt.latest_step(root)
        if start is None:
            state, start = template, 0
        else:
            state, _ = ckpt.restore(root, template)
        try:
            for s in range(start, total_steps):
                if fail_at and s == fail_at[0] and restarts <= max_restarts:
                    fail_at.pop(0)
                    raise InjectedFailure(f"injected failure at step {s}")
                state = step_fn(state)
                done = s + 1
                if done % ckpt_every == 0 or done == total_steps:
                    ckpt.save(root, done, state)
            return state
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            continue
