"""Failure injection + restart harness (fault-tolerance validation).

Real multi-pod jobs die: preemptions, ICI flaps, kernel panics.  The recovery
contract of this framework is *checkpoint/restart with bitwise continuation*.
This module provides deterministic fault injection that proves the contract on
CPU, at two granularities:

* ``run_with_failures`` — the step-granular harness: drives a training loop,
  killing it (by raising :class:`InjectedFailure` out of the step loop) at
  scheduled steps, then restarting from the latest checkpoint — exactly what a
  cluster supervisor does.  The test suite asserts the final state equals an
  uninterrupted run's state.  For the LM path the same contract is exercised
  through ``launch/train.py --resume`` (see tests/test_checkpoint.py).

* the **chunk-granular fault matrix** — :class:`Fault` / :class:`FaultInjector`
  drive the PINN trainers' single-dispatch chunk world (one ``run_chunk`` ==
  one scheduling unit), consumed by ``runtime.supervisor.Supervisor``.  Beyond
  crashes it covers the failure modes a crash-only harness can't see:

  ========== ============================================================
  kind        effect at the scheduled chunk
  ========== ============================================================
  crash       :class:`InjectedFailure` AFTER the chunk computes but BEFORE
              its checkpoint — the chunk's progress is lost (mid-chunk
              preemption)
  nan_params  NaN poked into one parameter leaf (one subdomain's slice of
              the stacked axis when ``subdomain`` is set) — the in-graph
              guard must trip within ONE chunk
  nan_grads   NaN poked into the first-moment Adam buffer: the loss stays
              finite but the NEXT update poisons the params — caught by
              the guard's param-norm check, not the loss check
  straggler   ``delay`` seconds of sleep before the chunk (simulated slow
              worker; feeds the supervisor's walltime-weighted rebalance)
  ========== ============================================================
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Iterable

import numpy as np

from repro.checkpoint import ckpt


class InjectedFailure(RuntimeError):
    pass


# ----------------------------------------------------------- step-granular


def run_with_failures(
    *,
    root: str,
    init_fn: Callable[[], object],
    step_fn: Callable[[object], object],
    total_steps: int,
    ckpt_every: int,
    fail_at: Iterable[int] = (),
    max_restarts: int = 16,
) -> object:
    """Run ``total_steps`` of ``step_fn`` with checkpoints every ``ckpt_every`` and
    injected crashes at the given global step numbers.  Returns the final state."""
    fail_at = sorted(set(fail_at))
    restarts = 0
    while True:
        # (re)start: restore or init
        template = init_fn()
        start = ckpt.latest_step(root)
        if start is None:
            state, start = template, 0
        else:
            state, _ = ckpt.restore(root, template)
        try:
            for s in range(start, total_steps):
                if fail_at and s == fail_at[0] and restarts <= max_restarts:
                    fail_at.pop(0)
                    raise InjectedFailure(f"injected failure at step {s}")
                state = step_fn(state)
                done = s + 1
                if done % ckpt_every == 0 or done == total_steps:
                    ckpt.save(root, done, state)
            return state
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            continue


# ---------------------------------------------------------- chunk-granular

FAULT_KINDS = ("crash", "nan_params", "nan_grads", "straggler")

# serve-side matrix (consumed by FaultyEngine; ``chunk`` = engine dispatch
# index — each evaluate ATTEMPT, so retries shift later indices, mirroring
# the training-side launch-indexed semantics):
#
#   ============= =========================================================
#   kind           effect at the scheduled dispatch
#   ============= =========================================================
#   engine_raise   InjectedFailure out of evaluate (poisoned query / OOM /
#                  crashed backend) — frontend must bisect + quarantine
#   nan_output     evaluation succeeds but one CLAIMED point comes back NaN
#                  (weight corruption) — the serve output guard must trip
#   slow_engine    ``delay`` seconds of injected latency before evaluating
#                  (straggling device / noisy neighbor)
#   compile_storm  the process-wide compiled-program cache is dropped: the
#                  next dispatch pays full retrace+compile (new shape class,
#                  restarted server) — a realistic tail-latency spike
#   ============= =========================================================
SERVE_FAULT_KINDS = ("engine_raise", "nan_output", "slow_engine",
                     "compile_storm")

# storage fault family (consumed by runtime.chaos: filesystem corruption of
# durable state — checkpoint generations or exported serve bundles — applied
# when the scheduled chunk/dispatch index comes due):
#
#   ============= =========================================================
#   kind           effect on the targeted generation's files
#   ============= =========================================================
#   bit_flip       one bit flipped at a seeded offset (bit rot / bad sector)
#   truncate       file cut to a seeded fraction of its length (interrupted
#                  write, filesystem shrink-on-crash)
#   torn_write     the file's tail overwritten with zero pages (power loss
#                  mid-write on a non-atomic filesystem)
#   missing_file   arrays.npz removed (lost object / failed replication)
#   ============= =========================================================
STORAGE_FAULT_KINDS = ("bit_flip", "truncate", "torn_write", "missing_file")

ALL_FAULT_KINDS = FAULT_KINDS + SERVE_FAULT_KINDS + STORAGE_FAULT_KINDS


@dataclass(frozen=True)
class Fault:
    """One scheduled fault.  ``chunk`` indexes the supervisor's chunk LAUNCHES
    (attempts, so a retry consumed by an earlier fault shifts later indices by
    design — schedules stay deterministic under recovery).  Serve-side kinds
    index engine dispatch attempts instead (see SERVE_FAULT_KINDS).  Storage
    kinds (STORAGE_FAULT_KINDS) fire at the same launch/dispatch indices but
    corrupt durable state on disk: ``target`` picks the artifact family
    ("ckpt" checkpoint root | "bundle" exported bundle root) and ``index``
    the generation, 0 = newest."""

    chunk: int
    kind: str                    # one of ALL_FAULT_KINDS
    subdomain: int | None = None  # nan_*: poison only this stacked slice
    delay: float = 0.0            # straggler/slow_engine: injected seconds
    target: str = "ckpt"          # storage kinds: "ckpt" | "bundle"
    index: int = 0                # storage kinds: generation index, 0=newest

    def __post_init__(self):
        if self.kind not in ALL_FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"train {FAULT_KINDS}, serve {SERVE_FAULT_KINDS}, or "
                f"storage {STORAGE_FAULT_KINDS}")
        if self.kind in STORAGE_FAULT_KINDS and self.target not in (
                "ckpt", "bundle"):
            raise ValueError(
                f"storage fault target {self.target!r} must be 'ckpt' or "
                f"'bundle'")


class FaultInjector:
    """Deterministic chunk-granular fault schedule (consumed once)."""

    def __init__(self, faults: Iterable[Fault] = ()):
        self._due = sorted(faults, key=lambda f: f.chunk)
        self.fired: list[Fault] = []

    def take(self, chunk_idx: int) -> list[Fault]:
        """Faults due at this chunk launch; each fires exactly once."""
        due = [f for f in self._due if f.chunk == chunk_idx]
        if due:
            self._due = [f for f in self._due if f.chunk != chunk_idx]
            self.fired.extend(due)
        return due

    @property
    def exhausted(self) -> bool:
        return not self._due


def parse_faults(spec: str) -> list[Fault]:
    """Parse a CLI fault schedule: ``kind@chunk[:subdomain][*delay]`` items,
    comma-separated — e.g. ``crash@1,nan_params@2:0,straggler@3*0.2``, the
    serve-side ``engine-raise@2,slow-engine@5*0.1``, or the storage family
    ``bit-flip@2,bundle.truncate@3:1`` (``[target.]kind@chunk[:index]``;
    target defaults to ``ckpt``, ``:n`` is the generation index, 0=newest).
    Hyphens and underscores in kind names are interchangeable.

    Unknown kinds and malformed items raise a :class:`ValueError` that lists
    every allowed kind — a silent or cryptic parse here is a debugging trap
    in the middle of a chaos run."""
    out = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        kind, at, rest = item.partition("@")
        kind = kind.replace("-", "_")
        target, dot, bare = kind.partition(".")
        if dot and target in ("ckpt", "bundle"):
            kind = bare
        else:
            target = "ckpt"
        if kind not in ALL_FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {kind!r} in {item!r}; allowed kinds: "
                f"train {FAULT_KINDS}, serve {SERVE_FAULT_KINDS}, "
                f"storage {STORAGE_FAULT_KINDS} "
                f"(syntax: [ckpt.|bundle.]kind@chunk[:subdomain|:index]"
                f"[*delay])")
        rest, _, delay = rest.partition("*")
        rest, _, sub = rest.partition(":")
        if not at or not rest.strip().lstrip("-").isdigit():
            raise ValueError(
                f"malformed fault item {item!r}: expected "
                f"[target.]kind@chunk[:subdomain][*delay] with an integer "
                f"chunk index")
        idx = int(sub) if sub else None
        out.append(Fault(chunk=int(rest), kind=kind,
                         subdomain=idx,
                         delay=float(delay) if delay else 0.25,
                         target=target,
                         index=idx if idx is not None else 0))
    return out


# -------------------------------------------------------------- serve-side


class FaultyEngine:
    """Wrap a serving engine with a deterministic dispatch-indexed fault
    schedule (the serve half of the fault matrix; kinds in
    SERVE_FAULT_KINDS).  Transparent otherwise: attribute access delegates to
    the wrapped engine, so frontends see bundle/counters as usual.

    ``sleep`` is injectable so ``slow_engine`` can advance a virtual clock in
    benchmarks instead of really sleeping."""

    def __init__(self, engine, injector: FaultInjector, sleep=None):
        import time
        self.engine = engine
        self.injector = injector
        self._sleep = sleep if sleep is not None else time.sleep
        self.calls = 0

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def evaluate(self, pts, order: int = 2) -> dict:
        idx = self.calls
        self.calls += 1
        due = self.injector.take(idx)
        for f in due:
            if f.kind == "slow_engine":
                self._sleep(f.delay)
            elif f.kind == "compile_storm":
                from repro.serve import engine as engine_mod
                engine_mod._EVAL_CACHE.clear()
            elif f.kind == "engine_raise":
                raise InjectedFailure(
                    f"injected engine_raise at dispatch {idx}")
        out = self.engine.evaluate(pts, order=order)
        for f in due:
            if f.kind == "nan_output":
                u = np.array(out["u"])  # stitched output: poison one CLAIMED
                finite = np.isfinite(u.reshape(len(u), -1)).all(axis=1)
                row = int(np.argmax(finite)) if finite.any() else 0
                u[row] = np.nan
                out = dict(out, u=u)
        return out


def inject_nan(tree: dict, kind: str, subdomain: int | None = None) -> dict:
    """Host-side NaN corruption of a state tree (``{"params", "opt", ...}``).

    ``nan_params`` poisons the first parameter leaf; ``nan_grads`` poisons the
    first Adam first-moment leaf (the next update turns the params non-finite,
    which the in-graph guard's param check catches even though the loss it just
    computed was finite).  With ``subdomain`` set, only that slice of the
    stacked leading axis is poisoned, so guard attribution is testable."""
    import jax
    import jax.numpy as jnp

    if kind not in ("nan_params", "nan_grads"):
        raise ValueError(f"inject_nan: not a NaN fault: {kind!r}")
    target = tree["params"] if kind == "nan_params" else tree["opt"]["m"]
    leaves, treedef = jax.tree_util.tree_flatten(target)
    x = np.array(leaves[0], copy=True)
    if subdomain is not None and x.ndim >= 1 and subdomain < x.shape[0]:
        x[(subdomain,) + (0,) * (x.ndim - 1)] = np.nan
    else:
        x.flat[0] = np.nan
    leaves = [jnp.asarray(x)] + list(leaves[1:])
    poisoned = jax.tree_util.tree_unflatten(treedef, leaves)
    out = dict(tree)
    if kind == "nan_params":
        out["params"] = poisoned
    else:
        out["opt"] = dict(tree["opt"])
        out["opt"]["m"] = poisoned
    return out
