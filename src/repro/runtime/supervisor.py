"""Chunk-level training supervisor: guarded chunks, rollback, elastic restart.

The paper's premise is long-running distributed DD-PINN jobs; at that scale
restarts are the common case.  This module is the production control loop that
sits ABOVE the trainers' single-dispatch chunk drivers and below nothing — it
is what a cluster job actually runs:

::

                      +--------------------------- retry (lr backoff) ---+
                      v                                                  |
    init/resume -> [run chunk (guarded, 1 dispatch)] -- guard trip ------+
         ^            | ok                            \\-- InjectedFailure
         |            v                                   (crash): restore,
         |         [checkpoint cadence + metadata]        retry at full lr
         |            |
         +- elastic --+   (n_old != n_new: nearest-centroid remap,
            restart        fresh moments, Adam count from metadata)

Design decisions:

* **Health lives in-graph.**  ``trainer.run_chunk_guarded`` detects non-finite
  loss/params inside the ``lax.scan`` body and freezes the carried state via
  ``lax.cond`` — the supervisor only ever sees one dispatch per chunk and a
  (n_sub,) verdict.  No per-step host sync, no donation break.
* **Crash vs divergence are different failures.**  A crash
  (:class:`~repro.runtime.failures.InjectedFailure`, i.e. preemption) restores
  the last good checkpoint and retries AT FULL learning rate — replaying the
  identical chunk reproduces the uninterrupted trajectory bitwise (tested).  A
  guard trip is a NUMERICS failure: the retry applies per-subdomain
  learning-rate backoff (the paper's per-subdomain hparam freedom, applied to
  recovery) to exactly the subdomains whose loss/params went non-finite.
* **Backoff never recompiles.**  ``lr_scale`` is a plain (n_sub,) argument of
  the guarded dispatch.
* **Rollback never trusts the disk.**  Every restore goes through
  :func:`repro.checkpoint.integrity.verified_restore`: a corrupt latest
  checkpoint (bit rot, torn write, truncation, lost file) is quarantined —
  renamed, never deleted — and the walk falls back to the newest VERIFIED
  generation, costing one generation of progress instead of the run.
  Corruption/fallback land in the report, the ``train.supervisor/*``
  counters, and the JSONL event stream.
* **Elastic resume is metadata-driven.**  Every checkpoint carries the
  decomposition signature (n_sub + centroids), the restart/backoff state, and
  the Adam step count; :func:`elastic_resume` restores a checkpoint taken at
  ``n_old`` subdomains into a trainer built for ``n_new`` via nearest-centroid
  :func:`~repro.runtime.elastic.remap_params`, with fresh moments and the
  preserved per-subdomain Adam counts.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt, integrity
from repro.obs import MetricsRegistry, Obs
from repro.optim import adam as adam_lib
from repro.runtime import elastic
from repro.runtime.failures import FaultInjector, InjectedFailure, inject_nan


@dataclass(frozen=True)
class SupervisorConfig:
    chunk_steps: int = 100          # outer steps per guarded dispatch
    ckpt_every_chunks: int = 1      # checkpoint cadence, in committed chunks
    keep: int = 3                   # keep-last-k checkpoints
    max_restarts: int = 8           # total rollback budget (crash + guard)
    lr_backoff: float = 0.5         # per-subdomain lr scale on a guard trip
    min_lr_scale: float = 1e-3      # give up backing off below this
    walltime_window: int = 16       # chunk walltimes kept in ckpt metadata


@dataclass
class SupervisorReport:
    chunks: int = 0                 # committed chunks
    restarts: int = 0               # rollbacks performed (crash + guard)
    crashes: int = 0                # InjectedFailure recoveries
    guard_trips: int = 0            # in-graph guard recoveries
    stragglers: int = 0             # straggler faults absorbed
    corruptions: int = 0            # corrupt generations quarantined
    walltimes: list = field(default_factory=list)   # committed-chunk seconds
    recovery_s: list = field(default_factory=list)  # rollback->retried latency
    fallback_depths: list = field(default_factory=list)  # per-rollback depth
    events: list = field(default_factory=list)      # human-readable log

    def as_dict(self) -> dict:
        return {k: (list(v) if isinstance(v, list) else v)
                for k, v in self.__dict__.items()}


def _as_tree(state) -> dict:
    """Trainer state -> checkpointable tree.  TrainState (Reference /
    Distributed) and the DataParallel dict share the {"params","opt","step"}
    layout, so supervisor checkpoints stay interchangeable with
    ``save_train_state`` / ``restore_train_state``."""
    if isinstance(state, dict):
        return state
    return {"params": state.params, "opt": state.opt, "step": state.step}


def _from_tree(tree: dict, like):
    if isinstance(like, dict):
        return tree
    from repro.core.trainer import TrainState

    return TrainState(params=tree["params"], opt=tree["opt"], step=tree["step"])


def _adam_count(tree: dict):
    c = np.asarray(tree["opt"]["count"])
    return c.tolist() if c.ndim else int(c)


def decomp_signature(decomp) -> dict:
    """What elastic restart needs to survive in metadata: the subdomain count
    and centroids (nearest-centroid remap needs nothing else)."""
    return {
        "n_sub": decomp.n_sub,
        "family": type(decomp).__name__,
        "centroids": [[float(x) for x in decomp.centroid(q)]
                      for q in range(decomp.n_sub)],
    }


class Supervisor:
    """Drive a trainer's guarded chunks with rollback, backoff and checkpoints.

    ``trainer`` is any of the three trainers (each exposes
    ``run_chunk_guarded``); ``root`` is the checkpoint directory; ``injector``
    is an optional chunk-granular :class:`FaultInjector` (tests/benchmarks);
    ``decomp`` (optional) stamps the decomposition signature into checkpoint
    metadata so the run can restart elastically.

    Telemetry (EXPERIMENTS.md §Observability): ``obs`` plugs in a shared
    :class:`~repro.obs.Obs` bundle — every walltime/recovery measurement goes
    through its injectable clock (so tests stub time instead of sleeping), the
    ``train.supervisor/*`` counters mirror the :class:`SupervisorReport` ints
    under the registry's one naming scheme, chunk walltimes and recovery
    latencies feed ``train.supervisor/{chunk_walltime_s,recovery_s}``
    histograms, and chunk/crash/guard_trip/straggler/rollback events stream to
    the JSONL sink when one is attached.  ``sleep`` is the straggler-delay
    sleeper (stub it together with the clock).  Without ``obs`` the supervisor
    keeps a private registry — behavior is unchanged.
    """

    def __init__(self, trainer, root: str, cfg: SupervisorConfig = SupervisorConfig(),
                 injector: FaultInjector | None = None, decomp=None,
                 obs: Obs | None = None, sleep=time.sleep):
        self.trainer, self.root, self.cfg = trainer, str(root), cfg
        self.injector = injector or FaultInjector()
        self.decomp = decomp
        self.lr_scale: np.ndarray | None = None   # lazy: shape from health
        self.report = SupervisorReport()
        self._restarts = 0
        self.obs = obs if obs is not None else Obs(registry=MetricsRegistry())
        self._clock, self._sleep = self.obs.clock, sleep
        # thread the tracer down: each chunk attempt gets a root span, the
        # trainer's dispatch span nests under it, and rollback/recovery land
        # as retrospective children — one trace_id per attempt, surfaced on
        # every JSONL event of that attempt
        self.tracer = self.obs.tracer
        if self.tracer is not None and getattr(trainer, "tracer", 1) is None:
            trainer.tracer = self.tracer
        reg = self.obs.registry
        self._counters = reg.group(
            "train.supervisor",
            ("chunks", "restarts", "crashes", "guard_trips", "stragglers",
             "corruptions"))
        self._h_wall = reg.histogram("train.supervisor/chunk_walltime_s")
        self._h_rec = reg.histogram("train.supervisor/recovery_s")

    def _bump(self, key: str) -> None:
        """One increment, two views: the registry counter (the naming scheme)
        and the legacy :class:`SupervisorReport` int."""
        self._counters[key] += 1
        setattr(self.report, key, getattr(self.report, key) + 1)

    # ------------------------------------------------------------- checkpoint
    def _metadata(self, state_tree: dict) -> dict:
        return {"supervisor": {
            "restarts": self._restarts,
            "lr_scale": (None if self.lr_scale is None
                         else np.asarray(self.lr_scale).tolist()),
            "adam_count": _adam_count(state_tree),
            "chunk_walltimes": self.report.walltimes[-self.cfg.walltime_window:],
            "decomp": decomp_signature(self.decomp) if self.decomp else None,
        }}

    def _save(self, state) -> None:
        tree = _as_tree(state)
        ckpt.save(self.root, int(np.asarray(tree["step"])), tree,
                  metadata=self._metadata(tree), keep=self.cfg.keep)

    def _rollback(self, like) -> object:
        self._restarts += 1
        self._bump("restarts")
        if self._restarts > self.cfg.max_restarts:
            raise RuntimeError(
                f"supervisor: restart budget exhausted "
                f"({self.cfg.max_restarts}); last events: {self.report.events[-4:]}")
        # verify-then-restore: a poisoned latest checkpoint (bit rot, torn
        # write, lost file) is quarantined and the walk falls back to the
        # newest VERIFIED generation instead of ending the run — corrupt
        # state never reaches the trainer
        tree, _, info = integrity.verified_restore(
            self.root, _as_tree(like), on_event=self.obs.emit)
        for name, reason in info.quarantined:
            self._bump("corruptions")
            self.report.events.append(
                f"corrupt checkpoint quarantined: {reason}")
        if info.fallback_depth:
            self.report.events.append(
                f"generation fallback depth {info.fallback_depth} "
                f"-> step {info.step}")
        self.report.fallback_depths.append(info.fallback_depth)
        tree = jax.tree.map(jnp.asarray, tree)
        return _from_tree(tree, like)

    # ---------------------------------------------------------------- backoff
    def _apply_backoff(self, health: dict) -> None:
        ok_sub = np.atleast_1d(np.asarray(health["ok_sub"]))
        if self.lr_scale is None:
            self.lr_scale = np.ones(ok_sub.shape, np.float32)
        scale = np.where(ok_sub, 1.0, self.cfg.lr_backoff).astype(np.float32)
        self.lr_scale = self.lr_scale * scale
        if (self.lr_scale < self.cfg.min_lr_scale).any():
            raise RuntimeError(
                "supervisor: lr backoff hit the floor "
                f"({self.cfg.min_lr_scale}) without recovering — "
                f"lr_scale={self.lr_scale.tolist()}")

    def _lr_scale_arg(self):
        if self.lr_scale is None:
            return None
        ls = jnp.asarray(self.lr_scale)
        # DataParallel's guard is scalar-shaped; collapse a broadcast vector
        return ls if ls.shape else ls.reshape(-1)

    # -------------------------------------------------------------- main loop
    def run(self, state, batch, total_steps: int):
        """Train to ``total_steps``, surviving crashes and divergence.

        Returns ``(state, report)``.  ``state`` follows the trainer's own state
        type and donation contract (rebind, never reuse the argument)."""
        cfg, tr = self.cfg, self.trainer
        done = int(np.asarray(_as_tree(state)["step"]))
        if ckpt.latest_step(self.root) is None:
            self._save(state)   # the first rollback needs a target
        attempt = 0
        committed = 0
        while done < total_steps:
            n = min(cfg.chunk_steps, total_steps - done)
            faults = self.injector.take(attempt)
            attempt += 1
            t0 = self._clock()
            # one trace per chunk ATTEMPT: dispatch + fault/recovery hops
            # share its trace_id, which also rides every event emitted below
            span = (self.tracer.start_trace("train.chunk", lane="train",
                                            chunk=attempt - 1, steps=n)
                    if self.tracer is not None else None)
            tid = {"trace_id": span.trace_id} if span is not None else {}
            if span is not None:
                span.__enter__()    # active: the trainer's span nests under
            outcome = "committed"
            try:
                try:
                    for f in faults:
                        if f.kind == "straggler":
                            self._bump("stragglers")
                            self.report.events.append(
                                f"straggler +{f.delay:.2f}s at chunk {attempt - 1}")
                            self.obs.emit("straggler", chunk=attempt - 1,
                                          delay_s=float(f.delay), **tid)
                            if span is not None:
                                span.event("train.straggler",
                                           delay_s=float(f.delay))
                            self._sleep(f.delay)
                        elif f.kind in ("nan_params", "nan_grads"):
                            self.report.events.append(
                                f"{f.kind} injected at chunk {attempt - 1} "
                                f"(subdomain {f.subdomain})")
                            if span is not None:
                                span.event("train.fault", kind=f.kind,
                                           subdomain=f.subdomain)
                            state = _from_tree(
                                inject_nan(_as_tree(state), f.kind, f.subdomain),
                                state)
                    state, terms, health = tr.run_chunk_guarded(
                        state, batch, n, self._lr_scale_arg())
                    for f in faults:
                        if f.kind == "crash":
                            # mid-chunk preemption: the chunk computed but its
                            # progress dies before the checkpoint
                            raise InjectedFailure(
                                f"injected crash at chunk {attempt - 1}")
                except InjectedFailure as e:
                    outcome = "crash"
                    self._bump("crashes")
                    self.report.events.append(str(e))
                    self.obs.emit("crash", chunk=attempt - 1, **tid)
                    t_r = self._clock()
                    state = self._rollback(state)
                    rec = self._clock() - t_r
                    self.report.recovery_s.append(rec)
                    self._h_rec.record(rec)
                    if span is not None:
                        self.tracer.record("train.rollback", t_r, t_r + rec,
                                           parent=span, cause="crash")
                    done = int(np.asarray(_as_tree(state)["step"]))
                    self.obs.emit("rollback", step=done, recovery_s=rec, **tid)
                    continue
                if not bool(health["ok"]):
                    outcome = "guard_trip"
                    bad = np.flatnonzero(~np.atleast_1d(np.asarray(health["ok_sub"])))
                    self._bump("guard_trips")
                    self.report.events.append(
                        f"guard trip at chunk {attempt - 1}: subdomains "
                        f"{bad.tolist()} non-finite after "
                        f"{int(health['good_steps'])} steps — rolling back with "
                        f"lr backoff x{cfg.lr_backoff}")
                    self.obs.emit("guard_trip", chunk=attempt - 1,
                                  bad_subdomains=bad.tolist(),
                                  good_steps=int(health["good_steps"]), **tid)
                    self._apply_backoff(health)
                    t_r = self._clock()
                    state = self._rollback(state)
                    rec = self._clock() - t_r
                    self.report.recovery_s.append(rec)
                    self._h_rec.record(rec)
                    if span is not None:
                        self.tracer.record("train.rollback", t_r, t_r + rec,
                                           parent=span, cause="guard_trip")
                    done = int(np.asarray(_as_tree(state)["step"]))
                    self.obs.emit("rollback", step=done, recovery_s=rec, **tid)
                    continue
                # committed
                done += n
                committed += 1
                self._bump("chunks")
                wall = self._clock() - t0
                self.report.walltimes.append(wall)
                self._h_wall.record(wall)
                if self.obs.events is not None:
                    # last committed step's mean loss (terms concrete already)
                    last = np.asarray(terms["loss"])[-1]
                    self.obs.emit("chunk", step=done, steps=n,
                                  loss=float(np.nanmean(last)),
                                  walltime_s=float(wall), **tid)
                if committed % cfg.ckpt_every_chunks == 0 or done >= total_steps:
                    self._save(state)
            finally:
                if span is not None:
                    span.annotate(outcome=outcome)
                    span.__exit__(None, None, None)
        return state, self.report

    # ------------------------------------------------------------- rebalance
    def rebalance_counts(self, counts, per_sub_walltimes=None) -> list[int]:
        """Straggler-aware point counts for the next (re-)decomposition.

        With measured per-subdomain chunk walltimes (per-rank timers on a real
        multi-host run, or the fault injector's straggler schedule in tests)
        the budget is reallocated proportionally to measured throughput —
        paper §7.6's idle-worker fix.  Without them, plain leveling."""
        counts = [int(c) for c in counts]
        if per_sub_walltimes is None:
            return elastic.balanced_counts(counts)
        return elastic.balanced_counts(
            counts, elastic.throughput_weights(counts, per_sub_walltimes))


# ------------------------------------------------------------ elastic resume

def elastic_resume(root: str, trainer, decomp, state=None):
    """Restore the latest supervisor checkpoint into ``trainer`` — which may be
    decomposed into a DIFFERENT number of subdomains than the checkpoint.

    Same ``n_sub`` (centroids immaterial): plain bitwise restore.  Different
    ``n_sub``: nearest-centroid :func:`~repro.runtime.elastic.remap_params`
    from the checkpoint metadata's centroid signature, optimizer moments reset,
    per-subdomain Adam step counts and the global step preserved via metadata
    (so bias correction and lr schedules continue instead of restarting cold).

    Returns ``(state, metadata)``.  ``state`` template defaults to
    ``trainer.init(0)``."""
    like = state if state is not None else trainer.init(0)
    like_tree = _as_tree(like)
    # verify first: elastic restarts read whatever generation survived the
    # outage, so the walk quarantines corrupt ones and pins ONE verified step
    # for both reads below
    manifest_leaves, manifest, info = integrity.verified_raw_leaves(root)
    meta = manifest["metadata"]
    sup = meta.get("supervisor", {})
    sig = sup.get("decomp")
    n_new = decomp.n_sub

    if sig is None or int(sig["n_sub"]) == n_new:
        tree, _ = ckpt.restore(root, like_tree, step=info.step)
        tree = jax.tree.map(jnp.asarray, tree)
        return _from_tree(tree, like), meta

    # paths are shape-agnostic, so restore hands back the OLD stacked leaves
    old_tree, _ = ckpt.restore(root, like_tree, step=info.step)
    old_spec = elastic.CentroidSpec(sig["centroids"])
    new_params, src = elastic.remap_params(old_tree["params"], old_spec, decomp)
    opt = adam_lib.init_adam(new_params)
    # Adam step count preserved via metadata (per remapped subdomain when the
    # trainer keeps a stacked count vector)
    count = np.asarray(sup.get("adam_count", np.asarray(old_tree["opt"]["count"])))
    like_count = np.asarray(like_tree["opt"]["count"])
    if like_count.ndim == 1:
        count = count[src] if count.ndim == 1 else np.full(n_new, count)
        opt["count"] = jnp.asarray(count.astype(np.int32))
    else:
        opt["count"] = jnp.asarray(np.int32(count.max() if count.ndim else count))
    tree = {"params": new_params, "opt": opt,
            "step": jnp.asarray(np.asarray(old_tree["step"]))}
    return _from_tree(tree, like), meta
