"""Elastic re-decomposition: resume a DD-PINN run on a DIFFERENT worker count.

At 1000+ node scale, restarts rarely come back with the same world size.  The
paper's decomposition is static; we extend it: a checkpoint taken at ``n_old``
subdomains can seed a restart at ``n_new`` subdomains.  Each NEW subdomain adopts
the parameters of the OLD subdomain whose centroid is nearest to its own (the
physics re-synchronizes the interfaces within a few hundred steps — validated in
``tests/test_elastic.py``).  Optimizer moments restart from zero (standard after a
topology change); the Adam step count is preserved via checkpoint metadata
(``runtime.supervisor.elastic_resume`` restores it per remapped subdomain).

Also provides straggler-aware re-balancing of residual point counts (the paper's
§7.6 notes subdomain 7's 800 points idling the other 9 workers):
:func:`balanced_counts` levels the per-worker budget, and with ``weights`` (e.g.
measured per-worker throughput from chunk walltimes, see
:func:`throughput_weights`) it allocates PROPORTIONALLY to worker speed, so a
straggling worker gets fewer points instead of stalling the exchange.
"""
from __future__ import annotations

import numpy as np

from repro.core.domain import Decomposition
from repro.utils import tree_unstack, tree_stack
import jax
import jax.numpy as jnp


def remap_params(
    old_params,            # stacked (n_old, ...)
    old_decomp: Decomposition,
    new_decomp: Decomposition,
):
    """Nearest-centroid parameter adoption across decompositions."""
    n_old, n_new = old_decomp.n_sub, new_decomp.n_sub
    old_c = np.stack([old_decomp.centroid(q) for q in range(n_old)])
    new_c = np.stack([new_decomp.centroid(q) for q in range(n_new)])
    # nearest old subdomain for every new one
    d2 = ((new_c[:, None, :] - old_c[None, :, :]) ** 2).sum(-1)
    src = np.argmin(d2, axis=1)  # (n_new,)
    return jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[src]), old_params), src


class CentroidSpec:
    """Minimal stand-in for a :class:`Decomposition` in :func:`remap_params`
    when only the centroids survive (e.g. read back from checkpoint metadata
    after an elastic restart — the old geometry object is gone)."""

    def __init__(self, centroids):
        self._c = np.asarray(centroids, np.float64)
        self.n_sub = len(self._c)

    def centroid(self, q: int) -> np.ndarray:
        return self._c[q]


def balanced_counts(counts: list[int], weights: list[float] | None = None) -> list[int]:
    """Rebalance per-worker point counts, preserving the global point budget.

    Without ``weights``: equalize (the paper's own fix for its §7.6 imbalance).
    With ``weights`` (relative worker speeds, any positive scale): allocate the
    budget proportionally to speed — the straggler-aware variant fed by
    measured chunk walltimes.  Largest-remainder rounding keeps the total
    exact."""
    total = sum(counts)
    n = len(counts)
    if weights is None:
        base = total // n
        out = [base] * n
        for i in range(total - base * n):
            out[i] += 1
        return out
    w = np.asarray(weights, np.float64)
    if len(w) != n:
        raise ValueError(f"{len(w)} weights for {n} workers")
    if (w < 0).any() or w.sum() <= 0:
        raise ValueError("weights must be non-negative with a positive sum")
    share = w / w.sum() * total
    out = np.floor(share).astype(np.int64)
    for i in np.argsort(-(share - out))[: total - int(out.sum())]:
        out[i] += 1
    return [int(c) for c in out]


def throughput_weights(counts, walltimes) -> list[float]:
    """Per-worker speed (points/sec) from measured per-worker chunk walltimes —
    the ``weights`` input to :func:`balanced_counts` (paper §7.6: fast workers
    idle behind the straggler; give them more points instead)."""
    c = np.asarray(counts, np.float64)
    t = np.asarray(walltimes, np.float64)
    if c.shape != t.shape:
        raise ValueError(f"counts {c.shape} vs walltimes {t.shape}")
    return [float(x) for x in c / np.maximum(t, 1e-12)]
