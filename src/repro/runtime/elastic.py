"""Elastic re-decomposition: resume a DD-PINN run on a DIFFERENT worker count.

At 1000+ node scale, restarts rarely come back with the same world size.  The
paper's decomposition is static; we extend it: a checkpoint taken at ``n_old``
subdomains can seed a restart at ``n_new`` subdomains.  Each NEW subdomain adopts
the parameters of the OLD subdomain whose centroid is nearest to its own (the
physics re-synchronizes the interfaces within a few hundred steps — validated in
``tests/test_elastic.py``).  Optimizer moments restart from zero (standard after a
topology change); the Adam step count is preserved via metadata.

Also provides straggler-aware re-balancing of residual point counts (the paper's
§7.6 notes subdomain 7's 800 points idling the other 9 workers).
"""
from __future__ import annotations

import numpy as np

from repro.core.domain import Decomposition
from repro.utils import tree_unstack, tree_stack
import jax
import jax.numpy as jnp


def remap_params(
    old_params,            # stacked (n_old, ...)
    old_decomp: Decomposition,
    new_decomp: Decomposition,
):
    """Nearest-centroid parameter adoption across decompositions."""
    n_old, n_new = old_decomp.n_sub, new_decomp.n_sub
    old_c = np.stack([old_decomp.centroid(q) for q in range(n_old)])
    new_c = np.stack([new_decomp.centroid(q) for q in range(n_new)])
    # nearest old subdomain for every new one
    d2 = ((new_c[:, None, :] - old_c[None, :, :]) ** 2).sum(-1)
    src = np.argmin(d2, axis=1)  # (n_new,)
    return jax.tree.map(lambda x: jnp.asarray(np.asarray(x)[src]), old_params), src


def balanced_counts(counts: list[int]) -> list[int]:
    """Equalize total work across workers, preserving the global point budget."""
    total = sum(counts)
    n = len(counts)
    base = total // n
    out = [base] * n
    for i in range(total - base * n):
        out[i] += 1
    return out
