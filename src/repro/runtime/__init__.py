from repro.runtime.elastic import balanced_counts, remap_params
from repro.runtime.failures import InjectedFailure, run_with_failures
