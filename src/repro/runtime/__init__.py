from repro.runtime.elastic import (CentroidSpec, balanced_counts, remap_params,
                                   throughput_weights)
from repro.runtime.failures import (FAULT_KINDS, SERVE_FAULT_KINDS, Fault,
                                    FaultInjector, FaultyEngine,
                                    InjectedFailure, inject_nan, parse_faults,
                                    run_with_failures)
from repro.runtime.supervisor import (Supervisor, SupervisorConfig,
                                      SupervisorReport, decomp_signature,
                                      elastic_resume)
