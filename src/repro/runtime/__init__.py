from repro.runtime.chaos import (ChaosInjector, compose, corrupt_file,
                                 corrupt_generation)
from repro.runtime.elastic import (CentroidSpec, balanced_counts, remap_params,
                                   throughput_weights)
from repro.runtime.failures import (ALL_FAULT_KINDS, FAULT_KINDS,
                                    SERVE_FAULT_KINDS, STORAGE_FAULT_KINDS,
                                    Fault, FaultInjector, FaultyEngine,
                                    InjectedFailure, inject_nan, parse_faults,
                                    run_with_failures)
from repro.runtime.supervisor import (Supervisor, SupervisorConfig,
                                      SupervisorReport, decomp_signature,
                                      elastic_resume)
