from repro.runtime.elastic import (CentroidSpec, balanced_counts, remap_params,
                                   throughput_weights)
from repro.runtime.failures import (FAULT_KINDS, Fault, FaultInjector,
                                    InjectedFailure, inject_nan, parse_faults,
                                    run_with_failures)
from repro.runtime.supervisor import (Supervisor, SupervisorConfig,
                                      SupervisorReport, decomp_signature,
                                      elastic_resume)
