"""Deterministic seeded chaos: storage faults composed with the fault matrices.

:mod:`repro.runtime.failures` covers compute-side failures (crashes, NaNs,
stragglers, engine faults); this module adds the STORAGE fault family —
corruption of durable state on disk — and a scheduler that composes all three
families into one deterministic schedule, so a scripted
train→crash→restore→export→serve→reload soak (``benchmarks/chaos_soak.py``)
can replay bit rot, torn writes, truncation and lost files against the exact
checkpoint/bundle generations the recovery paths will read next.

Everything is seeded: fault offsets and truncation points come from one
``numpy`` Generator, so a failing soak reproduces byte-for-byte.

* :func:`corrupt_generation` — apply one storage fault
  (:data:`~repro.runtime.failures.STORAGE_FAULT_KINDS`) to the ``index``-th
  newest generation of a checkpoint/bundle root;
* :class:`ChaosInjector` — a :class:`~repro.runtime.failures.FaultInjector`
  that additionally fires storage faults as filesystem side effects when
  their chunk/dispatch index comes due and hands only the compute faults to
  the caller — the supervisor and ``FaultyEngine`` consume it unmodified, so
  the storage family composes with the existing train-chunk and serve
  matrices without touching either;
* :func:`compose` — merge fault schedules from several families into one.
"""
from __future__ import annotations

import os

import numpy as np

from repro.runtime.failures import (Fault, FaultInjector, STORAGE_FAULT_KINDS)


def _generation_dir(root: str, index: int) -> str:
    """Path of the ``index``-th newest readable generation (0 = newest)."""
    from repro.checkpoint import integrity

    gens = integrity.generations(root)
    if index >= len(gens):
        raise IndexError(
            f"generation index {index} out of range: {root} has "
            f"{len(gens)} generation(s)")
    return os.path.join(root, gens[index][1])


def corrupt_file(path: str, kind: str, rng: np.random.Generator) -> dict:
    """Apply one storage fault to one file; returns what was done (for the
    soak's injection log).  Offsets/fractions are drawn from ``rng`` so a
    seeded schedule reproduces exactly."""
    size = os.path.getsize(path)
    if kind == "missing_file":
        os.remove(path)
        return {"kind": kind, "path": path}
    if size == 0:
        raise ValueError(f"cannot corrupt empty file {path}")
    if kind == "bit_flip":
        off = int(rng.integers(size))
        bit = int(rng.integers(8))
        with open(path, "r+b") as f:
            f.seek(off)
            byte = f.read(1)[0]
            f.seek(off)
            f.write(bytes([byte ^ (1 << bit)]))
        return {"kind": kind, "path": path, "offset": off, "bit": bit}
    if kind == "truncate":
        keep = int(size * float(rng.uniform(0.25, 0.75)))
        os.truncate(path, keep)
        return {"kind": kind, "path": path, "kept": keep, "of": size}
    if kind == "torn_write":
        # power loss mid-write: a prefix of real data, the tail zero pages
        keep = int(size * float(rng.uniform(0.25, 0.75)))
        with open(path, "r+b") as f:
            f.seek(keep)
            f.write(b"\0" * (size - keep))
        return {"kind": kind, "path": path, "torn_at": keep, "of": size}
    raise ValueError(f"unknown storage fault kind {kind!r}; expected one of "
                     f"{STORAGE_FAULT_KINDS}")


def corrupt_generation(root: str, kind: str, index: int = 0,
                       rng: np.random.Generator | None = None,
                       file: str | None = None) -> dict:
    """Corrupt one file of the ``index``-th newest generation under ``root``.

    ``file`` defaults to ``arrays.npz`` (the bulk payload, where real bit rot
    lands); pass ``"manifest.json"`` to attack the metadata side instead.
    Returns the injection record."""
    rng = rng if rng is not None else np.random.default_rng(0)
    d = _generation_dir(root, index)
    rec = corrupt_file(os.path.join(d, file or "arrays.npz"), kind, rng)
    return {**rec, "generation": os.path.basename(d), "index": index}


class ChaosInjector(FaultInjector):
    """Fault schedule spanning compute AND storage families.

    Drop-in for :class:`~repro.runtime.failures.FaultInjector` anywhere one
    is consumed (``Supervisor``, ``FaultyEngine``): :meth:`take` applies any
    storage faults due at this launch/dispatch index to their target root
    (``roots["ckpt"]`` / ``roots["bundle"]``) as filesystem side effects,
    records them in ``storage_fired``, and returns only the compute faults —
    the consumer never needs to know the storage family exists.  A storage
    fault whose target has no generation yet (e.g. before the first save) is
    deferred to the next launch rather than lost."""

    def __init__(self, faults=(), roots: dict | None = None, seed: int = 0):
        super().__init__(faults)
        self.roots = dict(roots or {})
        self._rng = np.random.default_rng(seed)
        self.storage_fired: list[dict] = []

    def take(self, chunk_idx: int) -> list[Fault]:
        due = super().take(chunk_idx)
        out = []
        for f in due:
            if f.kind not in STORAGE_FAULT_KINDS:
                out.append(f)
                continue
            root = self.roots.get(f.target)
            if root is None:
                raise ValueError(
                    f"storage fault {f.kind}@{f.chunk} targets "
                    f"{f.target!r} but ChaosInjector has no root for it "
                    f"(roots={sorted(self.roots)})")
            try:
                rec = corrupt_generation(root, f.kind, f.index, self._rng)
            except IndexError:
                # nothing durable to corrupt yet: re-arm for the next launch
                self.fired.remove(f)
                self._due.append(Fault(chunk=chunk_idx + 1, kind=f.kind,
                                       target=f.target, index=f.index))
                self._due.sort(key=lambda x: x.chunk)
                continue
            self.storage_fired.append({**rec, "target": f.target,
                                       "chunk": chunk_idx})
        return out


def compose(*schedules) -> list[Fault]:
    """Merge fault schedules (lists of :class:`Fault`) from any mix of the
    train / serve / storage families into one, ordered by launch index."""
    out: list[Fault] = []
    for s in schedules:
        out.extend(s)
    return sorted(out, key=lambda f: f.chunk)
