"""Model/shape configuration schema for the architecture zoo.

One ``<arch>.py`` per assigned architecture instantiates :class:`ModelConfig` with
the exact published numbers (plus ``reduced()`` for CPU smoke tests).  The four
input-shape cells are fixed by the assignment:

    train_4k     seq 4096,   global_batch 256   (train_step)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one-token decode w/ full KV cache)
    long_500k    seq 524288, global_batch 1     (long-context decode; sub-quadratic
                                                 archs only: zamba2, rwkv6)
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | mla | moe | hybrid | rwkv | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0        # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (dots_with_no_batch_dims_saveable)
    attn_block_q: int = 512          # query block for chunked attention
    attn_causal_skip: bool = False   # python-loop q blocks, slice k/v causally
    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_dense: int = 0             # leading dense layers (deepseek-moe: 1)
    d_ff_dense: int = 0              # d_ff of those dense layers (0 -> d_ff)
    capacity_factor: float = 1.25
    moe_shard_map: bool = False      # explicit EP via shard_map (see moe.py)
    # ---- MLA ----
    q_lora: int = 0
    kv_lora: int = 0
    nope_dim: int = 0
    rope_dim: int = 0
    v_head_dim: int = 0
    # ---- SSM / hybrid ----
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_every: int = 0              # zamba2: shared attn block every k mamba blocks
    # ---- enc-dec ----
    n_dec_layers: int = 0
    enc_ratio: int = 4               # encoder frames = seq_len // enc_ratio
    # ---- vlm ----
    n_patches: int = 0               # stub frontend: precomputed patch embeddings
    patch_dim: int = 0
    # ---- skips ----
    sub_quadratic: bool = False      # may run long_500k
    note: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to a 256 multiple so explicit input
        shardings divide evenly on the (16,16)/(2,16,16) meshes; padded logit
        columns are masked out in the loss and the serving argmax."""
        return ((self.vocab + 255) // 256) * 256

    def supports(self, shape: ShapeConfig) -> bool:
        if shape.name == "long_500k" and not self.sub_quadratic:
            return False
        return True

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        base = dict(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4),
            d_ff=128, vocab=256, head_dim=16, remat=False, attn_block_q=32,
        )
        if self.family == "moe":
            base.update(n_experts=4, top_k=2, d_expert=32, n_shared_experts=min(self.n_shared_experts, 1),
                        first_dense=min(self.first_dense, 1))
        if self.family == "mla":
            base.update(q_lora=32, kv_lora=16, nope_dim=8, rope_dim=8, v_head_dim=16, head_dim=0)
        if self.family in ("hybrid", "rwkv"):
            base.update(ssm_state=8, ssm_head_dim=8, ssm_chunk=16, d_model=64)
            if self.attn_every:
                base.update(attn_every=2, n_layers=4)
        if self.family == "encdec":
            base.update(n_dec_layers=2)
        if self.family == "vlm":
            base.update(n_patches=8, patch_dim=32)
        base.update(overrides)
        return replace(self, **base)


def param_count(cfg: ModelConfig) -> int:
    """Analytic parameter count (total)."""
    d, hd = cfg.d_model, cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) + (cfg.n_heads * hd) * d
    if cfg.family == "mla":
        qk_head = cfg.nope_dim + cfg.rope_dim
        attn = (d * cfg.q_lora + cfg.q_lora * cfg.n_heads * qk_head
                + d * (cfg.kv_lora + cfg.rope_dim)
                + cfg.kv_lora * cfg.n_heads * (cfg.nope_dim + cfg.v_head_dim)
                + cfg.n_heads * cfg.v_head_dim * d)
    dense_ffn = 3 * d * cfg.d_ff
    if cfg.family == "moe":
        moe_ffn = 3 * d * cfg.d_expert * (cfg.n_experts + cfg.n_shared_experts) + d * cfg.n_experts
        n_moe = cfg.n_layers - cfg.first_dense
        ffn_total = cfg.first_dense * dense_ffn + n_moe * moe_ffn
        per_layer_rest = attn + 2 * d
        return emb + ffn_total + cfg.n_layers * per_layer_rest
    if cfg.family == "rwkv":
        tmix = d * d * 4 + d * 6  # r,k,v,g,o approx + decays
        cmix = 2 * d * cfg.d_ff
        return emb + cfg.n_layers * (tmix + cmix + 4 * d)
    if cfg.family in ("hybrid",):
        d_in = cfg.ssm_expand * d
        mamba = d * (2 * d_in + 2 * cfg.ssm_state + d_in // cfg.ssm_head_dim) + d_in * d
        shared_attn = attn + dense_ffn
        n_attn_uses = cfg.n_layers // max(cfg.attn_every, 1)
        return emb + cfg.n_layers * (mamba + 2 * d) + shared_attn
    if cfg.family == "encdec":
        enc = cfg.n_layers * (attn + dense_ffn + 4 * d)
        dec = cfg.n_dec_layers * (2 * attn + dense_ffn + 6 * d)
        return emb + enc + dec
    return emb + cfg.n_layers * (attn + dense_ffn + 2 * d)


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: shared + top_k routed)."""
    if cfg.family != "moe":
        return param_count(cfg)
    d = cfg.d_model
    moe_active = 3 * d * cfg.d_expert * (cfg.top_k + cfg.n_shared_experts) + d * cfg.n_experts
    dense_ffn = 3 * d * cfg.d_ff
    hd = cfg.hd
    attn = d * (cfg.n_heads * hd) + 2 * d * (cfg.n_kv_heads * hd) + (cfg.n_heads * hd) * d
    n_moe = cfg.n_layers - cfg.first_dense
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    return emb + cfg.first_dense * dense_ffn + n_moe * moe_active + cfg.n_layers * (attn + 2 * d)
