"""seamless-m4t-large-v2: enc-dec multimodal backbone [arXiv:2308.11596].
24 encoder + 24 decoder layers (the real text stack; assignment's "24L" read as
per-stack depth).  Audio frontend is a stub: precomputed frame embeddings at
seq_len // 4 frames."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec", n_layers=24, n_dec_layers=24,
    d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=8192,
    vocab=256206, enc_ratio=4,
)
