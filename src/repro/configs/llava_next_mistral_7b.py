"""llava-next-mistral-7b: mistral-7b backbone + anyres patch-embedding stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf].  The vision tower is upstream; the stub
frontend supplies 2304 precomputed patch embeddings (CLIP-L hidden 1024)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128, d_ff=14336, vocab=32000,
    rope_theta=1e6, n_patches=2304, patch_dim=1024,
)
