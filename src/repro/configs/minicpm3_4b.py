"""minicpm3-4b: MLA (multi-head latent attention) [hf:openbmb/MiniCPM3-4B].

True MLA dims: q_lora 768, kv_lora 256, qk = 64 nope + 32 rope, v 64.
Assignment's "GQA kv=40" = MHA over the 40 latent-expanded heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="mla", n_layers=62, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=6400, vocab=73448,
    q_lora=768, kv_lora=256, nope_dim=64, rope_dim=32, v_head_dim=64,
)
