"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, active_param_count, param_count

from repro.configs import (  # noqa: E402
    deepseek_moe_16b, llama3_2_1b, llava_next_mistral_7b, minicpm3_4b,
    phi3_5_moe_42b, qwen2_5_14b, rwkv6_3b, seamless_m4t_large_v2, yi_34b,
    zamba2_1_2b,
)

ARCHS: dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        yi_34b, llama3_2_1b, qwen2_5_14b, minicpm3_4b, llava_next_mistral_7b,
        zamba2_1_2b, deepseek_moe_16b, phi3_5_moe_42b, rwkv6_3b,
        seamless_m4t_large_v2,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> list[str]:
    return list(ARCHS)
