"""deepseek-moe-16b: fine-grained MoE, 2 shared + 64 routed top-6, dense first
layer [arXiv:2401.06066].  Assignment's d_ff=1408 is the fine-grained expert dim;
the dense layer-0 FFN uses the model's 10944."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
    first_dense=1, d_ff_dense=10944,
)
