"""rwkv6-3b (Finch): attention-free, data-dependent decay [arXiv:2404.05892].
Sub-quadratic -> runs long_500k.  40 heads of dim 64."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="rwkv", n_layers=32, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=8960, vocab=65536,
    ssm_chunk=256, sub_quadratic=True,
)
