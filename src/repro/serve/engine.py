"""Single-dispatch stitched inference: the serving hot path.

``FieldEngine.evaluate(pts)`` answers "u / grad u / flux / residual at these N
points" for a frozen :class:`~repro.serve.export.FieldBundle`:

1. **route** (host, vectorized): claim matrix + per-subdomain buckets
   (:mod:`repro.serve.routing`);
2. **evaluate** (device, ONE dispatch): all subdomains enter the network in a
   single fused traced entry — ``vmap`` over the stacked subdomain axis of one
   :func:`repro.core.fused.model_bundle` call (static activation shared by all
   subdomains -> Pallas-kernel-capable path) or one
   :func:`repro.core.fused.model_bundle_select` call (heterogeneous Table-3
   activations, traced per-subdomain codes) — never a per-subdomain Python
   loop;
3. **stitch** (host): claims are averaged so interface points are
   single-valued (XPINN eq. 4), unclaimed (outside-domain) points come back
   NaN.

Two entry tiers (``order``): ``order=2`` is the full bundle (residual doubles
as a served error-proxy diagnostic); ``order=1`` disables the second-order
tangent stream entirely (``d2_dirs=()`` — the "no d2 at all" end of the PR-2
pruning axis) for cheaper pure-inference calls.

Compiled programs are cached process-wide keyed on the static evaluation
signature, so short-lived engines (e.g. one per ``evaluate_l2`` call) reuse
compilations; bucketed routing keeps distinct query sizes from retracing.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import fused
from repro.core.nets import SubdomainModelConfig
from repro.serve import routing
from repro.serve.export import FieldBundle

# process-wide compiled-program cache: static signature -> jitted fn
_EVAL_CACHE: dict = {}


def _stitch(routed: routing.RoutedQuery, arr: np.ndarray,
            claims: np.ndarray) -> np.ndarray:
    """Average each point's claims: (n_sub, m, ...) -> (N, ...)."""
    flat = arr.reshape((arr.shape[0] * arr.shape[1],) + arr.shape[2:])
    out = np.full((len(routed.pts),) + flat.shape[1:], np.nan, flat.dtype)
    prim = routed.primary
    out[routed.pt_idx[prim]] = flat[routed.rows[prim]]
    if not prim.all():  # interface points: accumulate extra claims, then mean
        np.add.at(out, routed.pt_idx[~prim], flat[routed.rows[~prim]])
        multi = claims > 1
        out[multi] /= claims[multi].reshape((-1,) + (1,) * (out.ndim - 1))
    return out


class FieldEngine:
    """Frozen-field evaluation with one fused network entry per query batch."""

    def __init__(self, bundle: FieldBundle, tol: float = 1e-9,
                 bucket: int = 64, block_n: int = 256,
                 interpret: bool | None = None, obs=None):
        self.bundle = bundle
        self.tol, self.bucket = tol, bucket
        self.block_n, self.interpret = block_n, interpret
        # optional telemetry (repro.obs.Obs): per-evaluate dispatch counter
        # and duration histogram under serve.engine/* — None keeps the engine
        # dependency-free for library callers
        self.obs = obs
        codes = np.asarray(
            bundle.act_codes if bundle.act_codes is not None
            else np.zeros((bundle.n_sub,), np.int32), np.int32)
        assert codes.shape == (bundle.n_sub,)
        self._codes = jnp.asarray(codes)
        # one shared activation -> static-act fused path (kernel-capable);
        # heterogeneous -> traced-code select path.  Both are ONE traced entry.
        self.uniform_act = fused.uniform_act_name(codes.tolist())
        self.n_dispatches = 0   # device dispatches issued (1 per evaluate)
        self.last_claims = None  # (N,) claim counts of the latest evaluate —
        # lets output guards distinguish legit outside-domain NaN from a
        # poisoned claimed point without a second routing pass

    # ------------------------------------------------------------ internals
    def _route(self, pts) -> routing.RoutedQuery:
        return routing.route(self.bundle.decomp, pts, tol=self.tol,
                             bucket=self.bucket)

    def _device_args(self, routed: routing.RoutedQuery):
        return (self.bundle.params, jnp.asarray(routed.X), self._codes,
                self.bundle.width_masks)

    def _get_fn(self, order: int):
        cfg: SubdomainModelConfig = self.bundle.model_cfg
        pde = self.bundle.pde
        if order == 2 and pde is None:
            raise ValueError("order=2 (flux/residual) needs a bundle PDE; "
                             "use order=1 for bare field serving")
        if pde is not None and not type(pde).supports_derivs():
            raise ValueError(
                f"bundle PDE {pde.name} lacks the batched *_from_derivs "
                "methods the serving engine assembles flux/residual from; "
                "export the bundle with pde=None for bare field serving")
        wm_key = (None if self.bundle.width_masks is None
                  else tuple(sorted(self.bundle.width_masks)))
        key = (tuple(cfg.nets.items()), self.uniform_act, order, pde, wm_key,
               self.block_n, self.interpret)
        fn = _EVAL_CACHE.get(key)
        if fn is not None:
            return fn
        # order=1: no second-order stream at all; order=2: the directions the
        # PDE residual consumes (PR-2 pruning, generalized down to "none")
        d2 = () if order == 1 else (pde.d2_dirs if pde is not None else None)
        uniform, block_n, interpret = self.uniform_act, self.block_n, self.interpret

        def one(p, x, code, wm):
            if uniform is not None:
                u, du, d2u = fused.model_bundle(cfg, p, x, uniform, wm,
                                                block_n, interpret, d2_dirs=d2)
            else:
                u, du, d2u = fused.model_bundle_select(cfg, p, x, code, wm,
                                                       d2_dirs=d2)
            out = {"u": u, "grad_u": jnp.moveaxis(du, 0, 1)}  # (m, dim, F)
            if pde is not None:
                out["flux"] = pde.flux_from_derivs(x, u, du)
                if order == 2:
                    out["residual"] = pde.residual_from_derivs(x, u, du, d2u)
            return out

        fn = _EVAL_CACHE[key] = jax.jit(
            lambda params, X, codes, wms: jax.vmap(one)(params, X, codes, wms))
        return fn

    def swap_bundle(self, bundle: FieldBundle) -> None:
        """Hot-swap the served bundle in place (the watchdog reload path).

        The engine OBJECT survives, so every wrapper holding a reference
        (``GuardedEngine``, ``FaultyEngine``, frontends) serves the new field
        from the next dispatch; compiled programs are reused through the
        process-wide cache when the model config is unchanged.  Callers
        owning result caches keyed on query signatures must invalidate them
        (:meth:`repro.serve.frontend.ServeFrontend.invalidate_cache`) — the
        reload helper in :mod:`repro.launch.serve_field` does both, and only
        AFTER the new bundle verified (a corrupt candidate never gets here).
        """
        codes = np.asarray(
            bundle.act_codes if bundle.act_codes is not None
            else np.zeros((bundle.n_sub,), np.int32), np.int32)
        assert codes.shape == (bundle.n_sub,)
        self.bundle = bundle
        self._codes = jnp.asarray(codes)
        self.uniform_act = fused.uniform_act_name(codes.tolist())
        self.last_claims = None

    # ------------------------------------------------------------ public API
    def evaluate(self, pts, order: int = 2) -> dict:
        """Stitched field quantities at an arbitrary query cloud.

        Returns numpy arrays in query order: ``u (N, n_fields)``,
        ``grad_u (N, dim, n_fields)``, and — when the bundle carries a PDE —
        ``flux (N, n_eq, dim)`` plus, for ``order=2``, ``residual (N, n_eq)``
        (a served error proxy: large residual = low local confidence).
        Interface points (claimed by >= 2 subdomains) are the two-sided
        average; points outside every subdomain are NaN.
        """
        routed = self._route(pts)
        fn = self._get_fn(order)
        t0 = self.obs.clock() if self.obs is not None else None
        # the engine's span parents to whatever span is active on the shared
        # tracer — under the frontend's live microbatch span it lands at the
        # bottom of the request's trace; standalone it is its own root
        tracer = self.obs.tracer if self.obs is not None else None
        sp = (tracer.span("serve.engine", lane="engine", order=order,
                          points=len(pts)) if tracer is not None else None)
        try:
            outs = fn(*self._device_args(routed))
            out = {}
            claims = routed.claims
            for k, v in outs.items():
                out[k] = _stitch(routed, np.asarray(v), claims)  # blocks
        finally:
            if sp is not None:
                sp.end()
        self.n_dispatches += 1
        self.last_claims = claims
        if self.obs is not None:
            reg = self.obs.registry
            reg.counter("serve.engine/dispatches").inc()
            reg.counter("serve.engine/points").inc(len(claims))
            reg.histogram("serve.engine/dispatch_s").record(
                self.obs.clock() - t0)
        return out
