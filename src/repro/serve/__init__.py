"""Field-serving subsystem: export -> route -> stitch -> serve.

The paper's end product is a *field* (e.g. the §7.6 inferred conductivity
K(x,y) over the ten-region map); training produces per-subdomain networks.
This package freezes a trained cPINN/XPINN into a self-contained artifact
(:mod:`repro.serve.export`), routes arbitrary query clouds to subdomains with
vectorized geometry tests (:mod:`repro.serve.routing`), evaluates ALL
subdomains in one fused network entry and stitches a single-valued field
across interfaces (:mod:`repro.serve.engine`), fronts the engine with
microbatching + an LRU result cache (:mod:`repro.serve.frontend`), and makes
the whole stack survivable under production traffic — admission control,
deadlines, degraded modes, circuit breaking (:mod:`repro.serve.resilience`).
"""
from repro.serve.export import (CorruptBundleError, FieldBundle,
                                export_bundle, load_bundle)
from repro.serve.engine import FieldEngine
from repro.serve.frontend import ServeFrontend, UnknownTicketError
from repro.serve.resilience import (CircuitBreaker, EngineOutputError,
                                    GuardedEngine, ResilienceConfig,
                                    ResilientFrontend, ServeResult)
from repro.serve.routing import membership_matrix, route, RoutedQuery
