"""Resilient serving: admission control, deadlines, degradation, isolation.

:class:`~repro.serve.frontend.ServeFrontend` answers queries; this layer makes
it survivable under production traffic and production failures.  A
:class:`ResilientFrontend` wraps the frontend with four mechanisms:

* **admission control** — the queue is bounded in BOTH requests and total
  queued points; a request that would overflow either bound is answered
  immediately with a typed ``shed`` result instead of growing the queue
  without bound (fast load-shedding: the caller learns in O(1), the queue
  never melts down);
* **deadline propagation** — every request carries an (optional) deadline
  from admission; an expired request is answered ``deadline_exceeded`` and is
  NEVER dispatched — work the caller already gave up on is not worth a device
  dispatch;
* **degraded-mode ladder** — under queue pressure or repeated failure the
  service steps down ``order=2`` (full bundle: u, grad, flux, residual) →
  ``order=1`` (the engine's cheap tier: the second-order tangent stream is
  disabled) → **cache-only** (answer hits from the result cache, shed
  misses).  Degraded answers carry ``degraded=True`` and the order actually
  served, so callers can tell;
* **failure isolation** — the frontend's flush bisects a failing microbatch
  so one poisoned cloud never blocks healthy batch-mates (quarantine); this
  layer adds capped, jittered retry per quarantined cloud, a per-engine
  circuit breaker (open after K consecutive dispatch failures, half-open
  probes after a cooldown), and a NaN/Inf guard that rejects dispatches whose
  *claimed* points come back non-finite (outside-domain NaN stays legal).

The invariant the whole layer maintains: **every admitted ticket is answered
exactly once** — served, degraded, shed, deadline-exceeded, or failed — and
the queue can always make progress no matter what the engine does.

When the shared ``obs`` carries a :class:`~repro.obs.Tracer`, every ticket
ALSO gets exactly one trace: a root span opened at submit (even a request
shed in O(1) gets — and closes — one), hop events for every retry /
quarantine / ladder step-down / cache-only fallback, the inner frontend's
queue-wait + dispatch + engine spans as children via the same trace_id, and
the root closed with the final status in :meth:`_answer` — the one choke
point every answer already passes through.  The trace_id surfaces on
``ServeResult.trace_id`` so callers can join answers to timelines.

Clock, sleep, and jitter RNG are injectable, so every behavior above is
unit-testable without real waiting (and the SLO benchmark can run the whole
stack on a virtual clock).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from repro.obs import MetricsRegistry, Obs
from repro.serve import routing
from repro.serve.frontend import ServeFrontend, UnknownTicketError, _signature


class EngineOutputError(RuntimeError):
    """The engine returned NaN/Inf at points it claims to own."""


# --------------------------------------------------------------- output guard

class GuardedEngine:
    """Engine wrapper: reject evaluations with non-finite CLAIMED outputs.

    Points outside the domain are NaN by contract; a NaN at a claimed point
    is corruption (bad weights, kernel bug, injected fault) and must not be
    cached or handed to a caller as data.  Raising turns the poisoned cloud
    into an ordinary failed microbatch, so the frontend's bisection + the
    resilience retry path handle it like any other engine failure.
    """

    def __init__(self, engine):
        self.engine = engine
        self.trips = 0

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def evaluate(self, pts, order: int = 2) -> dict:
        out = self.engine.evaluate(pts, order=order)
        claims = getattr(self.engine, "last_claims", None)
        if claims is None or len(claims) != len(out["u"]):
            claims = routing.route(self.engine.bundle.decomp, pts).claims
        claimed = np.asarray(claims) > 0
        if claimed.any():
            for k, v in out.items():
                arr = np.asarray(v)[claimed]
                if not np.isfinite(arr).all():
                    self.trips += 1
                    flat = np.isfinite(arr.reshape(arr.shape[0], -1))
                    n = int((~flat.all(axis=1)).sum())
                    raise EngineOutputError(
                        f"non-finite {k!r} at {n} claimed point(s)")
        return out


# ------------------------------------------------------------ circuit breaker

class CircuitBreaker:
    """closed -> (K consecutive failures) -> open -> (cooldown) -> half_open.

    ``allow()`` answers "may we dispatch right now": always in ``closed``,
    never in ``open`` (until the cooldown elapses, which moves the breaker to
    ``half_open``), and in ``half_open`` exactly as a probe — a success closes
    the breaker, a failure re-opens it for another cooldown.
    """

    def __init__(self, threshold: int = 5, cooldown: float = 5.0,
                 clock=time.monotonic):
        self.threshold, self.cooldown = threshold, cooldown
        self._clock = clock
        self.state = "closed"
        self.failures = 0          # consecutive
        self.opened_at: float | None = None
        self.opens = 0

    def allow(self) -> bool:
        if self.state == "open" and \
                self._clock() - self.opened_at >= self.cooldown:
            self.state = "half_open"
        return self.state != "open"

    def record_success(self) -> None:
        self.state, self.failures, self.opened_at = "closed", 0, None

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            if self.state != "open":
                self.opens += 1
            self.state, self.opened_at = "open", self._clock()


# ------------------------------------------------------------------- results

RESULT_STATUSES = ("served", "degraded", "shed", "deadline_exceeded", "failed")


@dataclass
class ServeResult:
    """Typed answer envelope: every admitted ticket gets exactly one.

    ``data`` carries the field arrays for ``served``/``degraded`` (and for
    cache-only answers), None otherwise; ``order`` is the tier actually
    evaluated; ``reason`` says WHY for anything that is not a clean serve
    (``overload``, ``draining``, ``cache_only``, ``breaker_open``,
    ``deadline``, or the engine error text).
    """

    status: str
    data: dict | None = None
    order: int | None = None
    degraded: bool = False
    reason: str = ""
    latency: float | None = None      # end-to-end: answer clock - admission clock
    queue_wait: float | None = None   # inner-queue wait (enqueue -> dispatch)
    dispatch: float | None = None     # engine evaluation seconds of the
                                      # microbatch that served this request
    trace_id: str | None = None       # causal trace of this ticket's lifecycle
                                      # (None when tracing is off)

    @property
    def ok(self) -> bool:
        return self.data is not None


@dataclass
class ResilienceConfig:
    max_queue_requests: int = 256      # admission bound, requests
    max_queue_points: int = 1 << 20    # admission bound, total queued points
    default_deadline: float | None = None  # seconds from admission, per request
    max_queue_age: float | None = None     # anti-starvation flush (see poll)
    order: int = 2                     # full-service tier (top of the ladder)
    degrade_at: float = 0.5            # queue pressure >= this -> order=1
    cache_only_at: float = 0.9         # queue pressure >= this -> cache-only
    retry_limit: int = 2               # dispatch attempts per cloud
    retry_backoff: float = 0.05        # base backoff seconds, jittered
    breaker_threshold: int = 5         # consecutive failures -> open
    breaker_cooldown: float = 5.0      # open -> half_open after this


@dataclass(eq=False)                   # identity semantics: entries live in sets
class _Queued:
    ticket: int
    pts: np.ndarray
    admitted: float
    deadline: float | None = None
    inner: int | None = None           # inner frontend ticket while dispatched
    attempts: int = 0
    order: int = 2                     # tier this entry was dispatched at
    key: tuple = field(default=())     # order-free cloud identity
    span: object = None                # open root span of this ticket's trace


# ------------------------------------------------------------------ frontend

class ResilientFrontend:
    """Admission-controlled, deadline-aware, degradable serving frontend.

    Same submit/flush/result/poll/query shape as :class:`ServeFrontend`, but
    ``result`` returns a :class:`ServeResult` envelope and never wedges: shed
    and expired requests are answered instantly, failures are retried with
    jittered backoff up to ``retry_limit`` attempts, then answered ``failed``.
    """

    def __init__(self, engine, config: ResilienceConfig | None = None,
                 clock=time.monotonic, sleep=time.sleep, seed: int = 0,
                 obs: Obs | None = None, **frontend_kwargs):
        self.cfg = config or ResilienceConfig()
        self.guard = GuardedEngine(engine)
        self.engine = engine
        # one registry spans this layer AND the inner frontend, so a single
        # snapshot reads serve.resilience/* next to serve.frontend/*
        self.obs = obs if obs is not None else Obs(
            registry=MetricsRegistry(clock=clock))
        self._fe = ServeFrontend(self.guard, order=self.cfg.order,
                                 clock=clock, obs=self.obs, **frontend_kwargs)
        self._clock, self._sleep = clock, sleep
        self._rng = np.random.default_rng(seed)
        self.breaker = CircuitBreaker(self.cfg.breaker_threshold,
                                      self.cfg.breaker_cooldown, clock)
        self._queue: list[_Queued] = []
        self._queued_points = 0
        self._results: dict[int, ServeResult] = {}
        self._next_ticket = 0
        self._answered = 0             # answers recorded (ever), incl. retrieved
        self.draining = False
        self.level = 0                  # last ladder level used by flush
        reg = self.obs.registry
        self.counters = reg.group("serve.resilience", (
            "admitted", "served", "served_cache", "degraded",
            "shed_overload", "shed_draining", "shed_cache_only",
            "shed_breaker_open", "deadline_exceeded", "failed",
            "retries", "flush_failures",
        ))
        self._h_e2e = reg.histogram("serve.resilience/e2e_s")

    # ----------------------------------------------------------- answering
    def _answer(self, q_or_ticket, res: ServeResult, span=None) -> None:
        if isinstance(q_or_ticket, _Queued):
            ticket, admitted = q_or_ticket.ticket, q_or_ticket.admitted
            span = q_or_ticket.span if span is None else span
        else:
            ticket, admitted = q_or_ticket, self._clock()
        if span is not None:
            # every ticket's root closes HERE — shed and deadline-exceeded
            # included — which is what makes "one trace per ticket, always
            # closed" the same invariant as "every ticket answered once"
            res.trace_id = span.trace_id
            span.end(status=res.status, reason=res.reason)
        if res.latency is None:
            res.latency = max(0.0, self._clock() - admitted)
        self._h_e2e.record(res.latency)
        self._results[ticket] = res
        self._answered += 1
        key = {"served": "served", "degraded": "degraded",
               "deadline_exceeded": "deadline_exceeded",
               "failed": "failed"}.get(res.status)
        if res.status == "shed":
            key = "shed_" + res.reason
            if key not in self.counters:
                key = "shed_overload"
        if key:
            self.counters[key] += 1
        if res.reason == "cache" and res.status == "served":
            self.counters["served_cache"] += 1  # sub-count of "served"

    # ------------------------------------------------------------ admission
    def submit(self, pts, deadline: float | None = None) -> int:
        """Admit (or immediately answer) a request; returns a ticket.

        ``deadline`` is seconds from now; ``cfg.default_deadline`` applies
        when omitted.  Sheds typed-and-fast when draining or when either
        queue bound (requests / total points) would be exceeded.
        """
        pts = routing._as_cloud(pts, self.engine.bundle.decomp.dim)
        ticket = self._next_ticket
        self._next_ticket += 1
        now = self._clock()
        tr = self.obs.tracer
        span = (tr.start_trace("serve.request", lane="serve", ticket=ticket,
                               points=len(pts)) if tr is not None else None)
        if self.draining:
            self._answer(ticket, ServeResult("shed", reason="draining"),
                         span=span)
            return ticket
        cfg = self.cfg
        if (len(self._queue) >= cfg.max_queue_requests
                or self._queued_points + len(pts) > cfg.max_queue_points):
            self._answer(ticket, ServeResult("shed", reason="overload"),
                         span=span)
            return ticket
        self.counters["admitted"] += 1
        if span is not None:
            span.event("serve.admitted")
        # admission-time cache probe: a full-order hit costs no queue slot
        sig = _signature(pts, cfg.order)
        hit = self._fe._cache_get(sig)
        if hit is not None:
            self._fe.counters["cache_hits"] += 1
            if span is not None:
                span.event("serve.cache_hit")
            self._answer(ticket, ServeResult("served", data=hit,
                                             order=cfg.order, reason="cache",
                                             queue_wait=0.0, dispatch=0.0),
                         span=span)
            return ticket
        dl = deadline if deadline is not None else cfg.default_deadline
        self._queue.append(_Queued(
            ticket=ticket, pts=pts, admitted=now,
            deadline=(now + dl) if dl is not None else None,
            key=(sig[0], sig[2]), span=span))
        self._queued_points += len(pts)
        self.poll()
        return ticket

    # ------------------------------------------------------------- deadlines
    def _expire(self, entries: list[_Queued]) -> list[_Queued]:
        """Answer expired entries ``deadline_exceeded``; return the live ones.
        Expired requests are never dispatched — their inner submission (if
        any) is withdrawn from the frontend queue."""
        now, live = self._clock(), []
        for q in entries:
            if q.deadline is not None and now >= q.deadline:
                if q.inner is not None:
                    self._fe.withdraw(q.inner)
                self._answer(q, ServeResult("deadline_exceeded",
                                            reason="deadline"))
            else:
                live.append(q)
        return live

    def next_flush_due(self) -> float | None:
        """Clock time at which :meth:`poll` will flush (queue head admission
        + ``max_queue_age``), or None if nothing is pending / no age bound.
        Lets discrete-event drivers advance a virtual clock to the next
        self-scheduled flush instead of busy-polling."""
        if self.cfg.max_queue_age is None or not self._queue:
            return None
        return self._queue[0].admitted + self.cfg.max_queue_age

    def poll(self) -> bool:
        """Anti-starvation: flush once the queue head ages past
        ``cfg.max_queue_age`` (mirrors :meth:`ServeFrontend.poll`).
        The comparison is ``clock >= admitted + age`` — the SAME expression
        :meth:`next_flush_due` returns — so a driver that advances its clock
        exactly to the due time always fires (``clock - admitted >= age``
        can round one ulp short and livelock such a driver)."""
        if (self.cfg.max_queue_age is not None and self._queue
                and self._clock() >= self._queue[0].admitted
                + self.cfg.max_queue_age):
            self.flush()
            return True
        return False

    # ---------------------------------------------------------------- ladder
    def pressure(self) -> float:
        cfg = self.cfg
        return max(len(self._queue) / cfg.max_queue_requests,
                   self._queued_points / cfg.max_queue_points)

    def _ladder_level(self) -> int:
        """0 = full order, 1 = first-order degraded, 2 = cache-only."""
        p = self.pressure()
        level = 0 if p < self.cfg.degrade_at else \
            1 if p < self.cfg.cache_only_at else 2
        if not self.breaker.allow():
            return 2
        if self.breaker.state == "half_open":
            level = max(level, 1)      # probe at the cheap tier
        return level

    def _cache_only(self, entries: list[_Queued], reason: str) -> None:
        """Bottom rung: answer cache hits (any tier), shed misses."""
        for q in entries:
            if q.span is not None:
                q.span.event("serve.cache_only", reason=reason)
            hit = order = None
            for o in (self.cfg.order, 1):
                hit = self._fe._cache_get(_signature(q.pts, o))
                if hit is not None:
                    order = o
                    break
            if hit is not None:
                self._answer(q, ServeResult(
                    "degraded", data=hit, order=order, degraded=True,
                    reason="cache_only"))
            else:
                self._answer(q, ServeResult("shed", reason=reason))

    # ----------------------------------------------------------------- flush
    def flush(self) -> None:
        """Answer everything currently queued.  Never raises on engine
        failure: quarantined clouds are retried (capped, jittered) and then
        answered ``failed``; breaker-open fast-fails without dispatching."""
        # ladder level reads queue pressure — measure BEFORE dequeuing
        self.level = level = self._ladder_level()
        entries, self._queue = self._queue, []
        self._queued_points = 0
        entries = self._expire(entries)
        if not entries:
            return
        if level == 2:
            reason = ("breaker_open" if self.breaker.state == "open"
                      else "cache_only")
            self._cache_only(entries, reason)
            return
        order = self.cfg.order if level == 0 else min(self.cfg.order, 1)
        self._dispatch(entries, order)

    def _dispatch(self, entries: list[_Queued], order: int) -> None:
        self._fe.order = order
        for q in entries:
            q.inner = self._fe.submit(q.pts, parent=q.span)
            q.order = order
            q.attempts = max(q.attempts, 1)
        alive = {q.inner: q for q in entries}
        d0 = self._fe.counters["dispatches"]
        rounds = 0
        while True:
            try:
                self._fe.flush()
                if self._fe.counters["dispatches"] > d0:
                    self.breaker.record_success()
                break
            except Exception as exc:
                rounds += 1
                self.counters["flush_failures"] += 1
                self.breaker.record_failure()
                # quarantined clouds sit back in the inner queue (healthy
                # batch-mates were served by the bisection); cap retries,
                # expire, and fast-fail the rest if the breaker opened
                still = []
                for t in self._fe.pending_tickets():
                    q = alive[t]
                    q.attempts += 1
                    if q.attempts > self.cfg.retry_limit:
                        self._fe.withdraw(t)
                        del alive[t]
                        self._answer(q, ServeResult(
                            "failed", reason=f"{type(exc).__name__}: {exc}"))
                    else:
                        still.append(q)
                live = self._expire(still)   # answers + withdraws expired
                for q in still:
                    if q not in live:
                        alive.pop(q.inner, None)
                still = live
                if not still:
                    break
                if not self.breaker.allow():
                    for q in still:
                        self._fe.withdraw(q.inner)
                        del alive[q.inner]
                    self._cache_only(still, "breaker_open")
                    break
                self.counters["retries"] += 1
                for q in still:
                    if q.span is not None:
                        q.span.event("serve.retry", attempt=q.attempts,
                                     order=order)
                # jittered capped backoff before re-dispatching quarantine
                self._sleep(self.cfg.retry_backoff *
                            (1.0 + float(self._rng.uniform(0.0, 1.0))))
                # REPEATED failure (2nd retry round on): step the retry down
                # the ladder — a single transient still gets full order
                # (withdraw + resubmit so cache keys match the retried tier)
                if order > 1 and rounds >= 2:
                    order = 1
                    self.level = max(self.level, 1)
                    self._fe.order = order
                    for q in still:
                        if self._fe.withdraw(q.inner) is not None:
                            del alive[q.inner]
                            if q.span is not None:
                                q.span.event("serve.degrade", to_order=order)
                            q.inner = self._fe.submit(q.pts, parent=q.span)
                            q.order = order
                            alive[q.inner] = q
        for q in list(alive.values()):
            if q.inner is not None and self._fe.ready(q.inner):
                data = self._fe.result(q.inner)
                stage = self._fe.last_stage or {}
                degraded = q.order < self.cfg.order
                self._answer(q, ServeResult(
                    "degraded" if degraded else "served", data=data,
                    order=q.order, degraded=degraded,
                    reason="pressure" if degraded else "",
                    queue_wait=stage.get("queue_wait_s"),
                    dispatch=stage.get("dispatch_s")))

    # ---------------------------------------------------------------- results
    def result(self, ticket: int) -> ServeResult:
        self.poll()
        if ticket not in self._results:
            if any(q.ticket == ticket for q in self._queue):
                self.flush()
            else:
                raise UnknownTicketError(
                    f"ticket {ticket}: never issued or already retrieved")
        return self._results.pop(ticket)

    def query(self, pts, deadline: float | None = None) -> ServeResult:
        t = self.submit(pts, deadline=deadline)
        self.flush()
        return self.result(t)

    # ------------------------------------------------------------- lifecycle
    def drain(self) -> dict:
        """Graceful shutdown: stop admitting (new submits shed with reason
        ``draining``), answer everything still queued, report."""
        self.draining = True
        while self._queue:
            self.flush()
        return self.health()

    def health(self) -> dict:
        """Liveness/readiness snapshot for process supervisors."""
        status = ("draining" if self.draining
                  else "breaker_open" if self.breaker.state == "open"
                  else "overloaded" if self.pressure() >= self.cfg.cache_only_at
                  else "degraded" if (self.pressure() >= self.cfg.degrade_at
                                      or self.breaker.state == "half_open")
                  else "ok")
        return {
            "status": status,
            "ready": not self.draining and self.breaker.state != "open",
            "breaker": {"state": self.breaker.state,
                        "consecutive_failures": self.breaker.failures,
                        "opens": self.breaker.opens},
            "queue": {"requests": len(self._queue),
                      "points": self._queued_points,
                      "pressure": round(self.pressure(), 4)},
            "ladder_level": self.level,
            "guard_trips": self.guard.trips,
            # tickets with NO answer recorded yet (retrieved answers count as
            # answered — drain() runs before callers collect their results)
            "unanswered": self._next_ticket - self._answered,
        }

    def stats(self) -> dict:
        c = dict(self.counters)
        c["guard_trips"] = self.guard.trips
        c["breaker_opens"] = self.breaker.opens
        answered = sum(self.counters[k] for k in
                       ("served", "degraded", "shed_overload", "shed_draining",
                        "shed_cache_only", "shed_breaker_open",
                        "deadline_exceeded", "failed"))
        c["answered"] = answered
        c["frontend"] = self._fe.stats()
        # staged latency rollup: e2e here, queue wait + dispatch from the
        # inner frontend's histograms (same registry, one naming scheme)
        c["latency"] = {"e2e_s": self._h_e2e.snapshot(),
                        **c["frontend"]["latency"]}
        return c
