"""Vectorized point -> subdomain routing for arbitrary query clouds.

Training pre-assigns points to subdomains at sampling time; serving gets an
arbitrary cloud and must answer "which network(s) own each point" fast:

* :class:`~repro.core.domain.CartesianDecomposition` — O(log n_cells)
  ``searchsorted`` index math per axis (the grid is a sorted edge array), no
  per-cell loop.
* :class:`~repro.core.domain.PolygonDecomposition` — ONE vectorized
  crossing-number (even-odd) test over ALL polygons at once (the training-side
  ``_points_in_polygon`` runs per region, host-side), exactly the same
  edge arithmetic so routed ownership agrees bitwise with
  ``subdomain_contains``, plus a point-to-edge distance pass so points within
  ``tol`` of a shared edge are claimed by BOTH regions.

Interface semantics: a point claimed by >= 2 subdomains gets the XPINN-style
*averaged* (stitched) prediction in the engine, so the served field is
single-valued across interfaces (paper eq. 4).  Points claimed by nobody
(outside the domain) come back NaN with a diagnostic count.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.domain import (
    CartesianDecomposition, Decomposition, PolygonDecomposition,
)

# chunk size for the polygon edge-distance pass (bounds the (n_poly, Vmax, N)
# broadcast temporaries to a few MB regardless of query size)
_CHUNK = 16384


def _as_cloud(pts, dim: int) -> np.ndarray:
    """Validate a query cloud to (N, dim) float64 — a wrongly-shaped array
    must fail loudly, not be silently reinterpreted by a blind reshape."""
    pts = np.asarray(pts, np.float64)
    if pts.ndim == 1 and pts.shape[0] == dim:
        return pts[None, :]
    if pts.ndim != 2 or pts.shape[1] != dim:
        raise ValueError(f"query cloud must be (N, {dim}); got {pts.shape}")
    return pts


def _axis_cells(edges: np.ndarray, v: np.ndarray, tol: float):
    """Inclusive cell-index range [lo, hi] claiming each coordinate.

    Cell i spans [edges[i], edges[i+1]]; it claims v iff
    ``edges[i] - tol <= v <= edges[i+1] + tol`` — with tol=0 this is exactly
    the closed-interval test of ``CartesianDecomposition.subdomain_contains``
    (a coordinate ON an internal grid line claims both adjacent cells).
    Returns (lo, hi) int arrays; empty ranges (lo > hi) mean "outside".
    """
    n_cells = len(edges) - 1
    hi = np.searchsorted(edges, v + tol, side="right") - 1
    lo = np.searchsorted(edges, v - tol, side="left") - 1
    return np.maximum(lo, 0), np.minimum(hi, n_cells - 1)


def _cartesian_membership(dec: CartesianDecomposition, pts: np.ndarray,
                          tol: float) -> np.ndarray:
    x_lo, x_hi = _axis_cells(dec._xs, pts[:, 0], tol)
    y_lo, y_hi = _axis_cells(dec._ys, pts[:, 1], tol)
    ix = np.arange(dec.nx)[:, None]
    iy = np.arange(dec.ny)[:, None]
    in_x = (ix >= x_lo[None, :]) & (ix <= x_hi[None, :])     # (nx, N)
    in_y = (iy >= y_lo[None, :]) & (iy <= y_hi[None, :])     # (ny, N)
    # q = ix * ny + iy (paper eq. 7 rank map)
    return (in_x[:, None, :] & in_y[None, :, :]).reshape(dec.n_sub, len(pts))


def _padded_vertices(dec: PolygonDecomposition) -> np.ndarray:
    """(n_poly, Vmax, 2) vertex stack, short polygons padded by repeating the
    last vertex (degenerate zero-length edges contribute nothing to either the
    crossing-number or the edge-distance test)."""
    vmax = max(len(p) for p in dec.polygons)
    return np.stack([
        np.concatenate([p, np.repeat(p[-1:], vmax - len(p), axis=0)])
        for p in dec.polygons
    ])


def _polygon_membership(dec: PolygonDecomposition, pts: np.ndarray,
                        tol: float) -> np.ndarray:
    P = _padded_vertices(dec)                  # vertices i
    Q = np.roll(P, 1, axis=1)                  # vertices j (previous, cyclic)
    out = np.zeros((dec.n_sub, len(pts)), dtype=bool)
    xi, yi = P[..., 0][..., None], P[..., 1][..., None]   # (n_poly, Vmax, 1)
    xj, yj = Q[..., 0][..., None], Q[..., 1][..., None]
    ab = Q - P                                             # edge j -> i ... (n_poly, Vmax, 2)
    denom = (ab ** 2).sum(-1)                              # (n_poly, Vmax)
    for s in range(0, len(pts), _CHUNK):
        x, y = pts[s:s + _CHUNK, 0], pts[s:s + _CHUNK, 1]
        # identical per-edge arithmetic to domain._points_in_polygon (XOR is
        # order-independent, so the all-polygons reduce matches the sequential
        # per-region loop bitwise)
        with np.errstate(divide="ignore", invalid="ignore", over="ignore"):
            cross = (yi > y) != (yj > y)
            slope = (xj - xi) * (y - yi) / (yj - yi + 1e-300) + xi
            contrib = cross & (x < slope)
        inside = np.logical_xor.reduce(contrib, axis=1)    # (n_poly, chunk)
        if tol > 0.0:
            # point-to-segment distance: claim the region when within tol of
            # any of its edges (shared edges -> both regions claim the point)
            ap = pts[s:s + _CHUNK][None, None, :, :] - P[:, :, None, :]
            t = (ap * ab[:, :, None, :]).sum(-1) / (denom + 1e-300)[..., None]
            t = np.clip(t, 0.0, 1.0)
            d = ap - t[..., None] * ab[:, :, None, :]
            near = ((d ** 2).sum(-1) <= tol * tol).any(axis=1)
            inside |= near
        out[:, s:s + _CHUNK] = inside
    return out


def membership_matrix(decomp: Decomposition, pts: np.ndarray,
                      tol: float = 0.0) -> np.ndarray:
    """(n_sub, N) bool claim matrix for a query cloud.

    With ``tol=0`` row q equals ``decomp.subdomain_contains(q, pts)`` (bitwise
    for both decomposition families); ``tol > 0`` widens every subdomain by
    ``tol`` so interface points are claimed by all adjacent regions.  Custom
    decomposition subclasses only support ``tol=0`` (per-region containment —
    there is no generic way to widen them), so interface averaging needs one
    of the two shipped families; pass ``tol=0`` explicitly to route/engine to
    opt into one-sided containment instead.
    """
    pts = _as_cloud(pts, decomp.dim)
    if isinstance(decomp, CartesianDecomposition):
        return _cartesian_membership(decomp, pts, tol)
    if isinstance(decomp, PolygonDecomposition):
        return _polygon_membership(decomp, pts, tol)
    if tol > 0.0:
        raise NotImplementedError(
            f"{type(decomp).__name__}: tol-widened membership (interface "
            "stitching) is only implemented for Cartesian/Polygon "
            "decompositions; pass tol=0 for plain containment routing")
    return np.stack([np.asarray(decomp.subdomain_contains(q, pts), bool)
                     for q in range(decomp.n_sub)])


@dataclass
class RoutedQuery:
    """A query cloud bucketed into per-subdomain segments (engine input).

    ``X`` is the padded (n_sub, m, dim) point tensor the fused entry consumes;
    ``rows``/``pt_idx`` map every claim back to its query point in the
    flattened (n_sub * m) row space; ``primary`` marks each point's FIRST
    claim (interface points carry one primary + extra claims to average).
    """

    pts: np.ndarray        # (N, dim) float64 — the original query cloud
    membership: np.ndarray  # (n_sub, N) bool
    claims: np.ndarray     # (N,) int — number of claiming subdomains
    owner: np.ndarray      # (N,) int32 — first claiming subdomain, -1 outside
    m: int                 # bucket size (max per-subdomain count, padded)
    X: np.ndarray          # (n_sub, m, dim) float32 — bucketed points
    rows: np.ndarray       # (R,) int64 — flattened (n_sub*m) row per claim
    pt_idx: np.ndarray     # (R,) int64 — query index per claim
    primary: np.ndarray    # (R,) bool — first claim of its point

    @property
    def n_unclaimed(self) -> int:
        return int((self.claims == 0).sum())


def route(decomp: Decomposition, pts: np.ndarray, tol: float = 1e-9,
          bucket: int = 64) -> RoutedQuery:
    """Assign a query cloud to subdomains and bucket it for the fused entry.

    ``bucket`` quantizes the per-subdomain segment length so repeated queries
    of similar size reuse one compiled engine program instead of recompiling
    per distinct point count.
    """
    pts = _as_cloud(pts, decomp.dim)
    mem = membership_matrix(decomp, pts, tol)
    claims = mem.sum(axis=0).astype(np.int64)
    owner = np.where(claims > 0, mem.argmax(axis=0), -1).astype(np.int32)

    counts = mem.sum(axis=1)
    m = max(bucket, int(-(-int(counts.max() or 1) // bucket) * bucket))
    n_sub = decomp.n_sub
    X = np.zeros((n_sub, m, decomp.dim), np.float32)
    rows_l, idx_l, prim_l = [], [], []
    for q in range(n_sub):
        idx_q = np.nonzero(mem[q])[0]
        k = len(idx_q)
        if k == 0:
            continue
        X[q, :k] = pts[idx_q]
        rows_l.append(q * m + np.arange(k, dtype=np.int64))
        idx_l.append(idx_q.astype(np.int64))
        prim_l.append(owner[idx_q] == q)
    cat = lambda ls, dt: (np.concatenate(ls) if ls else np.zeros((0,), dt))
    return RoutedQuery(
        pts=pts, membership=mem, claims=claims, owner=owner, m=m, X=X,
        rows=cat(rows_l, np.int64), pt_idx=cat(idx_l, np.int64),
        primary=cat(prim_l, bool),
    )
