"""Dependency-free batching/caching frontend over a :class:`FieldEngine`.

Serving traffic is bursty and repetitive: dashboards re-request the same
dense grids, and many small concurrent requests waste dispatches.  The
frontend fixes both without threads or external deps:

* **microbatching** — queued requests are aggregated (concatenated) into
  engine calls of up to ``max_batch`` points; the engine math is
  row-independent, so each request's slice of the batched result equals its
  standalone evaluation;
* **LRU result cache** — keyed on the query-cloud signature (bytes + shape +
  order); a repeated grid is answered from memory with the BITWISE-identical
  arrays of the first evaluation, no device dispatch;
* **deadline flush** — with ``max_queue_age`` set, the oldest queued request is
  never left waiting for batch-mates beyond the deadline: ``submit``/``poll``/
  ``result`` flush the queue once its head ages out (clock injectable for
  tests), so a lone query is served within one deadline of any frontend
  activity;
* **failure isolation** — a failing microbatch is bisected so one poisoned
  cloud is quarantined (requeued at the tail) while its healthy batch-mates
  are served from the same flush;
* **counters + staged latency** — requests / points / hit rate / dispatches /
  evaluation seconds live in a :class:`~repro.obs.MetricsRegistry` under
  ``serve.frontend/*`` (``self.counters`` is a dict-shaped view, so the
  legacy ``stats()`` shape is unchanged); per-request **queue wait** (enqueue
  -> dispatch) and per-microbatch **dispatch** (engine evaluation) durations
  feed ``serve.frontend/{queue_wait_s,dispatch_s}`` histograms, and each
  ticket's stage times are stashed for the resilience layer's end-to-end
  breakdown.  Pass ``obs`` to share a registry (and its clock's event log)
  across subsystems; omit it for a private registry (legacy behavior);
* **causal tracing** — when ``obs`` carries a :class:`~repro.obs.Tracer`,
  each request gets a span tree: queue wait and dispatch land as
  retrospective child spans under the request's span (either a root the
  frontend opens itself, or the ``parent`` span :meth:`submit` was handed —
  how the resilience layer threads ONE trace_id through every hop), and the
  microbatch dispatch is a live span so the engine's own span nests under
  it.  A ``tracer=None`` obs keeps every trace branch untaken.

Admission control, deadlines, degraded modes, and retry policy live one layer
up in :mod:`repro.serve.resilience`.

Usage: ``submit() ... flush() ... result()`` for explicit microbatching, or
``query()`` as the one-shot convenience (submit + flush + result).  Serving
loops with ``max_queue_age`` should call ``poll()`` on their idle path.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict

import numpy as np

from repro.obs import MetricsRegistry, Obs
from repro.serve.engine import FieldEngine


class UnknownTicketError(KeyError):
    """The ticket was never issued by this frontend, or its result was
    already retrieved (``result`` hands each ticket's arrays out once)."""


def _signature(pts: np.ndarray, order: int) -> tuple:
    return (pts.shape, order,
            hashlib.sha1(np.ascontiguousarray(pts).tobytes()).hexdigest())


class ServeFrontend:
    def __init__(self, engine: FieldEngine, order: int = 2,
                 max_batch: int = 16384, cache_size: int = 64,
                 cache_points: int | None = 1 << 22,
                 max_queue_age: float | None = None,
                 clock=time.monotonic, obs: Obs | None = None):
        self.engine = engine
        self.order = order
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.cache_points = cache_points
        self.max_queue_age = max_queue_age
        self._clock = clock
        self._cache: OrderedDict[tuple, dict] = OrderedDict()
        self._cache_pts = 0
        # pending entry: (ticket, pts, key, enqueue_time)
        self._pending: list[tuple[int, np.ndarray, tuple, float]] = []
        self._results: dict[int, dict] = {}
        self._next_ticket = 0
        self.obs = obs
        reg = obs.registry if obs is not None else MetricsRegistry(clock=clock)
        self.registry = reg
        self.counters = reg.group(
            "serve.frontend",
            ("requests", "points", "cache_hits", "cache_misses", "dispatches",
             "dispatched_points", "eval_seconds", "deadline_flushes",
             "quarantined"))
        self._h_queue_wait = reg.histogram("serve.frontend/queue_wait_s")
        self._h_dispatch = reg.histogram("serve.frontend/dispatch_s")
        # ticket -> {"queue_wait_s", "dispatch_s"}; recorded when the answer
        # lands, popped with result() — the resilience layer reads it for the
        # end-to-end latency breakdown
        self.stage_times: dict[int, dict] = {}
        self.last_stage: dict | None = None
        self.tracer = obs.tracer if obs is not None else None
        # ticket -> (request span, owned: bool, enqueue on the TRACER clock);
        # owned=False means a layer above opened the span and will close it
        self._req_spans: dict[int, tuple] = {}

    # ------------------------------------------------------------- caching
    def _cache_get(self, key: tuple) -> dict | None:
        out = self._cache.get(key)
        if out is not None:
            self._cache.move_to_end(key)
        return out

    def _cache_put(self, key: tuple, result: dict) -> None:
        n = key[0][0]  # points in the cloud (signature leads with pts.shape)
        if self.cache_points is not None and n > self.cache_points:
            return  # one giant grid must not monopolize (then thrash) the cache
        if key not in self._cache:
            self._cache_pts += n
        self._cache[key] = result
        self._cache.move_to_end(key)
        # evict by BOTH entry count and total cached points: the entry bound
        # alone lets cache_size huge grids pin gigabytes of result arrays
        while (len(self._cache) > self.cache_size
               or (self.cache_points is not None
                   and self._cache_pts > self.cache_points)):
            old, _ = self._cache.popitem(last=False)
            self._cache_pts -= old[0][0]

    def invalidate_cache(self) -> None:
        """Drop every cached result — REQUIRED after the engine's bundle is
        hot-swapped (cached arrays answer for the OLD field otherwise)."""
        self._cache.clear()
        self._cache_pts = 0

    # ------------------------------------------------------------- requests
    def submit(self, pts, parent=None) -> int:
        """Queue a request; returns a ticket for :meth:`result`.

        ``parent``: an open tracer span to hang this request's stage spans
        under (the resilience layer passes its root so the whole lifecycle
        shares one trace_id); without it, a tracer-on frontend opens its own
        root per request."""
        from repro.serve.routing import _as_cloud

        pts = _as_cloud(pts, self.engine.bundle.decomp.dim)
        ticket = self._next_ticket
        self._next_ticket += 1
        self.counters["requests"] += 1
        self.counters["points"] += len(pts)
        tr = self.tracer
        if tr is not None:
            span = parent if parent is not None else tr.start_trace(
                "serve.request", lane="serve", points=len(pts))
            self._req_spans[ticket] = (span, parent is None, tr.clock())
        key = _signature(pts, self.order)
        cached = self._cache_get(key)
        if cached is not None:
            self.counters["cache_hits"] += 1
            self._results[ticket] = cached
            self.stage_times[ticket] = {"queue_wait_s": 0.0, "dispatch_s": 0.0}
            if tr is not None:
                span.event("serve.cache_hit")
        else:
            self.counters["cache_misses"] += 1
            self._pending.append((ticket, pts, key, self._clock()))
        self.poll()
        return ticket

    # ------------------------------------------------------------- deadline
    def _deadline_due(self) -> bool:
        # clock >= enqueue + age (NOT clock - enqueue >= age): keeps the fire
        # condition bitwise-consistent with schedulers that precompute the due
        # time as enqueue + age — the subtraction form can round one ulp short
        return (self.max_queue_age is not None and bool(self._pending)
                and self._clock() >= self._pending[0][3] + self.max_queue_age)

    def poll(self) -> bool:
        """Flush iff the OLDEST queued request has waited ``max_queue_age`` —
        the anti-starvation path: a lone query with no batch-mates is served at
        the next frontend activity (submit/result/poll) past its deadline
        instead of waiting for the queue to fill.  Returns True if it flushed."""
        if not self._deadline_due():
            return False
        self.counters["deadline_flushes"] += 1
        self.flush()
        return True

    def flush(self) -> None:
        """Evaluate queued requests in microbatches of <= ``max_batch`` points.

        Duplicate clouds inside one flush are evaluated once and shared; each
        microbatch is ONE engine dispatch regardless of how many requests it
        aggregates.  A failing microbatch is BISECTED: healthy batch-mates are
        served, only the poisoned cloud(s) are quarantined — re-queued at the
        queue TAIL (original arrival times kept) — and the first failure is
        re-raised once everything servable has been served.  One poisoned
        query therefore never wedges the queue: the old behavior (re-queue the
        whole batch at the head) replayed the same failing microbatch forever.
        """
        pending, self._pending = self._pending, []
        by_key: OrderedDict[tuple, list] = OrderedDict()
        for ticket, pts, key, enq in pending:
            ent = by_key.setdefault(key, [pts, []])
            ent[1].append((ticket, enq))
        unique = [(key, pts, toks) for key, (pts, toks) in by_key.items()]
        failures: list = []
        i = 0
        while i < len(unique):
            # greedy microbatch: at least one request, then pack until full
            batch = [unique[i]]
            total = len(unique[i][1])
            i += 1
            while i < len(unique) and total + len(unique[i][1]) <= self.max_batch:
                batch.append(unique[i])
                total += len(unique[i][1])
                i += 1
            self._eval_batch(batch, failures)
        if failures:
            for key, pts, toks, _exc in failures:
                self._pending.extend((t, pts, key, enq) for t, enq in toks)
            raise failures[0][3]

    def _eval_batch(self, batch: list, failures: list) -> None:
        """One microbatch dispatch; on failure, bisect to isolate the poison."""
        cloud = np.concatenate([pts for _, pts, _ in batch], axis=0)
        tr, mb = self.tracer, None
        if tr is not None:
            # the microbatch span hangs off the first traced request in the
            # batch (its "leader"); the engine's own span nests under it via
            # the active-span stack, so at least one request's tree reaches
            # engine depth — and a bisect-isolated retry batch of one always
            # does
            lead = next((self._req_spans[t][0] for _k, _p, toks in batch
                         for t, _e in toks if t in self._req_spans), None)
            mb = tr.span("serve.microbatch", parent=lead, clouds=len(batch),
                         points=len(cloud))
        try:
            t0 = self._clock()
            if mb is not None:
                with mb:
                    out = self.engine.evaluate(cloud, order=self.order)
            else:
                out = self.engine.evaluate(cloud, order=self.order)
            dt = self._clock() - t0
            self.counters["eval_seconds"] += dt
        except Exception as exc:
            if len(batch) == 1:   # isolated: this cloud is the poison
                self.counters["quarantined"] += 1
                if tr is not None:
                    for t, _enq in batch[0][2]:
                        ent = self._req_spans.get(t)
                        if ent is not None:
                            ent[0].event("serve.quarantine",
                                         error=type(exc).__name__)
                failures.append(batch[0] + (exc,))
                return
            mid = len(batch) // 2
            self._eval_batch(batch[:mid], failures)
            self._eval_batch(batch[mid:], failures)
            return
        self.counters["dispatches"] += 1
        self.counters["dispatched_points"] += len(cloud)
        self._h_dispatch.record(dt)
        ofs = 0
        for key, pts, toks in batch:
            n = len(pts)
            # detach from the full-microbatch arrays (a view would pin the
            # whole batch in memory for the cache's lifetime) and freeze:
            # cache hits hand out the SAME arrays, so caller mutation
            # would otherwise silently poison later hits
            res = {}
            for k, v in out.items():
                arr = v[ofs:ofs + n].copy()
                arr.flags.writeable = False
                res[k] = arr
            ofs += n
            self._cache_put(key, res)
            for t, enq in toks:
                self._results[t] = res
                wait = max(0.0, t0 - enq)
                self._h_queue_wait.record(wait)
                self.stage_times[t] = {"queue_wait_s": wait, "dispatch_s": dt}
                if mb is not None:
                    ent = self._req_spans.get(t)
                    if ent is not None:
                        span, _owned, enq_t = ent
                        tr.record("serve.queue_wait", enq_t,
                                  max(enq_t, mb.t0), parent=span)
                        tr.record("serve.dispatch", mb.t0, mb.t1, parent=span,
                                  clouds=len(batch))

    # ------------------------------------------------------------- results
    def ready(self, ticket: int) -> bool:
        return ticket in self._results

    def pending_tickets(self) -> list[int]:
        return [t for t, _pts, _key, _enq in self._pending]

    def withdraw(self, ticket: int):
        """Remove a still-pending request (policy layers: deadlines, retry
        caps).  Returns the withdrawn ``(pts, key)`` or None if not pending."""
        for i, (t, pts, key, _enq) in enumerate(self._pending):
            if t == ticket:
                del self._pending[i]
                ent = self._req_spans.pop(t, None)
                if ent is not None and ent[1]:
                    ent[0].end(status="withdrawn")
                return pts, key
        return None

    def result(self, ticket: int) -> dict:
        """Pop a ticket's result.  A still-pending ticket auto-flushes the
        queue (it used to ``KeyError`` opaquely); an unknown or already-popped
        ticket raises :class:`UnknownTicketError`.  The ticket's stage times
        (queue wait / dispatch seconds) move to ``self.last_stage`` for the
        resilience layer's latency breakdown."""
        self.poll()
        if ticket not in self._results:
            if any(t == ticket for t, _p, _k, _e in self._pending):
                self.flush()
            else:
                raise UnknownTicketError(
                    f"ticket {ticket}: never issued or already retrieved "
                    f"(results are handed out once)")
        self.last_stage = self.stage_times.pop(ticket, None)
        ent = self._req_spans.pop(ticket, None)
        if ent is not None and ent[1]:
            ent[0].end(status="served")
        return self._results.pop(ticket)

    def query(self, pts) -> dict:
        """One-shot convenience: submit + flush + result."""
        t = self.submit(pts)
        self.flush()
        return self.result(t)

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        c = dict(self.counters)
        c["cache_entries"] = len(self._cache)
        c["cache_points"] = self._cache_pts
        lookups = c["cache_hits"] + c["cache_misses"]
        c["hit_rate"] = c["cache_hits"] / lookups if lookups else 0.0
        # engine throughput counts only points that actually dispatched —
        # dividing cache-served traffic by dispatch time would inflate it
        c["points_per_sec"] = (c["dispatched_points"] / c["eval_seconds"]
                               if c["eval_seconds"] > 0 else float("inf"))
        c["latency"] = {"queue_wait_s": self._h_queue_wait.snapshot(),
                        "dispatch_s": self._h_dispatch.snapshot()}
        return c
