"""Dependency-free batching/caching frontend over a :class:`FieldEngine`.

Serving traffic is bursty and repetitive: dashboards re-request the same
dense grids, and many small concurrent requests waste dispatches.  The
frontend fixes both without threads or external deps:

* **microbatching** — queued requests are aggregated (concatenated) into
  engine calls of up to ``max_batch`` points; the engine math is
  row-independent, so each request's slice of the batched result equals its
  standalone evaluation;
* **LRU result cache** — keyed on the query-cloud signature (bytes + shape +
  order); a repeated grid is answered from memory with the BITWISE-identical
  arrays of the first evaluation, no device dispatch;
* **deadline flush** — with ``max_queue_age`` set, the oldest queued request is
  never left waiting for batch-mates beyond the deadline: ``submit``/``poll``/
  ``result`` flush the queue once its head ages out (clock injectable for
  tests), so a lone query is served within one deadline of any frontend
  activity;
* **counters** — requests / points / hit rate / dispatches / evaluation
  seconds, for the throughput benchmark and ops dashboards.

Usage: ``submit() ... flush() ... result()`` for explicit microbatching, or
``query()`` as the one-shot convenience (submit + flush + result).  Serving
loops with ``max_queue_age`` should call ``poll()`` on their idle path.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict

import numpy as np

from repro.serve.engine import FieldEngine


def _signature(pts: np.ndarray, order: int) -> tuple:
    return (pts.shape, order,
            hashlib.sha1(np.ascontiguousarray(pts).tobytes()).hexdigest())


class ServeFrontend:
    def __init__(self, engine: FieldEngine, order: int = 2,
                 max_batch: int = 16384, cache_size: int = 64,
                 max_queue_age: float | None = None,
                 clock=time.monotonic):
        self.engine = engine
        self.order = order
        self.max_batch = max_batch
        self.cache_size = cache_size
        self.max_queue_age = max_queue_age
        self._clock = clock
        self._cache: OrderedDict[tuple, dict] = OrderedDict()
        self._pending: list[tuple[int, np.ndarray, tuple, float]] = []
        self._results: dict[int, dict] = {}
        self._next_ticket = 0
        self.counters = {"requests": 0, "points": 0, "cache_hits": 0,
                         "cache_misses": 0, "dispatches": 0,
                         "dispatched_points": 0, "eval_seconds": 0.0,
                         "deadline_flushes": 0}

    # ------------------------------------------------------------- caching
    def _cache_get(self, key: tuple) -> dict | None:
        out = self._cache.get(key)
        if out is not None:
            self._cache.move_to_end(key)
        return out

    def _cache_put(self, key: tuple, result: dict) -> None:
        self._cache[key] = result
        self._cache.move_to_end(key)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    # ------------------------------------------------------------- requests
    def submit(self, pts) -> int:
        """Queue a request; returns a ticket for :meth:`result`."""
        from repro.serve.routing import _as_cloud

        pts = _as_cloud(pts, self.engine.bundle.decomp.dim)
        ticket = self._next_ticket
        self._next_ticket += 1
        self.counters["requests"] += 1
        self.counters["points"] += len(pts)
        key = _signature(pts, self.order)
        cached = self._cache_get(key)
        if cached is not None:
            self.counters["cache_hits"] += 1
            self._results[ticket] = cached
        else:
            self.counters["cache_misses"] += 1
            self._pending.append((ticket, pts, key, self._clock()))
        self.poll()
        return ticket

    # ------------------------------------------------------------- deadline
    def _deadline_due(self) -> bool:
        return (self.max_queue_age is not None and bool(self._pending)
                and self._clock() - self._pending[0][3] >= self.max_queue_age)

    def poll(self) -> bool:
        """Flush iff the OLDEST queued request has waited ``max_queue_age`` —
        the anti-starvation path: a lone query with no batch-mates is served at
        the next frontend activity (submit/result/poll) past its deadline
        instead of waiting for the queue to fill.  Returns True if it flushed."""
        if not self._deadline_due():
            return False
        self.counters["deadline_flushes"] += 1
        self.flush()
        return True

    def flush(self) -> None:
        """Evaluate queued requests in microbatches of <= ``max_batch`` points.

        Duplicate clouds inside one flush are evaluated once and shared; each
        microbatch is ONE engine dispatch regardless of how many requests it
        aggregates.  A failing evaluation re-queues every not-yet-served
        request before re-raising, so tickets are never silently lost.
        """
        pending, self._pending = self._pending, []
        by_key: OrderedDict[tuple, list] = OrderedDict()
        for ticket, pts, key, _enq in pending:
            by_key.setdefault(key, [ticket, pts])
            if by_key[key][0] != ticket:
                by_key[key].append(ticket)
        unique = [(key, v[1], [v[0]] + v[2:]) for key, v in by_key.items()]
        i = 0
        while i < len(unique):
            # greedy microbatch: at least one request, then pack until full
            batch = [unique[i]]
            total = len(unique[i][1])
            i += 1
            while i < len(unique) and total + len(unique[i][1]) <= self.max_batch:
                batch.append(unique[i])
                total += len(unique[i][1])
                i += 1
            cloud = np.concatenate([pts for _, pts, _ in batch], axis=0)
            try:
                t0 = time.perf_counter()
                out = self.engine.evaluate(cloud, order=self.order)
                self.counters["eval_seconds"] += time.perf_counter() - t0
            except Exception:
                now = self._clock()
                for key, pts, tickets in batch + unique[i:]:
                    self._pending.extend((t, pts, key, now) for t in tickets)
                raise
            self.counters["dispatches"] += 1
            self.counters["dispatched_points"] += len(cloud)
            ofs = 0
            for key, pts, tickets in batch:
                n = len(pts)
                # detach from the full-microbatch arrays (a view would pin the
                # whole batch in memory for the cache's lifetime) and freeze:
                # cache hits hand out the SAME arrays, so caller mutation
                # would otherwise silently poison later hits
                res = {}
                for k, v in out.items():
                    arr = v[ofs:ofs + n].copy()
                    arr.flags.writeable = False
                    res[k] = arr
                ofs += n
                self._cache_put(key, res)
                for t in tickets:
                    self._results[t] = res

    def result(self, ticket: int) -> dict:
        self.poll()
        return self._results.pop(ticket)

    def query(self, pts) -> dict:
        """One-shot convenience: submit + flush + result."""
        t = self.submit(pts)
        self.flush()
        return self.result(t)

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        c = dict(self.counters)
        lookups = c["cache_hits"] + c["cache_misses"]
        c["hit_rate"] = c["cache_hits"] / lookups if lookups else 0.0
        # engine throughput counts only points that actually dispatched —
        # dividing cache-served traffic by dispatch time would inflate it
        c["points_per_sec"] = (c["dispatched_points"] / c["eval_seconds"]
                               if c["eval_seconds"] > 0 else float("inf"))
        return c
