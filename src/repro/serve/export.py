"""Frozen field artifacts: export a trained cPINN/XPINN, load it anywhere.

An exported bundle is a :mod:`repro.checkpoint.ckpt` checkpoint directory
(npz + manifest, atomic publication, keep-last-k) whose manifest metadata
additionally freezes everything needed to rebuild an inference-ready object
WITHOUT importing the trainer:

* the per-field :class:`~repro.core.nets.MLPConfig` stack,
* per-subdomain activation codes and width masks (paper Table-3 heterogeneity),
* the decomposition geometry (Cartesian grid spec or exact polygon vertices)
  plus the interface sampling density (``n_iface``) so the communication
  :class:`~repro.core.domain.Topology` can be rebuilt on demand,
* the PDE identity + constructor fields (for served flux/residual outputs).

``load_bundle`` returns a :class:`FieldBundle`; feed it to
:class:`repro.serve.engine.FieldEngine` to serve the stitched field.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core.domain import (
    CartesianDecomposition, Decomposition, PolygonDecomposition, Topology,
    build_topology,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig, act_code
from repro.core.pdes import PDE, REGISTRY

FORMAT = "repro.serve.bundle/1"


@dataclass
class FieldBundle:
    """Everything the inference engine needs, trainer-free.

    ``params`` are the STACKED per-subdomain parameters (leading n_sub axis,
    exactly the trainers' ``TrainState.params`` layout); ``act_codes`` is an
    (n_sub,) int vector; ``width_masks`` the optional per-net (n_sub, width)
    capacity masks.  Construct directly for in-memory serving (e.g.
    ``evaluate_l2``) or via :func:`load_bundle` from an exported artifact.
    """

    model_cfg: SubdomainModelConfig
    params: Any
    decomp: Decomposition
    act_codes: np.ndarray | None = None
    width_masks: dict | None = None
    pde: PDE | None = None
    n_iface: int = 16
    metadata: dict = dataclasses.field(default_factory=dict)

    @property
    def n_sub(self) -> int:
        return self.decomp.n_sub

    def topology(self) -> Topology:
        """Rebuild the exchange topology frozen with the bundle."""
        return build_topology(self.decomp, self.n_iface)


# ------------------------------------------------------------- geometry specs

def decomp_spec(decomp: Decomposition) -> dict:
    if isinstance(decomp, CartesianDecomposition):
        return {"kind": "cartesian", "bounds": [list(b) for b in decomp.bounds],
                "nx": decomp.nx, "ny": decomp.ny}
    if isinstance(decomp, PolygonDecomposition):
        return {"kind": "polygon",
                "polygons": [p.tolist() for p in decomp.polygons],
                "tol": decomp.tol}
    raise TypeError(f"cannot serialize decomposition {type(decomp).__name__}")


def decomp_from_spec(spec: dict) -> Decomposition:
    if spec["kind"] == "cartesian":
        return CartesianDecomposition(spec["bounds"], spec["nx"], spec["ny"])
    if spec["kind"] == "polygon":
        return PolygonDecomposition([np.asarray(p) for p in spec["polygons"]],
                                    tol=spec.get("tol", 1e-9))
    raise ValueError(f"unknown decomposition kind {spec['kind']!r}")


def _pde_spec(pde: PDE | None) -> dict | None:
    if pde is None:
        return None
    return {"name": pde.name, "fields": dataclasses.asdict(pde)}


def _pde_from_spec(spec: dict | None) -> PDE | None:
    if spec is None:
        return None
    return REGISTRY[spec["name"]](**spec["fields"])


def _normalize_codes(act_codes, model_cfg: SubdomainModelConfig,
                     n_sub: int) -> np.ndarray:
    if act_codes is None:
        from repro.core.nets import uniform_model_act
        return np.full((n_sub,), act_code(uniform_model_act(model_cfg)),
                       np.int32)
    return np.array([act_code(c) if isinstance(c, str) else int(c)
                     for c in np.asarray(act_codes).tolist()], np.int32)


# ------------------------------------------------------------- export / load

def export_bundle(
    root: str,
    params: Any,
    model_cfg: SubdomainModelConfig,
    decomp: Decomposition,
    act_codes=None,
    width_masks: dict | None = None,
    pde: PDE | None = None,
    n_iface: int = 16,
    step: int = 0,
    metadata: dict | None = None,
) -> str:
    """Freeze a trained field into a self-contained serve artifact.

    ``params`` is the stacked params pytree (``TrainState.params``); returns
    the checkpoint directory written (atomic — crash-safe like any
    ``repro.checkpoint`` save).
    """
    n_sub = decomp.n_sub
    codes = _normalize_codes(act_codes, model_cfg, n_sub)
    tree = {"params": params}
    if width_masks is not None:
        tree["width_masks"] = width_masks
    meta = {
        "format": FORMAT,
        "model": {name: dataclasses.asdict(c)
                  for name, c in model_cfg.nets.items()},
        "act_codes": codes.tolist(),
        "width_mask_nets": (sorted(width_masks) if width_masks else []),
        "decomp": decomp_spec(decomp),
        "pde": _pde_spec(pde),
        "n_iface": int(n_iface),
        "user": metadata or {},
    }
    return ckpt.save(root, step, tree, metadata=meta)


def _params_template(model_cfg: SubdomainModelConfig, n_sub: int) -> dict:
    out = {}
    for name, c in model_cfg.nets.items():
        out[name] = {
            "W": [np.zeros((n_sub, fi, fo), np.float32)
                  for fi, fo in c.layer_dims],
            "b": [np.zeros((n_sub, fo), np.float32)
                  for _, fo in c.layer_dims],
            "a": np.zeros((n_sub, c.depth), np.float32),
        }
    return out


def load_bundle(root: str, step: int | None = None) -> FieldBundle:
    """Load an exported bundle into an inference-ready :class:`FieldBundle`.

    Self-contained: rebuilds model config, geometry, and PDE from the manifest
    metadata, then restores the parameter arrays against a structure template
    derived from the config — no trainer (and no training state) involved.
    """
    if step is None:
        step = ckpt.latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no bundle under {root}")
    with open(os.path.join(root, f"step_{step:010d}", "manifest.json")) as f:
        meta = json.load(f)["metadata"]
    if meta.get("format") != FORMAT:
        raise ValueError(f"{root} is not a serve bundle "
                         f"(format={meta.get('format')!r})")
    model_cfg = SubdomainModelConfig(
        nets={name: MLPConfig(**fields) for name, fields in meta["model"].items()})
    decomp = decomp_from_spec(meta["decomp"])
    n_sub = decomp.n_sub
    like = {"params": _params_template(model_cfg, n_sub)}
    if meta["width_mask_nets"]:
        widths = {name: model_cfg.nets[name].width
                  for name in meta["width_mask_nets"]}
        like["width_masks"] = {name: np.zeros((n_sub, w), np.float32)
                               for name, w in widths.items()}
    tree, _ = ckpt.restore(root, like, step=step)
    return FieldBundle(
        model_cfg=model_cfg,
        params=jax.tree.map(jnp.asarray, tree["params"]),
        decomp=decomp,
        act_codes=np.asarray(meta["act_codes"], np.int32),
        width_masks=(jax.tree.map(jnp.asarray, tree["width_masks"])
                     if meta["width_mask_nets"] else None),
        pde=_pde_from_spec(meta["pde"]),
        n_iface=meta["n_iface"],
        metadata=meta["user"],
    )
