"""Frozen field artifacts: export a trained cPINN/XPINN, load it anywhere.

An exported bundle is a :mod:`repro.checkpoint.ckpt` checkpoint directory
(npz + manifest, atomic publication, keep-last-k) whose manifest metadata
additionally freezes everything needed to rebuild an inference-ready object
WITHOUT importing the trainer:

* the per-field :class:`~repro.core.nets.MLPConfig` stack,
* per-subdomain activation codes and width masks (paper Table-3 heterogeneity),
* the decomposition geometry (Cartesian grid spec or exact polygon vertices)
  plus the interface sampling density (``n_iface``) so the communication
  :class:`~repro.core.domain.Topology` can be rebuilt on demand,
* the PDE identity + constructor fields (for served flux/residual outputs).

``load_bundle`` returns a :class:`FieldBundle`; feed it to
:class:`repro.serve.engine.FieldEngine` to serve the stitched field.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt, integrity
from repro.core.domain import (
    CartesianDecomposition, Decomposition, PolygonDecomposition, Topology,
    build_topology,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig, act_code
from repro.core.pdes import PDE, REGISTRY

FORMAT = "repro.serve.bundle/1"


class CorruptBundleError(RuntimeError):
    """An exported bundle failed verification or could not be decoded.

    Replaces the raw ``zipfile``/``numpy``/``json`` exceptions that used to
    leak out of :func:`load_bundle` on a truncated or garbage artifact:
    ``file`` names the failing file inside the bundle generation, ``array``
    the failing npz member (when the corruption localizes), and ``field``
    the bundle field that member belongs to (``params/u``, ``width_masks``,
    ...)."""

    def __init__(self, root: str, reason: str, file: str | None = None,
                 array: str | None = None, field: str | None = None):
        self.root, self.reason = str(root), reason
        self.file, self.array, self.field = file, array, field
        at = "".join([f" file={file}" if file else "",
                      f" array={array}" if array else "",
                      f" field={field}" if field else ""])
        super().__init__(f"corrupt bundle under {root}{at}: {reason}")


def _leaf_field(manifest: dict | None, array: str | None) -> str | None:
    """Map an npz member name (``leaf_00017``) back to the bundle field its
    path names — what an operator needs to know, not the member index."""
    if manifest is None or array is None or not array.startswith("leaf_"):
        return None
    try:
        path = manifest["paths"][int(array.split("_", 1)[1])]
    except (KeyError, IndexError, ValueError):
        return None
    return path


@dataclass
class FieldBundle:
    """Everything the inference engine needs, trainer-free.

    ``params`` are the STACKED per-subdomain parameters (leading n_sub axis,
    exactly the trainers' ``TrainState.params`` layout); ``act_codes`` is an
    (n_sub,) int vector; ``width_masks`` the optional per-net (n_sub, width)
    capacity masks.  Construct directly for in-memory serving (e.g.
    ``evaluate_l2``) or via :func:`load_bundle` from an exported artifact.
    """

    model_cfg: SubdomainModelConfig
    params: Any
    decomp: Decomposition
    act_codes: np.ndarray | None = None
    width_masks: dict | None = None
    pde: PDE | None = None
    n_iface: int = 16
    metadata: dict = dataclasses.field(default_factory=dict)

    @property
    def n_sub(self) -> int:
        return self.decomp.n_sub

    def topology(self) -> Topology:
        """Rebuild the exchange topology frozen with the bundle."""
        return build_topology(self.decomp, self.n_iface)


# ------------------------------------------------------------- geometry specs

def decomp_spec(decomp: Decomposition) -> dict:
    if isinstance(decomp, CartesianDecomposition):
        return {"kind": "cartesian", "bounds": [list(b) for b in decomp.bounds],
                "nx": decomp.nx, "ny": decomp.ny}
    if isinstance(decomp, PolygonDecomposition):
        return {"kind": "polygon",
                "polygons": [p.tolist() for p in decomp.polygons],
                "tol": decomp.tol}
    raise TypeError(f"cannot serialize decomposition {type(decomp).__name__}")


def decomp_from_spec(spec: dict) -> Decomposition:
    if spec["kind"] == "cartesian":
        return CartesianDecomposition(spec["bounds"], spec["nx"], spec["ny"])
    if spec["kind"] == "polygon":
        return PolygonDecomposition([np.asarray(p) for p in spec["polygons"]],
                                    tol=spec.get("tol", 1e-9))
    raise ValueError(f"unknown decomposition kind {spec['kind']!r}")


def _pde_spec(pde: PDE | None) -> dict | None:
    if pde is None:
        return None
    return {"name": pde.name, "fields": dataclasses.asdict(pde)}


def _pde_from_spec(spec: dict | None) -> PDE | None:
    if spec is None:
        return None
    return REGISTRY[spec["name"]](**spec["fields"])


def _normalize_codes(act_codes, model_cfg: SubdomainModelConfig,
                     n_sub: int) -> np.ndarray:
    if act_codes is None:
        from repro.core.nets import uniform_model_act
        return np.full((n_sub,), act_code(uniform_model_act(model_cfg)),
                       np.int32)
    return np.array([act_code(c) if isinstance(c, str) else int(c)
                     for c in np.asarray(act_codes).tolist()], np.int32)


# ------------------------------------------------------------- export / load

def export_bundle(
    root: str,
    params: Any,
    model_cfg: SubdomainModelConfig,
    decomp: Decomposition,
    act_codes=None,
    width_masks: dict | None = None,
    pde: PDE | None = None,
    n_iface: int = 16,
    step: int = 0,
    metadata: dict | None = None,
) -> str:
    """Freeze a trained field into a self-contained serve artifact.

    ``params`` is the stacked params pytree (``TrainState.params``); returns
    the checkpoint directory written (atomic — crash-safe like any
    ``repro.checkpoint`` save).
    """
    n_sub = decomp.n_sub
    codes = _normalize_codes(act_codes, model_cfg, n_sub)
    tree = {"params": params}
    if width_masks is not None:
        tree["width_masks"] = width_masks
    meta = {
        "format": FORMAT,
        "model": {name: dataclasses.asdict(c)
                  for name, c in model_cfg.nets.items()},
        "act_codes": codes.tolist(),
        "width_mask_nets": (sorted(width_masks) if width_masks else []),
        "decomp": decomp_spec(decomp),
        "pde": _pde_spec(pde),
        "n_iface": int(n_iface),
        "user": metadata or {},
    }
    return ckpt.save(root, step, tree, metadata=meta)


def _params_template(model_cfg: SubdomainModelConfig, n_sub: int) -> dict:
    out = {}
    for name, c in model_cfg.nets.items():
        out[name] = {
            "W": [np.zeros((n_sub, fi, fo), np.float32)
                  for fi, fo in c.layer_dims],
            "b": [np.zeros((n_sub, fo), np.float32)
                  for _, fo in c.layer_dims],
            "a": np.zeros((n_sub, c.depth), np.float32),
        }
    return out


def load_bundle(root: str, step: int | None = None, verify: bool = True,
                max_fallback: int = 0) -> FieldBundle:
    """Load an exported bundle into an inference-ready :class:`FieldBundle`.

    Self-contained: rebuilds model config, geometry, and PDE from the manifest
    metadata, then restores the parameter arrays against a structure template
    derived from the config — no trainer (and no training state) involved.

    ``verify=True`` (the default) checks the generation's integrity envelope
    BEFORE constructing anything: any corruption — truncated/garbage npz,
    flipped bits, missing files — raises :class:`CorruptBundleError` naming
    the failing file/array/field instead of leaking a raw ``zipfile``/
    ``numpy`` exception, and a corrupt bundle never reaches the engine.
    ``max_fallback`` > 0 additionally lets the load walk back through older
    bundle generations, SKIPPING corrupt ones (read-only — quarantine
    renames are the single-writer trainer side's job, see
    :func:`repro.checkpoint.integrity.latest_verified_step`); the default 0
    makes a corrupt newest generation a hard, typed failure — the contract
    the serve watchdog's refuse-the-swap reload relies on.
    """
    def _from_ckpt_err(e: integrity.CorruptCheckpointError,
                       cause: BaseException) -> CorruptBundleError:
        gen = os.path.basename(e.path)
        which = ("arrays.npz" if e.array or "arrays.npz" in e.reason
                 else "manifest.json")
        man = None
        try:
            with open(os.path.join(e.path, "manifest.json")) as f:
                man = json.load(f)
        except Exception:
            pass
        err = CorruptBundleError(root, e.reason, file=f"{gen}/{which}",
                                 array=e.array,
                                 field=_leaf_field(man, e.array))
        err.__cause__ = cause
        return err

    try:
        if step is None:
            if not integrity.generations(root):
                raise FileNotFoundError(f"no bundle under {root}")
            if verify:
                # the serve-side load is read-only: the generation walk
                # SKIPS corrupt bundles without quarantining them — renames
                # belong to the (single-writer) trainer/export side
                step = integrity.latest_verified_step(
                    root, max_fallback=max_fallback,
                    do_quarantine=False).step
            else:
                step = ckpt.latest_step(root)
        elif verify:
            integrity.verify_step_dir(os.path.join(root, f"step_{step:010d}"))
        with open(os.path.join(root, f"step_{step:010d}",
                               "manifest.json")) as f:
            meta = json.load(f)["metadata"]
    except integrity.NoVerifiedCheckpointError as e:
        if e.failures:  # surface the newest generation's localized failure
            raise _from_ckpt_err(e.failures[0], e)
        raise CorruptBundleError(root, str(e)) from e
    except integrity.CorruptCheckpointError as e:
        raise _from_ckpt_err(e, e)
    except (OSError, json.JSONDecodeError, UnicodeDecodeError, KeyError) as e:
        if isinstance(e, FileNotFoundError) and e.filename is None:
            raise   # the typed no-bundle miss above, not a decode failure
        raise CorruptBundleError(root, f"manifest unreadable: {e}",
                                 file="manifest.json") from e
    if meta.get("format") != FORMAT:
        raise ValueError(f"{root} is not a serve bundle "
                         f"(format={meta.get('format')!r})")
    model_cfg = SubdomainModelConfig(
        nets={name: MLPConfig(**fields) for name, fields in meta["model"].items()})
    decomp = decomp_from_spec(meta["decomp"])
    n_sub = decomp.n_sub
    like = {"params": _params_template(model_cfg, n_sub)}
    if meta["width_mask_nets"]:
        widths = {name: model_cfg.nets[name].width
                  for name in meta["width_mask_nets"]}
        like["width_masks"] = {name: np.zeros((n_sub, w), np.float32)
                               for name, w in widths.items()}
    try:
        tree, _ = ckpt.restore(root, like, step=step)
    except Exception as e:
        # legacy (pre-integrity) bundle with a rotten npz: the verify pass
        # had nothing to check, so the decode error surfaces here — typed
        raise CorruptBundleError(root, f"arrays.npz undecodable: {e}",
                                 file="arrays.npz") from e
    return FieldBundle(
        model_cfg=model_cfg,
        params=jax.tree.map(jnp.asarray, tree["params"]),
        decomp=decomp,
        act_codes=np.asarray(meta["act_codes"], np.int32),
        width_masks=(jax.tree.map(jnp.asarray, tree["width_masks"])
                     if meta["width_mask_nets"] else None),
        pde=_pde_from_spec(meta["pde"]),
        n_iface=meta["n_iface"],
        metadata=meta["user"],
    )
