"""Unified telemetry substrate: metrics registry, JSONL events, profiling.

Dependency-free observability shared by training (``core.trainer`` telemetry
rows, ``runtime.supervisor``), serving (``serve.frontend`` /
``serve.resilience`` staged latency histograms), and the benchmarks
(comp/comm split, retrace flatness).  See EXPERIMENTS.md §Observability for
the metric catalog and the JSONL schema.

Entry points:

* :class:`MetricsRegistry` — counters / gauges / log-bucket histograms with
  percentile export and ONE injectable clock;
* :class:`EventLog` / :func:`validate_events` — JSONL event sink with a
  per-run manifest and a strict, smoke-validated schema;
* :class:`CompileWatcher` / :func:`comp_comm_split` / :func:`scope` —
  compile/retrace counting, walltime comp-vs-comm splitting, and the
  named-scope annotation vocabulary;
* :class:`Tracer` / :class:`Span` — span-based causal tracing with
  trace_id propagation (see EXPERIMENTS.md §Tracing), exported to
  Chrome-trace/Perfetto timelines via :mod:`repro.obs.trace_export`;
* :mod:`repro.obs.trajectory` — append-only bench history + the
  drift-robust perf regression gate;
* :class:`Obs` — the bundle the subsystems actually accept: a registry plus
  an optional event log and an optional tracer sharing its clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.obs.events import (EVENT_KINDS, EventLog, ObsSchemaError,
                              SCHEMA_VERSION, check_fields, read_events,
                              validate_events)
from repro.obs.profiling import (CompileWatcher, SCOPES, comp_comm_split,
                                 compile_counts, halo_traffic, scope)
from repro.obs.registry import (Counter, CounterGroup, Gauge, Histogram,
                                MetricsRegistry)
from repro.obs.trace_export import (ChromeTraceError, export_chrome_trace,
                                    halo_flow_events, to_chrome,
                                    training_timeline, validate_chrome_trace)
from repro.obs.tracing import Span, Tracer


@dataclass
class Obs:
    """Registry + optional event sink + optional tracer, one clock.

    Subsystems take ``obs: Obs | None``; ``None`` means "keep your own
    private registry" (legacy behavior, zero overhead change), and a None
    ``tracer`` keeps tracing bitwise out of every code path.  Build with
    :func:`make_obs` so the event log and tracer inherit the registry
    clock.
    """

    registry: MetricsRegistry
    events: EventLog | None = None
    tracer: Tracer | None = None

    @property
    def clock(self):
        return self.registry.clock

    def emit(self, kind: str, **fields) -> None:
        """Emit an event iff a sink is attached (metrics-only Obs is legal)."""
        if self.events is not None:
            self.events.emit(kind, **fields)

    def close(self) -> None:
        if self.events is not None:
            self.events.close()


def make_obs(jsonl_path: str | None = None, clock=time.perf_counter,
             run_id: str | None = None, config: dict | None = None,
             trace: bool = False, trace_sample: float = 1.0,
             trace_capacity: int = 8192) -> Obs:
    """One-call setup: registry (+ JSONL event log when a path is given,
    + tracer when ``trace``), all sharing ``clock``."""
    reg = MetricsRegistry(clock=clock)
    ev = (EventLog(jsonl_path, clock=clock, run_id=run_id, config=config)
          if jsonl_path else None)
    tr = (Tracer(clock=clock, sample_rate=trace_sample,
                 capacity=trace_capacity) if trace else None)
    return Obs(registry=reg, events=ev, tracer=tr)


__all__ = [
    "Counter", "CounterGroup", "Gauge", "Histogram", "MetricsRegistry",
    "EventLog", "ObsSchemaError", "check_fields", "read_events",
    "validate_events", "EVENT_KINDS", "SCHEMA_VERSION",
    "CompileWatcher", "SCOPES", "comp_comm_split", "compile_counts",
    "halo_traffic", "scope",
    "Span", "Tracer",
    "ChromeTraceError", "export_chrome_trace", "halo_flow_events",
    "to_chrome", "training_timeline", "validate_chrome_trace",
    "Obs", "make_obs",
]
