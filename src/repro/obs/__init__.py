"""Unified telemetry substrate: metrics registry, JSONL events, profiling.

Dependency-free observability shared by training (``core.trainer`` telemetry
rows, ``runtime.supervisor``), serving (``serve.frontend`` /
``serve.resilience`` staged latency histograms), and the benchmarks
(comp/comm split, retrace flatness).  See EXPERIMENTS.md §Observability for
the metric catalog and the JSONL schema.

Entry points:

* :class:`MetricsRegistry` — counters / gauges / log-bucket histograms with
  percentile export and ONE injectable clock;
* :class:`EventLog` / :func:`validate_events` — JSONL event sink with a
  per-run manifest and a strict, smoke-validated schema;
* :class:`CompileWatcher` / :func:`comp_comm_split` / :func:`scope` —
  compile/retrace counting, walltime comp-vs-comm splitting, and the
  named-scope annotation vocabulary;
* :class:`Obs` — the bundle the subsystems actually accept: a registry plus
  an optional event log sharing its clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

from repro.obs.events import (EVENT_KINDS, EventLog, ObsSchemaError,
                              SCHEMA_VERSION, read_events, validate_events)
from repro.obs.profiling import (CompileWatcher, SCOPES, comp_comm_split,
                                 compile_counts, halo_traffic, scope)
from repro.obs.registry import (Counter, CounterGroup, Gauge, Histogram,
                                MetricsRegistry)


@dataclass
class Obs:
    """Registry + optional event sink, one clock.

    Subsystems take ``obs: Obs | None``; ``None`` means "keep your own
    private registry" (legacy behavior, zero overhead change).  Build with
    :func:`make_obs` so the event log inherits the registry clock.
    """

    registry: MetricsRegistry
    events: EventLog | None = None

    @property
    def clock(self):
        return self.registry.clock

    def emit(self, kind: str, **fields) -> None:
        """Emit an event iff a sink is attached (metrics-only Obs is legal)."""
        if self.events is not None:
            self.events.emit(kind, **fields)

    def close(self) -> None:
        if self.events is not None:
            self.events.close()


def make_obs(jsonl_path: str | None = None, clock=time.perf_counter,
             run_id: str | None = None, config: dict | None = None) -> Obs:
    """One-call setup: registry (+ JSONL event log when a path is given),
    sharing ``clock``."""
    reg = MetricsRegistry(clock=clock)
    ev = (EventLog(jsonl_path, clock=clock, run_id=run_id, config=config)
          if jsonl_path else None)
    return Obs(registry=reg, events=ev)


__all__ = [
    "Counter", "CounterGroup", "Gauge", "Histogram", "MetricsRegistry",
    "EventLog", "ObsSchemaError", "read_events", "validate_events",
    "EVENT_KINDS", "SCHEMA_VERSION",
    "CompileWatcher", "SCOPES", "comp_comm_split", "compile_counts",
    "halo_traffic", "scope",
    "Obs", "make_obs",
]
