"""Chrome-trace / Perfetto export: span trees -> an openable timeline file.

Converts :mod:`repro.obs.tracing` spans into the Chrome trace-event JSON
format (the ``traceEvents`` array understood by ``chrome://tracing`` and
https://ui.perfetto.dev — drag the file in, or Perfetto's "Open trace").
Three layers:

* :func:`to_chrome` — spans (+ optional flow arrows) -> the trace document.
  Lanes become named "threads"; within a lane, concurrent traces are packed
  into parallel sub-tracks (waterfall layout) so the strict B/E begin/end
  nesting the format requires always holds; flow arrows (``ph: s/f``) draw
  the halo-exchange arcs between neighbor subdomain lanes;
* :func:`halo_flow_events` / :func:`training_timeline` — synthesize the
  per-subdomain lanes and neighbor halo arrows for a training trace from
  the chunk spans, the decomposition's neighbor table, and (optionally) the
  analytic byte counts of :func:`repro.obs.profiling.halo_traffic`.  The
  compiled chunk is ONE fused dispatch — XLA does not emit per-subdomain
  host timings — so these lanes are an analytic rendering: real chunk wall
  times, topology-true arrows, byte-true weights;
* :func:`validate_chrome_trace` — the structural contract the smoke suite
  and tests enforce: well-formed events, non-decreasing timestamps, every
  B matched by an E (per thread, stack-ordered), every flow start matched
  by a flow finish.  A trace that Perfetto would render wrong FAILS here.

Timestamps are rebased to the earliest span and expressed in microseconds,
as the format requires.
"""
from __future__ import annotations

import json
import os


def _as_dict(span) -> dict:
    """Normalize a tracing.Span or a plain dict to the exporter's record."""
    if isinstance(span, dict):
        d = dict(span)
        d.setdefault("lane", None)
        d.setdefault("attrs", {})
        d.setdefault("trace_id", "t0")
        d.setdefault("parent_id", None)
        d.setdefault("span_id", id(span))
        return d
    return {"name": span.name, "lane": span.lane, "t0": span.t0,
            "t1": span.t1 if span.t1 is not None else span.t0,
            "trace_id": span.trace_id, "span_id": span.span_id,
            "parent_id": span.parent_id, "attrs": dict(span.attrs)}


def _pack_slots(extents: list[tuple[str, float, float]]) -> dict[str, int]:
    """Greedy waterfall: assign each trace (keyed by id, with [t0, t1]
    extent) the first slot whose previous occupant has ended."""
    slot_end: list[float] = []
    out: dict[str, int] = {}
    for key, t0, t1 in sorted(extents, key=lambda e: (e[1], e[2])):
        for i, end in enumerate(slot_end):
            if end <= t0:
                out[key], slot_end[i] = i, t1
                break
        else:
            out[key] = len(slot_end)
            slot_end.append(t1)
    return out


def _emit_tree(spans: list[dict], ts, out: list[dict], pid: int,
               tid: int) -> None:
    """Emit B/E pairs for one laminar family (one trace on one lane), DFS
    order, clamping children into parents and serializing overlapping
    siblings so the stack discipline the format requires always holds."""
    by_parent: dict = {}
    ids = {s["span_id"] for s in spans}
    for s in spans:
        pk = s["parent_id"] if s["parent_id"] in ids else None
        by_parent.setdefault(pk, []).append(s)

    def walk(parent_key, lo, hi):
        cursor = lo
        for s in sorted(by_parent.get(parent_key, []),
                        key=lambda x: (x["t0"], x["span_id"])):
            t0 = min(max(s["t0"], cursor), hi)
            t1 = min(max(s["t1"], t0), hi)
            args = {"trace_id": s["trace_id"], **s["attrs"]}
            if s["attrs"].get("instant"):
                out.append({"ph": "i", "s": "t", "name": s["name"],
                            "pid": pid, "tid": tid, "ts": ts(t0),
                            "args": args})
            else:
                out.append({"ph": "B", "name": s["name"], "pid": pid,
                            "tid": tid, "ts": ts(t0), "args": args})
                walk(s["span_id"], t0, t1)
                out.append({"ph": "E", "name": s["name"], "pid": pid,
                            "tid": tid, "ts": ts(t1)})
            cursor = max(cursor, t1)

    lo = min(s["t0"] for s in spans)
    hi = max(s["t1"] for s in spans)
    walk(None, lo, hi)


def to_chrome(spans, flows=(), process_name: str = "repro") -> dict:
    """Build a Chrome trace document from spans and optional flow arrows.

    ``spans``: tracing.Span objects or dicts with at least
    ``{name, lane, t0, t1}``.  ``flows``: dicts
    ``{name, id?, src, dst, t_src, t_dst, ...attrs}`` where src/dst are lane
    names — rendered as Perfetto flow arcs between the lanes.
    """
    recs = [_as_dict(s) for s in spans]
    if not recs:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    origin = min(r["t0"] for r in recs)
    ts = lambda t: round((t - origin) * 1e6, 3)  # noqa: E731 — us, rebased

    # lane -> trace groups -> waterfall slots (tid per lane-slot)
    lanes: dict[str, dict[str, list[dict]]] = {}
    for r in recs:
        lane = r["lane"] or "main"
        lanes.setdefault(lane, {}).setdefault(r["trace_id"], []).append(r)

    events: list[dict] = []
    tid_of: dict[tuple[str, int], int] = {}
    pid = 1
    for lane in sorted(lanes):
        groups = lanes[lane]
        extents = [(tr, min(s["t0"] for s in ss),
                    max(s["t1"] for s in ss)) for tr, ss in groups.items()]
        slots = _pack_slots(extents)
        for tr in sorted(groups, key=lambda tr: slots[tr]):
            tid_of.setdefault((lane, slots[tr]), len(tid_of) + 1)
    body: list[dict] = []
    for lane in sorted(lanes):
        groups = lanes[lane]
        extents = [(tr, min(s["t0"] for s in ss),
                    max(s["t1"] for s in ss)) for tr, ss in groups.items()]
        slots = _pack_slots(extents)
        for tr, ss in groups.items():
            _emit_tree(ss, ts, body, pid, tid_of[(lane, slots[tr])])

    flow_evs: list[dict] = []
    for i, fl in enumerate(flows):
        src_tid = tid_of.get((fl["src"], 0))
        dst_tid = tid_of.get((fl["dst"], 0))
        if src_tid is None or dst_tid is None:
            continue  # flow references a lane with no spans — undrawable
        fid = int(fl.get("id", i + 1))
        args = {k: v for k, v in fl.items()
                if k not in ("name", "id", "src", "dst", "t_src", "t_dst")}
        flow_evs.append({"ph": "s", "cat": "halo", "name": fl["name"],
                         "id": fid, "pid": pid, "tid": src_tid,
                         "ts": ts(fl["t_src"]), "args": args})
        flow_evs.append({"ph": "f", "bp": "e", "cat": "halo",
                         "name": fl["name"], "id": fid, "pid": pid,
                         "tid": dst_tid,
                         "ts": ts(max(fl["t_dst"], fl["t_src"])),
                         "args": args})

    body.extend(flow_evs)
    body.sort(key=lambda e: e["ts"])  # stable: per-tid emit order survives

    meta = [{"ph": "M", "name": "process_name", "pid": pid, "ts": 0,
             "args": {"name": process_name}}]
    for (lane, slot), tid in sorted(tid_of.items(), key=lambda kv: kv[1]):
        label = lane if slot == 0 else f"{lane}#{slot + 1}"
        meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                     "tid": tid, "ts": 0, "args": {"name": label}})
    return {"traceEvents": meta + body, "displayTimeUnit": "ms"}


# ------------------------------------------------- training-timeline synthesis

def halo_flow_events(pairs, t0: float, t1: float, total_bytes: int = 0,
                     rounds: int = 1, name: str = "dd-comm-halo") -> list[dict]:
    """Flow arrows for the directed neighbor ``pairs`` [(src, dst), ...]
    across ``rounds`` evenly spaced exchange instants inside [t0, t1],
    splitting ``total_bytes`` (e.g. the ``collective_permute_bytes`` of the
    analytic HLO parse) evenly across arrows."""
    pairs = [tuple(p) for p in pairs]
    if not pairs or t1 <= t0:
        return []
    n = len(pairs) * max(1, rounds)
    per = int(total_bytes // n) if total_bytes else 0
    dt = (t1 - t0) / (max(1, rounds) + 1)
    hop = min(dt * 0.25, (t1 - t0) * 0.02)
    out, fid = [], 0
    for r in range(max(1, rounds)):
        t = t0 + (r + 1) * dt
        for (src, dst) in pairs:
            fid += 1
            out.append({"name": name, "id": fid, "src": f"sub{src}",
                        "dst": f"sub{dst}", "t_src": t, "t_dst": t + hop,
                        "bytes": per})
    return out


def training_timeline(chunk_spans, topo, halo: dict | None = None,
                      rounds_per_chunk: int = 1):
    """Per-subdomain lanes + halo arrows for a supervised training trace.

    ``chunk_spans``: committed chunk-level spans (one per supervisor chunk or
    run_chunk dispatch).  ``topo``: a ``core.domain.Topology`` (its
    ``neighbor`` table gives the directed edges).  ``halo``: the dict from
    :func:`repro.obs.profiling.halo_traffic` on the lowered chunk HLO, used
    to weight the arrows with real byte counts (0 when absent, e.g. the
    reference trainer whose gather is not a collective).

    Returns ``(lane_spans, flows)`` to pass to :func:`to_chrome` alongside
    the host-side spans.
    """
    import numpy as np

    nb = np.asarray(topo.neighbor)
    n_sub = nb.shape[0]
    pairs = [(i, int(j)) for i in range(n_sub) for j in nb[i] if j >= 0]
    total_bytes = int((halo or {}).get("collective_permute_bytes", 0))

    lane_spans: list[dict] = []
    flows: list[dict] = []
    for k, sp in enumerate(chunk_spans):
        d = _as_dict(sp)
        t0, t1 = d["t0"], d["t1"]
        for i in range(n_sub):
            lane_spans.append({
                "name": d["name"], "lane": f"sub{i}", "t0": t0, "t1": t1,
                "trace_id": d["trace_id"], "span_id": f"sub{i}.{k}",
                "parent_id": None,
                "attrs": {"subdomain": i, **d["attrs"]}})
        flows.extend(halo_flow_events(pairs, t0, t1, total_bytes,
                                      rounds=rounds_per_chunk))
    return lane_spans, flows


# ----------------------------------------------------------------- validation

class ChromeTraceError(ValueError):
    """The document violates the Chrome trace-event structural contract."""


def validate_chrome_trace(doc) -> dict:
    """Structural validation: the contract ``run.py --smoke`` and the tests
    enforce on every exported trace.

    Checks: a ``traceEvents`` list of well-formed events (``ph``/``pid``/
    ``tid``/``name``, numeric non-negative ``ts``); timestamps non-decreasing
    in file order (metadata aside); per-thread B/E stack discipline with
    name-matched pairs and nothing left open; every flow start (``s``)
    finished (``f``) at a later-or-equal ts.  Returns a summary dict.
    """
    if isinstance(doc, str):
        with open(doc) as f:
            doc = json.load(f)
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"),
                                                   list):
        raise ChromeTraceError("no traceEvents array")
    evs = doc["traceEvents"]
    if not evs:
        raise ChromeTraceError("empty traceEvents")

    stacks: dict = {}
    flows_open: dict = {}
    last_ts = None
    counts = {"B": 0, "E": 0, "i": 0, "s": 0, "f": 0, "M": 0}
    tids = set()
    for i, ev in enumerate(evs):
        where = f"event {i}"
        if not isinstance(ev, dict):
            raise ChromeTraceError(f"{where}: not an object")
        ph = ev.get("ph")
        if ph not in counts:
            raise ChromeTraceError(f"{where}: unknown ph {ph!r}")
        counts[ph] += 1
        if not isinstance(ev.get("name"), str) or \
                not isinstance(ev.get("pid"), int):
            raise ChromeTraceError(f"{where}: missing name/pid")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ChromeTraceError(f"{where}: bad ts {ts!r}")
        if last_ts is not None and ts < last_ts:
            raise ChromeTraceError(
                f"{where}: ts {ts} < previous {last_ts} — not sorted")
        last_ts = ts
        tid = ev.get("tid")
        if not isinstance(tid, int):
            raise ChromeTraceError(f"{where}: bad tid {tid!r}")
        tids.add((ev["pid"], tid))
        key = (ev["pid"], tid)
        if ph == "B":
            stacks.setdefault(key, []).append(ev["name"])
        elif ph == "E":
            st = stacks.get(key)
            if not st:
                raise ChromeTraceError(f"{where}: E with empty stack on "
                                       f"pid/tid {key}")
            top = st.pop()
            if top != ev["name"]:
                raise ChromeTraceError(
                    f"{where}: E {ev['name']!r} does not match open B "
                    f"{top!r} on pid/tid {key}")
        elif ph == "s":
            flows_open[ev.get("id")] = ts
        elif ph == "f":
            fid = ev.get("id")
            if fid not in flows_open:
                raise ChromeTraceError(f"{where}: flow finish {fid!r} with "
                                       f"no start")
            if ts < flows_open.pop(fid):
                raise ChromeTraceError(f"{where}: flow {fid!r} finishes "
                                       f"before it starts")
    for key, st in stacks.items():
        if st:
            raise ChromeTraceError(f"unclosed B spans on pid/tid {key}: {st}")
    if flows_open:
        raise ChromeTraceError(f"unfinished flows: {sorted(flows_open)}")
    if counts["B"] != counts["E"]:
        raise ChromeTraceError(
            f"unmatched B/E: {counts['B']} begins, {counts['E']} ends")
    return {"events": len(evs), "span_pairs": counts["B"],
            "instants": counts["i"], "flows": counts["s"],
            "lanes": len(tids)}


def export_chrome_trace(path: str, spans, flows=(),
                        process_name: str = "repro") -> dict:
    """Build, validate, and write a Chrome trace JSON; returns the
    validation summary.  An export that Perfetto could not render raises
    instead of writing a broken artifact."""
    doc = to_chrome(spans, flows, process_name=process_name)
    summary = validate_chrome_trace(doc)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f)
    return summary
