"""Profiling hooks: named scopes, compile/retrace counting, comp-vs-comm split.

Three tools that turn the repo's recurring forensic questions into one-line
assertions:

* **named-scope annotation scheme** — :func:`scope` extends the PR-4
  ``pinn2-bwd-*`` convention to the whole chunk driver: communication is
  bracketed ``dd-comm-halo`` (the ppermute/gather interface exchange), compute
  ``dd-comp-forward`` / ``dd-comp-update`` (megabatched network entry + loss
  backward + Adam).  The scopes land in compiled-HLO ``op_name`` metadata, so
  tests and the comp/comm splitter can attribute ops by phase
  (:func:`repro.utils.hlo.named_scope_counts`) instead of guessing;

* **compile/retrace counter** — :class:`CompileWatcher` counts
  ``jax.monitoring`` compile events process-wide (backend compiles, jaxpr
  traces, and compile seconds).  Cache-hit dispatches emit ZERO events
  (probe-verified), so "no retracing across batch buckets / lr_scale changes /
  guarded chunks" is a flat-line assertion — PR 4 spent a full investigation
  proving a serve regression was NOT retracing; with this counter that proof
  is ``watcher.backend_compiles == 0``;

* **comp-vs-comm walltime splitter** — :func:`comp_comm_split` times the full
  chunk (ppermute halo exchange inside the scan body) against the
  exchange-ablated chunk (``disable_exchange=True`` replaces comm with the
  local payload, keeping compute identical) in INTERLEAVED rounds with paired
  per-round statistics — the drift-robust protocol every benchmark here uses —
  and reports comp/comm/total per step.  :func:`halo_traffic` complements the
  walltime split with the analytic per-device collective-permute bytes parsed
  from the compiled chunk HLO (:mod:`repro.utils.hlo`), i.e. the paper's
  O(N_iface) communication-cost argument, measured.
"""
from __future__ import annotations

import time
from collections import defaultdict

import jax
import numpy as np

# The annotation scheme: one stable name per phase.  Keys are the phase
# vocabulary ("comm", "comp_forward", ...), values the HLO-visible scope
# names.  pinn2-bwd-* (PR 4) are listed so one table documents every marker.
SCOPES = {
    "comm": "dd-comm-halo",
    "comp_forward": "dd-comp-forward",
    "comp_update": "dd-comp-update",
    "bwd_fused": "pinn2-bwd-fused",
    "bwd_ref": "pinn2-bwd-ref",
    "bwd_fused_select": "pinn2-bwd-fused-select",
}


def scope(phase: str):
    """``with scope("comm"): ...`` — named scope from the phase vocabulary
    (unknown phases raise: the scheme only works if names stay canonical)."""
    try:
        return jax.named_scope(SCOPES[phase])
    except KeyError:
        raise ValueError(f"unknown profiling phase {phase!r}; "
                         f"known: {sorted(SCOPES)}") from None


# ------------------------------------------------------- compile/retrace count

_EVENTS = {
    "/jax/core/compile/backend_compile_duration": "backend_compiles",
    "/jax/core/compile/jaxpr_trace_duration": "traces",
}
_counts: dict[str, int] = defaultdict(int)
_seconds: dict[str, float] = defaultdict(float)
_installed = False


def _install() -> None:
    """Register the process-wide listener once (jax.monitoring has no
    unregister; a single accumulating listener + snapshot deltas avoids
    ever needing one)."""
    global _installed
    if _installed:
        return
    import jax.monitoring as monitoring

    def _listener(event: str, duration: float, **_kw) -> None:
        key = _EVENTS.get(event)
        if key is not None:
            _counts[key] += 1
            _seconds[key] += duration

    monitoring.register_event_duration_secs_listener(_listener)
    _installed = True


def compile_counts() -> dict:
    """Process-lifetime compile/trace counts (monotone; diff two snapshots
    or use :class:`CompileWatcher` for scoped deltas)."""
    _install()
    return {"backend_compiles": _counts["backend_compiles"],
            "traces": _counts["traces"],
            "compile_seconds": round(_seconds["backend_compiles"], 6)}


class CompileWatcher:
    """Scoped compile-event delta: ``with CompileWatcher() as w: ...`` then
    ``w.backend_compiles`` / ``w.traces`` / ``w.compile_seconds``.

    A cache-hit jit dispatch emits no events, so asserting
    ``w.backend_compiles == 0`` over a serving loop IS the no-retrace-storm
    regression test.  Optionally mirrors the delta into a registry
    (``obs.compile/*`` counters) and an event log (``compile`` event).
    """

    def __init__(self, registry=None, events=None):
        _install()
        self._registry, self._events = registry, events
        self.backend_compiles = self.traces = 0
        self.compile_seconds = 0.0

    def __enter__(self):
        self._c0 = dict(_counts)
        self._s0 = dict(_seconds)
        return self

    def __exit__(self, *exc):
        self.backend_compiles = (_counts["backend_compiles"]
                                 - self._c0.get("backend_compiles", 0))
        self.traces = _counts["traces"] - self._c0.get("traces", 0)
        self.compile_seconds = (_seconds["backend_compiles"]
                                - self._s0.get("backend_compiles", 0.0))
        if self._registry is not None:
            g = self._registry.group("obs.compile",
                                     ("backend_compiles", "traces"))
            g["backend_compiles"] += self.backend_compiles
            g["traces"] += self.traces
        if self._events is not None:
            self._events.emit("compile", backend_compiles=self.backend_compiles,
                              traces=self.traces,
                              compile_seconds=round(self.compile_seconds, 6))
        return False


# ------------------------------------------------------------- comp/comm split

def comp_comm_split(run_total, run_comp_only, iters: int = 5,
                    warmup: int = 1, steps: int = 1,
                    clock=time.perf_counter, tracer=None) -> dict:
    """Wall-time comp-vs-comm split of a chunked training step.

    ``run_total`` runs one chunk WITH the halo exchange; ``run_comp_only``
    runs the identical chunk with the exchange ablated
    (``DDConfig.disable_exchange=True``: the loss consumes the local payload,
    so compute is identical and the difference is the communication term —
    the paper's Fig-6 protocol).  Both callables must block until ready and
    handle their own state rebinding (donated buffers).

    Timed in interleaved rounds (total, comp, total, comp, ...) so the
    container's CPU-quota drift hits both paths equally; ``comm`` is the
    median of PAIRED per-round differences, floored at 0 (a noisy round can
    go negative).  ``steps`` divides everything down to per-step seconds.

    ``tracer`` (optional :class:`repro.obs.tracing.Tracer`): each timed round
    lands as a ``train.ablation`` trace with ``train.total`` /
    ``train.comp_only`` child spans, so the comp/comm split is visible on the
    Perfetto timeline next to the chunk spans it explains.
    """
    for _ in range(max(warmup, 1)):
        run_total()
        run_comp_only()
    t_tot, t_comp = [], []
    for i in range(iters):
        root = (tracer.start_trace("train.ablation", lane="train", round=i)
                if tracer is not None else None)
        t0 = clock()
        run_total()
        t1 = clock()
        t_tot.append(t1 - t0)
        t2 = clock()
        run_comp_only()
        t3 = clock()
        t_comp.append(t3 - t2)
        if root is not None:
            tracer.record("train.total", t0, t1, parent=root, round=i)
            tracer.record("train.comp_only", t2, t3, parent=root, round=i)
            root.end()
    tot, comp = np.asarray(t_tot), np.asarray(t_comp)
    comm = float(np.median(tot - comp))
    return {
        "total_s": float(np.median(tot)) / steps,
        "comp_s": float(np.median(comp)) / steps,
        "comm_s": max(0.0, comm) / steps,
        "comm_frac": max(0.0, comm) / max(float(np.median(tot)), 1e-30),
        "rounds": int(iters),
    }


def halo_traffic(hlo_text: str) -> dict:
    """Analytic per-device halo-exchange traffic of a compiled chunk: the
    collective-permute byte/op accounting (:mod:`repro.utils.hlo`) plus the
    named-scope attribution — how many collective ops sit under the
    ``dd-comm-halo`` scope (all of them, if the annotation scheme holds)."""
    from repro.utils import hlo as hlo_lib

    coll = hlo_lib.collective_bytes(hlo_text)
    scopes = hlo_lib.named_scope_counts(hlo_text, prefix="dd-")
    return {
        "collective_permute_ops": coll["counts"].get("collective-permute", 0),
        "collective_permute_bytes":
            coll["bytes_by_kind"].get("collective-permute", 0.0),
        "total_collective_bytes": coll["total_bytes"],
        "scope_op_counts": scopes,
    }
