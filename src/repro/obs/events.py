"""JSONL event sink with a per-run manifest and a validatable schema.

Every training/serving run can append structured events to one ``.jsonl``
file: the first line is a ``manifest`` event identifying the run (run id,
schema version, jax version/backend, free-form config), every following line
is a timestamped event of a REGISTERED kind.  The schema is deliberately
strict — unknown kinds and missing/['wrongly typed'] required fields FAIL
validation — because the smoke suite treats a malformed event stream as a
broken build (``benchmarks/run.py --smoke`` validates the file it emits).

Event envelope::

    {"t": <seconds, registry clock>, "kind": "<registered kind>", ...fields}

Registered kinds (``EVENT_KINDS``): required field -> type predicate.  Extra
fields are allowed everywhere (forward compatibility); required fields are
not optional.  ``validate_events`` returns the manifest on success and raises
:class:`ObsSchemaError` with the offending line number otherwise.
"""
from __future__ import annotations

import json
import os
import uuid

_num = (int, float)

SCHEMA_VERSION = 1

# kind -> {required field: type-or-tuple}.  "t" is required on every
# non-manifest event by the envelope check, not listed per kind.
EVENT_KINDS: dict[str, dict] = {
    "manifest": {"run_id": str, "schema_version": int},
    "metrics": {"snapshot": dict},              # registry.snapshot() dump
    "chunk": {"step": int, "steps": int, "loss": _num, "walltime_s": _num},
    "guard_trip": {"chunk": int, "bad_subdomains": list, "good_steps": int},
    "crash": {"chunk": int},
    "rollback": {"step": int, "recovery_s": _num},
    "straggler": {"chunk": int, "delay_s": _num},
    "heartbeat": {"status": str},
    "serve_report": {"requests": int, "goodput": _num},
    "compile": {"backend_compiles": int, "traces": int},
    "bench": {"name": str, "value": _num},
    # durable-state integrity (EXPERIMENTS.md §Durability): a generation
    # failed verification / restore fell back past corrupt generations /
    # a watchdog bundle reload swapped (or refused to swap) the live bundle
    "corruption": {"target": str, "reason": str},
    "fallback": {"target": str, "depth": int},
    "bundle_swap": {"swapped": bool, "path": str},
}


class ObsSchemaError(ValueError):
    """An event line violates the JSONL schema (malformed JSON, missing
    manifest, unknown kind, or a missing/mistyped required field)."""


class EventLog:
    """Append-only JSONL writer.  One manifest line at open, one line per
    :meth:`emit`, flushed eagerly (a crashed run keeps every committed
    event).  ``clock`` stamps the ``t`` field — inject the registry clock so
    event times and metric timers share a timebase."""

    def __init__(self, path: str, clock, run_id: str | None = None,
                 config: dict | None = None):
        self.path = str(path)
        self._clock = clock
        self.run_id = run_id or uuid.uuid4().hex[:12]
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(self.path, "w")
        manifest = {"kind": "manifest", "run_id": self.run_id,
                    "schema_version": SCHEMA_VERSION, "t": float(clock())}
        try:  # jax identity is part of the run identity, but obs must not
            import jax  # hard-require it (the registry/sink are pure python)
            manifest["jax_version"] = jax.__version__
            manifest["backend"] = jax.default_backend()
        except Exception:
            pass
        if config:
            manifest["config"] = config
        self._write(manifest)

    def _write(self, obj: dict) -> None:
        self._f.write(json.dumps(obj) + "\n")
        self._f.flush()

    def emit(self, kind: str, **fields) -> None:
        if kind not in EVENT_KINDS:
            raise ObsSchemaError(f"unregistered event kind {kind!r}")
        self._write({"t": float(self._clock()), "kind": kind, **fields})

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_events(path: str) -> list[dict]:
    """Parse a JSONL event file (no validation — see
    :func:`validate_events`)."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError as e:
                raise ObsSchemaError(f"{path}:{i}: malformed JSON: {e}") from e
    return out


def validate_events(path_or_events) -> dict:
    """Validate a JSONL event stream against the schema.

    Checks: first event is a ``manifest`` with the current schema version;
    every event is a dict with a registered ``kind``; every non-manifest
    event carries a numeric non-negative ``t``; every required field of its
    kind is present with the required type.  Returns the manifest dict.
    Raises :class:`ObsSchemaError` naming the first offending event.
    """
    events = (read_events(path_or_events)
              if isinstance(path_or_events, (str, os.PathLike))
              else list(path_or_events))
    if not events:
        raise ObsSchemaError("empty event stream (no manifest)")
    for i, ev in enumerate(events, 1):
        where = f"event {i}"
        if not isinstance(ev, dict):
            raise ObsSchemaError(f"{where}: not an object: {ev!r}")
        kind = ev.get("kind")
        if kind not in EVENT_KINDS:
            raise ObsSchemaError(f"{where}: unregistered kind {kind!r}")
        if i == 1:
            if kind != "manifest":
                raise ObsSchemaError(
                    f"{where}: first event must be 'manifest', got {kind!r}")
            if ev.get("schema_version") != SCHEMA_VERSION:
                raise ObsSchemaError(
                    f"{where}: schema_version {ev.get('schema_version')!r} "
                    f"!= {SCHEMA_VERSION}")
        elif kind == "manifest":
            raise ObsSchemaError(f"{where}: duplicate manifest")
        else:
            t = ev.get("t")
            if not isinstance(t, _num) or isinstance(t, bool) or t < 0:
                raise ObsSchemaError(f"{where} ({kind}): bad 't': {t!r}")
        check_fields(ev, EVENT_KINDS[kind], f"{where} ({kind})")
    return events[0]


def check_fields(obj: dict, spec: dict, where: str) -> None:
    """Typed required-field check shared by :func:`validate_events` and the
    bench-history validator (:mod:`repro.obs.trajectory`): every field in
    ``spec`` must be present in ``obj`` with the required type (bools never
    satisfy numeric specs); extra fields are always allowed."""
    for fld, typ in spec.items():
        v = obj.get(fld)
        if v is None or isinstance(v, bool) and typ is not bool \
                or not isinstance(v, typ):
            raise ObsSchemaError(
                f"{where}: field {fld!r} missing or not {typ}: {v!r}")
