"""Span-based causal tracing: WHERE time goes in a request/chunk lifecycle.

PR 8's registry answers "how much" (histograms, counters); this module answers
"where in the lifecycle": every serve request and every training chunk gets a
**trace** — a tree of timestamped **spans** (admission -> queue -> microbatch
pack -> dispatch -> engine eval; supervisor chunk -> run_chunk dispatch ->
rollback/recovery) sharing one ``trace_id`` that travels with the work across
subsystem boundaries (it surfaces on ``ServeResult.trace_id`` and in the
supervisor's JSONL events).  The span buffer feeds the Chrome-trace/Perfetto
exporter (:mod:`repro.obs.trace_export`) so a run drops an openable timeline.

Design constraints, in order:

* **off-mode is free** — every integration point takes ``tracer=None`` and
  guards with one ``is None`` check; no span objects, no clock reads, no
  change to compiled programs (host-side only; asserted bitwise + trace/HLO
  parity in tests/test_tracing.py);
* **on-mode is bounded** — completed spans live in a RING buffer
  (``capacity`` spans; the newest span evicts the oldest, eviction counted)
  and head **sampling** (``sample_rate``, decided once per trace by a
  deterministic systematic sampler) lets a production server keep trace_id
  propagation on every request while recording only a fraction.  Unsampled
  traces still get real trace_ids — causality survives, recording cost
  doesn't.  Measured overhead is enforced <= 2% in
  ``benchmarks/obs_telemetry.py``;
* **one clock** — the tracer takes the same injectable clock as the registry
  (:func:`repro.obs.make_obs` wires them together), so span timestamps,
  metric timers, and event ``t`` fields share a timebase and tests stub time
  instead of sleeping.

Span lifecycle: :meth:`Tracer.start_trace` opens a root, :meth:`Span.child` /
:meth:`Tracer.span` open children (``Tracer.span`` parents to the innermost
ACTIVE span — the with-statement stack — which is how the engine's span lands
under the frontend's dispatch span without either knowing the other),
:meth:`Span.event` records an instant marker, :meth:`Span.end` completes and
commits to the ring.  :meth:`Tracer.record` commits a retrospective span from
already-measured ``(t0, t1)`` — the natural fit for stage durations the serve
path measures anyway (queue wait, microbatch dispatch).
"""
from __future__ import annotations

import time
from collections import deque


class Span:
    """One timed node of a trace tree (also the handle while open).

    ``lane`` is the timeline row the exporter puts the span on (e.g.
    ``serve``, ``train``, ``sub3``); children inherit the parent's lane
    unless overridden.  ``attrs`` is free-form (JSON-able values only —
    enforced at export, not here, to keep the hot path cheap).
    """

    __slots__ = ("tracer", "trace_id", "span_id", "parent_id", "name",
                 "lane", "t0", "t1", "attrs", "sampled", "_ended")

    def __init__(self, tracer: "Tracer", trace_id: str, span_id: int,
                 parent_id: int | None, name: str, lane: str | None,
                 t0: float, attrs: dict, sampled: bool):
        self.tracer, self.trace_id, self.span_id = tracer, trace_id, span_id
        self.parent_id, self.name, self.lane = parent_id, name, lane
        self.t0, self.t1 = t0, None
        self.attrs, self.sampled = attrs, sampled
        self._ended = False

    # ------------------------------------------------------------- tree ops
    def child(self, name: str, lane: str | None = None, **attrs) -> "Span":
        """Open a child span (inherits trace_id, sampling, and lane)."""
        return self.tracer._open(name, self, lane, attrs)

    def event(self, name: str, **attrs) -> None:
        """Instant marker: a zero-duration child committed immediately."""
        if not self.sampled:
            self.tracer.spans_dropped_sampling += 1
            return
        t = self.tracer.clock()
        ev = Span(self.tracer, self.trace_id, self.tracer._next_span_id(),
                  self.span_id, name, self.lane, t, {**attrs, "instant": True},
                  True)
        ev.t1 = t
        ev._ended = True
        self.tracer._commit(ev)

    def annotate(self, **attrs) -> None:
        self.attrs.update(attrs)

    def end(self, **attrs) -> None:
        """Complete the span (idempotent) and commit it to the ring buffer."""
        if self._ended:
            return
        self._ended = True
        if attrs:
            self.attrs.update(attrs)
        self.t1 = self.tracer.clock()
        self.tracer._commit(self)      # counts the drop when unsampled

    # ------------------------------------------------- active-span stacking
    def __enter__(self) -> "Span":
        self.tracer._stack.append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        st = self.tracer._stack
        if st and st[-1] is self:
            st.pop()
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self.end()
        return False

    def __repr__(self) -> str:  # debugging/tests
        dur = None if self.t1 is None else round(self.t1 - self.t0, 6)
        return (f"Span({self.name!r} id={self.span_id} parent={self.parent_id}"
                f" trace={self.trace_id} lane={self.lane} dur={dur})")


class Tracer:
    """Bounded, samplable span recorder with one injectable clock.

    ``sample_rate`` in [0, 1] is applied per TRACE by a deterministic
    systematic sampler (every 1/rate-th trace records; no RNG, so tests and
    repeated runs see identical decisions).  ``capacity`` bounds the
    completed-span ring; older spans are evicted first and counted in
    :meth:`stats` — a serving process can trace forever in O(capacity)
    memory.
    """

    def __init__(self, clock=time.perf_counter, sample_rate: float = 1.0,
                 capacity: int = 8192):
        assert 0.0 <= sample_rate <= 1.0 and capacity > 0
        self.clock = clock
        self.sample_rate = float(sample_rate)
        self.capacity = int(capacity)
        self._ring: deque[Span] = deque(maxlen=self.capacity)
        self._stack: list[Span] = []          # active (with-statement) spans
        self._acc = 0.0                        # systematic sampler state
        self._trace_seq = 0
        self._span_seq = 0
        self.traces_started = 0
        self.traces_sampled = 0
        self.spans_recorded = 0
        self.spans_dropped_sampling = 0        # spans of unsampled traces
        self.spans_evicted = 0                 # ring-buffer overwrites
        self.watermark = 0                     # max ring fill ever seen

    # -------------------------------------------------------------- opening
    def _next_span_id(self) -> int:
        self._span_seq += 1
        return self._span_seq

    def _sample(self) -> bool:
        self._acc += self.sample_rate
        if self._acc >= 1.0 - 1e-12:
            self._acc -= 1.0
            return True
        return False

    def start_trace(self, name: str, lane: str | None = None,
                    **attrs) -> Span:
        """Open a new root span (new trace_id; sampling decided here)."""
        self.traces_started += 1
        self._trace_seq += 1
        sampled = self._sample()
        if sampled:
            self.traces_sampled += 1
        trace_id = f"t{self._trace_seq:08x}"
        return Span(self, trace_id, self._next_span_id(), None, name, lane,
                    self.clock(), dict(attrs), sampled)

    def _open(self, name: str, parent: Span | None, lane: str | None,
              attrs: dict) -> Span:
        if parent is None:
            return self.start_trace(name, lane, **attrs)
        return Span(self, parent.trace_id, self._next_span_id(),
                    parent.span_id, name, lane or parent.lane,
                    self.clock(), dict(attrs), parent.sampled)

    def span(self, name: str, parent: Span | None = None,
             lane: str | None = None, **attrs) -> Span:
        """Open a span under ``parent``, or under the innermost ACTIVE span
        when ``parent`` is omitted (a new root if none is active).  Use as a
        context manager to make it the active span for nested calls."""
        if parent is None and self._stack:
            parent = self._stack[-1]
        return self._open(name, parent, lane, attrs)

    def record(self, name: str, t0: float, t1: float,
               parent: Span | None = None, lane: str | None = None,
               **attrs) -> Span | None:
        """Commit a retrospective span from already-measured times (tracer
        clock timebase).  Returns the span, or None if its trace (or the
        whole tracer, for parentless records) is unsampled."""
        if parent is not None:
            sampled, trace_id, parent_id = (parent.sampled, parent.trace_id,
                                            parent.span_id)
            lane = lane or parent.lane
            if not sampled:
                self.spans_dropped_sampling += 1
                return None
        else:
            root = self.start_trace(name, lane, **attrs)
            if not root.sampled:
                return None
            trace_id, parent_id = root.trace_id, None
        sp = Span(self, trace_id, (root.span_id if parent is None
                                   else self._next_span_id()),
                  parent_id, name, lane, float(t0), dict(attrs), True)
        sp.t1 = float(t1)
        sp._ended = True
        self._commit(sp)
        return sp

    # ------------------------------------------------------------ recording
    def _commit(self, span: Span) -> None:
        if not span.sampled:
            self.spans_dropped_sampling += 1
            return
        if len(self._ring) == self.capacity:
            self.spans_evicted += 1
        self._ring.append(span)
        self.spans_recorded += 1
        self.watermark = max(self.watermark, len(self._ring))

    # -------------------------------------------------------------- reading
    def spans(self, trace_id: str | None = None) -> list[Span]:
        """Completed spans currently in the ring (oldest first)."""
        out = list(self._ring)
        if trace_id is not None:
            out = [s for s in out if s.trace_id == trace_id]
        return out

    def trace_ids(self) -> list[str]:
        seen: dict[str, None] = {}
        for s in self._ring:
            seen.setdefault(s.trace_id, None)
        return list(seen)

    def tree(self, trace_id: str) -> dict | None:
        """Nested {span, children: [...]} view of one trace (roots with a
        missing parent — e.g. evicted — are grafted to the synthetic top)."""
        spans = self.spans(trace_id)
        if not spans:
            return None
        nodes = {s.span_id: {"span": s, "children": []} for s in spans}
        roots = []
        for s in spans:
            node = nodes[s.span_id]
            if s.parent_id in nodes:
                nodes[s.parent_id]["children"].append(node)
            else:
                roots.append(node)
        if len(roots) == 1:
            return roots[0]
        return {"span": None, "children": roots}

    def stats(self) -> dict:
        """Sampling + buffer accounting (serve_field publishes this in its
        heartbeat/status file)."""
        return {
            "sample_rate": self.sample_rate,
            "traces": self.traces_started,
            "traces_sampled": self.traces_sampled,
            "spans_recorded": self.spans_recorded,
            "spans_dropped_sampling": self.spans_dropped_sampling,
            "spans_evicted": self.spans_evicted,
            "buffer": len(self._ring),
            "capacity": self.capacity,
            "watermark": self.watermark,
        }

    def clear(self) -> None:
        self._ring.clear()
