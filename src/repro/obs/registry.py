"""Dependency-free metrics registry: counters, gauges, log-bucket histograms.

Every subsystem in this repo grew its own ad-hoc counters (frontend hit rate,
resilience shed/degrade tallies, supervisor trip/rollback counts) and every
latency claim so far has been a bare median.  This module is the one
substrate they all share:

* :class:`Counter` / :class:`Gauge` — monotone tallies and last-value samples;
* :class:`Histogram` — log-bucketed (geometric bucket edges), O(1) record,
  exact count/sum/min/max, percentile export from the bucket CDF.  Built for
  latencies spanning microseconds to seconds: relative bucket error is
  bounded by the growth factor (default 2**0.25 ~ 19%), independent of scale;
* :class:`MetricsRegistry` — get-or-create by name (``subsystem/metric``
  naming scheme, e.g. ``serve.frontend/queue_wait_s``), one injectable clock
  shared by everything hanging off it (timers, event logs, supervisors), and
  a :meth:`~MetricsRegistry.group` view that lets legacy ``counters`` dicts
  keep their exact shape while the values live in the registry.

No threads, no deps, no global state: a registry is just an object you pass
around (tests inject a fake clock; production passes nothing).
"""
from __future__ import annotations

import math
import time
from collections.abc import MutableMapping
from contextlib import contextmanager


class Counter:
    """Monotone-ish tally (float-valued so duration accumulators fit too)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name, self.value = name, 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self):
        v = self.value
        return int(v) if float(v).is_integer() else v


class Gauge:
    """Last-written value (queue depth, pressure, lr scale...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name, self.value = name, 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Log-bucketed histogram with percentile export.

    Bucket ``i`` covers ``[lo * growth**i, lo * growth**(i+1))``; values below
    ``lo`` land in an underflow bucket, values at or above ``hi`` in an
    overflow bucket.  ``percentile`` interpolates inside the hit bucket's
    geometric span, so the reported quantile is within one growth factor of
    the true one — the standard HDR-style tradeoff: O(1) memory per bucket,
    no sample retention.
    """

    __slots__ = ("name", "lo", "growth", "_log_g", "n_buckets", "counts",
                 "count", "sum", "min", "max")

    def __init__(self, name: str, lo: float = 1e-6, hi: float = 3600.0,
                 growth: float = 2.0 ** 0.25):
        assert lo > 0 and hi > lo and growth > 1.0
        self.name, self.lo, self.growth = name, lo, growth
        self._log_g = math.log(growth)
        self.n_buckets = int(math.ceil(math.log(hi / lo) / self._log_g))
        # [0] underflow, [1..n] log buckets, [n+1] overflow
        self.counts = [0] * (self.n_buckets + 2)
        self.count, self.sum = 0, 0.0
        self.min, self.max = math.inf, -math.inf

    def record(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            return
        self.count += 1
        self.sum += v
        self.min, self.max = min(self.min, v), max(self.max, v)
        if v < self.lo:
            self.counts[0] += 1
        else:
            i = int(math.log(v / self.lo) / self._log_g)
            self.counts[min(i, self.n_buckets) + 1] += 1

    def _edges(self, i: int) -> tuple[float, float]:
        """(low, high) value edges of physical bucket index i."""
        if i == 0:
            return 0.0, self.lo
        lo = self.lo * self.growth ** (i - 1)
        return lo, lo * self.growth

    def percentile(self, p: float) -> float | None:
        """p in [0, 100].  None on an empty histogram.  Exact at the recorded
        min/max endpoints; geometric interpolation inside the hit bucket."""
        if self.count == 0:
            return None
        if p <= 0:
            return self.min
        if p >= 100:
            return self.max
        target = p / 100.0 * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo, hi = self._edges(i)
                lo, hi = max(lo, self.min), min(hi, self.max)
                if lo <= 0 or hi <= lo:
                    return max(lo, 0.0)
                frac = (target - seen) / c
                return lo * (hi / lo) ** frac
            seen += c
        return self.max

    def snapshot(self, percentiles=(50, 90, 99)) -> dict:
        out = {"count": self.count,
               "sum": round(self.sum, 9),
               "min": None if self.count == 0 else self.min,
               "max": None if self.count == 0 else self.max,
               "mean": (self.sum / self.count) if self.count else None}
        for p in percentiles:
            v = self.percentile(p)
            out[f"p{p:g}"] = None if v is None else round(v, 9)
        return out


class CounterGroup(MutableMapping):
    """Dict-shaped view over a family of registry counters.

    The legacy subsystems keep their ``self.counters["requests"] += 1`` idiom
    and their ``stats()`` shapes; the values live in the registry under
    ``<prefix>/<key>``, so one snapshot sees every subsystem with one naming
    scheme.  New keys may be added by assignment (mirrors dict semantics);
    deleting keys is not supported (metrics don't disappear mid-run).
    """

    def __init__(self, registry: "MetricsRegistry", prefix: str, keys=()):
        self._reg, self._prefix = registry, prefix
        self._keys: list[str] = []
        for k in keys:
            self._counter(k)

    def _counter(self, key: str) -> Counter:
        if key not in self._keys:
            self._keys.append(key)
        return self._reg.counter(f"{self._prefix}/{key}")

    def __getitem__(self, key: str):
        if key not in self._keys:
            raise KeyError(key)
        return self._reg.counter(f"{self._prefix}/{key}").snapshot()

    def __setitem__(self, key: str, value) -> None:
        self._counter(key).value = float(value)

    def __delitem__(self, key: str):
        raise TypeError("metrics are append-only; cannot delete "
                        f"{self._prefix}/{key}")

    def __iter__(self):
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)


class MetricsRegistry:
    """Get-or-create metric store with one injectable clock.

    Naming scheme: ``subsystem/metric`` with dotted subsystem paths —
    ``serve.frontend/dispatches``, ``train.supervisor/guard_trips``,
    ``obs.compile/backend_compiles``.  Durations are seconds and suffixed
    ``_s``.  Re-requesting a name returns the same object; requesting it as a
    different type is an error (catches naming collisions early).
    """

    def __init__(self, clock=time.perf_counter):
        self.clock = clock
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, *args, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name, *args, **kw)
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, lo: float = 1e-6, hi: float = 3600.0,
                  growth: float = 2.0 ** 0.25) -> Histogram:
        return self._get(name, Histogram, lo, hi, growth)

    def group(self, prefix: str, keys=()) -> CounterGroup:
        return CounterGroup(self, prefix, keys)

    @contextmanager
    def timer(self, name: str):
        """Record one duration sample (registry clock) into histogram
        ``name``."""
        h = self.histogram(name)
        t0 = self.clock()
        yield h
        h.record(self.clock() - t0)

    def snapshot(self, prefix: str = "") -> dict:
        """Flat {name: value-or-histogram-dict}, optionally prefix-filtered.
        This is the JSONL ``metrics`` event payload and the heartbeat body."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())
                if name.startswith(prefix)}
