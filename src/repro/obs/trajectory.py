"""Perf-trajectory store + drift-robust regression gate.

``BENCH_*.json`` are snapshots; this module gives them a TIME AXIS.  Every
benchmark run appends one validated record to an append-only
``BENCH_history.jsonl`` (keyed on git SHA + bench id + smoke/full mode), and
:func:`detect_regressions` compares a fresh run against the trailing history
so ``benchmarks/run.py --smoke`` can FAIL the build when a headline metric
got worse — the regression gate the repo has been missing since PR 1.

The detector is **drift-robust**: container CPU-quota wobble moves *every*
metric by a common factor run-to-run, and a naive per-metric threshold either
fires on that noise or is too loose to catch real regressions.  So each
metric's ratio vs its trailing median is divided by the *median ratio across
all metrics of the run* (the common-mode drift estimate — the same
paired-ratio philosophy as ``fig4_cost_profile``, applied across the history
axis): a global 30% slow day cancels out; one benchmark doubling while its
peers hold still does not.  Gating needs ``min_runs`` prior records for a
metric (a cold history never blocks) and only metrics whose UNIT names a
direction are gated — times are lower-better, rates higher-better,
dimensionless counts are informational and skipped.

Record line::

    {"t": ..., "sha": "...", "bench": "...", "mode": "smoke"|"full",
     "rows": [{"name": ..., "value": ..., "unit": ...}, ...]}

validated with the same typed required-field machinery as the obs event
schema (:func:`repro.obs.events.check_fields`) — minus the manifest-first
rule, which an append-only multi-run file cannot satisfy.  Smoke and full
runs never share baselines (``mode`` keys the comparison): a 3-iter smoke
value is not evidence about a 10-iter full value.
"""
from __future__ import annotations

import json
import os
import subprocess
import time

from repro.obs.events import ObsSchemaError, check_fields

_num = (int, float)

RECORD_FIELDS: dict = {"t": _num, "sha": str, "bench": str, "mode": str,
                       "rows": list}
ROW_FIELDS: dict = {"name": str, "value": _num, "unit": str}

# unit -> gate direction; anything unlisted is recorded but never gated
LOWER_BETTER = {"s", "ms", "us", "ns"}
HIGHER_BETTER = {"pts/s", "it/s", "steps/s", "req/s", "x", "GB/s",
                 "GFLOP/s", "flops/s"}

DEFAULT_THRESHOLD = 1.5    # drift-adjusted ratio that trips the gate
DEFAULT_MIN_RUNS = 3       # trailing records needed before a metric gates
DEFAULT_WINDOW = 8         # trailing records the baseline median sees


def git_sha(repo: str | None = None) -> str:
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=repo, capture_output=True, text=True,
                             timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except Exception:
        return "unknown"


# ------------------------------------------------------------------- storage

def validate_record(rec, where: str = "record") -> None:
    if not isinstance(rec, dict):
        raise ObsSchemaError(f"{where}: not an object: {rec!r}")
    check_fields(rec, RECORD_FIELDS, where)
    if rec["mode"] not in ("smoke", "full"):
        raise ObsSchemaError(f"{where}: mode {rec['mode']!r} not "
                             f"smoke|full")
    for j, row in enumerate(rec["rows"]):
        if not isinstance(row, dict):
            raise ObsSchemaError(f"{where}.rows[{j}]: not an object")
        check_fields(row, ROW_FIELDS, f"{where}.rows[{j}]")


def read_history(path: str) -> list[dict]:
    """Parse + validate the history file (missing file -> empty history)."""
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError as e:
                raise ObsSchemaError(
                    f"{path}:{i}: malformed JSON: {e}") from e
            validate_record(rec, f"{path}:{i}")
            out.append(rec)
    return out


def _as_rows(rows) -> list[dict]:
    """Accept benchmark ``(name, value, unit)`` tuples or row dicts."""
    out = []
    for r in rows:
        if isinstance(r, dict):
            out.append({"name": r["name"], "value": r["value"],
                        "unit": r.get("unit", "")})
        else:
            name, value, unit = r
            out.append({"name": str(name), "value": value, "unit": str(unit)})
    # gate arithmetic needs numbers; drop string-valued rows (e.g. labels)
    return [r for r in out if isinstance(r["value"], _num)
            and not isinstance(r["value"], bool)]


def append_record(path: str, bench: str, rows, mode: str,
                  sha: str | None = None, clock=time.time,
                  **extra) -> dict:
    """Validate and append one run record; returns the record."""
    rec = {"t": float(clock()), "sha": sha or git_sha(),
           "bench": str(bench), "mode": str(mode),
           "rows": _as_rows(rows), **extra}
    validate_record(rec)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    return rec


# ------------------------------------------------------------------ detection

def _median(xs):
    xs = sorted(xs)
    n = len(xs)
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def detect_regressions(history: list[dict], rows, mode: str,
                       threshold: float = DEFAULT_THRESHOLD,
                       min_runs: int = DEFAULT_MIN_RUNS,
                       window: int = DEFAULT_WINDOW) -> dict:
    """Compare a fresh run's ``rows`` against trailing same-mode history.

    Per gateable metric: ``raw = value / trailing-median`` oriented so >1 is
    WORSE (rates inverted).  Each metric's common-mode drift estimate is the
    median of the OTHER metrics' raw ratios (leave-one-out, so a metric's
    own regression cannot launder itself into "drift"), clamped to [0.5, 2]
    (quota wobble is modest; a x3 "drift" is a real problem);
    ``adjusted = raw / drift`` trips the gate when it exceeds ``threshold``.
    Returns a report dict whose ``regressions`` list is empty on a pass::

        {"checked": N, "gated": M, "drift": d,
         "regressions": [{name, value, baseline, raw_ratio,
                          adjusted_ratio, unit, n_baseline}, ...]}
    """
    rows = _as_rows(rows)
    base: dict[str, list] = {}
    for rec in history:
        if rec["mode"] != mode:
            continue
        for row in rec["rows"]:
            base.setdefault(row["name"], []).append(row["value"])

    ratios = []
    for row in rows:
        unit, v = row["unit"], row["value"]
        if unit in LOWER_BETTER:
            worse_up = True
        elif unit in HIGHER_BETTER:
            worse_up = False
        else:
            continue
        hist = base.get(row["name"], [])[-window:]
        if len(hist) < min_runs:
            continue
        b = _median(hist)
        if b == 0 or v == 0:
            continue
        raw = (v / b) if worse_up else (b / v)
        ratios.append({"name": row["name"], "value": v, "baseline": b,
                       "unit": unit, "raw_ratio": raw,
                       "n_baseline": len(hist)})

    overall = _median([r["raw_ratio"] for r in ratios]) if ratios else 1.0
    regressions = []
    for i, r in enumerate(ratios):
        others = [x["raw_ratio"] for j, x in enumerate(ratios) if j != i]
        drift = min(2.0, max(0.5, _median(others))) if others else 1.0
        adj = r["raw_ratio"] / drift
        if adj > threshold:
            regressions.append({**r, "raw_ratio": round(r["raw_ratio"], 4),
                                "adjusted_ratio": round(adj, 4),
                                "drift": round(drift, 4),
                                "baseline": round(r["baseline"], 6)})
    return {"checked": len(rows), "gated": len(ratios),
            "drift": round(overall, 4), "regressions": regressions}


class PerfRegressionError(AssertionError):
    """The regression gate tripped; ``report`` carries the full detail."""

    def __init__(self, report: dict, bench: str):
        self.report = report
        lines = [f"perf regression gate tripped for {bench!r} "
                 f"(common-mode drift x{report['drift']}):"]
        for r in report["regressions"]:
            lines.append(
                f"  {r['name']}: {r['value']} {r['unit']} vs trailing "
                f"median {r['baseline']} — x{r['adjusted_ratio']} "
                f"drift-adjusted (raw x{r['raw_ratio']}, "
                f"n={r['n_baseline']})")
        super().__init__("\n".join(lines))


def gate(path: str, bench: str, rows, mode: str,
         threshold: float = DEFAULT_THRESHOLD,
         min_runs: int = DEFAULT_MIN_RUNS, window: int = DEFAULT_WINDOW,
         sha: str | None = None, clock=time.time) -> dict:
    """The ``run.py --smoke`` entry point: check ``rows`` against trailing
    history, RAISE :class:`PerfRegressionError` on a trip (without recording
    the bad run — a regressed record would poison its own baseline), append
    the record on a pass.  Returns the detection report with ``recorded``
    set."""
    history = read_history(path)
    report = detect_regressions(history, rows, mode, threshold=threshold,
                                min_runs=min_runs, window=window)
    report["bench"], report["mode"] = bench, mode
    if report["regressions"]:
        raise PerfRegressionError(report, bench)
    append_record(path, bench, rows, mode, sha=sha, clock=clock)
    report["recorded"] = True
    report["history_runs"] = len(history) + 1
    return report
