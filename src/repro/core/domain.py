"""Domain decomposition for cPINN/XPINN (paper §5.1, Fig 3).

The computational domain Omega is split into ``n_sub`` non-overlapping subdomains,
one per worker (paper: one MPI rank; here: one mesh device along the ``"sub"`` axis).

Two decomposition families are provided:

* :class:`CartesianDecomposition` — the paper's Fig 3 layout: an ``nx x ny`` grid of
  rectangular subdomains over a rectangle (used for Burgers space / space-time DD and
  the Navier-Stokes cavity).  The paper's rank map (eq. 7) ``(r_x, r_y) = (r//N, r%N)``
  is implemented as ``q = ix * ny + iy``.
* :class:`PolygonDecomposition` — arbitrary polygonal regions with exact shared edges
  (used for the §7.6 inverse heat-conduction problem on a 10-region irregular "map").

A :class:`Topology` is derived from the decomposition: interface edges are greedily
*edge-colored* so that every subdomain has at most one edge per color ("slot").  Each
slot then lowers to ONE ``jax.lax.ppermute`` in the distributed trainer — the TPU
analogue of the paper's non-blocking ``MPI.Isend/Irecv`` per direction, with ppermute's
zero-fill for untargeted devices reproducing ``MPI.PROC_NULL``.  For a Cartesian grid
the coloring yields <= 4 slots (the paper's S/E/N/W); for irregular maps it yields
<= max_degree + 1 slots (Vizing bound).

Interface points are sampled ONCE per undirected edge and shared verbatim by both
sides (paper: both ranks receive the same physical points), so exchanged buffers align
pointwise.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Sequence

import numpy as np


# --------------------------------------------------------------------------- edges

@dataclass(frozen=True)
class Edge:
    """An undirected interface between subdomains ``a`` and ``b`` (a < b).

    ``points``   (n_pts, dim) — shared physical interface points.
    ``normal_a`` (n_pts, dim) — unit normal pointing OUT of subdomain ``a``
                                (subdomain ``b``'s outward normal is ``-normal_a``).
    """

    a: int
    b: int
    points: np.ndarray
    normal_a: np.ndarray

    def __post_init__(self):
        assert self.a < self.b, "edges are stored with a < b"
        assert self.points.shape == self.normal_a.shape


# ----------------------------------------------------------------- decompositions

class Decomposition:
    """Base class: geometry queries used to build training point sets."""

    dim: int
    n_sub: int

    # -- geometry -------------------------------------------------------------
    def subdomain_contains(self, q: int, pts: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def sample_interior(self, q: int, n: int, rng: np.random.Generator) -> np.ndarray:
        """n i.i.d. points in the interior of subdomain q."""
        raise NotImplementedError

    def boundary_segments(self, q: int) -> list[tuple[np.ndarray, np.ndarray]]:
        """Segments (p0, p1) of the GLOBAL boundary owned by subdomain q."""
        raise NotImplementedError

    def interface_edges(self, n_iface: int) -> list[Edge]:
        """All undirected interfaces, each with ``n_iface`` shared points."""
        raise NotImplementedError

    def centroid(self, q: int) -> np.ndarray:
        raise NotImplementedError

    # -- helpers ----------------------------------------------------------------
    def sample_boundary(self, q: int, n: int, rng: np.random.Generator) -> np.ndarray:
        """~n points distributed over subdomain q's share of the global boundary.

        Returns (m, dim) with m in [0, n] (m = 0 for interior subdomains).
        """
        segs = self.boundary_segments(q)
        if not segs or n == 0:
            return np.zeros((0, self.dim))
        lens = np.array([np.linalg.norm(p1 - p0) for p0, p1 in segs])
        total = lens.sum()
        out = []
        for (p0, p1), ln in zip(segs, lens):
            k = max(1, int(round(n * ln / total)))
            t = (np.arange(k) + rng.uniform(0.2, 0.8, size=k)) / k
            out.append(p0[None, :] + t[:, None] * (p1 - p0)[None, :])
        pts = np.concatenate(out, axis=0)
        return pts[:n]


def _segment_points(p0: np.ndarray, p1: np.ndarray, n: int) -> np.ndarray:
    """n points uniformly spread over segment (p0,p1), excluding endpoints."""
    t = (np.arange(n) + 0.5) / n
    return p0[None, :] + t[:, None] * (p1 - p0)[None, :]


def _segment_normal(p0: np.ndarray, p1: np.ndarray) -> np.ndarray:
    """Unit normal of a 2-D segment, rotated -90 deg from its direction."""
    d = p1 - p0
    n = np.array([d[1], -d[0]])
    return n / (np.linalg.norm(n) + 1e-30)


class CartesianDecomposition(Decomposition):
    """nx x ny grid of rectangles over ``bounds = ((x0,x1),(y0,y1))``.

    Subdomain index: ``q = ix * ny + iy`` (paper eq. (7) with row-major rank map).
    For 1-D-in-space problems (Burgers) the second axis is time: a space-only cPINN
    decomposition uses ``ny = 1``; XPINN space-time uses ``ny > 1``.
    """

    def __init__(self, bounds: Sequence[Sequence[float]], nx: int, ny: int):
        (x0, x1), (y0, y1) = bounds
        self.bounds = ((float(x0), float(x1)), (float(y0), float(y1)))
        self.nx, self.ny = int(nx), int(ny)
        self.dim = 2
        self.n_sub = self.nx * self.ny
        self._xs = np.linspace(x0, x1, self.nx + 1)
        self._ys = np.linspace(y0, y1, self.ny + 1)

    # -- index maps (paper eq. 7) -------------------------------------------------
    def grid_index(self, q: int) -> tuple[int, int]:
        return q // self.ny, q % self.ny

    def rank(self, ix: int, iy: int) -> int:
        return ix * self.ny + iy

    def cell_bounds(self, q: int):
        ix, iy = self.grid_index(q)
        return (self._xs[ix], self._xs[ix + 1]), (self._ys[iy], self._ys[iy + 1])

    # -- Decomposition API ----------------------------------------------------------
    def subdomain_contains(self, q: int, pts: np.ndarray) -> np.ndarray:
        (xa, xb), (ya, yb) = self.cell_bounds(q)
        return (
            (pts[:, 0] >= xa) & (pts[:, 0] <= xb) & (pts[:, 1] >= ya) & (pts[:, 1] <= yb)
        )

    def sample_interior(self, q: int, n: int, rng: np.random.Generator) -> np.ndarray:
        (xa, xb), (ya, yb) = self.cell_bounds(q)
        u = rng.uniform(size=(n, 2))
        return np.stack([xa + u[:, 0] * (xb - xa), ya + u[:, 1] * (yb - ya)], axis=1)

    def centroid(self, q: int) -> np.ndarray:
        (xa, xb), (ya, yb) = self.cell_bounds(q)
        return np.array([(xa + xb) / 2, (ya + yb) / 2])

    def boundary_segments(self, q: int):
        ix, iy = self.grid_index(q)
        (xa, xb), (ya, yb) = self.cell_bounds(q)
        segs = []
        if ix == 0:
            segs.append((np.array([xa, ya]), np.array([xa, yb])))  # west wall
        if ix == self.nx - 1:
            segs.append((np.array([xb, ya]), np.array([xb, yb])))  # east wall
        if iy == 0:
            segs.append((np.array([xa, ya]), np.array([xb, ya])))  # south wall
        if iy == self.ny - 1:
            segs.append((np.array([xa, yb]), np.array([xb, yb])))  # north wall
        return segs

    def interface_edges(self, n_iface: int) -> list[Edge]:
        edges = []
        # vertical interfaces between (ix, iy) and (ix+1, iy): outward normal +x
        for ix in range(self.nx - 1):
            for iy in range(self.ny):
                x = self._xs[ix + 1]
                p0 = np.array([x, self._ys[iy]])
                p1 = np.array([x, self._ys[iy + 1]])
                pts = _segment_points(p0, p1, n_iface)
                nrm = np.tile(np.array([1.0, 0.0]), (n_iface, 1))
                edges.append(Edge(self.rank(ix, iy), self.rank(ix + 1, iy), pts, nrm))
        # horizontal interfaces between (ix, iy) and (ix, iy+1): outward normal +y
        for ix in range(self.nx):
            for iy in range(self.ny - 1):
                y = self._ys[iy + 1]
                p0 = np.array([self._xs[ix], y])
                p1 = np.array([self._xs[ix + 1], y])
                pts = _segment_points(p0, p1, n_iface)
                nrm = np.tile(np.array([0.0, 1.0]), (n_iface, 1))
                edges.append(Edge(self.rank(ix, iy), self.rank(ix, iy + 1), pts, nrm))
        return edges


class PolygonDecomposition(Decomposition):
    """Arbitrary polygonal regions with EXACT shared edges.

    ``polygons``: list of (n_vertices, 2) arrays, CCW order.  Two regions are
    neighbors iff they share one or more polygon edges (matched vertex pairs within
    tolerance); the interface polyline is the union of shared segments.  Polygon edges
    not shared by any pair form the global boundary.  Used for the paper's §7.6
    10-region irregular-map inverse problem.
    """

    def __init__(self, polygons: Sequence[np.ndarray], tol: float = 1e-9):
        self.polygons = [np.asarray(p, dtype=np.float64) for p in polygons]
        self.dim = 2
        self.n_sub = len(self.polygons)
        self.tol = tol
        self._classify_edges()

    @staticmethod
    def _poly_edges(poly: np.ndarray):
        n = len(poly)
        return [(poly[i], poly[(i + 1) % n]) for i in range(n)]

    def _edge_key(self, p0, p1):
        a = tuple(np.round(p0 / self.tol).astype(np.int64))
        b = tuple(np.round(p1 / self.tol).astype(np.int64))
        return (a, b) if a <= b else (b, a)

    def _classify_edges(self):
        owner: dict = {}
        self._shared: dict[tuple[int, int], list[tuple[np.ndarray, np.ndarray]]] = {}
        self._bnd: dict[int, list[tuple[np.ndarray, np.ndarray]]] = {q: [] for q in range(self.n_sub)}
        for q, poly in enumerate(self.polygons):
            for p0, p1 in self._poly_edges(poly):
                key = self._edge_key(p0, p1)
                if key in owner:
                    q0, e0 = owner.pop(key)
                    pair = (min(q0, q), max(q0, q))
                    # store segment oriented CCW w.r.t. the LOWER-indexed region
                    seg = e0 if q0 == pair[0] else (p0, p1)
                    self._shared.setdefault(pair, []).append(seg)
                else:
                    owner[key] = (q, (p0, p1))
        for key, (q, seg) in owner.items():
            self._bnd[q].append(seg)

    def subdomain_contains(self, q: int, pts: np.ndarray) -> np.ndarray:
        return _points_in_polygon(pts, self.polygons[q])

    def sample_interior(self, q: int, n: int, rng: np.random.Generator) -> np.ndarray:
        poly = self.polygons[q]
        lo, hi = poly.min(axis=0), poly.max(axis=0)
        out = np.zeros((0, 2))
        while len(out) < n:
            cand = rng.uniform(lo, hi, size=(max(4 * n, 64), 2))
            cand = cand[_points_in_polygon(cand, poly)]
            out = np.concatenate([out, cand], axis=0)
        return out[:n]

    def centroid(self, q: int) -> np.ndarray:
        return self.polygons[q].mean(axis=0)

    def boundary_segments(self, q: int):
        return [(np.asarray(p0), np.asarray(p1)) for p0, p1 in self._bnd[q]]

    def interface_edges(self, n_iface: int) -> list[Edge]:
        edges = []
        for (qa, qb), segs in sorted(self._shared.items()):
            lens = np.array([np.linalg.norm(p1 - p0) for p0, p1 in segs])
            total = lens.sum()
            pts_l, nrm_l = [], []
            # distribute n_iface points over the polyline proportionally to length
            alloc = np.maximum(1, np.round(n_iface * lens / total).astype(int))
            while alloc.sum() > n_iface:
                alloc[int(np.argmax(alloc))] -= 1
            while alloc.sum() < n_iface:
                alloc[int(np.argmax(lens / alloc))] += 1
            for (p0, p1), k in zip(segs, alloc):
                p0, p1 = np.asarray(p0), np.asarray(p1)
                pts_l.append(_segment_points(p0, p1, int(k)))
                nrm = _segment_normal(p0, p1)
                # orient outward from qa: segments are stored CCW w.r.t. qa, and the
                # -90 deg rotation of a CCW edge direction points out of the polygon.
                nrm_l.append(np.tile(nrm, (int(k), 1)))
            edges.append(Edge(qa, qb, np.concatenate(pts_l), np.concatenate(nrm_l)))
        return edges


def _points_in_polygon(pts: np.ndarray, poly: np.ndarray) -> np.ndarray:
    """Vectorized even-odd point-in-polygon test."""
    x, y = pts[:, 0], pts[:, 1]
    inside = np.zeros(len(pts), dtype=bool)
    n = len(poly)
    j = n - 1
    for i in range(n):
        xi, yi = poly[i]
        xj, yj = poly[j]
        cross = (yi > y) != (yj > y)
        slope = (xj - xi) * (y - yi) / (yj - yi + 1e-300) + xi
        inside ^= cross & (x < slope)
        j = i
    return inside


def us_map_decomposition(
    n_cols: int = 5, n_rows: int = 2, jitter: float = 0.22, seed: int = 0
) -> PolygonDecomposition:
    """A 10-region irregular polygonal 'map' (paper §7.6 uses the US map with 10
    regions; the exact shapefile is immaterial to the algorithm — what matters is
    irregular, partly non-convex subdomains with exactly-matching shared edges).

    Construction: an (n_cols x n_rows) lattice of jittered corner points, with each
    internal lattice edge subdivided by a jittered midpoint -> regions are irregular
    (often non-convex) octagons that tile ``[0, n_cols] x [0, n_rows]``.
    """
    rng = np.random.default_rng(seed)
    # lattice corners, jittered except on the outer boundary (keep a clean rectangle)
    corner = np.zeros((n_cols + 1, n_rows + 1, 2))
    for i in range(n_cols + 1):
        for j in range(n_rows + 1):
            p = np.array([float(i), float(j)])
            if 0 < i < n_cols:
                p[0] += rng.uniform(-jitter, jitter)
            if 0 < j < n_rows:
                p[1] += rng.uniform(-jitter, jitter)
            corner[i, j] = p

    def _mid(pa, pb, internal):
        m = (pa + pb) / 2
        if internal:  # jitter perpendicular to the edge -> non-convexity
            d = pb - pa
            nrm = np.array([d[1], -d[0]])
            nrm /= np.linalg.norm(nrm) + 1e-30
            m = m + nrm * rng.uniform(-jitter, jitter)
        return m

    # midpoints of horizontal and vertical lattice edges (shared between regions)
    hmid = {}  # edge ((i,j)-(i+1,j))
    for i in range(n_cols):
        for j in range(n_rows + 1):
            hmid[(i, j)] = _mid(corner[i, j], corner[i + 1, j], 0 < j < n_rows)
    vmid = {}  # edge ((i,j)-(i,j+1))
    for i in range(n_cols + 1):
        for j in range(n_rows):
            vmid[(i, j)] = _mid(corner[i, j], corner[i, j + 1], 0 < i < n_cols)

    polys = []
    for i in range(n_cols):
        for j in range(n_rows):
            polys.append(
                np.stack(
                    [
                        corner[i, j], hmid[(i, j)], corner[i + 1, j], vmid[(i + 1, j)],
                        corner[i + 1, j + 1], hmid[(i, j + 1)], corner[i, j + 1], vmid[(i, j)],
                    ]
                )
            )
    return PolygonDecomposition(polys)


# ------------------------------------------------------------------------ topology

@dataclass
class Topology:
    """Edge-colored communication topology (stacked, SPMD-ready numpy arrays).

    Slot semantics: in slot k every subdomain with an edge of color k exchanges its
    interface quantities with the neighbor across that edge — one ppermute per slot.
    Because colors are assigned to UNDIRECTED edges, both endpoints use the SAME slot
    for the same edge, so the received buffer aligns with the local slot-k points.
    """

    n_sub: int
    n_slots: int
    n_iface: int
    dim: int
    neighbor: np.ndarray      # (n_sub, K) int32, -1 where no edge
    edge_mask: np.ndarray     # (n_sub, K) float32
    iface_points: np.ndarray  # (n_sub, K, n_iface, dim) float
    iface_normal: np.ndarray  # (n_sub, K, n_iface, dim) outward from q
    perms: list[list[tuple[int, int]]]  # per slot: directed (src, dst) pairs

    @property
    def max_degree(self) -> int:
        return int((self.neighbor >= 0).sum(axis=1).max())


def build_topology(decomp: Decomposition, n_iface: int) -> Topology:
    """Greedy edge coloring -> slots; one ppermute per slot in the trainer."""
    edges = decomp.interface_edges(n_iface)
    used: list[set[int]] = [set() for _ in range(decomp.n_sub)]
    color_of: list[int] = []
    n_slots = 0
    for e in edges:
        c = 0
        while c in used[e.a] or c in used[e.b]:
            c += 1
        color_of.append(c)
        used[e.a].add(c)
        used[e.b].add(c)
        n_slots = max(n_slots, c + 1)
    n_slots = max(n_slots, 1)

    K, n, d = n_slots, decomp.n_sub, decomp.dim
    neighbor = np.full((n, K), -1, dtype=np.int32)
    edge_mask = np.zeros((n, K), dtype=np.float32)
    # default points: subdomain centroid (harmless filler for empty slots)
    pts = np.zeros((n, K, n_iface, d))
    for q in range(n):
        pts[q] = decomp.centroid(q)[None, None, :]
    nrm = np.zeros((n, K, n_iface, d))
    nrm[..., 0] = 1.0
    perms: list[list[tuple[int, int]]] = [[] for _ in range(K)]
    for e, c in zip(edges, color_of):
        neighbor[e.a, c], neighbor[e.b, c] = e.b, e.a
        edge_mask[e.a, c] = edge_mask[e.b, c] = 1.0
        pts[e.a, c] = pts[e.b, c] = e.points
        nrm[e.a, c] = e.normal_a
        nrm[e.b, c] = -e.normal_a
        perms[c].append((e.a, e.b))
        perms[c].append((e.b, e.a))
    return Topology(
        n_sub=n, n_slots=K, n_iface=n_iface, dim=d,
        neighbor=neighbor, edge_mask=edge_mask,
        iface_points=pts, iface_normal=nrm, perms=perms,
    )
