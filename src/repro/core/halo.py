"""Interface (halo) exchange — the paper's MPI.Isend/Irecv stage on TPU ICI.

Two implementations with identical semantics (tested equal):

* :func:`exchange_ppermute` — runs INSIDE ``shard_map`` over the ``"sub"`` mesh axis.
  One ``jax.lax.ppermute`` per topology slot (edge color).  ppermute leaves devices
  that receive nothing with ZEROS — exactly the paper's ``MPI.PROC_NULL`` + zeroed
  buffer convention; the loss layer re-masks those slots anyway.  Because the slot
  perms pair each edge bidirectionally and both endpoints store the SAME physical
  points under the same slot, the received buffer aligns pointwise with local data.

* :func:`exchange_gather` — single-process reference on STACKED arrays (leading
  ``n_sub`` axis) using neighbor-index gathers.  Used by the vmap reference trainer
  and the equivalence tests.

Both are differentiable: the transpose of ppermute is the reversed ppermute, and the
transpose of gather is scatter-add — so the *fully-coupled* gradient mode
(``couple_gradients=True``, beyond-paper) costs one reversed exchange in the backward
pass, the same O(N_iface) bytes as the forward exchange.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.domain import Topology


def exchange_ppermute(payload: jax.Array, topo: Topology, axis_name: str = "sub") -> jax.Array:
    """payload: (K, n_iface, C) local per-device slot data -> received (K, n_iface, C).

    Bracketed by the ``dd-comm-halo`` named scope (repro.obs.profiling): every
    collective-permute the chunk driver issues carries the scope in its HLO
    op_name, so profilers and the comp/comm splitter attribute it to the
    communication phase."""
    with jax.named_scope("dd-comm-halo"):
        outs = []
        for k in range(topo.n_slots):
            outs.append(
                jax.lax.ppermute(payload[k], axis_name=axis_name, perm=topo.perms[k])
            )
        return jnp.stack(outs, axis=0)


def exchange_gather(payload: jax.Array, topo: Topology) -> jax.Array:
    """payload: (n_sub, K, n_iface, C) stacked -> received, zeros where no neighbor."""
    with jax.named_scope("dd-comm-halo"):
        nbr = jnp.asarray(topo.neighbor)                # (n_sub, K)
        safe = jnp.maximum(nbr, 0)
        k_idx = jnp.arange(topo.n_slots)[None, :]       # (1, K)
        recv = payload[safe, k_idx]                     # (n_sub, K, n_iface, C)
        mask = (nbr >= 0).astype(payload.dtype)[..., None, None]
        return recv * mask


def exchange_tree_ppermute(payload: dict, topo: Topology, axis_name: str = "sub") -> dict:
    return jax.tree.map(lambda x: exchange_ppermute(x, topo, axis_name), payload)


def exchange_tree_gather(payload: dict, topo: Topology) -> dict:
    return jax.tree.map(lambda x: exchange_gather(x, topo), payload)
