"""Fused derivative-bundle evaluation for plain stacked-MLP subdomain models.

Bridge between the loss layer and the fused Pallas kernel
(:func:`repro.kernels.pinn_mlp_forward2`): evaluates (u, du/dx_j, d²u/dx_j²)
for EVERY field network of a :class:`~repro.core.nets.SubdomainModelConfig` in
one kernel pass per net, concatenating field outputs exactly like
``nets.model_apply``.  The PDE then assembles residual / flux from the bundle
via ``residual_from_derivs`` / ``flux_from_derivs`` without re-entering the
network — replacing the per-point ``jax.jvp``-under-``vmap`` closures that
round-trip every layer's activations through HBM (paper Fig 4's dominant cost).

Model-semantics folding (so the kernel stays a plain stacked MLP):

* adaptive slopes: the kernel computes phi(a_l h); ``mlp_apply`` computes
  phi(slope_scale * a_l * h) (a_l = 1 frozen when not adaptive), so we pass
  ``slope_scale * a`` (or ``slope_scale * ones``) — gradients w.r.t. the
  trainable slopes flow through the product.
* width masks: ``mlp_apply`` zeroes masked hidden units AFTER each activation;
  multiplying the ROWS of every following weight matrix by the mask is exactly
  equivalent (masked units then contribute nothing to any downstream value or
  tangent), so masks fold into the packed weight stack for free.

Activation selection is STATIC per call (the kernel is specialized on the
activation); heterogeneous per-subdomain activations therefore stay on the jvp
fallback — see ``trainer._DDCommon`` for the dispatch decision.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.nets import SubdomainModelConfig, act_name
from repro.kernels import ops


def uniform_act_name(act_codes) -> str | None:
    """The single activation name shared by ALL subdomains, or None if they
    differ (kernel dispatch requires a static activation)."""
    if act_codes is None:
        return "tanh"
    names = [act_name(c) for c in act_codes]
    return names[0] if len(set(names)) == 1 else None


def _fold_net(c, p, width_mask, dtype):
    """Fold adaptive slopes + width masks into a plain (Ws, bs, a) stack."""
    Ws, bs = list(p["W"]), list(p["b"])
    if c.adaptive:
        a = c.slope_scale * p["a"]
    else:
        a = jnp.full((c.depth,), c.slope_scale, dtype)
    if width_mask is not None:
        Ws = [Ws[0]] + [width_mask[:, None] * w for w in Ws[1:]]
    return Ws, bs, a


def model_bundle(
    cfg: SubdomainModelConfig,
    params: dict,
    x,                       # (n, dim)
    act: str,
    width_masks: dict | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
    d2_dirs: tuple | None = None,
    bwd: str = "fused",
):
    """Fused (u, du, d2u) for the full multi-net subdomain model.

    Returns u (n, F), du (dim, n, F), d2u (dim, n, F) with F = cfg.out_dim and
    d2u the diagonal second derivatives, differentiable w.r.t. params via the
    kernel's custom VJP (``bwd`` selects the hand-derived fused reverse sweep
    or the checkpointed-ref oracle — see ``ops.pinn_mlp_forward2``).
    """
    (bundle,) = model_bundle_segments(cfg, params, (x,), act, width_masks,
                                      block_n, interpret, d2_dirs, bwd)
    return bundle


def model_bundle_select(
    cfg: SubdomainModelConfig,
    params: dict,
    x,                       # (n, dim)
    act_code,                # traced integer activation code (0/1/2)
    width_masks: dict | None = None,
    d2_dirs: tuple | None = None,
):
    """Fused (u, du, d2u) with a TRACED activation code — the serving path for
    models whose subdomains declare different activations (paper Table 3).

    Same folding (adaptive slopes, width masks) and same concatenated-field
    output contract as :func:`model_bundle`, dispatching to
    ``ops.pinn_mlp_forward2_select`` so a ``vmap`` over stacked subdomain
    params + per-subdomain codes stays a single traced network entry.
    ``d2_dirs=()`` turns off the second-order tangent stream (value +
    first-order-only inference).
    """
    outs = []
    for name, c in cfg.nets.items():
        wm = None if width_masks is None else width_masks.get(name)
        Ws, bs, a = _fold_net(c, params[name], wm, x.dtype)
        outs.append(ops.pinn_mlp_forward2_select(x, Ws, bs, a, act_code,
                                                 d2_dirs=d2_dirs))
    return tuple(jnp.concatenate([o[i] for o in outs], axis=-1)
                 for i in range(3))


def model_bundle_segments(
    cfg: SubdomainModelConfig,
    params: dict,
    x_segs,                  # sequence of (n_i, dim)
    act: str,
    width_masks: dict | None = None,
    block_n: int = 256,
    interpret: bool | None = None,
    d2_dirs: tuple | None = None,
    bwd: str = "fused",
):
    """Megabatched fused bundles: ONE kernel entry per field net for ALL point
    segments of a training step (residual + interface + data points).

    Returns a tuple of per-segment (u, du, d2u) bundles with field outputs
    concatenated exactly like :func:`model_bundle`.  Because the kernel math is
    row-independent, each segment's bundle equals a separate ``model_bundle``
    call on that segment alone — this only collapses len(x_segs) network
    entries (pack + launch + custom-VJP backward each) into one per net.
    """
    per_seg = [[] for _ in x_segs]
    dtype = x_segs[0].dtype
    for name, c in cfg.nets.items():
        wm = None if width_masks is None else width_masks.get(name)
        Ws, bs, a = _fold_net(c, params[name], wm, dtype)
        bundles = ops.pinn_mlp_forward2_segments(x_segs, Ws, bs, a, act=act,
                                                 block_n=block_n,
                                                 interpret=interpret,
                                                 d2_dirs=d2_dirs, bwd=bwd)
        for segs, b in zip(per_seg, bundles):
            segs.append(b)
    return tuple(
        tuple(jnp.concatenate([b[i] for b in segs], axis=-1) for i in range(3))
        for segs in per_seg
    )
