"""Distributed cPINN/XPINN trainers — the paper's Algorithm 1 in JAX.

Three trainers share one loss assembly:

* :class:`DistributedDDTrainer` — production path.  ``shard_map`` over a 1-D
  ``("sub",)`` mesh (one device per subdomain, the paper's one-rank-per-subdomain).
  Each step: (compute) local interface payload -> (communicate) one ppermute per
  topology slot -> (loss) eq. (5)/(6) -> independent Adam updates with per-subdomain
  learning rates.  Received payloads enter the loss as constants of the current
  step (Algorithm 1: each rank differentiates only its own subdomain loss), so
  the global gradient decomposes per subdomain — no collective in the backward.

* :class:`ReferenceTrainer` — bit-identical semantics on ONE device (vmap over the
  stacked subdomain axis + neighbor gathers).  Oracle for the equivalence tests, and
  the practical path when #devices < #subdomains.

* :class:`DataParallelTrainer` — the paper's Fig 1a baseline: one network, points
  sharded across workers, gradient allreduce (+ optional int8/top-k compression with
  error feedback), lr scaled by world size (Goyal et al. [21]).

Straggler mitigation / communication avoidance: ``local_steps = k`` runs k Adam
steps per halo exchange (received payloads frozen in between) — beyond-paper, see
EXPERIMENTS.md §Perf.

Single-dispatch training (EXPERIMENTS.md §Step fusion): every trainer exposes
``run_chunk(state, batch, steps)`` — a ``lax.scan`` over outer steps compiled
into ONE jitted dispatch with ``TrainState`` buffers donated (params/opt update
in place), the halo exchange living inside the scan body.  Each loss evaluation
enters the network exactly once: ``losses.network_eval`` megabatches residual +
interface + data points, ``jax.vjp`` captures that single forward so the
exchange payload and the differentiated loss share it, and the assembled loss's
cotangents chain back through the saved VJP.

Guarded chunks (EXPERIMENTS.md §Robustness): every trainer also exposes
``run_chunk_guarded(state, batch, steps, lr_scale)`` — the same scanned
single-dispatch driver with an IN-GRAPH health guard in the scan body.  After
each outer step the body checks that the per-subdomain losses and the updated
parameters are finite; once any check trips, a ``lax.cond`` freezes the carried
state for the remaining steps (early exit without breaking the static scan
length, donation, or the one-entry-per-loss-eval contract).  The chunk returns
``(state, terms, health)`` where ``health`` records the per-subdomain ok flags
and the number of applied steps, so the supervisor (``runtime.supervisor``)
can roll back to the last good checkpoint and retry with per-subdomain
learning-rate backoff — ``lr_scale`` rides the dispatch as a plain argument,
so backoff never recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import utils
from repro.core import fused, halo, losses, nets
from repro.kernels import ops
from repro.core.domain import Decomposition, Topology
from repro.core.losses import CPINN, XPINN, LossWeights, SubBatch
from repro.core.nets import SubdomainModelConfig
from repro.core.pdes import PDE
from repro.optim import adam as adam_lib
from repro.optim.compress import CompressionConfig, compress_decompress


@dataclass(frozen=True)
class DDConfig:
    method: int = XPINN
    weights: LossWeights = field(default_factory=LossWeights)
    couple_gradients: bool = False   # beyond-paper: grads flow through the exchange
    local_steps: int = 1             # k Adam steps per halo exchange (k=1: Algorithm 1)
    adam: adam_lib.AdamConfig = field(default_factory=adam_lib.AdamConfig)
    disable_exchange: bool = False   # benchmark ablation: comm replaced by own payload
    residual_path: str = "jvp"       # "jvp" (per-point closures) | "pallas" (fused kernel)
    backward_path: str = "fused"     # "fused" (hand-derived reverse sweep) | "ref"
                                     # (checkpointed jax.vjp oracle); pallas path only
    telemetry: bool = False          # in-graph per-step metric rows (grad/param
                                     # norms, iface mismatch, lr) on the terms


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array


# ------------------------------------------------------------- in-graph health

def _sqnorm(tree) -> jax.Array:
    """Scalar sum of squares over all leaves (f32 accumulation); NaN/Inf in any
    leaf makes the result non-finite — ONE cheap reduction guards the whole
    parameter pytree."""
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
               for x in jax.tree.leaves(tree))


def _stacked_sqnorm(tree) -> jax.Array:
    """(n_sub,) per-subdomain sum of squares over stacked (n_sub, ...) leaves."""
    return sum(jnp.sum(jnp.square(x.astype(jnp.float32)),
                       axis=tuple(range(1, x.ndim)))
               for x in jax.tree.leaves(tree))


def _nan_like(shapes):
    """NaN-filled pytree matching a ``jax.eval_shape`` result — the frozen
    branch's stand-in for the loss terms it did not compute."""
    return jax.tree.map(lambda s: jnp.full(s.shape, jnp.nan, s.dtype), shapes)


def _traced_dispatch(trainer, name: str, steps, call):
    """Host-side chunk span around a public ``run_chunk*`` dispatch.

    ``trainer.tracer is None`` (the default) takes ``call()`` verbatim — no
    span object, no clock read, no blocking, and the jitted program is the
    same object either way (trace-count/HLO parity asserted in
    tests/test_tracing.py).  With a tracer attached, the span brackets the
    dispatch and ``block_until_ready`` pins its end to the device actually
    finishing (chunk granularity only: ONE block per chunk, so the <= 2%
    overhead bound of benchmarks/obs_telemetry.py holds).  The span parents
    to the caller's active span (the supervisor's chunk root) via the
    tracer's stack."""
    tr = getattr(trainer, "tracer", None)
    if tr is None:
        return call()
    with tr.span(name, lane="train", steps=steps,
                 trainer=type(trainer).__name__):
        out = call()
        jax.block_until_ready(out)
    return out


# ------------------------------------------------------- in-graph telemetry

def _telemetry_terms(terms: dict, params, grads, lr, stacked: bool) -> dict:
    """Per-step metric rows riding the scan's ``terms`` output (EXPERIMENTS.md
    §Observability).  Pure arithmetic on values the step already computed —
    two parameter-tree reductions, a few scalar ops — so the chunk stays ONE
    dispatch and the measured overhead is bounded at 2%:

    * ``grad_norm`` / ``param_norm`` — L2 norms of the (last local step's)
      loss gradient and the updated parameters, per subdomain on stacked
      trees; the early-warning signals for the divergences the guard trips on;
    * ``lr`` — the EFFECTIVE per-subdomain learning rate of this step
      (includes the supervisor's recovery ``lr_scale`` backoff);
    * ``iface_mismatch`` — RMS interface disagreement sqrt(MSE_avg + MSE_F/flux),
      the paper's Figs 6-9 coupling-quality axis, when the loss has interface
      terms (the data-parallel baseline has none).
    """
    norm = _stacked_sqnorm if stacked else _sqnorm
    t = dict(terms)
    t["grad_norm"] = jnp.sqrt(norm(grads))
    t["param_norm"] = jnp.sqrt(norm(params))
    t["lr"] = jnp.broadcast_to(jnp.asarray(lr, jnp.float32),
                               t["loss"].shape)
    if "mse_avg" in t:
        t["iface_mismatch"] = jnp.sqrt(t["mse_avg"] + t["mse_iface"])
    return t


class _DDCommon:
    """Shared setup + per-subdomain step body."""

    def __init__(
        self,
        pde: PDE,
        model_cfg: SubdomainModelConfig,
        topo: Topology,
        cfg: DDConfig,
        act_codes: Sequence[str | int] | None = None,
        lrs: float | Sequence[float] = 1e-3,
        width_fracs: dict[str, Sequence[float]] | None = None,
    ):
        self.pde, self.model_cfg, self.topo, self.cfg = pde, model_cfg, topo, cfg
        n = topo.n_sub
        self._act_codes_in = act_codes
        # optional repro.obs.Tracer: host-side chunk spans around the public
        # run_chunk* dispatches (the supervisor wires its obs tracer in here)
        self.tracer = None
        # fused-kernel residual dispatch: requires (a) a single activation
        # shared by all subdomains (the kernel is specialized statically) and
        # (b) a PDE exposing the batched derivative-bundle methods.  An
        # explicitly requested pallas path that can't be honored is an error,
        # not a silent fallback.
        self.res_path = None
        if cfg.backward_path not in ops.BWD_PATHS:
            raise ValueError(f"unknown backward_path {cfg.backward_path!r}")
        if cfg.residual_path == "pallas":
            act = (nets.uniform_model_act(model_cfg) if act_codes is None
                   else fused.uniform_act_name(act_codes))
            if act is None:
                raise ValueError(
                    "residual_path='pallas' needs one activation shared by all "
                    f"subdomains; got {act_codes}")
            if not type(pde).supports_derivs():
                raise ValueError(
                    f"residual_path='pallas': {pde.name} lacks residual_from_derivs/"
                    "flux_from_derivs")
            self.res_path = losses.ResidualPath(act=act, bwd=cfg.backward_path)
        elif cfg.residual_path != "jvp":
            raise ValueError(f"unknown residual_path {cfg.residual_path!r}")
        self.lrs = jnp.full((n,), float(lrs)) if np.isscalar(lrs) else jnp.asarray(
            np.array(lrs, np.float32)
        )
        assert self.lrs.shape == (n,)
        # per-subdomain width masks (paper: per-subdomain architecture freedom)
        self.width_masks = None
        if width_fracs is not None:
            self.width_masks = {}
            for name, fr in width_fracs.items():
                w = model_cfg.nets[name].width
                m = np.zeros((n, w), np.float32)
                for q, f in enumerate(fr):
                    m[q, : max(1, int(round(f * w)))] = 1.0
                self.width_masks[name] = jnp.asarray(m)

    def init(self, seed: int = 0) -> TrainState:
        params, self.act_codes = nets.stacked_init(
            self.model_cfg, self.topo.n_sub, jax.random.PRNGKey(seed), self._act_codes_in
        )
        opt = adam_lib.init_adam(params)
        return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))

    # ---- single-subdomain pieces (no stacked axis) -------------------------------
    def _net_eval(self, params, act_code, wmask, batch: SubBatch):
        """All network-dependent quantities in one entry (megabatched on the
        fused path): (res, normal-projected own payload, data_pred)."""
        return losses.network_eval(
            self.pde, self.model_cfg, self.cfg.method, params, act_code, wmask,
            batch, self.res_path,
        )

    def _assemble(self, batch: SubBatch, res, own, data_pred, recv):
        """Loss arithmetic on precomputed network outputs — no network entry."""
        return losses.assemble_subdomain_loss(
            self.pde, self.cfg.method, self.cfg.weights, batch, res, own,
            data_pred, recv["u"], recv["g"],
        )

    def _maybe_stop(self, recv):
        if self.cfg.couple_gradients:
            return recv
        return jax.tree.map(jax.lax.stop_gradient, recv)


class ReferenceTrainer(_DDCommon):
    """Single-device oracle: vmap over subdomains + gather exchange."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.step = jax.jit(self._step)
        self._chunk_const = jax.jit(self._run_chunk_const, static_argnums=(2,),
                                    donate_argnums=(0,))
        self._chunk_stacked = jax.jit(self._run_chunk_stacked, donate_argnums=(0,))
        self._chunk_guarded = jax.jit(self._run_chunk_guarded, static_argnums=(2,),
                                      donate_argnums=(0,))

    def _outer_body(self, carry, batch: SubBatch, lrs=None):
        """One outer step (exchange + local_steps Adam updates) on stacked
        arrays.  ONE network entry per loss evaluation: ``jax.vjp`` captures
        the megabatched forward, the exchange payload is a slice of that SAME
        forward (no separate payload entry), and the assembled loss's
        cotangents chain back through the saved VJP.  ``lrs`` overrides the
        per-subdomain learning rates (guarded chunks scale them for recovery
        backoff)."""
        lrs = self.lrs if lrs is None else lrs
        params, opt, step = carry
        wm = self.width_masks  # dict of (n_sub, w) or None (None = empty pytree: vmap ok)
        net_eval = lambda p: jax.vmap(self._net_eval)(p, self.act_codes, wm, batch)

        def assemble_all(outs, recv):
            res, own, data_pred = outs
            total, terms = jax.vmap(self._assemble)(batch, res, own, data_pred, recv)
            return jnp.sum(total), terms

        # communicate once per outer step (Algorithm 1), then k local updates;
        # the exchange payload rides on inner step 1's forward
        with jax.named_scope("dd-comp-forward"):
            outs, vjp_fn = jax.vjp(net_eval, params)
        own0 = outs[1]
        if self.cfg.disable_exchange:
            recv = self._maybe_stop(own0)
        else:
            recv = self._maybe_stop(halo.exchange_tree_gather(own0, self.topo))

        terms = None
        for i in range(self.cfg.local_steps):
            if i > 0:  # received payloads stay frozen; fresh forward on new params
                with jax.named_scope("dd-comp-forward"):
                    outs, vjp_fn = jax.vjp(net_eval, params)
            with jax.named_scope("dd-comp-update"):
                (_, terms), gouts = jax.value_and_grad(assemble_all, has_aux=True)(outs, recv)
                (grads,) = vjp_fn(gouts)
                params, opt = adam_lib.adam_update(grads, opt, params, lrs, self.cfg.adam)
        if self.cfg.telemetry:
            terms = _telemetry_terms(terms, params, grads, lrs, stacked=True)
        return (params, opt, step + 1), terms

    def _step(self, state: TrainState, batch: SubBatch) -> tuple[TrainState, dict]:
        carry, terms = self._outer_body((state.params, state.opt, state.step), batch)
        params, opt, step = carry
        return TrainState(params=params, opt=opt, step=step), terms

    def _run_chunk_const(self, state, batch, steps):
        carry, terms = jax.lax.scan(
            lambda c, _: self._outer_body(c, batch),
            (state.params, state.opt, state.step), None, length=steps)
        params, opt, step = carry
        return TrainState(params=params, opt=opt, step=step), terms

    def _run_chunk_stacked(self, state, batches):
        carry, terms = jax.lax.scan(
            self._outer_body, (state.params, state.opt, state.step), batches)
        params, opt, step = carry
        return TrainState(params=params, opt=opt, step=step), terms

    def run_chunk(self, state: TrainState, batch: SubBatch, steps: int | None = None):
        """Run a whole chunk of outer steps in ONE jitted dispatch (lax.scan).

        ``batch`` is either a normal stacked SubBatch reused every step
        (``steps`` gives the chunk length) or, with ``steps=None``, a SubBatch
        whose leaves carry an extra LEADING chunk axis (one batch per step —
        e.g. resampled collocation points).  ``state`` is DONATED: params and
        optimizer buffers alias in place, so the caller must rebind
        (``state, terms = trainer.run_chunk(state, batch, n)``) and never touch
        the old state again.  Returns (state, terms) with every term stacked
        over the chunk axis, shape (steps, n_sub).
        """
        if steps is None:
            return _traced_dispatch(self, "train.run_chunk", None,
                                    lambda: self._chunk_stacked(state, batch))
        return _traced_dispatch(self, "train.run_chunk", steps,
                                lambda: self._chunk_const(state, batch, steps))

    # ------------------------------------------------------------ guarded chunk
    def _guarded_body(self, carry, batch: SubBatch, lrs):
        """Scan body with the in-graph health guard: run one outer step only
        while every subdomain is healthy, then freeze the carry.  The live
        branch IS ``_outer_body`` — same trace, same single network entry per
        loss evaluation — so guarding never adds a dispatch."""
        inner, ok_sub, good = carry
        live = lambda c: self._outer_body(c, batch, lrs)
        nan_terms = _nan_like(jax.eval_shape(live, inner)[1])
        all_ok = jnp.all(ok_sub)
        inner, terms = jax.lax.cond(all_ok, live, lambda c: (c, nan_terms), inner)
        # health of the step just applied: finite per-subdomain loss AND finite
        # updated params (catches NaN grads/moments the loss can't see yet)
        healthy = (jnp.isfinite(terms["loss"])
                   & jnp.isfinite(_stacked_sqnorm(inner[0])))
        # after a trip the NaN terms would flag everyone — keep the trip-time
        # ok vector so the supervisor sees WHICH subdomains diverged
        ok_sub = jnp.where(all_ok, ok_sub & healthy, ok_sub)
        if self.cfg.telemetry:
            # per-step guard row: which subdomains were still ok AFTER this
            # step (added outside the cond so the frozen branch records too)
            terms = dict(terms, step_ok=ok_sub)
        return (inner, ok_sub, good + all_ok.astype(jnp.int32)), terms

    def _run_chunk_guarded(self, state, batch, steps, lr_scale):
        lrs = self.lrs * lr_scale
        carry0 = ((state.params, state.opt, state.step),
                  jnp.ones((self.topo.n_sub,), bool), jnp.zeros((), jnp.int32))
        (inner, ok_sub, good), terms = jax.lax.scan(
            lambda c, _: self._guarded_body(c, batch, lrs), carry0, None,
            length=steps)
        params, opt, step = inner
        health = {"ok": jnp.all(ok_sub), "ok_sub": ok_sub, "good_steps": good}
        return TrainState(params=params, opt=opt, step=step), terms, health

    def run_chunk_guarded(self, state: TrainState, batch: SubBatch, steps: int,
                          lr_scale=None):
        """``run_chunk`` with the in-graph health guard — still ONE jitted
        dispatch with ``state`` donated.  Returns ``(state, terms, health)``:
        ``health["ok_sub"]`` (n_sub,) marks subdomains whose loss/params went
        non-finite, ``health["good_steps"]`` counts applied outer steps (the
        carry freezes once tripped; terms rows after the trip are NaN).
        ``lr_scale`` (n_sub,) scales the per-subdomain learning rates without
        recompiling (recovery backoff)."""
        if lr_scale is None:
            lr_scale = jnp.ones_like(self.lrs)
        return _traced_dispatch(
            self, "train.run_chunk_guarded", steps,
            lambda: self._chunk_guarded(state, batch, steps,
                                        jnp.asarray(lr_scale)))


class DistributedDDTrainer(_DDCommon):
    """shard_map over the ("sub",) mesh — one device per subdomain (Algorithm 1)."""

    def __init__(self, *args, mesh: Mesh | None = None, **kw):
        super().__init__(*args, **kw)
        n = self.topo.n_sub
        if mesh is None:
            devs = jax.devices()
            assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
            mesh = Mesh(np.array(devs[:n]), ("sub",))
        assert mesh.shape["sub"] == n
        self.mesh = mesh
        self.step = self._build_step()
        self._chunk_cache: dict[int, Any] = {}

    def init(self, seed: int = 0) -> TrainState:
        state = super().init(seed)
        # per-subdomain Adam step counter so every leaf carries the stacked axis
        state.opt["count"] = jnp.zeros((self.topo.n_sub,), jnp.int32)
        return state

    def _local_outer_body(self, params, opt, act_code, lr, wmask, batch: SubBatch):
        """One outer step for ONE shard (no leading axis), inside shard_map.
        Same single-entry-per-loss-evaluation structure as the reference
        trainer, with ppermute as the exchange."""
        cfg = self.cfg
        net_eval = lambda p: self._net_eval(p, act_code, wmask, batch)

        def assemble(outs, recv):
            res, own, data_pred = outs
            return self._assemble(batch, res, own, data_pred, recv)

        with jax.named_scope("dd-comp-forward"):
            outs, vjp_fn = jax.vjp(net_eval, params)
        own0 = outs[1]
        if cfg.disable_exchange:
            recv = self._maybe_stop(own0)
        else:
            recv = self._maybe_stop(halo.exchange_tree_ppermute(own0, self.topo, "sub"))

        terms = None
        for i in range(cfg.local_steps):
            if i > 0:
                with jax.named_scope("dd-comp-forward"):
                    outs, vjp_fn = jax.vjp(net_eval, params)
            with jax.named_scope("dd-comp-update"):
                (_, terms), gouts = jax.value_and_grad(assemble, has_aux=True)(outs, recv)
                (grads,) = vjp_fn(gouts)
                params, opt = adam_lib.adam_update(grads, opt, params, lr, cfg.adam)
        if cfg.telemetry:
            terms = _telemetry_terms(terms, params, grads, lr, stacked=False)
        return params, opt, terms

    def _build_step(self):
        spec = P("sub")

        def local_step(params, opt, step, act_code, lr, wmask, batch: SubBatch):
            # leading axis is the local shard (size 1): squeeze
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            params, opt_l, terms = self._local_outer_body(
                sq(params), sq(opt), act_code[0], lr[0], sq(wmask), sq(batch))
            unsq = lambda t: jax.tree.map(lambda x: x[None], t)
            return unsq(params), unsq(opt_l), step + 1, unsq(terms)

        shmapped = utils.shard_map(
            local_step,
            mesh=self.mesh,
            in_specs=(spec, spec, P(), spec, spec, spec, spec),
            out_specs=(spec, spec, P(), spec),
            check_vma=False,
        )

        @jax.jit
        def step(state: TrainState, batch: SubBatch):
            p, o, s, terms = shmapped(
                state.params, state.opt, state.step, self.act_codes, self.lrs,
                self.width_masks, batch,
            )
            return TrainState(params=p, opt=o, step=s), terms

        return step

    def _build_chunk(self, steps: int):
        spec = P("sub")

        def local_chunk(params, opt, step, act_code, lr, wmask, batch: SubBatch):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            p, o = sq(params), sq(opt)
            ac, l, wm, b = act_code[0], lr[0], sq(wmask), sq(batch)

            def body(carry, _):
                p, o = carry
                p, o, terms = self._local_outer_body(p, o, ac, l, wm, b)
                return (p, o), terms

            (p, o), terms = jax.lax.scan(body, (p, o), None, length=steps)
            unsq = lambda t: jax.tree.map(lambda x: x[None], t)
            # term leaves are (steps,); the shard axis goes SECOND so the
            # stitched result is (steps, n_sub)
            terms = jax.tree.map(lambda x: x[:, None], terms)
            return unsq(p), unsq(o), step + steps, terms

        shmapped = utils.shard_map(
            local_chunk,
            mesh=self.mesh,
            in_specs=(spec, spec, P(), spec, spec, spec, spec),
            out_specs=(spec, spec, P(), P(None, "sub")),
            check_vma=False,
        )

        def chunk(state: TrainState, batch: SubBatch):
            p, o, s, terms = shmapped(
                state.params, state.opt, state.step, self.act_codes, self.lrs,
                self.width_masks, batch,
            )
            return TrainState(params=p, opt=o, step=s), terms

        return jax.jit(chunk, donate_argnums=(0,))

    def run_chunk(self, state: TrainState, batch: SubBatch, steps: int):
        """`steps` outer steps (exchange inside the scan body) in ONE jitted
        dispatch; ``state`` is donated — rebind it.  Returns (state, terms)
        with term leaves stacked (steps, n_sub)."""
        fn = self._chunk_cache.get(steps)
        if fn is None:
            fn = self._chunk_cache[steps] = self._build_chunk(steps)
        return _traced_dispatch(self, "train.run_chunk", steps,
                                lambda: fn(state, batch))

    # ------------------------------------------------------------ guarded chunk
    def _build_guarded_chunk(self, steps: int):
        spec = P("sub")

        def local_chunk(params, opt, step, act_code, lr, lr_scale, wmask,
                        batch: SubBatch):
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            p, o = sq(params), sq(opt)
            ac, l, wm, b = act_code[0], lr[0] * lr_scale[0], sq(wmask), sq(batch)

            def live(args):
                p, o = args
                p2, o2, t = self._local_outer_body(p, o, ac, l, wm, b)
                return (p2, o2), t

            nan_terms = _nan_like(jax.eval_shape(live, (p, o))[1])

            def body(carry, _):
                (p, o), ok, good = carry
                # collective agreement: every shard freezes the moment ANY
                # shard trips (one scalar pmin per step — the SPMD analogue of
                # the reference trainer's jnp.all over the stacked ok vector)
                all_ok = jax.lax.pmin(ok.astype(jnp.int32), "sub") > 0
                (p, o), terms = jax.lax.cond(all_ok, live,
                                             lambda a: (a, nan_terms), (p, o))
                healthy = jnp.isfinite(terms["loss"]) & jnp.isfinite(_sqnorm(p))
                ok = jnp.where(all_ok, ok & healthy, ok)
                if self.cfg.telemetry:
                    terms = dict(terms, step_ok=ok)
                return ((p, o), ok, good + all_ok.astype(jnp.int32)), terms

            carry0 = ((p, o), jnp.ones((), bool), jnp.zeros((), jnp.int32))
            ((p, o), ok, good), terms = jax.lax.scan(body, carry0, None,
                                                     length=steps)
            unsq = lambda t: jax.tree.map(lambda x: x[None], t)
            terms = jax.tree.map(lambda x: x[:, None], terms)
            # good is collectively agreed -> identical on all shards (out P())
            return unsq(p), unsq(o), step + good, ok[None], good, terms

        shmapped = utils.shard_map(
            local_chunk,
            mesh=self.mesh,
            in_specs=(spec, spec, P(), spec, spec, spec, spec, spec),
            out_specs=(spec, spec, P(), spec, P(), P(None, "sub")),
            check_vma=False,
        )

        def chunk(state: TrainState, batch: SubBatch, lr_scale):
            p, o, s, ok, good, terms = shmapped(
                state.params, state.opt, state.step, self.act_codes, self.lrs,
                lr_scale, self.width_masks, batch,
            )
            health = {"ok": jnp.all(ok), "ok_sub": ok, "good_steps": good}
            return TrainState(params=p, opt=o, step=s), terms, health

        return jax.jit(chunk, donate_argnums=(0,))

    def run_chunk_guarded(self, state: TrainState, batch: SubBatch, steps: int,
                          lr_scale=None):
        """Guarded ``run_chunk`` (see :meth:`ReferenceTrainer.run_chunk_guarded`)
        on the SPMD path: each shard checks its own loss/params, a per-step
        scalar ``pmin`` agrees the freeze collectively, and ``health["ok_sub"]``
        comes back stitched (n_sub,).  Still one jitted dispatch, state
        donated; ``lr_scale`` is sharded over "sub" like the learning rates."""
        if lr_scale is None:
            lr_scale = jnp.ones_like(self.lrs)
        fn = self._chunk_cache.get(("guarded", steps))
        if fn is None:
            fn = self._chunk_cache[("guarded", steps)] = self._build_guarded_chunk(steps)
        return _traced_dispatch(
            self, "train.run_chunk_guarded", steps,
            lambda: fn(state, batch, jnp.asarray(lr_scale)))

    def shard_batch(self, batch: SubBatch) -> SubBatch:
        sh = NamedSharding(self.mesh, P("sub"))
        return jax.tree.map(lambda x: jax.device_put(x, sh), batch)

    def shard_state(self, state: TrainState) -> TrainState:
        sh = NamedSharding(self.mesh, P("sub"))
        rep = NamedSharding(self.mesh, P())
        return TrainState(
            params=jax.tree.map(lambda x: jax.device_put(x, sh), state.params),
            opt=jax.tree.map(
                lambda x: jax.device_put(x, sh if x.ndim > 0 else rep), state.opt
            ),
            step=jax.device_put(state.step, rep),
        )


class DataParallelTrainer:
    """Paper Fig 1a: same net on every worker, sharded points, gradient allreduce."""

    def __init__(
        self,
        pde: PDE,
        model_cfg: SubdomainModelConfig,
        n_workers: int,
        weights: LossWeights = LossWeights(),
        lr: float = 1e-3,
        scale_lr: bool = True,  # Goyal et al. [21]: lr *= world size
        compression: CompressionConfig | None = None,
        mesh: Mesh | None = None,
        adam_cfg: adam_lib.AdamConfig = adam_lib.AdamConfig(),
        residual_path: str = "jvp",
        backward_path: str = "fused",
        telemetry: bool = False,
    ):
        self.pde, self.model_cfg, self.weights = pde, model_cfg, weights
        self.n = n_workers
        self.lr = lr * (n_workers if scale_lr else 1)
        self.compression = compression
        self.adam_cfg = adam_cfg
        self.telemetry = telemetry
        # activation comes from the model config (raises only on genuinely
        # unsupported configs: mixed per-net activations or an unknown name)
        self.act = nets.uniform_model_act(model_cfg)
        self.act_code = nets.act_code(self.act)
        self.res_path = None
        if backward_path not in ops.BWD_PATHS:
            raise ValueError(f"unknown backward_path {backward_path!r}")
        if residual_path == "pallas":
            if not type(pde).supports_derivs():
                raise ValueError(f"residual_path='pallas': {pde.name} lacks bundle methods")
            self.res_path = losses.ResidualPath(act=self.act, bwd=backward_path)
        elif residual_path != "jvp":
            raise ValueError(f"unknown residual_path {residual_path!r}")
        if mesh is None:
            devs = jax.devices()
            assert len(devs) >= n_workers
            mesh = Mesh(np.array(devs[:n_workers]), ("sub",))
        self.mesh = mesh
        self.step = self._build_step()
        self._chunk_cache: dict[int, Any] = {}
        self.tracer = None   # optional repro.obs.Tracer (host chunk spans)

    def init(self, seed: int = 0):
        params = nets.init_model(self.model_cfg, jax.random.PRNGKey(seed))
        opt = adam_lib.init_adam(params)
        # error-feedback buffer is PER-WORKER state (each rank accumulates the
        # error of compressing ITS OWN pre-allreduce gradient): stacked leading
        # n axis, sharded over "sub" — replicating it would silently average
        # away the feedback (regression-tested in test_parallel_equivalence).
        err = (jax.tree.map(lambda x: jnp.zeros((self.n,) + x.shape, x.dtype), params)
               if self.compression else None)
        return {"params": params, "opt": opt, "err": err, "step": jnp.zeros((), jnp.int32)}

    def _local_update(self, params, opt, err_l, batch: SubBatch, lr_scale=None):
        """One allreduce-Adam update for ONE worker (err_l: this worker's
        error-feedback slice, no leading axis).  The fused path's
        vanilla_pinn_loss is already a single [res | data] megabatch entry."""
        comp = self.compression
        lr = self.lr if lr_scale is None else self.lr * lr_scale

        def loss_fn(p):
            return losses.vanilla_pinn_loss(
                self.pde, self.model_cfg, self.weights, p, self.act_code, None,
                batch, path=self.res_path,
            )

        with jax.named_scope("dd-comp-forward"):
            (_, terms), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
        if comp is not None:
            g, err_l = compress_decompress(g, err_l, comp)
        # the paper's distributed optimizer: allreduce-mean of loss gradients
        g = jax.lax.pmean(g, "sub")
        with jax.named_scope("dd-comp-update"):
            new_params, new_opt = adam_lib.adam_update(g, opt, params, lr, self.adam_cfg)
        terms = jax.lax.pmean(terms, "sub")
        if self.telemetry:
            # post-allreduce gradient and updated (replicated) params: rows are
            # identical on every worker, matching the terms' P() out-spec
            terms = _telemetry_terms(terms, new_params, g, lr, stacked=False)
        return new_params, new_opt, err_l, terms

    def _specs(self):
        err_spec = P("sub") if self.compression else P()
        return (P(), P(), err_spec, P(), P("sub"))

    def _build_step(self):
        comp = self.compression

        def local_step(params, opt, err, step, batch: SubBatch):
            batch = jax.tree.map(lambda x: x[0], batch)
            err_l = jax.tree.map(lambda x: x[0], err) if comp is not None else err
            params, opt, err_l, terms = self._local_update(params, opt, err_l, batch)
            err_new = jax.tree.map(lambda x: x[None], err_l) if comp is not None else err
            return params, opt, err_new, step + 1, terms

        in_specs = self._specs()
        shmapped = utils.shard_map(
            local_step,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=in_specs[:4] + (P(),),
            check_vma=False,
        )

        @jax.jit
        def step(state, batch: SubBatch):
            p, o, e, s, terms = shmapped(
                state["params"], state["opt"], state["err"], state["step"], batch
            )
            return {"params": p, "opt": o, "err": e, "step": s}, terms

        return step

    def _build_chunk(self, steps: int):
        comp = self.compression

        def local_chunk(params, opt, err, step, batch: SubBatch):
            batch = jax.tree.map(lambda x: x[0], batch)
            err_l = jax.tree.map(lambda x: x[0], err) if comp is not None else err

            def body(carry, _):
                params, opt, err_l = carry
                params, opt, err_l, terms = self._local_update(params, opt, err_l, batch)
                return (params, opt, err_l), terms

            (params, opt, err_l), terms = jax.lax.scan(
                body, (params, opt, err_l), None, length=steps)
            err_new = jax.tree.map(lambda x: x[None], err_l) if comp is not None else err
            return params, opt, err_new, step + steps, terms

        in_specs = self._specs()
        shmapped = utils.shard_map(
            local_chunk,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=in_specs[:4] + (P(),),
            check_vma=False,
        )

        def chunk(state, batch: SubBatch):
            p, o, e, s, terms = shmapped(
                state["params"], state["opt"], state["err"], state["step"], batch
            )
            return {"params": p, "opt": o, "err": e, "step": s}, terms

        return jax.jit(chunk, donate_argnums=(0,))

    def run_chunk(self, state, batch: SubBatch, steps: int):
        """`steps` allreduce-Adam updates in ONE jitted dispatch (lax.scan with
        donated state); term leaves come back stacked (steps,)."""
        fn = self._chunk_cache.get(steps)
        if fn is None:
            fn = self._chunk_cache[steps] = self._build_chunk(steps)
        return _traced_dispatch(self, "train.run_chunk", steps,
                                lambda: fn(state, batch))

    # ------------------------------------------------------------ guarded chunk
    def _build_guarded_chunk(self, steps: int):
        comp = self.compression

        def local_chunk(params, opt, err, step, lr_scale, batch: SubBatch):
            batch = jax.tree.map(lambda x: x[0], batch)
            err_l = jax.tree.map(lambda x: x[0], err) if comp is not None else err

            def live(args):
                params, opt, err_l = args
                p, o, e, t = self._local_update(params, opt, err_l, batch,
                                                lr_scale)
                return (p, o, e), t

            nan_terms = _nan_like(jax.eval_shape(live, (params, opt, err_l))[1])

            def body(carry, _):
                args, ok, good = carry
                # params/loss are replicated after the allreduce, so every
                # worker computes the same verdict — no extra collective
                args, terms = jax.lax.cond(ok, live,
                                           lambda a: (a, nan_terms), args)
                healthy = jnp.isfinite(terms["loss"]) & jnp.isfinite(_sqnorm(args[0]))
                ok, good = ok & healthy, good + ok.astype(jnp.int32)
                if self.telemetry:
                    terms = dict(terms, step_ok=ok)
                return (args, ok, good), terms

            carry0 = ((params, opt, err_l), jnp.ones((), bool),
                      jnp.zeros((), jnp.int32))
            ((params, opt, err_l), ok, good), terms = jax.lax.scan(
                body, carry0, None, length=steps)
            err_new = jax.tree.map(lambda x: x[None], err_l) if comp is not None else err
            return params, opt, err_new, step + good, ok, good, terms

        in_specs = self._specs()[:4] + (P(), P("sub"))
        shmapped = utils.shard_map(
            local_chunk,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=self._specs()[:4] + (P(), P(), P()),
            check_vma=False,
        )

        def chunk(state, batch: SubBatch, lr_scale):
            p, o, e, s, ok, good, terms = shmapped(
                state["params"], state["opt"], state["err"], state["step"],
                lr_scale, batch,
            )
            health = {"ok": ok, "ok_sub": ok, "good_steps": good}
            return {"params": p, "opt": o, "err": e, "step": s}, terms, health

        return jax.jit(chunk, donate_argnums=(0,))

    def run_chunk_guarded(self, state, batch: SubBatch, steps: int,
                          lr_scale=None):
        """Guarded ``run_chunk``: in-graph non-finite loss/param detection with
        ``lax.cond`` freeze (see :meth:`ReferenceTrainer.run_chunk_guarded`).
        One network + replicated state means ``health["ok_sub"]`` is the scalar
        ``ok`` and ``lr_scale`` is a replicated scalar."""
        if lr_scale is None:
            lr_scale = jnp.ones(())
        fn = self._chunk_cache.get(("guarded", steps))
        if fn is None:
            fn = self._chunk_cache[("guarded", steps)] = self._build_guarded_chunk(steps)
        return _traced_dispatch(
            self, "train.run_chunk_guarded", steps,
            lambda: fn(state, batch, jnp.asarray(lr_scale, jnp.float32)))


# ------------------------------------------------------------------ checkpointing

def save_train_state(root: str, state: TrainState, keep: int = 3,
                     metadata: dict | None = None) -> str:
    """Checkpoint a trainer's :class:`TrainState` (atomic npz + manifest)."""
    from repro.checkpoint import ckpt

    tree = {"params": state.params, "opt": state.opt, "step": state.step}
    return ckpt.save(root, int(state.step), tree, metadata=metadata, keep=keep)


def restore_train_state(root: str, like: TrainState,
                        step: int | None = None) -> TrainState:
    """Restore a :class:`TrainState` saved by :func:`save_train_state`.

    ``like`` (e.g. a fresh ``trainer.init()``) fixes the pytree structure;
    restored leaves come back as committed device arrays so the result feeds
    straight into the donating ``run_chunk`` drivers.  Bitwise resume is
    asserted in ``tests/test_serve.py``.
    """
    from repro.checkpoint import ckpt

    tree, _ = ckpt.restore(
        root, {"params": like.params, "opt": like.opt, "step": like.step},
        step=step)
    tree = jax.tree.map(jnp.asarray, tree)
    return TrainState(params=tree["params"], opt=tree["opt"],
                      step=tree["step"])


# ----------------------------------------------------------------------- evaluation

def evaluate_l2(
    decomp: Decomposition,
    model_cfg: SubdomainModelConfig,
    params,
    act_codes,
    pde: PDE,
    n_pts: int = 2000,
    seed: int = 0,
    width_masks=None,
) -> float:
    """Relative L2 error of the stitched solution (eq. 4) against pde.exact.

    Runs on the serving engine: one fused network entry for ALL subdomains
    (``repro.serve.engine.FieldEngine`` — the same route -> evaluate -> stitch
    path production queries take), not a per-subdomain Python loop.  Engine
    compilations are cached process-wide, so the periodic in-training eval
    stays one dispatch per call.
    """
    from repro.serve.engine import FieldEngine
    from repro.serve.export import FieldBundle

    rng = np.random.default_rng(seed)
    m = n_pts // decomp.n_sub + 1
    pts = np.stack([decomp.sample_interior(q, m, rng)
                    for q in range(decomp.n_sub)])        # (n_sub, m, dim)
    ex = pde.exact(pts.reshape(-1, decomp.dim))
    if ex is None:
        raise ValueError("PDE has no exact solution")
    # pde stays OUT of the bundle: only u is consumed here, and a PDE without
    # the batched *_from_derivs methods (jvp-fallback-only) must still eval
    bundle = FieldBundle(model_cfg=model_cfg, params=params, decomp=decomp,
                         act_codes=np.asarray(act_codes, np.int32),
                         width_masks=width_masks, pde=None)
    # tol=0: the points are sampled strictly inside their subdomains (no
    # interface widening needed), and plain containment routing keeps custom
    # Decomposition subclasses working (tol > 0 is Cartesian/Polygon-only)
    pred = FieldEngine(bundle, tol=0.0).evaluate(pts.reshape(-1, decomp.dim),
                                                 order=1)["u"]
    e = (pred.reshape(ex.shape) - ex).ravel()
    r = ex.ravel()
    return float(np.linalg.norm(e) / (np.linalg.norm(r) + 1e-30))
