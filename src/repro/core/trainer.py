"""Distributed cPINN/XPINN trainers — the paper's Algorithm 1 in JAX.

Three trainers share one loss assembly:

* :class:`DistributedDDTrainer` — production path.  ``shard_map`` over a 1-D
  ``("sub",)`` mesh (one device per subdomain, the paper's one-rank-per-subdomain).
  Each step: (compute) local interface payload -> (communicate) one ppermute per
  topology slot -> (loss) eq. (5)/(6) -> independent Adam updates with per-subdomain
  learning rates.  Gradients are taken of the GLOBAL loss ``psum_q J(theta_q)`` so
  the fully-coupled mode differentiates through ppermute (its transpose is the
  reversed ppermute); with the paper-faithful ``stop_gradient`` on received halos the
  same construction degenerates to the paper's independent per-subdomain gradients.

* :class:`ReferenceTrainer` — bit-identical semantics on ONE device (vmap over the
  stacked subdomain axis + neighbor gathers).  Oracle for the equivalence tests, and
  the practical path when #devices < #subdomains.

* :class:`DataParallelTrainer` — the paper's Fig 1a baseline: one network, points
  sharded across workers, gradient allreduce (+ optional int8/top-k compression with
  error feedback), lr scaled by world size (Goyal et al. [21]).

Straggler mitigation / communication avoidance: ``local_steps = k`` runs k Adam
steps per halo exchange (received payloads frozen in between) — beyond-paper, see
EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import utils
from repro.core import fused, halo, losses, nets
from repro.core.domain import Decomposition, Topology
from repro.core.losses import CPINN, XPINN, LossWeights, SubBatch
from repro.core.nets import SubdomainModelConfig
from repro.core.pdes import PDE
from repro.optim import adam as adam_lib
from repro.optim.compress import CompressionConfig, compress_decompress


@dataclass(frozen=True)
class DDConfig:
    method: int = XPINN
    weights: LossWeights = field(default_factory=LossWeights)
    couple_gradients: bool = False   # beyond-paper: grads flow through the exchange
    local_steps: int = 1             # k Adam steps per halo exchange (k=1: Algorithm 1)
    adam: adam_lib.AdamConfig = field(default_factory=adam_lib.AdamConfig)
    disable_exchange: bool = False   # benchmark ablation: comm replaced by own payload
    residual_path: str = "jvp"       # "jvp" (per-point closures) | "pallas" (fused kernel)


@jax.tree_util.register_dataclass
@dataclass
class TrainState:
    params: Any
    opt: dict
    step: jax.Array


class _DDCommon:
    """Shared setup + per-subdomain step body."""

    def __init__(
        self,
        pde: PDE,
        model_cfg: SubdomainModelConfig,
        topo: Topology,
        cfg: DDConfig,
        act_codes: Sequence[str | int] | None = None,
        lrs: float | Sequence[float] = 1e-3,
        width_fracs: dict[str, Sequence[float]] | None = None,
    ):
        self.pde, self.model_cfg, self.topo, self.cfg = pde, model_cfg, topo, cfg
        n = topo.n_sub
        self._act_codes_in = act_codes
        # fused-kernel residual dispatch: requires (a) a single activation
        # shared by all subdomains (the kernel is specialized statically) and
        # (b) a PDE exposing the batched derivative-bundle methods.  An
        # explicitly requested pallas path that can't be honored is an error,
        # not a silent fallback.
        self.res_path = None
        if cfg.residual_path == "pallas":
            act = fused.uniform_act_name(act_codes)
            if act is None:
                raise ValueError(
                    "residual_path='pallas' needs one activation shared by all "
                    f"subdomains; got {act_codes}")
            if not type(pde).supports_derivs():
                raise ValueError(
                    f"residual_path='pallas': {pde.name} lacks residual_from_derivs/"
                    "flux_from_derivs")
            self.res_path = losses.ResidualPath(act=act)
        elif cfg.residual_path != "jvp":
            raise ValueError(f"unknown residual_path {cfg.residual_path!r}")
        self.lrs = jnp.full((n,), float(lrs)) if np.isscalar(lrs) else jnp.asarray(
            np.array(lrs, np.float32)
        )
        assert self.lrs.shape == (n,)
        # per-subdomain width masks (paper: per-subdomain architecture freedom)
        self.width_masks = None
        if width_fracs is not None:
            self.width_masks = {}
            for name, fr in width_fracs.items():
                w = model_cfg.nets[name].width
                m = np.zeros((n, w), np.float32)
                for q, f in enumerate(fr):
                    m[q, : max(1, int(round(f * w)))] = 1.0
                self.width_masks[name] = jnp.asarray(m)

    def init(self, seed: int = 0) -> TrainState:
        params, self.act_codes = nets.stacked_init(
            self.model_cfg, self.topo.n_sub, jax.random.PRNGKey(seed), self._act_codes_in
        )
        opt = adam_lib.init_adam(params)
        return TrainState(params=params, opt=opt, step=jnp.zeros((), jnp.int32))

    # ---- single-subdomain pieces (no stacked axis) -------------------------------
    def _payload(self, params, act_code, wmask, batch: SubBatch):
        p = losses.interface_payload(
            self.pde, self.model_cfg, self.cfg.method, params, act_code, wmask,
            batch.iface_pts, path=self.res_path,
        )
        return losses.payload_dot_normal(p, batch.iface_nrm, self.cfg.method)

    def _loss(self, params, act_code, wmask, batch: SubBatch, recv, own):
        return losses.subdomain_loss(
            self.pde, self.model_cfg, self.cfg.method, self.cfg.weights,
            params, act_code, wmask, batch, recv["u"], recv["g"], own=own,
            path=self.res_path,
        )

    def _maybe_stop(self, recv):
        if self.cfg.couple_gradients:
            return recv
        return jax.tree.map(jax.lax.stop_gradient, recv)

    def _wmask_q(self, q_slice):
        if self.width_masks is None:
            return None
        return {k: v[q_slice] for k, v in self.width_masks.items()}


class ReferenceTrainer(_DDCommon):
    """Single-device oracle: vmap over subdomains + gather exchange."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.step = jax.jit(self._step)

    def _step(self, state: TrainState, batch: SubBatch) -> tuple[TrainState, dict]:
        wm = self.width_masks  # dict of (n_sub, w) or None (None = empty pytree: vmap ok)
        payload_of = lambda p: jax.vmap(self._payload)(p, self.act_codes, wm, batch)

        def one_inner(carry, recv):
            params, opt = carry

            def global_loss(p):
                own = payload_of(p)
                total, terms = jax.vmap(self._loss)(p, self.act_codes, wm, batch, recv, own)
                return jnp.sum(total), terms

            (_, terms), grads = jax.value_and_grad(global_loss, has_aux=True)(params)
            new_params, new_opt = adam_lib.adam_update(grads, opt, params, self.lrs, self.cfg.adam)
            return (new_params, new_opt), terms

        # communicate once per outer step (Algorithm 1), then k local updates
        own0 = payload_of(state.params)
        if self.cfg.disable_exchange:
            recv = self._maybe_stop(own0)
        else:
            recv = self._maybe_stop(halo.exchange_tree_gather(own0, self.topo))
        carry, terms = (state.params, state.opt), None
        for _ in range(self.cfg.local_steps):
            carry, terms = one_inner(carry, recv)
        params, opt = carry
        return TrainState(params=params, opt=opt, step=state.step + 1), terms


class DistributedDDTrainer(_DDCommon):
    """shard_map over the ("sub",) mesh — one device per subdomain (Algorithm 1)."""

    def __init__(self, *args, mesh: Mesh | None = None, **kw):
        super().__init__(*args, **kw)
        n = self.topo.n_sub
        if mesh is None:
            devs = jax.devices()
            assert len(devs) >= n, f"need {n} devices, have {len(devs)}"
            mesh = Mesh(np.array(devs[:n]), ("sub",))
        assert mesh.shape["sub"] == n
        self.mesh = mesh
        self.step = self._build_step()

    def init(self, seed: int = 0) -> TrainState:
        state = super().init(seed)
        # per-subdomain Adam step counter so every leaf carries the stacked axis
        state.opt["count"] = jnp.zeros((self.topo.n_sub,), jnp.int32)
        return state

    def _build_step(self):
        spec = P("sub")
        cfg = self.cfg

        def local_step(params, opt, step, act_code, lr, wmask, batch: SubBatch):
            # leading axis is the local shard (size 1): squeeze
            sq = lambda t: jax.tree.map(lambda x: x[0], t)
            params, opt_l = sq(params), sq(opt)
            act_code, lr = act_code[0], lr[0]
            batch = sq(batch)
            wmask = sq(wmask)

            def payload_of(p):
                return self._payload(p, act_code, wmask, batch)

            own0 = payload_of(params)
            if cfg.disable_exchange:
                recv = self._maybe_stop(own0)
            else:
                recv = self._maybe_stop(halo.exchange_tree_ppermute(own0, self.topo, "sub"))

            def one_inner(carry, _):
                p, o = carry

                def global_loss(pp):
                    own = payload_of(pp)
                    total, terms = self._loss(pp, act_code, wmask, batch, recv, own)
                    return jax.lax.psum(total, "sub"), terms

                (_, terms), g = jax.value_and_grad(global_loss, has_aux=True)(p)
                p2, o2 = adam_lib.adam_update(g, o, p, lr, cfg.adam)
                return (p2, o2), terms

            (params, opt_l), terms = (params, opt_l), None
            for _ in range(cfg.local_steps):
                (params, opt_l), terms = one_inner((params, opt_l), None)

            unsq = lambda t: jax.tree.map(lambda x: x[None], t)
            return unsq(params), unsq(opt_l), step + 1, unsq(terms)

        shmapped = utils.shard_map(
            local_step,
            mesh=self.mesh,
            in_specs=(spec, spec, P(), spec, spec, spec, spec),
            out_specs=(spec, spec, P(), spec),
            check_vma=False,
        )

        @jax.jit
        def step(state: TrainState, batch: SubBatch):
            p, o, s, terms = shmapped(
                state.params, state.opt, state.step, self.act_codes, self.lrs,
                self.width_masks, batch,
            )
            return TrainState(params=p, opt=o, step=s), terms

        return step

    def shard_batch(self, batch: SubBatch) -> SubBatch:
        sh = NamedSharding(self.mesh, P("sub"))
        return jax.tree.map(lambda x: jax.device_put(x, sh), batch)

    def shard_state(self, state: TrainState) -> TrainState:
        sh = NamedSharding(self.mesh, P("sub"))
        rep = NamedSharding(self.mesh, P())
        return TrainState(
            params=jax.tree.map(lambda x: jax.device_put(x, sh), state.params),
            opt=jax.tree.map(
                lambda x: jax.device_put(x, sh if x.ndim > 0 else rep), state.opt
            ),
            step=jax.device_put(state.step, rep),
        )


class DataParallelTrainer:
    """Paper Fig 1a: same net on every worker, sharded points, gradient allreduce."""

    def __init__(
        self,
        pde: PDE,
        model_cfg: SubdomainModelConfig,
        n_workers: int,
        weights: LossWeights = LossWeights(),
        lr: float = 1e-3,
        scale_lr: bool = True,  # Goyal et al. [21]: lr *= world size
        compression: CompressionConfig | None = None,
        mesh: Mesh | None = None,
        adam_cfg: adam_lib.AdamConfig = adam_lib.AdamConfig(),
        residual_path: str = "jvp",
    ):
        self.pde, self.model_cfg, self.weights = pde, model_cfg, weights
        self.n = n_workers
        self.lr = lr * (n_workers if scale_lr else 1)
        self.compression = compression
        self.adam_cfg = adam_cfg
        self.res_path = None
        if residual_path == "pallas":
            if not type(pde).supports_derivs():
                raise ValueError(f"residual_path='pallas': {pde.name} lacks bundle methods")
            self.res_path = losses.ResidualPath(act="tanh")  # DP baseline is tanh-only
        elif residual_path != "jvp":
            raise ValueError(f"unknown residual_path {residual_path!r}")
        if mesh is None:
            devs = jax.devices()
            assert len(devs) >= n_workers
            mesh = Mesh(np.array(devs[:n_workers]), ("sub",))
        self.mesh = mesh
        self.step = self._build_step()

    def init(self, seed: int = 0):
        params = nets.init_model(self.model_cfg, jax.random.PRNGKey(seed))
        opt = adam_lib.init_adam(params)
        # error-feedback buffer is PER-WORKER state (each rank accumulates the
        # error of compressing ITS OWN pre-allreduce gradient): stacked leading
        # n axis, sharded over "sub" — replicating it would silently average
        # away the feedback (regression-tested in test_parallel_equivalence).
        err = (jax.tree.map(lambda x: jnp.zeros((self.n,) + x.shape, x.dtype), params)
               if self.compression else None)
        return {"params": params, "opt": opt, "err": err, "step": jnp.zeros((), jnp.int32)}

    def _build_step(self):
        comp = self.compression

        def local_step(params, opt, err, step, batch: SubBatch):
            batch = jax.tree.map(lambda x: x[0], batch)

            def loss_fn(p):
                return losses.vanilla_pinn_loss(
                    self.pde, self.model_cfg, self.weights, p, nets.ACT_TANH, None,
                    batch, path=self.res_path,
                )

            (_, terms), g = jax.value_and_grad(loss_fn, has_aux=True)(params)
            if comp is not None:
                err_l = jax.tree.map(lambda x: x[0], err)  # this worker's shard
                g, err_l = compress_decompress(g, err_l, comp)
                err_new = jax.tree.map(lambda x: x[None], err_l)
            else:
                err_new = err
            # the paper's distributed optimizer: allreduce-mean of loss gradients
            g = jax.lax.pmean(g, "sub")
            new_params, new_opt = adam_lib.adam_update(g, opt, params, self.lr, self.adam_cfg)
            terms = jax.lax.pmean(terms, "sub")
            return new_params, new_opt, err_new, step + 1, terms

        spec_b = P("sub")
        err_spec = P("sub") if self.compression else P()
        shmapped = utils.shard_map(
            local_step,
            mesh=self.mesh,
            in_specs=(P(), P(), err_spec, P(), spec_b),
            out_specs=(P(), P(), err_spec, P(), P()),
            check_vma=False,
        )

        @jax.jit
        def step(state, batch: SubBatch):
            p, o, e, s, terms = shmapped(
                state["params"], state["opt"], state["err"], state["step"], batch
            )
            return {"params": p, "opt": o, "err": e, "step": s}, terms

        return step


# ----------------------------------------------------------------------- evaluation

def evaluate_l2(
    decomp: Decomposition,
    model_cfg: SubdomainModelConfig,
    params,
    act_codes,
    pde: PDE,
    n_pts: int = 2000,
    seed: int = 0,
    width_masks=None,
) -> float:
    """Relative L2 error of the stitched solution (eq. 4) against pde.exact."""
    rng = np.random.default_rng(seed)
    errs, refs = [], []
    for q in range(decomp.n_sub):
        pts = decomp.sample_interior(q, n_pts // decomp.n_sub + 1, rng)
        ex = pde.exact(pts)
        if ex is None:
            raise ValueError("PDE has no exact solution")
        p_q = jax.tree.map(lambda x: x[q], params)
        wm = None if width_masks is None else {k: v[q] for k, v in width_masks.items()}
        pred = nets.model_apply(model_cfg, p_q, jnp.asarray(pts, jnp.float32),
                                act_codes[q], wm)
        errs.append(np.asarray(pred) - ex)
        refs.append(ex)
    e = np.concatenate(errs).ravel()
    r = np.concatenate(refs).ravel()
    return float(np.linalg.norm(e) / (np.linalg.norm(r) + 1e-30))
