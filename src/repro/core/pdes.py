"""PDE definitions for the paper's computational experiments (§7).

Every PDE exposes *per-point* residual and flux functions built from forward-mode AD
(``jax.jvp`` — exact derivatives, the paper's "graph-based differentiation" of §4.1);
the loss layer vmaps them over collocation points.

Implemented (one per paper experiment):

* :class:`Burgers1D`      — §7.3 / §7.5 viscous Burgers, space(-time) DD; Cole-Hopf
                            exact solution via Gauss-Hermite quadrature for validation.
* :class:`NavierStokes2D` — §7.4 steady incompressible NS (lid-driven cavity, Re=100);
                            fluxes exactly as the paper's Table 1.
* :class:`HeatConduction2D` — §7.6 inverse variable-conductivity problem; temperature
                            and conductivity are SEPARATE networks; the forcing term
                            derived from the paper's exact (T, K) is f = 4 exp(-0.1 y).

Conventions: ``u_fn : (dim,) -> (n_fields,)`` is a single-point closure over the
subdomain model.  ``residual`` returns ``(n_eq,)``; ``flux`` returns ``(n_eq, dim)``
(space-time flux — for conservation laws the temporal flux component is the state
itself, so cPINN normal-flux continuity is well defined on ANY interface orientation).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

Fn = Callable[[jax.Array], jax.Array]


def dir_deriv(u_fn: Fn, x: jax.Array, v: jax.Array) -> jax.Array:
    """First directional derivative d/de u(x + e v)."""
    return jax.jvp(u_fn, (x,), (v.astype(x.dtype),))[1]


def dir_deriv2(u_fn: Fn, x: jax.Array, v: jax.Array) -> jax.Array:
    """Second directional derivative (forward-over-forward)."""
    v = v.astype(x.dtype)
    g = lambda y: jax.jvp(u_fn, (y,), (v.astype(y.dtype),))[1]
    return jax.jvp(g, (x,), (v,))[1]


def _basis(dim: int, i: int) -> jax.Array:
    return jnp.zeros((dim,)).at[i].set(1.0)


class PDE:
    name: str = "pde"
    input_dim: int
    n_fields: int
    n_eq: int

    def residual(self, u_fn: Fn, x: jax.Array) -> jax.Array:  # (n_eq,)
        raise NotImplementedError

    def flux(self, u_fn: Fn, x: jax.Array) -> jax.Array:  # (n_eq, dim)
        raise NotImplementedError

    # ---- batched derivative-bundle interface (fused-kernel hot path) --------
    # The fused Pallas kernel (kernels/ops.pinn_mlp_forward2) evaluates
    # (u, du/dx_j, d²u/dx_j²) for a whole point block in one pass; these
    # methods assemble residual / flux from that bundle WITHOUT re-entering the
    # network.  Shapes: x (n, dim); u (n, n_fields); du, d2u (dim, n, n_fields)
    # with d2u the DIAGONAL second derivatives (all residuals below are
    # Laplacian-form — no mixed partials).  A PDE that leaves these unimplemented
    # simply falls back to the per-point jvp closures above.

    # Directions whose SECOND derivative the residual actually consumes
    # (None = all).  The bundle evaluators prune the second-order tangent
    # stream to these directions — e.g. Burgers needs u_xx but never u_tt, and
    # first-order systems (Euler) need no d2u at all; unpruned rows of the
    # returned d2u are exact zeros.
    d2_dirs: tuple[int, ...] | None = None

    def residual_from_derivs(self, x: jax.Array, u: jax.Array, du: jax.Array,
                             d2u: jax.Array) -> jax.Array:  # (n, n_eq)
        raise NotImplementedError

    def flux_from_derivs(self, x: jax.Array, u: jax.Array,
                         du: jax.Array) -> jax.Array:  # (n, n_eq, dim)
        raise NotImplementedError

    @classmethod
    def supports_derivs(cls) -> bool:
        """True when the batched bundle methods are overridden (static check
        used by the loss dispatch)."""
        return (cls.residual_from_derivs is not PDE.residual_from_derivs
                and cls.flux_from_derivs is not PDE.flux_from_derivs)

    def boundary_data(self, pts: np.ndarray):
        """(values (n, n_fields), comp_mask (n, n_fields), keep (n,)) on candidate
        global-boundary points.  comp_mask selects which components carry data."""
        raise NotImplementedError

    def exact(self, pts: np.ndarray) -> np.ndarray | None:
        return None


# ------------------------------------------------------------------ Burgers (1D+t)

@dataclass(frozen=True)
class Burgers1D(PDE):
    """u_t + u u_x = nu u_xx on x in [-1,1], t in [0,T];  coords = (x, t).

    u(x,0) = -sin(pi x); u(+-1,t) = 0 (paper eq. (10)/(12), nu = 0.01/pi).
    """

    nu: float = 0.01 / np.pi
    t_final: float = 1.0
    name: str = "burgers1d"
    input_dim: int = 2
    n_fields: int = 1
    n_eq: int = 1
    d2_dirs = (0,)  # u_xx only — no second time derivative in the residual

    def residual(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        ex, et = _basis(2, 0), _basis(2, 1)
        u = u_fn(x)
        u_x = dir_deriv(u_fn, x, ex)
        u_t = dir_deriv(u_fn, x, et)
        u_xx = dir_deriv2(u_fn, x, ex)
        return u_t + u * u_x - self.nu * u_xx

    def flux(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        # conservation form: d/dt u + d/dx (u^2/2 - nu u_x) = 0
        u = u_fn(x)
        u_x = dir_deriv(u_fn, x, _basis(2, 0))
        fx = 0.5 * u * u - self.nu * u_x
        ft = u
        return jnp.stack([fx, ft], axis=-1)  # (1, 2)

    def residual_from_derivs(self, x, u, du, d2u):
        # u (n,1); du/d2u (2,n,1): [0]=d/dx, [1]=d/dt
        return du[1] + u * du[0] - self.nu * d2u[0]  # (n, 1)

    def flux_from_derivs(self, x, u, du):
        fx = 0.5 * u * u - self.nu * du[0]
        return jnp.stack([fx, u], axis=-1)  # (n, 1, 2)

    def boundary_data(self, pts: np.ndarray):
        x, t = pts[:, 0], pts[:, 1]
        on_ic = np.isclose(t, 0.0, atol=1e-9)
        on_wall = np.isclose(np.abs(x), 1.0, atol=1e-9)
        vals = np.where(on_ic, -np.sin(np.pi * x), 0.0)[:, None]
        keep = (on_ic | on_wall).astype(np.float32)
        comp = np.ones((len(pts), 1), np.float32)
        return vals.astype(np.float32), comp, keep

    def exact(self, pts: np.ndarray) -> np.ndarray:
        """Cole-Hopf solution via Gauss-Hermite quadrature (validation oracle)."""
        he_x, he_w = np.polynomial.hermite.hermgauss(96)
        x, t = pts[:, 0], np.maximum(pts[:, 1], 1e-12)
        nu = self.nu
        eta = (2.0 * np.sqrt(nu * t))[:, None] * he_x[None, :]  # (n, q)
        y = x[:, None] - eta
        f = np.exp(-np.cos(np.pi * y) / (2 * np.pi * nu))
        num = (np.sin(np.pi * y) * f * he_w[None, :]).sum(axis=1)
        den = (f * he_w[None, :]).sum(axis=1)
        u = -num / den
        u = np.where(pts[:, 1] <= 1e-12, -np.sin(np.pi * x), u)
        return u[:, None].astype(np.float32)


# ------------------------------------------------------- steady Navier-Stokes (2D)

@dataclass(frozen=True)
class NavierStokes2D(PDE):
    """Steady incompressible NS, lid-driven cavity (paper §7.4, Re=100).

    fields = (u, v, p); equations = (x-mom, y-mom, mass); fluxes per Table 1.
    """

    re: float = 100.0
    lid_velocity: float = 1.0
    name: str = "ns2d"
    input_dim: int = 2
    n_fields: int = 3
    n_eq: int = 3

    def residual(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        ex, ey = _basis(2, 0), _basis(2, 1)
        w = u_fn(x)                     # (3,) = u, v, p
        wx = dir_deriv(u_fn, x, ex)
        wy = dir_deriv(u_fn, x, ey)
        wxx = dir_deriv2(u_fn, x, ex)
        wyy = dir_deriv2(u_fn, x, ey)
        u, v = w[0], w[1]
        inv_re = 1.0 / self.re
        r_u = u * wx[0] + v * wy[0] + wx[2] - inv_re * (wxx[0] + wyy[0])
        r_v = u * wx[1] + v * wy[1] + wy[2] - inv_re * (wxx[1] + wyy[1])
        r_m = wx[0] + wy[1]
        return jnp.stack([r_u, r_v, r_m])

    def flux(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        ex, ey = _basis(2, 0), _basis(2, 1)
        w = u_fn(x)
        wx = dir_deriv(u_fn, x, ex)
        wy = dir_deriv(u_fn, x, ey)
        u, v, p = w[0], w[1], w[2]
        inv_re = 1.0 / self.re
        fx = jnp.stack([u * u + p - inv_re * wx[0],
                        u * v - inv_re * wx[1],
                        u])
        fy = jnp.stack([u * v - inv_re * wy[0],
                        v * v + p - inv_re * wy[1],
                        v])
        return jnp.stack([fx, fy], axis=-1)  # (3, 2)

    def residual_from_derivs(self, x, u, du, d2u):
        wx, wy, wxx, wyy = du[0], du[1], d2u[0], d2u[1]  # (n, 3)
        uu, vv = u[:, 0], u[:, 1]
        inv_re = 1.0 / self.re
        r_u = uu * wx[:, 0] + vv * wy[:, 0] + wx[:, 2] - inv_re * (wxx[:, 0] + wyy[:, 0])
        r_v = uu * wx[:, 1] + vv * wy[:, 1] + wy[:, 2] - inv_re * (wxx[:, 1] + wyy[:, 1])
        r_m = wx[:, 0] + wy[:, 1]
        return jnp.stack([r_u, r_v, r_m], axis=-1)  # (n, 3)

    def flux_from_derivs(self, x, u, du):
        wx, wy = du[0], du[1]
        uu, vv, p = u[:, 0], u[:, 1], u[:, 2]
        inv_re = 1.0 / self.re
        fx = jnp.stack([uu * uu + p - inv_re * wx[:, 0],
                        uu * vv - inv_re * wx[:, 1],
                        uu], axis=-1)
        fy = jnp.stack([uu * vv - inv_re * wy[:, 0],
                        vv * vv + p - inv_re * wy[:, 1],
                        vv], axis=-1)
        return jnp.stack([fx, fy], axis=-1)  # (n, 3, 2)

    def boundary_data(self, pts: np.ndarray):
        y = pts[:, 1]
        on_lid = np.isclose(y, 1.0, atol=1e-9)
        vals = np.zeros((len(pts), 3), np.float32)
        vals[:, 0] = np.where(on_lid, self.lid_velocity, 0.0)
        comp = np.zeros((len(pts), 3), np.float32)
        comp[:, 0] = comp[:, 1] = 1.0  # velocity Dirichlet only; p unconstrained
        keep = np.ones((len(pts),), np.float32)
        return vals, comp, keep


# ------------------------------------------- inverse heat conduction (variable K)

@dataclass(frozen=True)
class HeatConduction2D(PDE):
    """d/dx(K T_x) + d/dy(K T_y) = f,   f = 4 exp(-0.1 y)  (paper §7.6).

    fields = (T, K): TWO separate networks per subdomain (paper: "conductivity ...
    represented by a separate neural network").  Inverse problem: T data inside the
    domain, K data on the global boundary; K inferred everywhere.
    """

    name: str = "heat2d_inverse"
    input_dim: int = 2
    n_fields: int = 2
    n_eq: int = 1

    def residual(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        ex, ey = _basis(2, 0), _basis(2, 1)
        w = u_fn(x)                     # (2,) = T, K
        wx = dir_deriv(u_fn, x, ex)
        wy = dir_deriv(u_fn, x, ey)
        wxx = dir_deriv2(u_fn, x, ex)
        wyy = dir_deriv2(u_fn, x, ey)
        K = w[1]
        r = wx[1] * wx[0] + K * wxx[0] + wy[1] * wy[0] + K * wyy[0] - self._forcing(x)
        return r[None]

    @staticmethod
    def _forcing(x: jax.Array) -> jax.Array:
        return 4.0 * jnp.exp(-0.1 * x[1])

    def flux(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        ex, ey = _basis(2, 0), _basis(2, 1)
        w = u_fn(x)
        wx = dir_deriv(u_fn, x, ex)
        wy = dir_deriv(u_fn, x, ey)
        K = w[1]
        return jnp.stack([K * wx[0], K * wy[0]], axis=-1)[None, :]  # (1, 2)

    def residual_from_derivs(self, x, u, du, d2u):
        wx, wy, wxx, wyy = du[0], du[1], d2u[0], d2u[1]  # (n, 2) = (T, K)
        K = u[:, 1]
        r = (wx[:, 1] * wx[:, 0] + K * wxx[:, 0]
             + wy[:, 1] * wy[:, 0] + K * wyy[:, 0]
             - 4.0 * jnp.exp(-0.1 * x[:, 1]))
        return r[:, None]  # (n, 1)

    def flux_from_derivs(self, x, u, du):
        K = u[:, 1]
        return jnp.stack([K * du[0][:, 0], K * du[1][:, 0]], axis=-1)[:, None, :]  # (n, 1, 2)

    def exact(self, pts: np.ndarray) -> np.ndarray:
        T = 20.0 * np.exp(-0.1 * pts[:, 1])
        K = 20.0 + np.exp(0.1 * pts[:, 1]) * np.sin(0.5 * pts[:, 0])
        return np.stack([T, K], axis=-1).astype(np.float32)

    def boundary_data(self, pts: np.ndarray):
        ex = self.exact(pts)
        comp = np.zeros((len(pts), 2), np.float32)
        comp[:, 0] = 1.0  # Dirichlet T on the boundary
        comp[:, 1] = 1.0  # K data available along the boundary (paper §7.6)
        keep = np.ones((len(pts),), np.float32)
        return ex, comp, keep

    def interior_data(self, pts: np.ndarray):
        """Inverse-problem observations: T known inside the domain, K unknown."""
        ex = self.exact(pts)
        comp = np.zeros((len(pts), 2), np.float32)
        comp[:, 0] = 1.0
        return ex, comp





# --------------------------------------------------- 1-D compressible Euler (Sod)

@dataclass(frozen=True)
class Euler1D(PDE):
    """1-D compressible Euler equations in conservation form (the cPINN paper's
    [16] home turf: nonlinear conservation laws with flux-continuity stitching).

    coords = (x, t); fields U = (rho, rho*u, E); space-time flux rows
    (F(U), U) so cPINN normal-flux continuity works on any interface orientation:
        F = (rho u,  rho u^2 + p,  u (E + p)),   p = (gamma-1)(E - rho u^2 / 2).

    IC: Sod shock tube (rho,u,p) = (1,0,1) for x<0.5 | (0.125,0,0.1) for x>0.5.
    """

    gamma: float = 1.4
    t_final: float = 0.2
    name: str = "euler1d"
    input_dim: int = 2
    n_fields: int = 3
    n_eq: int = 3
    d2_dirs = ()  # first-order system: the bundle's d2u is never consumed

    def _primitive(self, U):
        rho = U[0]
        u = U[1] / (rho + 1e-8)
        p = (self.gamma - 1.0) * (U[2] - 0.5 * rho * u * u)
        return rho, u, p

    def _flux_x(self, U):
        rho, u, p = self._primitive(U)
        return jnp.stack([U[1], U[1] * u + p, u * (U[2] + p)])

    def residual(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        et = _basis(2, 1)
        U_t = dir_deriv(u_fn, x, et)
        Fx = lambda y: self._flux_x(u_fn(y))
        F_x = dir_deriv(Fx, x, _basis(2, 0))
        return U_t + F_x

    def flux(self, u_fn: Fn, x: jax.Array) -> jax.Array:
        U = u_fn(x)
        return jnp.stack([self._flux_x(U), U], axis=-1)  # (3, 2)

    def residual_from_derivs(self, x, u, du, d2u):
        # chain rule F_x = (dF/dU) U_x via jvp of the pointwise flux map — no
        # network re-entry, so the bundle (which ignores d2u here) suffices.
        F_x = jax.vmap(lambda U, Ux: jax.jvp(self._flux_x, (U,), (Ux,))[1])(u, du[0])
        return du[1] + F_x  # (n, 3)

    def flux_from_derivs(self, x, u, du):
        F = jax.vmap(self._flux_x)(u)
        return jnp.stack([F, u], axis=-1)  # (n, 3, 2)

    def _sod_ic(self, x: np.ndarray) -> np.ndarray:
        left = x < 0.5
        rho = np.where(left, 1.0, 0.125)
        u = np.zeros_like(x)
        p = np.where(left, 1.0, 0.1)
        E = p / (self.gamma - 1.0) + 0.5 * rho * u * u
        return np.stack([rho, rho * u, E], axis=-1).astype(np.float32)

    def boundary_data(self, pts: np.ndarray):
        x, t = pts[:, 0], pts[:, 1]
        on_ic = np.isclose(t, 0.0, atol=1e-9)
        on_wall = np.isclose(x, 0.0, atol=1e-9) | np.isclose(x, 1.0, atol=1e-9)
        vals = self._sod_ic(x)  # walls keep their undisturbed IC state for t<=0.2
        keep = (on_ic | on_wall).astype(np.float32)
        comp = np.ones((len(pts), 3), np.float32)
        return vals, comp, keep


REGISTRY = {
    "burgers1d": Burgers1D,
    "ns2d": NavierStokes2D,
    "heat2d_inverse": HeatConduction2D,
    "euler1d": Euler1D,
}
