"""The paper's contribution: domain-decomposed PINNs (cPINN/XPINN) in JAX."""
from repro.core.domain import (
    CartesianDecomposition, PolygonDecomposition, Topology, build_topology,
    us_map_decomposition,
)
from repro.core.losses import CPINN, XPINN, LossWeights, ResidualPath, SubBatch
from repro.core.nets import MLPConfig, SubdomainModelConfig
from repro.core.pdes import Burgers1D, HeatConduction2D, NavierStokes2D
from repro.core.trainer import (
    DDConfig, DataParallelTrainer, DistributedDDTrainer, ReferenceTrainer, TrainState,
    evaluate_l2, restore_train_state, save_train_state,
)
