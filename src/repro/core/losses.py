"""cPINN / XPINN loss functions (paper eqs. (3), (5), (6)).

Algorithm 1 splits each step into a COMPUTE stage (evaluate u, residual F, and flux
f.n at the own interface points — needs no neighbor data) and a COMMUNICATE stage
(exchange those quantities), followed by the loss.  We mirror that split:

* :func:`interface_payload` — everything a subdomain SENDS (per slot): its solution
  ``u`` at the shared interface points, plus ``f . n`` (cPINN, eq. 5) or the PDE
  residual ``F`` (XPINN, eq. 6).  Message size per interface point is
  ``n_fields + n_eq`` scalars — O(N_I), independent of network size, which is the
  paper's central communication-cost argument vs. data-parallel (O(N_params)).
* :func:`subdomain_loss` — eq. (5)/(6) assembled from local evaluations plus the
  RECEIVED payload.  Receiving ``f . n_neighbor`` means the local flux term compares
  ``f_q . n + recv`` (since ``n_neighbor = -n``), matching eq. (5) exactly.

All functions below are written for ONE subdomain (no stacked leading axis); the
trainers vmap (reference) or shard_map (distributed) them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import fused, nets
from repro.core.pdes import PDE

CPINN, XPINN = 0, 1
METHODS = {"cpinn": CPINN, "xpinn": XPINN}


@dataclass(frozen=True)
class LossWeights:
    """W_u, W_F, W_I (u-avg), W_I_flux / W_I_F of eqs. (5)/(6)."""

    data: float = 20.0
    residual: float = 1.0
    u_avg: float = 20.0
    iface: float = 1.0


@dataclass(frozen=True)
class ResidualPath:
    """Static dispatch record: route residual/payload evaluation through the
    fused second-order kernel (``kernels.pinn_mlp_forward2``).

    ``act`` is the STATIC activation the kernel is specialized on — the trainer
    only constructs a ResidualPath when every subdomain shares one activation
    (and the PDE implements the derivative-bundle methods).  ``None`` anywhere a
    path is accepted means the per-point jvp fallback (the paper's §4.1
    graph-based differentiation), which stays the correctness oracle.

    ``bwd`` selects the custom-VJP backward of the fused entry: ``"fused"``
    (default) is the hand-derived single-sweep reverse kernel over saved layer
    residuals; ``"ref"`` is the PR-1 checkpointed ``jax.vjp`` through the
    reference recurrence (oracle / fallback).
    """

    act: str = "tanh"
    block_n: int = 256
    interpret: bool | None = None  # None: compiled kernel on TPU, jnp recurrence elsewhere
    bwd: str = "fused"


@jax.tree_util.register_dataclass
@dataclass
class SubBatch:
    """Training points of ONE subdomain (padded + masked so shapes are uniform)."""

    res_pts: jax.Array    # (n_res, dim)
    res_mask: jax.Array   # (n_res,)
    data_pts: jax.Array   # (n_data, dim)
    data_vals: jax.Array  # (n_data, n_fields)
    data_comp: jax.Array  # (n_data, n_fields) component selector
    data_mask: jax.Array  # (n_data,)
    iface_pts: jax.Array  # (K, n_iface, dim)
    iface_nrm: jax.Array  # (K, n_iface, dim) outward normal
    edge_mask: jax.Array  # (K,)


def _u_fn(pde: PDE, cfg, params, act_code, width_masks):
    return nets.scalar_field_fn(cfg, params, act_code, width_masks)


def residual_eval(pde: PDE, cfg, params, act_code, width_masks, pts, path):
    """(n, n_eq) PDE residuals — fused-kernel bundle when a ResidualPath is
    given, per-point jvp closures otherwise."""
    if path is not None:
        u, du, d2u = fused.model_bundle(cfg, params, pts, path.act, width_masks,
                                        path.block_n, path.interpret,
                                        d2_dirs=pde.d2_dirs, bwd=path.bwd)
        return pde.residual_from_derivs(pts, u, du, d2u)
    u_fn = _u_fn(pde, cfg, params, act_code, width_masks)
    return jax.vmap(lambda x: pde.residual(u_fn, x))(pts)


def interface_payload(
    pde: PDE, cfg, method: int, params, act_code, width_masks,
    iface_pts: jax.Array,  # (K, n_iface, dim)
    path: ResidualPath | None = None,
) -> dict[str, jax.Array]:
    """Quantities SENT to neighbors: u and (f.n | F) at own interface points."""
    K, nI, dim = iface_pts.shape
    flat = iface_pts.reshape(K * nI, dim)
    if path is not None:
        # cPINN flux needs only (u, du); the second-order chain computed here is
        # deliberate waste: forward2 is the one fused entry point with a custom
        # VJP (training differentiates the payload), and interface points are
        # O(K * n_iface) — tiny next to the residual set that needs d2u anyway.
        ub, dub, d2ub = fused.model_bundle(cfg, params, flat, path.act,
                                           width_masks, path.block_n,
                                           path.interpret, d2_dirs=pde.d2_dirs,
                                           bwd=path.bwd)
        u = ub.reshape(K, nI, pde.n_fields)
        if method == CPINN:
            g = pde.flux_from_derivs(flat, ub, dub).reshape(K, nI, pde.n_eq, dim)
        else:
            g = pde.residual_from_derivs(flat, ub, dub, d2ub).reshape(K, nI, pde.n_eq)
        return {"u": u, "g": g}
    u_fn = _u_fn(pde, cfg, params, act_code, width_masks)
    u = jax.vmap(u_fn)(flat).reshape(K, nI, pde.n_fields)
    if method == CPINN:
        fl = jax.vmap(lambda x: pde.flux(u_fn, x))(flat)  # (K*nI, n_eq, dim)
        g = fl.reshape(K, nI, pde.n_eq, dim)
    else:
        r = jax.vmap(lambda x: pde.residual(u_fn, x))(flat)  # (K*nI, n_eq)
        g = r.reshape(K, nI, pde.n_eq)
    return {"u": u, "g": g}


def payload_dot_normal(payload: dict, iface_nrm: jax.Array, method: int) -> dict:
    """Project the cPINN flux tensor onto the sender's outward normal.

    Done BEFORE sending so the wire format is (n_fields + n_eq) scalars per point
    (the paper's 'very small buffer'); XPINN payloads are already scalar residuals.
    """
    if method == CPINN:
        g = jnp.einsum("kned,knd->kne", payload["g"], iface_nrm)
        return {"u": payload["u"], "g": g}
    return payload


def network_eval(
    pde: PDE, cfg, method: int, params, act_code, width_masks,
    batch: SubBatch, path: ResidualPath | None,
) -> tuple[jax.Array, dict, jax.Array]:
    """Every network-dependent quantity of one training step, in ONE entry.

    Returns (res (n_res, n_eq), own payload {u, g} already normal-projected,
    data_pred (n_data, n_fields)).

    Fused path (``path`` given): residual, interface, and data points are
    concatenated into one megabatch with a STATIC segment layout
    ``[res | iface(K*nI) | data]`` and the network is entered once per field
    net (:func:`fused.model_bundle_segments`); residuals / fluxes / payloads
    are assembled from the sliced bundle without re-entering the network.
    jvp path (``path=None``): the per-point closure oracle, unchanged
    (paper §4.1) — three separate vmapped entries, kept as the correctness
    reference.
    """
    K, nI, dim = batch.iface_pts.shape
    iface_flat = batch.iface_pts.reshape(K * nI, dim)
    if path is not None:
        res_b, iface_b, data_b = fused.model_bundle_segments(
            cfg, params, (batch.res_pts, iface_flat, batch.data_pts), path.act,
            width_masks, path.block_n, path.interpret, d2_dirs=pde.d2_dirs,
            bwd=path.bwd)
        res = pde.residual_from_derivs(batch.res_pts, *res_b)
        ub, dub, d2ub = iface_b
        u = ub.reshape(K, nI, pde.n_fields)
        if method == CPINN:
            g = pde.flux_from_derivs(iface_flat, ub, dub).reshape(
                K, nI, pde.n_eq, dim)
        else:
            g = pde.residual_from_derivs(iface_flat, ub, dub, d2ub).reshape(
                K, nI, pde.n_eq)
        own = {"u": u, "g": g}
        data_pred = data_b[0]
    else:
        u_fn = _u_fn(pde, cfg, params, act_code, width_masks)
        res = jax.vmap(lambda x: pde.residual(u_fn, x))(batch.res_pts)
        own = interface_payload(pde, cfg, method, params, act_code, width_masks,
                                batch.iface_pts, path=None)
        data_pred = jax.vmap(u_fn)(batch.data_pts)
    return res, payload_dot_normal(own, batch.iface_nrm, method), data_pred


def assemble_subdomain_loss(
    pde: PDE, method: int, weights: LossWeights,
    batch: SubBatch,
    res: jax.Array,       # (n_res, n_eq) precomputed PDE residuals
    own: dict,            # normal-projected own payload {u, g}
    data_pred: jax.Array,  # (n_data, n_fields)
    recv_u: jax.Array, recv_g: jax.Array,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Eq. (5)/(6) arithmetic from precomputed network outputs — pure masking /
    reduction, no network entry.  The trainers differentiate this w.r.t.
    (res, own, data_pred) and chain through the single fused entry's VJP."""
    K, nI, dim = batch.iface_pts.shape

    # --- MSE_u: data / boundary mismatch ------------------------------------
    w = batch.data_comp * batch.data_mask[:, None]
    mse_data = jnp.sum(w * (data_pred - batch.data_vals) ** 2) / jnp.maximum(
        jnp.sum(w), 1.0)

    # --- MSE_F: PDE residual --------------------------------------------------
    mse_res = jnp.sum(batch.res_mask[:, None] * res**2) / jnp.maximum(
        jnp.sum(batch.res_mask) * pde.n_eq, 1.0
    )

    # --- interface terms -----------------------------------------------------
    em = batch.edge_mask[:, None, None]

    # MSE_u_avg: |u_q - {{u}}|^2 = |(u_q - u_nbr)/2|^2, summed over neighbors q+
    davg = 0.5 * (own["u"] - recv_u)
    mse_avg = jnp.sum(em * davg**2) / (nI * pde.n_fields)

    # cPINN eq.(5): |f_q.n - f_q+.n|^2 with recv = f_q+ . n_q+ = -f_q+ . n
    # XPINN eq.(6): |F_q - F_q+|^2
    diff = own["g"] + recv_g if method == CPINN else own["g"] - recv_g
    mse_iface = jnp.sum(em * diff**2) / (nI * pde.n_eq)

    total = (
        weights.data * mse_data
        + weights.residual * mse_res
        + weights.u_avg * mse_avg
        + weights.iface * mse_iface
    )
    terms = {
        "loss": total, "mse_data": mse_data, "mse_res": mse_res,
        "mse_avg": mse_avg, "mse_iface": mse_iface,
    }
    return total, terms


def subdomain_loss(
    pde: PDE, cfg, method: int, weights: LossWeights,
    params, act_code, width_masks,
    batch: SubBatch,
    recv_u: jax.Array,   # (K, n_iface, n_fields) neighbor u at shared points
    recv_g: jax.Array,   # (K, n_iface, n_eq)     neighbor f.n_nbr (cPINN) or F (XPINN)
    own: dict | None = None,  # precomputed normal-projected interface payload
    path: ResidualPath | None = None,  # fused-kernel dispatch (None: jvp oracle)
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Eq. (5) (cPINN) or eq. (6) (XPINN) for one subdomain.

    Convenience entry point (tests / external callers).  When ``own`` is
    precomputed it re-enters the network separately for data + residual
    evaluation; the trainers instead use :func:`network_eval` +
    :func:`assemble_subdomain_loss` for the single-entry hot path.
    """
    if own is None:
        res, own, data_pred = network_eval(pde, cfg, method, params, act_code,
                                           width_masks, batch, path)
    else:
        u_fn = _u_fn(pde, cfg, params, act_code, width_masks)
        data_pred = jax.vmap(u_fn)(batch.data_pts)
        res = residual_eval(pde, cfg, params, act_code, width_masks,
                            batch.res_pts, path)
    return assemble_subdomain_loss(pde, method, weights, batch, res, own,
                                   data_pred, recv_u, recv_g)


def vanilla_pinn_loss(
    pde: PDE, cfg, weights: LossWeights, params, act_code, width_masks,
    batch: SubBatch, path: ResidualPath | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Eq. (3): the single-domain PINN loss (data-parallel baseline, Fig 1a).

    Fused path: residual + data points form one ``[res | data]`` megabatch —
    a single network entry per field net, same consolidation as
    :func:`network_eval`."""
    if path is not None:
        res_b, data_b = fused.model_bundle_segments(
            cfg, params, (batch.res_pts, batch.data_pts), path.act,
            width_masks, path.block_n, path.interpret, d2_dirs=pde.d2_dirs,
            bwd=path.bwd)
        res = pde.residual_from_derivs(batch.res_pts, *res_b)
        pred = data_b[0]
    else:
        u_fn = _u_fn(pde, cfg, params, act_code, width_masks)
        pred = jax.vmap(u_fn)(batch.data_pts)
        res = jax.vmap(lambda x: pde.residual(u_fn, x))(batch.res_pts)
    w = batch.data_comp * batch.data_mask[:, None]
    mse_data = jnp.sum(w * (pred - batch.data_vals) ** 2) / jnp.maximum(jnp.sum(w), 1.0)
    mse_res = jnp.sum(batch.res_mask[:, None] * res**2) / jnp.maximum(
        jnp.sum(batch.res_mask) * pde.n_eq, 1.0
    )
    total = weights.data * mse_data + weights.residual * mse_res
    return total, {"loss": total, "mse_data": mse_data, "mse_res": mse_res}
