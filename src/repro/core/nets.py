"""Per-subdomain PINN networks (paper §3 + adaptive activations of refs [26, 27]).

The paper's key flexibility claim is that every subdomain may use a DIFFERENT network:
activation function, adaptive slope, learning rate, width.  MPI gets this for free
(each rank runs its own code); SPMD-on-TPU requires uniform shapes, so we preserve the
*semantics* with:

* a per-subdomain integer activation code selecting tanh / sin / cos (Table 3),
* trainable per-layer adaptive slopes ``a`` (phi(a * z), ref [26]) — one per subdomain,
* per-subdomain width masks (nets narrower than the padded max width simply mask
  the extra columns; exact, at a small padding-FLOP cost),
* per-subdomain learning-rate vectors (handled by ``repro.optim.adam``).

Parameters for one subdomain are a dict ``{"W": [..], "b": [..], "a": [..]}``; the
distributed trainer stacks these along a leading ``n_sub`` axis (one per device).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

ACT_TANH, ACT_SIN, ACT_COS = 0, 1, 2
_ACT_NAMES = {"tanh": ACT_TANH, "sin": ACT_SIN, "cos": ACT_COS}


def act_name(code: int | str) -> str:
    """Concrete activation code/name -> canonical name (inverse of _ACT_NAMES).
    Used by the fused-kernel dispatch, which specializes on the name statically."""
    if isinstance(code, str):
        if code not in _ACT_NAMES:
            raise ValueError(f"unknown activation {code!r}")
        return code
    return {v: k for k, v in _ACT_NAMES.items()}[int(code)]


def act_code(name: str | int) -> int:
    """Canonical name/code -> concrete activation code (inverse of act_name)."""
    return _ACT_NAMES[act_name(name)]


def activation(z: jax.Array, code: jax.Array) -> jax.Array:
    """Branchless per-subdomain activation select (code is a traced scalar)."""
    return jnp.where(code == ACT_TANH, jnp.tanh(z),
                     jnp.where(code == ACT_SIN, jnp.sin(z), jnp.cos(z)))


@dataclass(frozen=True)
class MLPConfig:
    in_dim: int
    out_dim: int
    width: int
    depth: int  # number of HIDDEN layers (paper's "L hidden layers")
    adaptive: bool = True          # trainable slope a (ref [26]); a=1 frozen otherwise
    slope_scale: float = 1.0       # paper's scaled slope n*a uses a fixed scale n
    act: str = "tanh"              # model-declared activation (per-subdomain
                                   # act_codes override it in the DD trainers)

    @property
    def layer_dims(self) -> list[tuple[int, int]]:
        dims = [self.in_dim] + [self.width] * self.depth + [self.out_dim]
        return list(zip(dims[:-1], dims[1:]))


def init_mlp(cfg: MLPConfig, rng: jax.Array, dtype=jnp.float32) -> dict:
    """Xavier/Glorot init (paper uses standard known distributions)."""
    keys = jax.random.split(rng, len(cfg.layer_dims))
    Ws, bs = [], []
    for k, (fan_in, fan_out) in zip(keys, cfg.layer_dims):
        std = float(np.sqrt(2.0 / (fan_in + fan_out)))
        Ws.append(jax.random.normal(k, (fan_in, fan_out), dtype) * std)
        bs.append(jnp.zeros((fan_out,), dtype))
    a = jnp.ones((cfg.depth,), dtype)  # one adaptive slope per hidden layer
    return {"W": Ws, "b": bs, "a": a}


def mlp_apply(
    cfg: MLPConfig,
    params: dict,
    x: jax.Array,                  # (n, in_dim)
    act_code: jax.Array | int = ACT_TANH,
    width_mask: jax.Array | None = None,  # (width,) 0/1 — per-subdomain capacity
) -> jax.Array:
    """Forward pass; last layer linear (paper §3)."""
    h = x
    n_layers = len(params["W"])
    for i, (W, b) in enumerate(zip(params["W"], params["b"])):
        h = h @ W + b
        if i < n_layers - 1:  # hidden layers only
            a = params["a"][i] if cfg.adaptive else 1.0
            h = activation(cfg.slope_scale * a * h, act_code)
            if width_mask is not None:
                h = h * width_mask
    return h


@dataclass(frozen=True)
class SubdomainModelConfig:
    """The full per-subdomain model: one net per FIELD (forward problems have a single
    field net; the §7.6 inverse problem uses two — 'u' for temperature T and 'k' for
    conductivity K, each its own network, as in the paper)."""

    nets: dict[str, MLPConfig] = field(default_factory=dict)

    @property
    def out_dim(self) -> int:
        return sum(c.out_dim for c in self.nets.values())

    @property
    def field_slices(self) -> dict[str, slice]:
        out, ofs = {}, 0
        for name, c in self.nets.items():
            out[name] = slice(ofs, ofs + c.out_dim)
            ofs += c.out_dim
        return out


def uniform_model_act(cfg: SubdomainModelConfig) -> str:
    """The single activation declared by ALL field nets of a model config.

    `model_apply` evaluates every field net with one activation code, so a
    config whose nets declare different activations is genuinely unsupported —
    that (and an unknown name) are the only error cases.
    """
    acts = {c.act for c in cfg.nets.values()}
    if len(acts) != 1:
        raise ValueError(
            f"field nets declare mixed activations {sorted(acts)}; model_apply "
            "evaluates all nets with one activation code")
    (act,) = acts
    if act not in _ACT_NAMES:
        raise ValueError(f"unknown activation {act!r}")
    return act


def init_model(cfg: SubdomainModelConfig, rng: jax.Array) -> dict:
    keys = jax.random.split(rng, len(cfg.nets))
    return {name: init_mlp(c, k) for (name, c), k in zip(cfg.nets.items(), keys)}


def model_apply(
    cfg: SubdomainModelConfig,
    params: dict,
    x: jax.Array,
    act_code: jax.Array | int = ACT_TANH,
    width_masks: dict[str, jax.Array] | None = None,
) -> jax.Array:
    """Concatenated field outputs, (n, sum(out_dim))."""
    outs = []
    for name, c in cfg.nets.items():
        wm = None if width_masks is None else width_masks.get(name)
        outs.append(mlp_apply(c, params[name], x, act_code, wm))
    return jnp.concatenate(outs, axis=-1)


def stacked_init(
    cfg: SubdomainModelConfig, n_sub: int, rng: jax.Array,
    act_codes: Sequence[str | int] | None = None,
) -> tuple[dict, jax.Array]:
    """Independent init per subdomain, stacked on a leading axis, plus the
    per-subdomain activation-code vector (paper Table 3 heterogeneity)."""
    keys = jax.random.split(rng, n_sub)
    params = jax.vmap(lambda k: init_model(cfg, k))(keys)
    if act_codes is None:
        codes = np.full((n_sub,), _ACT_NAMES[uniform_model_act(cfg)], np.int32)
    else:
        codes = np.array(
            [_ACT_NAMES[c] if isinstance(c, str) else int(c) for c in act_codes],
            np.int32,
        )
        assert len(codes) == n_sub
    return params, jnp.asarray(codes)


def scalar_field_fn(cfg, params, act_code, width_masks=None):
    """Closure x -> (out_dim,) for a SINGLE point — the form PDE residuals
    differentiate (jvp/grad are taken per-point and vmapped)."""

    def fn(x1: jax.Array) -> jax.Array:
        return model_apply(cfg, params, x1[None, :], act_code, width_masks)[0]

    return fn
