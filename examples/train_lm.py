"""LM training driver example: train a ~100M-param llama-family model with the
full substrate (synthetic pipeline, AdamW + clip + warmup-cosine, checkpointing).

On this CPU container the default runs a reduced model for a quick demo; pass
``--preset 100m --steps 300`` for the full exercise (slow on CPU, the intended
target is the TPU mesh via launch/train.py).

    PYTHONPATH=src python examples/train_lm.py [--steps 30]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_mod  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--preset", default=None, choices=[None, "100m"])
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_example")
    args = ap.parse_args()

    sys.argv = ["train", "lm", "--arch", args.arch, "--reduced",
                "--steps", str(args.steps), "--batch", "4", "--seq", "256",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10",
                "--log-every", "5"]
    if args.preset:
        sys.argv += ["--preset", args.preset]
    train_mod.main()


if __name__ == "__main__":
    main()
