"""Quickstart: solve viscous Burgers with a space-time XPINN (paper §7.5).

The end-to-end driver for the paper's workload: decompose (-1,1) x (0,1) into
2x2 space-time subdomains, one network each, train a few hundred steps, and
validate against the Cole-Hopf exact solution.

    PYTHONPATH=src python examples/quickstart.py [--steps 1500]

With ``--supervised`` the run goes through the fault-tolerant chunk supervisor
(EXPERIMENTS.md §Robustness): guarded chunks, crash/NaN recovery, and ELASTIC
``--resume`` — a checkpoint taken at one ``--nx/--nt`` restarts at another via
nearest-centroid parameter adoption.  ``--inject`` drives the fault matrix:

    PYTHONPATH=src python examples/quickstart.py --supervised \\
        --inject 'crash@1,nan_params@3:0'
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.core import (  # noqa: E402
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology, evaluate_l2, restore_train_state, save_train_state,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig  # noqa: E402
from repro.data import make_batch  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--nx", type=int, default=2)
    ap.add_argument("--nt", type=int, default=2)
    ap.add_argument("--path", choices=("jvp", "pallas"), default="pallas",
                    help="residual evaluation: fused kernel (default) or the "
                         "per-point jvp oracle")
    ap.add_argument("--chunk", type=int, default=250,
                    help="outer steps per device dispatch (lax.scan driver); "
                         "1 falls back to the per-step jit loop")
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint the TrainState every N steps (0 = off)")
    ap.add_argument("--ckpt", default="ckpt_quickstart",
                    help="checkpoint directory for --save-every")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from the latest checkpoint under DIR")
    ap.add_argument("--supervised", action="store_true",
                    help="route training through the fault-tolerant chunk "
                         "supervisor: checkpoints to --ckpt, recovers crashes "
                         "and NaN divergence, and makes --resume ELASTIC (the "
                         "checkpoint may have been taken at a different "
                         "--nx/--nt)")
    ap.add_argument("--inject", default=None, metavar="SPEC",
                    help="fault schedule for --supervised: comma-separated "
                         "kind@chunk[:subdomain][*delay] items, e.g. "
                         "'crash@1,nan_params@2:0,straggler@3*0.5'")
    args = ap.parse_args()
    if args.inject and not args.supervised:
        ap.error("--inject requires --supervised")

    pde = Burgers1D()
    decomp = CartesianDecomposition(((-1, 1), (0, 1)), args.nx, args.nt)
    topo = build_topology(decomp, n_iface=20)
    print(f"[quickstart] {decomp.n_sub} space-time subdomains, "
          f"{int(topo.edge_mask.sum()) // 2} interfaces, {topo.n_slots} exchange slots")

    model_cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    batch = make_batch(decomp, topo, pde, n_res=1000, n_bnd=80,
                       rng=np.random.default_rng(0))
    trainer = ReferenceTrainer(pde, model_cfg, topo,
                               DDConfig(method=XPINN, residual_path=args.path),
                               lrs=2e-3)
    state = trainer.init(0)
    done = 0
    if args.resume and not args.supervised:
        state = restore_train_state(args.resume, state)
        done = int(state.step)
        print(f"[quickstart] resumed from {args.resume} at step {done}")
    b = batch.device_arrays()

    if args.supervised:
        from repro.runtime import (ChaosInjector, Supervisor, SupervisorConfig,
                                   elastic_resume, parse_faults)

        if args.resume:
            state, meta = elastic_resume(args.resume, trainer, decomp)
            done = int(np.asarray(state.step))
            sig = (meta.get("supervisor") or {}).get("decomp") or {}
            old_n = sig.get("n_sub", decomp.n_sub)
            print(f"[quickstart] elastic resume from {args.resume} at step "
                  f"{done} (checkpoint n_sub={old_n} -> {decomp.n_sub})")
        chunk = max(args.chunk, 1)
        cfg_sup = SupervisorConfig(
            chunk_steps=chunk,
            ckpt_every_chunks=(max(1, args.save_every // chunk)
                               if args.save_every else 1))
        # ChaosInjector so storage faults (ckpt.bit_flip@2, ...) compose with
        # the compute matrix in the same --inject spec; without any it behaves
        # exactly like the plain FaultInjector
        injector = (ChaosInjector(parse_faults(args.inject),
                                  roots={"ckpt": args.ckpt})
                    if args.inject else None)
        sup = Supervisor(trainer, args.ckpt, cfg_sup, injector, decomp=decomp)
        state, report = sup.run(state, b, args.steps)
        for ev in report.events:
            print(f"[supervisor] {ev}")
        print(f"[supervisor] chunks={report.chunks} restarts={report.restarts}"
              f" crashes={report.crashes} guard_trips={report.guard_trips} "
              f"stragglers={report.stragglers} corruptions={report.corruptions}")
        err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes,
                          pde)
        print(f"[quickstart] final rel L2 error vs Cole-Hopf exact: {err:.4f}")
        assert err < 0.5, "did not converge"
        return

    report_every = 250
    t0 = time.time()
    t_done = done
    while done < args.steps:
        n = min(max(args.chunk, 1), args.steps - done)
        if args.chunk <= 1:
            state, terms = trainer.step(state, b)
            n, last_loss = 1, float(np.asarray(terms["loss"]).sum())
        else:
            state, terms = trainer.run_chunk(state, b, n)
            last_loss = float(np.asarray(terms["loss"])[-1].sum())
        prev, done = done, done + n
        if args.save_every and done // args.save_every > prev // args.save_every:
            save_train_state(args.ckpt, state)
        if done == args.steps or done // report_every > prev // report_every:
            err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes, pde)
            print(f"[quickstart] step {done:5d} loss={last_loss:8.4f} rel_L2={err:.4f} "
                  f"({(done - t_done)/(time.time()-t0):.1f} it/s)")

    err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes, pde)
    print(f"[quickstart] final rel L2 error vs Cole-Hopf exact: {err:.4f}")
    assert err < 0.5, "did not converge"


if __name__ == "__main__":
    main()
