"""Quickstart: solve viscous Burgers with a space-time XPINN (paper §7.5).

The end-to-end driver for the paper's workload: decompose (-1,1) x (0,1) into
2x2 space-time subdomains, one network each, train a few hundred steps, and
validate against the Cole-Hopf exact solution.

    PYTHONPATH=src python examples/quickstart.py [--steps 1500]
"""
import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.core import (  # noqa: E402
    Burgers1D, CartesianDecomposition, DDConfig, ReferenceTrainer, XPINN,
    build_topology, evaluate_l2, restore_train_state, save_train_state,
)
from repro.core.nets import MLPConfig, SubdomainModelConfig  # noqa: E402
from repro.data import make_batch  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1500)
    ap.add_argument("--nx", type=int, default=2)
    ap.add_argument("--nt", type=int, default=2)
    ap.add_argument("--path", choices=("jvp", "pallas"), default="pallas",
                    help="residual evaluation: fused kernel (default) or the "
                         "per-point jvp oracle")
    ap.add_argument("--chunk", type=int, default=250,
                    help="outer steps per device dispatch (lax.scan driver); "
                         "1 falls back to the per-step jit loop")
    ap.add_argument("--save-every", type=int, default=0,
                    help="checkpoint the TrainState every N steps (0 = off)")
    ap.add_argument("--ckpt", default="ckpt_quickstart",
                    help="checkpoint directory for --save-every")
    ap.add_argument("--resume", default=None, metavar="DIR",
                    help="resume from the latest checkpoint under DIR")
    args = ap.parse_args()

    pde = Burgers1D()
    decomp = CartesianDecomposition(((-1, 1), (0, 1)), args.nx, args.nt)
    topo = build_topology(decomp, n_iface=20)
    print(f"[quickstart] {decomp.n_sub} space-time subdomains, "
          f"{int(topo.edge_mask.sum()) // 2} interfaces, {topo.n_slots} exchange slots")

    model_cfg = SubdomainModelConfig(nets={"u": MLPConfig(2, 1, 24, 4)})
    batch = make_batch(decomp, topo, pde, n_res=1000, n_bnd=80,
                       rng=np.random.default_rng(0))
    trainer = ReferenceTrainer(pde, model_cfg, topo,
                               DDConfig(method=XPINN, residual_path=args.path),
                               lrs=2e-3)
    state = trainer.init(0)
    done = 0
    if args.resume:
        state = restore_train_state(args.resume, state)
        done = int(state.step)
        print(f"[quickstart] resumed from {args.resume} at step {done}")
    b = batch.device_arrays()

    report_every = 250
    t0 = time.time()
    t_done = done
    while done < args.steps:
        n = min(max(args.chunk, 1), args.steps - done)
        if args.chunk <= 1:
            state, terms = trainer.step(state, b)
            n, last_loss = 1, float(np.asarray(terms["loss"]).sum())
        else:
            state, terms = trainer.run_chunk(state, b, n)
            last_loss = float(np.asarray(terms["loss"])[-1].sum())
        prev, done = done, done + n
        if args.save_every and done // args.save_every > prev // args.save_every:
            save_train_state(args.ckpt, state)
        if done == args.steps or done // report_every > prev // report_every:
            err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes, pde)
            print(f"[quickstart] step {done:5d} loss={last_loss:8.4f} rel_L2={err:.4f} "
                  f"({(done - t_done)/(time.time()-t0):.1f} it/s)")

    err = evaluate_l2(decomp, model_cfg, state.params, trainer.act_codes, pde)
    print(f"[quickstart] final rel L2 error vs Cole-Hopf exact: {err:.4f}")
    assert err < 0.5, "did not converge"


if __name__ == "__main__":
    main()
